//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network or registry access (see
//! `tango::util` — every other framework dependency is likewise replaced by
//! a local implementation), so this vendored crate provides exactly the
//! surface the workspace uses:
//!
//! - [`Result`] / [`Error`] — a boxed dynamic error with `?`-conversion
//!   from any `std::error::Error`;
//! - [`anyhow!`] — build an error from a format string or a displayable
//!   value;
//! - [`bail!`] — early-return an `Err(anyhow!(...))`.
//!
//! `{:#}` formatting walks the source chain like real `anyhow` does.

use std::error::Error as StdError;
use std::fmt;

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error.
///
/// Deliberately does **not** implement `std::error::Error` itself so the
/// blanket `From<E: std::error::Error>` impl (which powers `?`) does not
/// overlap with `impl From<T> for T`.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// A plain-message error payload.
struct Message(String);

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

impl Error {
    /// Error from a displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { inner: Box::new(Message(msg.to_string())) }
    }

    /// Error wrapping a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Self {
        Error { inner: Box::new(err) }
    }

    /// The wrapped error.
    pub fn root(&self) -> &(dyn StdError + Send + Sync + 'static) {
        &*self.inner
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        while let Some(s) = source {
            write!(f, "\n\ncaused by: {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::new(err)
    }
}

/// Construct an [`Error`] from a format string (+args) or any `Display`
/// value: `anyhow!("bad {x}")`, `anyhow!("{}: {e}", path)`, `anyhow!(e)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn macro_arms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b = anyhow!("value {x} and {}", 4);
        assert_eq!(b.to_string(), "value 3 and 4");
        let s = String::from("owned message");
        let c = anyhow!(s);
        assert_eq!(c.to_string(), "owned message");
    }

    #[test]
    fn bail_returns_err() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("refused {}", 7);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "refused 7");
    }

    #[test]
    fn alternate_format_walks_sources() {
        let e = Error::new(io_err());
        let plain = format!("{e}");
        let alt = format!("{e:#}");
        assert!(alt.starts_with(&plain));
    }
}
