//! Compile-complete **stub** of the `xla` (xla_extension / PJRT) bindings.
//!
//! The build environment ships no XLA shared library, so this crate mirrors
//! the API surface `tango::runtime` consumes and fails *at runtime* with a
//! clear error instead of failing the build. `PjRtClient::cpu()` errors
//! immediately, so `Runtime::open` reports the runtime as unavailable and
//! every PJRT-backed test skips — the documented behaviour when
//! `make artifacts` has not produced a usable XLA installation.
//!
//! Swap this path dependency for the real `xla` bindings (and rebuild) to
//! execute the jax-lowered HLO artifacts.

use std::fmt;

/// Stub error: the PJRT runtime is not present in this build.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: XLA/PJRT unavailable (stub build — install xla_extension and point \
             the `xla` dependency at the real bindings)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types crossing the runtime boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 64-bit float (unused by tango; keeps `other` match arms reachable).
    F64,
    /// 32-bit signed integer.
    S32,
    /// 8-bit signed integer.
    S8,
    /// Predicate / boolean.
    Pred,
}

/// Element types [`Literal::vec1`] / [`Literal::to_vec`] can carry.
pub trait NativeType: Copy {
    /// The runtime element-type tag.
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    shape: ArrayShape,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { shape: ArrayShape { dims: vec![data.len() as i64], ty: T::TY } }
    }

    /// Reshape (stub: errors — no backing buffer exists).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    /// Build from raw bytes (stub: errors).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        let _ = ty;
        Err(Error::unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    /// Shape of the literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    /// Copy out as a typed vector (stub: errors).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    /// Flatten a tuple literal (stub: errors).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal { shape: ArrayShape { dims: Vec::new(), ty: ElementType::F32 } }
    }
}

/// An HLO module parsed from text (stub: never constructed).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file (stub: errors).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal (stub: errors).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with positional arguments (stub: errors).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client (stub: construction fails, gating the whole runtime).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU client — the gate every runtime consumer hits first.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation (stub: errors).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_is_gated() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_shapes_flow_without_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[3]);
        assert_eq!(s.ty(), ElementType::F32);
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.reshape(&[3, 1]).is_err());
    }
}
