"""Pallas SPMM over a padded-CSR (ELL) layout.

The paper's SPMM randomly gathers node-feature rows per edge. On TPU the
idiomatic layout is ELL/padded-CSR: per destination node a fixed-width list
of in-neighbour ids plus a validity mask, so the gather vectorises and the
HBM→VMEM schedule is expressible with BlockSpec (row blocks of the
neighbour table; the feature table rides along whole — on real TPU it would
sit in HBM with per-block DMA, see DESIGN.md §Hardware-Adaptation).

The quantized variant takes int8 features + the fused ``s_α·s_h`` scale and
accumulates in int32 before one dequantizing multiply — the paper's
"dedicated quantization kernel, then random access to the small tensor".
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Destination-node rows per block.
BLOCK_ROWS = 64


def _spmm_kernel(nbr_ref, w_ref, h_ref, o_ref):
    nbr = nbr_ref[...]          # [B, P] int32 (invalid entries point at row 0)
    w = w_ref[...]              # [B, P] f32 (mask folded into the weight)
    h = h_ref[...]              # [N, F] f32 — the randomly-gathered operand
    gathered = jnp.take(h, nbr, axis=0)       # [B, P, F]
    o_ref[...] = jnp.sum(gathered * w[..., None], axis=1)


def _qspmm_kernel(deq_ref, nbr_ref, w_ref, qh_ref, o_ref):
    nbr = nbr_ref[...]
    w = w_ref[...]              # int32 quantized edge weights (mask folded)
    qh = qh_ref[...]            # [N, F] int8 quantized features
    gathered = jnp.take(qh, nbr, axis=0).astype(jnp.int32)
    acc = jnp.sum(gathered * w[..., None].astype(jnp.int32), axis=1)
    o_ref[...] = acc.astype(jnp.float32) * deq_ref[0, 0]


def spmm(nbr, weight, h):
    """FP32 padded-CSR SPMM: ``out[v] = Σ_p weight[v,p] · h[nbr[v,p]]``.

    ``weight`` must already carry the padding mask (0 on invalid slots).
    """
    n, p = nbr.shape
    f = h.shape[1]
    grid = (max(1, -(-n // BLOCK_ROWS)),)
    return pl.pallas_call(
        _spmm_kernel,
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, p), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, p), lambda i: (i, 0)),
            pl.BlockSpec(h.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, f), lambda i: (i, 0)),
        interpret=True,
    )(nbr, weight, h)


def qspmm(nbr, qweight, qh, weight_scale, h_scale):
    """Quantized SPMM: int8 weights and features, int32 accumulation, one
    fused ``s_w·s_h`` dequantizing multiply (paper §3.3)."""
    n, p = nbr.shape
    f = qh.shape[1]
    grid = (max(1, -(-n // BLOCK_ROWS)),)
    deq = (jnp.asarray(weight_scale, jnp.float32) * jnp.asarray(h_scale, jnp.float32)).reshape(1, 1)
    return pl.pallas_call(
        _qspmm_kernel,
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, p), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, p), lambda i: (i, 0)),
            pl.BlockSpec(qh.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, f), lambda i: (i, 0)),
        interpret=True,
    )(deq, nbr, qweight.astype(jnp.int32), qh)
