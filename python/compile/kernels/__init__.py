"""Layer-1 Pallas kernels for Tango (quantize, quantized GEMM, SPMM, SDDMM).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers them to plain HLO ops that the
Rust runtime can load and run. Correctness is pinned against the pure-jnp
oracles in :mod:`compile.kernels.ref` by the pytest suite.

Hardware adaptation (DESIGN.md section Hardware-Adaptation): the paper's
CUDA concepts map to Pallas/TPU as BlockSpec-tiled HBM->VMEM staging
(shared memory), ``jax.lax.dot_general`` with
``preferred_element_type=int32`` on int8 blocks (DP4A / int8 MXU), and a
counter-based in-kernel PRNG (register-resident cuRAND state).
"""
