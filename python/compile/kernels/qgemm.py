"""Pallas quantized GEMM with on-the-fly quantization (paper §3.3, Fig. 4).

The GPU kernel quantizes tiles while staging global→shared memory and runs
DP4A on packed int8. The TPU mapping: BlockSpec stages HBM→VMEM tiles, the
kernel quantizes the f32 block in VMEM, and the int8×int8→int32 contraction
targets the MXU via ``dot_general(..., preferred_element_type=int32)``.
Dequantization by ``s_A·s_B`` is fused into the store (step 4 of Fig. 4).

Grid is (M/bm, N/bn, K/bk); the output block plays the role of the
register-resident C accumulator (each K step folds its dequantized partial
in — same value as accumulating in int32 and dequantizing once, since the
scale is constant across K steps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Block sizes. Three 128×128 f32/int8 tiles stay far under the ~16 MiB
#: VMEM budget; 128 is the MXU-native tile edge.
BM, BN, BK = 128, 128, 128


def _qgemm_kernel(sa_ref, sb_ref, a_ref, b_ref, o_ref, *, qmax):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]
    # On-the-fly quantization of the staged blocks (quantize-at-load).
    # nan_to_num: interpret-mode Pallas pads partial K-blocks with NaN, and
    # NaN→int8 is undefined once the HLO is AOT-compiled — zero the padding
    # so it cannot contribute to the contraction.
    a_blk = jnp.nan_to_num(a_ref[...], nan=0.0)
    b_blk = jnp.nan_to_num(b_ref[...], nan=0.0)
    qa = jnp.clip(jnp.round(a_blk / sa), -qmax, qmax).astype(jnp.int8)
    qb = jnp.clip(jnp.round(b_blk / sb), -qmax, qmax).astype(jnp.int8)
    # int8 × int8 → int32 contraction (DP4A / int8-MXU analogue), with the
    # fused dequantization folded into the accumulation.
    acc = jax.lax.dot_general(qa, qb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    o_ref[...] += acc.astype(jnp.float32) * (sa * sb)


def qgemm(a, b, bits: int = 8):
    """Quantized GEMM: f32 [M,K]·[K,N] → (f32 [M,N], out_scale).

    Scales are the dynamic symmetric tensor scales of the inputs; the
    output's own scale is returned for the next primitive (the fused `s`
    computation of Fig. 4).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    sa = ref.scale_for(a, bits)
    sb = ref.scale_for(b, bits)
    qmax = float(ref.qmax_for_bits(bits))
    grid = (max(1, -(-m // BM)), max(1, -(-n // BN)), max(1, -(-k // BK)))
    kernel = functools.partial(_qgemm_kernel, qmax=qmax)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        interpret=True,
    )(sa.reshape(1, 1), sb.reshape(1, 1), a, b)
    out_scale = ref.scale_for(out, bits)
    return out, out_scale
