"""Pallas quantize kernel with GPU-style stochastic rounding (paper §3.2).

The paper keeps xoshiro256++ state in registers; the TPU-idiomatic
equivalent is a *counter-based* generator — each element mixes its global
index with the seed through an avalanche hash (splitmix64/xxhash-style
finalizer), entirely in registers on the VPU, no state array at all.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Rows per VMEM block. 128×F f32 blocks stay far under the ~16 MiB VMEM
#: budget for the feature widths this library uses (F ≤ 1024 ⇒ ≤ 0.5 MiB).
BLOCK_ROWS = 128


def _mix32(idx, seed):
    """Counter-based PRNG: avalanche-mix (index, seed) -> uniform [0,1).

    A 32-bit xorshift-multiply finalizer (murmur3/splitmix-style): every
    output bit depends on every input bit; adjacent indices decorrelate.
    """
    x = idx.astype(jnp.uint32) ^ jnp.uint32(seed & 0xFFFFFFFF)
    x = x * jnp.uint32(0x9E3779B1)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # Top 24 bits -> [0,1).
    return (x >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def _quantize_kernel(scale_ref, x_ref, o_ref, *, qmax, seed, stochastic, cols):
    pid = pl.program_id(0)
    x = x_ref[...]
    scaled = x / scale_ref[0, 0]
    if stochastic:
        # Global element index for the counter-based stream.
        base = pid * BLOCK_ROWS * cols
        rows, c = x.shape
        idx = base + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 0) * c \
            + jax.lax.broadcasted_iota(jnp.int32, (rows, c), 1)
        u = _mix32(idx, seed)
        f = jnp.floor(scaled)
        q = jnp.where(u < scaled - f, f + 1.0, f)
    else:
        q = jnp.round(scaled)
    o_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def quantize(x, bits: int = 8, stochastic: bool = False, seed: int = 0):
    """Quantize a rank-2 f32 array to int8 with a dynamic symmetric scale.

    Returns ``(q_int8, scale)``. The scale is the one abs-max reduction
    dynamic quantization needs (fused into the producer on the GPU; a
    separate cheap reduction here). It enters the kernel as a (1,1) scalar
    input block — the Pallas analogue of a kernel parameter.
    """
    assert x.ndim == 2, "quantize kernel expects rank-2"
    scale = ref.scale_for(x, bits)
    n, cols = x.shape
    grid = (max(1, -(-n // BLOCK_ROWS)),)
    kernel = functools.partial(
        _quantize_kernel,
        qmax=float(ref.qmax_for_bits(bits)),
        seed=seed,
        stochastic=stochastic,
        cols=cols,
    )
    q = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, cols), jnp.int8),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        interpret=True,
    )(scale.reshape(1, 1), x)
    return q, scale
