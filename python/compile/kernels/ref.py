"""Pure-jnp correctness oracles for every Pallas kernel.

These define the semantics; the kernels must match them (pytest asserts
allclose under hypothesis-driven shape/value sweeps).
"""

import jax.numpy as jnp

#: Symmetric quantization grid maximum for B bits.
def qmax_for_bits(bits: int) -> int:
    assert 2 <= bits <= 8
    return (1 << (bits - 1)) - 1


def scale_for(x, bits: int):
    """Dynamic symmetric tensor-level scale: absmax / qmax (1.0 if zero)."""
    absmax = jnp.max(jnp.abs(x))
    return jnp.where(absmax == 0.0, 1.0, absmax / qmax_for_bits(bits))


def quantize_nearest(x, scale, bits: int):
    """Eq. 1 with Z=0, round-to-nearest."""
    q = jnp.clip(jnp.round(x / scale), -qmax_for_bits(bits), qmax_for_bits(bits))
    return q.astype(jnp.int8)


def dequantize(q, scale):
    """Eq. 2 with Z=0."""
    return q.astype(jnp.float32) * scale


def qgemm(a, b, bits: int = 8):
    """Quantized GEMM oracle: quantize inputs, int32 matmul, dequantize.

    Returns (out_f32, out_scale) like the fused kernel.
    """
    sa = scale_for(a, bits)
    sb = scale_for(b, bits)
    qa = quantize_nearest(a, sa, bits).astype(jnp.int32)
    qb = quantize_nearest(b, sb, bits).astype(jnp.int32)
    acc = qa @ qb
    out = acc.astype(jnp.float32) * (sa * sb)
    return out, scale_for(out, bits)


def spmm_padded(nbr, mask, weight, h):
    """Padded-CSR SPMM oracle.

    out[v] = sum_p mask[v,p] * weight[v,p] * h[nbr[v,p]]
    nbr: [N,P] int32, mask/weight: [N,P] f32, h: [N,F] f32 -> [N,F].
    """
    gathered = h[nbr]                          # [N,P,F]
    w = (mask * weight)[..., None]             # [N,P,1]
    return jnp.sum(gathered * w, axis=1)


def sddmm_add(src, dst, s, d):
    """SDDMM-add oracle: out[e,h] = s[src[e],h] + d[dst[e],h]."""
    return s[src] + d[dst]


def sddmm_dot(src, dst, a, b, heads: int):
    """SDDMM-dot oracle: out[e,h] = sum_d a[dst[e],(h,d)] * b[src[e],(h,d)]."""
    e = src.shape[0]
    dd = a.shape[1] // heads
    av = a[dst].reshape(e, heads, dd)
    bv = b[src].reshape(e, heads, dd)
    return jnp.sum(av * bv, axis=-1)


def edge_softmax_padded(logits, mask):
    """Per-row masked softmax over the padded in-edge axis.

    logits/mask: [N,P] -> alpha [N,P] with sum over valid p = 1.
    """
    neg = jnp.where(mask > 0, logits, -jnp.inf)
    m = jnp.max(neg, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    ex = jnp.where(mask > 0, jnp.exp(neg - m), 0.0)
    denom = jnp.sum(ex, axis=1, keepdims=True)
    return jnp.where(denom > 0, ex / jnp.maximum(denom, 1e-30), 0.0)
