"""Pallas SDDMM kernels (paper §3.3).

Two variants with the paper's quantization rule:

- **add** (attention logits, Fig. 1a step 3): different operand scales do
  not factor through addition, so the kernel loads the small int8 tensors
  and **dequantizes on the fly** per element;
- **dot** (attention gradient, Fig. 1b step 5): multiplication commutes
  with the scales, so the kernel works **directly on quantized values**
  in int32 and applies the fused ``s_0·s_1`` once.

Edge-parallel layout: the edge list (src/dst id per edge) is blocked over
the grid; endpoint feature tables ride along for the gather.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Edges per block.
BLOCK_EDGES = 256


def _sddmm_add_kernel(ss_ref, sd_ref, src_ref, dst_ref, qs_ref, qd_ref, o_ref):
    src = src_ref[...][:, 0]
    dst = dst_ref[...][:, 0]
    # On-the-fly dequantization: each operand with its own scale.
    s = jnp.take(qs_ref[...], src, axis=0).astype(jnp.float32) * ss_ref[0, 0]
    d = jnp.take(qd_ref[...], dst, axis=0).astype(jnp.float32) * sd_ref[0, 0]
    o_ref[...] = s + d


def _sddmm_dot_kernel(deq_ref, src_ref, dst_ref, qa_ref, qb_ref, o_ref, *, heads):
    src = src_ref[...][:, 0]
    dst = dst_ref[...][:, 0]
    a = jnp.take(qa_ref[...], dst, axis=0).astype(jnp.int32)   # [B, H*D]
    b = jnp.take(qb_ref[...], src, axis=0).astype(jnp.int32)
    e, hd = a.shape
    d = hd // heads
    prod = (a * b).reshape(e, heads, d)
    # Direct quantized multiply-accumulate; single fused dequantization.
    o_ref[...] = jnp.sum(prod, axis=-1).astype(jnp.float32) * deq_ref[0, 0]


def sddmm_add(src, dst, qs, qd, s_scale, d_scale):
    """Quantized SDDMM-add: ``out[e] = deq(qs[src[e]]) + deq(qd[dst[e]])``."""
    e = src.shape[0]
    heads = qs.shape[1]
    grid = (max(1, -(-e // BLOCK_EDGES)),)
    ss = jnp.asarray(s_scale, jnp.float32).reshape(1, 1)
    sd = jnp.asarray(d_scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _sddmm_add_kernel,
        out_shape=jax.ShapeDtypeStruct((e, heads), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_EDGES, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_EDGES, 1), lambda i: (i, 0)),
            pl.BlockSpec(qs.shape, lambda i: (0, 0)),
            pl.BlockSpec(qd.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_EDGES, heads), lambda i: (i, 0)),
        interpret=True,
    )(ss, sd, src[:, None], dst[:, None], qs, qd)


def sddmm_dot(src, dst, qa, qb, a_scale, b_scale, heads: int):
    """Quantized SDDMM-dot: per-head int32 dot of quantized endpoint rows,
    one fused ``s_a·s_b`` dequantization."""
    e = src.shape[0]
    grid = (max(1, -(-e // BLOCK_EDGES)),)
    kernel = functools.partial(_sddmm_dot_kernel, heads=heads)
    deq = (jnp.asarray(a_scale, jnp.float32) * jnp.asarray(b_scale, jnp.float32)).reshape(1, 1)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((e, heads), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_EDGES, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_EDGES, 1), lambda i: (i, 0)),
            pl.BlockSpec(qa.shape, lambda i: (0, 0)),
            pl.BlockSpec(qb.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_EDGES, heads), lambda i: (i, 0)),
        interpret=True,
    )(deq, src[:, None], dst[:, None], qa, qb)
