"""Layer-2 JAX model: quantized GCN training step built on the Layer-1
Pallas kernels, with the explicit backward decomposition of paper §2.1 and
the §3.2 accuracy rules (quantized hidden layers, FP32 final layer, FP32
softmax/loss, FP32 weight update).

Graph representation is padded-CSR (ELL): ``nbr [N,P]`` int32 in-neighbour
ids and ``wgt [N,P]`` f32 normalised edge weights (0 on padding). The
datasets this library generates are symmetrised (reverse edges + self
loops), so the normalised adjacency is symmetric and the backward SPMM
(`Âᵀ·∂Z`) reuses the same table — asserted by the AOT smoke test.

Everything here is lowered ONCE by ``aot.py`` into HLO text; Python never
runs at training time.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import qgemm as qgemm_mod
from .kernels import quantize as quantize_mod
from .kernels import ref
from .kernels import spmm as spmm_mod


def relu(x):
    return jnp.maximum(x, 0.0)


def masked_softmax_xent(logits, onehot, mask):
    """Mean CE over masked rows; returns (loss, dlogits) — FP32 (§3.2)."""
    m = jnp.max(logits, axis=1, keepdims=True)
    ex = jnp.exp(logits - m)
    p = ex / jnp.sum(ex, axis=1, keepdims=True)
    logp = logits - m - jnp.log(jnp.sum(ex, axis=1, keepdims=True))
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(mask[:, None] * onehot * logp) / n
    dlogits = (p - onehot) * mask[:, None] / n
    return loss, dlogits


def gcn_forward(x, w1, w2, nbr, wgt, bits: int = 8):
    """Two-layer quantized GCN forward (hidden layer quantized, final FP32).

    Returns logits ``[N, C]``.
    """
    # Layer 1 (quantized): GEMM with on-the-fly quantization, then the
    # dedicated-quantize + quantized SPMM pipeline of §3.3.
    xw1, s_xw1 = qgemm_mod.qgemm(x, w1, bits)
    q_xw1, s_h = quantize_mod.quantize(xw1, bits)
    q_wgt, s_w = quantize_mod.quantize(wgt, bits)
    z1 = spmm_mod.qspmm(nbr, q_wgt, q_xw1, s_w, s_h)
    h1 = relu(z1)
    del s_xw1
    # Layer 2 (FP32 — the layer before Softmax stays full precision, §3.2).
    hw2 = h1 @ w2
    logits = spmm_mod.spmm(nbr, wgt, hw2)
    return logits


def gcn_train_step(x, onehot, mask, w1, w2, nbr, wgt, bits: int = 8, lr: float = 0.05):
    """One quantized GCN training step (fwd + analytic bwd + FP32 update).

    Returns ``(loss, new_w1, new_w2)``.
    """
    # ---- forward (caching what backward reuses) ----
    xw1, _ = qgemm_mod.qgemm(x, w1, bits)
    q_xw1, s_h = quantize_mod.quantize(xw1, bits)
    q_wgt, s_w = quantize_mod.quantize(wgt, bits)
    z1 = spmm_mod.qspmm(nbr, q_wgt, q_xw1, s_w, s_h)
    h1 = relu(z1)
    hw2 = h1 @ w2
    logits = spmm_mod.spmm(nbr, wgt, hw2)
    # ---- loss (FP32) ----
    loss, dlogits = masked_softmax_xent(logits, onehot, mask)
    # ---- backward (Fig. 1b decomposition; Â symmetric ⇒ Âᵀ = Â) ----
    dhw2 = spmm_mod.spmm(nbr, wgt, dlogits)          # ∂(H1·W2) = Âᵀ·∂logits
    dw2 = h1.T @ dhw2                                 # FP32 (pre-softmax layer)
    dh1 = dhw2 @ w2.T
    dz1 = jnp.where(z1 > 0.0, dh1, 0.0)
    # Quantize ∂Z1 once; the backward SPMM and both backward GEMMs share it
    # (the inter-primitive cache rule, §3.3).
    q_dz1, s_dz = quantize_mod.quantize(dz1, bits)
    dxw1 = spmm_mod.qspmm(nbr, q_wgt, q_dz1, s_w, s_dz)  # Âᵀ·∂Z1
    dw1, _ = qgemm_mod.qgemm(x.T, dxw1, bits)            # ∂W1 = Xᵀ·∂(XW1)
    # ---- FP32 weight update (§3.2, Eq. 6) ----
    return loss, w1 - lr * dw1, w2 - lr * dw2


def gcn_train_step_fp32(x, onehot, mask, w1, w2, nbr, wgt, lr: float = 0.05):
    """The DGL-baseline step: same decomposition, all FP32 primitives."""
    xw1 = x @ w1
    z1 = spmm_mod.spmm(nbr, wgt, xw1)
    h1 = relu(z1)
    hw2 = h1 @ w2
    logits = spmm_mod.spmm(nbr, wgt, hw2)
    loss, dlogits = masked_softmax_xent(logits, onehot, mask)
    dhw2 = spmm_mod.spmm(nbr, wgt, dlogits)
    dw2 = h1.T @ dhw2
    dh1 = dhw2 @ w2.T
    dz1 = jnp.where(z1 > 0.0, dh1, 0.0)
    dxw1 = spmm_mod.spmm(nbr, wgt, dz1)
    dw1 = x.T @ dxw1
    return loss, w1 - lr * dw1, w2 - lr * dw2


def reference_train_step(x, onehot, mask, w1, w2, nbr, wgt, lr: float = 0.05):
    """jax.grad oracle for the FP32 step (pytest cross-checks the manual
    backward against autodiff)."""

    def loss_fn(params):
        w1_, w2_ = params
        xw1 = x @ w1_
        z1 = ref.spmm_padded(nbr, (wgt != 0).astype(jnp.float32), wgt, xw1)
        h1 = relu(z1)
        hw2 = h1 @ w2_
        logits = ref.spmm_padded(nbr, (wgt != 0).astype(jnp.float32), wgt, hw2)
        loss, _ = masked_softmax_xent(logits, onehot, mask)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)((w1, w2))
    return loss, w1 - lr * grads[0], w2 - lr * grads[1]


def make_train_step(bits: int = 8, lr: float = 0.05, quantized: bool = True):
    """The jit-able entry point ``aot.py`` lowers."""
    if quantized:
        return functools.partial(gcn_train_step, bits=bits, lr=lr)
    return functools.partial(gcn_train_step_fp32, lr=lr)
