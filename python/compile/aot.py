"""AOT lowering: Layer-1/2 entry points → HLO text artifacts + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(behind the Rust ``xla`` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
``artifacts`` target). Python never runs after this step.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import qgemm, quantize, sddmm, spmm

# ---- exported problem sizes -------------------------------------------------
# Fixed shapes for the end-to-end train-step artifact: a 2048-node graph
# with padded in-degree 8, 64-d features, 64 hidden units, 8 classes.
N, P, F, H, C = 2048, 8, 64, 64, 8
# Primitive-artifact shapes (micro-benchable from Rust).
GM, GK, GN = 256, 128, 64
E = 4096


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries():
    """(name, fn, example_args, description) for every artifact."""
    f32 = jnp.float32
    i32 = jnp.int32
    i8 = jnp.int8
    out = []
    # --- end-to-end quantized GCN train step (the quickstart driver) ---
    out.append((
        "gcn_train_step",
        model.make_train_step(bits=8, lr=0.05, quantized=True),
        (spec((N, F)), spec((N, C)), spec((N,)), spec((F, H)), spec((H, C)),
         spec((N, P), i32), spec((N, P))),
        "quantized 2-layer GCN: fwd + analytic bwd + FP32 SGD update "
        "-> (loss, new_w1, new_w2)",
    ))
    out.append((
        "gcn_train_step_fp32",
        model.make_train_step(lr=0.05, quantized=False),
        (spec((N, F)), spec((N, C)), spec((N,)), spec((F, H)), spec((H, C)),
         spec((N, P), i32), spec((N, P))),
        "FP32 baseline GCN train step -> (loss, new_w1, new_w2)",
    ))
    out.append((
        "gcn_forward",
        lambda x, w1, w2, nbr, wgt: (model.gcn_forward(x, w1, w2, nbr, wgt),),
        (spec((N, F)), spec((F, H)), spec((H, C)), spec((N, P), i32), spec((N, P))),
        "quantized GCN inference -> (logits,)",
    ))
    # --- primitive artifacts (runtime micro-tests / benches) ---
    out.append((
        "quantize8",
        lambda x: quantize.quantize(x, 8),
        (spec((GM, GK)),),
        "dynamic symmetric INT8 quantization -> (q, scale)",
    ))
    out.append((
        "qgemm8",
        lambda a, b: qgemm.qgemm(a, b, 8),
        (spec((GM, GK)), spec((GK, GN))),
        "fused on-the-fly-quantized GEMM -> (out, out_scale)",
    ))
    out.append((
        "spmm_f32",
        lambda nbr, wgt, h: (spmm.spmm(nbr, wgt, h),),
        (spec((N, P), i32), spec((N, P)), spec((N, GN))),
        "padded-CSR FP32 SPMM -> (out,)",
    ))
    out.append((
        "qspmm8",
        lambda nbr, qw, qh, sw, sh: (spmm.qspmm(nbr, qw, qh, sw, sh),),
        (spec((N, P), i32), spec((N, P), i8), spec((N, GN), i8), spec(()), spec(())),
        "quantized padded-CSR SPMM -> (out,)",
    ))
    out.append((
        "qsddmm_add8",
        lambda src, dst, qs, qd, ss, sd: (sddmm.sddmm_add(src, dst, qs, qd, ss, sd),),
        (spec((E,), i32), spec((E,), i32), spec((N, 4), i8), spec((N, 4), i8), spec(()), spec(())),
        "quantized SDDMM-add w/ on-the-fly dequantization -> (edge_feat,)",
    ))
    out.append((
        "qsddmm_dot8",
        lambda src, dst, qa, qb, sa, sb: (sddmm.sddmm_dot(src, dst, qa, qb, sa, sb, 4),),
        (spec((E,), i32), spec((E,), i32), spec((N, 32), i8), spec((N, 32), i8), spec(()), spec(())),
        "quantized SDDMM-dot (direct quantized multiply) -> (edge_feat,)",
    ))
    return out


DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32", jnp.int8.dtype: "i8"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    for name, fn, example_args, desc in entries():
        text = to_hlo_text(fn, example_args)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        n_out = len(jax.eval_shape(fn, *example_args))
        manifest["artifacts"].append({
            "name": name,
            "file": path,
            "description": desc,
            "inputs": [
                {"shape": list(a.shape), "dtype": DTYPE_NAMES[a.dtype]}
                for a in example_args
            ],
            "num_outputs": n_out,
            "sizes": {"n": N, "p": P, "f": F, "h": H, "c": C,
                      "gm": GM, "gk": GK, "gn": GN, "e": E},
        })
        print(f"wrote {path} ({len(text)} chars, {n_out} outputs)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
