"""Build-time-only Python package: Layer-2 JAX model + Layer-1 Pallas
kernels + the AOT lowering that emits HLO text artifacts for the Rust
runtime. Never imported at training/serving time."""
