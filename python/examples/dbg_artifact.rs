use tango::runtime::{Runtime, Value};
use tango::tensor::Dense;
use tango::graph::generators::random_features;

fn main() -> tango::Result<()> {
    let mut rt = Runtime::open("artifacts")?;
    let spec = rt.manifest.get("gcn_forward").unwrap().clone();
    let (n, p, f, h, c) = (spec.sizes["n"], spec.sizes["p"], spec.sizes["f"], spec.sizes["h"], spec.sizes["c"]);
    // w1 = 0 -> logits must be all zero
    let x = random_features(n, f, 1);
    let w1 = Dense::<f32>::zeros(&[f, h]);
    let w2 = random_features(h, c, 2);
    let nbr = Dense::<i32>::zeros(&[n, p]);
    let mut wgt = Dense::<f32>::zeros(&[n, p]);
    for v in 0..n { wgt.set(v, 0, 1.0); }
    let out = rt.run("gcn_forward", &[Value::F32(x.clone()), Value::F32(w1), Value::F32(w2.clone()), Value::I32(nbr.clone()), Value::F32(wgt.clone())])?;
    let logits = out[0].as_f32()?;
    println!("zero-w1 logits absmax = {}", logits.abs_max());

    // identity-ish test: w1 = I (f==h), nbr self loops
    let mut w1 = Dense::<f32>::zeros(&[f, h]);
    for i in 0..f.min(h) { w1.set(i, i, 1.0); }
    let mut nbr2 = Dense::<i32>::zeros(&[n, p]);
    for v in 0..n { nbr2.set(v, 0, v as i32); }
    let out = rt.run("gcn_forward", &[Value::F32(x.clone()), Value::F32(w1), Value::F32(w2.clone()), Value::I32(nbr2), Value::F32(wgt)])?;
    let logits = out[0].as_f32()?;
    // expect logits ≈ relu(x_quantized) @ w2 (roughly bounded)
    println!("identity logits absmax = {} (x absmax {}, w2 absmax {})", logits.abs_max(), x.abs_max(), w2.abs_max());
    Ok(())
}
