"""Quantize kernel vs ref oracle, incl. stochastic-rounding statistics."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize, ref


def arr(rng, shape, lo=-3.0, hi=3.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 65),
    bits=st.sampled_from([2, 4, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nearest_matches_ref(rows, cols, bits, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (rows, cols))
    q, s = quantize.quantize(x, bits)
    want = ref.quantize_nearest(x, s, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want))
    # scale is the dynamic symmetric scale
    np.testing.assert_allclose(float(s), float(ref.scale_for(x, bits)), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 200), cols=st.integers(1, 33), seed=st.integers(0, 2**31 - 1))
def test_stochastic_within_one_grid_step(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, (rows, cols))
    q, s = quantize.quantize(x, 8, stochastic=True, seed=seed)
    # |x - deq(q)| <= one grid step for stochastic rounding.
    err = np.abs(np.asarray(x) - np.asarray(ref.dequantize(q, s)))
    assert err.max() <= float(s) * (1.0 + 1e-5)


def test_stochastic_rounding_is_unbiased():
    # E[deq(q(x))] -> x over many seeds.
    x = jnp.full((1, 64), 0.37123, dtype=jnp.float32) * jnp.linspace(0.1, 1.0, 64)
    x = x.reshape(1, 64).astype(jnp.float32)
    acc = np.zeros((1, 64), dtype=np.float64)
    n = 300
    for seed in range(n):
        q, s = quantize.quantize(x, 8, stochastic=True, seed=seed)
        acc += np.asarray(ref.dequantize(q, s), dtype=np.float64)
    mean = acc / n
    _, s = quantize.quantize(x, 8)
    # Bias well below half a grid step.
    assert np.abs(mean - np.asarray(x)).max() < 0.2 * float(s)


def test_zero_tensor_scale_one():
    x = jnp.zeros((16, 16), dtype=jnp.float32)
    q, s = quantize.quantize(x, 8)
    assert float(s) == 1.0
    assert np.all(np.asarray(q) == 0)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_range_respected(bits):
    rng = np.random.default_rng(7)
    x = arr(rng, (64, 8), -100.0, 100.0)
    q, _ = quantize.quantize(x, bits)
    qmax = ref.qmax_for_bits(bits)
    assert np.abs(np.asarray(q, dtype=np.int32)).max() <= qmax


def test_symmetric_zero_maps_to_zero():
    x = jnp.asarray([[-1.0, 0.0, 1.0, 0.0]], dtype=jnp.float32)
    q, _ = quantize.quantize(x, 8)
    assert np.asarray(q)[0, 1] == 0
    assert np.asarray(q)[0, 3] == 0
