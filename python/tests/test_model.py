"""Layer-2 model: manual backward vs jax.grad oracle; quantized step sanity;
training loop convergence on a planted-community graph."""

import numpy as np
import jax.numpy as jnp

from compile import model


def symmetric_padded_graph(rng, n, p):
    """Symmetric weighted padded graph (self-loops + undirected edges with a
    shared weight per pair) — the contract the exported model assumes
    (datasets are symmetrised, so Â = Âᵀ)."""
    nbr = np.zeros((n, p), dtype=np.int32)
    wgt = np.zeros((n, p), dtype=np.float32)
    fill = np.zeros(n, dtype=np.int64)
    for v in range(n):  # self loops
        nbr[v, 0] = v
        wgt[v, 0] = rng.uniform(0.1, 1.0)
        fill[v] = 1
    for _ in range(n * p):
        u, v = rng.integers(0, n, size=2)
        if u == v or fill[u] >= p or fill[v] >= p:
            continue
        w = rng.uniform(0.1, 1.0)
        nbr[u, fill[u]] = v
        wgt[u, fill[u]] = w
        fill[u] += 1
        nbr[v, fill[v]] = u
        wgt[v, fill[v]] = w
        fill[v] += 1
    return jnp.asarray(nbr), jnp.asarray(wgt)


def make_problem(rng, n=128, p=4, f=16, h=8, c=4):
    nbr, wgt = symmetric_padded_graph(rng, n, p)
    x = jnp.asarray(rng.normal(size=(n, f)), dtype=jnp.float32)
    labels = rng.integers(0, c, size=n)
    onehot = jnp.asarray(np.eye(c)[labels], dtype=jnp.float32)
    tmask = jnp.asarray(rng.integers(0, 2, size=n), dtype=jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(f, h)) * 0.3, dtype=jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(h, c)) * 0.3, dtype=jnp.float32)
    return x, onehot, tmask, w1, w2, nbr, wgt


def test_fp32_manual_backward_matches_autodiff():
    rng = np.random.default_rng(0)
    x, onehot, tmask, w1, w2, nbr, wgt = make_problem(rng)
    loss_m, w1_m, w2_m = model.gcn_train_step_fp32(x, onehot, tmask, w1, w2, nbr, wgt, lr=0.1)
    loss_r, w1_r, w2_r = model.reference_train_step(x, onehot, tmask, w1, w2, nbr, wgt, lr=0.1)
    np.testing.assert_allclose(float(loss_m), float(loss_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(w1_m), np.asarray(w1_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w2_m), np.asarray(w2_r), rtol=1e-4, atol=1e-5)


def test_quantized_step_close_to_fp32_step():
    rng = np.random.default_rng(1)
    x, onehot, tmask, w1, w2, nbr, wgt = make_problem(rng)
    loss_q, w1_q, w2_q = model.gcn_train_step(x, onehot, tmask, w1, w2, nbr, wgt, lr=0.1)
    loss_f, w1_f, w2_f = model.gcn_train_step_fp32(x, onehot, tmask, w1, w2, nbr, wgt, lr=0.1)
    assert abs(float(loss_q) - float(loss_f)) < 0.25
    # Updates point the same way (cosine similarity of the weight deltas).
    dq = (np.asarray(w1_q) - np.asarray(w1)).ravel()
    df = (np.asarray(w1_f) - np.asarray(w1)).ravel()
    cos = dq @ df / (np.linalg.norm(dq) * np.linalg.norm(df) + 1e-12)
    assert cos > 0.8, cos


def test_quantized_training_converges():
    # Planted structure: features = label centroid + noise; GCN must fit it.
    rng = np.random.default_rng(2)
    n, p, f, h, c = 128, 4, 16, 16, 4
    labels = rng.integers(0, c, size=n)
    centroids = rng.normal(size=(c, f)) * 2.0
    x = jnp.asarray(centroids[labels] + rng.normal(size=(n, f)) * 0.3, dtype=jnp.float32)
    onehot = jnp.asarray(np.eye(c)[labels], dtype=jnp.float32)
    tmask = jnp.ones((n,), dtype=jnp.float32)
    # homophilous padded graph: neighbours mostly same-label
    nbr_np = np.zeros((n, p), dtype=np.int32)
    for v in range(n):
        same = np.flatnonzero(labels == labels[v])
        nbr_np[v] = rng.choice(same, size=p)
    nbr = jnp.asarray(nbr_np)
    wgt = jnp.full((n, p), 1.0 / p, dtype=jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(f, h)) * 0.3, dtype=jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(h, c)) * 0.3, dtype=jnp.float32)
    losses = []
    for _ in range(25):
        loss, w1, w2 = model.gcn_train_step(x, onehot, tmask, w1, w2, nbr, wgt, lr=0.2)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_forward_shapes():
    rng = np.random.default_rng(3)
    x, onehot, tmask, w1, w2, nbr, wgt = make_problem(rng, n=96, c=4)
    logits = model.gcn_forward(x, w1, w2, nbr, wgt)
    assert logits.shape == (96, 4)
    assert np.all(np.isfinite(np.asarray(logits)))
