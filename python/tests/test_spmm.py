"""SPMM kernels (FP32 and quantized) vs the padded-CSR oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize, ref, spmm


def padded_graph(rng, n, p):
    nbr = jnp.asarray(rng.integers(0, n, size=(n, p)), dtype=jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, size=(n, p)), dtype=jnp.float32)
    wgt = jnp.asarray(rng.normal(size=(n, p)), dtype=jnp.float32) * mask
    return nbr, mask, wgt


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 300),
    p=st.integers(1, 12),
    f=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_fp32_matches_ref(n, p, f, seed):
    rng = np.random.default_rng(seed)
    nbr, mask, wgt = padded_graph(rng, n, p)
    h = jnp.asarray(rng.normal(size=(n, f)), dtype=jnp.float32)
    out = spmm.spmm(nbr, wgt, h)
    want = ref.spmm_padded(nbr, mask, wgt, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(4, 200), p=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_quantized_matches_dequantized_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    nbr, mask, wgt = padded_graph(rng, n, p)
    h = jnp.asarray(rng.normal(size=(n, 16)), dtype=jnp.float32)
    qw, sw = quantize.quantize(wgt, 8)
    qh, sh = quantize.quantize(h, 8)
    out = spmm.qspmm(nbr, qw, qh, sw, sh)
    # Exact semantics: the int32 accumulation of dequantized grids.
    want = ref.spmm_padded(
        nbr, jnp.ones_like(mask), ref.dequantize(qw, sw), ref.dequantize(qh, sh)
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_quantized_close_to_fp32():
    rng = np.random.default_rng(3)
    nbr, mask, wgt = padded_graph(rng, 128, 6)
    h = jnp.asarray(rng.normal(size=(128, 32)), dtype=jnp.float32)
    exact = np.asarray(ref.spmm_padded(nbr, mask, wgt, h))
    qw, sw = quantize.quantize(wgt, 8)
    qh, sh = quantize.quantize(h, 8)
    out = np.asarray(spmm.qspmm(nbr, qw, qh, sw, sh))
    rel = np.abs(out - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.1, rel


def test_isolated_node_rows_are_zero():
    n, p = 8, 4
    nbr = jnp.zeros((n, p), dtype=jnp.int32)
    wgt = jnp.zeros((n, p), dtype=jnp.float32)  # fully masked
    h = jnp.ones((n, 16), dtype=jnp.float32)
    out = np.asarray(spmm.spmm(nbr, wgt, h))
    assert np.all(out == 0.0)
