"""Quantized GEMM kernel vs oracle + accuracy bounds vs exact matmul."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import qgemm, ref


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 150),
    n=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_oracle(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.float32)
    out, s = qgemm.qgemm(a, b)
    want, ws = ref.qgemm(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(s), float(ws), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_error_bound_vs_exact(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(64, 128)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 32)), dtype=jnp.float32)
    out, _ = qgemm.qgemm(a, b)
    exact = np.asarray(a) @ np.asarray(b)
    rel = np.abs(np.asarray(out) - exact).max() / (np.abs(exact).max() + 1e-9)
    assert rel < 0.05, rel


def test_block_boundary_shapes():
    # Exercise exact multiples and off-by-one around BM/BN/BK = 128.
    rng = np.random.default_rng(0)
    for m, k, n in [(128, 128, 128), (129, 127, 1), (256, 257, 130)]:
        a = jnp.asarray(rng.normal(size=(m, k)), dtype=jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), dtype=jnp.float32)
        out, _ = qgemm.qgemm(a, b)
        want, _ = ref.qgemm(a, b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_i32_accumulation_no_overflow():
    # All-max inputs over a long K: products hit 127*127*K — must accumulate
    # exactly in int32 (the Fig. 3 argument).
    k = 512
    a = jnp.ones((1, k), dtype=jnp.float32)
    b = jnp.ones((k, 1), dtype=jnp.float32)
    out, _ = qgemm.qgemm(a, b)
    np.testing.assert_allclose(np.asarray(out)[0, 0], k, rtol=1e-5)
