"""SDDMM kernels (add w/ on-the-fly dequant, dot on quantized values)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize, ref, sddmm


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200),
    e=st.integers(1, 600),
    heads=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_add_matches_ref(n, e, heads, seed):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    # Very different ranges so the two scales differ (the reason add cannot
    # run directly on quantized values).
    s = jnp.asarray(rng.normal(size=(n, heads)) * 50.0, dtype=jnp.float32)
    d = jnp.asarray(rng.normal(size=(n, heads)), dtype=jnp.float32)
    qs, ss = quantize.quantize(s, 8)
    qd, sd = quantize.quantize(d, 8)
    out = sddmm.sddmm_add(src, dst, qs, qd, ss, sd)
    want = ref.sddmm_add(src, dst, ref.dequantize(qs, ss), ref.dequantize(qd, sd))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 150),
    e=st.integers(1, 500),
    heads=st.sampled_from([1, 2, 4]),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_dot_matches_ref(n, e, heads, d, seed):
    rng = np.random.default_rng(seed)
    src = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    a = jnp.asarray(rng.normal(size=(n, heads * d)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, heads * d)), dtype=jnp.float32)
    qa, sa = quantize.quantize(a, 8)
    qb, sb = quantize.quantize(b, 8)
    out = sddmm.sddmm_dot(src, dst, qa, qb, sa, sb, heads)
    want = ref.sddmm_dot(src, dst, ref.dequantize(qa, sa), ref.dequantize(qb, sb), heads)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_dot_scale_product_identity():
    # The §3.3 algebra: (s0·a_q)·(s1·b_q) == (s0·s1)·(a_q·b_q) — the kernel
    # computes the RHS; check it equals the LHS path.
    rng = np.random.default_rng(5)
    n, e = 32, 64
    src = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    a = jnp.asarray(rng.normal(size=(n, 8)), dtype=jnp.float32)
    b = jnp.asarray(rng.normal(size=(n, 8)), dtype=jnp.float32)
    qa, sa = quantize.quantize(a, 8)
    qb, sb = quantize.quantize(b, 8)
    kernel = np.asarray(sddmm.sddmm_dot(src, dst, qa, qb, sa, sb, 1))
    lhs = np.asarray(ref.sddmm_dot(src, dst, ref.dequantize(qa, sa), ref.dequantize(qb, sb), 1))
    np.testing.assert_allclose(kernel, lhs, rtol=1e-5, atol=1e-5)


def test_add_close_to_fp32():
    rng = np.random.default_rng(9)
    n, e = 64, 256
    src = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, size=e), dtype=jnp.int32)
    s = jnp.asarray(rng.normal(size=(n, 4)), dtype=jnp.float32)
    d = jnp.asarray(rng.normal(size=(n, 4)), dtype=jnp.float32)
    qs, ss = quantize.quantize(s, 8)
    qd, sd = quantize.quantize(d, 8)
    out = np.asarray(sddmm.sddmm_add(src, dst, qs, qd, ss, sd))
    exact = np.asarray(ref.sddmm_add(src, dst, s, d))
    assert np.abs(out - exact).max() < float(ss) + float(sd) + 1e-6
