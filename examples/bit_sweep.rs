//! The Fig. 2 rule in action: sweep quantization bit widths on a dataset,
//! print the first-layer `Error_X` per width, the width the lightweight
//! rule derives, and the accuracy actually achieved at each width.
//!
//! Run: `cargo run --release --example bit_sweep -- [--dataset Pubmed] [--epochs 40]`

use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::graph::datasets;
use tango::model::{GcnConfig, GcnModel, TrainMode};
use tango::quant::{derive_bits, DEFAULT_ERROR_TARGET};
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let dataset = args.get("dataset", "Pubmed").to_string();
    let epochs: usize = args.get_as("epochs", 40);
    let seed: u64 = args.get_as("seed", 42);
    let data = if dataset == "tiny" { datasets::tiny(seed) } else { datasets::load_by_name(&dataset, seed) };

    // The lightweight rule: quantize the first layer's output only.
    let probe_model = GcnModel::new(
        GcnConfig {
            in_dim: data.features.cols(),
            hidden: 64,
            out_dim: data.num_classes,
            layers: 2,
            mode: TrainMode::fp32(),
        },
        &data.graph,
        seed,
    );
    let probe = probe_model.first_layer_output(&data.features);
    let derivation = derive_bits(&probe, DEFAULT_ERROR_TARGET);
    println!("Error_X sweep on {dataset} (first-layer output, target {:.1}):", DEFAULT_ERROR_TARGET);
    for (bits, e) in &derivation.sweep {
        let marker = if *bits == derivation.bits { "  <= chosen" } else { "" };
        println!("  {bits} bits: Error_X = {e:.4}{marker}");
    }

    // Ground truth: train at each width and report accuracy.
    println!("\ntraining accuracy per bit width ({epochs} epochs):");
    let fp_cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: dataset.clone(),
        epochs,
        lr: 0.1,
        hidden: 64,
        heads: 4,
        layers: 2,
        mode: TrainMode::fp32(),
        auto_bits: false,
        seed,
        log_every: 0,
        ..Default::default()
    };
    let fp_acc = Trainer::from_config(&fp_cfg)?.run()?.final_eval;
    println!("  fp32  : {fp_acc:.4}");
    for bits in [2u8, 4, 6, 8] {
        let mut cfg = fp_cfg.clone();
        cfg.mode = TrainMode::tango(bits);
        let acc = Trainer::from_config(&cfg)?.run()?.final_eval;
        let marker = if bits == derivation.bits { "  <= derived width" } else { "" };
        println!("  {bits} bits: {acc:.4} ({:.1}% of fp32){marker}", acc / fp_acc.max(1e-9) * 100.0);
    }
    Ok(())
}
