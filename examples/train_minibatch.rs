//! Sampled mini-batch GCN/GAT training with quantized feature gathering:
//! the DGL-style execution mode (layered neighbor sampling → MFG blocks →
//! INT8 feature gather → block forward/backward through the unified
//! `GnnModel` path), with the hot-node feature-cache hit rate surfaced via
//! `TrainReport::cache`. `--task linkpred` switches to edge-seeded blocks
//! with seed-edge exclusion and reports AUC.
//!
//! Run: `cargo run --release --example train_minibatch -- \
//!        [--dataset Pubmed] [--model gcn|gat] [--mode tango|fp32] \
//!        [--task nc|linkpred] [--fanouts 10,10] [--batch-size 256] \
//!        [--epochs 10] [--cache-nodes 8192]`

use tango::config::{parse_fanouts, parse_mode, parse_task, task_name, ModelKind, TrainConfig};
use tango::metrics::fmt_time;
use tango::sampler::MiniBatchTrainer;
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let epochs: usize = args.get_as("epochs", 10);
    let mut cfg = TrainConfig {
        model: args
            .get("model", "gcn")
            .parse::<ModelKind>()
            .map_err(|e| anyhow::anyhow!(e))?,
        dataset: args.get("dataset", "Pubmed").to_string(),
        epochs,
        hidden: args.get_as("hidden", 64),
        lr: args.get_as("lr", 0.1),
        mode: parse_mode(args.get("mode", "tango"), args.get_as("bits", 8))
            .map_err(|e| anyhow::anyhow!(e))?,
        seed: args.get_as("seed", 42),
        log_every: (epochs / 10).max(1),
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts =
        parse_fanouts(args.get("fanouts", "10,10")).map_err(|e| anyhow::anyhow!(e))?;
    cfg.sampler.batch_size = args.get_as("batch-size", 256);
    cfg.sampler.cache_nodes = args.get_as("cache-nodes", 0);
    if args.flags.contains_key("cache-nodes") && cfg.sampler.cache_nodes == 0 {
        anyhow::bail!("--cache-nodes must be >= 1 (omit the flag for an unbounded cache)");
    }
    if let Some(t) = args.flags.get("task") {
        cfg.task = Some(parse_task(t).map_err(|e| anyhow::anyhow!(e))?);
    }

    let mut trainer = MiniBatchTrainer::from_config(&cfg)?;
    let d = trainer.dataset();
    println!(
        "sampled training: {:?} on {} ({} nodes, {} edges) — task {}, fanouts {:?}, \
         batch {}, mode {} ({} bits)\n",
        cfg.model,
        d.name,
        d.graph.num_nodes,
        d.graph.num_edges(),
        task_name(trainer.task()),
        trainer.fanouts(),
        cfg.sampler.batch_size,
        tango::config::mode_name(&cfg.mode),
        trainer.mode().bits,
    );
    let report = trainer.run()?;
    println!(
        "\nfinal {} {:.4} | {} epochs in {} ({}/epoch)",
        tango::config::metric_name(trainer.task()),
        report.final_eval,
        report.losses.len(),
        fmt_time(report.wall_secs),
        fmt_time(report.wall_secs / report.losses.len().max(1) as f64),
    );
    match report.cache {
        Some(stats) => {
            println!("quantized feature cache: {}", stats.summary(report.cache_bytes));
            println!(
                "(every hit skips one row quantization — hot nodes are re-sampled across \
                 batches, the BiFeat effect)"
            );
        }
        None => println!("fp32 mode: features gathered without quantization"),
    }
    Ok(())
}
