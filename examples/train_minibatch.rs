//! Sampled mini-batch GCN/GAT training with quantized feature gathering:
//! the DGL-style execution mode (layered neighbor sampling → MFG blocks →
//! INT8 feature gather → block forward/backward), with the hot-node
//! feature-cache hit rate reported from `QuantCache::stats()`.
//!
//! Run: `cargo run --release --example train_minibatch -- \
//!        [--dataset Pubmed] [--model gcn|gat] [--mode tango|fp32] \
//!        [--fanouts 10,10] [--batch-size 256] [--epochs 10] \
//!        [--cache-nodes 8192]`

use tango::config::{parse_fanouts, parse_mode, ModelKind, TrainConfig};
use tango::metrics::fmt_time;
use tango::sampler::MiniBatchTrainer;
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let epochs: usize = args.get_as("epochs", 10);
    let mut cfg = TrainConfig {
        model: args
            .get("model", "gcn")
            .parse::<ModelKind>()
            .map_err(|e| anyhow::anyhow!(e))?,
        dataset: args.get("dataset", "Pubmed").to_string(),
        epochs,
        hidden: args.get_as("hidden", 64),
        lr: args.get_as("lr", 0.1),
        mode: parse_mode(args.get("mode", "tango"), args.get_as("bits", 8))
            .map_err(|e| anyhow::anyhow!(e))?,
        seed: args.get_as("seed", 42),
        log_every: (epochs / 10).max(1),
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts =
        parse_fanouts(args.get("fanouts", "10,10")).map_err(|e| anyhow::anyhow!(e))?;
    cfg.sampler.batch_size = args.get_as("batch-size", 256);
    cfg.sampler.cache_nodes = args.get_as("cache-nodes", 0);

    let mut trainer = MiniBatchTrainer::from_config(&cfg)?;
    let d = trainer.dataset();
    println!(
        "sampled training: {:?} on {} ({} nodes, {} edges) — fanouts {:?}, batch {}, \
         mode {} ({} bits)\n",
        cfg.model,
        d.name,
        d.graph.num_nodes,
        d.graph.num_edges(),
        trainer.fanouts(),
        cfg.sampler.batch_size,
        tango::config::mode_name(&cfg.mode),
        trainer.mode().bits,
    );
    let report = trainer.run()?;
    println!(
        "\nfinal eval {:.4} | {} epochs in {} ({}/epoch)",
        report.final_eval,
        report.losses.len(),
        fmt_time(report.wall_secs),
        fmt_time(report.wall_secs / report.losses.len().max(1) as f64),
    );
    match trainer.gather_stats() {
        Some(stats) => {
            let total = stats.hits + stats.misses;
            println!(
                "quantized feature cache: {:.1}% hit rate ({} hits / {} gathered rows), \
                 {} evictions, {} KiB of INT8 rows cached",
                stats.hits as f64 / total.max(1) as f64 * 100.0,
                stats.hits,
                total,
                stats.evictions,
                trainer.gather_cached_bytes() / 1024,
            );
            println!(
                "(every hit skips one row quantization — hot nodes are re-sampled across \
                 batches, the BiFeat effect)"
            );
        }
        None => println!("fp32 mode: features gathered without quantization"),
    }
    Ok(())
}
