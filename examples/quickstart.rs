//! Quickstart: the full three-layer stack end to end.
//!
//! 1. Trains a quantized GCN on a small planted-community graph with the
//!    Rust-native primitives (Layer 3).
//! 2. Loads the jax-lowered `gcn_train_step` artifact (Layers 1+2, built by
//!    `make artifacts`) and runs a training loop through PJRT — Python is
//!    not involved at runtime.
//!
//! Run: `cargo run --release --example quickstart`

use tango::config::TrainConfig;
use tango::coordinator::Trainer;
use tango::graph::generators::{features_for_labels, planted_partition};
use tango::graph::Csr;
use tango::quant::rng::Xoshiro256pp;
use tango::runtime::{Runtime, Value};
use tango::tensor::Dense;

fn main() -> tango::Result<()> {
    // ---- Part 1: native quantized training --------------------------------
    println!("== native quantized GCN (Rust primitives) ==");
    let mut cfg = TrainConfig::quickstart();
    cfg.epochs = 30;
    cfg.log_every = 10;
    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;
    println!(
        "native: final eval {:.4} in {:.2}s\n",
        report.final_eval, report.wall_secs
    );

    // ---- Part 2: the AOT path (jax-lowered HLO through PJRT) --------------
    println!("== AOT gcn_train_step (jax+Pallas lowered, PJRT executed) ==");
    let mut rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping AOT part: {e} (run `make artifacts`)");
            return Ok(());
        }
    };
    let spec = rt.manifest.get("gcn_train_step").expect("manifest entry").clone();
    let (n, p, f, h, c) =
        (spec.sizes["n"], spec.sizes["p"], spec.sizes["f"], spec.sizes["h"], spec.sizes["c"]);
    // Generate a symmetric planted-community graph at the artifact's shape.
    let (graph, labels) = planted_partition(n, 3, c, 0.8, 7);
    let graph = graph.with_reverse_edges().dedup().with_self_loops();
    let csr = Csr::from_coo(&graph);
    // Padded-CSR arrays (in-neighbour table + mean-aggregation weights).
    let mut nbr = Dense::<i32>::zeros(&[n, p]);
    let mut wgt = Dense::<f32>::zeros(&[n, p]);
    for v in 0..n {
        let (srcs, _) = csr.row(v);
        let deg = srcs.len().min(p).max(1);
        for (slot, &u) in srcs.iter().take(p).enumerate() {
            nbr.set(v, slot, u as i32);
            wgt.set(v, slot, 1.0 / deg as f32);
        }
    }
    let features = features_for_labels(&labels, f, c, 0.5, 11);
    let mut onehot = Dense::<f32>::zeros(&[n, c]);
    for (v, &l) in labels.iter().enumerate() {
        onehot.set(v, l as usize, 1.0);
    }
    let mask = Dense::from_vec(&[n], vec![1.0f32; n]);
    // Glorot-ish init.
    let mut rng = Xoshiro256pp::new(3);
    let mut w1 = Dense::from_vec(
        &[f, h],
        (0..f * h).map(|_| (rng.next_f32() * 2.0 - 1.0) * 0.25).collect(),
    );
    let mut w2 = Dense::from_vec(
        &[h, c],
        (0..h * c).map(|_| (rng.next_f32() * 2.0 - 1.0) * 0.25).collect(),
    );
    let steps = 60;
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..steps {
        let out = rt.run(
            "gcn_train_step",
            &[
                Value::F32(features.clone()),
                Value::F32(onehot.clone()),
                Value::F32(mask.clone()),
                Value::F32(w1.clone()),
                Value::F32(w2.clone()),
                Value::I32(nbr.clone()),
                Value::F32(wgt.clone()),
            ],
        )?;
        let loss = out[0].as_scalar_f32()?;
        w1 = out[1].as_f32()?.clone();
        w2 = out[2].as_f32()?.clone();
        first_loss.get_or_insert(loss);
        last_loss = loss;
        if step % 10 == 0 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "AOT: loss {:.4} -> {:.4} over {steps} steps ({:.1} ms/step); quantized \
         train-step executed entirely from the jax/Pallas-lowered artifact",
        first_loss.unwrap(),
        last_loss,
        dt / steps as f64 * 1e3
    );
    assert!(
        last_loss < first_loss.unwrap(),
        "AOT training must reduce the loss"
    );
    Ok(())
}
