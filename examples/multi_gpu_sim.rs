//! Fig. 9 at example scale: data-parallel mini-batch training across 2–6
//! simulated GPUs, FP32 vs quantized gradient all-reduce, with the PCIe
//! congestion model. Real computation + numerically real all-reduce;
//! interconnect time modelled (DESIGN.md §Substitutions).
//!
//! Run: `cargo run --release --example multi_gpu_sim -- [--dataset ogbn-arxiv]`

use tango::config::{ModelKind, TrainConfig};
use tango::graph::datasets;
use tango::metrics::fmt_time;
use tango::model::TrainMode;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let dataset = args.get("dataset", "ogbn-arxiv").to_string();
    let data = datasets::load_by_name(&dataset, 42);
    println!(
        "dataset {dataset}: {} nodes, {} edges\n",
        data.graph.num_nodes,
        data.graph.num_edges()
    );
    println!(
        "{:>7} {:>14} {:>14} {:>9}  (epoch wall time, compute+comm)",
        "workers", "fp32", "tango", "speedup"
    );
    for k in [2usize, 3, 4, 5, 6] {
        let mk = |quant: bool| {
            let mut train = TrainConfig {
                model: ModelKind::Gcn,
                dataset: dataset.clone(),
                epochs: 3,
                lr: 0.05,
                hidden: 128,
                heads: 4,
                layers: 2,
                mode: if quant { TrainMode::tango(8) } else { TrainMode::fp32() },
                auto_bits: false,
                seed: 42,
                log_every: 0,
                ..Default::default()
            };
            // Unified sampler knobs: the same fields `tango train --sampler
            // neighbor` uses drive each worker's Block pipeline.
            train.sampler.fanouts = vec![8, 8];
            train.sampler.batch_size = 1024;
            MultiGpuConfig {
                train,
                workers: k,
                epochs: 3,
                quantize_grads: quant,
                interconnect: Interconnect::pcie3(),
            }
        };
        let fp = run_data_parallel(&mk(false), &data)?;
        let tg = run_data_parallel(&mk(true), &data)?;
        let fp_t = fp.total_time() / fp.epochs.len() as f64;
        let tg_t = tg.total_time() / tg.epochs.len() as f64;
        let cache = match tg.cache {
            Some(s) => format!(
                "cache {:.0}% hit, {} ev",
                s.hits as f64 / (s.hits + s.misses).max(1) as f64 * 100.0,
                s.evictions
            ),
            None => String::new(),
        };
        println!(
            "{k:>7} {:>14} {:>14} {:>8.2}x  {cache}",
            fmt_time(fp_t),
            fmt_time(tg_t),
            fp_t / tg_t
        );
    }
    println!(
        "\nThe speedup grows with worker count: quantized payloads relieve the \
         shared-bus congestion (the paper's PCIe observation, Fig. 9)."
    );
    Ok(())
}
