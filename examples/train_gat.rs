//! End-to-end validation driver (DESIGN.md §End-to-end): trains the paper's
//! GAT configuration (2 layers, 4 heads, hidden 128) on the ogbn-arxiv
//! analogue for several hundred epochs in both FP32 and Tango modes,
//! logging the loss curves and comparing final accuracy and wall time —
//! the Fig. 7/8 experiment at full example scale.
//!
//! Run: `cargo run --release --example train_gat -- [--epochs 300] [--dataset ogbn-arxiv]`

use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::model::TrainMode;
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let epochs: usize = args.get_as("epochs", 300);
    let dataset = args.get("dataset", "ogbn-arxiv").to_string();
    let base = TrainConfig {
        model: ModelKind::Gat,
        dataset,
        epochs,
        lr: 0.05,
        hidden: 128,
        heads: 4,
        layers: 2,
        mode: TrainMode::fp32(),
        auto_bits: false,
        seed: args.get_as("seed", 42),
        log_every: (epochs / 10).max(1),
        ..Default::default()
    };

    println!("== FP32 (DGL baseline) ==");
    let mut fp = Trainer::from_config(&base)?;
    let fp_report = fp.run()?;

    println!("\n== Tango (INT8, stochastic rounding, auto-derived bits) ==");
    let mut cfg = base.clone();
    cfg.mode = TrainMode::tango(8);
    cfg.auto_bits = true;
    let mut tg = Trainer::from_config(&cfg)?;
    println!("bit-derivation rule chose {} bits", tg.mode().bits);
    let tg_report = tg.run()?;

    println!("\n== summary ==");
    println!(
        "fp32 : eval {:.4}  {:.1}s total  {:.0} ms/epoch",
        fp_report.final_eval,
        fp_report.wall_secs,
        fp_report.wall_secs / epochs as f64 * 1e3
    );
    println!(
        "tango: eval {:.4}  {:.1}s total  {:.0} ms/epoch  (speedup {:.2}x, bits {})",
        tg_report.final_eval,
        tg_report.wall_secs,
        tg_report.wall_secs / epochs as f64 * 1e3,
        fp_report.wall_secs / tg_report.wall_secs,
        tg_report.bits
    );
    println!(
        "accuracy retention: {:.1}% of FP32 (paper claims >99%)",
        tg_report.final_eval / fp_report.final_eval.max(1e-9) * 100.0
    );
    println!("\nloss curve (every {} epochs):", (epochs / 20).max(1));
    println!("{:>6} {:>10} {:>10}", "epoch", "fp32", "tango");
    for i in (0..epochs).step_by((epochs / 20).max(1)) {
        println!("{:>6} {:>10.4} {:>10.4}", i, fp_report.losses[i], tg_report.losses[i]);
    }
    Ok(())
}
