//! Link prediction on the DBLP/Amazon analogues (paper Table 1's LP task):
//! a GCN encoder with the dot-product `TaskHead` decoder trained under BCE,
//! in FP32 and Tango modes, reporting AUC — first as full-graph epochs,
//! then as sampled mini-batches over **edge-seeded blocks** (positive-edge
//! sweeps, seeded uniform negatives, seed-edge exclusion), the
//! `tango train --sampler neighbor --task linkpred` path.
//!
//! Run: `cargo run --release --example link_prediction -- [--dataset DBLP] [--epochs 60]`

use tango::config::{parse_mode, ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let dataset = args.get("dataset", "DBLP").to_string();
    let epochs: usize = args.get_as("epochs", 60);
    let base = |mode_name: &str| -> tango::Result<TrainConfig> {
        Ok(TrainConfig {
            model: ModelKind::Gcn,
            dataset: dataset.clone(),
            epochs,
            lr: 0.05,
            hidden: 64,
            heads: 4,
            layers: 2,
            mode: parse_mode(mode_name, 8).map_err(|e| anyhow::anyhow!(e))?,
            auto_bits: false,
            seed: args.get_as("seed", 42),
            log_every: (epochs / 6).max(1),
            ..Default::default()
        })
    };
    for mode_name in ["fp32", "tango"] {
        let cfg = base(mode_name)?;
        println!("== {mode_name} on {dataset} (full-graph link prediction) ==");
        let mut trainer = Trainer::from_config(&cfg)?;
        let report = trainer.run()?;
        println!(
            "{mode_name}: AUC {:.4} in {:.1}s ({:.0} ms/epoch)\n",
            report.final_eval,
            report.wall_secs,
            report.wall_secs / epochs as f64 * 1e3
        );
    }
    // The sampled path: every epoch sweeps the canonical positive edges in
    // shuffled batches; each batch seeds the fanout sampler from its
    // candidate endpoints and excludes the positives from the sampled
    // messages (the leakage guard).
    let mb_epochs = (epochs / 4).max(2);
    let mut cfg = base("tango")?;
    cfg.epochs = mb_epochs;
    cfg.log_every = (mb_epochs / 4).max(1);
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![10, 10];
    cfg.sampler.batch_size = args.get_as("batch-size", 512);
    println!(
        "== tango on {dataset} (sampled LP: edge-seeded blocks, fanouts {:?}, batch {}) ==",
        cfg.sampler.fanouts, cfg.sampler.batch_size
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;
    println!(
        "sampled tango: AUC {:.4} in {:.1}s ({:.0} ms/epoch)",
        report.final_eval,
        report.wall_secs,
        report.wall_secs / mb_epochs as f64 * 1e3
    );
    if let Some(stats) = report.cache {
        println!("feature cache: {}", stats.summary(report.cache_bytes));
    }
    Ok(())
}
