//! Link prediction on the DBLP/Amazon analogues (paper Table 1's LP task):
//! a GCN encoder trained with dot-product edge scores and BCE, in FP32 and
//! Tango modes, reporting AUC.
//!
//! Run: `cargo run --release --example link_prediction -- [--dataset DBLP] [--epochs 60]`

use tango::config::{parse_mode, ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::util::cli::Args;

fn main() -> tango::Result<()> {
    let args = Args::from_env();
    let dataset = args.get("dataset", "DBLP").to_string();
    let epochs: usize = args.get_as("epochs", 60);
    for mode_name in ["fp32", "tango"] {
        let cfg = TrainConfig {
            model: ModelKind::Gcn,
            dataset: dataset.clone(),
            epochs,
            lr: 0.05,
            hidden: 64,
            heads: 4,
            layers: 2,
            mode: parse_mode(mode_name, 8).map_err(|e| anyhow::anyhow!(e))?,
            auto_bits: false,
            seed: args.get_as("seed", 42),
            log_every: (epochs / 6).max(1),
            ..Default::default()
        };
        println!("== {mode_name} on {dataset} (link prediction) ==");
        let mut trainer = Trainer::from_config(&cfg)?;
        let report = trainer.run()?;
        println!(
            "{mode_name}: AUC {:.4} in {:.1}s ({:.0} ms/epoch)\n",
            report.final_eval,
            report.wall_secs,
            report.wall_secs / epochs as f64 * 1e3
        );
    }
    Ok(())
}
