//! §3.2 bench: stochastic-rounding quantization throughput with
//! register-resident vs memory-resident PRNG state (the xoshiro256++ vs
//! cuRAND comparison), plus nearest-rounding and Error_X costs.

use tango::graph::generators::random_features;
use tango::metrics::{bench, Table};
use tango::quant::rng::{MemoryStateRng, Xoshiro256pp};
use tango::quant::{error_x_quantized, quantize, Rounding};

fn main() {
    // Raw PRNG throughput: the paper's ~20x claim mechanism.
    let n_draws = 1_000_000u64;
    let reg = bench("xoshiro256++ (register state) 1M draws", || {
        let mut r = Xoshiro256pp::new(1);
        let mut acc = 0u64;
        for _ in 0..n_draws {
            acc = acc.wrapping_add(r.next_u64());
        }
        acc
    });
    let mem = bench("xoshiro256++ (memory state) 1M draws", || {
        let mut r = MemoryStateRng::new(1);
        let mut acc = 0u64;
        for _ in 0..n_draws {
            acc = acc.wrapping_add(r.next_u64());
        }
        acc
    });
    println!("{}", reg.summary());
    println!("{}", mem.summary());
    println!(
        "register-state PRNG speedup: {:.2}x (paper reports ~20x vs cuRAND on GPU)\n",
        mem.mean / reg.mean
    );

    let mut t = Table::new(
        "bench: quantization pass (16M elements)",
        &["rounding", "time ms", "GB/s (f32 read + i8 write)"],
    );
    let x = random_features(4096, 4096, 2);
    for (name, rounding) in [
        ("nearest", Rounding::Nearest),
        ("stochastic", Rounding::Stochastic { seed: 3 }),
    ] {
        let r = bench(&format!("quantize {name}"), || quantize(&x, 8, rounding));
        println!("{}", r.summary());
        let bytes = (x.len() * 5) as f64;
        t.row(&[name.into(), format!("{:.2}", r.mean * 1e3), format!("{:.2}", bytes / r.mean / 1e9)]);
    }
    t.print();

    let q = quantize(&x, 8, Rounding::Nearest);
    let e = bench("error_x 16M elements", || error_x_quantized(&x, &q));
    println!("{}", e.summary());
}
