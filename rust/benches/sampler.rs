//! Sampler bench: layered neighbor sampling, FP32 vs quantized (cached)
//! feature gathering, and sampled mini-batch epochs vs full-graph epochs —
//! the BiFeat-style motivation for quantizing the gather path.

use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::graph::datasets;
use tango::graph::Csr;
use tango::metrics::{bench, Table};
use tango::model::TrainMode;
use tango::sampler::{gather_rows, EdgeBatcher, NeighborSampler, QuantFeatureStore};

fn main() {
    let mut t = Table::new(
        "bench: neighbor sampling + quantized feature gather",
        &["dataset", "sample", "gather fp32", "gather int8 (warm)", "mb s/ep", "full s/ep"],
    );
    for name in ["Pubmed", "ogbn-arxiv"] {
        let data = datasets::load_by_name(name, 42);
        let csr = Csr::from_coo(&data.graph);
        let degrees = data.graph.in_degrees();
        let sampler = NeighborSampler::new(vec![10, 10], 7);
        let seeds: Vec<u32> = data.train_nodes.iter().take(512).copied().collect();

        let sample = bench(&format!("{name} sample 512 seeds [10,10]"), || {
            sampler.sample_blocks(&csr, &degrees, &seeds, 1)
        });
        println!("{}", sample.summary());

        let blocks = sampler.sample_blocks(&csr, &degrees, &seeds, 1);
        let input = blocks[0].src_nodes.clone();
        println!(
            "{name}: batch pulls {} input nodes, {} + {} block edges",
            input.len(),
            blocks[0].num_edges(),
            blocks[1].num_edges()
        );

        let gf = bench(&format!("{name} gather fp32 x{}", input.len()), || {
            gather_rows(&data.features, &input)
        });
        println!("{}", gf.summary());

        let mut store = QuantFeatureStore::new(&data.features, 8);
        store.gather_quantized(&data.features, &input); // warm the row cache
        let gq = bench(&format!("{name} gather int8 warm x{}", input.len()), || {
            store.gather_quantized(&data.features, &input)
        });
        println!("{}", gq.summary());
        let stats = store.stats();
        println!(
            "{name}: feature-cache hit rate {:.1}% ({} hits / {} misses)",
            stats.hits as f64 / (stats.hits + stats.misses).max(1) as f64 * 100.0,
            stats.hits,
            stats.misses
        );

        // End-to-end: sampled mini-batch epochs vs full-graph epochs.
        let epochs = 2usize;
        let mut cfg = TrainConfig {
            model: ModelKind::Gcn,
            dataset: name.into(),
            epochs,
            hidden: 64,
            mode: TrainMode::tango(8),
            log_every: 0,
            ..Default::default()
        };
        cfg.sampler.enabled = true;
        cfg.sampler.fanouts = vec![10, 10];
        cfg.sampler.batch_size = 512;
        let mb = Trainer::from_config(&cfg).unwrap().run().unwrap();
        let mut full_cfg = cfg.clone();
        full_cfg.sampler.enabled = false;
        let full = Trainer::from_config(&full_cfg).unwrap().run().unwrap();
        let (mb_ep, full_ep) =
            (mb.wall_secs / epochs as f64, full.wall_secs / epochs as f64);
        println!(
            "{name}: minibatch {mb_ep:.3} s/epoch vs full-graph {full_ep:.3} s/epoch\n"
        );

        t.row(&[
            name.into(),
            format!("{:.2}ms", sample.mean * 1e3),
            format!("{:.3}ms", gf.mean * 1e3),
            format!("{:.3}ms", gq.mean * 1e3),
            format!("{mb_ep:.3}"),
            format!("{full_ep:.3}"),
        ]);
    }
    t.print();

    // Edge-seeded LP batches: assembly (canonical lookup + seeded negatives
    // + exclusion set) and the exclusion-aware layered sampling itself,
    // vs the plain node-seeded path over the same endpoint frontier.
    println!("\nedge-seeded link-prediction batches (DBLP, 512 positives, fanouts [10,10]):");
    let data = datasets::load_by_name("DBLP", 42);
    let csr = Csr::from_coo(&data.graph);
    let degrees = data.graph.in_degrees();
    let sampler = NeighborSampler::new(vec![10, 10], 7);
    let batcher = EdgeBatcher::new(&data.graph);
    let ids: Vec<u32> = batcher.edge_ids().into_iter().take(512).collect();

    let assemble = bench("DBLP assemble 512-edge batch (+1 neg/pos)", || {
        batcher.batch(&ids, 1, 99)
    });
    println!("{}", assemble.summary());

    let eb = batcher.batch(&ids, 1, 99);
    println!(
        "batch: {} candidate pairs over {} seed endpoints, {} excluded edge directions",
        eb.pairs.len(),
        eb.seeds.len(),
        eb.exclude.len()
    );
    let excl = bench("DBLP edge-seeded sample [10,10] w/ exclusion", || {
        sampler.sample_blocks_excluding(&csr, &degrees, &eb.seeds, 1, &eb.exclude)
    });
    println!("{}", excl.summary());
    let plain = bench("DBLP node-seeded sample [10,10] same frontier", || {
        sampler.sample_blocks(&csr, &degrees, &eb.seeds, 1)
    });
    println!(
        "{}\n(exclusion overhead: {:.1}% on this batch)",
        plain.summary(),
        (excl.mean / plain.mean - 1.0) * 100.0
    );
}
