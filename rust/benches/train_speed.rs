//! Fig. 8 bench: end-to-end epoch time of FP32 / Tango / EXACT on GCN and
//! GAT over the scaled datasets.
//!
//! Besides the printed table, the bench writes a machine-readable
//! `BENCH_train_speed.json` at the repo root (schema
//! `tango-bench/train_speed/v1`) so CI can archive speed numbers per
//! commit. `--quick` trims the dataset sweep to Pubmed for smoke runs.

use std::collections::BTreeMap;
use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::metrics::Table;
use tango::model::TrainMode;
use tango::util::cli::Args;
use tango::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let args = Args::from_env();
    let quick = args.get_bool("quick");
    let epochs = 2usize;
    let datasets: &[&str] = if quick {
        &["Pubmed"]
    } else {
        &["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"]
    };
    let mut t = Table::new(
        "bench: end-to-end training (fig8)",
        &[
            "model",
            "dataset",
            "fp32 s/ep",
            "tango s/ep",
            "exact s/ep",
            "tango4p s/ep",
            "tango speedup",
            "exact speedup",
            "tango4p speedup",
        ],
    );
    let mut results: Vec<Json> = Vec::new();
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        let name = if model == ModelKind::Gcn { "GCN" } else { "GAT" };
        for ds in datasets {
            // Per-epoch wall (the full budget: train sweep + eval) and the
            // training-compute share of it, both averaged over the epochs.
            let time = |mode: TrainMode, packed: bool| -> (f64, f64) {
                let cfg = TrainConfig {
                    model,
                    dataset: (*ds).into(),
                    epochs,
                    lr: 0.05,
                    hidden: 64,
                    heads: 4,
                    layers: 2,
                    mode,
                    auto_bits: false,
                    seed: 42,
                    log_every: 0,
                    packed_compute: packed,
                    ..Default::default()
                };
                let mut tr = Trainer::from_config(&cfg).unwrap();
                let report = tr.run().unwrap();
                let compute = report.stage_totals().compute_s;
                (report.wall_secs / epochs as f64, compute / epochs as f64)
            };
            let (fp, fp_c) = time(TrainMode::fp32(), false);
            let (tg, tg_c) = time(TrainMode::tango(8), false);
            let (ex, ex_c) = time(TrainMode::exact(8), false);
            // The packed 4-bit configuration: sub-byte kernels end to end
            // (`--packed-compute`, the `PrimitiveBackend::Packed` seam).
            let (t4p, t4p_c) = time(TrainMode::tango(4), true);
            println!(
                "{name} {ds}: fp32 {fp:.3}s tango {tg:.3}s exact {ex:.3}s \
                 tango4-packed {t4p:.3}s"
            );
            t.row(&[
                name.into(),
                (*ds).into(),
                format!("{fp:.3}"),
                format!("{tg:.3}"),
                format!("{ex:.3}"),
                format!("{t4p:.3}"),
                format!("{:.2}x", fp / tg),
                format!("{:.2}x", fp / ex),
                format!("{:.2}x", fp / t4p),
            ]);
            results.push(obj(vec![
                ("model", Json::Str(name.to_lowercase())),
                ("dataset", Json::Str((*ds).to_string())),
                ("fp32_s_per_epoch", Json::Num(fp)),
                ("tango_s_per_epoch", Json::Num(tg)),
                ("exact_s_per_epoch", Json::Num(ex)),
                ("fp32_compute_s_per_epoch", Json::Num(fp_c)),
                ("tango_compute_s_per_epoch", Json::Num(tg_c)),
                ("exact_compute_s_per_epoch", Json::Num(ex_c)),
                ("tango4_packed_s_per_epoch", Json::Num(t4p)),
                ("tango4_packed_compute_s_per_epoch", Json::Num(t4p_c)),
                ("tango_speedup", Json::Num(fp / tg)),
                ("exact_speedup", Json::Num(fp / ex)),
                ("tango4_packed_speedup", Json::Num(fp / t4p)),
            ]));
        }
    }
    t.print();
    let mean = |key: &str| -> f64 {
        let vals: Vec<f64> =
            results.iter().filter_map(|r| r.get(key).and_then(|v| v.as_f64())).collect();
        if vals.is_empty() { 0.0 } else { vals.iter().sum::<f64>() / vals.len() as f64 }
    };
    let (mean_tango, mean_packed) = (mean("tango_speedup"), mean("tango4_packed_speedup"));
    let rows = results.len();
    let artifact = obj(vec![
        ("schema", Json::Str("tango-bench/train_speed/v1".into())),
        ("bench", Json::Str("train_speed".into())),
        ("epochs_per_run", Json::Num(epochs as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_train_speed.json");
    tango::util::fsio::write_atomic(path, &artifact.to_string()).expect("write BENCH_train_speed.json");
    println!("wrote {path}");
    // One-row summary appended to the cross-commit perf trajectory (the
    // full artifact above is overwritten per run; the history accumulates).
    let history = obj(vec![
        ("schema", Json::Str("tango-bench/history/v1".into())),
        ("bench", Json::Str("train_speed".into())),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Num(rows as f64)),
        ("mean_tango_speedup", Json::Num(mean_tango)),
        ("mean_tango4_packed_speedup", Json::Num(mean_packed)),
    ]);
    let hist_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_history.jsonl");
    tango::util::fsio::append_line_atomic(hist_path, &history.to_string())
        .expect("append BENCH_history.jsonl");
    println!("appended {hist_path}");
}
