//! Fig. 8 bench: end-to-end epoch time of FP32 / Tango / EXACT on GCN and
//! GAT over the scaled datasets.

use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::metrics::Table;
use tango::model::TrainMode;

fn main() {
    let epochs = 2usize;
    let mut t = Table::new(
        "bench: end-to-end training (fig8)",
        &["model", "dataset", "fp32 s/ep", "tango s/ep", "exact s/ep", "tango speedup", "exact speedup"],
    );
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        let name = if model == ModelKind::Gcn { "GCN" } else { "GAT" };
        for ds in ["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"] {
            let time = |mode: TrainMode| -> f64 {
                let cfg = TrainConfig {
                    model,
                    dataset: ds.into(),
                    epochs,
                    lr: 0.05,
                    hidden: 64,
                    heads: 4,
                    layers: 2,
                    mode,
                    auto_bits: false,
                    seed: 42,
                    log_every: 0,
                    ..Default::default()
                };
                let mut tr = Trainer::from_config(&cfg).unwrap();
                tr.run().unwrap().wall_secs / epochs as f64
            };
            let fp = time(TrainMode::fp32());
            let tg = time(TrainMode::tango(8));
            let ex = time(TrainMode::exact(8));
            println!("{name} {ds}: fp32 {fp:.3}s tango {tg:.3}s exact {ex:.3}s");
            t.row(&[
                name.into(),
                ds.into(),
                format!("{fp:.3}"),
                format!("{tg:.3}"),
                format!("{ex:.3}"),
                format!("{:.2}x", fp / tg),
                format!("{:.2}x", fp / ex),
            ]);
        }
    }
    t.print();
}
