//! Multi-GPU all-reduce bench: modelled PCIe transfer time of the quantized
//! gradient ring all-reduce vs the FP32 baseline (the Fig. 9 mechanism),
//! plus a real sampled-Block data-parallel run at example scale.
//!
//! The acceptance bar this guards: at 4 workers and a realistic GNN
//! gradient size, the quantized payload must model >= 3.5x faster transfer
//! than FP32 (4x payload shrink, minus per-chunk scale sidecars and the
//! latency floor).

use tango::config::{ModelKind, TrainConfig};
use tango::graph::datasets;
use tango::metrics::Table;
use tango::model::TrainMode;
use tango::multigpu::{
    allreduce_payload_bytes, ring_messages, run_data_parallel, Interconnect, MultiGpuConfig,
};

fn main() {
    let ic = Interconnect::pcie3();
    // A GraphSAGE/GCN-scale parameter count (e.g. 512-dim features into a
    // 256-wide hidden layer plus output heads): 4M gradient elements.
    let grad_elems = 4_000_000usize;
    let mut t = Table::new(
        "bench: modelled ring all-reduce transfer, FP32 vs quantized payloads",
        &["workers", "fp32", "int8", "speedup"],
    );
    let mut at4 = 0.0f64;
    for k in [2usize, 3, 4, 5, 6] {
        let time = |quant: bool| {
            ic.transfer_time(allreduce_payload_bytes(grad_elems, k, quant), ring_messages(k), k)
        };
        let (fp, q) = (time(false), time(true));
        let speedup = fp / q;
        if k == 4 {
            at4 = speedup;
        }
        t.row(&[
            k.to_string(),
            format!("{:.3}ms", fp * 1e3),
            format!("{:.3}ms", q * 1e3),
            format!("{speedup:.2}x"),
        ]);
    }
    t.print();
    println!(
        "\n4-worker modelled transfer speedup: {at4:.2}x (bar: >= 3.5x for \
         {grad_elems} gradient elements)"
    );
    assert!(at4 >= 3.5, "quantized all-reduce must model >= 3.5x at 4 workers, got {at4:.2}x");

    // Real end-to-end flavour at test scale: persistent workers training on
    // sampler Blocks, one shared quantized feature store, per-step ring
    // all-reduce over the modelled interconnect.
    let data = datasets::tiny(7);
    let mk = |quant: bool| {
        let mut train = TrainConfig {
            model: ModelKind::Gcn,
            dataset: "tiny".into(),
            epochs: 2,
            lr: 0.05,
            hidden: 16,
            layers: 2,
            mode: if quant { TrainMode::tango(8) } else { TrainMode::fp32() },
            seed: 7,
            log_every: 0,
            ..Default::default()
        };
        train.sampler.fanouts = vec![6, 6];
        train.sampler.batch_size = 16;
        MultiGpuConfig {
            train,
            workers: 4,
            epochs: 2,
            quantize_grads: quant,
            interconnect: Interconnect::pcie3(),
        }
    };
    let fp = run_data_parallel(&mk(false), &data).unwrap();
    let tg = run_data_parallel(&mk(true), &data).unwrap();
    let fp_comm: f64 = fp.epochs.iter().map(|e| e.comm_s).sum();
    let tg_comm: f64 = tg.epochs.iter().map(|e| e.comm_s).sum();
    println!(
        "\ntiny, 4 workers, {} grad elems: comm fp32 {:.3}us vs int8 {:.3}us per run \
         ({} steps/epoch)",
        fp.grad_elems,
        fp_comm * 1e6,
        tg_comm * 1e6,
        fp.epochs[0].steps
    );
    assert!(tg_comm < fp_comm, "quantized comm must be cheaper end to end");
}
