//! Fig. 10 bench: forward-to-backward reuse of quantized tensors.

use tango::graph::datasets::SPECS;
use tango::graph::generators::random_features;
use tango::metrics::{bench, Table};
use tango::primitives::{qgemm, qgemm_prequantized};
use tango::quant::{quantize, Rounding};

fn main() {
    let mut t = Table::new(
        "bench: quantized-tensor caching (fig10)",
        &["dataset", "D", "fresh ms", "cached ms", "speedup"],
    );
    for spec in SPECS.iter() {
        let m = spec.num_nodes;
        for d in [128usize, 256] {
            let a = random_features(m, d, 1);
            let b = random_features(d, d, 2);
            let fresh = bench(&format!("{} D{d} fresh", spec.name), || {
                qgemm(&a, &b, 8, Rounding::Nearest)
            });
            let qa = quantize(&a, 8, Rounding::Nearest);
            let qb = quantize(&b, 8, Rounding::Nearest);
            let cached = bench(&format!("{} D{d} cached", spec.name), || {
                qgemm_prequantized(&qa, &qb, 8)
            });
            println!("{}", fresh.summary());
            println!("{}", cached.summary());
            t.row(&[
                spec.name.into(),
                d.to_string(),
                format!("{:.2}", fresh.mean * 1e3),
                format!("{:.2}", cached.mean * 1e3),
                format!("{:.2}x", fresh.mean / cached.mean),
            ]);
        }
    }
    t.print();
}
