//! Fig. 11/12 bench: quantized GEMM vs FP32 GEMM across the paper's hidden
//! sizes, plus the GPU cost-model projections.

use tango::graph::generators::random_features;
use tango::metrics::{bench, Table};
use tango::perfmodel::{gemm_time, profile_ratios, GemmKind, A100, V100};
use tango::primitives::{gemm_f32, qgemm, qgemm_prequantized};
use tango::quant::{quantize, Rounding};

fn main() {
    let m = 8192; // graph-scale row count (single-core box)
    let mut t = Table::new("bench: GEMM (measured)", &["D", "fp32", "int8 fused", "int8 cached", "speedup", "cached speedup"]);
    for d in [128usize, 256, 512] {
        let a = random_features(m, d, 1);
        let b = random_features(d, d, 2);
        let f = bench(&format!("gemm_f32 {m}x{d}x{d}"), || gemm_f32(&a, &b));
        println!("{}", f.summary());
        let q = bench(&format!("qgemm8 {m}x{d}x{d}"), || qgemm(&a, &b, 8, Rounding::Nearest));
        println!("{}", q.summary());
        let qa = quantize(&a, 8, Rounding::Nearest);
        let qb = quantize(&b, 8, Rounding::Nearest);
        let c = bench(&format!("qgemm8 cached {m}x{d}x{d}"), || qgemm_prequantized(&qa, &qb, 8));
        println!("{}", c.summary());
        t.row(&[
            d.to_string(),
            format!("{:.2}ms", f.mean * 1e3),
            format!("{:.2}ms", q.mean * 1e3),
            format!("{:.2}ms", c.mean * 1e3),
            format!("{:.2}x", f.mean / q.mean),
            format!("{:.2}x", f.mean / c.mean),
        ]);
    }
    t.print();

    let mut t = Table::new("bench: GEMM (GPU cost model)", &["GPU", "D", "kind", "speedup vs fp32/fp16"]);
    for d in [256usize, 512] {
        let mm = 169_343;
        let v = gemm_time(&V100, mm, d, d, GemmKind::Fp32Cuda, false)
            / gemm_time(&V100, mm, d, d, GemmKind::Int8Dp4a, false);
        t.row(&["V100".into(), d.to_string(), "INT8 DP4A".into(), format!("{v:.2}x")]);
        let a = gemm_time(&A100, mm, d, d, GemmKind::Fp16Tensor, false)
            / gemm_time(&A100, mm, d, d, GemmKind::Int8Tensor, false);
        t.row(&["A100".into(), d.to_string(), "INT8 TC vs FP16 TC".into(), format!("{a:.2}x")]);
    }
    t.print();

    let p = profile_ratios(&V100, 169_343, 256, 256);
    println!(
        "fig12 model: compute {:.2}x  memory {:.2}x  IPC {:.0}%  instr {:.0}%",
        p.compute_throughput_ratio,
        p.memory_throughput_ratio,
        p.ipc_ratio * 100.0,
        p.instruction_ratio * 100.0
    );
}
