//! Pipeline bench: sequential (`prefetch = 0`) vs pipelined
//! (`prefetch = 2`) quantized mini-batch epochs — the paper's §4.2 overlap
//! ("we overlap the feature quantization with the subgraph sampling"),
//! measured end to end on both task heads.
//!
//! The pipelined run does the *same* work batch for batch (bit-identical
//! losses — `tests/pipeline_equivalence.rs`); any wall-time gap is stage
//! one (sampling + quantized gather) hidden behind model compute.

use tango::config::{task_name, ModelKind, TaskKind, TrainConfig};
use tango::metrics::Table;
use tango::model::TrainMode;
use tango::sampler::MiniBatchTrainer;

/// Epochs timed per run (first run also warms the process allocator).
const EPOCHS: usize = 2;
/// Timed repetitions; best-of damps scheduler noise.
const REPS: usize = 3;

fn epoch_secs(task: TaskKind, prefetch: usize) -> f64 {
    let mut cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: match task {
            TaskKind::NodeClassification => "Pubmed".into(),
            TaskKind::LinkPrediction => "DBLP".into(),
        },
        epochs: EPOCHS,
        hidden: 64,
        mode: TrainMode::tango(8),
        log_every: 0,
        task: Some(task),
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![10, 10];
    cfg.sampler.batch_size = 512;
    cfg.sampler.prefetch = prefetch;
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        best = best.min(r.wall_secs / EPOCHS as f64);
    }
    best
}

fn main() {
    // Pin the worker pool so the producer thread competes with a known
    // number of compute threads, not whatever the host happens to have.
    if std::env::var("TANGO_THREADS").is_err() {
        std::env::set_var("TANGO_THREADS", "4");
    }
    println!(
        "bench: sequential vs pipelined sampled epochs (quantized gather, \
         TANGO_THREADS={}, best of {REPS})\n",
        std::env::var("TANGO_THREADS").unwrap()
    );
    let mut t = Table::new(
        "bench: batch-prefetch pipeline (paper §4.2 overlap)",
        &["task", "dataset", "seq s/ep", "piped s/ep", "overlap speedup"],
    );
    for (task, dataset) in [
        (TaskKind::NodeClassification, "Pubmed"),
        (TaskKind::LinkPrediction, "DBLP"),
    ] {
        let name = task_name(task.to_task());
        let seq = epoch_secs(task, 0);
        let piped = epoch_secs(task, 2);
        println!(
            "{name} on {dataset}: sequential {seq:.4} s/epoch, pipelined {piped:.4} s/epoch \
             ({:.2}x)",
            seq / piped
        );
        // The whole point of the PR: the real overlap must not be slower
        // than running the stages back to back.
        assert!(
            piped <= seq,
            "{name}: pipelined epoch ({piped:.4}s) slower than sequential ({seq:.4}s)"
        );
        t.row(&[
            name.to_string(),
            dataset.into(),
            format!("{seq:.4}"),
            format!("{piped:.4}"),
            format!("{:.2}x", seq / piped),
        ]);
    }
    t.print();
}
