//! Packed-kernel bench: sub-byte SPMM directly on bit-packed rows vs the
//! dequantize-to-f32 path, over the same skewed-degree
//! (preferential-attachment) graph the policy bench uses.
//!
//! The dequantize baseline is what the `Dequantize` backend does with a
//! packed gather payload: materialize the f32 matrix
//! (`QuantRows::dequantize`) and run the FP32 SPMM. The packed path
//! (`packed_spmm`, the `--packed-compute` backend) consumes the bitstream
//! directly — at 4 bits and below it reads an 8–16× smaller random-access
//! operand and skips the f32 materialization entirely, which is the paper's
//! §3.3 "quantization must pay at compute time" claim in miniature. The run
//! asserts the packed SPMM epoch wins at every width ≤ 4 bits and emits a
//! machine-readable `BENCH_packed.json` (schema `tango-bench/packed/v1`)
//! beside `BENCH_train_speed.json` so CI can archive per-subsystem speed
//! trajectories.

use std::collections::BTreeMap;
use std::time::Instant;
use tango::graph::generators::{power_law, random_features};
use tango::graph::Csr;
use tango::metrics::Table;
use tango::policy::PolicyConfig;
use tango::primitives::{packed_spmm, spmm_edge_weighted};
use tango::quant::{dequantize, quantize, Rounding};
use tango::sampler::{QuantFeatureStore, QuantRows};
use tango::util::cli::Args;
use tango::util::json::Json;

/// Graph size: big enough to stress memory traffic, small enough for CI.
const NODES: usize = 8000;
/// Preferential-attachment edges per node (skewed in-degrees).
const EDGES_PER_NODE: usize = 4;
/// Feature width.
const DIM: usize = 64;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

/// Total wall seconds of `iters` runs of `body` after `warm` warmups.
fn time_iters(warm: usize, iters: usize, mut body: impl FnMut()) -> f64 {
    for _ in 0..warm {
        body();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        body();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    // Pin the worker pool for stable measurements.
    if std::env::var("TANGO_THREADS").is_err() {
        std::env::set_var("TANGO_THREADS", "4");
    }
    let args = Args::from_env();
    let quick = args.get_bool("quick");
    let iters = if quick { 8 } else { 30 };

    let coo = power_law(NODES, EDGES_PER_NODE, 7)
        .with_reverse_edges()
        .dedup()
        .with_self_loops();
    let csr = Csr::from_coo(&coo);
    let degrees = coo.in_degrees();
    let features = random_features(NODES, DIM, 11);
    let edges = coo.num_edges();
    println!("graph: {NODES} nodes, {edges} edges, dim {DIM}, {iters} iters/config\n");

    // One shared edge-weight operand (α in the aggregation) for every
    // config; only the node-feature operand changes representation.
    let qalpha = quantize(&random_features(edges, 1, 13), 8, Rounding::Nearest);
    let alpha_f32 = dequantize(&qalpha);

    // Uniform widths, plus the PR-5 skewed-degree mixed policy (hubs at
    // INT8, cold tail at 6/4 bits) gathered over the full node set.
    let mixed_rows = {
        let pc = PolicyConfig { degree_buckets: vec![8, 32], bucket_bits: vec![8, 6, 4] };
        let policy = pc.materialize(8, &degrees, &features).expect("valid policy");
        let mut store = QuantFeatureStore::with_policy(policy, 0);
        let all: Vec<u32> = (0..NODES as u32).collect();
        store.gather_quantized(&features, &all)
    };
    let configs: Vec<(String, QuantRows, Option<u8>)> = [8u8, 4, 2, 1]
        .iter()
        .map(|&bits| {
            let q = quantize(&features, bits, Rounding::Nearest);
            (format!("uniform {bits}-bit"), QuantRows::from_qtensor(&q), Some(bits))
        })
        .chain(std::iter::once(("mixed 8/6/4".to_string(), mixed_rows, None)))
        .collect();

    let mut t = Table::new(
        "bench: packed SPMM vs dequantize-to-f32 (one epoch = one full-graph SPMM)",
        &["config", "packed KiB", "f32 KiB", "dequant s", "packed s", "speedup"],
    );
    let mut results: Vec<Json> = Vec::new();
    let f32_bytes = NODES * DIM * 4;
    for (name, rows, bits) in &configs {
        let deq_s = time_iters(2, iters, || {
            let h = rows.dequantize();
            std::hint::black_box(spmm_edge_weighted(&csr, &alpha_f32, &h, 1).len());
        });
        let packed_s = time_iters(2, iters, || {
            std::hint::black_box(packed_spmm(&csr, &qalpha, rows, 1).len());
        });
        let speedup = deq_s / packed_s.max(1e-12);
        println!(
            "{name}: dequantize {deq_s:.4} s, packed {packed_s:.4} s ({speedup:.2}x), \
             payload {:.1} KiB vs {:.1} KiB f32",
            rows.packed_bytes() as f64 / 1024.0,
            f32_bytes as f64 / 1024.0
        );
        t.row(&[
            name.clone(),
            format!("{:.1}", rows.packed_bytes() as f64 / 1024.0),
            format!("{:.1}", f32_bytes as f64 / 1024.0),
            format!("{deq_s:.4}"),
            format!("{packed_s:.4}"),
            format!("{speedup:.2}x"),
        ]);
        results.push(obj(vec![
            ("config", Json::Str(name.clone())),
            ("bits", bits.map(|b| Json::Num(b as f64)).unwrap_or(Json::Null)),
            ("packed_bytes", Json::Num(rows.packed_bytes() as f64)),
            ("f32_bytes", Json::Num(f32_bytes as f64)),
            ("dequantize_s", Json::Num(deq_s)),
            ("packed_s", Json::Num(packed_s)),
            ("speedup", Json::Num(speedup)),
        ]));
        // The acceptance criterion: at ≤ 4 bits, computing on the packed
        // payload must beat dequantize-then-f32-SPMM on this graph.
        if let Some(b) = bits {
            if *b <= 4 {
                assert!(
                    packed_s < deq_s,
                    "{name}: packed SPMM must win at <= 4 bits ({packed_s:.4} vs {deq_s:.4} s)"
                );
            }
        }
    }
    t.print();

    let speedups: Vec<f64> =
        results.iter().filter_map(|r| r.get("speedup").and_then(|v| v.as_f64())).collect();
    let mean_speedup = if speedups.is_empty() {
        0.0
    } else {
        speedups.iter().sum::<f64>() / speedups.len() as f64
    };
    let rows = results.len();
    let artifact = obj(vec![
        ("schema", Json::Str("tango-bench/packed/v1".into())),
        ("bench", Json::Str("packed".into())),
        ("nodes", Json::Num(NODES as f64)),
        ("edges", Json::Num(edges as f64)),
        ("dim", Json::Num(DIM as f64)),
        ("iters", Json::Num(iters as f64)),
        ("quick", Json::Bool(quick)),
        ("results", Json::Arr(results)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_packed.json");
    tango::util::fsio::write_atomic(path, &artifact.to_string()).expect("write BENCH_packed.json");
    println!("\nwrote {path}");
    // One-row summary appended to the cross-commit perf trajectory (the
    // full artifact above is overwritten per run; the history accumulates).
    let history = obj(vec![
        ("schema", Json::Str("tango-bench/history/v1".into())),
        ("bench", Json::Str("packed".into())),
        ("quick", Json::Bool(quick)),
        ("rows", Json::Num(rows as f64)),
        ("mean_speedup", Json::Num(mean_speedup)),
    ]);
    let hist_path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_history.jsonl");
    tango::util::fsio::append_line_atomic(hist_path, &history.to_string())
        .expect("append BENCH_history.jsonl");
    println!("appended {hist_path}");
}
