//! Fig. 13/14/Table 2 bench: SPMM variants across the scaled datasets.

use tango::graph::datasets;
use tango::graph::generators::random_features;
use tango::graph::{Csr, Incidence};
use tango::metrics::{bench, Table};
use tango::primitives::{
    incidence_spmm, qspmm_edge_weighted, spmm_edge_aggregate_3mat, spmm_edge_weighted,
    spmm_per_head, spmm_via_spmvs,
};
use tango::quant::{quantize, Rounding};

fn main() {
    let mut t13a = Table::new(
        "bench: incidence SPMM vs 3-matrix (fig13a)",
        &["dataset", "feat", "3mat ms", "incidence ms", "speedup"],
    );
    for name in ["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"] {
        let data = datasets::load_by_name(name, 1);
        let csr = Csr::from_coo(&data.graph);
        let inc = Incidence::from_csr(&csr);
        for f in [4usize, 16] {
            let ef = random_features(csr.num_edges, f, 2);
            let base = bench(&format!("{name} 3mat f{f}"), || spmm_edge_aggregate_3mat(&csr, &ef));
            let ours = bench(&format!("{name} incidence f{f}"), || incidence_spmm(&inc, &ef));
            println!("{}", base.summary());
            println!("{}", ours.summary());
            t13a.row(&[
                name.into(),
                f.to_string(),
                format!("{:.2}", base.mean * 1e3),
                format!("{:.2}", ours.mean * 1e3),
                format!("{:.2}x", base.mean / ours.mean),
            ]);
        }
    }
    t13a.print();

    let mut tq = Table::new(
        "bench: quantized vs fp32 edge-weighted SPMM",
        &["dataset", "heads*D", "fp32 ms", "int8 ms", "speedup"],
    );
    for name in ["ogbn-arxiv", "ogbn-products"] {
        let data = datasets::load_by_name(name, 1);
        let csr = Csr::from_coo(&data.graph);
        let (h, d) = (4usize, 32usize);
        let alpha = random_features(csr.num_edges, h, 3);
        let x = random_features(csr.num_nodes, h * d, 4);
        let f = bench(&format!("{name} spmm f32"), || spmm_edge_weighted(&csr, &alpha, &x, h));
        let qa = quantize(&alpha, 8, Rounding::Nearest);
        let qx = quantize(&x, 8, Rounding::Nearest);
        let q = bench(&format!("{name} spmm q8"), || qspmm_edge_weighted(&csr, &qa, &qx, h));
        println!("{}", f.summary());
        println!("{}", q.summary());
        tq.row(&[
            name.into(),
            format!("{h}*{d}"),
            format!("{:.2}", f.mean * 1e3),
            format!("{:.2}", q.mean * 1e3),
            format!("{:.2}x", f.mean / q.mean),
        ]);
    }
    tq.print();

    // fig13b per-head split and fig14 many-SpMV on arxiv.
    let data = datasets::load_by_name("ogbn-arxiv", 1);
    let csr = Csr::from_coo(&data.graph);
    let mut t13b = Table::new("bench: per-head split (fig13b)", &["heads", "native ms", "split ms", "speedup"]);
    for h in [2usize, 4, 8] {
        let alpha = random_features(csr.num_edges, h, 5);
        let x = random_features(csr.num_nodes, h * 16, 6);
        let native = bench(&format!("native h{h}"), || spmm_edge_weighted(&csr, &alpha, &x, h));
        let split = bench(&format!("split h{h}"), || spmm_per_head(&csr, &alpha, &x, h));
        t13b.row(&[
            h.to_string(),
            format!("{:.2}", native.mean * 1e3),
            format!("{:.2}", split.mean * 1e3),
            format!("{:.2}x", native.mean / split.mean),
        ]);
    }
    t13b.print();

    let mut t14 = Table::new("bench: many-SpMV transform (fig14)", &["feat", "native ms", "spmv ms", "speedup"]);
    for f in [2usize, 6, 12] {
        let alpha = random_features(csr.num_edges, 1, 7);
        let x = random_features(csr.num_nodes, f, 8);
        let native = bench(&format!("native f{f}"), || spmm_edge_weighted(&csr, &alpha, &x, 1));
        let spmv = bench(&format!("spmv f{f}"), || spmm_via_spmvs(&csr, &alpha, &x, 1));
        t14.row(&[
            f.to_string(),
            format!("{:.2}", native.mean * 1e3),
            format!("{:.2}", spmv.mean * 1e3),
            format!("{:.2}x", native.mean / spmv.mean),
        ]);
    }
    t14.print();
}
