//! Fig. 15/16a bench: SDDMM add/dot, FP32 vs INT8 vs INT4-range.

use tango::graph::datasets;
use tango::graph::generators::random_features;
use tango::metrics::{bench, Table};
use tango::primitives::{qsddmm_add, qsddmm_dot, sddmm_add, sddmm_dot};
use tango::quant::{quantize, Rounding};

fn main() {
    let (heads, d) = (4usize, 64usize);
    let mut t = Table::new(
        "bench: SDDMM (fig15/fig16a)",
        &["dataset", "kind", "fp32 ms", "int8 ms", "int4 ms", "q8 speedup", "q4 speedup"],
    );
    for name in ["ogbn-arxiv", "ogbn-products", "Pubmed", "DBLP", "Amazon"] {
        let data = datasets::load_by_name(name, 1);
        let coo = &data.graph;
        let n = coo.num_nodes;
        // add variant (attention logits shape [N, H])
        let s = random_features(n, heads, 2);
        let dd = random_features(n, heads, 3);
        let q8s = quantize(&s, 8, Rounding::Nearest);
        let q8d = quantize(&dd, 8, Rounding::Nearest);
        let q4s = quantize(&s, 4, Rounding::Nearest);
        let q4d = quantize(&dd, 4, Rounding::Nearest);
        let af = bench(&format!("{name} add f32"), || sddmm_add(coo, &s, &dd));
        let a8 = bench(&format!("{name} add q8"), || qsddmm_add(coo, &q8s, &q8d));
        let a4 = bench(&format!("{name} add q4"), || qsddmm_add(coo, &q4s, &q4d));
        t.row(&[
            name.into(),
            "add".into(),
            format!("{:.2}", af.mean * 1e3),
            format!("{:.2}", a8.mean * 1e3),
            format!("{:.2}", a4.mean * 1e3),
            format!("{:.2}x", af.mean / a8.mean),
            format!("{:.2}x", af.mean / a4.mean),
        ]);
        // dot variant (gradient shape [N, H*D])
        let a = random_features(n, heads * d, 4);
        let b = random_features(n, heads * d, 5);
        let q8a = quantize(&a, 8, Rounding::Nearest);
        let q8b = quantize(&b, 8, Rounding::Nearest);
        let q4a = quantize(&a, 4, Rounding::Nearest);
        let q4b = quantize(&b, 4, Rounding::Nearest);
        let df = bench(&format!("{name} dot f32"), || sddmm_dot(coo, &a, &b, heads));
        let d8 = bench(&format!("{name} dot q8"), || qsddmm_dot(coo, &q8a, &q8b, heads));
        let d4 = bench(&format!("{name} dot q4"), || qsddmm_dot(coo, &q4a, &q4b, heads));
        t.row(&[
            name.into(),
            "dot".into(),
            format!("{:.2}", df.mean * 1e3),
            format!("{:.2}", d8.mean * 1e3),
            format!("{:.2}", d4.mean * 1e3),
            format!("{:.2}x", df.mean / d8.mean),
            format!("{:.2}x", df.mean / d4.mean),
        ]);
    }
    t.print();
}
