//! Policy bench: uniform INT8 vs degree-bucketed mixed-precision gather
//! over one sampled epoch of a skewed-degree (preferential-attachment)
//! graph — the Degree-Quant/BiFeat trade made measurable: hot hub nodes
//! stay at INT8 while the long cold tail packs at 6/4 bits, so the mixed
//! policy gathers strictly fewer bytes for the same sampled row traffic.
//!
//! Both stores see the *same* block stream (sampling is independent of the
//! store), so the INT8 baseline bytes of the two runs are identical and
//! the packed-byte gap is purely the policy's doing. The run asserts
//! `mixed packed < uniform INT8` — the acceptance criterion of the policy
//! subsystem — and reports wall time per store.

use std::time::Instant;
use tango::graph::generators::{power_law, random_features};
use tango::graph::Csr;
use tango::metrics::Table;
use tango::policy::PolicyConfig;
use tango::sampler::{shuffled_batches, NeighborSampler, QuantFeatureStore};

/// Graph size: big enough for a real byte gap, small enough for CI.
const NODES: usize = 8000;
/// Preferential-attachment edges per node (skewed in-degrees).
const EDGES_PER_NODE: usize = 4;
/// Feature width.
const DIM: usize = 64;
/// Seeds per mini-batch.
const BATCH: usize = 256;

fn main() {
    // Pin the worker pool for stable measurements.
    if std::env::var("TANGO_THREADS").is_err() {
        std::env::set_var("TANGO_THREADS", "4");
    }
    let coo = power_law(NODES, EDGES_PER_NODE, 7)
        .with_reverse_edges()
        .dedup()
        .with_self_loops();
    let csr = Csr::from_coo(&coo);
    let degrees = coo.in_degrees();
    let features = random_features(NODES, DIM, 11);
    let hubs = degrees.iter().filter(|&&d| d >= 32).count();
    let tail = degrees.iter().filter(|&&d| d < 8).count();
    println!(
        "graph: {NODES} nodes, {} edges, {hubs} hubs (deg >= 32), {tail} cold-tail \
         nodes (deg < 8)\n",
        coo.num_edges()
    );

    let sampler = NeighborSampler::new(vec![10, 10], 3);
    let all: Vec<u32> = (0..NODES as u32).collect();
    let batches = shuffled_batches(&all, BATCH, 5);

    let policies: [(&str, PolicyConfig); 2] = [
        ("uniform INT8", PolicyConfig::default()),
        (
            "mixed 8/6/4",
            PolicyConfig { degree_buckets: vec![8, 32], bucket_bits: vec![8, 6, 4] },
        ),
    ];
    let mut t = Table::new(
        "bench: degree-aware mixed-precision gather (one sampled epoch)",
        &["policy", "rows", "packed KiB", "INT8 KiB", "ratio", "epoch s"],
    );
    let mut results: Vec<(u64, u64)> = Vec::new();
    for (name, pc) in &policies {
        let policy = pc.materialize(8, &degrees, &features).expect("valid policy");
        let mut store = QuantFeatureStore::with_policy(policy, 0);
        let t0 = Instant::now();
        for (bi, batch) in batches.iter().enumerate() {
            let blocks = sampler.sample_blocks(&csr, &degrees, batch, bi as u64);
            let q = store.gather_quantized(&features, &blocks[0].src_nodes);
            std::hint::black_box(q.packed_bytes());
        }
        let secs = t0.elapsed().as_secs_f64();
        let report = store.policy_report();
        let rows: u64 = report.buckets.iter().map(|b| b.rows).sum();
        let (packed, int8) = (report.packed_bytes(), report.int8_bytes());
        println!(
            "{name}: {rows} rows gathered, {:.1} KiB packed vs {:.1} KiB INT8 in {secs:.4} s",
            packed as f64 / 1024.0,
            int8 as f64 / 1024.0
        );
        for line in report.summary_lines() {
            println!("  {line}");
        }
        t.row(&[
            name.to_string(),
            rows.to_string(),
            format!("{:.1}", packed as f64 / 1024.0),
            format!("{:.1}", int8 as f64 / 1024.0),
            format!("{:.2}x", int8 as f64 / (packed as f64).max(1.0)),
            format!("{secs:.4}"),
        ]);
        results.push((packed, int8));
    }
    t.print();

    let (uniform_packed, uniform_int8) = results[0];
    let (mixed_packed, mixed_int8) = results[1];
    // Same block stream → same rows → same INT8 baseline.
    assert_eq!(
        uniform_int8, mixed_int8,
        "both stores must see identical gather traffic"
    );
    assert_eq!(uniform_packed, uniform_int8, "INT8 packs 1:1");
    // The acceptance criterion: mixed-policy gathered bytes beat uniform
    // INT8 on a skewed-degree graph.
    assert!(
        mixed_packed < uniform_int8,
        "mixed policy must gather fewer bytes: {mixed_packed} vs {uniform_int8}"
    );
    println!(
        "\nmixed policy gathers {:.1}% of the uniform INT8 bytes",
        mixed_packed as f64 / uniform_int8 as f64 * 100.0
    );
}
