//! Property tests for the bit-packed payload and the packed sub-byte
//! kernels (`quant::pack`, `sampler::QuantRows`, `primitives::packed`):
//!
//! - pack → unpack is bit-identical at every nominal width 1..=8;
//! - `QuantRows::from_qtensor` round-trips the codes and scale exactly;
//! - on uniform-scale batches the packed kernels are **bit-identical** to
//!   the dense-i8 reference kernels (`qspmm_edge_weighted`,
//!   `qgemm_prequantized`) — the invariant that lets `PrimitiveBackend`
//!   flip without perturbing training numerics;
//! - on mixed-policy batches (per-row widths and scales) the packed
//!   kernels match a transliterated per-edge/per-row reference exactly.

use tango::graph::{Coo, Csr};
use tango::primitives::{
    packed_qgemm, packed_spmm, qgemm_prequantized, qspmm_edge_weighted, PrimitiveBackend,
};
use tango::quant::{pack_row, packed_len, qmax_for_bits, quantize, unpack_row, QTensor, Rounding};
use tango::sampler::QuantRows;
use tango::tensor::Dense;
use tango::util::prop::{check, Gen};

/// A random on-grid i8 value for a nominal width.
fn grid_i8(g: &mut Gen, bits: u8) -> i8 {
    let qmax = qmax_for_bits(bits);
    (g.usize_in(0, 2 * qmax as usize) as i32 - qmax) as i8
}

fn random_graph(g: &mut Gen, max_nodes: usize, max_edges: usize) -> Coo {
    let (n, src, dst) = g.graph(max_nodes, max_edges);
    Coo::new(n, src, dst)
}

fn random_dense(g: &mut Gen, rows: usize, cols: usize) -> Dense<f32> {
    Dense::from_vec(&[rows, cols], g.f32_vec(rows * cols, -2.0, 2.0))
}

/// A random mixed-policy batch: per-row widths and scales, values on each
/// row's grid. At least two distinct widths, so `uniform()` is `None` and
/// the kernels take their mixed arms.
fn random_mixed_rows(g: &mut Gen, m: usize, k: usize) -> QuantRows {
    const WIDTHS: [u8; 6] = [1, 2, 3, 4, 6, 8];
    let mut bits: Vec<u8> = (0..m).map(|_| WIDTHS[g.usize_in(0, WIDTHS.len() - 1)]).collect();
    if m >= 2 && bits.iter().all(|&b| b == bits[0]) {
        bits[1] = if bits[0] == 2 { 4 } else { 2 };
    }
    let scales: Vec<f32> = (0..m).map(|_| g.f32_in(1e-3, 0.5)).collect();
    let mut data = Dense::<i8>::zeros(&[m, k]);
    for i in 0..m {
        let b = bits[i];
        for v in data.row_mut(i) {
            *v = grid_i8(g, b);
        }
    }
    QuantRows::from_i8_rows(&data, scales, bits)
}

/// The mixed-batch SPMM arithmetic, transliterated: fold each edge at
/// `s_α · s_row[u]` in CSR row order — the exact expression (and f32
/// evaluation order) `packed_spmm`'s mixed arm uses.
fn reference_mixed_spmm(csr: &Csr, qalpha: &QTensor, rows: &QuantRows, heads: usize) -> Dense<f32> {
    let hd = rows.dim();
    let d = hd / heads;
    let mut out = Dense::zeros(&[csr.num_nodes, hd]);
    for v in 0..csr.num_nodes {
        let orow = out.row_mut(v);
        let (srcs, eids) = csr.row(v);
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            let u = u as usize;
            let fac = qalpha.scale * rows.scales[u];
            let q = rows.row_i8(u);
            let arow = qalpha.data.row(e as usize);
            for hh in 0..heads {
                let a = arow[hh] as i32;
                for dd in 0..d {
                    let i = hh * d + dd;
                    orow[i] += (a * q[i] as i32) as f32 * fac;
                }
            }
        }
    }
    out
}

/// The mixed-batch GEMM arithmetic, transliterated: exact i32 row
/// accumulation, dequantized at `s_row[i] · s_B`, output scale from the
/// global abs-max. Integer accumulation order is immaterial and the
/// per-element store expression matches `packed_qgemm`'s, so the comparison
/// is exact.
fn reference_mixed_qgemm(qa: &QuantRows, qb: &QTensor, out_bits: u8) -> (Dense<f32>, f32) {
    let (m, k) = (qa.rows(), qa.dim());
    let n = qb.data.cols();
    let mut out = Dense::zeros(&[m, n]);
    let mut absmax = 0.0f32;
    for i in 0..m {
        let arow = qa.row_i8(i);
        let deq = qa.scales[i] * qb.scale;
        let crow = out.row_mut(i);
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += arow[kk] as i32 * qb.data.at(kk, j) as i32;
            }
            let v = acc as f32 * deq;
            crow[j] = v;
            absmax = absmax.max(v.abs());
        }
    }
    let qmax = ((1i32 << (out_bits - 1)) - 1) as f32;
    let scale = if absmax == 0.0 { 1.0 } else { absmax / qmax };
    (out, scale)
}

#[test]
fn prop_pack_roundtrip_bit_identity_all_widths() {
    check("pack roundtrip 1..=8", 120, |g| {
        let bits = g.usize_in(1, 8) as u8;
        let n = g.usize_in(1, 70);
        let row: Vec<i8> = (0..n).map(|_| grid_i8(g, bits)).collect();
        let packed = pack_row(&row, bits);
        assert_eq!(packed.len(), packed_len(n, bits), "bits {bits} n {n}");
        assert_eq!(unpack_row(&packed, bits, n), row, "bits {bits} n {n}");
    });
}

#[test]
fn prop_quantrows_roundtrips_qtensor_exactly() {
    check("QuantRows <-> QTensor", 80, |g| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 48);
        let bits = [1u8, 2, 4, 8][g.usize_in(0, 3)];
        let q = quantize(&random_dense(g, m, k), bits, Rounding::Nearest);
        let rows = QuantRows::from_qtensor(&q);
        assert_eq!(rows.unpack_dense(), q.data, "codes survive packing");
        assert_eq!(rows.uniform(), Some((q.scale, q.bits)));
        let back = rows.to_qtensor().expect("uniform batch converts back");
        assert_eq!(back.data, q.data);
        assert_eq!(back.scale, q.scale);
        assert_eq!(back.bits, q.bits);
        let nominal: usize = (0..m).map(|_| packed_len(k, bits)).sum();
        assert_eq!(rows.packed_bytes(), nominal, "no hidden padding");
    });
}

#[test]
fn prop_uniform_packed_spmm_is_bit_identical_to_dense_kernel() {
    check("uniform packed_spmm == qspmm", 50, |g| {
        let coo = random_graph(g, 40, 160);
        if coo.num_edges() == 0 {
            return;
        }
        let csr = Csr::from_coo(&coo);
        let heads = g.usize_in(1, 2);
        let d = g.usize_in(1, 10);
        let bits = [1u8, 2, 4, 8][g.usize_in(0, 3)];
        let qa = quantize(&random_dense(g, coo.num_edges(), heads), 8, Rounding::Nearest);
        let qh = quantize(&random_dense(g, coo.num_nodes, heads * d), bits, Rounding::Nearest);
        let dense = qspmm_edge_weighted(&csr, &qa, &qh, heads);
        let packed = packed_spmm(&csr, &qa, &QuantRows::from_qtensor(&qh), heads);
        assert_eq!(dense, packed, "heads {heads} bits {bits}");
        // The model-facing seam routes through the same kernels.
        let via_seam = PrimitiveBackend::Packed.qspmm(&csr, &qa, &qh, heads);
        assert_eq!(dense, via_seam);
    });
}

#[test]
fn prop_uniform_packed_qgemm_is_bit_identical_to_dense_kernel() {
    check("uniform packed_qgemm == qgemm_prequantized", 50, |g| {
        let m = g.usize_in(1, 80);
        let k = g.usize_in(1, 40);
        let n = g.usize_in(1, 12);
        let bits = [1u8, 2, 4, 8][g.usize_in(0, 3)];
        let qa = quantize(&random_dense(g, m, k), bits, Rounding::Nearest);
        let qb = quantize(&random_dense(g, k, n), 8, Rounding::Nearest);
        let (dense, s_dense) = qgemm_prequantized(&qa, &qb, 8);
        let (packed, s_packed) = packed_qgemm(&QuantRows::from_qtensor(&qa), &qb, 8);
        assert_eq!(dense, packed, "bits {bits}");
        assert_eq!(s_dense, s_packed, "bits {bits}");
    });
}

#[test]
fn prop_mixed_packed_spmm_matches_reference() {
    check("mixed packed_spmm == per-edge reference", 50, |g| {
        let coo = random_graph(g, 30, 120);
        // Need >= 2 nodes so the batch can carry two distinct widths (a
        // single-row batch is uniform by construction and would take the
        // kernel's exact-i32 arm instead of the per-edge fold).
        if coo.num_edges() == 0 || coo.num_nodes < 2 {
            return;
        }
        let csr = Csr::from_coo(&coo);
        let heads = g.usize_in(1, 2);
        let d = g.usize_in(1, 8);
        let rows = random_mixed_rows(g, coo.num_nodes, heads * d);
        let qa = quantize(&random_dense(g, coo.num_edges(), heads), 8, Rounding::Nearest);
        let packed = packed_spmm(&csr, &qa, &rows, heads);
        let reference = reference_mixed_spmm(&csr, &qa, &rows, heads);
        assert_eq!(packed, reference);
    });
}

#[test]
fn prop_mixed_packed_qgemm_matches_reference() {
    check("mixed packed_qgemm == per-row reference", 50, |g| {
        let m = g.usize_in(2, 80);
        let k = g.usize_in(1, 40);
        let n = g.usize_in(1, 12);
        let qa = random_mixed_rows(g, m, k);
        let qb = quantize(&random_dense(g, k, n), 8, Rounding::Nearest);
        let (packed, s_packed) = packed_qgemm(&qa, &qb, 8);
        let (reference, s_ref) = reference_mixed_qgemm(&qa, &qb, 8);
        assert_eq!(packed, reference);
        assert_eq!(s_packed, s_ref);
    });
}
