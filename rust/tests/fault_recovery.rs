//! Fault-tolerant training (PR 9 tentpole): crash/resume bit-identity and
//! per-fault-class recovery.
//!
//! The contract under test: recovery is **invisible to the numerics**. A
//! run that checkpoints, crashes and resumes — or absorbs an injected
//! fault inside its retry budget — produces the same bit-exact parameters,
//! losses and evals as the uninterrupted, fault-free control. Fault
//! schedules key on the *global step* under a fixed seed (never
//! wall-clock), so every scenario here is deterministic; the simulated
//! exponential backoff is charged into the report, never slept.
//!
//! Covered per class: producer panics (restart within budget / fatal past
//! it), multigpu worker failures (peer rebuild / fatal past budget with a
//! `--resume` pointer), ring link drops (re-charged retry / degrade to
//! skip-straggler past budget), and shared-store lock poisoning (recovered
//! on both the real store mutex and the FP32 scratch path).

use tango::ckpt::Checkpoint;
use tango::config::{parse_mode, ModelKind, TrainConfig};
use tango::coordinator::TrainReport;
use tango::graph::datasets;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig, MultiGpuReport};
use tango::sampler::MiniBatchTrainer;
use tango::util::json::Json;

fn tmp(name: &str) -> String {
    std::env::temp_dir().join(name).to_string_lossy().into_owned()
}

fn train_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs: 3,
        lr: 0.1,
        hidden: 8,
        heads: 2,
        layers: 2,
        mode: parse_mode("tango", 8).unwrap(),
        auto_bits: false,
        seed,
        log_every: 0,
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![4, 4];
    cfg.sampler.batch_size = 32; // tiny: 160 train nodes -> 5 batches/epoch
    cfg.sampler.prefetch = 2;
    cfg
}

/// Run to completion, returning the report and the trained parameters.
fn run_train(cfg: &TrainConfig) -> (TrainReport, Vec<f32>) {
    let mut t = MiniBatchTrainer::from_config(cfg).unwrap();
    let report = t.run().unwrap();
    let params = t.params_flat();
    (report, params)
}

fn mg_cfg(seed: u64, workers: usize, quantize: bool, mode: &str) -> MultiGpuConfig {
    let mut train = train_cfg(seed);
    train.mode = parse_mode(mode, 8).unwrap();
    MultiGpuConfig {
        train,
        workers,
        epochs: 3,
        quantize_grads: quantize,
        interconnect: Interconnect::pcie3(),
    }
}

fn losses(r: &MultiGpuReport) -> Vec<f32> {
    r.epochs.iter().map(|e| e.loss).collect()
}

// ------------------------------------------------------- producer faults

#[test]
fn recovered_producer_panics_leave_the_run_bit_identical() {
    let base = train_cfg(7);
    let (control, control_params) = run_train(&base);

    let mut faulted = base.clone();
    faulted.fault.inject = true;
    // Global steps 3 and 8 = batch 3 of epochs 0 and 1 (5 batches/epoch).
    faulted.fault.producer_steps = vec![3, 8];
    let (r, params) = run_train(&faulted);

    assert_eq!(r.losses, control.losses);
    assert_eq!(r.evals, control.evals);
    assert_eq!(params, control_params);
    let f = r.fault.expect("injected run reports its fault ledger");
    assert!(f.any_fired());
    assert_eq!(f.producer_panics, 2);
    assert_eq!(f.producer_restarts, 2);
    assert!(f.backoff_s > 0.0, "simulated backoff is charged, not slept");
    // An uninjected run carries no ledger at all.
    assert!(control.fault.is_none());
}

#[test]
fn producer_retry_budget_exhaustion_is_a_named_error() {
    let mut cfg = train_cfg(7);
    cfg.fault.inject = true;
    // The same step three times = two restarts, then the third panic
    // overruns the default budget of 2.
    cfg.fault.producer_steps = vec![3, 3, 3];
    let e = MiniBatchTrainer::from_config(&cfg).unwrap().run().unwrap_err().to_string();
    assert!(e.contains("retry budget"), "{e}");
    assert!(e.contains("batch 3"), "{e}");
}

// ---------------------------------------------------- train crash/resume

#[test]
fn train_crash_and_resume_is_bit_identical_to_the_control() {
    let path = tmp("tango_fault_train_crash_resume.json");
    std::fs::remove_file(&path).ok();
    let base = train_cfg(9);
    let (control, control_params) = run_train(&base);

    // Crash: checkpoint every 2 steps, then a producer panic at global
    // step 3 with a zero retry budget kills the run mid-epoch.
    let mut crashed = base.clone();
    crashed.ckpt.every = 2;
    crashed.ckpt.path = path.clone();
    crashed.fault.inject = true;
    crashed.fault.producer_steps = vec![3];
    crashed.fault.max_retries = 0;
    let e = MiniBatchTrainer::from_config(&crashed).unwrap().run().unwrap_err().to_string();
    assert!(e.contains("retry budget"), "{e}");
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!((ck.cursor.epoch, ck.cursor.step), (0, 2), "mid-epoch checkpoint");

    // Resume: same config pointed at the checkpoint continues the trace.
    let mut resumed = base.clone();
    resumed.ckpt.every = 2;
    resumed.ckpt.path = path.clone();
    resumed.ckpt.resume = Some(path.clone());
    let (r, params) = run_train(&resumed);
    assert_eq!(r.losses, control.losses);
    assert_eq!(r.evals, control.evals);
    assert_eq!(params, control_params);
    assert!(r.fault.is_none());
    std::fs::remove_file(&path).ok();
}

#[test]
fn train_resume_extends_a_completed_run_across_the_epoch_boundary() {
    let path = tmp("tango_fault_train_epoch_boundary.json");
    std::fs::remove_file(&path).ok();
    let base = train_cfg(11);
    let (control, control_params) = run_train(&base);

    // One epoch, run-complete checkpoint (the periodic cadence never hits).
    let mut first = base.clone();
    first.epochs = 1;
    first.ckpt.every = 1000;
    first.ckpt.path = path.clone();
    run_train(&first);
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!((ck.cursor.epoch, ck.cursor.step), (1, 0));

    // Resuming under the full epoch budget replays epochs 1..3 exactly.
    let mut rest = base.clone();
    rest.ckpt.resume = Some(path.clone());
    let (r, params) = run_train(&rest);
    assert_eq!(r.losses, control.losses);
    assert_eq!(r.evals, control.evals);
    assert_eq!(params, control_params);
    std::fs::remove_file(&path).ok();
}

// -------------------------------------------------- multigpu crash/resume

#[test]
fn multigpu_crash_and_resume_is_bit_identical_to_the_control() {
    let ctrl_path = tmp("tango_fault_mg_control.json");
    let path = tmp("tango_fault_mg_crash.json");
    std::fs::remove_file(&ctrl_path).ok();
    std::fs::remove_file(&path).ok();
    let data = datasets::tiny(13);

    let mut control_cfg = mg_cfg(13, 2, true, "tango");
    control_cfg.train.ckpt.every = 4;
    control_cfg.train.ckpt.path = ctrl_path.clone();
    let control = run_data_parallel(&control_cfg, &data).unwrap();

    // Crash: round-boundary checkpoint every 4 all-reduce rounds, then a
    // worker failure at round 5 with a zero retry budget.
    let mut crashed = mg_cfg(13, 2, true, "tango");
    crashed.train.ckpt.every = 4;
    crashed.train.ckpt.path = path.clone();
    crashed.train.fault.inject = true;
    crashed.train.fault.worker_steps = vec![5];
    crashed.train.fault.max_retries = 0;
    let e = run_data_parallel(&crashed, &data).unwrap_err().to_string();
    assert!(e.contains("retry budget") && e.contains("--resume"), "{e}");
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.command, "multigpu");
    assert_eq!((ck.cursor.epoch, ck.cursor.step), (1, 1), "mid-run round cursor");

    // Resume continues the lockstep trace bit for bit.
    let mut resumed = mg_cfg(13, 2, true, "tango");
    resumed.train.ckpt.every = 4;
    resumed.train.ckpt.path = path.clone();
    resumed.train.ckpt.resume = Some(path.clone());
    let r = run_data_parallel(&resumed, &data).unwrap();
    assert_eq!(r.final_params, control.final_params);
    assert_eq!(losses(&r), losses(&control));
    // The resumed run's run-complete checkpoint is the control's, bit for
    // bit — the same file the CI crash-resume job byte-compares.
    assert_eq!(Checkpoint::load(&path).unwrap(), Checkpoint::load(&ctrl_path).unwrap());
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&ctrl_path).ok();
}

#[test]
fn multigpu_one_worker_resume_still_replays_the_minibatch_trainer() {
    // The 1-worker FP32 replay guarantee must survive a crash/resume: the
    // resumed data-parallel run equals the uninterrupted one bitwise and
    // still tracks the single-GPU MiniBatchTrainer step for step.
    let path = tmp("tango_fault_mg_one_worker.json");
    std::fs::remove_file(&path).ok();
    let data = datasets::tiny(17);
    let control = run_data_parallel(&mg_cfg(17, 1, false, "fp32"), &data).unwrap();

    let mut crashed = mg_cfg(17, 1, false, "fp32");
    crashed.train.ckpt.every = 3;
    crashed.train.ckpt.path = path.clone();
    crashed.train.fault.inject = true;
    crashed.train.fault.worker_steps = vec![4];
    crashed.train.fault.max_retries = 0;
    run_data_parallel(&crashed, &data).unwrap_err();
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!((ck.cursor.epoch, ck.cursor.step), (0, 3));

    let mut resumed = mg_cfg(17, 1, false, "fp32");
    resumed.train.ckpt.resume = Some(path.clone());
    let r = run_data_parallel(&resumed, &data).unwrap();
    assert_eq!(r.final_params, control.final_params);
    assert_eq!(losses(&r), losses(&control));

    let mut mb = MiniBatchTrainer::from_config(&mg_cfg(17, 1, false, "fp32").train).unwrap();
    let single = mb.run().unwrap();
    assert_eq!(r.epochs.len(), single.losses.len());
    for (e, (ms, loss)) in r.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: resumed multigpu {} vs minibatch {}",
            ms.loss,
            loss
        );
    }
    std::fs::remove_file(&path).ok();
}

// --------------------------------------------------- worker/link/lock faults

#[test]
fn worker_failure_rebuilds_from_a_peer_in_lockstep() {
    let data = datasets::tiny(19);
    let control = run_data_parallel(&mg_cfg(19, 2, false, "fp32"), &data).unwrap();

    let mut faulted = mg_cfg(19, 2, false, "fp32");
    faulted.train.fault.inject = true;
    faulted.train.fault.worker_steps = vec![2];
    let r = run_data_parallel(&faulted, &data).unwrap();

    // The rebuild copies the peer's (identical, post-broadcast) params, so
    // the recovered run is the control, bit for bit.
    assert_eq!(r.final_params, control.final_params);
    assert_eq!(losses(&r), losses(&control));
    let f = r.fault.expect("injected run reports its fault ledger");
    assert_eq!(f.worker_failures, 1);
    assert_eq!(f.worker_rebuilds, 1);
    assert!(f.backoff_s > 0.0);
}

#[test]
fn link_drop_within_budget_retries_and_recharges_the_interconnect() {
    let data = datasets::tiny(23);
    let control = run_data_parallel(&mg_cfg(23, 2, true, "tango"), &data).unwrap();

    let mut faulted = mg_cfg(23, 2, true, "tango");
    faulted.train.fault.inject = true;
    faulted.train.fault.link_steps = vec![2];
    let r = run_data_parallel(&faulted, &data).unwrap();

    assert_eq!(r.final_params, control.final_params, "a retried ring pass is lossless");
    assert_eq!(losses(&r), losses(&control));
    let f = r.fault.expect("injected run reports its fault ledger");
    assert_eq!(f.link_drops, 1);
    assert_eq!(f.link_retries, 1);
    assert_eq!(f.allreduce_degraded, 0);
    assert!(f.backoff_s > 0.0);
    // The retry re-charges a full ring pass through the modelled link.
    let comm = |r: &MultiGpuReport| r.epochs.iter().map(|e| e.comm_s).sum::<f64>();
    assert!(comm(&r) > comm(&control), "{} vs {}", comm(&r), comm(&control));
}

#[test]
fn link_budget_exhaustion_degrades_to_skip_straggler_but_completes() {
    let data = datasets::tiny(29);
    let mut faulted = mg_cfg(29, 2, true, "tango");
    faulted.train.fault.inject = true;
    // Three drops at one round: two retries, then the budget is gone and
    // the round degrades instead of dying.
    faulted.train.fault.link_steps = vec![2, 2, 2];
    let r = run_data_parallel(&faulted, &data).unwrap();
    let f = r.fault.expect("injected run reports its fault ledger");
    assert_eq!(f.link_drops, 3);
    assert_eq!(f.link_retries, 2);
    assert_eq!(f.allreduce_degraded, 1);
    assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
    assert_eq!(r.epochs.len(), 3, "a degraded run still completes");
}

#[test]
fn lock_poison_recovers_on_both_the_real_store_and_the_scratch_path() {
    let data = datasets::tiny(31);
    // Quantized run: the real shared feature-store mutex is poisoned and
    // recovered; the numerics never see it.
    let control = run_data_parallel(&mg_cfg(31, 2, true, "tango"), &data).unwrap();
    let mut faulted = mg_cfg(31, 2, true, "tango");
    faulted.train.fault.inject = true;
    faulted.train.fault.lock_steps = vec![1];
    let r = run_data_parallel(&faulted, &data).unwrap();
    assert_eq!(r.final_params, control.final_params);
    assert_eq!(losses(&r), losses(&control));
    let f = r.fault.expect("injected run reports its fault ledger");
    assert_eq!(f.lock_poisons, 1);
    assert_eq!(f.lock_recoveries, 1);

    // FP32 run: no shared store — the identical recovery path runs on a
    // scratch mutex so the fault class stays testable in every mode.
    let mut fp = mg_cfg(31, 2, false, "fp32");
    fp.train.fault.inject = true;
    fp.train.fault.lock_steps = vec![1];
    let r = run_data_parallel(&fp, &data).unwrap();
    let f = r.fault.expect("injected run reports its fault ledger");
    assert_eq!((f.lock_poisons, f.lock_recoveries), (1, 1));
}

// -------------------------------------------------------- artifact wiring

#[test]
fn fault_ledger_lands_in_the_metrics_artifact() {
    let mut cfg = train_cfg(37);
    cfg.fault.inject = true;
    cfg.fault.producer_steps = vec![3];
    let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
    let report = t.run().unwrap();
    let artifact = tango::obs::train_artifact(&cfg, &report, &tango::obs::snapshot());
    let fault = artifact.get("fault").expect("fault section present");
    assert_eq!(fault.get("producer_panics").and_then(Json::as_f64), Some(1.0));
    assert_eq!(fault.get("producer_restarts").and_then(Json::as_f64), Some(1.0));
    assert_eq!(fault.get("worker_failures").and_then(Json::as_f64), Some(0.0));
    assert!(fault.get("backoff_s").and_then(Json::as_f64).unwrap() > 0.0);
}
