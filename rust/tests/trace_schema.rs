//! Schema and semantics of the `tango-trace/v1` event timeline (PR 10
//! tentpole).
//!
//! Five guarantees, each load-bearing for anything that consumes the
//! Chrome trace artifact:
//!
//! 1. **golden key paths** — every event carries exactly the keys its
//!    phase promises (`B`/`E`: name/ph/pid/tid/ts; `C` adds `args.value`;
//!    `i` adds `s: "t"`), so Perfetto and the CI gate can parse blindly;
//! 2. **per-thread sanity** — within one tid, timestamps never run
//!    backwards and `B`/`E` events nest like a well-formed stack;
//! 3. **governed names** — every event name resolves in `obs::keys`
//!    (audit rule O1, extended to `instant` this PR);
//! 4. **the overlap the trace exists to show** — a prefetch-2 sampled run
//!    records a producer-thread `stage1` interval that overlaps a
//!    consumer-thread `compute` interval in wall time;
//! 5. **flight recorder** — every PR 9 fault-injection class leaves a
//!    `kind: "flight"` dump whose final events name the recovery path.
//!
//! Trace state (the enable flag, the rings, the flight-recorder arming) is
//! process-global, so every test serializes on one lock and restores the
//! disabled default before releasing it.

use std::collections::BTreeMap;
use std::sync::Mutex;
use tango::config::{parse_mode, ModelKind, TrainConfig};
use tango::graph::datasets;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::obs::{self, keys};
use tango::sampler::MiniBatchTrainer;
use tango::util::json::Json;

static LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the event timeline on and a clean slate; restore the
/// disabled default (and disarm the flight recorder) afterwards.
fn with_trace<T>(f: impl FnOnce() -> T) -> T {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_trace_enabled(true);
    obs::reset();
    let out = f();
    obs::set_trace_enabled(false);
    obs::set_flight_recorder(None, 0);
    obs::reset();
    out
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("{name}_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn train_cfg(seed: u64) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs: 3,
        lr: 0.1,
        hidden: 8,
        heads: 2,
        layers: 2,
        mode: parse_mode("tango", 8).unwrap(),
        auto_bits: false,
        seed,
        log_every: 0,
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![4, 4];
    cfg.sampler.batch_size = 32; // tiny: 160 train nodes -> 5 batches/epoch
    cfg.sampler.prefetch = 2;
    cfg
}

fn mg_cfg(seed: u64, workers: usize, quantize: bool, mode: &str) -> MultiGpuConfig {
    let mut train = train_cfg(seed);
    train.mode = parse_mode(mode, 8).unwrap();
    MultiGpuConfig {
        train,
        workers,
        epochs: 3,
        quantize_grads: quantize,
        interconnect: Interconnect::pcie3(),
    }
}

fn events(doc: &Json) -> Vec<Json> {
    doc.get("traceEvents").and_then(Json::as_arr).map(|a| a.to_vec()).unwrap_or_default()
}

/// One traced sampled training run, exported as the train trace document.
fn traced_train_doc() -> Json {
    let mut t = MiniBatchTrainer::from_config(&train_cfg(7)).unwrap();
    t.run().unwrap();
    obs::export_trace("train")
}

// -------------------------------------------------------- 1: golden schema

#[test]
fn export_matches_the_golden_key_schema() {
    with_trace(|| {
        let doc = traced_train_doc();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(obs::TRACE_SCHEMA));
        assert_eq!(doc.get("command").and_then(Json::as_str), Some("train"));
        let evs = events(&doc);
        assert!(!evs.is_empty(), "a traced run must record events");
        for e in &evs {
            let Json::Obj(m) = e else { panic!("event is not an object: {e:?}") };
            let event_keys: Vec<&str> = m.keys().map(|s| s.as_str()).collect();
            match e.get("ph").and_then(Json::as_str) {
                Some("B") | Some("E") => {
                    assert_eq!(event_keys, ["name", "ph", "pid", "tid", "ts"], "{e:?}");
                }
                Some("C") => {
                    assert_eq!(event_keys, ["args", "name", "ph", "pid", "tid", "ts"], "{e:?}");
                    let Some(Json::Obj(args)) = e.get("args") else {
                        panic!("C event args must be an object: {e:?}")
                    };
                    let arg_keys: Vec<&str> = args.keys().map(|s| s.as_str()).collect();
                    assert_eq!(arg_keys, ["value"], "{e:?}");
                    assert!(args["value"].as_f64().is_some(), "{e:?}");
                }
                Some("i") => {
                    assert_eq!(event_keys, ["name", "ph", "pid", "s", "tid", "ts"], "{e:?}");
                    assert_eq!(e.get("s").and_then(Json::as_str), Some("t"), "{e:?}");
                }
                other => panic!("unexpected phase {other:?} in {e:?}"),
            }
            assert!(e.get("ts").and_then(Json::as_f64).is_some_and(|t| t >= 0.0), "{e:?}");
        }
        // The document round-trips through the repo's own parser.
        assert!(Json::parse(&doc.to_string()).is_ok());
    });
}

// ---------------------------------------------- 2: per-thread lane sanity

#[test]
fn per_thread_timelines_nest_and_run_forward() {
    with_trace(|| {
        let evs = events(&traced_train_doc());
        let mut by_tid: BTreeMap<i64, Vec<&Json>> = BTreeMap::new();
        for e in &evs {
            let tid = e.get("tid").and_then(Json::as_f64).expect("tid") as i64;
            by_tid.entry(tid).or_default().push(e);
        }
        assert!(by_tid.len() >= 2, "prefetch must run on its own thread: {:?}", by_tid.keys());
        for (tid, lane) in &by_tid {
            let mut prev = f64::NEG_INFINITY;
            let mut stack: Vec<&str> = Vec::new();
            for e in lane {
                let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
                assert!(ts >= prev, "tid {tid}: timestamps run backwards ({ts} after {prev})");
                prev = ts;
                let name = e.get("name").and_then(Json::as_str).expect("name");
                match e.get("ph").and_then(Json::as_str).expect("ph") {
                    "B" => stack.push(name),
                    "E" => {
                        assert_eq!(stack.pop(), Some(name), "tid {tid}: unbalanced E for {name}")
                    }
                    _ => {}
                }
            }
            assert!(stack.is_empty(), "tid {tid}: spans left open: {stack:?}");
        }
    });
}

// -------------------------------------------------- 3: names are governed

#[test]
fn event_names_resolve_in_the_key_registry() {
    with_trace(|| {
        let evs = events(&traced_train_doc());
        for e in &evs {
            let name = e.get("name").and_then(Json::as_str).expect("name");
            let known = keys::ALL_STATIC_KEYS.contains(&name)
                || name.starts_with("gather.error_x.bucket");
            assert!(known, "trace event name {name} does not resolve in obs::keys");
        }
    });
}

// ---------------------------------------- 4: prefetch/compute overlap proof

#[test]
fn producer_stage1_overlaps_consumer_compute() {
    with_trace(|| {
        let evs = events(&traced_train_doc());
        // Reconstruct closed intervals per tid from the B/E stream.
        let mut stacks: BTreeMap<i64, Vec<(String, f64)>> = BTreeMap::new();
        let mut intervals: Vec<(String, i64, f64, f64)> = Vec::new();
        for e in &evs {
            let tid = e.get("tid").and_then(Json::as_f64).expect("tid") as i64;
            let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
            let name = e.get("name").and_then(Json::as_str).expect("name");
            match e.get("ph").and_then(Json::as_str).expect("ph") {
                "B" => stacks.entry(tid).or_default().push((name.to_string(), ts)),
                "E" => {
                    if let Some((open, start)) = stacks.entry(tid).or_default().pop() {
                        intervals.push((open, tid, start, ts));
                    }
                }
                _ => {}
            }
        }
        let stage1: Vec<_> = intervals.iter().filter(|i| i.0 == keys::SPAN_STAGE1).collect();
        let compute: Vec<_> = intervals.iter().filter(|i| i.0 == keys::SPAN_COMPUTE).collect();
        assert!(!stage1.is_empty(), "producer stage1 spans missing from the trace");
        assert!(!compute.is_empty(), "consumer compute spans missing from the trace");
        // The claim the timeline exists to prove: some producer-thread
        // stage1 interval overlaps some compute interval on another thread.
        let overlap = stage1
            .iter()
            .any(|s| compute.iter().any(|c| c.1 != s.1 && s.2 < c.3 && c.2 < s.3));
        assert!(
            overlap,
            "no producer stage1 interval overlaps a consumer compute interval \
             ({} stage1, {} compute)",
            stage1.len(),
            compute.len()
        );
    });
}

// ------------------------------------------- 5: flight recorder, per class

fn read_dump(path: &str) -> Json {
    Json::parse(&std::fs::read_to_string(path).expect("flight dump written")).expect("dump parses")
}

/// Shared flight-dump schema assertions: `tango-trace/v1`, `kind: flight`,
/// `reason` naming the recovery, and the timeline containing the matching
/// instant mark (the recovery path emits it right before dumping).
fn assert_dump(doc: &Json, reason: &str) {
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(obs::TRACE_SCHEMA));
    assert_eq!(doc.get("kind").and_then(Json::as_str), Some("flight"));
    assert_eq!(doc.get("reason").and_then(Json::as_str), Some(reason));
    let evs = events(doc);
    assert!(
        evs.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("i")
            && e.get("name").and_then(Json::as_str) == Some(reason)),
        "dump must carry the {reason} instant mark"
    );
}

#[test]
fn producer_restart_leaves_a_flight_dump() {
    let path = tmp("tango_flight_producer");
    let _ = std::fs::remove_file(&path);
    with_trace(|| {
        obs::set_flight_recorder(Some(&path), 256);
        let mut cfg = train_cfg(7);
        cfg.fault.inject = true;
        cfg.fault.producer_steps = vec![3];
        let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
        let report = t.run().unwrap();
        obs::set_flight_recorder(None, 0);
        let f = report.fault.clone().expect("injected run reports its fault ledger");
        assert_eq!(f.producer_restarts, 1);
        assert_eq!(f.flight_dumps, 1);
        assert_dump(&read_dump(&path), keys::EVT_RECOVERY_PRODUCER_RESTART);
        // The dump count also lands in the metrics artifact's fault section.
        let artifact = obs::train_artifact(&cfg, &report, &obs::snapshot());
        assert_eq!(
            artifact.get("fault").and_then(|f| f.get("flight_dumps")).and_then(Json::as_f64),
            Some(1.0)
        );
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn worker_rebuild_leaves_a_flight_dump() {
    let path = tmp("tango_flight_worker");
    let _ = std::fs::remove_file(&path);
    with_trace(|| {
        obs::set_flight_recorder(Some(&path), 256);
        let data = datasets::tiny(19);
        let mut cfg = mg_cfg(19, 2, false, "fp32");
        cfg.train.fault.inject = true;
        cfg.train.fault.worker_steps = vec![2];
        let r = run_data_parallel(&cfg, &data).unwrap();
        obs::set_flight_recorder(None, 0);
        let f = r.fault.expect("injected run reports its fault ledger");
        assert_eq!(f.worker_rebuilds, 1);
        assert_eq!(f.flight_dumps, 1);
        assert_dump(&read_dump(&path), keys::EVT_RECOVERY_WORKER_REBUILD);
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn link_retry_leaves_a_flight_dump() {
    let path = tmp("tango_flight_link");
    let _ = std::fs::remove_file(&path);
    with_trace(|| {
        obs::set_flight_recorder(Some(&path), 256);
        let data = datasets::tiny(23);
        let mut cfg = mg_cfg(23, 2, true, "tango");
        cfg.train.fault.inject = true;
        cfg.train.fault.link_steps = vec![2];
        let r = run_data_parallel(&cfg, &data).unwrap();
        obs::set_flight_recorder(None, 0);
        let f = r.fault.expect("injected run reports its fault ledger");
        assert_eq!(f.link_retries, 1);
        assert_eq!(f.flight_dumps, 1);
        assert_dump(&read_dump(&path), keys::EVT_RECOVERY_LINK_RETRY);
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn allreduce_degrade_leaves_a_flight_dump() {
    let path = tmp("tango_flight_degrade");
    let _ = std::fs::remove_file(&path);
    with_trace(|| {
        obs::set_flight_recorder(Some(&path), 256);
        let data = datasets::tiny(29);
        let mut cfg = mg_cfg(29, 2, true, "tango");
        cfg.train.fault.inject = true;
        // Two retries burn the budget, then the round degrades; the dump on
        // disk is the last one written — the degrade post-mortem.
        cfg.train.fault.link_steps = vec![2, 2, 2];
        let r = run_data_parallel(&cfg, &data).unwrap();
        obs::set_flight_recorder(None, 0);
        let f = r.fault.expect("injected run reports its fault ledger");
        assert_eq!(f.allreduce_degraded, 1);
        assert_eq!(f.flight_dumps, 3, "two retry dumps + one degrade dump");
        assert_dump(&read_dump(&path), keys::EVT_RECOVERY_ALLREDUCE_DEGRADE);
    });
    let _ = std::fs::remove_file(&path);
}

#[test]
fn lock_recovery_leaves_a_flight_dump() {
    let path = tmp("tango_flight_lock");
    let _ = std::fs::remove_file(&path);
    with_trace(|| {
        obs::set_flight_recorder(Some(&path), 256);
        let data = datasets::tiny(31);
        let mut cfg = mg_cfg(31, 2, true, "tango");
        cfg.train.fault.inject = true;
        cfg.train.fault.lock_steps = vec![1];
        let r = run_data_parallel(&cfg, &data).unwrap();
        obs::set_flight_recorder(None, 0);
        let f = r.fault.expect("injected run reports its fault ledger");
        assert_eq!(f.lock_recoveries, 1);
        assert_eq!(f.flight_dumps, 1);
        assert_dump(&read_dump(&path), keys::EVT_RECOVERY_LOCK);
    });
    let _ = std::fs::remove_file(&path);
}
