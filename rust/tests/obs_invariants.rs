//! Observability invariants (PR 6 tentpole guarantees):
//!
//! 1. hierarchical spans record full `/`-joined paths and are thread-safe;
//! 2. histogram percentiles are ordered (`p50 <= p95 <= p99 <= max`) and
//!    monotone in `q` under fuzzed inputs;
//! 3. metrics merging is associative (counters, histograms, spans);
//! 4. **bit-identity**: a traced run produces exactly the same losses as an
//!    untraced one — the instrumentation only reads training values.
//!
//! The enabled flag and the registry are process-global, so every test that
//! toggles or reads them serializes on one lock (`with_tracing`) and always
//! restores tracing to on.

use std::sync::Mutex;
use tango::config::{ModelKind, SamplerConfig, TrainConfig};
use tango::obs::{self, Histogram, Metrics};
use tango::quant::rng::Xoshiro256pp;
use tango::sampler::MiniBatchTrainer;
use tango::util::json::Json;

/// Serializes every test that touches the process-global enabled flag or
/// expects exclusive use of the global registry.
static TRACE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with tracing forced to `on`, restoring tracing afterwards.
fn with_tracing<T>(on: bool, f: impl FnOnce() -> T) -> T {
    let _guard = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    obs::set_enabled(on);
    let out = f();
    obs::set_enabled(true);
    out
}

fn sampled_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs,
        hidden: 16,
        seed: 11,
        sampler: SamplerConfig {
            enabled: true,
            fanouts: vec![6, 6],
            batch_size: 48,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn spans_nest_into_full_paths() {
    with_tracing(true, || {
        obs::reset();
        {
            let _outer = obs::span("inv.outer");
            {
                let _inner = obs::span("inv.inner");
            }
            {
                let _other = obs::span("inv.other");
            }
        }
        let snap = obs::snapshot();
        for path in ["inv.outer", "inv.outer/inv.inner", "inv.outer/inv.other"] {
            let sp = snap.spans.get(path).unwrap_or_else(|| panic!("missing span {path}"));
            assert_eq!(sp.calls, 1, "{path}");
            assert!(sp.total_s >= 0.0);
        }
        // Sibling paths never concatenate: no "inv.inner/inv.other".
        assert!(!snap.spans.contains_key("inv.outer/inv.inner/inv.other"));
    });
}

#[test]
fn spans_and_counters_are_thread_safe() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 200;
    with_tracing(true, || {
        obs::reset();
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                scope.spawn(|| {
                    for _ in 0..PER_THREAD {
                        let _s = obs::span("inv.mt");
                        obs::counter_add("inv.mt.counter", 1);
                        obs::observe("inv.mt.hist", 1e-6);
                    }
                });
            }
        });
        let snap = obs::snapshot();
        let n = THREADS as u64 * PER_THREAD;
        assert_eq!(snap.counters.get("inv.mt.counter"), Some(&n));
        assert_eq!(snap.hists.get("inv.mt.hist").unwrap().count(), n);
        // Every thread's spans are roots of their own thread path, so they
        // all aggregate under the bare name.
        assert_eq!(snap.spans.get("inv.mt").unwrap().calls, n);
    });
}

#[test]
fn percentiles_are_ordered_and_monotone_under_fuzzing() {
    let mut rng = Xoshiro256pp::new(0xB0B);
    for case in 0..50 {
        let mut h = Histogram::default();
        let n = 1 + (rng.next_u64() % 400) as usize;
        for _ in 0..n {
            // Mix magnitudes from ns to minutes (and some junk values the
            // histogram must clamp).
            let exp = (rng.next_u64() % 12) as i32 - 9;
            let v = rng.next_f32() as f64 * 10f64.powi(exp);
            h.record(if case % 7 == 0 { -v } else { v });
        }
        let p50 = h.percentile(0.50);
        let p95 = h.percentile(0.95);
        let p99 = h.percentile(0.99);
        assert!(p50 <= p95, "case {case}: p50 {p50} > p95 {p95}");
        assert!(p95 <= p99, "case {case}: p95 {p95} > p99 {p99}");
        assert!(p99 <= h.max(), "case {case}: p99 {p99} > max {}", h.max());
        assert!(h.min() <= p50, "case {case}: min {} > p50 {p50}", h.min());
        let mut prev = 0.0;
        for i in 0..=20 {
            let v = h.percentile(i as f64 / 20.0);
            assert!(v >= prev, "case {case}: quantile not monotone at q={}", i as f64 / 20.0);
            prev = v;
        }
    }
}

#[test]
fn metrics_merge_is_associative() {
    let mut rng = Xoshiro256pp::new(77);
    // Durations on a dyadic grid (multiples of 2^-10, bounded): their f64
    // sums are exact, so merge associativity is exact equality rather than
    // up-to-rounding. Keys overlap across the three metrics (`c0..c2`,
    // `h0/h1`, `s0/s1`) so every merge exercises real folding.
    let mut make = || {
        let mut dur = |rng: &mut Xoshiro256pp| (rng.next_u64() % 4096) as f64 / 1024.0;
        let mut m = Metrics::default();
        for i in 0..4 {
            *m.counters.entry(format!("c{}", i % 3)).or_insert(0) += 1 + rng.next_u64() % 100;
            let mut h = Histogram::default();
            for _ in 0..4 {
                h.record(dur(&mut rng));
            }
            m.hists.entry(format!("h{}", i % 2)).or_default().merge(&h);
            let sp = m.spans.entry(format!("s{}", i % 2)).or_default();
            sp.calls += 1;
            sp.total_s += dur(&mut rng);
            sp.hist.record(dur(&mut rng));
        }
        m
    };
    let (a, b, c) = (make(), make(), make());
    // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a.clone();
    right.merge(&bc);
    assert_eq!(left, right);
    // Merging an empty registry is the identity.
    let mut with_empty = left.clone();
    with_empty.merge(&Metrics::default());
    assert_eq!(with_empty, left);
}

#[test]
fn traced_runs_are_bit_identical_to_untraced() {
    let run = |trace: bool| -> (Vec<f32>, Vec<f32>) {
        with_tracing(trace, || {
            obs::reset();
            let mut t = MiniBatchTrainer::from_config(&sampled_cfg(4)).unwrap();
            let r = t.run().unwrap();
            (r.losses, r.evals)
        })
    };
    let (traced_losses, traced_evals) = run(true);
    let (plain_losses, plain_evals) = run(false);
    assert_eq!(traced_losses, plain_losses, "tracing must not perturb training");
    assert_eq!(traced_evals, plain_evals, "tracing must not perturb evaluation");
}

#[test]
fn disabled_tracing_records_nothing() {
    with_tracing(false, || {
        obs::set_trace_enabled(false);
        obs::reset();
        {
            let _s = obs::span("inv.off.span");
            let _t = obs::timed("inv.off.timed");
            obs::counter_add("inv.off.counter", 1);
            obs::gauge_set("inv.off.gauge", 1.0);
            obs::observe("inv.off.hist", 1.0);
            obs::instant("inv.off.instant");
        }
        assert!(obs::snapshot().is_empty(), "off must mean off");
        // The event timeline is off by default too: no trace events either.
        let trace = obs::export_trace("test");
        let events = trace.get("traceEvents").and_then(Json::as_arr).map(|a| a.len());
        assert_eq!(events, Some(0), "disabled tracing must leave the timeline empty");
    });
}

#[test]
fn back_to_back_traced_runs_have_independent_timelines() {
    // The (ph, name) multiset of a traced run is deterministic (training is
    // seeded, so the same spans/counters fire the same number of times), and
    // `obs::reset()` must restart the trace clock — so run 2's earliest
    // timestamp lands before run 1's latest, not after it.
    fn events(doc: &Json) -> Vec<Json> {
        doc.get("traceEvents").and_then(Json::as_arr).map(|a| a.to_vec()).unwrap_or_default()
    }
    fn signature(doc: &Json) -> Vec<(String, String)> {
        let mut sig: Vec<(String, String)> = events(doc)
            .iter()
            .map(|e| {
                (
                    e.get("ph").and_then(Json::as_str).unwrap_or("").to_string(),
                    e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                )
            })
            .collect();
        sig.sort();
        sig
    }
    fn ts_bounds(doc: &Json) -> (f64, f64) {
        let ts: Vec<f64> =
            events(doc).iter().filter_map(|e| e.get("ts").and_then(Json::as_f64)).collect();
        let lo = ts.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ts.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    }
    with_tracing(true, || {
        obs::set_trace_enabled(true);
        let run = || {
            obs::reset();
            let mut t = MiniBatchTrainer::from_config(&sampled_cfg(2)).unwrap();
            t.run().unwrap();
            obs::export_trace("test")
        };
        let a = run();
        let b = run();
        obs::set_trace_enabled(false);
        obs::reset();
        assert!(!events(&a).is_empty(), "a traced run must record events");
        assert_eq!(
            signature(&a),
            signature(&b),
            "two identical traced runs must produce the same event multiset"
        );
        let (_, a_max) = ts_bounds(&a);
        let (b_min, _) = ts_bounds(&b);
        assert!(
            b_min < a_max,
            "reset must restart the trace clock: run 2 begins at {b_min}us, \
             run 1 ended at {a_max}us"
        );
    });
}

#[test]
fn traced_sampled_run_populates_the_expected_surface() {
    with_tracing(true, || {
        obs::reset();
        let mut t = MiniBatchTrainer::from_config(&sampled_cfg(2)).unwrap();
        t.run().unwrap();
        let snap = obs::snapshot();
        for span in ["epoch", "epoch/eval", "stage1", "stage1/sample", "stage1/gather"] {
            assert!(snap.spans.contains_key(span), "missing span {span}: {:?}", snap.spans.keys());
        }
        for counter in
            ["pipeline.batches_prepared", "gather.rows", "gather.cache_hits", "gather.cache_misses"]
        {
            assert!(
                snap.counters.contains_key(counter),
                "missing counter {counter}: {:?}",
                snap.counters.keys()
            );
        }
        assert!(
            snap.gauges.keys().any(|k| k.starts_with("gather.error_x.bucket")),
            "per-bucket Error_X gauges: {:?}",
            snap.gauges.keys()
        );
        assert!(
            snap.hists.contains_key("sampler.sample_blocks"),
            "{:?}",
            snap.hists.keys()
        );
    });
}
