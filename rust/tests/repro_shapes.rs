//! Shape assertions over the paper-figure reproductions: who wins, by
//! roughly what factor, where crossovers fall — the acceptance criteria of
//! DESIGN.md §5 (absolute numbers are substrate-dependent and not asserted).

use tango::graph::datasets;
use tango::graph::generators::random_features;
use tango::graph::{Csr, Incidence};
use tango::metrics::{bench_with_config, BenchConfig};
use tango::perfmodel::{gemm_time, sddmm_time, GemmKind, SparseDtype, A100, V100};
use tango::primitives::{
    gemm_f32, incidence_spmm, qgemm, qgemm_prequantized, qsddmm_dot, sddmm_dot,
    spmm_edge_aggregate_3mat,
};
use tango::quant::{quantize, Rounding};

fn bc() -> BenchConfig {
    BenchConfig { warmup_secs: 0.05, measure_secs: 0.25, min_samples: 3 }
}

#[test]
fn fig10_shape_caching_wins() {
    // Caching quantized tensors must speed up the GEMM (paper: 1.6–1.7×).
    let a = random_features(8192, 128, 1);
    let b = random_features(128, 128, 2);
    let fresh = bench_with_config("fresh", bc(), &mut || qgemm(&a, &b, 8, Rounding::Nearest));
    let qa = quantize(&a, 8, Rounding::Nearest);
    let qb = quantize(&b, 8, Rounding::Nearest);
    let cached = bench_with_config("cached", bc(), &mut || qgemm_prequantized(&qa, &qb, 8));
    let speedup = fresh.mean / cached.mean;
    assert!(speedup > 1.1, "caching speedup only {speedup:.2}x");
}

#[test]
fn fig11_shape_qgemm_beats_fp32_on_cpu() {
    // The measured CPU analogue of Fig. 11a: INT8 GEMM (including its
    // quantization cost) beats the FP32 GEMM at the paper's shapes.
    let m = 8192;
    for &d in &[256usize] {
        let a = random_features(m, d, 3);
        let w = random_features(d, d, 4);
        let f = bench_with_config("f32", bc(), &mut || gemm_f32(&a, &w));
        let q = bench_with_config("q8", bc(), &mut || qgemm(&a, &w, 8, Rounding::Nearest));
        let s = f.mean / q.mean;
        assert!(s > 1.0, "D={d}: qgemm slower than fp32 ({s:.2}x)");
    }
}

#[test]
fn fig11_shape_model_bands() {
    // V100 DP4A band ~2.2–2.5×, A100 INT8-vs-FP16 band ~1.8–1.9×.
    let m = 169_343;
    let v = gemm_time(&V100, m, 256, 256, GemmKind::Fp32Cuda, false)
        / gemm_time(&V100, m, 256, 256, GemmKind::Int8Dp4a, false);
    assert!(v > 1.8 && v < 3.2, "V100 model speedup {v:.2}");
    let a = gemm_time(&A100, m, 512, 512, GemmKind::Fp16Tensor, false)
        / gemm_time(&A100, m, 512, 512, GemmKind::Int8Tensor, false);
    assert!(a > 1.5 && a < 2.0, "A100 model speedup {a:.2}");
}

#[test]
fn fig13_table2_shape_incidence_wins_everywhere() {
    // Incidence SPMM beats the 3-matrix kernel on every dataset (paper avg
    // 2.1×; we only demand a strict win).
    for name in ["ogbn-arxiv", "Pubmed", "DBLP"] {
        let data = datasets::load_by_name(name, 1);
        let csr = Csr::from_coo(&data.graph);
        let inc = Incidence::from_csr(&csr);
        let ef = random_features(csr.num_edges, 16, 5);
        let base = bench_with_config("3mat", bc(), &mut || spmm_edge_aggregate_3mat(&csr, &ef));
        let ours = bench_with_config("inc", bc(), &mut || incidence_spmm(&inc, &ef));
        let s = base.mean / ours.mean;
        assert!(s > 1.0, "{name}: incidence slower ({s:.2}x)");
    }
}

#[test]
fn fig15_shape_quantized_sddmm_dot_wins_at_width() {
    // Quantized SDDMM-dot touches 1/4 the random bytes; at the paper's
    // (4, 64) feature shape it must win on a large graph.
    let data = datasets::load_by_name("ogbn-products", 2);
    let n = data.graph.num_nodes;
    let (heads, d) = (4usize, 64usize);
    let a = random_features(n, heads * d, 6);
    let b = random_features(n, heads * d, 7);
    let qa = quantize(&a, 8, Rounding::Nearest);
    let qb = quantize(&b, 8, Rounding::Nearest);
    let f = bench_with_config("dotf", bc(), &mut || sddmm_dot(&data.graph, &a, &b, heads));
    let q = bench_with_config("dotq", bc(), &mut || qsddmm_dot(&data.graph, &qa, &qb, heads));
    let s = f.mean / q.mean;
    assert!(s > 1.0, "quantized SDDMM-dot slower ({s:.2}x)");
}

#[test]
fn fig16_shape_int4_marginal_over_int8() {
    // §4.4: "Using fewer bits shows marginal improvement".
    let m = 169_343;
    let t8 = gemm_time(&A100, m, 512, 512, GemmKind::Int8Tensor, false);
    let t4 = gemm_time(&A100, m, 512, 512, GemmKind::Int4Tensor, false);
    assert!(t4 < t8);
    assert!(t8 / t4 < 1.5, "INT4 gain {:.2}x should be marginal", t8 / t4);
    // Sparse side: INT4 beats INT8 on traffic, both beat FP32 at scale.
    let f32t = sddmm_time(&V100, 169_343, 1_166_243, 256, SparseDtype::F32);
    let i8t = sddmm_time(&V100, 169_343, 1_166_243, 256, SparseDtype::I8);
    let i4t = sddmm_time(&V100, 169_343, 1_166_243, 256, SparseDtype::I4);
    assert!(i4t <= i8t && i8t < f32t);
}

#[test]
fn fig2_shape_bit_rule_monotone() {
    // The Fig. 2 rule: a looser Error_X target never needs more bits.
    use tango::quant::derive_bits;
    let data = datasets::load_by_name("Pubmed", 3);
    let probe = {
        use tango::model::{GcnConfig, GcnModel, TrainMode};
        let m = GcnModel::new(
            GcnConfig { in_dim: data.features.cols(), hidden: 32, out_dim: data.num_classes, layers: 2, mode: TrainMode::fp32() },
            &data.graph,
            3,
        );
        m.first_layer_output(&data.features)
    };
    let tight = derive_bits(&probe, 0.1).bits;
    let mid = derive_bits(&probe, 0.3).bits;
    let loose = derive_bits(&probe, 0.7).bits;
    assert!(tight >= mid && mid >= loose, "{tight} {mid} {loose}");
}
