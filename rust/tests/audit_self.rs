//! Self-test for `tango-audit` (rust/src/audit/).
//!
//! Two halves:
//! 1. the full audit over this very tree must come back clean — zero
//!    findings after `audit.allow.toml`, zero stale allowlist entries —
//!    which is the same bar the CI `audit` job enforces;
//! 2. each rule must demonstrably *fire* on a small inline fixture with
//!    the right `file:line`, since the audit's own sources are excluded
//!    from the scan and would otherwise never prove the rules work.
//!
//! Cargo runs integration tests with the package root as the working
//! directory, so `.` is the repo root here.

use std::collections::BTreeSet;
use std::path::Path;
use tango::audit::{
    self, check_surface, extract_cli_flags, extract_mentions, extract_toml_keys, Allowlist, Rule,
};
use tango::util::json::Json;

// ---------------------------------------------------------------- clean tree

#[test]
fn repo_tree_is_clean_under_the_shipped_allowlist() {
    let allow_text = std::fs::read_to_string("audit.allow.toml").expect("audit.allow.toml at root");
    let allow = Allowlist::parse(&allow_text).expect("allowlist parses");
    let report = audit::run(Path::new("."), &allow).expect("audit runs");

    for f in &report.findings {
        eprintln!("{}\n    | {}", f.render(), f.snippet);
    }
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    assert!(report.findings.is_empty(), "{} unallowed finding(s)", report.findings.len());
    assert!(report.warnings.is_empty(), "{} stale allowlist entr(ies)", report.warnings.len());
    assert!(report.ok(true), "report must pass under --deny-warnings");

    // Sanity: the scan actually covered the tree, and the allowlist is
    // doing real work (every entry suppresses at least one finding).
    assert!(report.files_scanned > 30, "only {} files scanned", report.files_scanned);
    assert!(!report.suppressed.is_empty());

    // The machine-readable artifact round-trips through the repo's parser.
    let json = report.to_json();
    assert_eq!(json.get("schema").and_then(Json::as_str), Some(audit::SCHEMA));
    assert!(Json::parse(&json.to_string()).is_ok());
}

// ------------------------------------------------------------- D1: clocks

#[test]
fn d1_fires_on_clock_reads_outside_the_obs_layers() {
    let src = "fn f() {\n    let t = std::time::Instant::now();\n    t.elapsed()\n}\n";
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::D1);
    assert_eq!((f[0].path.as_str(), f[0].line), ("rust/src/fake.rs", 2));
    assert!(f[0].snippet.contains("Instant::now"));

    // The observability and metrics layers are the timing layers.
    assert!(audit::scan_source("rust/src/obs/fake.rs", src).is_empty());
    assert!(audit::scan_source("rust/src/metrics/fake.rs", src).is_empty());
}

// ---------------------------------------------------- D1: hash iteration

#[test]
fn d1_fires_on_hash_iteration() {
    let src = concat!(
        "fn f() {\n",
        "    let mut seen: std::collections::HashSet<u32> = Default::default();\n",
        "    for v in &seen {\n",
        "        let _ = v;\n",
        "    }\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::D1, 3));

    // Field declarations track too: iterating a HashMap-typed field fires.
    let src = concat!(
        "struct C {\n",
        "    entries: std::collections::HashMap<u64, u32>,\n",
        "}\n",
        "impl C {\n",
        "    fn total(&self) -> u32 {\n",
        "        self.entries.values().sum()\n",
        "    }\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::D1, 6));
}

#[test]
fn d1_sanctions_the_drain_and_sort_idiom() {
    // Re-binding the name to a non-hash value (collect + sort) untracks it
    // — this is exactly the fix `graph/generators.rs::power_law` ships.
    let src = concat!(
        "fn f() {\n",
        "    let mut chosen = std::collections::HashSet::new();\n",
        "    chosen.insert(1u32);\n",
        "    let mut chosen: Vec<u32> = chosen.into_iter().collect();\n",
        "    chosen.sort_unstable();\n",
        "    for t in &chosen {\n",
        "        let _ = t;\n",
        "    }\n",
        "}\n"
    );
    assert!(audit::scan_source("rust/src/fake.rs", src).is_empty());
}

// ------------------------------------------------------------ O1: obs keys

#[test]
fn o1_fires_on_inline_obs_keys_and_accepts_constants() {
    let src = concat!(
        "fn f() {\n",
        "    let _g = span(\"epoch\");\n",
        "    counter_add(crate::obs::keys::CTR_GATHER_ROWS, 1);\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::O1, 2));
    assert!(f[0].message.contains("obs::keys"));

    // format!-built keys are inline too (dynamic families get constructor
    // functions in obs::keys instead).
    let f = audit::scan_source("rust/src/fake.rs", "fn f() { timed(&format!(\"k{}\", 1)); }\n");
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::O1);

    // `instant` trace markers are governed like spans: their names become
    // Chrome trace events and must resolve in obs::keys.
    let src_instant = concat!(
        "fn f() {\n",
        "    crate::obs::instant(\"recovery.ad_hoc\");\n",
        "    crate::obs::instant(crate::obs::keys::EVT_RECOVERY_LOCK);\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src_instant);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].line), (Rule::O1, 2));
    assert!(f[0].message.contains("instant"));

    // Inside the obs layer itself the entry points handle raw strings.
    assert!(audit::scan_source("rust/src/obs/fake.rs", src).is_empty());
    assert!(audit::scan_source("rust/src/obs/fake.rs", src_instant).is_empty());
}

// ------------------------------------------------------------- P1: panics

#[test]
fn p1_fires_on_panic_paths_but_not_comments_or_tests() {
    let src = concat!(
        "//! Doc comments may say unwrap() freely.\n",
        "fn f(x: Option<u32>) -> u32 {\n",
        "    x.unwrap()\n",
        "}\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    fn g(x: Option<u32>) -> u32 { x.unwrap() }\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].path.as_str(), f[0].line), (Rule::P1, "rust/src/fake.rs", 3));

    let f = audit::scan_source("rust/src/fake.rs", "fn f() { panic!(\"boom\"); }\n");
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::P1);

    let f = audit::scan_source("rust/src/fake.rs", "fn f(x: Option<u32>) { x.expect(\"set\"); }\n");
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::P1);

    // Byte-argument `expect` helpers (json.rs-style parsers) are not the
    // panicking Option/Result API.
    assert!(audit::scan_source("rust/src/fake.rs", "fn f(p: &mut P) { p.expect(b'x'); }\n")
        .is_empty());
}

// ------------------------------------------------------ W1: atomic writes

#[test]
fn w1_fires_on_direct_file_writes_but_not_comments_or_tests() {
    let src = concat!(
        "//! Docs may mention fs::write( freely.\n",
        "fn f() -> std::io::Result<()> {\n",
        "    std::fs::write(\"out.json\", \"{}\")\n",
        "}\n",
        "#[cfg(test)]\n",
        "mod tests {\n",
        "    fn g() { std::fs::write(\"t.json\", \"{}\").unwrap(); }\n",
        "}\n"
    );
    let f = audit::scan_source("rust/src/fake.rs", src);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].rule, f[0].path.as_str(), f[0].line), (Rule::W1, "rust/src/fake.rs", 3));
    assert!(f[0].message.contains("write_atomic"));

    let f = audit::scan_source(
        "rust/src/fake.rs",
        "fn f() { let _h = std::fs::File::create(\"x.bin\"); }\n",
    );
    assert_eq!(f.len(), 1);
    assert_eq!(f[0].rule, Rule::W1);

    // Routing through the helper is the sanctioned shape.
    assert!(audit::scan_source(
        "rust/src/fake.rs",
        "fn f() -> tango::Result<()> { crate::util::fsio::write_atomic(\"out.json\", \"{}\") }\n"
    )
    .is_empty());
}

// ------------------------------------------------------- C1: config surface

#[test]
fn c1_cross_references_flags_keys_and_mentions() {
    let flags = extract_cli_flags(
        "rust/src/main.rs",
        "cfg.lr = flag(args, \"lr\", cfg.lr)?;\nlet quick = args.get_bool(\"quick\");\n",
    );
    let keys = extract_toml_keys(
        "rust/src/config/mod.rs",
        "let get = |k: &str| doc.get(\"train\", k);\nget(\"lr\")\n",
    );
    let mentions: BTreeSet<String> = extract_mentions("[train]\nlr = 0.05\n");

    // `lr` is symmetric across all three surfaces; `quick` is missing both
    // a TOML key and a config-file mention.
    let f = check_surface(&flags, &keys, &mentions);
    assert_eq!(f.len(), 2);
    assert!(f.iter().all(|x| x.rule == Rule::C1 && x.snippet == "--quick"));
    assert_eq!((f[0].path.as_str(), f[0].line), ("rust/src/main.rs", 2));

    // And the reverse direction: a key nobody can set from the CLI.
    let orphan = extract_toml_keys("rust/src/config/mod.rs", "get(\"ghost\")\n");
    let f = check_surface(&[], &orphan, &mentions);
    assert_eq!(f.len(), 2); // no flag + no mention
    assert!(f.iter().all(|x| x.snippet == "ghost"));
}

// ------------------------------------------------- allowlist gate behaviour

#[test]
fn allowlist_suppresses_matching_findings_and_reports_stale_entries() {
    let allow = Allowlist::parse(
        "[allow.fixture]\nrule = \"P1\"\npath = \"rust/src/fake.rs\"\n\
         contains = \"x.unwrap()\"\nreason = \"fixture\"\n\
         [allow.stale]\nrule = \"D1\"\npath = \"rust/src/nope.rs\"\nreason = \"old\"\n",
    )
    .unwrap();
    let findings = audit::scan_source("rust/src/fake.rs", "fn f(x: Option<u32>) { x.unwrap(); }\n");
    let (kept, suppressed, unused) = allow.apply(findings);
    assert!(kept.is_empty());
    assert_eq!(suppressed.len(), 1);
    assert_eq!(suppressed[0].0, "fixture");
    assert_eq!(unused, vec!["stale".to_string()]);
}
