//! Degree-aware mixed-precision policy: equivalence and end-to-end runs.
//!
//! The load-bearing guarantee: the **uniform** policy (one bucket at the
//! mode's width — the default when no policy knobs are set) is
//! bit-identical to pre-policy behaviour. The policy module derives the
//! single bucket's scale with the same fold `quant::scale_for_bits` uses
//! and quantizes rows through the same `quantize_slice_nearest`, so the
//! pinned traces here (and every pre-existing sampled/multi-GPU test)
//! survive the subsystem unchanged. On top of that: mixed policies train
//! end to end on both task heads and both engines, shrink gathered bytes
//! below uniform INT8, and stay bit-identical across prefetch depths.

use tango::config::{parse_mode, ModelKind, TaskKind, TrainConfig};
use tango::graph::datasets;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::sampler::MiniBatchTrainer;

fn cfg(mode: &str, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs,
        lr: 0.1,
        hidden: 16,
        heads: 2,
        layers: 2,
        mode: parse_mode(mode, 8).unwrap(),
        auto_bits: false,
        seed: 7,
        log_every: 0,
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![4, 4];
    cfg.sampler.batch_size = 32;
    cfg
}

fn mixed(mut cfg: TrainConfig) -> TrainConfig {
    // tiny's in-degrees centre around ~9, so boundaries [6, 12] populate
    // all three buckets.
    cfg.policy.degree_buckets = vec![6, 12];
    cfg.policy.bucket_bits = vec![8, 6, 4];
    cfg
}

fn traces(cfg: &TrainConfig) -> (Vec<f32>, Vec<f32>) {
    let r = MiniBatchTrainer::from_config(cfg).unwrap().run().unwrap();
    (r.losses, r.evals)
}

#[test]
fn explicit_single_bucket_policy_is_bit_identical_to_default() {
    // Spelling the uniform policy out (one bucket, 8 bits) must not change
    // a single loss or eval relative to the default (no policy knobs).
    let base = cfg("tango", 4);
    let mut explicit = base.clone();
    explicit.policy.bucket_bits = vec![8];
    assert_eq!(traces(&base), traces(&explicit));
}

#[test]
fn uniform_policy_report_shows_one_full_width_bucket() {
    let mut t = MiniBatchTrainer::from_config(&cfg("tango", 2)).unwrap();
    let r = t.run().unwrap();
    let policy = r.policy.expect("quantized run reports its policy");
    assert!(!policy.is_mixed());
    assert_eq!(policy.bits, vec![8]);
    assert_eq!(policy.boundaries, Vec::<u32>::new());
    // INT8 packs 1:1 — no compression claimed where none happens.
    assert_eq!(policy.packed_bytes(), policy.int8_bytes());
    assert!(policy.packed_bytes() > 0, "an epoch sweep gathers rows");
    // FP32 runs have no store, hence no policy report.
    let r = MiniBatchTrainer::from_config(&cfg("fp32", 2)).unwrap().run().unwrap();
    assert!(r.policy.is_none());
}

#[test]
fn mixed_policy_trains_nc_and_shrinks_gathered_bytes() {
    let mut t = MiniBatchTrainer::from_config(&mixed(cfg("tango", 12))).unwrap();
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()), "{:?}", r.losses);
    assert!(r.losses.last().unwrap() < &r.losses[0], "{:?}", r.losses);
    let policy = r.policy.expect("mixed run reports its policy");
    assert!(policy.is_mixed());
    assert_eq!(policy.bits, vec![8, 6, 4]);
    assert_eq!(policy.boundaries, vec![6, 12]);
    assert!(
        policy.packed_bytes() < policy.int8_bytes(),
        "sub-INT8 buckets must shrink gathered bytes: {} vs {}",
        policy.packed_bytes(),
        policy.int8_bytes()
    );
    // Per-bucket rows add up to the cache traffic.
    let rows: u64 = policy.buckets.iter().map(|b| b.rows).sum();
    let stats = r.cache.expect("quantized run has cache stats");
    assert_eq!(rows, stats.hits + stats.misses);
}

#[test]
fn mixed_policy_trains_linkpred_end_to_end() {
    let mut c = mixed(cfg("tango", 3));
    c.task = Some(TaskKind::LinkPrediction);
    let mut t = MiniBatchTrainer::from_config(&c).unwrap();
    assert_eq!(t.task(), datasets::Task::LinkPrediction);
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()), "{:?}", r.losses);
    assert!(r.final_eval > 0.0 && r.final_eval <= 1.0, "AUC {}", r.final_eval);
    assert!(r.policy.expect("mixed LP run reports its policy").is_mixed());
}

#[test]
fn mixed_policy_is_bit_identical_across_prefetch_depths() {
    // Per-bucket scales are static and batch streams are position-keyed,
    // so the §4.2 overlap guarantee survives mixed precision.
    let sequential = {
        let mut c = mixed(cfg("tango", 3));
        c.sampler.prefetch = 0;
        traces(&c)
    };
    let default_prefetch = traces(&mixed(cfg("tango", 3))); // prefetch = 2
    assert_eq!(default_prefetch, sequential, "default prefetch (2) vs sequential");
    for depth in [5usize, 8] {
        let mut c = mixed(cfg("tango", 3));
        c.sampler.prefetch = depth;
        assert_eq!(traces(&c), sequential, "depth {depth}");
    }
}

#[test]
fn degree_sampler_trains_and_is_deterministic() {
    let mut c = cfg("tango", 4);
    c.sampler.degree_biased = true;
    let a = traces(&c);
    let b = traces(&c);
    assert_eq!(a, b, "degree-biased runs replay under a fixed seed");
    assert!(a.0.iter().all(|l| l.is_finite()));
    // And it genuinely samples differently from the uniform sweep.
    let uniform = traces(&cfg("tango", 4));
    assert_ne!(a.0, uniform.0, "degree bias must change the sampled blocks");
}

#[test]
fn degree_sampler_with_mixed_policy_runs_multigpu() {
    let mut train = mixed(cfg("tango", 2));
    train.sampler.degree_biased = true;
    train.sampler.batch_size = 16;
    let mg = MultiGpuConfig {
        train,
        workers: 3,
        epochs: 2,
        quantize_grads: true,
        interconnect: Interconnect::pcie3(),
    };
    let data = datasets::tiny(7);
    let r = run_data_parallel(&mg, &data).unwrap();
    assert_eq!(r.epochs.len(), 2);
    assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
    let policy = r.policy.expect("mixed multigpu run reports its policy");
    assert!(policy.is_mixed());
    assert!(policy.packed_bytes() < policy.int8_bytes());
}

#[test]
fn one_worker_multigpu_replays_minibatch_under_mixed_policy() {
    // The step-for-step replay guarantee extends to mixed policies: same
    // shared store semantics, same per-bucket scales, same streams.
    let train = mixed(cfg("tango", 3));
    let mut mb = MiniBatchTrainer::from_config(&train).unwrap();
    let single = mb.run().unwrap();
    let data = datasets::tiny(train.seed);
    let mg = MultiGpuConfig {
        train,
        workers: 1,
        epochs: 3,
        quantize_grads: false,
        interconnect: Interconnect::pcie3(),
    };
    let r = run_data_parallel(&mg, &data).unwrap();
    assert_eq!(r.epochs.len(), single.losses.len());
    for (e, (ms, loss)) in r.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: multigpu {} vs minibatch {}",
            ms.loss,
            loss
        );
    }
}
