//! Golden-value guard for the full-graph → identity-block collapse.
//!
//! The `GnnModel` refactor deleted the models' dedicated full-graph
//! forward/backward and replaced it with the block path over identity
//! blocks. This test pins the numerics to the **pre-refactor
//! implementation**: `RefGcn` below is a line-for-line copy of the old
//! full-graph GCN step (static build-time quantized edge norms, the same
//! stochastic-rounding stream ids, the same primitive calls in the same
//! order). The quickstart `Trainer` losses must match it bit for bit —
//! in FP32 *and* Tango mode — so the refactor provably changed no NC
//! training trajectory.

use tango::config::TrainConfig;
use tango::coordinator::Trainer;
use tango::graph::datasets;
use tango::graph::{Coo, Csr};
use tango::model::{softmax_cross_entropy, Sgd, TrainMode};
use tango::primitives::{
    gemm_f32, qgemm, qgemm_prequantized, qspmm_edge_weighted, spmm_csr_values,
};
use tango::quant::rng::Xoshiro256pp;
use tango::quant::{quantize, QTensor, Rounding};
use tango::tensor::Dense;

/// The pre-refactor full-graph GCN (FP32 + Tango arms only — what the NC
/// quickstart exercises). Kept verbatim as the golden reference.
struct RefGcn {
    mode: TrainMode,
    layers_w: Vec<Dense<f32>>,
    layers_gw: Vec<Dense<f32>>,
    csr: Csr,
    csr_rev: Csr,
    norm: Vec<f32>,
    /// Static quantized edge norms (quantized once at build — the old
    /// full-graph behaviour).
    qnorm: QTensor,
    step_count: u64,
}

struct RefCache {
    x: Dense<f32>,
    z: Dense<f32>,
    qx: Option<QTensor>,
    qw: Option<QTensor>,
}

impl RefGcn {
    fn new(
        in_dim: usize,
        hidden: usize,
        out_dim: usize,
        mode: TrainMode,
        graph: &Coo,
        seed: u64,
    ) -> Self {
        let csr = Csr::from_coo(graph);
        let csr_rev = Csr::from_coo_reversed(graph);
        let deg = graph.in_degrees();
        let mut norm = vec![0.0f32; graph.num_edges()];
        for e in 0..graph.num_edges() {
            let du = deg[graph.src[e] as usize].max(1) as f32;
            let dv = deg[graph.dst[e] as usize].max(1) as f32;
            norm[e] = 1.0 / (du * dv).sqrt();
        }
        let qnorm = quantize(
            &Dense::from_vec(&[norm.len(), 1], norm.clone()),
            mode.bits,
            Rounding::Nearest,
        );
        // Glorot init with the exact same rng stream as GcnModel::new.
        let mut rng = Xoshiro256pp::new(seed);
        let dims = [in_dim, hidden, out_dim];
        let mut layers_w = Vec::new();
        let mut layers_gw = Vec::new();
        for l in 0..2 {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
            let data: Vec<f32> =
                (0..fan_in * fan_out).map(|_| (rng.next_f32() * 2.0 - 1.0) * limit).collect();
            layers_w.push(Dense::from_vec(&[fan_in, fan_out], data));
            layers_gw.push(Dense::zeros(&[fan_in, fan_out]));
        }
        RefGcn { mode, layers_w, layers_gw, csr, csr_rev, norm, qnorm, step_count: 0 }
    }

    fn layer_quantized(&self, l: usize) -> bool {
        self.mode.quantize && (l + 1 < 2 || !self.mode.fp32_pre_softmax)
    }

    fn forward_cached(&self, features: &Dense<f32>) -> (Dense<f32>, Vec<RefCache>) {
        let mode = self.mode;
        let mut caches = Vec::new();
        let mut x = features.clone();
        for l in 0..2 {
            let w = &self.layers_w[l];
            let (xw, qx, qw) = if self.layer_quantized(l) {
                let r = qgemm(&x, w, mode.bits, mode.rounding(self.step_count, l as u64));
                (r.out, Some(r.qa), Some(r.qb))
            } else {
                (gemm_f32(&x, w), None, None)
            };
            let z = if self.layer_quantized(l) {
                let qxw = quantize(&xw, mode.bits, mode.rounding(self.step_count, 100 + l as u64));
                qspmm_edge_weighted(&self.csr, &self.qnorm, &qxw, 1)
            } else {
                spmm_csr_values(&self.csr, &self.norm, &xw)
            };
            let out = if l == 0 { z.map(|v| v.max(0.0)) } else { z.clone() };
            caches.push(RefCache { x: x.clone(), z, qx, qw });
            x = out;
        }
        (x, caches)
    }

    fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> f32 {
        let (logits, caches) = self.forward_cached(features);
        let (loss, dlogits) = loss_grad(&logits);
        let mode = self.mode;
        let mut grad = dlogits;
        for l in (0..2).rev() {
            let cache = &caches[l];
            if l == 0 {
                // ReLU backward through the inter-layer activation.
                let mut g = grad.clone();
                for (gv, &zv) in g.data_mut().iter_mut().zip(cache.z.data().iter()) {
                    if zv <= 0.0 {
                        *gv = 0.0;
                    }
                }
                grad = g;
            }
            let dxw = if self.layer_quantized(l) {
                let qg = quantize(&grad, mode.bits, mode.rounding(self.step_count, 200 + l as u64));
                qspmm_edge_weighted(&self.csr_rev, &self.qnorm, &qg, 1)
            } else {
                spmm_csr_values(&self.csr_rev, &self.norm, &grad)
            };
            if self.layer_quantized(l) {
                let qdxw = quantize(&dxw, mode.bits, mode.rounding(self.step_count, 300 + l as u64));
                let qx = cache.qx.as_ref().unwrap();
                let qw = cache.qw.as_ref().unwrap();
                let (gw, _) = qgemm_prequantized(&qx.transpose2d(), &qdxw, mode.bits);
                self.layers_gw[l] = gw;
                if l > 0 {
                    let (gx, _) = qgemm_prequantized(&qdxw, &qw.transpose2d(), mode.bits);
                    grad = gx;
                }
            } else {
                self.layers_gw[l] = gemm_f32(&cache.x.transpose(), &dxw);
                if l > 0 {
                    grad = gemm_f32(&dxw, &self.layers_w[l].transpose());
                }
            }
        }
        for l in 0..2 {
            opt.step(l, &mut self.layers_w[l], &self.layers_gw[l]);
        }
        self.step_count += 1;
        loss
    }
}

/// Run the reference implementation on the quickstart config shape.
fn reference_losses(mode: TrainMode, epochs: usize) -> Vec<f32> {
    let cfg = TrainConfig::quickstart();
    let d = datasets::tiny(cfg.seed);
    let mut m = RefGcn::new(d.features.cols(), cfg.hidden, d.num_classes, mode, &d.graph, cfg.seed);
    let mut opt = Sgd::new(cfg.lr);
    let mut losses = Vec::new();
    for _ in 0..epochs {
        losses.push(m.train_step(&d.features, &mut opt, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        }));
    }
    losses
}

/// Run the real Trainer on the same config.
fn trainer_losses(mode: TrainMode) -> Vec<f32> {
    let mut cfg = TrainConfig::quickstart();
    cfg.mode = mode;
    let mut t = Trainer::from_config(&cfg).unwrap();
    t.run().unwrap().losses
}

#[test]
fn quickstart_tango_losses_match_pre_refactor_reference() {
    let mode = TrainMode::tango(8); // the quickstart default
    let golden = reference_losses(mode, 20);
    let got = trainer_losses(mode);
    assert_eq!(got.len(), golden.len());
    for (e, (a, b)) in golden.iter().zip(got.iter()).enumerate() {
        assert_eq!(a, b, "epoch {e}: reference {a} vs trainer {b} — quickstart numerics drifted");
    }
}

#[test]
fn quickstart_fp32_losses_match_pre_refactor_reference() {
    let mode = TrainMode::fp32();
    let golden = reference_losses(mode, 20);
    let got = trainer_losses(mode);
    for (e, (a, b)) in golden.iter().zip(got.iter()).enumerate() {
        assert_eq!(a, b, "epoch {e}: reference {a} vs trainer {b}");
    }
}

#[test]
fn quickstart_losses_are_the_recorded_shape() {
    // Beyond reference equality: the curve must actually train (sanity that
    // the golden comparison is not vacuous on a broken config).
    let losses = trainer_losses(TrainMode::tango(8));
    assert_eq!(losses.len(), 20);
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses[19] < losses[0], "{losses:?}");
}
