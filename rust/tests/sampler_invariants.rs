//! Property-based invariants of the neighbor-sampling subsystem (driven by
//! `tango::util::prop`): sampled blocks are valid MFGs — compacted ids in
//! range, every edge endpoint present and backed by a parent edge, fanout
//! respected, layers chained, all deterministic under a fixed seed — the
//! quantized feature gather matches direct quantization, edge-seeded LP
//! batches never leak their positive edges into the sampled messages, the
//! degree-bucket partition is complete/disjoint with monotone boundaries,
//! and degree-biased fanout draws are weight-proportional (chi-square).

use tango::graph::{Coo, Csr};
use tango::policy::{BitPolicy, DegreeBuckets, FeaturePolicy};
use tango::quant::{quantize_slice_nearest, quantize_with_scale, Rounding};
use tango::sampler::{
    gather_rows, shuffled_batches, EdgeBatcher, NeighborSampler, QuantFeatureStore, SamplerBias,
};
use tango::tensor::Dense;
use tango::util::prop::{check, Gen};

/// A random parent graph with self-loops (every node has an in-edge, as the
/// datasets guarantee) plus its CSR and in-degrees.
fn random_parent(g: &mut Gen) -> (Coo, Csr, Vec<u32>) {
    let (n, src, dst) = g.graph(40, 160);
    let coo = Coo::new(n, src, dst).with_self_loops();
    let csr = Csr::from_coo(&coo);
    let deg = coo.in_degrees();
    (coo, csr, deg)
}

/// Distinct random seed nodes (a prefix of a shuffled node list).
fn random_seeds(g: &mut Gen, n: usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    for i in (1..order.len()).rev() {
        let j = g.usize_in(0, i);
        order.swap(i, j);
    }
    order.truncate(g.usize_in(1, n.min(8)));
    order
}

#[test]
fn prop_sampled_blocks_are_valid_mfgs() {
    check("sampled blocks valid", 60, |g| {
        let (coo, csr, deg) = random_parent(g);
        let layers = g.usize_in(1, 3);
        let fanouts: Vec<usize> = (0..layers).map(|_| g.usize_in(1, 5)).collect();
        let sampler = NeighborSampler::new(fanouts.clone(), g.u64());
        let seeds = random_seeds(g, coo.num_nodes);
        let blocks = sampler.sample_blocks(&csr, &deg, &seeds, g.u64());
        assert_eq!(blocks.len(), layers);
        let parent_edges: std::collections::HashSet<(u32, u32)> =
            (0..coo.num_edges()).map(|e| (coo.src[e], coo.dst[e])).collect();
        for (l, b) in blocks.iter().enumerate() {
            // Shape invariants: dst prefix, consistent graph views.
            assert!(b.num_dst <= b.num_src());
            assert_eq!(b.coo.num_nodes, b.num_src());
            assert_eq!(b.csr.num_nodes, b.num_dst);
            assert_eq!(b.csr_rev.num_nodes, b.num_src());
            assert_eq!(b.csr.num_edges, b.num_edges());
            assert_eq!(b.csr_rev.num_edges, b.num_edges());
            assert_eq!(b.norm.len(), b.num_edges());
            // Compacted ids injective and in range; every edge is real.
            let distinct: std::collections::HashSet<_> = b.src_nodes.iter().collect();
            assert_eq!(distinct.len(), b.src_nodes.len(), "node map must be injective");
            let mut per_dst = vec![0usize; b.num_dst];
            for e in 0..b.num_edges() {
                let (ls, ld) = (b.coo.src[e] as usize, b.coo.dst[e] as usize);
                assert!(ls < b.num_src(), "src id out of range");
                assert!(ld < b.num_dst, "dst id out of range");
                per_dst[ld] += 1;
                let (gs, gd) = (b.src_nodes[ls], b.src_nodes[ld]);
                assert!(parent_edges.contains(&(gs, gd)), "({gs},{gd}) not a parent edge");
                assert!(b.norm[e] > 0.0 && b.norm[e] <= 1.0, "norm {}", b.norm[e]);
            }
            // Fanout bound; self-loops guarantee at least one in-edge each.
            assert!(per_dst.iter().all(|&c| c <= fanouts[l]), "{per_dst:?} > {}", fanouts[l]);
            assert!(per_dst.iter().all(|&c| c >= 1));
        }
        // Layer chaining ends exactly at the seeds.
        for l in 0..layers - 1 {
            assert_eq!(blocks[l].dst_nodes(), &blocks[l + 1].src_nodes[..]);
        }
        assert_eq!(blocks[layers - 1].dst_nodes(), &seeds[..]);
    });
}

#[test]
fn prop_sampling_is_deterministic_under_fixed_seed() {
    check("sampler determinism", 40, |g| {
        let (coo, csr, deg) = random_parent(g);
        let layers = g.usize_in(1, 3);
        let fanouts: Vec<usize> = (0..layers).map(|_| g.usize_in(1, 4)).collect();
        let sampler_seed = g.u64();
        let stream = g.u64();
        let seeds = random_seeds(g, coo.num_nodes);
        let a = NeighborSampler::new(fanouts.clone(), sampler_seed)
            .sample_blocks(&csr, &deg, &seeds, stream);
        let b = NeighborSampler::new(fanouts, sampler_seed)
            .sample_blocks(&csr, &deg, &seeds, stream);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.src_nodes, y.src_nodes);
            assert_eq!(x.num_dst, y.num_dst);
            assert_eq!(x.coo, y.coo);
            assert_eq!(x.norm, y.norm);
        }
    });
}

#[test]
fn prop_edge_seeded_blocks_are_valid_and_leak_free() {
    check("edge-seeded blocks", 60, |g| {
        let (coo, csr, deg) = random_parent(g);
        let batcher = EdgeBatcher::new(&coo);
        if batcher.num_edges() == 0 {
            return; // degenerate all-self-loop graph: nothing to train on
        }
        // A random positive-edge batch.
        let mut ids = batcher.edge_ids();
        for i in (1..ids.len()).rev() {
            let j = g.usize_in(0, i);
            ids.swap(i, j);
        }
        ids.truncate(g.usize_in(1, ids.len().min(10)));
        let neg_per_pos = g.usize_in(1, 3);
        let eb = batcher.batch(&ids, neg_per_pos, g.u64());

        // Candidate layout: positives first (each a real canonical edge),
        // then negatives; all pair ids index the compacted seed list.
        assert_eq!(eb.pairs.len(), ids.len() * (1 + neg_per_pos));
        let distinct: std::collections::HashSet<u32> = eb.seeds.iter().copied().collect();
        assert_eq!(distinct.len(), eb.seeds.len(), "seed list must be injective");
        for (k, &(lu, lv, t)) in eb.pairs.iter().enumerate() {
            assert!((lu as usize) < eb.seeds.len() && (lv as usize) < eb.seeds.len());
            assert_eq!(t, if k < ids.len() { 1.0 } else { 0.0 });
            if k < ids.len() {
                let (gu, gv) = (eb.seeds[lu as usize], eb.seeds[lv as usize]);
                assert_eq!(batcher.edge(ids[k]), (gu.min(gv), gu.max(gv)));
                assert!(eb.exclude.contains(&(gu, gv)) && eb.exclude.contains(&(gv, gu)));
            }
        }

        // Sample with exclusion: blocks stay valid MFGs over the compacted
        // ids, end at the seeds, and NEVER contain an excluded seed edge in
        // any layer (the leakage check).
        let layers = g.usize_in(1, 3);
        let fanouts: Vec<usize> = (0..layers).map(|_| g.usize_in(1, 5)).collect();
        let sampler = NeighborSampler::new(fanouts, g.u64());
        let stream = g.u64();
        let blocks =
            sampler.sample_blocks_excluding(&csr, &deg, &eb.seeds, stream, &eb.exclude);
        assert_eq!(blocks.len(), layers);
        assert_eq!(blocks[layers - 1].dst_nodes(), &eb.seeds[..]);
        let parent_edges: std::collections::HashSet<(u32, u32)> =
            (0..coo.num_edges()).map(|e| (coo.src[e], coo.dst[e])).collect();
        for b in &blocks {
            let distinct: std::collections::HashSet<_> = b.src_nodes.iter().collect();
            assert_eq!(distinct.len(), b.src_nodes.len(), "compacted ids must be injective");
            for e in 0..b.num_edges() {
                let (ls, ld) = (b.coo.src[e] as usize, b.coo.dst[e] as usize);
                assert!(ls < b.num_src() && ld < b.num_dst, "compacted id out of range");
                let (gs, gd) = (b.src_nodes[ls], b.src_nodes[ld]);
                assert!(parent_edges.contains(&(gs, gd)), "({gs},{gd}) not a parent edge");
                assert!(
                    !eb.exclude.contains(&(gs, gd)),
                    "seed edge ({gs},{gd}) leaked into layer messages"
                );
            }
        }

        // Determinism: the same (sampler seed, stream, batch seed) replays
        // the batch and its blocks exactly.
        let eb2 = batcher.batch(&ids, neg_per_pos, {
            // replay needs the same seed — re-derive it from the generator
            // is impossible, so determinism is asserted on a fixed seed:
            0xDEAD_BEEF
        });
        let eb3 = batcher.batch(&ids, neg_per_pos, 0xDEAD_BEEF);
        assert_eq!(eb2.seeds, eb3.seeds);
        assert_eq!(eb2.pairs, eb3.pairs);
        let b1 = sampler.sample_blocks_excluding(&csr, &deg, &eb2.seeds, 7, &eb2.exclude);
        let b2 = sampler.sample_blocks_excluding(&csr, &deg, &eb3.seeds, 7, &eb3.exclude);
        for (x, y) in b1.iter().zip(b2.iter()) {
            assert_eq!(x.src_nodes, y.src_nodes);
            assert_eq!(x.coo, y.coo);
            assert_eq!(x.norm, y.norm);
        }
    });
}

#[test]
fn prop_quantized_gather_matches_direct_quantization() {
    check("quantized gather", 40, |g| {
        let n = g.usize_in(1, 30);
        let d = g.usize_in(1, 8);
        let feats = Dense::from_vec(&[n, d], g.f32_vec(n * d, -3.0, 3.0));
        let mut store = QuantFeatureStore::new(&feats, 8);
        let k = g.usize_in(1, 20);
        let nodes: Vec<u32> = (0..k).map(|_| g.usize_in(0, n - 1) as u32).collect();
        let q = store.gather_quantized(&feats, &nodes);
        let direct =
            quantize_with_scale(&gather_rows(&feats, &nodes), store.scale(), 8, Rounding::Nearest);
        assert_eq!(q.unpack_dense(), direct.data, "cached rows must equal direct quantization");
        assert!(q.scales.iter().all(|&s| s == direct.scale), "uniform rows share the scale");
        // Re-gathering the same nodes is all hits, bit-identical.
        let misses_before = store.stats().misses;
        let q2 = store.gather_quantized(&feats, &nodes);
        assert_eq!(q2, q);
        assert_eq!(store.stats().misses, misses_before, "second gather must not quantize");
    });
}

#[test]
fn prop_degree_bucket_partition_is_complete_disjoint_and_monotone() {
    check("degree buckets partition", 60, |g| {
        // A random strictly-increasing boundary list (sort + dedup of
        // random picks).
        let m = g.usize_in(0, 4);
        let mut bounds: Vec<u32> = (0..m).map(|_| g.usize_in(1, 100) as u32).collect();
        bounds.sort_unstable();
        bounds.dedup();
        let buckets = DegreeBuckets::new(bounds.clone()).unwrap();
        let nb = buckets.num_buckets();
        assert_eq!(nb, bounds.len() + 1);
        let n = g.usize_in(1, 200);
        let degrees: Vec<u32> = (0..n).map(|_| g.usize_in(0, 150) as u32).collect();
        let assign = buckets.assign(&degrees);
        assert_eq!(assign.len(), n, "every node gets exactly one bucket");
        let mut census = vec![0usize; nb];
        let mlen = bounds.len();
        for (v, &b) in assign.iter().enumerate() {
            let b = b as usize;
            assert!(b < nb, "bucket id out of range");
            census[b] += 1;
            // The bucket's documented degree range really holds (bucket 0
            // hottest): the ranges tile the axis, so membership in one
            // range excludes every other — disjointness.
            let d = degrees[v];
            if mlen > 0 {
                if b == 0 {
                    assert!(d >= bounds[mlen - 1], "deg {d} not in hottest bucket range");
                } else if b == mlen {
                    assert!(d < bounds[0], "deg {d} not in coldest bucket range");
                } else {
                    assert!(
                        d >= bounds[mlen - 1 - b] && d < bounds[mlen - b],
                        "deg {d} outside bucket {b} range"
                    );
                }
            }
        }
        // Completeness: the census covers every node.
        assert_eq!(census.iter().sum::<usize>(), n);
        // Monotonicity is enforced: a shuffled (non-increasing) boundary
        // list is rejected.
        if bounds.len() >= 2 {
            let mut rev = bounds.clone();
            rev.reverse();
            assert!(DegreeBuckets::new(rev).is_err(), "non-monotone boundaries must fail");
        }
    });
}

#[test]
fn degree_biased_draws_are_weight_proportional() {
    // Node 0 has in-neighbors 1, 2, 3 whose (caller-supplied) global
    // in-degrees are 1, 3 and 6. A fanout-1 degree-biased draw must pick
    // each with probability proportional to its weight; a chi-square
    // statistic over many deterministic streams bounds the deviation
    // (df = 2, threshold far beyond any plausible PRNG fluctuation).
    let coo = Coo::new(4, vec![1, 2, 3], vec![0, 0, 0]);
    let csr = Csr::from_coo(&coo);
    let degrees = vec![1u32, 1, 3, 6];
    let sampler = NeighborSampler::with_bias(vec![1], 99, SamplerBias::Degree);
    let n = 9000u64;
    let mut counts = [0u64; 4];
    for stream in 0..n {
        let blocks = sampler.sample_blocks(&csr, &degrees, &[0], stream);
        assert_eq!(blocks[0].num_edges(), 1, "fanout 1 draws one in-edge");
        let chosen = blocks[0].src_nodes[blocks[0].coo.src[0] as usize];
        counts[chosen as usize] += 1;
    }
    assert_eq!(counts[0], 0, "node 0 is not its own in-neighbor");
    let total_w = 10.0f64;
    let mut chi2 = 0.0f64;
    for (v, w) in [(1usize, 1.0f64), (2, 3.0), (3, 6.0)] {
        let expected = n as f64 * w / total_w;
        let observed = counts[v] as f64;
        chi2 += (observed - expected) * (observed - expected) / expected;
        assert!(observed > 0.0, "neighbor {v} never drawn: {counts:?}");
    }
    assert!(chi2 < 25.0, "chi-square {chi2} too large: {counts:?}");

    // The uniform sampler over the same graph is degree-blind: roughly
    // equal counts, wildly off the 1:3:6 weighting.
    let uniform = NeighborSampler::new(vec![1], 99);
    let mut ucounts = [0u64; 4];
    for stream in 0..n {
        let blocks = uniform.sample_blocks(&csr, &degrees, &[0], stream);
        let chosen = blocks[0].src_nodes[blocks[0].coo.src[0] as usize];
        ucounts[chosen as usize] += 1;
    }
    let expected = n as f64 / 3.0;
    for v in 1..4 {
        let dev = (ucounts[v] as f64 - expected).abs() / expected;
        assert!(dev < 0.1, "uniform draw skewed at {v}: {ucounts:?}");
    }
}

#[test]
fn prop_mixed_policy_gather_matches_per_row_quantization() {
    check("mixed policy gather", 30, |g| {
        let n = g.usize_in(2, 24);
        let d = g.usize_in(1, 8);
        let feats = Dense::from_vec(&[n, d], g.f32_vec(n * d, -3.0, 3.0));
        let degrees: Vec<u32> = (0..n).map(|_| g.usize_in(1, 20) as u32).collect();
        let policy = FeaturePolicy::materialize(
            DegreeBuckets::new(vec![5, 12]).unwrap(),
            BitPolicy::new(vec![8, 6, 4]).unwrap(),
            &degrees,
            &feats,
        )
        .unwrap();
        let mut store = QuantFeatureStore::with_policy(policy.clone(), 0);
        let k = g.usize_in(1, 16);
        let nodes: Vec<u32> = (0..k).map(|_| g.usize_in(0, n - 1) as u32).collect();
        let q = store.gather_quantized(&feats, &nodes);
        for (i, &v) in nodes.iter().enumerate() {
            let b = policy.bucket_of_node(v as usize);
            assert_eq!(q.scales[i], policy.scale(b), "row {i} scale");
            assert_eq!(q.bits[i], policy.bits_of(b), "row {i} bits");
            let direct =
                quantize_slice_nearest(feats.row(v as usize), policy.scale(b), policy.bits_of(b));
            assert_eq!(q.row_i8(i), direct, "row {i} must match direct");
        }
        // Re-gathering hits the cache and stays bit-identical.
        let misses_before = store.stats().misses;
        let q2 = store.gather_quantized(&feats, &nodes);
        assert_eq!(q2, q);
        assert_eq!(store.stats().misses, misses_before, "second gather must not quantize");
    });
}

#[test]
fn prop_batches_partition_the_node_set() {
    check("batch partition", 40, |g| {
        let n = g.usize_in(1, 200);
        let nodes: Vec<u32> = (0..n as u32).collect();
        let bs = g.usize_in(1, 64);
        let batches = shuffled_batches(&nodes, bs, g.u64());
        assert!(batches.iter().all(|b| b.len() <= bs && !b.is_empty()));
        let mut all: Vec<u32> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, nodes, "every node exactly once per epoch");
    });
}
