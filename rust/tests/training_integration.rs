//! End-to-end training integration: full trainer runs across models, modes
//! and tasks on the scaled datasets, checking the paper's accuracy claims
//! at test scale.

use tango::config::{ModelKind, TrainConfig};
use tango::coordinator::Trainer;
use tango::model::TrainMode;

fn cfg(model: ModelKind, dataset: &str, mode: TrainMode, epochs: usize) -> TrainConfig {
    TrainConfig {
        model,
        dataset: dataset.into(),
        epochs,
        lr: 0.1,
        hidden: 32,
        heads: 4,
        layers: 2,
        mode,
        auto_bits: false,
        seed: 42,
        log_every: 0,
        ..Default::default()
    }
}

#[test]
fn gcn_tango_matches_fp32_accuracy_on_tiny() {
    // The paper's headline: Tango reaches >99% of FP32 accuracy with the
    // same epoch budget. At test scale we allow a small absolute slack.
    let run = |mode| {
        let mut t = Trainer::from_config(&cfg(ModelKind::Gcn, "tiny", mode, 60)).unwrap();
        t.run().unwrap().final_eval
    };
    let fp = run(TrainMode::fp32());
    let tango = run(TrainMode::tango(8));
    assert!(fp > 0.5, "fp32 baseline failed to learn: {fp}");
    assert!(tango >= fp - 0.08, "tango {tango} too far below fp32 {fp}");
}

#[test]
fn gat_tango_learns_tiny() {
    let mut t =
        Trainer::from_config(&cfg(ModelKind::Gat, "tiny", TrainMode::tango(8), 50)).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_eval > 0.4, "GAT tango eval {}", r.final_eval);
    assert!(r.losses.last().unwrap() < &r.losses[0]);
}

#[test]
fn nearest_rounding_hurts_or_matches_stochastic() {
    // Fig. 7 Test2: nearest rounding destabilises training. At tiny scale we
    // only require it never *beats* stochastic by a margin.
    let run = |mode| {
        let mut t = Trainer::from_config(&cfg(ModelKind::Gcn, "tiny", mode, 60)).unwrap();
        t.run().unwrap().final_eval
    };
    let stoch = run(TrainMode::tango(8));
    let nearest = run(TrainMode::tango_test2(8));
    assert!(nearest <= stoch + 0.1, "nearest {nearest} vs stochastic {stoch}");
}

#[test]
fn exact_baseline_is_slower_than_both() {
    // Fig. 8's key takeaway: EXACT-style quantize-for-memory costs time.
    let time = |mode| {
        let mut t = Trainer::from_config(&cfg(ModelKind::Gcn, "Pubmed", mode, 2)).unwrap();
        t.run().unwrap().wall_secs
    };
    let fp = time(TrainMode::fp32());
    let exact = time(TrainMode::exact(8));
    assert!(exact > fp, "EXACT ({exact:.3}s) must be slower than FP32 ({fp:.3}s)");
}

#[test]
fn pubmed_gcn_full_pipeline() {
    // A real scaled dataset end to end, quantized, with auto bit derivation.
    let mut c = cfg(ModelKind::Gcn, "Pubmed", TrainMode::tango(8), 12);
    c.auto_bits = true;
    c.hidden = 64;
    let mut t = Trainer::from_config(&c).unwrap();
    let bits = t.mode().bits;
    assert!((2..=8).contains(&bits));
    let r = t.run().unwrap();
    assert!(r.final_eval > 0.4, "pubmed eval {}", r.final_eval);
    assert_eq!(r.bits, bits);
}

#[test]
fn link_prediction_auc_above_chance() {
    let mut c = cfg(ModelKind::Gcn, "DBLP", TrainMode::tango(8), 8);
    c.hidden = 32;
    let mut t = Trainer::from_config(&c).unwrap();
    let r = t.run().unwrap();
    assert!(r.final_eval > 0.55, "DBLP AUC {} not above chance", r.final_eval);
}

#[test]
fn sampled_linkpred_end_to_end() {
    // The ROADMAP item this PR closes: `tango train --sampler neighbor
    // --task linkpred` — edge-seeded blocks with seed-edge exclusion
    // through the same Trainer front door, reporting AUC.
    let mut c = cfg(ModelKind::Gcn, "DBLP", TrainMode::tango(8), 3);
    c.hidden = 16;
    c.sampler.enabled = true;
    c.sampler.fanouts = vec![5, 5];
    c.sampler.batch_size = 512;
    let mut t = Trainer::from_config(&c).unwrap();
    assert_eq!(t.task(), tango::graph::datasets::Task::LinkPrediction);
    let r = t.run().unwrap();
    assert_eq!(r.losses.len(), 3);
    assert!(r.losses.iter().all(|l| l.is_finite()), "{:?}", r.losses);
    assert!(r.final_eval > 0.0 && r.final_eval <= 1.0, "AUC {}", r.final_eval);
    // Quantized sampled runs surface the gather-cache stats in the report.
    assert!(r.cache.is_some());
}

#[test]
fn task_flag_runs_sampled_linkpred_on_generated_nc_graph() {
    // `--task linkpred` on an NC dataset: train LP purely off topology.
    let mut c = cfg(ModelKind::Gcn, "tiny", TrainMode::fp32(), 6);
    c.hidden = 16;
    c.task = Some(tango::config::TaskKind::LinkPrediction);
    c.sampler.enabled = true;
    c.sampler.fanouts = vec![8, 8];
    c.sampler.batch_size = 64;
    let mut t = Trainer::from_config(&c).unwrap();
    assert_eq!(t.task(), tango::graph::datasets::Task::LinkPrediction);
    let r = t.run().unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(
        r.losses.last().unwrap() < &(r.losses[0] + 0.05),
        "LP loss must not blow up: {:?}",
        r.losses
    );
    assert!(r.final_eval > 0.0 && r.final_eval <= 1.0, "AUC {}", r.final_eval);
}

#[test]
fn stage_budget_closes_against_wall() {
    // PR 6: `wall_secs` is the *full* epoch budget (training sweep + eval)
    // and the per-epoch stage breakdown accounts for it. The consumer-side
    // stages (`wait + compute + eval`) must close against the measured wall
    // within 5% relative slack plus a small absolute allowance per epoch
    // for the untimed seams (batch shuffling, channel plumbing).
    let check = |report: &tango::coordinator::TrainReport, epochs: usize, what: &str| {
        assert_eq!(report.stages.len(), epochs, "{what}: one stage entry per epoch");
        let totals = report.stage_totals();
        assert!(
            (totals.wall_s - report.wall_secs).abs() <= 1e-6 * report.wall_secs.max(1e-9),
            "{what}: per-epoch walls must sum to wall_secs ({} vs {})",
            totals.wall_s,
            report.wall_secs
        );
        for (i, st) in report.stages.iter().enumerate() {
            assert!(
                st.accounted() <= st.wall_s * 1.05 + 2e-3,
                "{what} epoch {i}: accounted {} exceeds wall {}",
                st.accounted(),
                st.wall_s
            );
        }
        let slack = 0.05 * report.wall_secs + 2e-3 * epochs as f64;
        assert!(
            (report.wall_secs - totals.accounted()).abs() <= slack,
            "{what}: budget does not close: wall {} vs accounted {} (slack {slack})",
            report.wall_secs,
            totals.accounted()
        );
    };

    // Full-graph: wait is zero, compute + eval is the whole epoch.
    let mut t =
        Trainer::from_config(&cfg(ModelKind::Gcn, "Pubmed", TrainMode::tango(8), 3)).unwrap();
    let full = t.run().unwrap();
    check(&full, 3, "full-graph");
    assert!(full.stage_totals().wait_s == 0.0, "full-graph runs have no stage-one wait");

    // Sampled with prefetch disabled: stage one runs inline, so it is all
    // visible consumer-side wait and the budget still closes.
    let mut c = cfg(ModelKind::Gcn, "Pubmed", TrainMode::tango(8), 3);
    c.sampler.enabled = true;
    c.sampler.fanouts = vec![5, 5];
    c.sampler.batch_size = 256;
    c.sampler.prefetch = 0;
    let mut t = Trainer::from_config(&c).unwrap();
    let sampled = t.run().unwrap();
    check(&sampled, 3, "sampled-inline");
    assert!(sampled.stage_totals().wait_s > 0.0, "inline stage one must be accounted as wait");
}

#[test]
fn multigpu_speedup_grows_with_workers() {
    // Fig. 9's shape: quantized-vs-fp32 comm advantage grows with workers.
    // comm_s is the modelled interconnect time, so tiny keeps the real
    // per-worker training cheap without weakening the comparison.
    use tango::graph::datasets;
    use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
    let data = datasets::tiny(42);
    let epoch_comm = |k: usize, quant: bool| {
        let mut train = cfg(
            ModelKind::Gcn,
            "tiny",
            if quant { TrainMode::tango(8) } else { TrainMode::fp32() },
            1,
        );
        train.sampler.fanouts = vec![4, 4];
        train.sampler.batch_size = 64;
        let mc = MultiGpuConfig {
            train,
            workers: k,
            epochs: 1,
            quantize_grads: quant,
            interconnect: Interconnect::pcie3(),
        };
        let r = run_data_parallel(&mc, &data).unwrap();
        r.epochs[0].comm_s
    };
    for k in [2usize, 6] {
        let fp = epoch_comm(k, false);
        let tg = epoch_comm(k, true);
        assert!(tg < fp, "quantized comm must be cheaper at k={k}");
    }
    // Absolute comm saving grows with worker count (congestion relief).
    let save2 = epoch_comm(2, false) - epoch_comm(2, true);
    let save6 = epoch_comm(6, false) - epoch_comm(6, true);
    assert!(save6 > save2, "comm saving should grow with workers: {save2} vs {save6}");
}
