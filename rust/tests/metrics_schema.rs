//! Golden-schema test for the `--metrics-out` artifacts (PR 6 satellite).
//!
//! Builds the `tango train` and `tango multigpu` artifacts through the same
//! assembly path the CLI uses ([`tango::obs::train_artifact`] /
//! [`tango::obs::multigpu_artifact`]) from real small runs, then compares
//! the full recursive key structure against a checked-in expected set.
//! Dynamic-name maps (`counters`, `gauges`, `histograms`, `spans`) collapse
//! to `<name>.*` — their keys vary with instrumentation, their *presence*
//! does not. Adding, renaming or dropping an artifact field fails this test
//! until the golden list (and the schema version, if the change breaks
//! consumers) is updated deliberately.

use std::collections::BTreeSet;
use tango::config::{ModelKind, SamplerConfig, TrainConfig};
use tango::graph::datasets;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::obs;
use tango::sampler::MiniBatchTrainer;
use tango::util::json::Json;

/// Recursively collect the artifact's key paths. Arrays recurse into their
/// first element as `path[]`; the four dynamic-name maps become `path.*`.
fn collect(prefix: &str, j: &Json, out: &mut BTreeSet<String>) {
    match j {
        Json::Obj(map) => {
            for (k, v) in map {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                if matches!(p.as_str(), "counters" | "gauges" | "histograms" | "spans") {
                    out.insert(format!("{p}.*"));
                    continue;
                }
                collect(&p, v, out);
            }
        }
        Json::Arr(items) => {
            let p = format!("{prefix}[]");
            match items.first() {
                Some(first @ Json::Obj(_)) => collect(&p, first, out),
                _ => {
                    out.insert(p);
                }
            }
        }
        _ => {
            out.insert(prefix.to_string());
        }
    }
}

fn keys_of(j: &Json) -> Vec<String> {
    let mut out = BTreeSet::new();
    collect("", j, &mut out);
    out.into_iter().collect()
}

/// The train-config subtree (shared by both artifacts), rooted at `base`.
fn config_keys(base: &str) -> Vec<String> {
    [
        "bits",
        "dataset",
        "epochs",
        "heads",
        "hidden",
        "layers",
        "lr",
        "mode",
        "model",
        "packed_compute",
        "policy.bucket_bits[]",
        "policy.degree_buckets[]",
        "sampler.batch_size",
        "sampler.cache_nodes",
        "sampler.degree_biased",
        "sampler.enabled",
        "sampler.fanouts[]",
        "sampler.prefetch",
        "sampler.seed",
        "seed",
    ]
    .iter()
    .map(|k| format!("{base}.{k}"))
    .collect()
}

/// Keys shared by both artifacts outside `config`/`report`.
fn shared_keys() -> Vec<String> {
    let mut v: Vec<String> = [
        "cache.evictions",
        "cache.hits",
        "cache.misses",
        "command",
        "counters.*",
        "fault",
        "gauges.*",
        "histograms.*",
        "policy.bits[]",
        "policy.boundaries[]",
        "policy.buckets[].error_x",
        "policy.buckets[].hits",
        "policy.buckets[].int8_bytes",
        "policy.buckets[].misses",
        "policy.buckets[].packed_bytes",
        "policy.buckets[].rows",
        "policy.int8_bytes",
        "policy.node_counts[]",
        "policy.packed_bytes",
        "schema",
        "spans.*",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    for st in STAGE_KEYS {
        v.push(format!("epochs[].stages.{st}"));
    }
    v
}

const STAGE_KEYS: [&str; 7] =
    ["comm_s", "compute_s", "eval_s", "gather_s", "sample_s", "wait_s", "wall_s"];

fn sorted(mut v: Vec<String>) -> Vec<String> {
    v.sort();
    v
}

fn base_train() -> TrainConfig {
    TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs: 2,
        hidden: 8,
        seed: 9,
        sampler: SamplerConfig {
            enabled: true,
            fanouts: vec![4, 4],
            batch_size: 32,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn train_artifact_matches_golden_key_set() {
    let cfg = base_train();
    let mut t = MiniBatchTrainer::with_dataset(cfg.clone(), datasets::tiny(cfg.seed)).unwrap();
    let report = t.run().unwrap();
    assert!(!report.stages.is_empty(), "sampled run reports per-epoch stages");
    let artifact = obs::train_artifact(&cfg, &report, &obs::snapshot());
    assert_eq!(artifact.get("schema").unwrap().as_str(), Some(obs::SCHEMA));
    assert_eq!(artifact.get("command").unwrap().as_str(), Some("train"));

    let mut expected = shared_keys();
    expected.extend(config_keys("config"));
    expected.extend(
        [
            "epochs[].epoch",
            "epochs[].eval",
            "epochs[].loss",
            "report.bits",
            "report.cache_bytes",
            "report.epochs_to_converge",
            "report.final_eval",
            "report.prefetch_wait_s",
            "report.wall_secs",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    for st in STAGE_KEYS {
        expected.push(format!("report.stage_totals.{st}"));
    }
    assert_eq!(keys_of(&artifact), sorted(expected));

    // The artifact round-trips through the JSON writer/parser.
    let reparsed = Json::parse(&artifact.to_string()).unwrap();
    assert_eq!(reparsed, artifact);
}

#[test]
fn multigpu_artifact_matches_golden_key_set() {
    let cfg = MultiGpuConfig {
        train: base_train(),
        workers: 2,
        epochs: 2,
        quantize_grads: true,
        interconnect: Interconnect::pcie3(),
    };
    let data = datasets::tiny(cfg.train.seed);
    let report = run_data_parallel(&cfg, &data).unwrap();
    let artifact = obs::multigpu_artifact(&cfg, &report, &obs::snapshot());
    assert_eq!(artifact.get("schema").unwrap().as_str(), Some(obs::SCHEMA));
    assert_eq!(artifact.get("command").unwrap().as_str(), Some("multigpu"));

    let mut expected = shared_keys();
    expected.extend(config_keys("config.train"));
    expected.extend(
        [
            "config.epochs",
            "config.quantize_grads",
            "config.workers",
            "epochs[].epoch",
            "epochs[].loss",
            "epochs[].steps",
            "report.cache_bytes",
            "report.grad_elems",
            "report.total_time_s",
        ]
        .iter()
        .map(|s| s.to_string()),
    );
    assert_eq!(keys_of(&artifact), sorted(expected));

    let reparsed = Json::parse(&artifact.to_string()).unwrap();
    assert_eq!(reparsed, artifact);
}

#[test]
fn absent_sections_are_null_not_missing() {
    // An FP32 full-graph run has no cache and no policy report — the keys
    // must still exist (as null) so downstream tooling indexes blindly.
    let mut cfg = base_train();
    cfg.sampler.enabled = false;
    cfg.mode = tango::model::TrainMode::fp32();
    let mut t = tango::coordinator::Trainer::with_dataset(cfg.clone(), datasets::tiny(cfg.seed))
        .unwrap();
    let report = t.run().unwrap();
    let artifact = obs::train_artifact(&cfg, &report, &obs::snapshot());
    assert_eq!(artifact.get("cache"), Some(&Json::Null));
    assert_eq!(artifact.get("policy"), Some(&Json::Null));
    assert_eq!(artifact.get("fault"), Some(&Json::Null), "fault section is null when injection is off");
    // Stage objects keep all seven keys even when some stages are zero.
    let epochs = artifact.get("epochs").unwrap().as_arr().unwrap();
    let stages = epochs[0].get("stages").unwrap();
    for st in STAGE_KEYS {
        assert!(stages.get(st).is_some(), "missing stage key {st}");
    }
}
