//! Golden-schema + corruption tests for the `tango-ckpt/v1` artifact
//! (PR 9 satellite).
//!
//! Three halves:
//! 1. the checkpoint file's full recursive key structure is pinned against
//!    a checked-in expected set (the `tests/metrics_schema.rs` discipline,
//!    applied to the checkpoint artifact) — adding, renaming or dropping a
//!    field fails here until the golden list is updated deliberately;
//! 2. a real training run's run-complete checkpoint must reflect the run
//!    (cursor at the end, bit-exact params and loss trace);
//! 3. loads of missing, corrupt, truncated or wrong-schema files — and
//!    resumes into mismatched runs — are actionable errors, never panics.

use std::collections::BTreeSet;
use tango::ckpt::{fingerprint_of, Checkpoint, Cursor, Fingerprint, SCHEMA};
use tango::config::{ModelKind, SamplerConfig, TrainConfig};
use tango::graph::datasets;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::sampler::MiniBatchTrainer;
use tango::util::json::Json;

fn tmp(name: &str) -> String {
    std::env::temp_dir().join(name).to_string_lossy().into_owned()
}

/// A checkpoint exercising every schema shape: a `None` velocity slot next
/// to a `Some`, active policy scales, non-empty traces.
fn sample() -> Checkpoint {
    Checkpoint {
        command: "train".to_string(),
        fingerprint: Fingerprint {
            dataset: "tiny".to_string(),
            model: "gcn".to_string(),
            mode: "tango".to_string(),
            bits: 8,
            seed: 7,
            sample_seed: 23,
            workers: 1,
            sampled: true,
        },
        cursor: Cursor { epoch: 1, step: 2, loss_sum: 0.625, loss_steps: 2 },
        step_count: 7,
        params: vec![1.0, -0.5, f32::MIN_POSITIVE, 0.0],
        velocity: vec![None, Some((vec![2, 2], vec![0.1, 0.2, 0.3, 0.4]))],
        policy_scales: Some(vec![0.5, 0.25]),
        losses: vec![0.9],
        evals: vec![0.5],
    }
}

/// Recursively collect key paths; array elements all collapse to `path[]`
/// (so a null and an object slot of `velocity` both contribute).
fn collect(prefix: &str, j: &Json, out: &mut BTreeSet<String>) {
    match j {
        Json::Obj(map) => {
            for (k, v) in map {
                let p = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                collect(&p, v, out);
            }
        }
        Json::Arr(items) => {
            let p = format!("{prefix}[]");
            if items.is_empty() {
                out.insert(p);
            } else {
                for item in items {
                    collect(&p, item, out);
                }
            }
        }
        _ => {
            out.insert(prefix.to_string());
        }
    }
}

fn base_train() -> TrainConfig {
    TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs: 2,
        hidden: 8,
        seed: 9,
        sampler: SamplerConfig {
            enabled: true,
            fanouts: vec![4, 4],
            batch_size: 32,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn checkpoint_file_matches_golden_key_paths() {
    let path = tmp("tango_ckpt_schema_golden.json");
    let ck = sample();
    ck.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'), "artifact files are newline-terminated");
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));

    let mut keys = BTreeSet::new();
    collect("", &doc, &mut keys);
    let expected: BTreeSet<String> = [
        "command",
        "cursor.epoch",
        "cursor.loss_steps",
        "cursor.loss_sum",
        "cursor.step",
        "evals[]",
        "fingerprint.bits",
        "fingerprint.dataset",
        "fingerprint.model",
        "fingerprint.mode",
        "fingerprint.sample_seed",
        "fingerprint.sampled",
        "fingerprint.seed",
        "fingerprint.workers",
        "losses[]",
        "params.data",
        "params.len",
        "policy_scales",
        "schema",
        "step_count",
        "velocity[]",
        "velocity[].data",
        "velocity[].shape[]",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(keys, expected);

    // Float payloads are hex bit patterns, not decimal: 8 chars per f32.
    let data = doc.get("params").unwrap().get("data").unwrap().as_str().unwrap();
    assert_eq!(data.len(), ck.params.len() * 8);
    assert!(data.chars().all(|c| c.is_ascii_hexdigit()), "{data}");
    let loss_sum = doc.get("cursor").unwrap().get("loss_sum").unwrap().as_str().unwrap();
    assert_eq!(loss_sum.len(), 16);

    // And the round trip is exact.
    assert_eq!(Checkpoint::load(&path).unwrap(), ck);
    std::fs::remove_file(&path).ok();
}

#[test]
fn run_complete_checkpoint_reflects_the_run() {
    let path = tmp("tango_ckpt_schema_run.json");
    let mut cfg = base_train();
    cfg.ckpt.every = 3;
    cfg.ckpt.path = path.clone();
    let mut t = MiniBatchTrainer::with_dataset(cfg.clone(), datasets::tiny(cfg.seed)).unwrap();
    let report = t.run().unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.command, "train");
    assert_eq!(ck.fingerprint, fingerprint_of(&cfg, 1, true));
    // Run-complete cursor: nothing left to replay.
    assert_eq!((ck.cursor.epoch, ck.cursor.step), (cfg.epochs, 0));
    assert_eq!((ck.cursor.loss_sum, ck.cursor.loss_steps), (0.0, 0));
    // Bit-exact state: the stored params are the trained params, and the
    // stored traces are the report's (f32 widened to f64 exactly).
    assert_eq!(ck.params, t.params_flat());
    assert_eq!(ck.losses.len(), report.losses.len());
    for (stored, live) in ck.losses.iter().zip(&report.losses) {
        assert_eq!(*stored as f32, *live);
    }
    for (stored, live) in ck.evals.iter().zip(&report.evals) {
        assert_eq!(*stored as f32, *live);
    }
    assert!(ck.step_count > 0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupt_truncated_and_wrong_schema_loads_are_actionable_errors() {
    // Missing file.
    let e = Checkpoint::load("/nonexistent/tango_nope.json").unwrap_err().to_string();
    assert!(e.contains("reading checkpoint"), "{e}");

    // Not JSON at all.
    let path = tmp("tango_ckpt_schema_corrupt.json");
    std::fs::write(&path, "this is not json{{{").unwrap();
    let e = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(e.contains("not valid JSON"), "{e}");

    // Truncated mid-document (the crash-mid-write shape write_atomic
    // prevents; the loader must still reject it by name).
    let good = sample();
    good.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let e = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(e.contains(&path), "error names the file: {e}");

    // Wrong schema tag: names both the found and the supported version.
    std::fs::write(&path, "{\"schema\":\"tango-ckpt/v0\"}\n").unwrap();
    let e = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(e.contains("tango-ckpt/v0") && e.contains(SCHEMA), "{e}");

    // Valid JSON, corrupted hex payload: the error names the field path.
    let mut doc = good.to_json();
    if let Json::Obj(m) = &mut doc {
        let Some(Json::Obj(p)) = m.get_mut("params") else { panic!("params object") };
        p.insert("data".to_string(), Json::Str("zzzz".to_string()));
    }
    std::fs::write(&path, doc.to_string()).unwrap();
    let e = Checkpoint::load(&path).unwrap_err().to_string();
    assert!(e.contains("params.data"), "{e}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_rejects_mismatched_runs_by_name() {
    let path = tmp("tango_ckpt_schema_mismatch.json");
    let mut cfg = base_train();
    cfg.epochs = 1;
    cfg.ckpt.every = 1000; // cadence never hits; the run-complete save does
    cfg.ckpt.path = path.clone();
    MiniBatchTrainer::with_dataset(cfg.clone(), datasets::tiny(cfg.seed))
        .unwrap()
        .run()
        .unwrap();

    // A different master seed is a different run.
    let mut other = cfg.clone();
    other.seed += 1;
    other.ckpt.every = 0;
    other.ckpt.resume = Some(path.clone());
    let e = MiniBatchTrainer::with_dataset(other.clone(), datasets::tiny(other.seed))
        .unwrap()
        .run()
        .unwrap_err()
        .to_string();
    assert!(e.contains("seed"), "{e}");

    // A train checkpoint cannot resume a multigpu run.
    let mut train = cfg.clone();
    train.ckpt.every = 0;
    train.ckpt.resume = Some(path.clone());
    let mg = MultiGpuConfig {
        train,
        workers: 1,
        epochs: 1,
        quantize_grads: false,
        interconnect: Interconnect::pcie3(),
    };
    let e = run_data_parallel(&mg, &datasets::tiny(cfg.seed)).unwrap_err().to_string();
    assert!(e.contains("command"), "{e}");
    std::fs::remove_file(&path).ok();
}
