//! Property-based invariants across modules (the proptest-style suite;
//! driven by `tango::util::prop`).

use tango::coordinator::{detect_reuse, QuantCache};
use tango::graph::{Coo, Csr, Incidence};
use tango::multigpu::ring_allreduce;
use tango::primitives::{
    edge_softmax, gemm_f32, incidence_spmm, qgemm, spmm_edge_aggregate_3mat, spmm_edge_weighted,
    spmm_per_head,
};
use tango::quant::{dequantize, error_x, quantize, Rounding};
use tango::tensor::Dense;
use tango::util::prop::{check, Gen};

fn random_graph(g: &mut Gen, max_nodes: usize, max_edges: usize) -> Coo {
    let (n, src, dst) = g.graph(max_nodes, max_edges);
    Coo::new(n, src, dst)
}

fn random_dense(g: &mut Gen, rows: usize, cols: usize) -> Dense<f32> {
    Dense::from_vec(&[rows, cols], g.f32_vec(rows * cols, -2.0, 2.0))
}

#[test]
fn prop_quantize_roundtrip_error_bounded() {
    check("quantize roundtrip", 100, |g| {
        let n = g.usize_in(1, 512);
        let bits = [2u8, 4, 8][g.usize_in(0, 2)];
        let x = Dense::from_vec(&[n], g.f32_vec(n, -10.0, 10.0));
        let rounding = if g.bool(0.5) { Rounding::Nearest } else { Rounding::Stochastic { seed: g.u64() } };
        let q = quantize(&x, bits, rounding);
        let y = dequantize(&q);
        let bound = match rounding {
            Rounding::Nearest => q.scale / 2.0,
            Rounding::Stochastic { .. } => q.scale,
        } + 1e-5;
        for (a, b) in x.data().iter().zip(y.data().iter()) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
        // Error_X always in [0, 1].
        let e = error_x(&x, &y);
        assert!((0.0..=1.0).contains(&e), "Error_X {e}");
    });
}

#[test]
fn prop_incidence_spmm_equals_three_matrix() {
    // The Fig. 5 reformulation is exact on arbitrary graphs.
    check("incidence == 3mat", 60, |g| {
        let coo = random_graph(g, 40, 150);
        let csr = Csr::from_coo(&coo);
        let inc = Incidence::from_csr(&csr);
        let f = g.usize_in(1, 12);
        let ef = random_dense(g, coo.num_edges(), f);
        if coo.num_edges() == 0 {
            return;
        }
        let a = spmm_edge_aggregate_3mat(&csr, &ef);
        let b = incidence_spmm(&inc, &ef);
        assert!(a.max_abs_diff(&b) < 1e-4);
    });
}

#[test]
fn prop_per_head_split_equals_native() {
    // The Fig. 6 kernel transform is exact.
    check("per-head == native", 40, |g| {
        let coo = random_graph(g, 30, 100);
        if coo.num_edges() == 0 {
            return;
        }
        let csr = Csr::from_coo(&coo);
        let heads = g.usize_in(1, 4);
        let d = g.usize_in(1, 6);
        let alpha = random_dense(g, coo.num_edges(), heads);
        let h = random_dense(g, coo.num_nodes, heads * d);
        let native = spmm_edge_weighted(&csr, &alpha, &h, heads);
        let split = spmm_per_head(&csr, &alpha, &h, heads);
        assert!(native.max_abs_diff(&split) < 1e-4);
    });
}

#[test]
fn prop_qgemm_error_bounded_by_grid() {
    // |qgemm - gemm| <= K * (|A|max sb + |B|max sa + sa sb) per element —
    // use the loose practical bound K·(sa·|B|max + sb·|A|max + sa·sb).
    check("qgemm error bound", 30, |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 48);
        let n = g.usize_in(1, 16);
        let a = random_dense(g, m, k);
        let b = random_dense(g, k, n);
        let exact = gemm_f32(&a, &b);
        let q = qgemm(&a, &b, 8, Rounding::Nearest);
        let (sa, sb) = (q.qa.scale, q.qb.scale);
        let bound = k as f32
            * (0.5 * sa * b.abs_max() + 0.5 * sb * a.abs_max() + 0.25 * sa * sb)
            + 1e-4;
        assert!(
            q.out.max_abs_diff(&exact) <= bound,
            "err {} > bound {bound}",
            q.out.max_abs_diff(&exact)
        );
    });
}

#[test]
fn prop_edge_softmax_is_distribution() {
    check("softmax rows sum to 1", 40, |g| {
        let coo = random_graph(g, 25, 80);
        if coo.num_edges() == 0 {
            return;
        }
        let csr = Csr::from_coo(&coo);
        let heads = g.usize_in(1, 3);
        let logits = random_dense(g, coo.num_edges(), heads);
        let alpha = edge_softmax(&csr, &logits);
        for v in 0..csr.num_nodes {
            let (_, eids) = csr.row(v);
            if eids.is_empty() {
                continue;
            }
            for h in 0..heads {
                let s: f32 = eids.iter().map(|&e| alpha.at(e as usize, h)).sum();
                assert!((s - 1.0).abs() < 1e-3, "v={v} h={h}: {s}");
                for &e in eids {
                    assert!(alpha.at(e as usize, h) >= 0.0);
                }
            }
        }
    });
}

#[test]
fn prop_allreduce_mean_and_agreement() {
    check("allreduce", 40, |g| {
        let k = g.usize_in(1, 5);
        let n = g.usize_in(1, 100);
        let grads: Vec<Vec<f32>> = (0..k).map(|_| g.f32_vec(n, -3.0, 3.0)).collect();
        let want: Vec<f32> =
            (0..n).map(|i| grads.iter().map(|gr| gr[i]).sum::<f32>() / k as f32).collect();
        let mut fp = grads.clone();
        ring_allreduce(&mut fp, false, 0);
        for w in 0..k {
            for i in 0..n {
                assert!((fp[w][i] - want[i]).abs() < 1e-5);
            }
        }
        let mut q = grads;
        ring_allreduce(&mut q, true, g.u64());
        for w in 1..k {
            assert_eq!(q[0], q[w]);
        }
    });
}

#[test]
fn prop_cache_returns_identical_tensors() {
    check("qcache identity", 40, |g| {
        let mut cache = QuantCache::new();
        let rows = g.usize_in(1, 32);
        let cols = g.usize_in(1, 16);
        let x = random_dense(g, rows, cols);
        let key = g.u64();
        let r1 = cache.get_or_quantize(key, &x, 8, Rounding::Nearest).clone();
        let r2 = cache.get_or_quantize(key, &x, 8, Rounding::Nearest).clone();
        assert_eq!(r1, r2);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);
    });
}

#[test]
fn prop_reuse_plan_saves_iff_sharing_exists() {
    use tango::coordinator::{CompGraph, OpKind};
    check("reuse accounting", 60, |g| {
        let n_t = g.usize_in(2, 10);
        let mut cg = CompGraph::new();
        let ids: Vec<_> = (0..n_t).map(|i| cg.tensor(&format!("t{i}"))).collect();
        let ops = g.usize_in(1, 12);
        for i in 0..ops {
            let kind = [OpKind::Gemm, OpKind::Spmm, OpKind::Sddmm, OpKind::Softmax][g.usize_in(0, 3)];
            let a = ids[g.usize_in(0, n_t - 1)];
            let o = ids[g.usize_in(0, n_t - 1)];
            cg.op(kind, &format!("op{i}"), &[a], &[o], g.bool(0.5));
        }
        let plan = detect_reuse(&cg);
        assert!(plan.cached_quantizations <= plan.naive_quantizations);
        // Savings exist iff some tensor has >1 quantizable consumer.
        let sharing = (0..n_t).any(|t| {
            let (f, b) = cg.quantizable_consumers(ids[t]);
            f + b > 1
        });
        assert_eq!(plan.saved() > 0, sharing);
    });
}

#[test]
fn prop_csr_roundtrip_preserves_edges() {
    check("csr reverse roundtrip", 60, |g| {
        let coo = random_graph(g, 30, 120);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.reverse().reverse(), csr);
        // Every edge id appears exactly once.
        let mut ids: Vec<u32> = csr.edge_ids.clone();
        ids.sort_unstable();
        let want: Vec<u32> = (0..coo.num_edges() as u32).collect();
        assert_eq!(ids, want);
    });
}
