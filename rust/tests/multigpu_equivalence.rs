//! Multi-GPU ↔ single-GPU equivalence and determinism.
//!
//! The data-parallel simulator shares the sampler Block pipeline, the
//! shuffled epoch sweep and the splitmix64 seed mixing with
//! `MiniBatchTrainer`, so a 1-worker FP32 run must replay the single-GPU
//! trainer *step for step* — on both task heads, now that both engines
//! construct models through the one `GnnModel`/`AnyModel` seam; and any
//! run must be bit-reproducible for a fixed config at every worker count.

use tango::config::{ModelKind, TaskKind, TrainConfig};
use tango::graph::datasets;
use tango::model::TrainMode;
use tango::multigpu::{run_data_parallel, Interconnect, MultiGpuConfig};
use tango::quant::rng::mix_seeds;
use tango::sampler::MiniBatchTrainer;

fn base_train(mode: TrainMode, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig {
        model: ModelKind::Gcn,
        dataset: "tiny".into(),
        epochs,
        lr: 0.1,
        hidden: 16,
        heads: 2,
        layers: 2,
        mode,
        auto_bits: false,
        seed: 11,
        log_every: 0,
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![5, 5];
    cfg.sampler.batch_size = 32;
    cfg
}

fn multi(train: TrainConfig, workers: usize, epochs: usize, quant: bool) -> MultiGpuConfig {
    MultiGpuConfig {
        train,
        workers,
        epochs,
        quantize_grads: quant,
        interconnect: Interconnect::pcie3(),
    }
}

#[test]
fn one_worker_matches_minibatch_trainer_loss_trajectory() {
    let epochs = 5;
    let train = base_train(TrainMode::fp32(), epochs);

    let mut mb = MiniBatchTrainer::from_config(&train).unwrap();
    let single = mb.run().unwrap();

    let data = datasets::tiny(train.seed);
    let mg = run_data_parallel(&multi(train, 1, epochs, false), &data).unwrap();

    assert_eq!(mg.epochs.len(), single.losses.len());
    for (e, (ms, loss)) in mg.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: multigpu {} vs minibatch {}",
            ms.loss,
            loss
        );
    }
}

#[test]
fn one_worker_matches_minibatch_trainer_quantized_gather() {
    // Same equivalence with the quantized feature store in the loop (the
    // process-wide store quantizes against one static scale, so the shared
    // cache cannot change gathered values).
    let epochs = 4;
    let train = base_train(TrainMode::tango(8), epochs);

    let mut mb = MiniBatchTrainer::from_config(&train).unwrap();
    let single = mb.run().unwrap();

    let data = datasets::tiny(train.seed);
    let mg = run_data_parallel(&multi(train, 1, epochs, false), &data).unwrap();

    for (e, (ms, loss)) in mg.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: multigpu {} vs minibatch {}",
            ms.loss,
            loss
        );
    }
}

#[test]
fn one_worker_matches_minibatch_trainer_linkpred() {
    // Refactor-safety for the new task head: LP shards canonical edges,
    // draws seeded negatives and samples edge-seeded blocks through the
    // same mixers as MiniBatchTrainer — one worker must replay it step for
    // step, exactly like the NC path.
    let epochs = 4;
    let mut train = base_train(TrainMode::fp32(), epochs);
    train.task = Some(TaskKind::LinkPrediction);

    let mut mb = MiniBatchTrainer::from_config(&train).unwrap();
    assert_eq!(mb.task(), datasets::Task::LinkPrediction);
    let single = mb.run().unwrap();

    let data = datasets::tiny(train.seed);
    let mg = run_data_parallel(&multi(train, 1, epochs, false), &data).unwrap();

    assert_eq!(mg.epochs.len(), single.losses.len());
    for (e, (ms, loss)) in mg.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: multigpu {} vs minibatch {}",
            ms.loss,
            loss
        );
    }
}

#[test]
fn one_worker_with_prefetch_replays_sequential_minibatch_trainer() {
    // The replay guarantee must hold *across* pipeline modes: a strictly
    // sequential single-GPU run (prefetch 0) and a 1-worker data-parallel
    // run prefetching 3 batches ahead are the same training trajectory —
    // per-batch RNG streams are keyed by position, not by when stage one
    // runs.
    let epochs = 4;
    let mut train = base_train(TrainMode::tango(8), epochs);
    train.sampler.prefetch = 0;

    let mut mb = MiniBatchTrainer::from_config(&train).unwrap();
    let single = mb.run().unwrap();

    let data = datasets::tiny(train.seed);
    let mut piped = train.clone();
    piped.sampler.prefetch = 3;
    let mg = run_data_parallel(&multi(piped, 1, epochs, false), &data).unwrap();

    assert_eq!(mg.epochs.len(), single.losses.len());
    for (e, (ms, loss)) in mg.epochs.iter().zip(&single.losses).enumerate() {
        assert!(
            (ms.loss - loss).abs() < 1e-6,
            "epoch {e}: prefetched multigpu {} vs sequential minibatch {}",
            ms.loss,
            loss
        );
    }
}

#[test]
fn multi_worker_linkpred_learns() {
    let data = datasets::tiny(11);
    let mut train = base_train(TrainMode::fp32(), 6);
    train.task = Some(TaskKind::LinkPrediction);
    let r = run_data_parallel(&multi(train, 3, 6, false), &data).unwrap();
    assert!(r.epochs.iter().all(|e| e.loss.is_finite()));
    let first = r.epochs.first().unwrap().loss;
    let last = r.epochs.last().unwrap().loss;
    assert!(last < first + 0.05, "LP loss must not blow up: {first} -> {last}");
}

#[test]
fn runs_are_deterministic_across_repeats_at_every_worker_count() {
    let data = datasets::tiny(11);
    for &k in &[1usize, 2, 3] {
        let run = || {
            let train = base_train(TrainMode::fp32(), 3);
            let r = run_data_parallel(&multi(train, k, 3, true), &data).unwrap();
            r.epochs.iter().map(|e| e.loss).collect::<Vec<f32>>()
        };
        assert_eq!(run(), run(), "workers={k} must be reproducible");
    }
}

#[test]
fn worker_streams_are_distinct_beyond_256() {
    // The old mixer (`seed ^ (epoch << 8) ^ worker`) collided for
    // worker >= 256 and correlated streams across epochs; the shared
    // splitmix64 mixer must not.
    let mut seen = std::collections::HashSet::new();
    for epoch in 0..4u64 {
        for w in 0..300u64 {
            let s = mix_seeds(&[0x5A17, 11, w]);
            let stream = mix_seeds(&[s, epoch]);
            assert!(seen.insert(stream), "stream collision at epoch {epoch}, worker {w}");
        }
    }
}

#[test]
fn more_workers_still_learn() {
    // Sanity at k>1: the averaged-update lockstep must actually train.
    let data = datasets::tiny(11);
    let train = base_train(TrainMode::fp32(), 6);
    let r = run_data_parallel(&multi(train, 3, 6, false), &data).unwrap();
    let first = r.epochs.first().unwrap().loss;
    let last = r.epochs.last().unwrap().loss;
    assert!(last < first, "loss must fall: {first} -> {last}");
}
