//! Pipelined-vs-sequential equivalence: `--prefetch 0` and `--prefetch N`
//! must produce **bit-identical** training traces.
//!
//! Stage one (sampling + quantized gather) keys every batch's RNG stream by
//! `mix_seeds(&[epoch, batch index])` alone, and the quantized feature
//! store quantizes against one static scale — so running stage one on a
//! producer thread, batches ahead of the training step, changes *when* work
//! happens but never *what* is computed. These tests pin that for both
//! tasks, both models and both precision modes, plus the pipeline's edge
//! cases (tiny epochs, depth > batch count, producer panics).

use tango::config::{parse_mode, ModelKind, TaskKind, TrainConfig};
use tango::sampler::{run_prefetched, MiniBatchTrainer};

fn cfg(model: ModelKind, mode: &str, task: Option<TaskKind>, prefetch: usize) -> TrainConfig {
    let mut cfg = TrainConfig {
        model,
        dataset: "tiny".into(),
        epochs: 3,
        lr: 0.1,
        hidden: 8,
        heads: 2,
        layers: 2,
        mode: parse_mode(mode, 8).unwrap(),
        auto_bits: false,
        seed: 7,
        log_every: 0,
        task,
        ..Default::default()
    };
    cfg.sampler.enabled = true;
    cfg.sampler.fanouts = vec![4, 4];
    cfg.sampler.batch_size = 32;
    cfg.sampler.prefetch = prefetch;
    cfg
}

/// Full report of a run.
fn traces_report(cfg: &TrainConfig) -> tango::coordinator::TrainReport {
    MiniBatchTrainer::from_config(cfg).unwrap().run().unwrap()
}

/// Full loss + eval traces of a run (bitwise comparison via `==`).
fn traces(cfg: &TrainConfig) -> (Vec<f32>, Vec<f32>) {
    let r = traces_report(cfg);
    (r.losses, r.evals)
}

#[test]
fn prefetch_is_bit_identical_across_models_modes_and_tasks() {
    for model in [ModelKind::Gcn, ModelKind::Gat] {
        for mode in ["fp32", "tango"] {
            for task in [None, Some(TaskKind::LinkPrediction)] {
                let seq = traces(&cfg(model, mode, task, 0));
                let piped = traces(&cfg(model, mode, task, 2));
                assert_eq!(
                    seq, piped,
                    "prefetch changed the trace: model {model:?}, mode {mode}, task {task:?}"
                );
                // Deeper prefetch, same trace.
                let deep = traces(&cfg(model, mode, task, 8));
                assert_eq!(seq, deep, "deep prefetch drifted: {model:?}/{mode}/{task:?}");
            }
        }
    }
}

#[test]
fn prefetch_deeper_than_the_epoch_is_fine() {
    // tiny has 160 train nodes; batch 128 → 2 batches per epoch, far fewer
    // than the prefetch depth — everything buffers, nothing deadlocks.
    let mut a = cfg(ModelKind::Gcn, "tango", None, 0);
    a.sampler.batch_size = 128;
    let mut b = a.clone();
    b.sampler.prefetch = 16;
    assert_eq!(traces(&a), traces(&b));
}

#[test]
fn quantized_cache_stats_still_surface_with_prefetch_on() {
    // The feature store moves to the producer thread for every epoch; its
    // hit/miss/eviction accounting must still land in TrainReport.cache.
    let mut c = cfg(ModelKind::Gcn, "tango", None, 3);
    c.sampler.cache_nodes = 32;
    let mut t = MiniBatchTrainer::from_config(&c).unwrap();
    let r = t.run().unwrap();
    let stats = r.cache.expect("quantized run reports cache stats");
    assert!(stats.hits + stats.misses > 0, "{stats:?}");
    assert!(stats.evictions > 0, "160 train nodes must overflow 32 slots");
    assert!(r.cache_bytes > 0);
}

#[test]
fn measured_stage_one_wait_lands_in_the_report() {
    // Sequential runs charge the whole inline sample+gather time as wait;
    // it must be positive, finite and bounded by the training wall time.
    let r = traces_report(&cfg(ModelKind::Gcn, "tango", None, 0));
    assert!(r.prefetch_wait_s > 0.0, "inline stage one must be charged");
    assert!(r.prefetch_wait_s <= r.wall_secs, "wait is a slice of the wall");
    // Prefetched runs still report a finite, non-negative wait.
    let p = traces_report(&cfg(ModelKind::Gcn, "tango", None, 2));
    assert!(p.prefetch_wait_s.is_finite() && p.prefetch_wait_s >= 0.0);
    assert!(p.prefetch_wait_s <= p.wall_secs);
}

#[test]
fn producer_panic_is_an_error_not_a_hang() {
    let err = run_prefetched(
        5,
        2,
        |i| {
            if i == 2 {
                panic!("injected stage-one failure");
            }
            i
        },
        |_, _| {},
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected stage-one failure"), "{msg}");
}

#[test]
fn recovered_producer_panic_keeps_the_trace_bit_identical() {
    // The restartable pipeline's whole point: a producer that dies and is
    // restarted (PR 9 fault harness, within the retry budget) must leave
    // the training trace untouched — sequential and pipelined alike.
    for prefetch in [0, 2] {
        let clean = traces(&cfg(ModelKind::Gcn, "tango", None, prefetch));
        let mut faulted = cfg(ModelKind::Gcn, "tango", None, prefetch);
        faulted.fault.inject = true;
        // Global steps 2 and 7 = batch 2 of epochs 0 and 1 (5 batches/epoch).
        faulted.fault.producer_steps = vec![2, 7];
        let r = traces_report(&faulted);
        assert_eq!((r.losses, r.evals), clean, "prefetch {prefetch}");
        let f = r.fault.expect("injected run reports its fault ledger");
        assert_eq!(f.producer_panics, 2, "prefetch {prefetch}");
        assert_eq!(f.producer_restarts, 2, "prefetch {prefetch}");
    }
}

#[test]
fn empty_batch_list_and_tiny_epochs_are_noops_not_hangs() {
    // Zero batches (an empty seed sweep) with a nonzero depth.
    let stats = run_prefetched(0, 4, |_| unreachable!("no batches"), |_, _: ()| {}).unwrap();
    assert_eq!(stats.batches, 0);
    // One batch degenerates to the sequential path.
    let mut got = Vec::new();
    run_prefetched(1, 4, |i| i, |_, v| got.push(v)).unwrap();
    assert_eq!(got, vec![0]);
}
