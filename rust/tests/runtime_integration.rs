//! Integration tests over the AOT artifacts: every artifact in the manifest
//! loads, compiles and runs from Rust, and the numerics of the jax/Pallas
//! kernels agree with the Rust-native primitives.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use tango::graph::generators::random_features;
use tango::primitives::{gemm_f32, qgemm};
use tango::quant::{dequantize, quantize, Rounding};
use tango::runtime::{Runtime, Value};
use tango::tensor::Dense;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn quantize8_artifact_matches_rust_quantizer() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.get("quantize8").unwrap().clone();
    let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let x = random_features(m, k, 1);
    let out = rt.run("quantize8", &[Value::F32(x.clone())]).unwrap();
    assert_eq!(out.len(), 2);
    let q = match &out[0] {
        Value::I8(t) => t.clone(),
        other => panic!("expected i8 payload, got {other:?}"),
    };
    let scale = out[1].as_scalar_f32().unwrap();
    let rq = quantize(&x, 8, Rounding::Nearest);
    assert!((scale - rq.scale).abs() < 1e-6 * rq.scale, "{scale} vs {}", rq.scale);
    // Nearest rounding can differ by 1 ulp at exact .5 boundaries; demand
    // bit-identity elsewhere.
    let mut diffs = 0usize;
    for (a, b) in q.data().iter().zip(rq.data.data().iter()) {
        if a != b {
            diffs += 1;
            assert!((a - b).abs() <= 1, "{a} vs {b}");
        }
    }
    assert!(diffs < q.len() / 100, "{diffs} of {} differ", q.len());
}

#[test]
fn qgemm8_artifact_matches_rust_qgemm() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.get("qgemm8").unwrap().clone();
    let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[1];
    let a = random_features(m, k, 2);
    let b = random_features(k, n, 3);
    let out = rt.run("qgemm8", &[Value::F32(a.clone()), Value::F32(b.clone())]).unwrap();
    let got = out[0].as_f32().unwrap();
    let rust = qgemm(&a, &b, 8, Rounding::Nearest);
    // Same INT8 grid: both should land within one dequantized ULP of the
    // rust result, and close to the exact FP32 product.
    let exact = gemm_f32(&a, &b);
    let rel_jax = got.max_abs_diff(&exact) / exact.abs_max();
    let rel_rust = rust.out.max_abs_diff(&exact) / exact.abs_max();
    assert!(rel_jax < 0.05, "jax-kernel rel err {rel_jax}");
    assert!((rel_jax - rel_rust).abs() < 0.03, "jax {rel_jax} vs rust {rel_rust}");
}

#[test]
fn spmm_artifact_matches_manual_aggregation() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.get("spmm_f32").unwrap().clone();
    let (n, p) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let f = spec.inputs[2].shape[1];
    // Tiny deterministic graph: node v aggregates node (v+1) % n.
    let mut nbr = Dense::<i32>::zeros(&[n, p]);
    let mut wgt = Dense::<f32>::zeros(&[n, p]);
    for v in 0..n {
        nbr.set(v, 0, ((v + 1) % n) as i32);
        wgt.set(v, 0, 2.0);
    }
    let h = random_features(n, f, 4);
    let out = rt
        .run("spmm_f32", &[Value::I32(nbr), Value::F32(wgt), Value::F32(h.clone())])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for v in 0..n.min(50) {
        let u = (v + 1) % n;
        for j in 0..f {
            let want = 2.0 * h.at(u, j);
            assert!((got.at(v, j) - want).abs() < 1e-4, "v={v} j={j}");
        }
    }
}

#[test]
fn every_artifact_compiles() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt.names().iter().map(|s| s.to_string()).collect();
    assert!(names.len() >= 9, "expected >=9 artifacts, got {names:?}");
    for name in &names {
        rt.load(name).unwrap_or_else(|e| panic!("artifact {name} failed to compile: {e}"));
    }
}

#[test]
fn gcn_train_step_artifact_reduces_loss() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.get("gcn_train_step").unwrap().clone();
    let (n, p, f, h, c) =
        (spec.sizes["n"], spec.sizes["p"], spec.sizes["f"], spec.sizes["h"], spec.sizes["c"]);
    // Learnable planted problem with a symmetric padded graph.
    let mut rng = tango::quant::rng::Xoshiro256pp::new(9);
    let labels: Vec<u32> = (0..n).map(|_| (rng.next_u64() % c as u64) as u32).collect();
    let features = tango::graph::generators::features_for_labels(&labels, f, c, 0.5, 10);
    let mut onehot = Dense::<f32>::zeros(&[n, c]);
    for (v, &l) in labels.iter().enumerate() {
        onehot.set(v, l as usize, 1.0);
    }
    let mask = Dense::from_vec(&[n], vec![1.0f32; n]);
    let (mut nbr, mut wgt) = (Dense::<i32>::zeros(&[n, p]), Dense::<f32>::zeros(&[n, p]));
    let mut fill = vec![1usize; n];
    for v in 0..n {
        nbr.set(v, 0, v as i32);
        wgt.set(v, 0, 1.0);
    }
    for _ in 0..n * p {
        let u = (rng.next_u64() % n as u64) as usize;
        let v = (rng.next_u64() % n as u64) as usize;
        if u == v || fill[u] >= p || fill[v] >= p {
            continue;
        }
        nbr.set(u, fill[u], v as i32);
        wgt.set(u, fill[u], 1.0);
        fill[u] += 1;
        nbr.set(v, fill[v], u as i32);
        wgt.set(v, fill[v], 1.0);
        fill[v] += 1;
    }
    // Row-normalise.
    for v in 0..n {
        let s: f32 = wgt.row(v).iter().sum();
        for x in wgt.row_mut(v) {
            *x /= s;
        }
    }
    let mut w1 = random_features(f, h, 11);
    w1.scale(0.25);
    let mut w2 = random_features(h, c, 12);
    w2.scale(0.25);
    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..10 {
        let out = rt
            .run(
                "gcn_train_step",
                &[
                    Value::F32(features.clone()),
                    Value::F32(onehot.clone()),
                    Value::F32(mask.clone()),
                    Value::F32(w1.clone()),
                    Value::F32(w2.clone()),
                    Value::I32(nbr.clone()),
                    Value::F32(wgt.clone()),
                ],
            )
            .unwrap();
        let loss = out[0].as_scalar_f32().unwrap();
        w1 = out[1].as_f32().unwrap().clone();
        w2 = out[2].as_f32().unwrap().clone();
        first.get_or_insert(loss);
        last = loss;
        assert!(loss.is_finite());
    }
    assert!(last < first.unwrap(), "loss {} -> {last} did not decrease", first.unwrap());
    // Sanity: dequantize helper available for symmetric checks elsewhere.
    let q = quantize(&w1, 8, Rounding::Nearest);
    assert_eq!(dequantize(&q).shape(), w1.shape());
}
