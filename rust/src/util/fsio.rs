//! Crash-safe file writes (audit rule W1).
//!
//! Every artifact this repo emits — metrics JSON, bench JSON, checkpoints —
//! goes through [`write_atomic`]: the contents land in a `{path}.tmp`
//! sibling first and are renamed over the destination only once fully
//! written. A crash (or an injected fault) mid-write leaves the previous
//! file intact, never a truncated artifact; rename within one directory is
//! atomic on every platform the toolchain targets.

/// Write `contents` to `path` atomically: write `{path}.tmp`, then rename
/// it over `path`. Errors carry both paths so the failure is actionable.
pub fn write_atomic(path: &str, contents: &str) -> crate::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, contents)
        .map_err(|e| anyhow::anyhow!("writing temporary file {tmp}: {e}"))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| anyhow::anyhow!("renaming {tmp} over {path}: {e}"))?;
    Ok(())
}

/// Append one line to a line-oriented file (e.g. a `.jsonl` history) as an
/// atomic read-modify-write: the existing contents are read (absent file =
/// empty), the line is appended with a trailing newline, and the whole
/// file is rewritten through [`write_atomic`] — so a crash mid-append can
/// lose the new line but never corrupt the lines already recorded.
pub fn append_line_atomic(path: &str, line: &str) -> crate::Result<()> {
    let mut contents = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
        Err(e) => return Err(anyhow::anyhow!("reading history file {path}: {e}")),
    };
    if !contents.is_empty() && !contents.ends_with('\n') {
        contents.push('\n');
    }
    contents.push_str(line);
    contents.push('\n');
    write_atomic(path, &contents)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_land_and_leave_no_tmp_behind() {
        let dir = std::env::temp_dir();
        let path = dir.join("tango_fsio_test.json");
        let path = path.to_str().unwrap();
        write_atomic(path, "{\"a\":1}").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "{\"a\":1}");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        // Overwrite replaces the old contents wholesale.
        write_atomic(path, "{\"a\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "{\"a\":2}");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn append_line_accumulates_without_clobbering() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("tango_fsio_hist_{}.jsonl", std::process::id()));
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        append_line_atomic(path, "{\"row\":1}").unwrap();
        append_line_atomic(path, "{\"row\":2}").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "{\"row\":1}\n{\"row\":2}\n");
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn unwritable_destination_is_an_error_naming_the_path() {
        let err = write_atomic("/nonexistent_dir_tango/x.json", "{}").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("/nonexistent_dir_tango/x.json.tmp"), "{msg}");
    }
}
