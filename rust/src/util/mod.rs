//! Infrastructure substrates that would normally be external crates.
//!
//! The build environment is fully offline, so the crate implements its own
//! minimal versions of the usual framework dependencies:
//!
//! - [`par`] — a scoped-thread data-parallel layer (the rayon stand-in) the
//!   hot primitives are built on;
//! - [`prop`] — a tiny property-based testing helper (the proptest
//!   stand-in) driven by the same xoshiro256++ generator the quantizer uses;
//! - [`cli`] — a no-dependency command-line argument parser;
//! - [`json`] — a minimal JSON writer/parser for the artifact manifest;
//! - [`fsio`] — crash-safe atomic file writes (tmp + rename) every emitted
//!   artifact and checkpoint goes through (audit rule W1).

pub mod cli;
pub mod fsio;
pub mod json;
pub mod par;
pub mod prop;
