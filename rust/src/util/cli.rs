//! No-dependency command-line parsing (the offline clap stand-in).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` shapes the `tango` binary and the examples need.

use std::collections::HashMap;

/// Parsed arguments: positionals in order, flags by name (without `--`).
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` pairs; bare `--key` maps to "true".
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    if let Some(v) = iter.next() {
                        out.flags.insert(stripped.to_string(), v);
                    }
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Flag as string with default.
    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(|s| s.as_str()).unwrap_or(default)
    }

    /// Flag parsed to any `FromStr` type, with default. Panics with a clear
    /// message on malformed values (CLI boundary, so panicking is the UX).
    pub fn get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.flags.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|e| panic!("--{key}={v}: {e:?}")),
        }
    }

    /// Flag parsed to any `FromStr` type, with default; malformed values
    /// become an error instead of a panic, so binaries can report them
    /// through their normal `Result` exit path (audit rule P1).
    pub fn try_get_as<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Debug,
    {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}={v}: {e:?}")),
        }
    }

    /// Boolean flag: present (or "true"/"1") means true.
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["train", "--dataset", "Pubmed", "--epochs=30", "--quantize"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("dataset", ""), "Pubmed");
        assert_eq!(a.get_as::<usize>("epochs", 0), 30);
        assert!(a.get_bool("quantize"));
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get("dataset", "tiny"), "tiny");
        assert_eq!(a.get_as::<u64>("seed", 7), 7);
    }

    #[test]
    fn try_get_as_reports_malformed_values() {
        let a = parse(&["train", "--epochs", "ten"]);
        assert!(a.try_get_as::<usize>("epochs", 1).is_err());
        assert_eq!(a.try_get_as::<usize>("missing", 4), Ok(4));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--verbose", "--level", "3"]);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_as::<i32>("level", 0), 3);
    }
}
