//! Scoped-thread data parallelism (the offline rayon stand-in).
//!
//! The primitives need exactly two shapes of parallelism:
//!
//! - [`for_each_chunk`]: split a `&mut [T]` into fixed-size chunks (one row
//!   of an output matrix each) and process them on a pool of scoped threads
//!   with dynamic batch claiming — graph rows have highly skewed degrees, so
//!   static partitioning would straggle;
//! - [`map_range`]: compute an indexed map `0..n -> Vec<O>` in parallel,
//!   preserving order (used for per-node segment reductions and the panel
//!   abs-max collection in the quantized GEMM).
//!
//! Thread count defaults to `available_parallelism`, overridable with
//! `TANGO_THREADS` (benches pin it for stable measurements).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("TANGO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// How many chunk-batches to slice the work into per thread: small enough
/// to amortise claiming, large enough to balance skewed rows.
const BATCHES_PER_THREAD: usize = 16;

/// Process `data` in `chunk_len`-sized mutable chunks, in parallel.
/// `f(chunk_index, chunk)` is called exactly once per chunk, where
/// `chunk_index` counts chunks from the start of `data`. The final chunk may
/// be shorter. Falls back to sequential for tiny inputs or 1 thread.
pub fn for_each_chunk<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks.max(1));
    if threads <= 1 || n_chunks <= 4 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    // Batch chunks so claiming is cheap: each claim hands a contiguous run
    // of `batch` chunks to one worker.
    let batch = n_chunks.div_ceil(threads * BATCHES_PER_THREAD).max(1);
    let slots: Vec<Mutex<Option<&mut [T]>>> =
        data.chunks_mut(batch * chunk_len).map(|c| Mutex::new(Some(c))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let b = cursor.fetch_add(1, Ordering::Relaxed);
                if b >= slots.len() {
                    break;
                }
                let slab = slots[b]
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .take()
                    .expect("batch claimed twice");
                for (i, chunk) in slab.chunks_mut(chunk_len).enumerate() {
                    f(b * batch + i, chunk);
                }
            });
        }
    });
}

/// Parallel indexed map over `0..n`, preserving order.
pub fn map_range<O: Send, F>(n: usize, f: F) -> Vec<O>
where
    F: Fn(usize) -> O + Sync,
{
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 4 {
        return (0..n).map(f).collect();
    }
    let batch = n.div_ceil(threads * BATCHES_PER_THREAD).max(1);
    let n_batches = n.div_ceil(batch);
    let slots: Vec<Mutex<Option<Vec<O>>>> = (0..n_batches).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let b = cursor.fetch_add(1, Ordering::Relaxed);
                if b >= n_batches {
                    break;
                }
                let lo = b * batch;
                let hi = (lo + batch).min(n);
                let vals: Vec<O> = (lo..hi).map(&f).collect();
                *slots[b].lock().unwrap_or_else(|e| e.into_inner()) = Some(vals);
            });
        }
    });
    let mut out = Vec::with_capacity(n);
    for slot in slots {
        out.extend(slot.into_inner().unwrap_or_else(|e| e.into_inner()).expect("batch unfilled"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_each_processed_once() {
        let mut data = vec![0u32; 1003];
        for_each_chunk(&mut data, 10, |i, chunk| {
            for v in chunk.iter_mut() {
                *v += i as u32 + 1;
            }
        });
        assert_eq!(data[0], 1); // chunk 0
        assert_eq!(data[15], 2); // chunk 1
        assert_eq!(data[1002], 101); // chunk 100 (tail, len 3)
        assert!(data.iter().all(|&v| v != 0));
    }

    #[test]
    fn chunk_indices_are_global() {
        let mut data = vec![0usize; 997];
        for_each_chunk(&mut data, 7, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i;
            }
        });
        for (pos, &v) in data.iter().enumerate() {
            assert_eq!(v, pos / 7, "pos {pos}");
        }
    }

    #[test]
    fn map_range_preserves_order() {
        let out = map_range(1000, |i| i * i);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn empty_inputs_are_fine() {
        let mut data: Vec<u8> = vec![];
        for_each_chunk(&mut data, 4, |_, _| panic!("no chunks expected"));
        let out: Vec<u8> = map_range(0, |_| 1u8);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_tail_chunk() {
        let mut data = vec![1u8; 7];
        let sizes = Mutex::new(Vec::new());
        for_each_chunk(&mut data, 3, |_, c| sizes.lock().unwrap().push(c.len()));
        let mut s = sizes.into_inner().unwrap();
        s.sort_unstable();
        assert_eq!(s, vec![1, 3, 3]);
    }
}
