//! Minimal JSON reader/writer (the offline serde_json stand-in).
//!
//! Only the subset the artifact manifest needs: objects, arrays, strings,
//! numbers, booleans, null. No escapes beyond `\" \\ \n \t \/ \r`, which is
//! all `aot.py` emits.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number as f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number as usize, if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| n.fract() == 0.0 && *n >= 0.0).map(|n| n as usize)
    }

    /// Array items, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {other:?} at byte {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c == b'-' || c == b'+' || c == b'.' || c == b'e' || c == b'E' || c.is_ascii_digit())
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| anyhow::anyhow!("bad escape"))?;
                    out.push(match esc {
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        other => anyhow::bail!("unsupported escape \\{}", other as char),
                    });
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']', got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}', got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_shape() {
        let text = r#"{"artifacts":[{"name":"gcn_fwd","path":"gcn_fwd.hlo.txt","inputs":[[4,8],[8,3]],"dtype":"f32"}],"version":1}"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "gcn_fwd");
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        // reparse of to_string is stable
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("3.5").unwrap().as_f64(), Some(3.5));
        assert_eq!(Json::parse("-12").unwrap().as_f64(), Some(-12.0));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\"c""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"{"a":[1,[2,{"b":null}]],"c":true}"#).unwrap();
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[1].as_arr().unwrap()[1]
                .get("b")
                .unwrap(),
            &Json::Null
        );
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
    }
}
