//! Minimal property-based testing helper (the offline proptest stand-in).
//!
//! Drives randomized invariant checks from the same xoshiro256++ generator
//! the quantizer uses. Each property runs `cases` times with derived seeds;
//! on failure the failing seed is reported so the case can be replayed.
//!
//! ```
//! use tango::util::prop::{check, Gen};
//! check("abs is non-negative", 64, |g| {
//!     let x = g.f32_in(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::quant::rng::Xoshiro256pp;

/// A source of random test inputs.
pub struct Gen {
    rng: Xoshiro256pp,
    /// The seed this case was started from (for failure replay).
    pub seed: u64,
}

impl Gen {
    /// New generator for one case.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Xoshiro256pp::new(seed), seed }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Uniform u64.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f32) -> bool {
        self.rng.next_f32() < p
    }

    /// A vec of f32s in `[lo, hi)`.
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A random small graph as (num_nodes, src, dst) with at least 1 node.
    pub fn graph(&mut self, max_nodes: usize, max_edges: usize) -> (usize, Vec<u32>, Vec<u32>) {
        let n = self.usize_in(1, max_nodes);
        let m = self.usize_in(0, max_edges);
        let src = (0..m).map(|_| self.usize_in(0, n - 1) as u32).collect();
        let dst = (0..m).map(|_| self.usize_in(0, n - 1) as u32).collect();
        (n, src, dst)
    }
}

/// Run `body` for `cases` derived seeds. Panics (with the seed) on failure.
pub fn check(name: &str, cases: u64, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = 0xDA7A_5EED ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            body(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("sum symmetric", 32, |g| {
            let a = g.f32_in(-5.0, 5.0);
            let b = g.f32_in(-5.0, 5.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failures() {
        check("always fails", 4, |_| panic!("boom"));
    }

    #[test]
    fn graph_generator_is_well_formed() {
        check("graph bounds", 64, |g| {
            let (n, src, dst) = g.graph(20, 50);
            assert!(n >= 1);
            assert_eq!(src.len(), dst.len());
            assert!(src.iter().all(|&v| (v as usize) < n));
            assert!(dst.iter().all(|&v| (v as usize) < n));
        });
    }

    #[test]
    fn usize_in_inclusive_bounds() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 5);
            assert!((3..=5).contains(&v));
        }
    }
}
