//! SPMM variants (paper §3.3): the DGL-shaped three-matrix kernel, the
//! cuSPARSE-shaped two-matrix kernel, the incidence-matrix reformulation,
//! the per-head split, and the quantized edge-weighted aggregation.
//!
//! Shapes follow the paper's GAT walkthrough (Fig. 1): node features are
//! `[N, H*D]` (H heads of width D), edge features are `[E, H]` (one scalar
//! per head per edge).

use crate::graph::{Csr, Incidence};
use crate::quant::QTensor;
use crate::tensor::Dense;
use crate::util::par;

/// Three-matrix SPMM, DGL-shaped: `out[v] = Σ_{e=(u→v)} α[e,h] · H[u,(h,d)]`.
///
/// This is forward step 5 of Fig. 1a (and, on the reversed CSR, backward
/// step 4). `alpha: [E, H]`, `h: [N, H*D]` → `[N, H*D]`.
pub fn spmm_edge_weighted(csr: &Csr, alpha: &Dense<f32>, h: &Dense<f32>, heads: usize) -> Dense<f32> {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_SPMM_EDGE_WEIGHTED);
    let n = csr.num_nodes;
    let hd = h.cols();
    assert_eq!(alpha.cols(), heads, "alpha must be [E, heads]");
    assert_eq!(alpha.rows(), csr.num_edges);
    assert_eq!(hd % heads, 0, "feature dim {hd} not divisible by heads {heads}");
    let d = hd / heads;
    let mut out = Dense::zeros(&[n, hd]);
    par::for_each_chunk(out.data_mut(), hd, |v, orow| {
        let (srcs, eids) = csr.row(v);
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            let hrow = h.row(u as usize);
            let arow = alpha.row(e as usize);
            for hh in 0..heads {
                let a = arow[hh];
                let base = hh * d;
                for dd in 0..d {
                    orow[base + dd] += a * hrow[base + dd];
                }
            }
        }
    });
    out
}

/// Quantized edge-weighted SPMM: both the edge weights and the node
/// features arrive as INT8 tensors (quantized once, sequentially, by a
/// dedicated pass — paper §3.3 argues against on-the-fly quantization for
/// sparse primitives). The random accesses then touch 1-byte instead of
/// 4-byte elements; accumulation is i32; a single fused `s_α·s_h` multiply
/// dequantizes the output.
pub fn qspmm_edge_weighted(csr: &Csr, qalpha: &QTensor, qh: &QTensor, heads: usize) -> Dense<f32> {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_QSPMM_EDGE_WEIGHTED);
    let n = csr.num_nodes;
    let hd = qh.data.cols();
    assert_eq!(qalpha.data.cols(), heads, "alpha must be [E, heads]");
    assert_eq!(qalpha.data.rows(), csr.num_edges);
    assert_eq!(hd % heads, 0, "feature dim {hd} not divisible by heads {heads}");
    let d = hd / heads;
    let deq = qalpha.scale * qh.scale;
    let mut out = Dense::zeros(&[n, hd]);
    par::for_each_chunk(out.data_mut(), hd, |v, orow| {
        let (srcs, eids) = csr.row(v);
        let mut acc = vec![0i32; hd];
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            let hrow = qh.data.row(u as usize);
            let arow = qalpha.data.row(e as usize);
            for hh in 0..heads {
                let a = arow[hh] as i32;
                let base = hh * d;
                for dd in 0..d {
                    acc[base + dd] += a * hrow[base + dd] as i32;
                }
            }
        }
        for (o, &v) in orow.iter_mut().zip(acc.iter()) {
            *o = v as f32 * deq;
        }
    });
    out
}

/// Two-matrix CSR SPMM, cuSPARSE-shaped: `out = A · X` where `A`'s stored
/// values are `values[edge_id]` (a single scalar per edge, no heads).
pub fn spmm_csr_values(csr: &Csr, values: &[f32], x: &Dense<f32>) -> Dense<f32> {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_SPMM_CSR);
    assert_eq!(values.len(), csr.num_edges);
    let n = csr.num_nodes;
    let f = x.cols();
    let mut out = Dense::zeros(&[n, f]);
    par::for_each_chunk(out.data_mut(), f, |v, orow| {
        let (srcs, eids) = csr.row(v);
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            let w = values[e as usize];
            let xrow = x.row(u as usize);
            for j in 0..f {
                orow[j] += w * xrow[j];
            }
        }
    });
    out
}

/// The paper's **per-head split** (Fig. 6a): a three-matrix SPMM with `H`
/// heads becomes `H` two-matrix cuSPARSE SPMMs, one per head. Returns the
/// same `[N, H*D]` result as [`spmm_edge_weighted`] — the adaptive policy
/// (see `coordinator::adaptive`) decides which to launch.
pub fn spmm_per_head(csr: &Csr, alpha: &Dense<f32>, h: &Dense<f32>, heads: usize) -> Dense<f32> {
    let n = csr.num_nodes;
    let hd = h.cols();
    let d = hd / heads;
    let mut out = Dense::zeros(&[n, hd]);
    for hh in 0..heads {
        // Slice head hh of alpha and h into dense temporaries (the kernel
        // launch boundary of the cuSPARSE transform).
        let values: Vec<f32> = (0..csr.num_edges).map(|e| alpha.at(e, hh)).collect();
        let mut xh = Dense::zeros(&[n, d]);
        for v in 0..n {
            xh.row_mut(v).copy_from_slice(&h.row(v)[hh * d..(hh + 1) * d]);
        }
        let oh = spmm_csr_values(csr, &values, &xh);
        for v in 0..n {
            out.row_mut(v)[hh * d..(hh + 1) * d].copy_from_slice(oh.row(v));
        }
    }
    out
}

/// DGL-shaped **three-matrix** edge aggregation (paper Fig. 5a): computes
/// `out[v] = Σ_{e incident to v} edge_feat[e]` by multiplying graph ×
/// edge-features × an all-ones node-feature matrix. The redundant ones
/// matrix is real and really accessed — this is the baseline whose waste
/// the incidence formulation removes.
pub fn spmm_edge_aggregate_3mat(csr: &Csr, edge_feat: &Dense<f32>) -> Dense<f32> {
    let n = csr.num_nodes;
    let f = edge_feat.cols();
    // The all-"1" node feature matrix DGL allocates (paper Fig. 5a).
    let ones = Dense::from_vec(&[n, f], vec![1.0f32; n * f]);
    let mut out = Dense::zeros(&[n, f]);
    par::for_each_chunk(out.data_mut(), f, |v, orow| {
        let (srcs, eids) = csr.row(v);
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            let erow = edge_feat.row(e as usize);
            let onerow = ones.row(u as usize); // the wasted random access
            for j in 0..f {
                orow[j] += erow[j] * onerow[j];
            }
        }
    });
    out
}

/// **Incidence-matrix SPMM** (paper Fig. 5b): the same edge aggregation as
/// a two-matrix product `incidence × edge_feat`. A node's incident edge ids
/// are contiguous, so the walk is near-sequential over `edge_feat` once the
/// edge ids were grouped — the Table 2 memory-throughput win.
pub fn incidence_spmm(inc: &Incidence, edge_feat: &Dense<f32>) -> Dense<f32> {
    assert_eq!(edge_feat.rows(), inc.num_edges);
    let f = edge_feat.cols();
    let mut out = Dense::zeros(&[inc.num_nodes, f]);
    par::for_each_chunk(out.data_mut(), f, |v, orow| {
        for &e in inc.row(v) {
            let erow = edge_feat.row(e as usize);
            for j in 0..f {
                orow[j] += erow[j];
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, random_features};
    use crate::graph::Coo;
    use crate::quant::{quantize, Rounding};

    fn toy() -> (Coo, Csr) {
        // Paper Fig. 1: e0: 1->0, e1: 3->1, e2: 1->2, e3: 0->3, e4: 2->3
        let coo = Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3]);
        let csr = Csr::from_coo(&coo);
        (coo, csr)
    }

    #[test]
    fn edge_weighted_matches_paper_example() {
        // Paper step 5: H[v3] = α[e3]·H'[v0] + α[e4]·H'[v2].
        let (_, csr) = toy();
        let heads = 2;
        // H': [4, 2*2] rows v0..v3
        let h = Dense::from_vec(
            &[4, 4],
            vec![
                0.59, 0.73, 0.51, -0.65, // v0
                0.76, 0.73, 0.79, -1.07, // v1
                1.08, 1.19, -0.04, 0.57, // v2
                0.28, 0.05, -0.22, 0.30, // v3
            ],
        );
        let alpha = Dense::from_vec(
            &[5, 2],
            vec![
                1.0, 1.0, // e0
                1.0, 1.0, // e1
                1.0, 1.0, // e2
                0.63, 0.46, // e3
                0.37, 0.54, // e4
            ],
        );
        let out = spmm_edge_weighted(&csr, &alpha, &h, heads);
        // v3 head0: 0.63*[0.59,0.73] + 0.37*[1.08,1.19] = [0.7713, 0.9002]
        assert!((out.at(3, 0) - (0.63 * 0.59 + 0.37 * 1.08)).abs() < 1e-5);
        assert!((out.at(3, 1) - (0.63 * 0.73 + 0.37 * 1.19)).abs() < 1e-5);
        // v3 head1: 0.46*[0.51,-0.65] + 0.54*[-0.04,0.57]
        assert!((out.at(3, 2) - (0.46 * 0.51 + 0.54 * -0.04)).abs() < 1e-5);
        assert!((out.at(3, 3) - (0.46 * -0.65 + 0.54 * 0.57)).abs() < 1e-5);
    }

    #[test]
    fn per_head_split_equals_fused() {
        let g = erdos_renyi(60, 400, 1);
        let csr = Csr::from_coo(&g);
        let heads = 4;
        let alpha = random_features(400, heads, 2);
        let h = random_features(60, heads * 8, 3);
        let fused = spmm_edge_weighted(&csr, &alpha, &h, heads);
        let split = spmm_per_head(&csr, &alpha, &h, heads);
        assert!(fused.max_abs_diff(&split) < 1e-4);
    }

    #[test]
    fn incidence_equals_3mat() {
        let g = erdos_renyi(50, 300, 4);
        let csr = Csr::from_coo(&g);
        let inc = Incidence::from_csr(&csr);
        let ef = random_features(300, 8, 5);
        let a = spmm_edge_aggregate_3mat(&csr, &ef);
        let b = incidence_spmm(&inc, &ef);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn incidence_matches_paper_gradient_example() {
        // ∂v3 = ∂e3 + ∂e4 (paper Fig. 5).
        let (coo, _) = toy();
        let inc = Incidence::in_edges(&coo);
        let ef = Dense::from_vec(&[5, 1], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let out = incidence_spmm(&inc, &ef);
        assert_eq!(out.at(3, 0), 9.0); // e3 + e4 = 4 + 5
        assert_eq!(out.at(0, 0), 1.0); // e0
    }

    #[test]
    fn quantized_spmm_close_to_fp32() {
        let g = erdos_renyi(80, 600, 6);
        let csr = Csr::from_coo(&g);
        let heads = 2;
        let alpha = random_features(600, heads, 7);
        let h = random_features(80, heads * 16, 8);
        let exact = spmm_edge_weighted(&csr, &alpha, &h, heads);
        let qa = quantize(&alpha, 8, Rounding::Nearest);
        let qh = quantize(&h, 8, Rounding::Nearest);
        let approx = qspmm_edge_weighted(&csr, &qa, &qh, heads);
        let rel = approx.max_abs_diff(&exact) / exact.abs_max().max(1e-6);
        assert!(rel < 0.1, "rel {rel}");
    }

    #[test]
    fn csr_values_matches_edge_weighted_single_head() {
        let g = erdos_renyi(40, 200, 9);
        let csr = Csr::from_coo(&g);
        let alpha = random_features(200, 1, 10);
        let h = random_features(40, 8, 11);
        let a = spmm_edge_weighted(&csr, &alpha, &h, 1);
        let values: Vec<f32> = alpha.data().to_vec();
        let b = spmm_csr_values(&csr, &values, &h);
        assert!(a.max_abs_diff(&b) < 1e-5);
    }

    #[test]
    #[should_panic(expected = "alpha must be [E, heads]")]
    fn quantized_spmm_validates_alpha_head_count() {
        // Regression: the quantized kernel used to skip the shape checks its
        // FP32 twin performs and silently computed garbage on a 2-head alpha
        // passed with heads = 1.
        let g = erdos_renyi(12, 40, 13);
        let csr = Csr::from_coo(&g);
        let qa = quantize(&random_features(40, 2, 14), 8, Rounding::Nearest);
        let qh = quantize(&random_features(12, 8, 15), 8, Rounding::Nearest);
        let _ = qspmm_edge_weighted(&csr, &qa, &qh, 1);
    }

    #[test]
    #[should_panic(expected = "not divisible by heads")]
    fn quantized_spmm_validates_head_divisibility() {
        let g = erdos_renyi(12, 40, 16);
        let csr = Csr::from_coo(&g);
        let qa = quantize(&random_features(40, 3, 17), 8, Rounding::Nearest);
        // 8 features are not divisible into 3 heads.
        let qh = quantize(&random_features(12, 8, 18), 8, Rounding::Nearest);
        let _ = qspmm_edge_weighted(&csr, &qa, &qh, 3);
    }

    #[test]
    fn isolated_nodes_get_zero_rows() {
        // Node 2 has no in-edges.
        let coo = Coo::new(3, vec![0], vec![1]);
        let csr = Csr::from_coo(&coo);
        let alpha = Dense::from_vec(&[1, 1], vec![1.0]);
        let h = random_features(3, 4, 12);
        let out = spmm_edge_weighted(&csr, &alpha, &h, 1);
        assert!(out.row(2).iter().all(|&v| v == 0.0));
        assert!(out.row(0).iter().all(|&v| v == 0.0));
    }
}
