//! SpMV and the many-SpMV transform (paper Fig. 6b / Fig. 14).
//!
//! When the edge-feature dimension (number of heads) is large, the paper
//! splits a three-matrix SPMM into one sparse matrix–vector product per
//! (head, feature) column so each launch is a plain cuSPARSE SpMV. The win
//! shrinks as the kernel count grows (launch overhead) — the crossover that
//! Fig. 14 plots and the adaptive policy keys on.

use crate::graph::Csr;
use crate::tensor::Dense;

/// `y = A · x` where `A`'s stored value for edge `e` is `values[e]`.
pub fn spmv_csr(csr: &Csr, values: &[f32], x: &[f32]) -> Vec<f32> {
    assert_eq!(values.len(), csr.num_edges);
    assert_eq!(x.len(), csr.num_nodes);
    let mut y = vec![0.0f32; csr.num_nodes];
    for v in 0..csr.num_nodes {
        let (srcs, eids) = csr.row(v);
        let mut acc = 0.0f32;
        for (&u, &e) in srcs.iter().zip(eids.iter()) {
            acc += values[e as usize] * x[u as usize];
        }
        y[v] = acc;
    }
    y
}

/// The many-SpMV transform: computes the same `[N, H*D]` result as
/// `spmm_edge_weighted` by launching one SpMV per (head, column) pair —
/// `H*D` kernels total. Returns (result, kernel_count) so callers (and the
/// adaptive policy) can account the launch overhead.
pub fn spmm_via_spmvs(
    csr: &Csr,
    alpha: &Dense<f32>,
    h: &Dense<f32>,
    heads: usize,
) -> (Dense<f32>, usize) {
    let n = csr.num_nodes;
    let hd = h.cols();
    let d = hd / heads;
    let mut out = Dense::zeros(&[n, hd]);
    let mut kernels = 0usize;
    for hh in 0..heads {
        let values: Vec<f32> = (0..csr.num_edges).map(|e| alpha.at(e, hh)).collect();
        for dd in 0..d {
            let col = hh * d + dd;
            let x: Vec<f32> = (0..n).map(|v| h.at(v, col)).collect();
            let y = spmv_csr(csr, &values, &x);
            kernels += 1;
            for v in 0..n {
                out.set(v, col, y[v]);
            }
        }
    }
    (out, kernels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, random_features};
    use crate::graph::Coo;
    use crate::primitives::spmm::spmm_edge_weighted;

    #[test]
    fn spmv_small_example() {
        // e0: 1->0 w=2, e1: 0->1 w=3
        let coo = Coo::new(2, vec![1, 0], vec![0, 1]);
        let csr = Csr::from_coo(&coo);
        let y = spmv_csr(&csr, &[2.0, 3.0], &[10.0, 20.0]);
        assert_eq!(y, vec![40.0, 30.0]);
    }

    #[test]
    fn many_spmv_equals_fused_spmm() {
        let g = erdos_renyi(40, 250, 1);
        let csr = Csr::from_coo(&g);
        let heads = 3;
        let alpha = random_features(250, heads, 2);
        let h = random_features(40, heads * 4, 3);
        let fused = spmm_edge_weighted(&csr, &alpha, &h, heads);
        let (split, kernels) = spmm_via_spmvs(&csr, &alpha, &h, heads);
        assert_eq!(kernels, heads * 4);
        assert!(fused.max_abs_diff(&split) < 1e-4);
    }

    #[test]
    fn kernel_count_scales_with_dims() {
        let g = erdos_renyi(10, 30, 4);
        let csr = Csr::from_coo(&g);
        let alpha = random_features(30, 2, 5);
        let h = random_features(10, 2 * 6, 6);
        let (_, kernels) = spmm_via_spmvs(&csr, &alpha, &h, 2);
        assert_eq!(kernels, 12);
    }
}
