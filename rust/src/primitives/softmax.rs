//! Edge softmax and LeakyReLU, kept in **full precision** per the paper's
//! accuracy rule (§3.2, Eq. 7/8): the exponential amplifies any quantization
//! error on its inputs by `exp(e0 - e1)`, so the layer feeding Softmax — and
//! the softmax itself — stay FP32. (The "Test1" ablation of Fig. 7 is what
//! happens when this rule is violated; see `repro::fig7`.)

use crate::graph::Csr;
use crate::tensor::Dense;
use crate::util::par;

/// Per-destination softmax over in-edge logits (Fig. 1a step 4).
///
/// `logits: [E, H]` grouped by the CSR's destination rows → `α: [E, H]`,
/// numerically stabilised by the per-segment max.
pub fn edge_softmax(csr: &Csr, logits: &Dense<f32>) -> Dense<f32> {
    let heads = logits.cols();
    let mut out = Dense::zeros(&[logits.rows(), heads]);
    // Safety: rows of `out` touched by different v are disjoint because each
    // edge id appears exactly once in the CSR. We collect per-node edge sets
    // first, then scatter sequentially per node (parallel over nodes via
    // unsafe shared pointer is avoidable: compute per-node then write).
    let results: Vec<(usize, Vec<f32>)> = par::map_range(csr.num_nodes, |v| {
            let (_, eids) = csr.row(v);
            let mut vals = vec![0.0f32; eids.len() * heads];
            for h in 0..heads {
                let mut maxv = f32::NEG_INFINITY;
                for &e in eids {
                    maxv = maxv.max(logits.at(e as usize, h));
                }
                let mut denom = 0.0f32;
                for (k, &e) in eids.iter().enumerate() {
                    let x = (logits.at(e as usize, h) - maxv).exp();
                    vals[k * heads + h] = x;
                    denom += x;
                }
                if denom > 0.0 {
                    for k in 0..eids.len() {
                        vals[k * heads + h] /= denom;
                    }
                }
            }
            (v, vals)
        });
    for (v, vals) in results {
        let (_, eids) = csr.row(v);
        for (k, &e) in eids.iter().enumerate() {
            out.row_mut(e as usize).copy_from_slice(&vals[k * heads..(k + 1) * heads]);
        }
    }
    out
}

/// Backward of [`edge_softmax`]: given `α` and `∂α`, returns `∂logits`.
///
/// Per segment (destination node, head): `∂x_i = α_i (∂α_i - Σ_j α_j ∂α_j)`.
pub fn edge_softmax_backward(csr: &Csr, alpha: &Dense<f32>, grad_alpha: &Dense<f32>) -> Dense<f32> {
    let heads = alpha.cols();
    let mut out = Dense::zeros(&[alpha.rows(), heads]);
    let results: Vec<(usize, Vec<f32>)> = par::map_range(csr.num_nodes, |v| {
            let (_, eids) = csr.row(v);
            let mut vals = vec![0.0f32; eids.len() * heads];
            for h in 0..heads {
                let mut dot = 0.0f32;
                for &e in eids {
                    dot += alpha.at(e as usize, h) * grad_alpha.at(e as usize, h);
                }
                for (k, &e) in eids.iter().enumerate() {
                    let a = alpha.at(e as usize, h);
                    let g = grad_alpha.at(e as usize, h);
                    vals[k * heads + h] = a * (g - dot);
                }
            }
            (v, vals)
        });
    for (v, vals) in results {
        let (_, eids) = csr.row(v);
        for (k, &e) in eids.iter().enumerate() {
            out.row_mut(e as usize).copy_from_slice(&vals[k * heads..(k + 1) * heads]);
        }
    }
    out
}

/// Elementwise LeakyReLU (paper uses it on attention logits, Fig. 1a step 3).
pub fn leaky_relu(x: &Dense<f32>, slope: f32) -> Dense<f32> {
    x.map(|v| if v >= 0.0 { v } else { slope * v })
}

/// Backward of LeakyReLU: `∂x = ∂y · (x >= 0 ? 1 : slope)`.
pub fn leaky_relu_backward(x: &Dense<f32>, grad_y: &Dense<f32>, slope: f32) -> Dense<f32> {
    assert_eq!(x.shape(), grad_y.shape());
    let mut out = grad_y.clone();
    for (o, &xi) in out.data_mut().iter_mut().zip(x.data().iter()) {
        if xi < 0.0 {
            *o *= slope;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, random_features};
    use crate::graph::Coo;

    fn toy_csr() -> Csr {
        Csr::from_coo(&Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3]))
    }

    #[test]
    fn softmax_matches_paper_attention_scores() {
        // Paper step 4: v3's in-edges e3, e4 with logits [1.40, 0] and
        // [0.86, 0.14] → α[e3] = [0.63, 0.46], α[e4] = [0.37, 0.54].
        let csr = toy_csr();
        let logits = Dense::from_vec(
            &[5, 2],
            vec![
                0.0, 0.0, // e0 (sole in-edge of v0)
                0.0, 0.0, // e1
                0.0, 0.0, // e2
                1.40, 0.0, // e3
                0.86, 0.14, // e4
            ],
        );
        let a = edge_softmax(&csr, &logits);
        assert!((a.at(3, 0) - 0.63).abs() < 0.01, "{}", a.at(3, 0));
        assert!((a.at(4, 0) - 0.37).abs() < 0.01);
        assert!((a.at(3, 1) - 0.46).abs() < 0.01);
        assert!((a.at(4, 1) - 0.54).abs() < 0.01);
        // Single-in-edge nodes get α = 1.
        assert!((a.at(0, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one_per_destination() {
        let g = erdos_renyi(30, 200, 1);
        let csr = Csr::from_coo(&g);
        let logits = random_features(200, 3, 2);
        let a = edge_softmax(&csr, &logits);
        for v in 0..30 {
            let (_, eids) = csr.row(v);
            if eids.is_empty() {
                continue;
            }
            for h in 0..3 {
                let s: f32 = eids.iter().map(|&e| a.at(e as usize, h)).sum();
                assert!((s - 1.0).abs() < 1e-4, "v={v} h={h} sum={s}");
            }
        }
    }

    #[test]
    fn softmax_invariant_to_shift() {
        let csr = toy_csr();
        let l1 = random_features(5, 2, 3);
        let mut l2 = l1.clone();
        for v in l2.data_mut() {
            *v += 100.0;
        }
        let a1 = edge_softmax(&csr, &l1);
        let a2 = edge_softmax(&csr, &l2);
        assert!(a1.max_abs_diff(&a2) < 1e-4);
    }

    #[test]
    fn softmax_backward_matches_finite_difference() {
        let csr = toy_csr();
        let logits = random_features(5, 2, 4);
        let upstream = random_features(5, 2, 5);
        let grad = {
            let a = edge_softmax(&csr, &logits);
            edge_softmax_backward(&csr, &a, &upstream)
        };
        // Finite differences on a few coordinates.
        let eps = 1e-3f32;
        for &(e, h) in &[(0usize, 0usize), (3, 0), (4, 1)] {
            let mut lp = logits.clone();
            lp.set(e, h, logits.at(e, h) + eps);
            let mut lm = logits.clone();
            lm.set(e, h, logits.at(e, h) - eps);
            let f = |l: &Dense<f32>| -> f32 {
                let a = edge_softmax(&csr, l);
                a.data().iter().zip(upstream.data().iter()).map(|(x, u)| x * u).sum()
            };
            let fd = (f(&lp) - f(&lm)) / (2.0 * eps);
            assert!(
                (fd - grad.at(e, h)).abs() < 2e-2,
                "e={e} h={h}: fd={fd} analytic={}",
                grad.at(e, h)
            );
        }
    }

    #[test]
    fn leaky_relu_forward_backward() {
        let x = Dense::from_vec(&[4], vec![-2.0f32, -0.5, 0.0, 3.0]);
        let y = leaky_relu(&x, 0.01);
        assert_eq!(y.data(), &[-0.02, -0.005, 0.0, 3.0]);
        let g = Dense::from_vec(&[4], vec![1.0f32; 4]);
        let dx = leaky_relu_backward(&x, &g, 0.01);
        assert_eq!(dx.data(), &[0.01, 0.01, 1.0, 1.0]);
    }
}
