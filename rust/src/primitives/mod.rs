//! The three GNN primitives (paper §2.1) and their quantized counterparts
//! (paper §3.3).
//!
//! A GNN training step decomposes into exactly three tensor primitives:
//!
//! - **GEMM** — node projection `H' = H·W` and its backward
//!   (`∂W = Hᵀ·∂H'`, `∂H = ∂H'·Wᵀ`). Compute-bound; quantization wins by
//!   cutting multiply-accumulate cost ([`qgemm`]).
//! - **SPMM** — neighbourhood aggregation `H^(l) = (G ⊙ α)·H'` and the
//!   edge-gradient reductions `∂S/∂D = (G ⊙ ∂E)·1`. Memory-bound;
//!   quantization wins by shrinking the randomly-accessed operand
//!   ([`spmm`], [`incidence_spmm`]).
//! - **SDDMM** — edge-feature computation `E = G ⊙ (S ⊕ Dᵀ)` and the
//!   attention gradient `∂α = G ⊙ (∂H·H'ᵀ)`. Memory-bound; add/sub variants
//!   dequantize on the fly, mul/div variants compute directly on quantized
//!   values with the scale product `s0·s1` ([`sddmm`]).
//!
//! The FP32 versions double as the "cuBLAS/cuSPARSE/DGL" baselines of the
//! paper's evaluation; the quantized versions are Tango's contributions.
//!
//! # Backend dispatch
//!
//! Quantized call sites in the models don't hard-code a kernel — they go
//! through [`PrimitiveBackend`], the seam that selects *how* a quantized
//! operand is consumed:
//!
//! - [`PrimitiveBackend::Dequantize`] (default) runs the dense-i8 kernels
//!   ([`qspmm_edge_weighted`], [`qgemm_prequantized`]) — one i8 slot per
//!   element regardless of nominal width;
//! - [`PrimitiveBackend::Packed`] runs the bit-packed kernels in
//!   [`packed`] ([`packed_spmm`], [`packed_qgemm`]) — sub-byte rows stay
//!   packed into the multiply (`--packed-compute`).
//!
//! On uniform-scale operands the two arms are bit-identical by
//! construction (pinned in `tests/packed_kernels.rs`), so flipping the
//! backend never changes training numerics — only where the bytes and
//! FLOPs go. This is the same seam the ROADMAP wants for dispatching a
//! future Pallas/PJRT (or any GPU) artifact per primitive: add a variant,
//! not a fork of the model code.

pub mod gemm;
pub mod packed;
pub mod qgemm;
pub mod sddmm;
pub mod softmax;
pub mod spmm;
pub mod spmv;

pub use gemm::{gemm_f32, gemm_f32_at_b, gemm_f32_a_bt};
pub use packed::{packed_qgemm, packed_spmm};
pub use qgemm::{qgemm, qgemm_prequantized, QGemmOutput};
pub use sddmm::{
    qsddmm_add, qsddmm_dot, sddmm_add, sddmm_broadcast_dst, sddmm_dot,
};
pub use softmax::{edge_softmax, edge_softmax_backward, leaky_relu, leaky_relu_backward};
pub use spmm::{
    incidence_spmm, qspmm_edge_weighted, spmm_csr_values, spmm_edge_aggregate_3mat,
    spmm_edge_weighted, spmm_per_head,
};
pub use spmv::{spmm_via_spmvs, spmv_csr};

use crate::graph::Csr;
use crate::quant::QTensor;
use crate::sampler::QuantRows;
use crate::tensor::Dense;

/// The kernel family a quantized call site dispatches to — see the module
/// docs. Carried on `TrainMode` and set from `TrainConfig::packed_compute`,
/// so the mini-batch trainer and every multi-GPU worker inherit one choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PrimitiveBackend {
    /// Dense-i8 / dequantize-to-f32 reference kernels (the default).
    #[default]
    Dequantize,
    /// Bit-packed sub-byte kernels ([`packed`]).
    Packed,
}

impl PrimitiveBackend {
    /// Backend for a `packed_compute` flag value.
    pub fn from_flag(packed: bool) -> Self {
        if packed {
            PrimitiveBackend::Packed
        } else {
            PrimitiveBackend::Dequantize
        }
    }

    /// Edge-weighted SPMM over an already-quantized dense operand,
    /// dispatched per backend. Both arms are bit-identical (the packed arm
    /// packs `qh`'s rows at its uniform scale first), so model code can
    /// route every quantized SPMM through here unconditionally.
    pub fn qspmm(&self, csr: &Csr, qalpha: &QTensor, qh: &QTensor, heads: usize) -> Dense<f32> {
        match self {
            PrimitiveBackend::Dequantize => qspmm_edge_weighted(csr, qalpha, qh, heads),
            PrimitiveBackend::Packed => {
                packed_spmm(csr, qalpha, &QuantRows::from_qtensor(qh), heads)
            }
        }
    }
}
