//! The three GNN primitives (paper §2.1) and their quantized counterparts
//! (paper §3.3).
//!
//! A GNN training step decomposes into exactly three tensor primitives:
//!
//! - **GEMM** — node projection `H' = H·W` and its backward
//!   (`∂W = Hᵀ·∂H'`, `∂H = ∂H'·Wᵀ`). Compute-bound; quantization wins by
//!   cutting multiply-accumulate cost ([`qgemm`]).
//! - **SPMM** — neighbourhood aggregation `H^(l) = (G ⊙ α)·H'` and the
//!   edge-gradient reductions `∂S/∂D = (G ⊙ ∂E)·1`. Memory-bound;
//!   quantization wins by shrinking the randomly-accessed operand
//!   ([`spmm`], [`incidence_spmm`]).
//! - **SDDMM** — edge-feature computation `E = G ⊙ (S ⊕ Dᵀ)` and the
//!   attention gradient `∂α = G ⊙ (∂H·H'ᵀ)`. Memory-bound; add/sub variants
//!   dequantize on the fly, mul/div variants compute directly on quantized
//!   values with the scale product `s0·s1` ([`sddmm`]).
//!
//! The FP32 versions double as the "cuBLAS/cuSPARSE/DGL" baselines of the
//! paper's evaluation; the quantized versions are Tango's contributions.

pub mod gemm;
pub mod qgemm;
pub mod sddmm;
pub mod softmax;
pub mod spmm;
pub mod spmv;

pub use gemm::{gemm_f32, gemm_f32_at_b, gemm_f32_a_bt};
pub use qgemm::{qgemm, qgemm_prequantized, QGemmOutput};
pub use sddmm::{
    qsddmm_add, qsddmm_dot, sddmm_add, sddmm_broadcast_dst, sddmm_dot,
};
pub use softmax::{edge_softmax, edge_softmax_backward, leaky_relu, leaky_relu_backward};
pub use spmm::{
    incidence_spmm, qspmm_edge_weighted, spmm_csr_values, spmm_edge_aggregate_3mat,
    spmm_edge_weighted, spmm_per_head,
};
pub use spmv::{spmm_via_spmvs, spmv_csr};
