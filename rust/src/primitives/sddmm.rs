//! SDDMM variants (paper §3.3): element-wise add (forward attention logits,
//! Fig. 1a step 3) and row-wise dot (attention gradient, Fig. 1b step 5),
//! each in FP32 and quantized form.
//!
//! The quantization rule (paper §3.3):
//!
//! - **add/sub** cannot be computed on quantized values directly because the
//!   two operands carry different scales (`s_S·S_q + s_D·D_q` does not
//!   factor) — so the kernel loads the small INT8 tensors and dequantizes
//!   *on the fly* per element ([`qsddmm_add`]);
//! - **mul/div** factor through: `(s_0·a_q)·(s_1·b_q) = (s_0·s_1)·(a_q·b_q)`,
//!   so the kernel multiplies raw INT8 values in i32 and applies one fused
//!   scale at the end ([`qsddmm_dot`]).

use crate::graph::Coo;
use crate::quant::QTensor;
use crate::tensor::Dense;
use crate::util::par;

/// FP32 SDDMM-add: `E[e,h] = S[src(e),h] + D[dst(e),h]`.
///
/// `s, d: [N, H]` → `[E, H]`. This is step 3 of Fig. 1a (before LeakyReLU).
pub fn sddmm_add(coo: &Coo, s: &Dense<f32>, d: &Dense<f32>) -> Dense<f32> {
    let heads = s.cols();
    assert_eq!(d.cols(), heads);
    let m = coo.num_edges();
    let mut out = Dense::zeros(&[m, heads]);
    par::for_each_chunk(out.data_mut(), heads, |e, erow| {
        let srow = s.row(coo.src[e] as usize);
        let drow = d.row(coo.dst[e] as usize);
        for h in 0..heads {
            erow[h] = srow[h] + drow[h];
        }
    });
    out
}

/// Quantized SDDMM-add with **on-the-fly dequantization**: random accesses
/// hit the 1-byte quantized tensors; each element is dequantized with its
/// own scale before the add (scales differ, so no direct quantized add).
pub fn qsddmm_add(coo: &Coo, qs: &QTensor, qd: &QTensor) -> Dense<f32> {
    let heads = qs.data.cols();
    let m = coo.num_edges();
    let (ss, sd) = (qs.scale, qd.scale);
    let mut out = Dense::zeros(&[m, heads]);
    par::for_each_chunk(out.data_mut(), heads, |e, erow| {
        let srow = qs.data.row(coo.src[e] as usize);
        let drow = qd.data.row(coo.dst[e] as usize);
        for h in 0..heads {
            erow[h] = srow[h] as f32 * ss + drow[h] as f32 * sd;
        }
    });
    out
}

/// FP32 SDDMM-dot: `out[e,h] = Σ_d A[dst(e),(h,d)] · B[src(e),(h,d)]`.
///
/// This is the attention gradient `∂α = G ⊙ (∂H^(l) · H'ᵀ)` of Fig. 1b
/// step 5: `a` is indexed by the edge's destination, `b` by its source.
pub fn sddmm_dot(coo: &Coo, a: &Dense<f32>, b: &Dense<f32>, heads: usize) -> Dense<f32> {
    let hd = a.cols();
    assert_eq!(b.cols(), hd);
    let d = hd / heads;
    let m = coo.num_edges();
    let mut out = Dense::zeros(&[m, heads]);
    par::for_each_chunk(out.data_mut(), heads, |e, erow| {
        let arow = a.row(coo.dst[e] as usize);
        let brow = b.row(coo.src[e] as usize);
        for h in 0..heads {
            let base = h * d;
            let mut acc = 0.0f32;
            for dd in 0..d {
                acc += arow[base + dd] * brow[base + dd];
            }
            erow[h] = acc;
        }
    });
    out
}

/// Quantized SDDMM-dot computed **directly on quantized values**: INT8
/// products accumulate in i32 and one fused `s_a·s_b` dequantizes the edge
/// scalar — multiplication commutes with the scale, so no per-element
/// dequantization is needed (paper §3.3's `∂α[e0] ≈ (s_0·s_1)·(∂H_q·H'_q)`).
pub fn qsddmm_dot(coo: &Coo, qa: &QTensor, qb: &QTensor, heads: usize) -> Dense<f32> {
    let hd = qa.data.cols();
    let d = hd / heads;
    let m = coo.num_edges();
    let deq = qa.scale * qb.scale;
    let mut out = Dense::zeros(&[m, heads]);
    par::for_each_chunk(out.data_mut(), heads, |e, erow| {
        let arow = qa.data.row(coo.dst[e] as usize);
        let brow = qb.data.row(coo.src[e] as usize);
        for h in 0..heads {
            let base = h * d;
            let mut acc = 0i32;
            for dd in 0..d {
                acc += arow[base + dd] as i32 * brow[base + dd] as i32;
            }
            erow[h] = acc as f32 * deq;
        }
    });
    out
}

/// Broadcast a per-destination value onto every in-edge:
/// `out[e,h] = M[dst(e),h]` — the `E' = G ⊙ (1 · M'ᵀ)` SDDMM of Fig. 1a
/// step 4 that assigns each softmax denominator back to its edges.
pub fn sddmm_broadcast_dst(coo: &Coo, m: &Dense<f32>) -> Dense<f32> {
    let heads = m.cols();
    let e_cnt = coo.num_edges();
    let mut out = Dense::zeros(&[e_cnt, heads]);
    par::for_each_chunk(out.data_mut(), heads, |e, erow| {
        erow.copy_from_slice(m.row(coo.dst[e] as usize));
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, random_features};
    use crate::quant::{quantize, Rounding};

    fn toy() -> Coo {
        Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3])
    }

    #[test]
    fn add_matches_paper_example() {
        // Paper step 3: e3 connects v0->v3: S[v0] + D[v3] = [1.20,-0.19] +
        // [0.20,0.05] = [1.40,-0.14].
        let s = Dense::from_vec(
            &[4, 2],
            vec![1.20, -0.19, 0.77, -0.62, 1.39, 0.25, 0.24, 0.09],
        );
        let d = Dense::from_vec(
            &[4, 2],
            vec![0.89, 0.48, 0.86, -0.26, 1.11, 0.27, 0.20, 0.05],
        );
        let e = sddmm_add(&toy(), &s, &d);
        assert!((e.at(3, 0) - 1.40).abs() < 1e-5);
        assert!((e.at(3, 1) - -0.14).abs() < 1e-5);
    }

    #[test]
    fn dot_matches_manual() {
        // ∂α[e0]: e0 is 1->0, so dot(a[dst=0], b[src=1]) per head.
        let coo = toy();
        let a = random_features(4, 2 * 3, 1);
        let b = random_features(4, 2 * 3, 2);
        let out = sddmm_dot(&coo, &a, &b, 2);
        let mut want = 0.0;
        for dd in 0..3 {
            want += a.at(0, dd) * b.at(1, dd);
        }
        assert!((out.at(0, 0) - want).abs() < 1e-5);
    }

    #[test]
    fn qadd_dequantizes_with_distinct_scales() {
        // Construct S and D with very different ranges so their scales
        // differ by ~100×; the on-the-fly dequantization must still land
        // near the FP32 result.
        let coo = erdos_renyi(30, 100, 3);
        let mut s = random_features(30, 4, 4);
        s.scale(100.0);
        let d = random_features(30, 4, 5);
        let exact = sddmm_add(&coo, &s, &d);
        let qs = quantize(&s, 8, Rounding::Nearest);
        let qd = quantize(&d, 8, Rounding::Nearest);
        assert!(qs.scale > 50.0 * qd.scale, "scales must differ for this test");
        let approx = qsddmm_add(&coo, &qs, &qd);
        let rel = approx.max_abs_diff(&exact) / exact.abs_max();
        assert!(rel < 0.02, "rel {rel}");
    }

    #[test]
    fn qdot_close_to_fp32() {
        let coo = erdos_renyi(40, 200, 6);
        let a = random_features(40, 4 * 8, 7);
        let b = random_features(40, 4 * 8, 8);
        let exact = sddmm_dot(&coo, &a, &b, 4);
        let qa = quantize(&a, 8, Rounding::Nearest);
        let qb = quantize(&b, 8, Rounding::Nearest);
        let approx = qsddmm_dot(&coo, &qa, &qb, 4);
        let rel = approx.max_abs_diff(&exact) / exact.abs_max().max(1e-6);
        assert!(rel < 0.05, "rel {rel}");
    }

    #[test]
    fn broadcast_dst_assigns_denominators() {
        let coo = toy();
        let m = Dense::from_vec(&[4, 1], vec![10.0, 20.0, 30.0, 40.0]);
        let e = sddmm_broadcast_dst(&coo, &m);
        // e3 and e4 both target v3.
        assert_eq!(e.at(3, 0), 40.0);
        assert_eq!(e.at(4, 0), 40.0);
        assert_eq!(e.at(0, 0), 10.0); // e0 -> v0
    }

    #[test]
    fn int4_dot_within_coarse_tolerance() {
        let coo = erdos_renyi(20, 80, 9);
        let a = random_features(20, 16, 10);
        let b = random_features(20, 16, 11);
        let exact = sddmm_dot(&coo, &a, &b, 1);
        let qa = quantize(&a, 4, Rounding::Nearest);
        let qb = quantize(&b, 4, Rounding::Nearest);
        let approx = qsddmm_dot(&coo, &qa, &qb, 1);
        let rel = approx.max_abs_diff(&exact) / exact.abs_max().max(1e-6);
        assert!(rel < 0.5, "int4 rel {rel}");
    }

    #[test]
    fn empty_graph_yields_empty_edge_features() {
        let coo = Coo::new(3, vec![], vec![]);
        let s = random_features(3, 2, 12);
        let out = sddmm_add(&coo, &s, &s);
        assert_eq!(out.rows(), 0);
    }
}
