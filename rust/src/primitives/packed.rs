//! Kernels that compute **directly on bit-packed sub-byte rows** (paper
//! §3.3; QGTC direction, PAPERS.md) — the point where the policy's 1/2/4-bit
//! rows stop being a wire-format trick and start paying at compute time.
//!
//! Both kernels consume a [`QuantRows`] payload (LSB-first bitstreams, see
//! [`crate::quant::pack`]) without ever materializing an f32 copy:
//!
//! - [`packed_spmm`] — the rectangular block aggregation
//!   `out[v] = Σ_e α[e,h] · row[u,(h,d)]`. Rows decode on the fly (nibble /
//!   crumb LUT lanes for 2/4-bit, raw bytes for 8-bit); the 1-bit ternary
//!   grid gets a word-level treatment: 64-bit words split into plus/minus
//!   crumb planes with `AND` masks and `trailing_zeros` walks over the set
//!   bits only, so zero elements cost nothing. Accumulation is exact i32
//!   when every row shares one scale (bit-identical to
//!   [`qspmm_edge_weighted`](super::qspmm_edge_weighted) by construction),
//!   with a single fused `s_α·s_row` dequantize at the store; mixed-width
//!   batches fold each edge at its source row's scale instead.
//! - [`packed_qgemm`] — the dense layer transform `C = A·B` with a packed
//!   left operand. Mirrors
//!   [`qgemm_prequantized`](super::qgemm_prequantized)'s panel loop (4-way
//!   K-unroll, zero-skip, fused output abs-max) but unpacks each A-row once
//!   per panel row and dequantizes at `s_row[i]·s_B` — bit-identical to the
//!   dense-i8 kernel on uniform input, per-row-scaled on mixed input.
//!
//! The kernels assume on-grid payloads (`|q| <= qmax_for_bits(bits)`),
//! which every quantizer in the crate guarantees; the ternary word path in
//! particular relies on `{-1, 0, +1}` crumbs only.
//!
//! Equivalence against the dequantize/unpacked reference is pinned in
//! `tests/packed_kernels.rs`; the speed claim (packed beats
//! dequantize-to-f32 at ≤4-bit) is asserted by `benches/packed.rs`.

use crate::graph::Csr;
use crate::quant::QTensor;
use crate::sampler::QuantRows;
use crate::tensor::Dense;
use crate::util::par;

/// Row-panel height per parallel task (mirrors `qgemm_prequantized` so the
/// uniform case is bit-identical, store order included).
const PANEL: usize = 64;

/// Mask selecting bit 0 of every 2-bit crumb in a 64-bit word.
const CRUMB_LO: u64 = 0x5555_5555_5555_5555;

/// Edge-weighted SPMM over bit-packed rows:
/// `out[v,(h,d)] = Σ_{e=(u→v)} α[e,h] · row[u,(h,d)]`, with `α` a dense-i8
/// [`QTensor`] (`[E, heads]`) and the node features a packed [`QuantRows`]
/// (`[N, heads*D]`). Uniform-scale batches accumulate in exact i32 and
/// dequantize once at `s_α·s_row` (bit-identical to the dense-i8 kernel);
/// mixed batches fold `s_α·s_row[u]` per edge.
pub fn packed_spmm(csr: &Csr, qalpha: &QTensor, rows: &QuantRows, heads: usize) -> Dense<f32> {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_PACKED_SPMM);
    let n = csr.num_nodes;
    let hd = rows.dim();
    assert_eq!(qalpha.data.cols(), heads, "alpha must be [E, heads]");
    assert_eq!(qalpha.data.rows(), csr.num_edges);
    assert_eq!(hd % heads, 0, "feature dim {hd} not divisible by heads {heads}");
    let d = hd / heads;
    let mut out = Dense::zeros(&[n, hd]);
    match rows.uniform() {
        Some((s, _)) => {
            let deq = qalpha.scale * s;
            par::for_each_chunk(out.data_mut(), hd, |v, orow| {
                let (srcs, eids) = csr.row(v);
                let mut acc = vec![0i32; hd];
                let mut scratch = vec![0i8; hd];
                for (&u, &e) in srcs.iter().zip(eids.iter()) {
                    let u = u as usize;
                    let arow = qalpha.data.row(e as usize);
                    if heads == 1 && rows.bits[u] == 1 {
                        ternary_accumulate_i32(&mut acc, rows.packed_row(u), arow[0] as i32);
                        continue;
                    }
                    rows.unpack_row_into(u, &mut scratch);
                    for hh in 0..heads {
                        let a = arow[hh] as i32;
                        let base = hh * d;
                        for dd in 0..d {
                            acc[base + dd] += a * scratch[base + dd] as i32;
                        }
                    }
                }
                for (o, &acc_v) in orow.iter_mut().zip(acc.iter()) {
                    *o = acc_v as f32 * deq;
                }
            });
        }
        None => {
            let s_a = qalpha.scale;
            par::for_each_chunk(out.data_mut(), hd, |v, orow| {
                let (srcs, eids) = csr.row(v);
                let mut scratch = vec![0i8; hd];
                for (&u, &e) in srcs.iter().zip(eids.iter()) {
                    let u = u as usize;
                    let fac = s_a * rows.scales[u];
                    let arow = qalpha.data.row(e as usize);
                    if heads == 1 && rows.bits[u] == 1 {
                        ternary_accumulate_f32(orow, rows.packed_row(u), arow[0] as i32, fac);
                        continue;
                    }
                    rows.unpack_row_into(u, &mut scratch);
                    for hh in 0..heads {
                        let a = arow[hh] as i32;
                        let base = hh * d;
                        for dd in 0..d {
                            orow[base + dd] += (a * scratch[base + dd] as i32) as f32 * fac;
                        }
                    }
                }
            });
        }
    }
    out
}

/// Word-level ternary accumulation, i32 accumulators: split each 64-bit
/// word of crumbs into "nonzero" (`bit 0`) and "minus" (`bit 1`) planes and
/// walk only the set bits. Padding crumbs are `0b00`, so the walk never
/// touches elements past the row's logical length. Adding `a·t` for
/// `t ∈ {-1,0,+1}` this way is exactly the generic loop's arithmetic.
fn ternary_accumulate_i32(acc: &mut [i32], packed: &[u8], a: i32) {
    if a == 0 {
        return; // every contribution is a·t = 0
    }
    let mut base = 0usize;
    let mut words = packed.chunks_exact(8);
    for wbytes in &mut words {
        let mut arr = [0u8; 8];
        arr.copy_from_slice(wbytes);
        let w = u64::from_le_bytes(arr);
        if w != 0 {
            let nonzero = w & CRUMB_LO;
            let minus = (w >> 1) & CRUMB_LO;
            let mut plus = nonzero & !minus;
            let mut neg = minus;
            while plus != 0 {
                acc[base + (plus.trailing_zeros() >> 1) as usize] += a;
                plus &= plus - 1;
            }
            while neg != 0 {
                acc[base + (neg.trailing_zeros() >> 1) as usize] -= a;
                neg &= neg - 1;
            }
        }
        base += 32;
    }
    for &b in words.remainder() {
        let lanes = &crate::quant::pack::CRUMB_LUT[b as usize];
        let take = (acc.len() - base).min(4);
        for (j, &t) in lanes[..take].iter().enumerate() {
            acc[base + j] += a * t as i32;
        }
        base += take;
    }
}

/// Word-level ternary accumulation, f32 accumulators (the mixed-width SPMM
/// arm): identical plane walk, contributions pre-scaled by `fac` — bitwise
/// equal to the generic `(a·t) as f32 * fac` fold for `t ∈ {-1,0,+1}`.
fn ternary_accumulate_f32(orow: &mut [f32], packed: &[u8], a: i32, fac: f32) {
    let plus_v = a as f32 * fac;
    let minus_v = (-a) as f32 * fac;
    let mut base = 0usize;
    let mut words = packed.chunks_exact(8);
    for wbytes in &mut words {
        let mut arr = [0u8; 8];
        arr.copy_from_slice(wbytes);
        let w = u64::from_le_bytes(arr);
        if w != 0 {
            let nonzero = w & CRUMB_LO;
            let minus = (w >> 1) & CRUMB_LO;
            let mut plus = nonzero & !minus;
            let mut neg = minus;
            while plus != 0 {
                orow[base + (plus.trailing_zeros() >> 1) as usize] += plus_v;
                plus &= plus - 1;
            }
            while neg != 0 {
                orow[base + (neg.trailing_zeros() >> 1) as usize] += minus_v;
                neg &= neg - 1;
            }
        }
        base += 32;
    }
    for &b in words.remainder() {
        let lanes = &crate::quant::pack::CRUMB_LUT[b as usize];
        let take = (orow.len() - base).min(4);
        for (j, &t) in lanes[..take].iter().enumerate() {
            orow[base + j] += (a * t as i32) as f32 * fac;
        }
        base += take;
    }
}

/// Dense GEMM with a bit-packed left operand: `C = A·B` where `A` is a
/// packed [`QuantRows`] (`[M, K]`, per-row scales) and `B` a dense-i8
/// [`QTensor`] (`[K, N]`). Each output row dequantizes at `s_row[i]·s_B`;
/// the output's own scale falls out of the fused store-loop abs-max exactly
/// as in [`qgemm_prequantized`](super::qgemm_prequantized). Returns
/// `(C, s_C)`.
pub fn packed_qgemm(qa: &QuantRows, qb: &QTensor, out_bits: u8) -> (Dense<f32>, f32) {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_PACKED_QGEMM);
    let (m, k) = (qa.rows(), qa.dim());
    let (kb, n) = (qb.data.rows(), qb.data.cols());
    assert_eq!(k, kb, "packed_qgemm inner dims: {k} vs {kb}");
    let s_b = qb.scale;
    let mut out = Dense::zeros(&[m, n]);
    let bd = qb.data.data();
    let panel_max = std::sync::Mutex::new(0.0f32);
    par::for_each_chunk(out.data_mut(), PANEL * n, |panel, chunk| {
        let i0 = panel * PANEL;
        let rows = chunk.len() / n;
        let mut acc = vec![0i32; n];
        let mut arow_buf = vec![0i8; k];
        let mut local_max = 0.0f32;
        for r in 0..rows {
            qa.unpack_row_into(i0 + r, &mut arow_buf);
            let arow = &arow_buf[..];
            let deq = qa.scales[i0 + r] * s_b;
            acc.iter_mut().for_each(|v| *v = 0);
            // Same INT8×INT8→INT32 dataflow as the dense-i8 kernel: 4-way
            // K-unroll with zero-skip (sub-byte rows are zero-heavy, so the
            // skip fires more often the colder the row).
            let mut kk = 0;
            while kk + 4 <= k {
                let a0 = arow[kk] as i32;
                let a1 = arow[kk + 1] as i32;
                let a2 = arow[kk + 2] as i32;
                let a3 = arow[kk + 3] as i32;
                if a0 | a1 | a2 | a3 != 0 {
                    let b0 = &bd[kk * n..(kk + 1) * n];
                    let b1 = &bd[(kk + 1) * n..(kk + 2) * n];
                    let b2 = &bd[(kk + 2) * n..(kk + 3) * n];
                    let b3 = &bd[(kk + 3) * n..(kk + 4) * n];
                    for j in 0..n {
                        acc[j] += a0 * b0[j] as i32
                            + a1 * b1[j] as i32
                            + a2 * b2[j] as i32
                            + a3 * b3[j] as i32;
                    }
                }
                kk += 4;
            }
            while kk < k {
                let aik = arow[kk] as i32;
                if aik != 0 {
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        acc[j] += aik * brow[j] as i32;
                    }
                }
                kk += 1;
            }
            let crow = &mut chunk[r * n..(r + 1) * n];
            for j in 0..n {
                let v = acc[j] as f32 * deq;
                crow[j] = v;
                local_max = local_max.max(v.abs());
            }
        }
        let mut g = panel_max.lock().unwrap_or_else(|e| e.into_inner());
        *g = g.max(local_max);
    });
    let absmax = panel_max.into_inner().unwrap_or_else(|e| e.into_inner());
    let qmax = ((1i32 << (out_bits - 1)) - 1) as f32;
    let out_scale = if absmax == 0.0 { 1.0 } else { absmax / qmax };
    (out, out_scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, random_features};
    use crate::primitives::{qgemm_prequantized, qspmm_edge_weighted};
    use crate::quant::{quantize, Rounding};

    /// Uniform batches: the packed SPMM is bit-identical to the dense-i8
    /// kernel at every width, including the ternary word path.
    #[test]
    fn uniform_packed_spmm_matches_dense_i8_kernel() {
        let g = erdos_renyi(60, 400, 21);
        let csr = Csr::from_coo(&g);
        for (heads, bits) in [(1usize, 8u8), (1, 4), (1, 2), (1, 1), (2, 4), (2, 1)] {
            let alpha = random_features(400, heads, 22);
            let h = random_features(60, heads * 12, 23);
            let qa = quantize(&alpha, 8, Rounding::Nearest);
            let qh = quantize(&h, bits, Rounding::Nearest);
            let dense = qspmm_edge_weighted(&csr, &qa, &qh, heads);
            let packed = packed_spmm(&csr, &qa, &QuantRows::from_qtensor(&qh), heads);
            assert_eq!(dense, packed, "heads {heads} bits {bits}");
        }
    }

    /// Uniform batches: the packed QGEMM is bit-identical to
    /// `qgemm_prequantized`, fused output scale included.
    #[test]
    fn uniform_packed_qgemm_matches_dense_i8_kernel() {
        for bits in [8u8, 4, 2, 1] {
            let a = random_features(70, 33, 31);
            let b = random_features(33, 9, 32);
            let qa = quantize(&a, bits, Rounding::Nearest);
            let qb = quantize(&b, 8, Rounding::Nearest);
            let (dense, s_dense) = qgemm_prequantized(&qa, &qb, 8);
            let (packed, s_packed) = packed_qgemm(&QuantRows::from_qtensor(&qa), &qb, 8);
            assert_eq!(dense, packed, "bits {bits}");
            assert_eq!(s_dense, s_packed, "bits {bits}");
        }
    }

    #[test]
    #[should_panic(expected = "alpha must be [E, heads]")]
    fn packed_spmm_rejects_bad_alpha_cols() {
        let g = erdos_renyi(10, 30, 41);
        let csr = Csr::from_coo(&g);
        let qa = quantize(&random_features(30, 2, 42), 8, Rounding::Nearest);
        let qh = quantize(&random_features(10, 8, 43), 4, Rounding::Nearest);
        // alpha has 2 heads but the call claims 1.
        let _ = packed_spmm(&csr, &qa, &QuantRows::from_qtensor(&qh), 1);
    }
}
