//! FP32 GEMM — the "cuBLAS" baseline of the paper's evaluation.
//!
//! Cache-blocked, rayon-parallel over row panels. Not a BLAS contender, but
//! a fair FP32 baseline for the INT8 comparison: both sides use the same
//! blocking and threading, so the measured ratio isolates the element-width
//! effect the paper's Fig. 11 attributes to quantization.

use crate::tensor::Dense;
use crate::util::par;

/// Row-panel height processed per rayon task.
const PANEL: usize = 64;
/// K-blocking factor (keeps a B block resident in L1/L2).
const KBLOCK: usize = 256;

/// `C = A · B` for row-major `A: [m,k]`, `B: [k,n]`.
pub fn gemm_f32(a: &Dense<f32>, b: &Dense<f32>) -> Dense<f32> {
    let (m, k) = (a.rows(), a.cols());
    let (kb, n) = (b.rows(), b.cols());
    assert_eq!(k, kb, "gemm inner dims: {k} vs {kb}");
    let mut out = Dense::zeros(&[m, n]);
    let bd = b.data();
    par::for_each_chunk(out.data_mut(), PANEL * n, |panel, chunk| {
        let i0 = panel * PANEL;
        let rows = chunk.len() / n;
        for k0 in (0..k).step_by(KBLOCK) {
            let k1 = (k0 + KBLOCK).min(k);
            for r in 0..rows {
                let arow = a.row(i0 + r);
                let crow = &mut chunk[r * n..(r + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    });
    out
}

/// `C = Aᵀ · B` for `A: [k,m]`, `B: [k,n]` — the `∂W = Hᵀ·∂H'` shape.
pub fn gemm_f32_at_b(a: &Dense<f32>, b: &Dense<f32>) -> Dense<f32> {
    gemm_f32(&a.transpose(), b)
}

/// `C = A · Bᵀ` for `A: [m,k]`, `B: [n,k]` — the `∂H = ∂H'·Wᵀ` shape.
pub fn gemm_f32_a_bt(a: &Dense<f32>, b: &Dense<f32>) -> Dense<f32> {
    gemm_f32(a, &b.transpose())
}

/// Naive triple loop — correctness oracle for tests only.
pub fn gemm_naive(a: &Dense<f32>, b: &Dense<f32>) -> Dense<f32> {
    let (m, k) = (a.rows(), a.cols());
    let n = b.cols();
    assert_eq!(k, b.rows());
    let mut out = Dense::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a.at(i, kk) * b.at(kk, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;

    #[test]
    fn matches_naive_small() {
        let a = Dense::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Dense::from_vec(&[3, 2], vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = gemm_f32(&a, &b);
        assert_eq!(c.data(), gemm_naive(&a, &b).data());
        assert_eq!(c.at(0, 0), 58.0);
        assert_eq!(c.at(1, 1), 154.0);
    }

    #[test]
    fn matches_naive_random_odd_sizes() {
        // Sizes chosen to straddle panel/kblock boundaries.
        for &(m, k, n) in &[(1, 1, 1), (65, 7, 3), (64, 256, 32), (100, 300, 17)] {
            let a = random_features(m, k, 1);
            let b = random_features(k, n, 2);
            let fast = gemm_f32(&a, &b);
            let slow = gemm_naive(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn transposed_variants() {
        let a = random_features(10, 6, 3); // [k=10, m=6] for at_b
        let b = random_features(10, 4, 4);
        let c = gemm_f32_at_b(&a, &b);
        assert_eq!(c.shape(), &[6, 4]);
        let oracle = gemm_naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&oracle) < 1e-4);

        let x = random_features(5, 8, 5);
        let w = random_features(3, 8, 6); // [n=3, k=8]
        let y = gemm_f32_a_bt(&x, &w);
        assert_eq!(y.shape(), &[5, 3]);
        let oracle = gemm_naive(&x, &w.transpose());
        assert!(y.max_abs_diff(&oracle) < 1e-4);
    }

    #[test]
    fn identity_multiplication() {
        let mut eye = Dense::zeros(&[4, 4]);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let x = random_features(4, 4, 7);
        assert!(gemm_f32(&eye, &x).max_abs_diff(&x) < 1e-6);
    }
}
