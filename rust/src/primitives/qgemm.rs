//! Quantized GEMM with on-the-fly quantization and fused scaling-factor
//! computation (paper §3.3, Fig. 4).
//!
//! The paper's kernel does four things in one pass, which we mirror:
//!
//! 1. **Quantize at load**: input tiles are quantized while being staged
//!    (GPU: global→shared; here: f32 rows → i8 panels), and the quantized
//!    copies are *kept* — the backward pass reuses them (Fig. 10's caching).
//! 2. **INT8 multiply, INT32 accumulate**: the product of two 8-bit values
//!    plus accumulation overflows 8 bits (Fig. 3), so accumulators are i32
//!    (the DP4A/tensor-core behaviour).
//! 3. **Fused dequantization**: the i32 result dequantizes to f32 by
//!    `s_A·s_B` in the store loop — no separate dequantize kernel.
//! 4. **Fused output-scale computation**: the output's own scaling factor
//!    `s_C` (its abs-max / qmax) falls out of the same store loop, so the
//!    *next* primitive can quantize without another reduction pass.

use crate::quant::{quantize, QTensor, Rounding};
use crate::tensor::Dense;
use crate::util::par;

/// Row-panel height per rayon task (mirrors the FP32 baseline's blocking so
/// measured speedups isolate the quantization effect).
const PANEL: usize = 64;

/// Everything the fused quantized GEMM produces in one pass.
#[derive(Debug, Clone)]
pub struct QGemmOutput {
    /// Dequantized FP32 result `C = A·B` (approximation).
    pub out: Dense<f32>,
    /// The output's own symmetric scaling factor, computed during the store
    /// loop (paper Fig. 3: `s_H' = 166.26` falls out of the GEMM kernel).
    pub out_scale: f32,
    /// Quantized copy of `A`, stored back for backward-pass reuse.
    pub qa: QTensor,
    /// Quantized copy of `B`, stored back for backward-pass reuse.
    pub qb: QTensor,
}

/// Quantized GEMM on FP32 inputs: quantizes `A` and `B` on the fly, runs the
/// INT8×INT8→INT32 product, and returns the dequantized result together
/// with the fused output scale and the quantized input copies.
pub fn qgemm(a: &Dense<f32>, b: &Dense<f32>, bits: u8, rounding: Rounding) -> QGemmOutput {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_QGEMM);
    assert_eq!(a.cols(), b.rows(), "qgemm inner dims");
    // "On-the-fly" on the CPU substrate: one sweep per input computing the
    // scale, one sweep rounding. (A GPU fuses these into the tile loads; the
    // algorithmic cost — 4K(M+N) ops, paper §3.3 — is identical.)
    let qa = quantize(a, bits, rounding);
    let qb = quantize(b, bits, derange(rounding));
    let (out, out_scale) = qgemm_prequantized(&qa, &qb, bits);
    QGemmOutput { out, out_scale, qa, qb }
}

/// Offset a stochastic seed so A and B don't share a rounding stream.
fn derange(r: Rounding) -> Rounding {
    match r {
        Rounding::Nearest => Rounding::Nearest,
        Rounding::Stochastic { seed } => Rounding::Stochastic { seed: seed.wrapping_add(0x9E37) },
    }
}

/// The reuse path (paper Fig. 10): both inputs are already quantized —
/// e.g. cached from the forward pass — so the kernel skips quantization
/// entirely. Returns the dequantized result and its fused output scale.
pub fn qgemm_prequantized(qa: &QTensor, qb: &QTensor, out_bits: u8) -> (Dense<f32>, f32) {
    let _t = crate::obs::timed(crate::obs::keys::TIMED_PRIM_QGEMM_PREQUANTIZED);
    let (m, k) = (qa.data.rows(), qa.data.cols());
    let (kb, n) = (qb.data.rows(), qb.data.cols());
    assert_eq!(k, kb, "qgemm inner dims: {k} vs {kb}");
    let deq = qa.scale * qb.scale;
    let mut out = Dense::zeros(&[m, n]);
    let bd = qb.data.data();
    // Fused store-loop abs-max per panel, reduced across panels at the end.
    let panel_max = std::sync::Mutex::new(0.0f32);
    par::for_each_chunk(out.data_mut(), PANEL * n, |panel, chunk| {
        let i0 = panel * PANEL;
        let rows = chunk.len() / n;
        let mut acc = vec![0i32; n];
        let mut local_max = 0.0f32;
        for r in 0..rows {
            let arow = qa.data.row(i0 + r);
            acc.iter_mut().for_each(|v| *v = 0);
            // INT8 multiply, INT32 accumulate, 4-way unrolled over K — the
            // DP4A dataflow (§Perf: the unroll lets the autovectorizer use
            // the wide integer units; 1.34x over the scalar-k loop).
            let mut kk = 0;
            while kk + 4 <= k {
                let a0 = arow[kk] as i32;
                let a1 = arow[kk + 1] as i32;
                let a2 = arow[kk + 2] as i32;
                let a3 = arow[kk + 3] as i32;
                if a0 | a1 | a2 | a3 != 0 {
                    let b0 = &bd[kk * n..(kk + 1) * n];
                    let b1 = &bd[(kk + 1) * n..(kk + 2) * n];
                    let b2 = &bd[(kk + 2) * n..(kk + 3) * n];
                    let b3 = &bd[(kk + 3) * n..(kk + 4) * n];
                    for j in 0..n {
                        acc[j] += a0 * b0[j] as i32
                            + a1 * b1[j] as i32
                            + a2 * b2[j] as i32
                            + a3 * b3[j] as i32;
                    }
                }
                kk += 4;
            }
            while kk < k {
                let aik = arow[kk] as i32;
                if aik != 0 {
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        acc[j] += aik * brow[j] as i32;
                    }
                }
                kk += 1;
            }
            // Fused dequantize + output abs-max (paper Fig. 4 step 4).
            let crow = &mut chunk[r * n..(r + 1) * n];
            for j in 0..n {
                let v = acc[j] as f32 * deq;
                crow[j] = v;
                local_max = local_max.max(v.abs());
            }
        }
        let mut g = panel_max.lock().unwrap_or_else(|e| e.into_inner());
        *g = g.max(local_max);
    });
    let absmax = panel_max.into_inner().unwrap_or_else(|e| e.into_inner());
    let qmax = ((1i32 << (out_bits - 1)) - 1) as f32;
    let out_scale = if absmax == 0.0 { 1.0 } else { absmax / qmax };
    (out, out_scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;
    use crate::primitives::gemm::gemm_f32;
    use crate::quant::scale_for_bits;

    #[test]
    fn approximates_fp32_gemm() {
        let a = random_features(64, 128, 1);
        let b = random_features(128, 32, 2);
        let exact = gemm_f32(&a, &b);
        let q = qgemm(&a, &b, 8, Rounding::Nearest);
        // INT8 relative error on a K=128 dot of unit-range values.
        let rel = q.out.max_abs_diff(&exact) / exact.abs_max();
        assert!(rel < 0.05, "rel error {rel}");
    }

    #[test]
    fn int4_is_coarser_than_int8() {
        let a = random_features(32, 64, 3);
        let b = random_features(64, 16, 4);
        let exact = gemm_f32(&a, &b);
        let e8 = qgemm(&a, &b, 8, Rounding::Nearest).out.max_abs_diff(&exact);
        let e4 = qgemm(&a, &b, 4, Rounding::Nearest).out.max_abs_diff(&exact);
        assert!(e4 > e8, "int4 err {e4} should exceed int8 err {e8}");
    }

    #[test]
    fn fused_output_scale_matches_separate_computation() {
        let a = random_features(16, 32, 5);
        let b = random_features(32, 8, 6);
        let q = qgemm(&a, &b, 8, Rounding::Nearest);
        let expected = scale_for_bits(&q.out, 8);
        assert!((q.out_scale - expected).abs() < 1e-6 * expected.max(1.0));
    }

    #[test]
    fn prequantized_path_matches_fresh_quantization() {
        // The cache-reuse contract: running from cached QTensors must give
        // bit-identical results to the fused path.
        let a = random_features(24, 48, 7);
        let b = random_features(48, 12, 8);
        let q = qgemm(&a, &b, 8, Rounding::Nearest);
        let (out2, s2) = qgemm_prequantized(&q.qa, &q.qb, 8);
        assert_eq!(q.out.data(), out2.data());
        assert_eq!(q.out_scale, s2);
    }

    #[test]
    fn accumulator_does_not_overflow_int8_range() {
        // Worst case: K=512 of ±127·±127 products = ±8.2M, far over i8/i16
        // but comfortably inside i32 — the Fig. 3 argument.
        let ones = Dense::from_vec(&[1, 512], vec![1.0f32; 512]);
        let ones_t = Dense::from_vec(&[512, 1], vec![1.0f32; 512]);
        let q = qgemm(&ones, &ones_t, 8, Rounding::Nearest);
        // 512 * (127 * 127) * (1/127)^2 = 512 exactly.
        assert!((q.out.at(0, 0) - 512.0).abs() < 1e-3, "{}", q.out.at(0, 0));
    }

    #[test]
    fn zero_inputs_give_zero_output_scale_one() {
        let a: Dense<f32> = Dense::zeros(&[4, 4]);
        let b: Dense<f32> = Dense::zeros(&[4, 4]);
        let q = qgemm(&a, &b, 8, Rounding::Nearest);
        assert!(q.out.data().iter().all(|&v| v == 0.0));
        assert_eq!(q.out_scale, 1.0);
    }

    #[test]
    fn stochastic_rounding_unbiased_through_gemm() {
        // E[qgemm] ≈ gemm: average many stochastic draws of a small case.
        let a = random_features(4, 16, 9);
        let b = random_features(16, 4, 10);
        let exact = gemm_f32(&a, &b);
        let mut mean = Dense::zeros(&[4, 4]);
        let n = 300;
        for s in 0..n {
            let q = qgemm(&a, &b, 8, Rounding::Stochastic { seed: s });
            mean.add_assign(&q.out);
        }
        mean.scale(1.0 / n as f32);
        let rel = mean.max_abs_diff(&exact) / exact.abs_max();
        assert!(rel < 0.01, "stochastic mean deviates: {rel}");
    }

    #[test]
    fn rectangular_shapes() {
        for &(m, k, n) in &[(1, 8, 1), (65, 3, 2), (128, 64, 5)] {
            let a = random_features(m, k, 11);
            let b = random_features(k, n, 12);
            let q = qgemm(&a, &b, 8, Rounding::Nearest);
            assert_eq!(q.out.shape(), &[m, n]);
        }
    }
}
