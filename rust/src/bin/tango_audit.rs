//! `tango-audit` — run the repo's static-analysis pass from the CLI.
//!
//! ```text
//! tango_audit [--root DIR] [--json PATH] [--deny-warnings]
//! ```
//!
//! Exit code 0 iff no findings survive `audit.allow.toml` (and, under
//! `--deny-warnings`, no warnings — e.g. stale allowlist entries).
//! `--json PATH` additionally writes the `tango-audit/v1` report.
//! Rules and allowlist format: `rust/src/audit/README.md`.

use std::path::Path;
use tango::audit::{self, Allowlist};
use tango::util::cli::Args;

fn run() -> tango::Result<bool> {
    let args = Args::from_env();
    let root = args.get("root", ".").to_string();
    let deny_warnings = args.get_bool("deny-warnings");
    let root = Path::new(&root);

    let allow_path = root.join("audit.allow.toml");
    let allow = if allow_path.is_file() {
        let text = std::fs::read_to_string(&allow_path)?;
        Allowlist::parse(&text).map_err(|e| anyhow::anyhow!("audit.allow.toml: {e}"))?
    } else {
        Allowlist::empty()
    };

    let report = audit::run(root, &allow)?;
    print!("{}", report.render_text());
    if let Some(path) = args.flags.get("json") {
        tango::util::fsio::write_atomic(path, &(report.to_json().to_string() + "\n"))?;
        println!("report: {path}");
    }
    Ok(report.ok(deny_warnings))
}

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("tango-audit error: {e:#}");
            std::process::exit(2);
        }
    }
}
