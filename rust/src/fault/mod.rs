//! Deterministic seeded fault injection + recovery accounting.
//!
//! Robustness claims are only testable if failures are reproducible, so
//! every fault here is scheduled by **global training step** under a seed —
//! never wall-clock — and the whole harness is inert unless
//! `--inject-faults` (TOML `[fault] inject_faults = true`) is set. Four
//! fault classes map onto the crate's real failure surfaces:
//!
//! - **Producer** — the prefetch producer thread panics mid-epoch
//!   ([`injected_panic`] fires inside the stage-1 closure); the trainer
//!   restarts it from the last consumed batch with a bounded retry budget
//!   and *simulated* exponential backoff ([`FaultInjector::charge_backoff`]
//!   accounts the sleep it would have done — no actual sleeping, rule D1).
//! - **Worker** — a multi-GPU worker's step fails before computing; the
//!   coordinator rebuilds it from round-entry state and replays the round.
//! - **Link** — a ring all-reduce link drops; the round retries (re-charging
//!   [`Interconnect::transfer_time`](crate::multigpu::Interconnect) for the
//!   re-transmission) and, past the budget, degrades to a skip-straggler
//!   all-reduce over the surviving workers (recorded as a degradation).
//! - **Lock** — a shared-state mutex is poisoned ([`poison_lock`]); users
//!   recover via the repo-wide `unwrap_or_else(|e| e.into_inner())` idiom.
//!
//! Recovered faults are numerically neutral: the run's losses, weights and
//! RNG streams are bit-identical to an uninjected run
//! (`tests/fault_recovery.rs`). Every injection and recovery is counted in
//! a [`FaultReport`] that lands in the `fault` section of the
//! `tango-metrics/v1` artifact and in [`obs`](crate::obs) counters.

use std::sync::Mutex;

use crate::config::FaultConfig;
use crate::obs::{counter_add, keys};
use crate::quant::rng::mix_seeds;

/// One class of injectable fault. See the module docs for semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Prefetch-producer thread panic (single-process training).
    Producer,
    /// Multi-GPU worker step failure.
    Worker,
    /// Ring all-reduce link drop.
    Link,
    /// Shared-state mutex poisoning.
    Lock,
}

/// Per-class sorted multisets of global steps at which faults fire.
///
/// A repeated step fires repeatedly at that step — that's how tests
/// exhaust a retry budget deterministically.
#[derive(Debug, Clone, Default)]
struct FaultPlan {
    producer: Vec<u64>,
    worker: Vec<u64>,
    link: Vec<u64>,
    lock: Vec<u64>,
}

impl FaultPlan {
    fn from_config(cfg: &FaultConfig) -> Self {
        // Schedules arrive sorted from `parse_fault_steps`; re-sort anyway
        // so programmatic configs get the same firing order.
        let sorted = |v: &Vec<u64>| {
            let mut v = v.clone();
            v.sort_unstable();
            v
        };
        FaultPlan {
            producer: sorted(&cfg.producer_steps),
            worker: sorted(&cfg.worker_steps),
            link: sorted(&cfg.link_steps),
            lock: sorted(&cfg.lock_steps),
        }
    }

    fn schedule(&mut self, class: FaultClass) -> &mut Vec<u64> {
        match class {
            FaultClass::Producer => &mut self.producer,
            FaultClass::Worker => &mut self.worker,
            FaultClass::Link => &mut self.link,
            FaultClass::Lock => &mut self.lock,
        }
    }

    /// Pop one occurrence of `step` from the class schedule. Returns true
    /// iff a fault fires — each scheduled occurrence fires exactly once.
    fn fire(&mut self, class: FaultClass, step: u64) -> bool {
        let sched = self.schedule(class);
        match sched.binary_search(&step) {
            Ok(i) => {
                sched.remove(i);
                true
            }
            Err(_) => false,
        }
    }
}

/// Counts of injected faults, recoveries and degradations for one run.
///
/// Serialized as the `fault` section of `tango-metrics/v1` (Null when
/// injection is off) — field names are the artifact's key names.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultReport {
    /// Producer-thread panics injected.
    pub producer_panics: u64,
    /// Producer threads restarted (≤ panics; the last panic may be fatal).
    pub producer_restarts: u64,
    /// Worker step failures injected.
    pub worker_failures: u64,
    /// Workers rebuilt from round-entry state and replayed.
    pub worker_rebuilds: u64,
    /// All-reduce link drops injected.
    pub link_drops: u64,
    /// All-reduce retries after a dropped link.
    pub link_retries: u64,
    /// Rounds degraded to skip-straggler after link-retry exhaustion.
    pub allreduce_degraded: u64,
    /// Mutexes poisoned by injection.
    pub lock_poisons: u64,
    /// Poisoned mutexes recovered and verified re-lockable.
    pub lock_recoveries: u64,
    /// Total *simulated* exponential-backoff delay, in seconds. Never
    /// slept — accounted so recovery cost shows up in reports without a
    /// wall-clock dependency.
    pub backoff_s: f64,
    /// Flight-recorder dumps written by recovery paths this run.
    pub flight_dumps: u64,
}

impl FaultReport {
    /// Fold another report into this one (multi-phase runs).
    pub fn merge(&mut self, other: &FaultReport) {
        self.producer_panics += other.producer_panics;
        self.producer_restarts += other.producer_restarts;
        self.worker_failures += other.worker_failures;
        self.worker_rebuilds += other.worker_rebuilds;
        self.link_drops += other.link_drops;
        self.link_retries += other.link_retries;
        self.allreduce_degraded += other.allreduce_degraded;
        self.lock_poisons += other.lock_poisons;
        self.lock_recoveries += other.lock_recoveries;
        self.backoff_s += other.backoff_s;
        self.flight_dumps += other.flight_dumps;
    }

    /// True iff any fault of any class was injected.
    pub fn any_fired(&self) -> bool {
        self.producer_panics + self.worker_failures + self.link_drops + self.lock_poisons > 0
    }
}

/// The seeded fault scheduler + recovery ledger threaded through a run.
///
/// Construction returns `None` unless the config opts in, so the disabled
/// path stays a single `Option` check. Trainers share an injector across
/// threads behind a `Mutex` (the producer thread probes it too).
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    seed: u64,
    /// Retry budget per fault occurrence before escalation (degrade/fatal).
    pub max_retries: usize,
    /// Base of the simulated exponential backoff, in milliseconds.
    pub backoff_ms: u64,
    /// Running recovery ledger; harvested into reports at run end.
    pub report: FaultReport,
}

impl FaultInjector {
    /// Build an injector iff `cfg.inject` is set.
    pub fn new(cfg: &FaultConfig) -> Option<Self> {
        if !cfg.inject {
            return None;
        }
        Some(FaultInjector {
            plan: FaultPlan::from_config(cfg),
            seed: cfg.seed,
            max_retries: cfg.max_retries,
            backoff_ms: cfg.backoff_ms,
            report: FaultReport::default(),
        })
    }

    /// Should a `class` fault fire at global `step`? Pops one scheduled
    /// occurrence and counts the injection when it does.
    pub fn fire(&mut self, class: FaultClass, step: u64) -> bool {
        if !self.plan.fire(class, step) {
            return false;
        }
        match class {
            FaultClass::Producer => {
                self.report.producer_panics += 1;
                counter_add(keys::CTR_FAULT_PRODUCER_PANICS, 1);
            }
            FaultClass::Worker => {
                self.report.worker_failures += 1;
                counter_add(keys::CTR_FAULT_WORKER_FAILURES, 1);
            }
            FaultClass::Link => {
                self.report.link_drops += 1;
                counter_add(keys::CTR_FAULT_LINK_DROPS, 1);
            }
            FaultClass::Lock => {
                self.report.lock_poisons += 1;
                counter_add(keys::CTR_FAULT_LOCK_POISONS, 1);
            }
        }
        true
    }

    /// Deterministic victim worker for a `step` fault in a `k`-worker run.
    pub fn victim(&self, step: u64, k: usize) -> usize {
        (mix_seeds(&[self.seed, step]) % k.max(1) as u64) as usize
    }

    /// Account one simulated exponential-backoff delay for retry
    /// `attempt` (1-based): `backoff_ms * 2^(attempt-1)`, charged to the
    /// ledger in seconds. Never sleeps.
    pub fn charge_backoff(&mut self, attempt: usize) {
        let factor = 1u64 << (attempt.saturating_sub(1)).min(20);
        self.report.backoff_s += (self.backoff_ms * factor) as f64 / 1000.0;
    }
}

/// Panic with a recognizable injected-fault message. The *only* `panic!`
/// of the harness lives here, so the audit P1 allowlist carries exactly one
/// vetted entry for injected faults.
pub fn injected_panic(what: &str) -> ! {
    panic!("injected fault: {what}")
}

/// Poison `lock` by panicking a scoped thread while it holds the guard.
/// Returns once the mutex is observably poisoned.
pub fn poison_lock<T>(lock: &Mutex<T>) {
    std::thread::scope(|s| {
        let handle = s.spawn(|| {
            let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
            injected_panic("lock poison");
        });
        // The panic is the point; swallow the join error.
        let _ = handle.join();
    });
    debug_assert!(lock.is_poisoned());
}

/// Recover a poisoned `lock` the repo-idiomatic way (`into_inner`), verify
/// it is re-lockable, and count the recovery in `injector`'s ledger.
pub fn recover_poisoned_lock<T>(lock: &Mutex<T>, injector: &mut FaultInjector) {
    {
        let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
    }
    // A second acquisition proves the mutex still functions after recovery.
    let _guard = lock.lock().unwrap_or_else(|e| e.into_inner());
    injector.report.lock_recoveries += 1;
    counter_add(keys::CTR_FAULT_LOCK_RECOVERIES, 1);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(producer: &[u64], link: &[u64]) -> FaultConfig {
        FaultConfig {
            inject: true,
            producer_steps: producer.to_vec(),
            link_steps: link.to_vec(),
            ..FaultConfig::default()
        }
    }

    #[test]
    fn disabled_config_builds_no_injector() {
        assert!(FaultInjector::new(&FaultConfig::default()).is_none());
    }

    #[test]
    fn scheduled_steps_fire_once_per_occurrence() {
        let mut inj = FaultInjector::new(&cfg_with(&[5, 5, 9], &[])).unwrap();
        assert!(!inj.fire(FaultClass::Producer, 4));
        assert!(inj.fire(FaultClass::Producer, 5));
        assert!(inj.fire(FaultClass::Producer, 5), "second occurrence at the same step");
        assert!(!inj.fire(FaultClass::Producer, 5), "multiset exhausted");
        assert!(inj.fire(FaultClass::Producer, 9));
        assert!(!inj.fire(FaultClass::Link, 5), "classes are independent");
        assert_eq!(inj.report.producer_panics, 3);
    }

    #[test]
    fn unsorted_programmatic_schedules_still_fire() {
        let mut inj = FaultInjector::new(&cfg_with(&[9, 2, 7], &[])).unwrap();
        for step in [2, 7, 9] {
            assert!(inj.fire(FaultClass::Producer, step));
        }
    }

    #[test]
    fn victim_is_deterministic_and_in_range() {
        let inj = FaultInjector::new(&cfg_with(&[], &[1])).unwrap();
        for step in 0..32 {
            let v = inj.victim(step, 4);
            assert!(v < 4);
            assert_eq!(v, inj.victim(step, 4), "same step, same victim");
        }
        // Different steps must be able to pick different victims.
        let distinct: std::collections::BTreeSet<_> = (0..32).map(|s| inj.victim(s, 4)).collect();
        assert!(distinct.len() > 1);
        assert_eq!(inj.victim(3, 1), 0, "k=1 degenerates safely");
    }

    #[test]
    fn backoff_doubles_and_accumulates_without_sleeping() {
        let mut inj = FaultInjector::new(&cfg_with(&[1], &[])).unwrap();
        inj.charge_backoff(1);
        inj.charge_backoff(2);
        inj.charge_backoff(3);
        // 100ms + 200ms + 400ms with the default base.
        assert!((inj.report.backoff_s - 0.7).abs() < 1e-12);
    }

    #[test]
    fn poisoned_lock_recovers_and_is_counted() {
        let mut inj = FaultInjector::new(&cfg_with(&[], &[])).unwrap();
        let lock = Mutex::new(41usize);
        poison_lock(&lock);
        assert!(lock.is_poisoned());
        recover_poisoned_lock(&lock, &mut inj);
        assert_eq!(inj.report.lock_recoveries, 1);
        *lock.lock().unwrap_or_else(|e| e.into_inner()) += 1;
        assert_eq!(*lock.lock().unwrap_or_else(|e| e.into_inner()), 42);
    }

    #[test]
    fn report_merge_sums_every_field() {
        let mut a = FaultReport { producer_panics: 1, backoff_s: 0.5, ..Default::default() };
        let b = FaultReport { producer_panics: 2, link_retries: 3, backoff_s: 0.25, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.producer_panics, 3);
        assert_eq!(a.link_retries, 3);
        assert!((a.backoff_s - 0.75).abs() < 1e-12);
        assert!(a.any_fired());
        assert!(!FaultReport::default().any_fired());
    }
}
