//! Deterministic checkpoint/resume: the `tango-ckpt/v1` artifact.
//!
//! A checkpoint captures everything a trainer needs to continue a run
//! **bit-identically** to the uninterrupted trace: FP32 master weights,
//! optimizer (momentum) state, the epoch/batch cursor with its partial
//! loss accumulator, the model's global `step_count` (the stochastic-
//! rounding stream descriptor — every RNG stream in the crate is derived
//! from config seeds plus this counter and the cursor, so no generator
//! state needs serializing), per-bucket policy scales, and the completed
//! loss/eval traces so a resumed report matches the control's.
//!
//! Float payloads are stored as **hex bit patterns** (`f32` → 8 hex chars,
//! `f64` → 16), not decimal — round-tripping through decimal would be the
//! one place a resumed run could diverge by an ULP. Writes are atomic
//! (tmp + rename via [`util::fsio`](crate::util::fsio)), so a crash
//! mid-save leaves the previous checkpoint intact; loads of corrupt,
//! truncated or mismatched files return actionable errors, never panic
//! (`tests/ckpt_schema.rs`).
//!
//! A [`Fingerprint`] of the run configuration is validated on resume:
//! restoring weights into a differently-shaped run would fail late and
//! confusingly, so mismatches are rejected up front by name.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Schema tag of the checkpoint artifact.
pub const SCHEMA: &str = "tango-ckpt/v1";

/// Identity of the run a checkpoint belongs to. Every field is validated
/// on resume; a mismatch is a config error, not a corrupt file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    /// Dataset name.
    pub dataset: String,
    /// Model architecture (`gcn` / `gat`).
    pub model: String,
    /// Quantization mode name.
    pub mode: String,
    /// Quantization bit width.
    pub bits: u32,
    /// Master RNG seed.
    pub seed: u64,
    /// Sampler seed (mini-batch runs).
    pub sample_seed: u64,
    /// Simulated worker count (1 for single-process training).
    pub workers: usize,
    /// True for mini-batch (sampled) training, false for full-graph.
    pub sampled: bool,
}

/// Where training stopped: the next epoch/step to execute plus the
/// partial per-epoch loss accumulator.
#[derive(Debug, Clone, PartialEq)]
pub struct Cursor {
    /// Epoch to resume into (0-based). Equal to the configured epoch
    /// count in a run-complete checkpoint.
    pub epoch: usize,
    /// Steps of `epoch` already executed; resume skips this many batches
    /// (or rounds). `step == steps_per_epoch` means the epoch's loop is
    /// done and only finalization remains.
    pub step: usize,
    /// Partial sum of per-step losses inside `epoch` (bit-exact).
    pub loss_sum: f64,
    /// Steps already folded into `loss_sum`.
    pub loss_steps: usize,
}

/// One serializable `tango-ckpt/v1` checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Producing command: `"train"` or `"multigpu"`.
    pub command: String,
    /// Run identity, validated on resume.
    pub fingerprint: Fingerprint,
    /// Resume position.
    pub cursor: Cursor,
    /// Model global step counter — seeds the stochastic-rounding streams,
    /// so it must survive a resume for bit-identity.
    pub step_count: u64,
    /// Flattened FP32 master weights.
    pub params: Vec<f32>,
    /// Optimizer momentum buffers, as exported by
    /// [`Sgd::export_velocity`](crate::model::Sgd::export_velocity).
    pub velocity: Vec<Option<(Vec<usize>, Vec<f32>)>>,
    /// Per-bucket static scales of the degree-aware policy, when active.
    pub policy_scales: Option<Vec<f32>>,
    /// Mean loss of each completed epoch (bit-exact).
    pub losses: Vec<f64>,
    /// Held-out eval of each completed epoch (bit-exact).
    pub evals: Vec<f64>,
}

/// Build the [`Fingerprint`] of a run from its config. Call with the
/// *effective* config (after `auto_bits` derivation) so the stored width is
/// the one actually training.
pub fn fingerprint_of(cfg: &crate::config::TrainConfig, workers: usize, sampled: bool) -> Fingerprint {
    Fingerprint {
        dataset: cfg.dataset.clone(),
        model: crate::config::model_name(cfg.model).to_string(),
        mode: crate::config::mode_name(&cfg.mode).to_string(),
        bits: cfg.mode.bits as u32,
        seed: cfg.seed,
        sample_seed: cfg.sampler.seed,
        workers,
        sampled,
    }
}

// ---- hex bit-pattern codecs -------------------------------------------------

/// Encode f32s as concatenated 8-hex-char bit patterns (byte-exact).
pub fn f32s_to_hex(v: &[f32]) -> String {
    let mut s = String::with_capacity(v.len() * 8);
    for f in v {
        s.push_str(&format!("{:08x}", f.to_bits()));
    }
    s
}

/// Decode a [`f32s_to_hex`] string back to floats.
pub fn hex_to_f32s(s: &str) -> Result<Vec<f32>, String> {
    if s.len() % 8 != 0 {
        return Err(format!("hex f32 payload length {} is not a multiple of 8", s.len()));
    }
    s.as_bytes()
        .chunks(8)
        .map(|c| {
            let chunk = std::str::from_utf8(c).map_err(|_| "non-ascii hex".to_string())?;
            u32::from_str_radix(chunk, 16)
                .map(f32::from_bits)
                .map_err(|_| format!("bad hex f32 chunk {chunk:?}"))
        })
        .collect()
}

/// Encode one f64 as a 16-hex-char bit pattern.
pub fn f64_to_hex(x: f64) -> String {
    format!("{:016x}", x.to_bits())
}

/// Decode a [`f64_to_hex`] string.
pub fn hex_to_f64(s: &str) -> Result<f64, String> {
    if s.len() != 16 {
        return Err(format!("hex f64 {s:?} is not 16 chars"));
    }
    u64::from_str_radix(s, 16).map(f64::from_bits).map_err(|_| format!("bad hex f64 {s:?}"))
}

// ---- JSON (de)serialization -------------------------------------------------

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn int(n: u64) -> Json {
    Json::Num(n as f64)
}

fn hexes(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Str(f64_to_hex(*x))).collect())
}

impl Checkpoint {
    /// Serialize to the deterministic `tango-ckpt/v1` JSON value.
    pub fn to_json(&self) -> Json {
        let f = &self.fingerprint;
        let c = &self.cursor;
        let velocity = Json::Arr(
            self.velocity
                .iter()
                .map(|slot| match slot {
                    None => Json::Null,
                    Some((shape, data)) => obj(vec![
                        ("shape", Json::Arr(shape.iter().map(|&d| int(d as u64)).collect())),
                        ("data", Json::Str(f32s_to_hex(data))),
                    ]),
                })
                .collect(),
        );
        obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("command", Json::Str(self.command.clone())),
            (
                "fingerprint",
                obj(vec![
                    ("dataset", Json::Str(f.dataset.clone())),
                    ("model", Json::Str(f.model.clone())),
                    ("mode", Json::Str(f.mode.clone())),
                    ("bits", int(f.bits as u64)),
                    ("seed", int(f.seed)),
                    ("sample_seed", int(f.sample_seed)),
                    ("workers", int(f.workers as u64)),
                    ("sampled", Json::Bool(f.sampled)),
                ]),
            ),
            (
                "cursor",
                obj(vec![
                    ("epoch", int(c.epoch as u64)),
                    ("step", int(c.step as u64)),
                    ("loss_sum", Json::Str(f64_to_hex(c.loss_sum))),
                    ("loss_steps", int(c.loss_steps as u64)),
                ]),
            ),
            ("step_count", int(self.step_count)),
            (
                "params",
                obj(vec![
                    ("len", int(self.params.len() as u64)),
                    ("data", Json::Str(f32s_to_hex(&self.params))),
                ]),
            ),
            ("velocity", velocity),
            (
                "policy_scales",
                match &self.policy_scales {
                    None => Json::Null,
                    Some(s) => Json::Str(f32s_to_hex(s)),
                },
            ),
            ("losses", hexes(&self.losses)),
            ("evals", hexes(&self.evals)),
        ])
    }

    /// Rebuild a checkpoint from its JSON value, rejecting wrong schemas
    /// and structurally broken documents with named-path errors.
    pub fn from_json(doc: &Json) -> crate::Result<Checkpoint> {
        let str_at = |path: &str, v: Option<&Json>| -> crate::Result<String> {
            v.and_then(|j| j.as_str())
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("checkpoint field {path} missing or not a string"))
        };
        let num_at = |path: &str, v: Option<&Json>| -> crate::Result<u64> {
            v.and_then(|j| j.as_f64())
                .filter(|n| n.fract() == 0.0 && *n >= 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| {
                    anyhow::anyhow!("checkpoint field {path} missing or not a non-negative integer")
                })
        };
        let f64_at = |path: &str, v: Option<&Json>| -> crate::Result<f64> {
            let hex = v
                .and_then(|j| j.as_str())
                .ok_or_else(|| anyhow::anyhow!("checkpoint field {path} missing or not a hex string"))?;
            hex_to_f64(hex).map_err(|e| anyhow::anyhow!("checkpoint field {path}: {e}"))
        };

        let schema = str_at("schema", doc.get("schema"))?;
        if schema != SCHEMA {
            anyhow::bail!("checkpoint schema is {schema:?}, this build reads {SCHEMA:?}");
        }

        let fp = doc
            .get("fingerprint")
            .ok_or_else(|| anyhow::anyhow!("checkpoint field fingerprint missing"))?;
        let sampled = match fp.get("sampled") {
            Some(Json::Bool(b)) => *b,
            _ => anyhow::bail!("checkpoint field fingerprint.sampled missing or not a bool"),
        };
        let fingerprint = Fingerprint {
            dataset: str_at("fingerprint.dataset", fp.get("dataset"))?,
            model: str_at("fingerprint.model", fp.get("model"))?,
            mode: str_at("fingerprint.mode", fp.get("mode"))?,
            bits: num_at("fingerprint.bits", fp.get("bits"))? as u32,
            seed: num_at("fingerprint.seed", fp.get("seed"))?,
            sample_seed: num_at("fingerprint.sample_seed", fp.get("sample_seed"))?,
            workers: num_at("fingerprint.workers", fp.get("workers"))? as usize,
            sampled,
        };

        let cur = doc.get("cursor").ok_or_else(|| anyhow::anyhow!("checkpoint field cursor missing"))?;
        let cursor = Cursor {
            epoch: num_at("cursor.epoch", cur.get("epoch"))? as usize,
            step: num_at("cursor.step", cur.get("step"))? as usize,
            loss_sum: f64_at("cursor.loss_sum", cur.get("loss_sum"))?,
            loss_steps: num_at("cursor.loss_steps", cur.get("loss_steps"))? as usize,
        };

        let pj = doc.get("params").ok_or_else(|| anyhow::anyhow!("checkpoint field params missing"))?;
        let plen = num_at("params.len", pj.get("len"))? as usize;
        let params = hex_to_f32s(str_at("params.data", pj.get("data"))?.as_str())
            .map_err(|e| anyhow::anyhow!("checkpoint field params.data: {e}"))?;
        if params.len() != plen {
            anyhow::bail!(
                "checkpoint params.data holds {} floats but params.len says {plen} \
                 (truncated or corrupted file?)",
                params.len()
            );
        }

        let vel_arr = doc
            .get("velocity")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow::anyhow!("checkpoint field velocity missing or not an array"))?;
        let mut velocity = Vec::with_capacity(vel_arr.len());
        for (i, slot) in vel_arr.iter().enumerate() {
            velocity.push(match slot {
                Json::Null => None,
                slot => {
                    let shape: Vec<usize> = slot
                        .get("shape")
                        .and_then(|j| j.as_arr())
                        .ok_or_else(|| anyhow::anyhow!("checkpoint field velocity[{i}].shape missing"))?
                        .iter()
                        .map(|d| {
                            d.as_usize().ok_or_else(|| {
                                anyhow::anyhow!("checkpoint field velocity[{i}].shape has a non-integer")
                            })
                        })
                        .collect::<crate::Result<_>>()?;
                    let data = hex_to_f32s(
                        str_at(&format!("velocity[{i}].data"), slot.get("data"))?.as_str(),
                    )
                    .map_err(|e| anyhow::anyhow!("checkpoint field velocity[{i}].data: {e}"))?;
                    if data.len() != shape.iter().product::<usize>() {
                        anyhow::bail!(
                            "checkpoint velocity[{i}] shape {shape:?} does not match {} floats",
                            data.len()
                        );
                    }
                    Some((shape, data))
                }
            });
        }

        let policy_scales = match doc.get("policy_scales") {
            None | Some(Json::Null) => None,
            Some(j) => Some(
                hex_to_f32s(
                    j.as_str()
                        .ok_or_else(|| anyhow::anyhow!("checkpoint field policy_scales not a hex string"))?,
                )
                .map_err(|e| anyhow::anyhow!("checkpoint field policy_scales: {e}"))?,
            ),
        };

        let trace = |path: &str| -> crate::Result<Vec<f64>> {
            doc.get(path)
                .and_then(|j| j.as_arr())
                .ok_or_else(|| anyhow::anyhow!("checkpoint field {path} missing or not an array"))?
                .iter()
                .enumerate()
                .map(|(i, j)| f64_at(&format!("{path}[{i}]"), Some(j)))
                .collect()
        };

        Ok(Checkpoint {
            command: str_at("command", doc.get("command"))?,
            fingerprint,
            cursor,
            step_count: num_at("step_count", doc.get("step_count"))?,
            params,
            velocity,
            policy_scales,
            losses: trace("losses")?,
            evals: trace("evals")?,
        })
    }

    /// Atomically write the checkpoint (tmp + rename) — a crash mid-save
    /// leaves any previous checkpoint at `path` intact.
    pub fn save(&self, path: &str) -> crate::Result<()> {
        crate::util::fsio::write_atomic(path, &(self.to_json().to_string() + "\n"))
            .map_err(|e| anyhow::anyhow!("saving checkpoint {path}: {e}"))?;
        crate::obs::counter_add(crate::obs::keys::CTR_CKPT_SAVES, 1);
        Ok(())
    }

    /// Load and structurally validate a checkpoint. Corrupt, truncated or
    /// wrong-schema files return errors naming the path and field — never
    /// a panic.
    pub fn load(path: &str) -> crate::Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading checkpoint {path}: {e}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint {path} is not valid JSON ({e}) — truncated write or not a tango-ckpt file?"))?;
        Self::from_json(&doc).map_err(|e| anyhow::anyhow!("checkpoint {path}: {e}"))
    }

    /// Reject a resume into a run whose configuration does not match the
    /// checkpoint's fingerprint, naming every mismatched field.
    pub fn validate_resume(&self, command: &str, expect: &Fingerprint) -> crate::Result<()> {
        let mut mismatches = Vec::new();
        if self.command != command {
            mismatches.push(format!("command: checkpoint={:?} run={command:?}", self.command));
        }
        let f = &self.fingerprint;
        if f.dataset != expect.dataset {
            mismatches.push(format!("dataset: checkpoint={:?} run={:?}", f.dataset, expect.dataset));
        }
        if f.model != expect.model {
            mismatches.push(format!("model: checkpoint={:?} run={:?}", f.model, expect.model));
        }
        if f.mode != expect.mode {
            mismatches.push(format!("mode: checkpoint={:?} run={:?}", f.mode, expect.mode));
        }
        if f.bits != expect.bits {
            mismatches.push(format!("bits: checkpoint={} run={}", f.bits, expect.bits));
        }
        if f.seed != expect.seed {
            mismatches.push(format!("seed: checkpoint={} run={}", f.seed, expect.seed));
        }
        if f.sample_seed != expect.sample_seed {
            mismatches.push(format!(
                "sample_seed: checkpoint={} run={}",
                f.sample_seed, expect.sample_seed
            ));
        }
        if f.workers != expect.workers {
            mismatches.push(format!("workers: checkpoint={} run={}", f.workers, expect.workers));
        }
        if f.sampled != expect.sampled {
            mismatches.push(format!("sampled: checkpoint={} run={}", f.sampled, expect.sampled));
        }
        if mismatches.is_empty() {
            Ok(())
        } else {
            anyhow::bail!(
                "--resume checkpoint does not match this run's configuration: {}",
                mismatches.join("; ")
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            command: "train".to_string(),
            fingerprint: Fingerprint {
                dataset: "karate".to_string(),
                model: "gcn".to_string(),
                mode: "int8".to_string(),
                bits: 8,
                seed: 7,
                sample_seed: 11,
                workers: 1,
                sampled: true,
            },
            cursor: Cursor { epoch: 2, step: 3, loss_sum: 1.25e-3, loss_steps: 3 },
            step_count: 13,
            params: vec![1.0, -0.5, f32::MIN_POSITIVE, 0.0],
            velocity: vec![None, Some((vec![2, 2], vec![0.1, 0.2, 0.3, 0.4]))],
            policy_scales: Some(vec![0.5, 0.25]),
            losses: vec![0.9, 0.8],
            evals: vec![0.5, 0.6],
        }
    }

    #[test]
    fn hex_codecs_roundtrip_bit_patterns() {
        let vals = [0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, f32::MAX, -123.456, f32::NAN];
        let back = hex_to_f32s(&f32s_to_hex(&vals)).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for x in [0.0f64, -1.0, 1e-300, f64::MAX] {
            assert_eq!(hex_to_f64(&f64_to_hex(x)).unwrap().to_bits(), x.to_bits());
        }
        assert!(hex_to_f32s("abc").is_err());
        assert!(hex_to_f64("zz").is_err());
        assert!(hex_to_f32s("zzzzzzzz").is_err());
    }

    #[test]
    fn checkpoint_roundtrips_through_json() {
        let ck = sample();
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn save_load_roundtrips_and_is_newline_terminated() {
        let path = std::env::temp_dir().join("tango_ckpt_roundtrip.json");
        let path = path.to_str().unwrap();
        let ck = sample();
        ck.save(path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.ends_with('\n'));
        assert_eq!(Checkpoint::load(path).unwrap(), ck);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn wrong_schema_and_missing_fields_are_named_errors() {
        let e = Checkpoint::from_json(&Json::parse(r#"{"schema":"tango-ckpt/v9"}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(e.contains("tango-ckpt/v9"), "{e}");
        let mut doc = sample().to_json();
        if let Json::Obj(m) = &mut doc {
            m.remove("cursor");
        }
        let e = Checkpoint::from_json(&doc).unwrap_err().to_string();
        assert!(e.contains("cursor"), "{e}");
    }

    #[test]
    fn truncated_params_are_detected() {
        let mut doc = sample().to_json();
        if let Json::Obj(m) = &mut doc {
            let Some(Json::Obj(p)) = m.get_mut("params") else { panic!() };
            let Some(Json::Str(s)) = p.get_mut("data") else { panic!() };
            s.truncate(8); // one float left, len still says 4
        }
        let e = Checkpoint::from_json(&doc).unwrap_err().to_string();
        assert!(e.contains("truncated"), "{e}");
    }

    #[test]
    fn fingerprint_mismatch_names_every_field() {
        let ck = sample();
        let mut other = ck.fingerprint.clone();
        other.model = "gat".to_string();
        other.seed = 8;
        let e = ck.validate_resume("train", &other).unwrap_err().to_string();
        assert!(e.contains("model") && e.contains("seed"), "{e}");
        assert!(!e.contains("dataset:"), "matching fields stay out of the message: {e}");
        let e = ck.validate_resume("multigpu", &ck.fingerprint).unwrap_err().to_string();
        assert!(e.contains("command"), "{e}");
        ck.validate_resume("train", &ck.fingerprint).unwrap();
    }
}
