//! RAII timers: hierarchical [`span`]s and flat [`timed`] histogram guards.
//!
//! A span pushes its name onto a thread-local path stack on creation and,
//! on drop, records its elapsed time against the `/`-joined path — so
//! `span("epoch")` enclosing `span("eval")` yields registry entries
//! `"epoch"` and `"epoch/eval"`, and stats aggregate per *position in the
//! call tree*, not just per name. Paths are per-thread: a producer thread's
//! `"stage1/gather"` does not nest under the consumer's `"epoch"`.
//!
//! [`timed`] is the flat variant for hot primitives (`spmm`, `qgemm`):
//! one histogram per static name regardless of caller, so per-call latency
//! distributions stay comparable across every call site.
//!
//! Both guards are inert (no clock read, no thread-local touch) when
//! tracing is [disabled](super::enabled).
//!
//! When event-timeline collection is on ([`super::trace_enabled`]) the same
//! guards additionally emit Chrome trace `B`/`E` events — bare segment
//! names, not joined paths, so every event name resolves in
//! [`super::keys`] — on open and drop; the aggregate registry and the
//! timeline stay independently switchable.

use super::{registry, trace};
use std::cell::RefCell;
use std::time::Instant;

thread_local! {
    /// Current `/`-joined span path plus the stack of lengths to truncate
    /// back to on pop (avoids re-joining segments on every drop).
    static PATH: RefCell<(String, Vec<usize>)> =
        const { RefCell::new((String::new(), Vec::new())) };
}

/// RAII guard for one hierarchical span; records on drop.
#[must_use = "a span measures the scope it lives in; binding to _ drops it immediately"]
pub struct Span {
    start: Option<Instant>,
    metered: bool,
    /// Bare segment name to close the trace `E` event with, when traced.
    trace_name: Option<String>,
}

/// Open a hierarchical span named `name` on this thread. Returns a guard
/// that records `<parent-path>/<name>` when dropped. No-op while disabled.
pub fn span(name: &str) -> Span {
    let metered = registry::enabled();
    let traced = trace::enabled();
    if !metered && !traced {
        return Span { start: None, metered: false, trace_name: None };
    }
    if metered {
        PATH.with(|p| {
            let (path, stack) = &mut *p.borrow_mut();
            stack.push(path.len());
            if !path.is_empty() {
                path.push('/');
            }
            path.push_str(name);
        });
    }
    let trace_name = if traced {
        trace::emit_begin(name);
        Some(name.to_string())
    } else {
        None
    };
    Span { start: Some(Instant::now()), metered, trace_name }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        if self.metered {
            let secs = start.elapsed().as_secs_f64();
            PATH.with(|p| {
                let (path, stack) = &mut *p.borrow_mut();
                registry::record_span(path, secs);
                if let Some(len) = stack.pop() {
                    path.truncate(len);
                }
            });
        }
        if let Some(name) = self.trace_name.take() {
            trace::emit_end(&name);
        }
    }
}

/// RAII guard for one flat histogram observation; records on drop.
#[must_use = "a timer measures the scope it lives in; binding to _ drops it immediately"]
pub struct Timed {
    name: &'static str,
    start: Option<Instant>,
    metered: bool,
    traced: bool,
}

/// Time a scope into the flat histogram `name`. No-op while disabled.
pub fn timed(name: &'static str) -> Timed {
    let metered = registry::enabled();
    let traced = trace::enabled();
    if !metered && !traced {
        return Timed { name, start: None, metered, traced };
    }
    if traced {
        trace::emit_begin(name);
    }
    Timed { name, start: Some(Instant::now()), metered, traced }
}

impl Drop for Timed {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        if self.metered {
            registry::observe(self.name, start.elapsed().as_secs_f64());
        }
        if self.traced {
            trace::emit_end(self.name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_spans_record_full_paths() {
        {
            let _a = span("test.span.outer");
            let _b = span("test.span.inner");
        }
        let snap = registry::snapshot();
        assert!(snap.spans.contains_key("test.span.outer"), "{:?}", snap.spans.keys());
        assert!(snap.spans.contains_key("test.span.outer/test.span.inner"));
    }

    #[test]
    fn sibling_spans_share_a_parent_path() {
        {
            let _a = span("test.span.parent");
            {
                let _x = span("x");
            }
            {
                let _y = span("y");
            }
        }
        let snap = registry::snapshot();
        assert!(snap.spans.contains_key("test.span.parent/x"));
        assert!(snap.spans.contains_key("test.span.parent/y"));
    }

    #[test]
    fn timed_records_flat_histogram() {
        {
            let _t = timed("test.span.timed");
        }
        let snap = registry::snapshot();
        let h = snap.hists.get("test.span.timed").expect("histogram exists");
        assert!(h.count() >= 1);
    }
}
