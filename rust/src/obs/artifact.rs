//! Structured JSON run artifacts (`--metrics-out`).
//!
//! One assembly path serves the CLI and the golden-schema test
//! (`tests/metrics_schema.rs`): [`train_artifact`] / [`multigpu_artifact`]
//! build a [`Json`] tree with a **stable top-level key set** —
//!
//! `schema, command, config, epochs, report, counters, gauges, histograms,
//! spans, cache, policy, fault`
//!
//! — where absent sections are `null`, never missing, so downstream
//! tooling can index unconditionally. Every epoch entry carries the same
//! 7-key `stages` object (`sample_s, gather_s, wait_s, compute_s, comm_s,
//! eval_s, wall_s`; single-GPU runs report `comm_s = 0`, multi-GPU runs
//! `eval_s = 0`), and every histogram/span carries `p50/p95/p99`.

use super::registry::{Metrics, SpanStat};
use crate::config::{mode_name, TrainConfig};
use crate::coordinator::qcache::CacheStats;
use crate::coordinator::{EpochStages, TrainReport};
use crate::multigpu::{MultiGpuConfig, MultiGpuReport};
use crate::policy::PolicyGatherReport;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Artifact schema identifier (bump on breaking shape changes).
pub const SCHEMA: &str = "tango-metrics/v1";

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn int(v: u64) -> Json {
    Json::Num(v as f64)
}

fn s(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn stages_json(st: &EpochStages, comm_s: f64) -> Json {
    obj(vec![
        ("sample_s", num(st.sample_s)),
        ("gather_s", num(st.gather_s)),
        ("wait_s", num(st.wait_s)),
        ("compute_s", num(st.compute_s)),
        ("comm_s", num(comm_s)),
        ("eval_s", num(st.eval_s)),
        ("wall_s", num(st.wall_s)),
    ])
}

fn hist_json(h: &super::hist::Histogram) -> Json {
    obj(vec![
        ("count", int(h.count())),
        ("sum_s", num(h.sum())),
        ("mean_s", num(h.mean())),
        ("min_s", num(h.min())),
        ("max_s", num(h.max())),
        ("p50_s", num(h.percentile(0.50))),
        ("p95_s", num(h.percentile(0.95))),
        ("p99_s", num(h.percentile(0.99))),
    ])
}

fn span_json(sp: &SpanStat) -> Json {
    obj(vec![
        ("calls", int(sp.calls)),
        ("total_s", num(sp.total_s)),
        ("mean_s", num(sp.hist.mean())),
        ("p50_s", num(sp.hist.percentile(0.50))),
        ("p95_s", num(sp.hist.percentile(0.95))),
        ("p99_s", num(sp.hist.percentile(0.99))),
        ("max_s", num(sp.hist.max())),
    ])
}

fn metrics_json(m: &Metrics) -> (Json, Json, Json, Json) {
    let counters: BTreeMap<String, Json> =
        m.counters.iter().map(|(k, &v)| (k.clone(), int(v))).collect();
    let gauges: BTreeMap<String, Json> =
        m.gauges.iter().map(|(k, &v)| (k.clone(), num(v))).collect();
    let hists: BTreeMap<String, Json> =
        m.hists.iter().map(|(k, h)| (k.clone(), hist_json(h))).collect();
    let spans: BTreeMap<String, Json> =
        m.spans.iter().map(|(k, sp)| (k.clone(), span_json(sp))).collect();
    (Json::Obj(counters), Json::Obj(gauges), Json::Obj(hists), Json::Obj(spans))
}

fn cache_json(c: Option<&CacheStats>) -> Json {
    match c {
        None => Json::Null,
        Some(c) => obj(vec![
            ("hits", int(c.hits)),
            ("misses", int(c.misses)),
            ("evictions", int(c.evictions)),
        ]),
    }
}

fn policy_json(p: Option<&PolicyGatherReport>) -> Json {
    let Some(p) = p else { return Json::Null };
    let buckets: Vec<Json> = p
        .buckets
        .iter()
        .map(|b| {
            obj(vec![
                ("rows", int(b.rows)),
                ("hits", int(b.hits)),
                ("misses", int(b.misses)),
                ("packed_bytes", int(b.packed_bytes)),
                ("int8_bytes", int(b.int8_bytes)),
                ("error_x", b.mean_error().map(num).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    obj(vec![
        ("boundaries", Json::Arr(p.boundaries.iter().map(|&b| int(b as u64)).collect())),
        ("bits", Json::Arr(p.bits.iter().map(|&b| int(b as u64)).collect())),
        ("node_counts", Json::Arr(p.node_counts.iter().map(|&n| int(n)).collect())),
        ("buckets", Json::Arr(buckets)),
        ("packed_bytes", int(p.packed_bytes())),
        ("int8_bytes", int(p.int8_bytes())),
    ])
}

fn fault_json(f: Option<&crate::fault::FaultReport>) -> Json {
    let Some(f) = f else { return Json::Null };
    obj(vec![
        ("producer_panics", int(f.producer_panics)),
        ("producer_restarts", int(f.producer_restarts)),
        ("worker_failures", int(f.worker_failures)),
        ("worker_rebuilds", int(f.worker_rebuilds)),
        ("link_drops", int(f.link_drops)),
        ("link_retries", int(f.link_retries)),
        ("allreduce_degraded", int(f.allreduce_degraded)),
        ("lock_poisons", int(f.lock_poisons)),
        ("lock_recoveries", int(f.lock_recoveries)),
        ("backoff_s", num(f.backoff_s)),
        ("flight_dumps", int(f.flight_dumps)),
    ])
}

fn train_config_json(cfg: &TrainConfig) -> Json {
    obj(vec![
        ("model", s(format!("{:?}", cfg.model).to_lowercase())),
        ("dataset", s(cfg.dataset.clone())),
        ("mode", s(mode_name(&cfg.mode))),
        ("bits", int(cfg.mode.bits as u64)),
        ("epochs", int(cfg.epochs as u64)),
        ("lr", num(cfg.lr as f64)),
        ("hidden", int(cfg.hidden as u64)),
        ("heads", int(cfg.heads as u64)),
        ("layers", int(cfg.layers as u64)),
        ("seed", int(cfg.seed)),
        ("packed_compute", Json::Bool(cfg.packed_compute)),
        (
            "sampler",
            obj(vec![
                ("enabled", Json::Bool(cfg.sampler.enabled)),
                ("degree_biased", Json::Bool(cfg.sampler.degree_biased)),
                (
                    "fanouts",
                    Json::Arr(cfg.sampler.fanouts.iter().map(|&f| int(f as u64)).collect()),
                ),
                ("batch_size", int(cfg.sampler.batch_size as u64)),
                ("seed", int(cfg.sampler.seed)),
                ("cache_nodes", int(cfg.sampler.cache_nodes as u64)),
                ("prefetch", int(cfg.sampler.prefetch as u64)),
            ]),
        ),
        (
            "policy",
            obj(vec![
                (
                    "degree_buckets",
                    Json::Arr(cfg.policy.degree_buckets.iter().map(|&b| int(b as u64)).collect()),
                ),
                (
                    "bucket_bits",
                    Json::Arr(cfg.policy.bucket_bits.iter().map(|&b| int(b as u64)).collect()),
                ),
            ]),
        ),
    ])
}

/// Assemble the `tango train` run artifact.
pub fn train_artifact(cfg: &TrainConfig, report: &TrainReport, metrics: &Metrics) -> Json {
    let epochs: Vec<Json> = report
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| {
            obj(vec![
                ("epoch", int(i as u64)),
                ("loss", num(report.losses.get(i).copied().unwrap_or(0.0) as f64)),
                ("eval", num(report.evals.get(i).copied().unwrap_or(0.0) as f64)),
                ("stages", stages_json(st, 0.0)),
            ])
        })
        .collect();
    let totals = report.stage_totals();
    let (counters, gauges, histograms, spans) = metrics_json(metrics);
    obj(vec![
        ("schema", s(SCHEMA)),
        ("command", s("train")),
        ("config", train_config_json(cfg)),
        ("epochs", Json::Arr(epochs)),
        (
            "report",
            obj(vec![
                ("final_eval", num(report.final_eval as f64)),
                ("bits", int(report.bits as u64)),
                ("epochs_to_converge", int(report.epochs_to_converge as u64)),
                ("wall_secs", num(report.wall_secs)),
                ("prefetch_wait_s", num(report.prefetch_wait_s)),
                ("cache_bytes", int(report.cache_bytes as u64)),
                ("stage_totals", stages_json(&totals, 0.0)),
            ]),
        ),
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", histograms),
        ("spans", spans),
        ("cache", cache_json(report.cache.as_ref())),
        ("policy", policy_json(report.policy.as_ref())),
        ("fault", fault_json(report.fault.as_ref())),
    ])
}

/// Assemble the `tango multigpu` run artifact.
pub fn multigpu_artifact(
    cfg: &MultiGpuConfig,
    report: &MultiGpuReport,
    metrics: &Metrics,
) -> Json {
    let epochs: Vec<Json> = report
        .epochs
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let st = EpochStages {
                sample_s: e.sample_s,
                gather_s: e.gather_s,
                wait_s: e.wait_s,
                compute_s: e.compute_s,
                eval_s: 0.0,
                wall_s: e.total(),
            };
            obj(vec![
                ("epoch", int(i as u64)),
                ("steps", int(e.steps as u64)),
                ("loss", num(e.loss as f64)),
                ("stages", stages_json(&st, e.comm_s)),
            ])
        })
        .collect();
    let (counters, gauges, histograms, spans) = metrics_json(metrics);
    obj(vec![
        ("schema", s(SCHEMA)),
        ("command", s("multigpu")),
        (
            "config",
            obj(vec![
                ("train", train_config_json(&cfg.train)),
                ("workers", int(cfg.workers as u64)),
                ("epochs", int(cfg.epochs as u64)),
                ("quantize_grads", Json::Bool(cfg.quantize_grads)),
            ]),
        ),
        ("epochs", Json::Arr(epochs)),
        (
            "report",
            obj(vec![
                ("total_time_s", num(report.total_time())),
                ("grad_elems", int(report.grad_elems as u64)),
                ("cache_bytes", int(report.cache_bytes as u64)),
            ]),
        ),
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", histograms),
        ("spans", spans),
        ("cache", cache_json(report.cache.as_ref())),
        ("policy", policy_json(report.policy.as_ref())),
        ("fault", fault_json(report.fault.as_ref())),
    ])
}

/// Serialize an artifact to `path` (pretty-printing is the consumer's job —
/// the writer emits the deterministic single-line form of `util/json.rs`).
/// Atomic (tmp + rename): a crash mid-write never truncates an artifact.
pub fn write_artifact(path: &str, artifact: &Json) -> crate::Result<()> {
    crate::util::fsio::write_atomic(path, &artifact.to_string())
        .map_err(|e| anyhow::anyhow!("writing metrics artifact {path}: {e}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    #[test]
    fn stage_json_always_has_the_seven_keys() {
        let st = EpochStages::default();
        let j = stages_json(&st, 0.0);
        let Json::Obj(map) = j else { panic!("stages must be an object") };
        let keys: Vec<&str> = map.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            vec!["comm_s", "compute_s", "eval_s", "gather_s", "sample_s", "wait_s", "wall_s"]
        );
    }

    #[test]
    fn hist_json_carries_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=20 {
            h.record(i as f64 * 1e-3);
        }
        let Json::Obj(map) = hist_json(&h) else { panic!() };
        for k in ["count", "p50_s", "p95_s", "p99_s", "mean_s", "max_s"] {
            assert!(map.contains_key(k), "missing {k}");
        }
        let p50 = map["p50_s"].as_f64().unwrap();
        let p99 = map["p99_s"].as_f64().unwrap();
        assert!(p50 <= p99);
    }
}
