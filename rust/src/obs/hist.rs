//! Log-bucketed latency histogram.
//!
//! Buckets grow by powers of two from a 1 ns floor, so 64 buckets span
//! sub-nanosecond to ~584 years with a worst-case quantile error of 2×.
//! Exact `min`/`max`/`sum` ride along, and percentiles are clamped to the
//! observed `[min, max]` — the quantile function is monotone in `q` and
//! `p50 <= p95 <= p99 <= max` holds by construction (the property
//! `tests/obs_invariants.rs` fuzzes).

/// Number of power-of-two buckets.
const BUCKETS: usize = 64;
/// Lower resolution bound, seconds (1 ns).
const BASE: f64 = 1e-9;

/// A mergeable log-bucketed histogram over non-negative durations (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: [u64; BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; BUCKETS], count: 0, sum: 0.0, min: f64::INFINITY, max: 0.0 }
    }
}

/// Bucket index for a duration: bucket 0 holds `v <= 1ns`, bucket `i` holds
/// `(2^{i-1}, 2^i]` ns, the last bucket catches everything larger.
fn bucket_of(v: f64) -> usize {
    if !(v > BASE) {
        return 0;
    }
    (((v / BASE).log2().ceil()) as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `i`, in seconds.
fn bucket_upper(i: usize) -> f64 {
    BASE * (1u64 << i.min(62)) as f64
}

impl Histogram {
    /// Record one observation (negative/NaN values clamp to 0).
    pub fn record(&mut self, secs: f64) {
        let v = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observations, seconds.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean observation, seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest observation (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation (0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate `q`-quantile (`q` in `[0, 1]`): the upper bound of the
    /// bucket holding the rank-`ceil(q·count)` observation, clamped to the
    /// exact observed `[min, max]`. Monotone in `q`; 0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one. Merging is associative and
    /// commutative (bucket-wise sums + min/max folds).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn single_observation_percentiles_are_exact() {
        let mut h = Histogram::default();
        h.record(3.5e-3);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 3.5e-3, "q={q}");
        }
    }

    #[test]
    fn percentile_within_2x_of_true_value() {
        let mut h = Histogram::default();
        // 100 observations 1ms..100ms.
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let p50 = h.percentile(0.5);
        // True p50 = 50ms; bucket bound error is <= 2x, clamped to max.
        assert!(p50 >= 50e-3 && p50 <= 100e-3, "p50={p50}");
        assert!(h.percentile(0.99) <= h.max());
    }

    #[test]
    fn monotone_in_q() {
        let mut h = Histogram::default();
        for i in 0..1000u64 {
            h.record((i as f64 * 0.37).sin().abs() * 1e-2 + 1e-6);
        }
        let mut prev = 0.0;
        for i in 0..=100 {
            let v = h.percentile(i as f64 / 100.0);
            assert!(v >= prev, "q={} gave {v} < {prev}", i as f64 / 100.0);
            prev = v;
        }
        assert!(prev <= h.max());
    }

    #[test]
    fn merge_matches_combined_recording() {
        let vals_a = [1e-6, 5e-4, 2e-3];
        let vals_b = [9e-7, 1e-1, 3e-5, 4e-2];
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut both = Histogram::default();
        for v in vals_a {
            a.record(v);
            both.record(v);
        }
        for v in vals_b {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a, both);
    }
}
