//! Zero-dependency tracing + metrics (the observability layer).
//!
//! Tango's argument is a time budget — quantization overhead hidden behind
//! sampling, primitives made faster — so the reproduction needs trustworthy
//! per-stage numbers, not coarse aggregates. This module provides them
//! without perturbing what it measures:
//!
//! - [`span`] — RAII hierarchical timers keyed by the `/`-joined path of
//!   the enclosing spans on the same thread (`"epoch/eval"`,
//!   `"stage1/gather"`), aggregated in a thread-safe registry;
//! - [`timed`] — flat per-call latency histograms for hot primitives
//!   (`prim.qgemm`, `prim.spmm.*`, `allreduce.ring`);
//! - [`counter_add`] / [`gauge_set`] — named counters (rows gathered,
//!   cache hits/misses, packed wire bytes) and gauges (per-bucket mean
//!   `Error_X`);
//! - [`keys`] — the central registry of span/counter/gauge key strings;
//!   call sites name keys via these constants only (audit rule O1);
//! - [`Histogram`] — log-bucketed latencies with `p50/p95/p99`;
//! - [`train_artifact`] / [`multigpu_artifact`] / [`write_artifact`] — the
//!   `--metrics-out` structured JSON run artifact;
//! - the event timeline ([`trace_enabled`] / [`set_trace_enabled`],
//!   [`instant`], [`trace_pid_scope`], [`export_trace`] / [`write_trace`])
//!   — per-thread bounded rings of `B/E/i/C` events on a run-relative
//!   clock, fed by the same `span`/`timed`/`counter_add` entry points,
//!   exported via `--trace-out` as Perfetto-loadable Chrome trace JSON
//!   (`tango-trace/v1`) — the artifact that *shows* the producer-thread
//!   prefetch overlapping compute;
//! - the fault flight recorder ([`set_flight_recorder`], [`flight_dump`])
//!   — on every fault-harness recovery (and trainer error return) the
//!   last-N events per thread are dumped atomically beside the metrics
//!   artifact, a post-mortem whose final events name the recovery taken.
//!
//! **Off means off**: every recording entry point checks [`enabled`] with
//! one relaxed atomic load and returns before reading a clock or touching
//! the registry. Tracing starts on; `TANGO_TRACE=0` (or `[metrics]
//! trace = false` / `--trace false`) disables it. On or off, the
//! instrumentation only *reads* training values — losses, weights and RNG
//! streams are bit-identical either way (`tests/obs_invariants.rs`).
//!
//! The registry is process-global and accumulates across runs in one
//! process; per-run numbers that feed reports
//! ([`TrainReport::stages`](crate::coordinator::TrainReport)) use run-local
//! accounting ([`StageTimes`](crate::sampler::StageTimes)) instead, so
//! parallel test threads cannot contaminate each other.

mod artifact;
mod hist;
pub mod keys;
mod registry;
mod span;
mod trace;

pub use artifact::{multigpu_artifact, train_artifact, write_artifact, SCHEMA};
pub use hist::Histogram;
pub use registry::{
    counter_add, enabled, gauge_set, observe, reset, set_enabled, snapshot, Metrics, SpanStat,
};
pub use span::{span, timed, Span, Timed};
pub use trace::{
    current_pid as trace_current_pid, enabled as trace_enabled, export as export_trace,
    flight_dump, instant, pid_scope as trace_pid_scope, set_enabled as set_trace_enabled,
    set_flight_recorder, write as write_trace, PidScope, TRACE_SCHEMA,
};
