//! Central registry of every observability key string.
//!
//! The metrics artifact (`tango-metrics/v1`) is a consumed schema: span
//! paths, counter names and gauge names end up in JSON that downstream
//! tooling (and `tests/metrics_schema.rs`) keys on. An inline string at a
//! call site can drift — renamed in one place, stale in the artifact —
//! without any compiler help. So every `span` / `timed` / `counter_add` /
//! `gauge_set` / `instant` key lives here as a named constant, and the
//! `tango-audit` O1 rule rejects string literals at obs call sites outside
//! this module — trace event names included, so every name in a
//! `tango-trace/v1` timeline resolves right here.
//!
//! Dynamic keys (the per-bucket `Error_X` gauges) get constructor
//! functions instead of constants, keeping the naming scheme pinned in
//! exactly one place.

// ---- hierarchical span segments (obs::span) --------------------------------

/// Per-epoch span enclosing one full training epoch (full-graph + sampled).
pub const SPAN_EPOCH: &str = "epoch";
/// Held-out evaluation inside an epoch (`epoch/eval` in the artifact).
pub const SPAN_EVAL: &str = "eval";
/// Model forward/backward/step over one batch (`epoch/compute`).
pub const SPAN_COMPUTE: &str = "compute";
/// Per-epoch span of the multi-GPU coordinator loop.
pub const SPAN_MG_EPOCH: &str = "mg_epoch";
/// One worker's compute+allreduce step inside `mg_epoch`.
pub const SPAN_WORKER_STEP: &str = "worker_step";
/// Producer-side stage-1 (sample + quantized gather) in the prefetch pipeline.
pub const SPAN_STAGE1: &str = "stage1";
/// Neighbor sampling inside `stage1` (or inline when prefetch is off).
pub const SPAN_SAMPLE: &str = "sample";
/// Quantized feature gather inside `stage1` (or inline).
pub const SPAN_GATHER: &str = "gather";

// ---- flat per-call histograms (obs::timed) ---------------------------------

/// Ring all-reduce of one gradient tensor.
pub const TIMED_ALLREDUCE_RING: &str = "allreduce.ring";
/// Edge-weighted FP32 SPMM.
pub const TIMED_PRIM_SPMM_EDGE_WEIGHTED: &str = "prim.spmm.edge_weighted";
/// Edge-weighted SPMM over quantized features.
pub const TIMED_PRIM_QSPMM_EDGE_WEIGHTED: &str = "prim.qspmm.edge_weighted";
/// CSR-ordered FP32 SPMM.
pub const TIMED_PRIM_SPMM_CSR: &str = "prim.spmm.csr";
/// Quantize-then-multiply GEMM.
pub const TIMED_PRIM_QGEMM: &str = "prim.qgemm";
/// GEMM over an already-quantized left operand.
pub const TIMED_PRIM_QGEMM_PREQUANTIZED: &str = "prim.qgemm.prequantized";
/// Multi-layer neighbor-block sampling for one minibatch.
pub const TIMED_SAMPLER_SAMPLE_BLOCKS: &str = "sampler.sample_blocks";
/// Edge-weighted SPMM computing directly on bit-packed sub-byte rows.
pub const TIMED_PRIM_PACKED_SPMM: &str = "prim.packed.spmm";
/// Dense GEMM over a bit-packed left operand (per-row scales).
pub const TIMED_PRIM_PACKED_QGEMM: &str = "prim.packed.qgemm";

// ---- counters (obs::counter_add) -------------------------------------------

/// Bytes actually moved on the simulated wire by quantized all-reduce.
pub const CTR_MULTIGPU_ALLREDUCE_WIRE_BYTES: &str = "multigpu.allreduce_wire_bytes";
/// Gradient elements all-reduced.
pub const CTR_MULTIGPU_ALLREDUCE_ELEMS: &str = "multigpu.allreduce_elems";
/// Batches fully prepared by the prefetch producer.
pub const CTR_PIPELINE_BATCHES_PREPARED: &str = "pipeline.batches_prepared";
/// Feature rows gathered (cache hits + misses).
pub const CTR_GATHER_ROWS: &str = "gather.rows";
/// Gather rows served from the quantized cache.
pub const CTR_GATHER_CACHE_HITS: &str = "gather.cache_hits";
/// Gather rows quantized on demand (cache misses).
pub const CTR_GATHER_CACHE_MISSES: &str = "gather.cache_misses";
/// Bytes of sub-byte packed payload produced by gathers.
pub const CTR_GATHER_PACKED_BYTES: &str = "gather.packed_bytes";
/// Bytes after unpacking to int8 working format.
pub const CTR_GATHER_INT8_BYTES: &str = "gather.int8_bytes";
/// Checkpoints written (`tango-ckpt/v1`, atomic tmp+rename).
pub const CTR_CKPT_SAVES: &str = "ckpt.saves";
/// Training runs restored from a checkpoint (`--resume`).
pub const CTR_CKPT_RESUMES: &str = "ckpt.resumes";
/// Injected prefetch-producer panics observed by the trainer.
pub const CTR_FAULT_PRODUCER_PANICS: &str = "fault.producer.panics";
/// Producer threads restarted after an injected panic.
pub const CTR_FAULT_PRODUCER_RESTARTS: &str = "fault.producer.restarts";
/// Injected multi-GPU worker step failures.
pub const CTR_FAULT_WORKER_FAILURES: &str = "fault.worker.failures";
/// Workers rebuilt from round-entry state and replayed.
pub const CTR_FAULT_WORKER_REBUILDS: &str = "fault.worker.rebuilds";
/// Injected all-reduce link drops.
pub const CTR_FAULT_LINK_DROPS: &str = "fault.link.drops";
/// All-reduce retries after a dropped link (re-charged transfer time).
pub const CTR_FAULT_LINK_RETRIES: &str = "fault.link.retries";
/// All-reduce rounds that degraded to skip-straggler after retry exhaustion.
pub const CTR_FAULT_ALLREDUCE_DEGRADED: &str = "fault.allreduce.degraded";
/// Injected feature-store lock poisonings.
pub const CTR_FAULT_LOCK_POISONS: &str = "fault.lock.poisons";
/// Poisoned locks recovered via `into_inner` and verified re-lockable.
pub const CTR_FAULT_LOCK_RECOVERIES: &str = "fault.lock.recoveries";
/// Flight-recorder dumps written on fault recoveries / trainer errors.
pub const CTR_FAULT_FLIGHT_DUMPS: &str = "fault.flight.dumps";

// ---- trace instant events (obs::instant) -----------------------------------
//
// Point events on the trace timeline marking a recovery path taken; each
// doubles as the `reason` of the flight-recorder dump it triggers.

/// A prefetch producer thread was restarted after an injected panic.
pub const EVT_RECOVERY_PRODUCER_RESTART: &str = "recovery.producer_restart";
/// A failed worker was rebuilt from a peer and its step replayed.
pub const EVT_RECOVERY_WORKER_REBUILD: &str = "recovery.worker_rebuild";
/// A dropped all-reduce link was retried (transfer time re-charged).
pub const EVT_RECOVERY_LINK_RETRY: &str = "recovery.link_retry";
/// All-reduce degraded to skip-straggler after retry exhaustion.
pub const EVT_RECOVERY_ALLREDUCE_DEGRADE: &str = "recovery.allreduce_degrade";
/// A poisoned feature-store lock was recovered via `into_inner`.
pub const EVT_RECOVERY_LOCK: &str = "recovery.lock_recovered";
/// A trainer returned an error to the CLI (post-mortem dump trigger).
pub const EVT_TRAINER_ERROR: &str = "recovery.trainer_error";

// ---- dynamic gauge families (obs::gauge_set) -------------------------------

/// Gauge name for the mean quantization `Error_X` of degree bucket `b`
/// (paper Fig. 4's per-bucket error decomposition).
pub fn gather_error_x_bucket(b: usize) -> String {
    format!("gather.error_x.bucket{b}")
}

/// Every static key, for schema tests and exhaustive artifact checks.
pub const ALL_STATIC_KEYS: &[&str] = &[
    SPAN_EPOCH,
    SPAN_EVAL,
    SPAN_COMPUTE,
    SPAN_MG_EPOCH,
    SPAN_WORKER_STEP,
    SPAN_STAGE1,
    SPAN_SAMPLE,
    SPAN_GATHER,
    TIMED_ALLREDUCE_RING,
    TIMED_PRIM_SPMM_EDGE_WEIGHTED,
    TIMED_PRIM_QSPMM_EDGE_WEIGHTED,
    TIMED_PRIM_SPMM_CSR,
    TIMED_PRIM_QGEMM,
    TIMED_PRIM_QGEMM_PREQUANTIZED,
    TIMED_SAMPLER_SAMPLE_BLOCKS,
    TIMED_PRIM_PACKED_SPMM,
    TIMED_PRIM_PACKED_QGEMM,
    CTR_MULTIGPU_ALLREDUCE_WIRE_BYTES,
    CTR_MULTIGPU_ALLREDUCE_ELEMS,
    CTR_PIPELINE_BATCHES_PREPARED,
    CTR_GATHER_ROWS,
    CTR_GATHER_CACHE_HITS,
    CTR_GATHER_CACHE_MISSES,
    CTR_GATHER_PACKED_BYTES,
    CTR_GATHER_INT8_BYTES,
    CTR_CKPT_SAVES,
    CTR_CKPT_RESUMES,
    CTR_FAULT_PRODUCER_PANICS,
    CTR_FAULT_PRODUCER_RESTARTS,
    CTR_FAULT_WORKER_FAILURES,
    CTR_FAULT_WORKER_REBUILDS,
    CTR_FAULT_LINK_DROPS,
    CTR_FAULT_LINK_RETRIES,
    CTR_FAULT_ALLREDUCE_DEGRADED,
    CTR_FAULT_LOCK_POISONS,
    CTR_FAULT_LOCK_RECOVERIES,
    CTR_FAULT_FLIGHT_DUMPS,
    EVT_RECOVERY_PRODUCER_RESTART,
    EVT_RECOVERY_WORKER_REBUILD,
    EVT_RECOVERY_LINK_RETRY,
    EVT_RECOVERY_ALLREDUCE_DEGRADE,
    EVT_RECOVERY_LOCK,
    EVT_TRAINER_ERROR,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_unique_and_nonempty() {
        let mut seen = std::collections::BTreeSet::new();
        for k in ALL_STATIC_KEYS {
            assert!(!k.is_empty());
            assert!(seen.insert(*k), "duplicate obs key {k}");
        }
    }

    #[test]
    fn dynamic_gauge_names_are_stable() {
        assert_eq!(gather_error_x_bucket(0), "gather.error_x.bucket0");
        assert_eq!(gather_error_x_bucket(3), "gather.error_x.bucket3");
    }
}
