//! Global metrics registry: named counters, gauges, histograms and
//! hierarchical span stats behind one mutex, plus the process-wide enable
//! flag (`TANGO_TRACE=0|false|off` disables at startup; config/CLI can flip
//! it with [`set_enabled`]).
//!
//! Every recording entry point checks [`enabled`] with a single relaxed
//! atomic load and returns before touching the mutex or formatting any
//! name — disabled tracing costs one branch, which is what keeps the
//! bit-identity and bench guarantees intact (timers never touch RNG state
//! or training values either way; see `tests/obs_invariants.rs`).
//!
//! The registry accumulates over the whole process. CLI runs snapshot it
//! once at exit for the `--metrics-out` artifact; per-run *reports*
//! ([`TrainReport`](crate::coordinator::TrainReport) stage budgets) use
//! run-local accounting instead, so parallel test threads sharing this
//! global cannot contaminate each other's numbers.

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

/// Aggregate stats for one span path (e.g. `"epoch/stage1/gather"`).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct SpanStat {
    /// Number of times the span closed.
    pub calls: u64,
    /// Total time spent inside, seconds.
    pub total_s: f64,
    /// Per-call latency distribution.
    pub hist: Histogram,
}

impl SpanStat {
    fn record(&mut self, secs: f64) {
        self.calls += 1;
        self.total_s += secs;
        self.hist.record(secs);
    }

    /// Fold another span's stats in (associative, commutative).
    pub fn merge(&mut self, other: &SpanStat) {
        self.calls += other.calls;
        self.total_s += other.total_s;
        self.hist.merge(&other.hist);
    }
}

/// A point-in-time copy of everything recorded so far.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Metrics {
    /// Monotonic named counters (events, bytes, rows).
    pub counters: BTreeMap<String, u64>,
    /// Last-written named gauges (levels, running means).
    pub gauges: BTreeMap<String, f64>,
    /// Flat named latency histograms ([`timed`](super::timed) guards).
    pub hists: BTreeMap<String, Histogram>,
    /// Hierarchical span stats keyed by `/`-joined path.
    pub spans: BTreeMap<String, SpanStat>,
}

impl Metrics {
    /// Fold `other` into `self`. Counter/histogram/span merging is
    /// associative and commutative; gauges take `other`'s value (last
    /// writer wins), which keeps merge associative.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(v);
        }
        for (k, v) in &other.spans {
            self.spans.entry(k.clone()).or_default().merge(v);
        }
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty()
            && self.gauges.is_empty()
            && self.hists.is_empty()
            && self.spans.is_empty()
    }
}

fn enabled_cell() -> &'static AtomicBool {
    static CELL: OnceLock<AtomicBool> = OnceLock::new();
    CELL.get_or_init(|| {
        let off = matches!(
            std::env::var("TANGO_TRACE").as_deref(),
            Ok("0") | Ok("false") | Ok("off") | Ok("no")
        );
        AtomicBool::new(!off)
    })
}

/// Whether tracing is currently on (default yes; `TANGO_TRACE=0` starts off).
#[inline]
pub fn enabled() -> bool {
    enabled_cell().load(Ordering::Relaxed)
}

/// Flip tracing on/off for the whole process (config `[metrics] trace`).
pub fn set_enabled(on: bool) {
    enabled_cell().store(on, Ordering::Relaxed);
}

fn global() -> &'static Mutex<Metrics> {
    static GLOBAL: OnceLock<Mutex<Metrics>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(Metrics::default()))
}

fn with_global(f: impl FnOnce(&mut Metrics)) {
    // A poisoned lock only means another thread panicked mid-record;
    // metrics stay usable.
    let mut g = global().lock().unwrap_or_else(|e| e.into_inner());
    f(&mut g);
}

/// Add `n` to the named counter. When event-timeline collection is on,
/// the increment is also emitted as a Chrome trace `C` event — the
/// timeline is switchable independently of the aggregate registry.
pub fn counter_add(name: &str, n: u64) {
    if super::trace::enabled() {
        super::trace::emit_counter(name, n as f64);
    }
    if !enabled() {
        return;
    }
    with_global(|m| *m.counters.entry(name.to_string()).or_insert(0) += n);
}

/// Set the named gauge to `v`.
pub fn gauge_set(name: &str, v: f64) {
    if !enabled() {
        return;
    }
    with_global(|m| {
        m.gauges.insert(name.to_string(), v);
    });
}

/// Record one duration into the named flat histogram.
pub fn observe(name: &str, secs: f64) {
    if !enabled() {
        return;
    }
    with_global(|m| m.hists.entry(name.to_string()).or_default().record(secs));
}

/// Record one closed span occurrence under its full path.
pub(crate) fn record_span(path: &str, secs: f64) {
    with_global(|m| m.spans.entry(path.to_string()).or_default().record(secs));
}

/// Copy out everything recorded so far.
pub fn snapshot() -> Metrics {
    let g = global().lock().unwrap_or_else(|e| e.into_inner());
    g.clone()
}

/// Clear the registry (tests, and the CLI before a run so the
/// `--metrics-out` artifact describes that run alone). Also clears the
/// per-thread trace event rings and restarts the trace clock epoch, so
/// back-to-back traced runs in one process get independent timelines.
pub fn reset() {
    with_global(|m| *m = Metrics::default());
    super::trace::reset();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_snapshot() {
        counter_add("test.registry.counter", 3);
        counter_add("test.registry.counter", 4);
        let snap = snapshot();
        // >= because other tests in this binary may add to the registry too;
        // the unique name keeps this exact.
        assert_eq!(snap.counters.get("test.registry.counter"), Some(&7));
    }

    #[test]
    fn gauges_take_last_value() {
        gauge_set("test.registry.gauge", 1.5);
        gauge_set("test.registry.gauge", 2.5);
        assert_eq!(snapshot().gauges.get("test.registry.gauge"), Some(&2.5));
    }

    #[test]
    fn merge_is_associative_on_counters() {
        let mk = |k: &str, v: u64| {
            let mut m = Metrics::default();
            m.counters.insert(k.into(), v);
            m
        };
        let (a, b, c) = (mk("x", 1), mk("x", 2), mk("y", 5));
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
    }
}
