//! Event-timeline tracing: per-thread bounded ring buffers of
//! begin/end/instant/counter events on a monotonic run-relative clock.
//!
//! The aggregate registry ([`super::snapshot`]) can say *how much* time a
//! stage took; it cannot show two threads overlapping in time. This module
//! records the individual events — span open/close ([`emit_begin`] /
//! [`emit_end`], fed by the existing [`span`](super::span) /
//! [`timed`](super::timed) guards), counter bumps ([`emit_counter`], fed by
//! [`counter_add`](super::counter_add)) and explicit [`instant`] marks —
//! and exports them as Chrome trace-event JSON (schema `tango-trace/v1`,
//! `ph: B/E/i/C`) loadable in Perfetto, so the producer-thread prefetch
//! visibly overlaps the consumer's compute span.
//!
//! **Off means off**: collection is gated by its own relaxed [`enabled`]
//! flag, *default off*, checked before any clock read or allocation — a
//! metrics-only run pays one extra relaxed load per event site and stays
//! bit-identical (`tests/obs_invariants.rs`). The CLI turns collection on
//! when `--trace-out` or `--flight-recorder` is set.
//!
//! Every thread that emits gets its own bounded ring (oldest events
//! evicted past [`RING_CAP`]); rings are registered globally so
//! [`export`] drains all of them deterministically (registration order)
//! and [`reset`] — reached via [`super::reset`] — clears the buffers *and*
//! the clock epoch, keeping back-to-back runs in one process independent.
//! Timestamps are microseconds since the epoch; `pid` is the simulated
//! worker id (0 = coordinator / single-process; [`pid_scope`] tags worker
//! and producer threads in `tango multigpu`), `tid` the ring's
//! registration index.
//!
//! The **flight recorder** rides on the same rings: [`set_flight_recorder`]
//! arms a dump path, and [`flight_dump`] — called by the trainers on every
//! fault-harness recovery and by the CLI on an error return — atomically
//! writes the last-N events per thread (schema `tango-trace/v1`,
//! `kind: "flight"`), a post-mortem whose final events name the recovery
//! path taken.

use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Artifact schema tag shared by full traces and flight-recorder dumps.
pub const TRACE_SCHEMA: &str = "tango-trace/v1";

/// Per-thread ring capacity. Bounds memory for arbitrarily long runs; a
/// smoke run's full timeline fits with a wide margin.
const RING_CAP: usize = 65_536;

/// Chrome trace-event phase of one recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Duration begin (`B`) — a `span`/`timed` guard opened.
    Begin,
    /// Duration end (`E`) — the guard dropped.
    End,
    /// Instant mark (`i`) — a point event such as a fault recovery.
    Instant,
    /// Counter sample (`C`) — the increment passed to `counter_add`.
    Counter,
}

impl Phase {
    fn ph(self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
            Phase::Counter => "C",
        }
    }
}

/// One recorded event. `ts_us` is microseconds since the run epoch.
#[derive(Debug, Clone)]
struct Event {
    ts_us: f64,
    ph: Phase,
    name: String,
    pid: u32,
    /// Counter increment (`C` events only).
    value: f64,
}

/// One thread's bounded event ring.
#[derive(Debug)]
struct Ring {
    tid: u32,
    buf: VecDeque<Event>,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.buf.len() == RING_CAP {
            self.buf.pop_front();
        }
        self.buf.push_back(ev);
    }
}

/// Process-global trace state: the run epoch, every registered ring, and
/// the flight-recorder arming. One mutex — emit paths only touch it on
/// their first event after a reset (epoch refresh / ring registration).
struct Shared {
    epoch: Instant,
    rings: Vec<Arc<Mutex<Ring>>>,
    next_tid: u32,
    flight_path: Option<String>,
    flight_last_n: usize,
}

fn shared() -> &'static Mutex<Shared> {
    static SHARED: OnceLock<Mutex<Shared>> = OnceLock::new();
    SHARED.get_or_init(|| {
        Mutex::new(Shared {
            epoch: Instant::now(),
            rings: Vec::new(),
            next_tid: 0,
            flight_path: None,
            flight_last_n: 0,
        })
    })
}

fn enabled_cell() -> &'static AtomicBool {
    static CELL: OnceLock<AtomicBool> = OnceLock::new();
    CELL.get_or_init(|| AtomicBool::new(false))
}

/// Bumped by [`reset`]; threads refresh their cached epoch when it moves.
static EPOCH_GEN: AtomicU64 = AtomicU64::new(0);

/// Whether event collection is on (default **off**, unlike the aggregate
/// registry). One relaxed load — the whole cost of a disabled event site.
#[inline]
pub fn enabled() -> bool {
    enabled_cell().load(Ordering::Relaxed)
}

/// Flip event collection on/off (CLI `--trace-out` / `--flight-recorder`,
/// tests). Collection alone never changes training numerics.
pub fn set_enabled(on: bool) {
    enabled_cell().store(on, Ordering::Relaxed);
}

/// Per-thread cached state: this thread's ring, its view of the epoch, and
/// the worker pid events are stamped with.
struct Tls {
    ring: Option<Arc<Mutex<Ring>>>,
    epoch: Instant,
    gen: u64,
    pid: u32,
}

thread_local! {
    static TLS: RefCell<Tls> = RefCell::new(Tls {
        ring: None,
        epoch: Instant::now(),
        gen: u64::MAX,
        pid: 0,
    });
}

/// RAII pid tag: events emitted by this thread while the scope lives carry
/// the given worker pid (restored on drop). Cheap enough to enter per step.
#[must_use = "the pid tag lasts only while this scope is held"]
pub struct PidScope {
    prev: u32,
}

/// Tag this thread's events with simulated-worker `pid` until the returned
/// scope drops (`tango multigpu` worker and producer threads).
pub fn pid_scope(pid: u32) -> PidScope {
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        let prev = t.pid;
        t.pid = pid;
        PidScope { prev }
    })
}

impl Drop for PidScope {
    fn drop(&mut self) {
        TLS.with(|t| t.borrow_mut().pid = self.prev);
    }
}

/// The worker pid this thread currently stamps events with.
pub fn current_pid() -> u32 {
    TLS.with(|t| t.borrow().pid)
}

/// Record one event on this thread's ring. Callers have already checked
/// [`enabled`].
fn record(ph: Phase, name: &str, value: f64) {
    let gen = EPOCH_GEN.load(Ordering::Relaxed);
    TLS.with(|t| {
        let mut t = t.borrow_mut();
        if t.gen != gen || t.ring.is_none() {
            let mut g = shared().lock().unwrap_or_else(|e| e.into_inner());
            t.epoch = g.epoch;
            t.gen = gen;
            if t.ring.is_none() {
                let ring = Arc::new(Mutex::new(Ring { tid: g.next_tid, buf: VecDeque::new() }));
                g.next_tid += 1;
                g.rings.push(Arc::clone(&ring));
                t.ring = Some(ring);
            }
        }
        let ev = Event {
            ts_us: t.epoch.elapsed().as_secs_f64() * 1e6,
            ph,
            name: name.to_string(),
            pid: t.pid,
            value,
        };
        if let Some(ring) = &t.ring {
            ring.lock().unwrap_or_else(|e| e.into_inner()).push(ev);
        }
    });
}

/// Span/timed guard opened (`B`). Called by `obs::span` / `obs::timed`.
pub(super) fn emit_begin(name: &str) {
    record(Phase::Begin, name, 0.0);
}

/// Span/timed guard dropped (`E`).
pub(super) fn emit_end(name: &str) {
    record(Phase::End, name, 0.0);
}

/// Counter increment (`C`). Called by `obs::counter_add`; `args.value`
/// carries the increment, not the running total.
pub(super) fn emit_counter(name: &str, n: f64) {
    record(Phase::Counter, name, n);
}

/// Emit an instant event (`i`) naming a point in time — fault recoveries,
/// degradations, error exits. Keys come from [`super::keys`] (audit O1).
pub fn instant(name: &str) {
    if !enabled() {
        return;
    }
    record(Phase::Instant, name, 0.0);
}

/// Clear every ring and restart the run-relative clock. Reached through
/// [`super::reset`] so one call scrubs aggregates *and* timelines; rings
/// of threads that have exited are dropped entirely.
pub(super) fn reset() {
    let mut g = shared().lock().unwrap_or_else(|e| e.into_inner());
    g.epoch = Instant::now();
    // A ring whose owning thread is gone has no other strong reference.
    g.rings.retain(|r| Arc::strong_count(r) > 1);
    for r in &g.rings {
        r.lock().unwrap_or_else(|e| e.into_inner()).buf.clear();
    }
    g.next_tid = g.rings.iter().map(|r| ring_tid(r) + 1).max().unwrap_or(0);
    EPOCH_GEN.fetch_add(1, Ordering::Relaxed);
}

fn ring_tid(r: &Arc<Mutex<Ring>>) -> u32 {
    r.lock().unwrap_or_else(|e| e.into_inner()).tid
}

/// Arm (or disarm, with `None`) the flight recorder: on every
/// [`flight_dump`] call the last `last_n` events per thread are written
/// atomically to `path`.
pub fn set_flight_recorder(path: Option<&str>, last_n: usize) {
    let mut g = shared().lock().unwrap_or_else(|e| e.into_inner());
    g.flight_path = path.map(|p| p.to_string());
    g.flight_last_n = last_n;
}

fn event_json(ev: &Event, tid: u32) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("name".into(), Json::Str(ev.name.clone()));
    m.insert("ph".into(), Json::Str(ev.ph.ph().to_string()));
    m.insert("pid".into(), Json::Num(ev.pid as f64));
    m.insert("tid".into(), Json::Num(tid as f64));
    m.insert("ts".into(), Json::Num(ev.ts_us));
    match ev.ph {
        Phase::Counter => {
            let mut args = BTreeMap::new();
            args.insert("value".to_string(), Json::Num(ev.value));
            m.insert("args".into(), Json::Obj(args));
        }
        Phase::Instant => {
            // Thread-scoped instant (Chrome's `s` field).
            m.insert("s".into(), Json::Str("t".to_string()));
        }
        Phase::Begin | Phase::End => {}
    }
    Json::Obj(m)
}

/// Collect events from every ring, in ring registration order, keeping at
/// most the last `last_n` per ring (`usize::MAX` = all).
fn collect_events(last_n: usize) -> Vec<Json> {
    let g = shared().lock().unwrap_or_else(|e| e.into_inner());
    let mut out = Vec::new();
    for r in &g.rings {
        let ring = r.lock().unwrap_or_else(|e| e.into_inner());
        let skip = ring.buf.len().saturating_sub(last_n);
        for ev in ring.buf.iter().skip(skip) {
            out.push(event_json(ev, ring.tid));
        }
    }
    out
}

/// Build the full `tango-trace/v1` Chrome trace document for this run.
/// Events are grouped per thread in registration order; within a thread
/// they are in emission order (timestamps monotone per tid).
pub fn export(command: &str) -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("schema".into(), Json::Str(TRACE_SCHEMA.to_string()));
    m.insert("command".into(), Json::Str(command.to_string()));
    m.insert("traceEvents".into(), Json::Arr(collect_events(usize::MAX)));
    Json::Obj(m)
}

/// Write the full trace for `command` to `path` (atomic tmp + rename).
pub fn write(path: &str, command: &str) -> crate::Result<()> {
    crate::util::fsio::write_atomic(path, &export(command).to_string())
}

/// Dump the last-N events per thread to the armed flight-recorder path
/// (schema `tango-trace/v1`, `kind: "flight"`, `reason` naming the
/// recovery). Returns `true` iff armed and the write succeeded; a no-op
/// (false) when the recorder is off, so recovery paths call it
/// unconditionally.
pub fn flight_dump(reason: &str) -> bool {
    let (path, last_n) = {
        let g = shared().lock().unwrap_or_else(|e| e.into_inner());
        match (&g.flight_path, g.flight_last_n) {
            (Some(p), n) if n > 0 => (p.clone(), n),
            _ => return false,
        }
    };
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("schema".into(), Json::Str(TRACE_SCHEMA.to_string()));
    m.insert("kind".into(), Json::Str("flight".to_string()));
    m.insert("reason".into(), Json::Str(reason.to_string()));
    m.insert("traceEvents".into(), Json::Arr(collect_events(last_n)));
    crate::util::fsio::write_atomic(&path, &Json::Obj(m).to_string()).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace state is process-global; serialize the tests that toggle it.
    /// Other modules' unit tests run concurrently in this binary and may
    /// hit obs entry points, so assertions filter by this module's own
    /// `test.trace.*` names instead of counting events globally.
    static LOCK: Mutex<()> = Mutex::new(());

    /// Events from `doc` whose name starts with `prefix`, in export order.
    fn named(doc: &Json, prefix: &str) -> Vec<Json> {
        doc.get("traceEvents")
            .and_then(|e| e.as_arr())
            .map(|a| {
                a.iter()
                    .filter(|e| {
                        e.get("name")
                            .and_then(|s| s.as_str())
                            .is_some_and(|n| n.starts_with(prefix))
                    })
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    #[test]
    fn disabled_collection_records_nothing() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        super::super::reset();
        instant("test.trace.off");
        assert!(named(&export("test"), "test.trace.off").is_empty());
    }

    #[test]
    fn events_round_trip_with_monotone_timestamps() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        super::super::reset();
        emit_begin("test.trace.rt.span");
        emit_counter("test.trace.rt.ctr", 3.0);
        emit_end("test.trace.rt.span");
        instant("test.trace.rt.mark");
        let doc = export("test");
        set_enabled(false);
        assert_eq!(doc.get("schema").and_then(|s| s.as_str()), Some(TRACE_SCHEMA));
        let evs = named(&doc, "test.trace.rt.");
        let phs: Vec<&str> =
            evs.iter().filter_map(|e| e.get("ph").and_then(|p| p.as_str())).collect();
        assert_eq!(phs, vec!["B", "C", "E", "i"]);
        let ts: Vec<f64> =
            evs.iter().filter_map(|e| e.get("ts").and_then(|t| t.as_f64())).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "timestamps monotone: {ts:?}");
        assert_eq!(
            evs[1].get("args").and_then(|a| a.get("value")).and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert_eq!(evs[3].get("s").and_then(|s| s.as_str()), Some("t"));
    }

    #[test]
    fn reset_clears_rings_and_restarts_the_clock() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        super::super::reset();
        instant("test.trace.reset.first");
        let before = export("test");
        super::super::reset();
        instant("test.trace.reset.second");
        let after = export("test");
        set_enabled(false);
        assert_eq!(named(&before, "test.trace.reset.first").len(), 1);
        assert!(
            named(&after, "test.trace.reset.first").is_empty(),
            "old events must not survive a reset"
        );
        assert_eq!(named(&after, "test.trace.reset.second").len(), 1);
    }

    #[test]
    fn flight_dump_is_inert_until_armed() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_flight_recorder(None, 0);
        assert!(!flight_dump("test.trace.reason"));
        let path =
            std::env::temp_dir().join(format!("tango_trace_flight_{}.json", std::process::id()));
        let path_s = path.to_string_lossy().into_owned();
        set_enabled(true);
        super::super::reset();
        instant("test.trace.recovery");
        set_flight_recorder(Some(&path_s), 8);
        assert!(flight_dump("test.trace.reason"));
        set_flight_recorder(None, 0);
        set_enabled(false);
        let text = std::fs::read_to_string(&path).expect("dump written");
        let doc = Json::parse(&text).expect("json");
        assert_eq!(doc.get("kind").and_then(|s| s.as_str()), Some("flight"));
        assert_eq!(doc.get("reason").and_then(|s| s.as_str()), Some("test.trace.reason"));
        let evs = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("events");
        assert!(evs.iter().any(|e| {
            e.get("name").and_then(|s| s.as_str()) == Some("test.trace.recovery")
        }));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn pid_scope_tags_and_restores() {
        let _l = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        super::super::reset();
        assert_eq!(current_pid(), 0);
        {
            let _p = pid_scope(3);
            assert_eq!(current_pid(), 3);
            instant("test.trace.worker");
        }
        assert_eq!(current_pid(), 0);
        let doc = export("test");
        set_enabled(false);
        let evs = named(&doc, "test.trace.worker");
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("pid").and_then(|p| p.as_f64()), Some(3.0));
    }
}
