//! Batch feature gathering, plain and quantized.
//!
//! In sampled mini-batch training the per-batch feature gather dominates
//! step time once the graph outgrows cache (the BiFeat observation, see
//! PAPERS.md): every batch slices a fresh `[num_input, F]` matrix out of
//! the node-feature table. The quantized path moves 1-byte rows instead of
//! 4-byte rows and — because the feature table is *static* across training —
//! caches each node's quantized row in a [`QuantCache`], so hot
//! (frequently re-sampled) nodes quantize once per run instead of once per
//! batch.
//!
//! Precision is governed by a [`FeaturePolicy`] (see [`crate::policy`]):
//! every node belongs to a degree bucket with its own static symmetric
//! `(scale, bits)`. The **uniform** policy (one bucket) reproduces the
//! original single global scale exactly — static data ⇒ static scales —
//! and mixed policies compress cold-bucket rows below INT8, which the
//! per-bucket [`BucketGatherStats`] accounting makes visible.

use crate::coordinator::qcache::{CacheStats, QuantCache};
use crate::policy::{BucketGatherStats, FeaturePolicy, PolicyGatherReport};
use crate::quant::pack::{pack_row, packed_len, unpack_row_into};
use crate::quant::{packed_bits_per_elem, quantize_slice_nearest, QTensor};
use crate::tensor::Dense;
use crate::util::par;
use std::collections::HashMap;

/// Gather feature rows for a node list into a dense `[nodes.len(), F]`
/// matrix (the FP32 baseline gather). Row copies run data-parallel over the
/// output (one chunk per row — `par::for_each_chunk` falls back to the
/// plain loop for small batches).
pub fn gather_rows(features: &Dense<f32>, nodes: &[u32]) -> Dense<f32> {
    let dim = features.cols();
    let mut out = Dense::zeros(&[nodes.len(), dim]);
    if dim == 0 || nodes.is_empty() {
        return out;
    }
    par::for_each_chunk(out.data_mut(), dim, |i, chunk| {
        chunk.copy_from_slice(features.row(nodes[i] as usize));
    });
    out
}

/// Bytes a feature row occupies packed at `bits` per element (the 1-bit
/// ternary grid charges two physical bits — see
/// [`crate::quant::packed_bits_per_elem`]).
fn packed_row_bytes(dim: usize, bits: u8) -> u64 {
    (dim * packed_bits_per_elem(bits)).div_ceil(8) as u64
}

/// One gathered batch of quantized feature rows under a (possibly mixed)
/// per-bucket policy: a **bit-packed** payload plus each row's
/// `(scale, bits)`. Uniform-policy batches have every row at the same pair,
/// making this the row-wise generalization of a single batch [`QTensor`].
///
/// Rows are stored packed at their nominal widths (LSB-first bitstreams,
/// see [`crate::quant::pack`]), so [`Self::packed_bytes`] is the *actual*
/// allocation, not nominal accounting — a 4-bit row really occupies half a
/// byte per element. The packed kernels in [`crate::primitives::packed`]
/// consume this payload directly; [`Self::dequantize`] is the
/// dequantize-to-f32 fallback path.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRows {
    /// Bit-packed payload: row `i` occupies `buf[offsets[i]..offsets[i+1]]`.
    buf: Vec<u8>,
    /// Row byte boundaries into `buf` (`rows + 1` entries, `offsets[0] = 0`).
    offsets: Vec<usize>,
    /// Logical shape `[rows, F]` of the unpacked payload.
    shape: [usize; 2],
    /// Per-row symmetric scale.
    pub scales: Vec<f32>,
    /// Per-row bit width.
    pub bits: Vec<u8>,
}

impl QuantRows {
    /// Pack already-quantized i8 rows (each at `bits[i]` / `scales[i]`)
    /// into the bit-packed payload. Rows pack in parallel.
    pub fn from_i8_rows(data: &Dense<i8>, scales: Vec<f32>, bits: Vec<u8>) -> Self {
        let (rows, dim) = (data.rows(), data.cols());
        debug_assert_eq!(scales.len(), rows);
        debug_assert_eq!(bits.len(), rows);
        let mut offsets = Vec::with_capacity(rows + 1);
        offsets.push(0usize);
        for &b in &bits {
            offsets.push(offsets[offsets.len() - 1] + packed_len(dim, b));
        }
        let packed: Vec<Vec<u8>> = par::map_range(rows, |i| pack_row(data.row(i), bits[i]));
        let mut buf = Vec::with_capacity(offsets[rows]);
        for r in &packed {
            buf.extend_from_slice(r);
        }
        QuantRows { buf, offsets, shape: [rows, dim], scales, bits }
    }

    /// Pack a uniform batch [`QTensor`] — every row at the tensor's single
    /// `(scale, bits)`. This is how the model's block forward hands an
    /// already-quantized dense operand to the packed kernels.
    pub fn from_qtensor(q: &QTensor) -> Self {
        let rows = q.data.rows();
        Self::from_i8_rows(&q.data, vec![q.scale; rows], vec![q.bits; rows])
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.scales.len()
    }

    /// Feature dimension (unpacked elements per row).
    pub fn dim(&self) -> usize {
        self.shape[1]
    }

    /// Logical shape `[rows, F]` of the unpacked payload.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Packed payload bytes — the real allocation (each row at its nominal
    /// width, padded to whole bytes).
    pub fn packed_bytes(&self) -> usize {
        self.buf.len()
    }

    /// The packed bytes of row `i` (an LSB-first bitstream at
    /// `packed_bits_per_elem(bits[i])` bits per element).
    pub fn packed_row(&self, i: usize) -> &[u8] {
        &self.buf[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Unpack row `i` into `out` (`out.len()` must be [`Self::dim`]).
    pub fn unpack_row_into(&self, i: usize, out: &mut [i8]) {
        unpack_row_into(self.packed_row(i), self.bits[i], out);
    }

    /// Unpack row `i` into a fresh i8 vector.
    pub fn row_i8(&self, i: usize) -> Vec<i8> {
        let mut out = vec![0i8; self.dim()];
        self.unpack_row_into(i, &mut out);
        out
    }

    /// Unpack the whole payload to one i8 slot per element (data-parallel,
    /// one chunk per row) — the 8-bit-style dense view.
    pub fn unpack_dense(&self) -> Dense<i8> {
        let dim = self.dim();
        let mut out: Dense<i8> = Dense::zeros(&self.shape);
        if dim == 0 || self.scales.is_empty() {
            return out;
        }
        par::for_each_chunk(out.data_mut(), dim, |i, chunk| {
            unpack_row_into(self.packed_row(i), self.bits[i], chunk);
        });
        out
    }

    /// `Some((scale, bits))` when every row shares one pair — the case
    /// where the batch is exactly a bit-packed [`QTensor`].
    pub fn uniform(&self) -> Option<(f32, u8)> {
        let (&s0, &b0) = (self.scales.first()?, self.bits.first()?);
        let same = self.scales.iter().all(|&s| s == s0) && self.bits.iter().all(|&b| b == b0);
        same.then_some((s0, b0))
    }

    /// Unpack a uniform batch back into a [`QTensor`] (`None` when rows
    /// carry mixed `(scale, bits)` pairs).
    pub fn to_qtensor(&self) -> Option<QTensor> {
        let (scale, bits) = self.uniform()?;
        Some(QTensor { data: self.unpack_dense(), scale, bits })
    }

    /// Dequantize every row at its own scale into a `[rows, F]` FP32
    /// matrix (data-parallel, one chunk per row).
    pub fn dequantize(&self) -> Dense<f32> {
        let dim = self.dim();
        let mut out: Dense<f32> = Dense::zeros(&self.shape);
        if dim == 0 || self.scales.is_empty() {
            return out;
        }
        par::for_each_chunk(out.data_mut(), dim, |i, chunk| {
            let s = self.scales[i];
            let mut row = vec![0i8; dim];
            unpack_row_into(self.packed_row(i), self.bits[i], &mut row);
            for (o, &q) in chunk.iter_mut().zip(&row) {
                *o = q as f32 * s;
            }
        });
        out
    }
}

/// Quantized feature store: gathers batch feature slices as quantized rows
/// under a degree-bucketed [`FeaturePolicy`], caching per-node quantized
/// rows for hot nodes. The uniform policy (the [`Self::new`] /
/// [`Self::with_capacity`] constructors) is bit-identical to a single
/// global `(scale, bits)` store.
#[derive(Debug)]
pub struct QuantFeatureStore {
    policy: FeaturePolicy,
    cache: QuantCache,
    /// Per-bucket gather traffic, aligned with the policy's buckets.
    bucket_stats: Vec<BucketGatherStats>,
}

impl QuantFeatureStore {
    /// Build a uniform-policy store for a feature table: one abs-max
    /// reduction derives the shared scale; rows quantize lazily on first
    /// gather. The hot-node cache is unbounded (every sampled node's row is
    /// kept for the run).
    pub fn new(features: &Dense<f32>, bits: u8) -> Self {
        Self::with_capacity(features, bits, 0)
    }

    /// Like [`Self::new`], but the hot-node cache holds at most `max_nodes`
    /// quantized rows (0 = unbounded). An epoch sweep touches every training
    /// node, so an unbounded cache grows to the whole feature table; the
    /// bound caps that at `max_nodes · F` bytes, evicting the oldest rows
    /// first (evictions are reported by [`Self::stats`]).
    pub fn with_capacity(features: &Dense<f32>, bits: u8, max_nodes: usize) -> Self {
        let policy = FeaturePolicy::uniform(bits, features)
            .expect("uniform feature policy is always valid for bits 1..=8");
        Self::with_policy(policy, max_nodes)
    }

    /// Build over an already-materialized (possibly mixed) policy — the
    /// degree-bucketed path. Scales were derived at materialization, so no
    /// feature pass happens here; `max_nodes` bounds the hot-node cache
    /// (0 = unbounded) exactly as in [`Self::with_capacity`].
    pub fn with_policy(policy: FeaturePolicy, max_nodes: usize) -> Self {
        let cache =
            if max_nodes == 0 { QuantCache::new() } else { QuantCache::with_capacity(max_nodes) };
        let bucket_stats = vec![BucketGatherStats::default(); policy.num_buckets()];
        QuantFeatureStore { policy, cache, bucket_stats }
    }

    /// Gather the quantized rows of `nodes` into one `[nodes.len(), F]`
    /// [`QuantRows`] batch, each row at its bucket's `(scale, bits)`. Rows
    /// of previously seen nodes come from the cache.
    ///
    /// Runs in batch passes instead of row-at-a-time: classify every node
    /// against the cache, quantize the misses in parallel straight from
    /// their feature slices (no per-miss f32 staging copy), assemble the
    /// output in parallel, then admit the fresh rows. Assembly happens
    /// *before* admission, so a bound smaller than the batch (rows evicted
    /// by this very call) still gathers exact values — the static
    /// per-bucket scales guarantee requantization is bit-identical anyway.
    pub fn gather_quantized(&mut self, features: &Dense<f32>, nodes: &[u32]) -> QuantRows {
        let dim = features.cols();
        // Tracing reads values but never writes them: Error_X measurement
        // and traffic counters cannot perturb the quantized payload (the
        // bit-identity test in `tests/obs_invariants.rs`).
        let traced = crate::obs::enabled();
        let (mut batch_packed, mut batch_int8) = (0u64, 0u64);
        // Pass 1: first sight of an uncached node is a miss; duplicates and
        // cached rows are hits. `miss_idx` maps each missing node to its
        // slot in `miss_nodes`/`miss_rows` — one structure serves dedup,
        // assembly lookup and admission. Per-bucket traffic (rows, bytes at
        // the policy width vs uniform INT8) is charged here too.
        let mut miss_nodes: Vec<u32> = Vec::new();
        let mut miss_idx: HashMap<u32, usize> = HashMap::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut scales = Vec::with_capacity(nodes.len());
        let mut bits = Vec::with_capacity(nodes.len());
        for &v in nodes {
            let b = self.policy.bucket_of_node(v as usize);
            let row_bits = self.policy.bits_of(b);
            scales.push(self.policy.scale(b));
            bits.push(row_bits);
            let st = &mut self.bucket_stats[b];
            st.rows += 1;
            let row_packed = packed_row_bytes(dim, row_bits);
            st.packed_bytes += row_packed;
            st.int8_bytes += dim as u64;
            batch_packed += row_packed;
            batch_int8 += dim as u64;
            if self.cache.peek(v as u64).is_some() || miss_idx.contains_key(&v) {
                hits += 1;
                st.hits += 1;
            } else {
                misses += 1;
                st.misses += 1;
                miss_idx.insert(v, miss_nodes.len());
                miss_nodes.push(v);
            }
        }
        self.cache.count_hits(hits);
        self.cache.count_misses(misses);
        // Pass 2: quantize the missing rows in parallel, straight from
        // their feature slices at their bucket's `(scale, bits)` (shared
        // helper with `quantize_with_scale` — cached rows cannot drift from
        // direct quantization).
        // When tracing, each fresh row also measures its Error_X (paper
        // Eq. 4) against the FP32 source — the per-bucket quantization-error
        // evidence the Degree-Quant/A²Q bit assignments are justified from.
        let policy = &self.policy;
        let miss_rows: Vec<(Vec<i8>, f32)> = par::map_range(miss_nodes.len(), |j| {
            let v = miss_nodes[j] as usize;
            let b = policy.bucket_of_node(v);
            let scale = policy.scale(b);
            let row = quantize_slice_nearest(features.row(v), scale, policy.bits_of(b));
            let err = if traced {
                crate::quant::error_x_slice(features.row(v), &row, scale)
            } else {
                0.0
            };
            (row, err)
        });
        // Pass 3: parallel assembly — each row bit-packs straight from its
        // i8 source (fresh quantization or cache hit) at its nominal width,
        // so the batch payload is the real packed allocation. Cached rows
        // stay dense i8 (repacking a hot row is far cheaper than the
        // quantization the cache skips, and the cache serves every width).
        let mut offsets = Vec::with_capacity(nodes.len() + 1);
        offsets.push(0usize);
        for &b in &bits {
            offsets.push(offsets[offsets.len() - 1] + packed_len(dim, b));
        }
        let cache = &self.cache;
        let packed_rows: Vec<Vec<u8>> = par::map_range(nodes.len(), |i| {
            let v = nodes[i];
            let row: &[i8] = match miss_idx.get(&v) {
                Some(&j) => miss_rows[j].0.as_slice(),
                None => cache.peek(v as u64).expect("row cached in pass 1").data.data(),
            };
            pack_row(row, bits[i])
        });
        let mut buf = Vec::with_capacity(offsets[nodes.len()]);
        for r in &packed_rows {
            buf.extend_from_slice(r);
        }
        // Pass 4: admit the fresh rows (oldest-first eviction under a bound)
        // and, when tracing, fold their measured Error_X into the bucket
        // accounting.
        for (v, (row, err)) in miss_nodes.into_iter().zip(miss_rows) {
            let b = self.policy.bucket_of_node(v as usize);
            if traced {
                let st = &mut self.bucket_stats[b];
                st.err_sum += err as f64;
                st.err_rows += 1;
            }
            self.cache.put(
                v as u64,
                QTensor {
                    data: Dense::from_vec(&[1, dim], row),
                    scale: self.policy.scale(b),
                    bits: self.policy.bits_of(b),
                },
            );
        }
        if traced {
            crate::obs::counter_add(crate::obs::keys::CTR_GATHER_ROWS, nodes.len() as u64);
            crate::obs::counter_add(crate::obs::keys::CTR_GATHER_CACHE_HITS, hits);
            crate::obs::counter_add(crate::obs::keys::CTR_GATHER_CACHE_MISSES, misses);
            crate::obs::counter_add(crate::obs::keys::CTR_GATHER_PACKED_BYTES, batch_packed);
            crate::obs::counter_add(crate::obs::keys::CTR_GATHER_INT8_BYTES, batch_int8);
            for (b, st) in self.bucket_stats.iter().enumerate() {
                if let Some(mean) = st.mean_error() {
                    crate::obs::gauge_set(&crate::obs::keys::gather_error_x_bucket(b), mean);
                }
            }
        }
        QuantRows { buf, offsets, shape: [nodes.len(), dim], scales, bits }
    }

    /// Gather and dequantize in one call — what the block forward consumes
    /// when the model itself runs on FP32 inputs.
    pub fn gather_dequantized(&mut self, features: &Dense<f32>, nodes: &[u32]) -> Dense<f32> {
        self.gather_quantized(features, nodes).dequantize()
    }

    /// **The** symmetric scale of a uniform-policy store. Panics on a
    /// mixed store — there rows carry per-bucket scales
    /// ([`QuantRows::scales`]) and no single number describes a batch;
    /// read [`Self::policy`] instead.
    pub fn scale(&self) -> f32 {
        assert!(!self.is_mixed(), "mixed-policy stores have per-bucket scales (use policy())");
        self.policy.scale(0)
    }

    /// Bit width of a uniform-policy store (panics on a mixed store, like
    /// [`Self::scale`]).
    pub fn bits(&self) -> u8 {
        assert!(!self.is_mixed(), "mixed-policy stores have per-bucket widths (use policy())");
        self.policy.bits_of(0)
    }

    /// The materialized policy driving this store.
    pub fn policy(&self) -> &FeaturePolicy {
        &self.policy
    }

    /// True when more than one `(scale, bits)` pair is live.
    pub fn is_mixed(&self) -> bool {
        self.policy.is_mixed()
    }

    /// Cache hit/miss statistics (hit rate = hot-node reuse).
    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Per-bucket gather accounting with the policy shape riding along —
    /// what `TrainReport::policy` / `MultiGpuReport::policy` surface.
    pub fn policy_report(&self) -> PolicyGatherReport {
        PolicyGatherReport {
            boundaries: self.policy.buckets().boundaries().to_vec(),
            bits: self.policy.bits().to_vec(),
            node_counts: self.policy.node_counts().to_vec(),
            buckets: self.bucket_stats.clone(),
        }
    }

    /// Bytes held by cached quantized rows.
    pub fn cached_bytes(&self) -> usize {
        self.cache.cached_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;
    use crate::policy::{BitPolicy, DegreeBuckets};
    use crate::quant::{quantize_with_scale, Rounding};

    #[test]
    fn gather_rows_slices_in_order() {
        let f = random_features(6, 3, 1);
        let out = gather_rows(&f, &[4, 0, 4]);
        assert_eq!(out.shape(), &[3, 3]);
        assert_eq!(out.row(0), f.row(4));
        assert_eq!(out.row(1), f.row(0));
        assert_eq!(out.row(2), f.row(4));
    }

    #[test]
    fn quantized_gather_matches_direct_quantization() {
        let f = random_features(10, 4, 2);
        let mut store = QuantFeatureStore::new(&f, 8);
        let nodes = vec![3u32, 7, 3, 0];
        let q = store.gather_quantized(&f, &nodes);
        let direct =
            quantize_with_scale(&gather_rows(&f, &nodes), store.scale(), 8, Rounding::Nearest);
        assert_eq!(q.unpack_dense(), direct.data);
        assert_eq!(q.packed_bytes(), 4 * 4, "8-bit rows pack 1:1");
        assert_eq!(q.to_qtensor().expect("uniform batch"), direct);
        assert!(q.scales.iter().all(|&s| s == direct.scale), "uniform rows share the scale");
        assert!(q.bits.iter().all(|&b| b == 8));
        assert_eq!(q.shape(), &[4, 4]);
        assert_eq!(q.rows(), 4);
    }

    #[test]
    fn hot_nodes_hit_the_cache() {
        let f = random_features(8, 4, 3);
        let mut store = QuantFeatureStore::new(&f, 8);
        store.gather_quantized(&f, &[1, 2, 3]);
        assert_eq!(store.stats().misses, 3);
        assert_eq!(store.stats().hits, 0);
        store.gather_quantized(&f, &[2, 3, 4]);
        assert_eq!(store.stats().misses, 4);
        assert_eq!(store.stats().hits, 2);
        assert_eq!(store.cached_bytes(), 4 * 4);
    }

    #[test]
    fn bounded_store_evicts_but_stays_exact() {
        let f = random_features(16, 4, 5);
        let mut bounded = QuantFeatureStore::with_capacity(&f, 8, 4);
        let mut unbounded = QuantFeatureStore::new(&f, 8);
        let nodes: Vec<u32> = (0..16).chain(0..16).collect();
        for chunk in nodes.chunks(8) {
            // Eviction changes *when* rows are requantized, never the values
            // (the per-bucket scales are static).
            let a = bounded.gather_quantized(&f, chunk);
            let b = unbounded.gather_quantized(&f, chunk);
            assert_eq!(a, b);
        }
        assert!(bounded.stats().evictions > 0, "{:?}", bounded.stats());
        assert_eq!(unbounded.stats().evictions, 0);
        // The bound holds: at most 4 rows of 4 bytes live at once.
        assert!(bounded.cached_bytes() <= 4 * 4, "{}", bounded.cached_bytes());
    }

    #[test]
    fn dequantized_gather_is_close_to_fp32() {
        let f = random_features(12, 6, 4);
        let mut store = QuantFeatureStore::new(&f, 8);
        let nodes: Vec<u32> = vec![0, 5, 11];
        let approx = store.gather_dequantized(&f, &nodes);
        let exact = gather_rows(&f, &nodes);
        // Nearest rounding: within half a grid step everywhere.
        assert!(approx.max_abs_diff(&exact) <= store.scale() / 2.0 + 1e-6);
    }

    /// A two-bucket policy over 8 nodes: 4..8 hot (8 bits), 0..4 cold
    /// (4 bits).
    fn mixed_policy(f: &Dense<f32>) -> FeaturePolicy {
        let degrees: Vec<u32> = (0..8).map(|v| if v < 4 { 1 } else { 9 }).collect();
        FeaturePolicy::materialize(
            DegreeBuckets::new(vec![5]).unwrap(),
            BitPolicy::new(vec![8, 4]).unwrap(),
            &degrees,
            f,
        )
        .unwrap()
    }

    #[test]
    fn mixed_gather_quantizes_each_row_at_its_bucket() {
        let f = random_features(8, 6, 7);
        let policy = mixed_policy(&f);
        let (hot_scale, cold_scale) = (policy.scale(0), policy.scale(1));
        let mut store = QuantFeatureStore::with_policy(policy, 0);
        assert!(store.is_mixed());
        let nodes = vec![0u32, 6, 2, 7];
        let q = store.gather_quantized(&f, &nodes);
        assert_eq!(q.scales, vec![cold_scale, hot_scale, cold_scale, hot_scale]);
        assert_eq!(q.bits, vec![4, 8, 4, 8]);
        // Every row unpacks to exactly direct quantization at its own
        // (scale, bits) — packing is lossless on the grid.
        for (i, &v) in nodes.iter().enumerate() {
            let direct =
                crate::quant::quantize_slice_nearest(f.row(v as usize), q.scales[i], q.bits[i]);
            assert_eq!(q.row_i8(i), direct, "row {i} (node {v})");
        }
        // Mixed rows never collapse to a single QTensor.
        assert!(q.uniform().is_none() && q.to_qtensor().is_none());
        // Dequantize honours per-row scales.
        let deq = q.dequantize();
        for i in 0..nodes.len() {
            let row = q.row_i8(i);
            for (a, &qv) in deq.row(i).iter().zip(row.iter()) {
                assert_eq!(*a, qv as f32 * q.scales[i]);
            }
        }
        // Cold rows really pack below INT8 now: the payload allocation is
        // 2 hot rows at 6 B + 2 cold (4-bit) rows at 3 B.
        assert_eq!(q.packed_bytes(), 2 * 6 + 2 * 3);
        assert_eq!(q.packed_row(0).len(), 3, "4-bit row occupies 3 bytes for 6 elems");
        assert_eq!(q.packed_row(1).len(), 6, "8-bit row packs 1:1");
    }

    #[test]
    fn per_bucket_stats_split_traffic() {
        let f = random_features(8, 6, 8);
        let mut store = QuantFeatureStore::with_policy(mixed_policy(&f), 0);
        store.gather_quantized(&f, &[0, 6, 2, 7]);
        store.gather_quantized(&f, &[0, 6]);
        let report = store.policy_report();
        assert!(report.is_mixed());
        assert_eq!(report.bits, vec![8, 4]);
        assert_eq!(report.node_counts, vec![4, 4]);
        let hot = report.buckets[0];
        let cold = report.buckets[1];
        assert_eq!(hot.rows, 3); // 6, 7, then 6 again
        assert_eq!(hot.misses, 2);
        assert_eq!(hot.hits, 1);
        assert_eq!(cold.rows, 3); // 0, 2, then 0 again
        assert_eq!(hot.int8_bytes, 3 * 6);
        assert_eq!(hot.packed_bytes, 3 * 6); // 8-bit rows pack 1:1
        assert_eq!(cold.int8_bytes, 3 * 6);
        assert_eq!(cold.packed_bytes, 3 * 3); // 4-bit rows pack 2:1
        assert!(report.packed_bytes() < report.int8_bytes());
    }

    #[test]
    fn uniform_policy_store_matches_plain_store_bitwise() {
        // The pre-policy equivalence at the store level: a single-bucket
        // policy gathers exactly what the plain constructor does.
        let f = random_features(12, 5, 11);
        let uniform = FeaturePolicy::uniform(8, &f).unwrap();
        let mut a = QuantFeatureStore::with_policy(uniform, 0);
        let mut b = QuantFeatureStore::new(&f, 8);
        let chunks: [&[u32]; 3] = [&[0, 3, 7], &[3, 3, 11], &[1, 0, 9]];
        for chunk in chunks {
            let qa = a.gather_quantized(&f, chunk);
            let qb = b.gather_quantized(&f, chunk);
            assert_eq!(qa, qb);
            assert_eq!(qa.dequantize(), qb.dequantize());
        }
        assert_eq!(a.scale(), b.scale());
        assert_eq!(a.stats(), b.stats());
    }
}
