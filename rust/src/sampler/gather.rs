//! Batch feature gathering, plain and quantized.
//!
//! In sampled mini-batch training the per-batch feature gather dominates
//! step time once the graph outgrows cache (the BiFeat observation, see
//! PAPERS.md): every batch slices a fresh `[num_input, F]` matrix out of
//! the node-feature table. The quantized path moves 1-byte rows instead of
//! 4-byte rows and — because the feature table is *static* across training —
//! caches each node's quantized row in a [`QuantCache`], so hot
//! (frequently re-sampled) nodes quantize once per run instead of once per
//! batch.
//!
//! All rows share one symmetric scale derived from the full table (static
//! data ⇒ static scale), which is what lets cached rows assemble into a
//! single batch [`QTensor`].

use crate::coordinator::qcache::{CacheStats, QuantCache};
use crate::quant::{dequantize, quantize_slice_nearest, scale_for_bits, QTensor};
use crate::tensor::Dense;
use crate::util::par;
use std::collections::HashMap;

/// Gather feature rows for a node list into a dense `[nodes.len(), F]`
/// matrix (the FP32 baseline gather). Row copies run data-parallel over the
/// output (one chunk per row — `par::for_each_chunk` falls back to the
/// plain loop for small batches).
pub fn gather_rows(features: &Dense<f32>, nodes: &[u32]) -> Dense<f32> {
    let dim = features.cols();
    let mut out = Dense::zeros(&[nodes.len(), dim]);
    if dim == 0 || nodes.is_empty() {
        return out;
    }
    par::for_each_chunk(out.data_mut(), dim, |i, chunk| {
        chunk.copy_from_slice(features.row(nodes[i] as usize));
    });
    out
}

/// Quantized feature store: gathers batch feature slices as INT8 rows under
/// one shared scale, caching per-node quantized rows for hot nodes.
#[derive(Debug)]
pub struct QuantFeatureStore {
    scale: f32,
    bits: u8,
    cache: QuantCache,
}

impl QuantFeatureStore {
    /// Build a store for a feature table: one abs-max reduction derives the
    /// shared scale; rows quantize lazily on first gather. The hot-node
    /// cache is unbounded (every sampled node's row is kept for the run).
    pub fn new(features: &Dense<f32>, bits: u8) -> Self {
        Self::with_capacity(features, bits, 0)
    }

    /// Like [`Self::new`], but the hot-node cache holds at most `max_nodes`
    /// quantized rows (0 = unbounded). An epoch sweep touches every training
    /// node, so an unbounded cache grows to the whole feature table; the
    /// bound caps that at `max_nodes · F` bytes, evicting the oldest rows
    /// first (evictions are reported by [`Self::stats`]).
    pub fn with_capacity(features: &Dense<f32>, bits: u8, max_nodes: usize) -> Self {
        let cache =
            if max_nodes == 0 { QuantCache::new() } else { QuantCache::with_capacity(max_nodes) };
        QuantFeatureStore { scale: scale_for_bits(features, bits), bits, cache }
    }

    /// Gather the quantized rows of `nodes` into one `[nodes.len(), F]`
    /// [`QTensor`]. Rows of previously seen nodes come from the cache.
    ///
    /// Runs in batch passes instead of row-at-a-time: classify every node
    /// against the cache, quantize the misses in parallel straight from
    /// their feature slices (no per-miss f32 staging copy), assemble the
    /// output in parallel, then admit the fresh rows. Assembly happens
    /// *before* admission, so a bound smaller than the batch (rows evicted
    /// by this very call) still gathers exact values — the shared static
    /// scale guarantees requantization is bit-identical anyway.
    pub fn gather_quantized(&mut self, features: &Dense<f32>, nodes: &[u32]) -> QTensor {
        let dim = features.cols();
        let (scale, bits) = (self.scale, self.bits);
        // Pass 1: first sight of an uncached node is a miss; duplicates and
        // cached rows are hits. `miss_idx` maps each missing node to its
        // slot in `miss_nodes`/`miss_rows` — one structure serves dedup,
        // assembly lookup and admission.
        let mut miss_nodes: Vec<u32> = Vec::new();
        let mut miss_idx: HashMap<u32, usize> = HashMap::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        for &v in nodes {
            if self.cache.peek(v as u64).is_some() || miss_idx.contains_key(&v) {
                hits += 1;
            } else {
                misses += 1;
                miss_idx.insert(v, miss_nodes.len());
                miss_nodes.push(v);
            }
        }
        self.cache.count_hits(hits);
        self.cache.count_misses(misses);
        // Pass 2: quantize the missing rows in parallel, straight from
        // their feature slices (shared helper with `quantize_with_scale` —
        // cached rows cannot drift from direct quantization).
        let miss_rows: Vec<Vec<i8>> = par::map_range(miss_nodes.len(), |j| {
            quantize_slice_nearest(features.row(miss_nodes[j] as usize), scale, bits)
        });
        // Pass 3: parallel assembly from cached + freshly quantized rows.
        let mut out = Dense::zeros(&[nodes.len(), dim]);
        if dim > 0 && !nodes.is_empty() {
            let cache = &self.cache;
            par::for_each_chunk(out.data_mut(), dim, |i, chunk| {
                let v = nodes[i];
                let row: &[i8] = match miss_idx.get(&v) {
                    Some(&j) => miss_rows[j].as_slice(),
                    None => cache.peek(v as u64).expect("row cached in pass 1").data.data(),
                };
                chunk.copy_from_slice(row);
            });
        }
        // Pass 4: admit the fresh rows (oldest-first eviction under a bound).
        for (v, row) in miss_nodes.into_iter().zip(miss_rows) {
            self.cache.put(
                v as u64,
                QTensor { data: Dense::from_vec(&[1, dim], row), scale, bits },
            );
        }
        QTensor { data: out, scale: self.scale, bits: self.bits }
    }

    /// Gather and dequantize in one call — what the block forward consumes
    /// when the model itself runs on FP32 inputs.
    pub fn gather_dequantized(&mut self, features: &Dense<f32>, nodes: &[u32]) -> Dense<f32> {
        dequantize(&self.gather_quantized(features, nodes))
    }

    /// Shared symmetric scale of every stored row.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Bit width of the stored rows.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Cache hit/miss statistics (hit rate = hot-node reuse).
    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Bytes held by cached quantized rows.
    pub fn cached_bytes(&self) -> usize {
        self.cache.cached_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;
    use crate::quant::{quantize_with_scale, Rounding};

    #[test]
    fn gather_rows_slices_in_order() {
        let f = random_features(6, 3, 1);
        let out = gather_rows(&f, &[4, 0, 4]);
        assert_eq!(out.shape(), &[3, 3]);
        assert_eq!(out.row(0), f.row(4));
        assert_eq!(out.row(1), f.row(0));
        assert_eq!(out.row(2), f.row(4));
    }

    #[test]
    fn quantized_gather_matches_direct_quantization() {
        let f = random_features(10, 4, 2);
        let mut store = QuantFeatureStore::new(&f, 8);
        let nodes = vec![3u32, 7, 3, 0];
        let q = store.gather_quantized(&f, &nodes);
        let direct =
            quantize_with_scale(&gather_rows(&f, &nodes), store.scale(), 8, Rounding::Nearest);
        assert_eq!(q.data, direct.data);
        assert_eq!(q.scale, direct.scale);
        assert_eq!(q.shape(), &[4, 4]);
    }

    #[test]
    fn hot_nodes_hit_the_cache() {
        let f = random_features(8, 4, 3);
        let mut store = QuantFeatureStore::new(&f, 8);
        store.gather_quantized(&f, &[1, 2, 3]);
        assert_eq!(store.stats().misses, 3);
        assert_eq!(store.stats().hits, 0);
        store.gather_quantized(&f, &[2, 3, 4]);
        assert_eq!(store.stats().misses, 4);
        assert_eq!(store.stats().hits, 2);
        assert_eq!(store.cached_bytes(), 4 * 4);
    }

    #[test]
    fn bounded_store_evicts_but_stays_exact() {
        let f = random_features(16, 4, 5);
        let mut bounded = QuantFeatureStore::with_capacity(&f, 8, 4);
        let mut unbounded = QuantFeatureStore::new(&f, 8);
        let nodes: Vec<u32> = (0..16).chain(0..16).collect();
        for chunk in nodes.chunks(8) {
            // Eviction changes *when* rows are requantized, never the values
            // (the shared scale is static).
            let a = bounded.gather_quantized(&f, chunk);
            let b = unbounded.gather_quantized(&f, chunk);
            assert_eq!(a.data, b.data);
        }
        assert!(bounded.stats().evictions > 0, "{:?}", bounded.stats());
        assert_eq!(unbounded.stats().evictions, 0);
        // The bound holds: at most 4 rows of 4 bytes live at once.
        assert!(bounded.cached_bytes() <= 4 * 4, "{}", bounded.cached_bytes());
    }

    #[test]
    fn dequantized_gather_is_close_to_fp32() {
        let f = random_features(12, 6, 4);
        let mut store = QuantFeatureStore::new(&f, 8);
        let nodes: Vec<u32> = vec![0, 5, 11];
        let approx = store.gather_dequantized(&f, &nodes);
        let exact = gather_rows(&f, &nodes);
        // Nearest rounding: within half a grid step everywhere.
        assert!(approx.max_abs_diff(&exact) <= store.scale() / 2.0 + 1e-6);
    }
}
