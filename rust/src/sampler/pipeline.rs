//! Pipelined mini-batch prefetch: truly overlap sampling + quantized
//! feature gathering with model compute (the paper's §4.2 inter-primitive
//! overlap — "we overlap the feature quantization with the subgraph
//! sampling" — made real instead of modelled).
//!
//! Two pieces live here:
//!
//! - [`run_prefetched`] / [`spawn_producer`] — a bounded double-buffer
//!   producer/consumer engine: a background thread runs stage one for
//!   batches `t+1..t+depth` while the caller's thread consumes batch `t`.
//!   `depth == 0` degenerates to the strictly sequential loop. Because
//!   every batch's RNG stream is keyed only by `(epoch, batch index)`
//!   (`mix_seeds(&[epoch, bi])`), a prefetched run is **bit-identical** to
//!   a sequential one — `tests/pipeline_equivalence.rs` enforces this.
//!   A panic on the producer thread surfaces as an error on the consumer
//!   (never a hang), and dropping the handle shuts the producer down.
//!
//! - [`SampleStage`] / [`PreparedBatch`] / [`FeatureGather`] — **the**
//!   stage-one definition: neighbor sampling (node- or edge-seeded with the
//!   LP leakage guard) plus the (quantized) feature gather, shared verbatim
//!   by [`MiniBatchTrainer`](super::MiniBatchTrainer) and the multi-GPU
//!   workers, so the 1-worker step-for-step replay guarantee
//!   (`tests/multigpu_equivalence.rs`) survives the pipelining. The whole
//!   stage is `Send`: the sampler is immutable, the edge batcher is
//!   read-only, and the quantized feature store moves to the producer
//!   thread (owned `&mut`) or stays process-wide behind a `Mutex` (the
//!   multi-GPU shape) — cache stats keep flowing into `TrainReport.cache`
//!   either way.

use super::{
    gather_rows, sample_lp_step, Block, EdgeBatcher, NeighborSampler, QuantFeatureStore,
    QuantRows,
};
use crate::graph::Csr;
use crate::tensor::Dense;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Mutex;
use std::thread::{Scope, ScopedJoinHandle};
use std::time::Instant;

/// Shared stage-one time accounting: how long the producer side spent
/// sampling vs gathering, summed across every `prepare` call that writes
/// here (atomics, so producer threads of any count can share one instance).
///
/// This is *run-local* — each epoch owns its own `StageTimes` — so the
/// numbers land in [`EpochStages`](crate::coordinator::EpochStages) without
/// going through the process-global [`obs`](crate::obs) registry (which
/// parallel test runs share).
#[derive(Debug, Default)]
pub struct StageTimes {
    sample_ns: AtomicU64,
    gather_ns: AtomicU64,
}

impl StageTimes {
    /// Charge `secs` of neighbor-sampling work.
    pub fn add_sample(&self, secs: f64) {
        self.sample_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Charge `secs` of feature-gather work.
    pub fn add_gather(&self, secs: f64) {
        self.gather_ns.fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    }

    /// Total sampling seconds charged so far.
    pub fn sample_s(&self) -> f64 {
        self.sample_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Total gather seconds charged so far.
    pub fn gather_s(&self) -> f64 {
        self.gather_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// What the consumer needs besides blocks + features to run the step.
#[derive(Debug, Clone)]
pub enum BatchTarget {
    /// Node classification: per-seed labels (`labels[i]` belongs to seed row
    /// `i` of the final block — the softmax-CE rows are `0..labels.len()`).
    Nc { labels: Vec<u32> },
    /// Link prediction: candidate pairs `(u, v, target)` with local indices
    /// into the final block's destination rows.
    Lp { pairs: Vec<(u32, u32, f32)> },
}

/// The input-feature payload of a prepared batch: dense FP32 rows, or the
/// quantized gather's bit-packed rows handed to the model untouched
/// (`packed_compute` — the sub-byte payload stays packed into the layer-0
/// GEMM instead of round-tripping through FP32).
#[derive(Debug)]
pub enum BatchInput {
    /// Dense FP32 rows (plain gather, or a quantized gather dequantized).
    F32(Dense<f32>),
    /// Bit-packed quantized rows straight from the gather.
    Packed(QuantRows),
}

impl BatchInput {
    /// Number of feature rows.
    pub fn rows(&self) -> usize {
        match self {
            BatchInput::F32(x) => x.rows(),
            BatchInput::Packed(q) => q.rows(),
        }
    }

    /// The rows as dense FP32, dequantizing a packed payload.
    pub fn to_f32(&self) -> Dense<f32> {
        match self {
            BatchInput::F32(x) => x.clone(),
            BatchInput::Packed(q) => q.dequantize(),
        }
    }
}

/// One fully prepared mini-batch — everything `train_step_input` consumes.
#[derive(Debug)]
pub struct PreparedBatch {
    /// Per-layer sampled blocks, input-side first.
    pub blocks: Vec<Block>,
    /// Gathered input features for `blocks[0].src_nodes` — FP32, or still
    /// bit-packed when the stage runs with `packed` set.
    pub x0: BatchInput,
    /// Loss-side payload.
    pub target: BatchTarget,
}

/// How stage one turns an input frontier into feature rows.
///
/// All variants are `Send`, so a [`SampleStage`] can move to (or be
/// mutably borrowed by) a producer thread.
pub enum FeatureGather<'a> {
    /// FP32 rows straight from the feature table.
    Plain(&'a Dense<f32>),
    /// Quantized gather through a stage-owned store (single-trainer shape).
    Quantized { features: &'a Dense<f32>, store: &'a mut QuantFeatureStore },
    /// Quantized gather through a process-wide shared store (multi-GPU
    /// shape). The lock is held only for the INT8 row gather; the
    /// full-width dequantize runs outside it.
    Shared { features: &'a Dense<f32>, store: &'a Mutex<QuantFeatureStore> },
}

impl<'a> FeatureGather<'a> {
    /// Single-trainer constructor: quantized when a store exists.
    pub fn new(features: &'a Dense<f32>, store: Option<&'a mut QuantFeatureStore>) -> Self {
        match store {
            Some(store) => FeatureGather::Quantized { features, store },
            None => FeatureGather::Plain(features),
        }
    }

    /// Multi-worker constructor over an optional shared store.
    pub fn shared(
        features: &'a Dense<f32>,
        store: Option<&'a Mutex<QuantFeatureStore>>,
    ) -> Self {
        match store {
            Some(store) => FeatureGather::Shared { features, store },
            None => FeatureGather::Plain(features),
        }
    }

    /// Gather the feature rows of `nodes` as FP32 (dequantizing when the
    /// gather is quantized).
    pub fn gather(&mut self, nodes: &[u32]) -> Dense<f32> {
        match self {
            FeatureGather::Plain(features) => gather_rows(features, nodes),
            FeatureGather::Quantized { features, store } => {
                store.gather_dequantized(features, nodes)
            }
            FeatureGather::Shared { features, store } => {
                let q = store
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .gather_quantized(features, nodes);
                q.dequantize()
            }
        }
    }

    /// Gather the feature rows of `nodes` in the form the consumer asked
    /// for: still bit-packed when `packed` is set and the gather is
    /// quantized (the sub-byte payload skips the dequantize entirely), FP32
    /// otherwise. A plain gather has no quantized rows to pass through, so
    /// `packed` degrades to FP32 there.
    pub fn gather_input(&mut self, nodes: &[u32], packed: bool) -> BatchInput {
        if !packed {
            return BatchInput::F32(self.gather(nodes));
        }
        match self {
            FeatureGather::Plain(features) => BatchInput::F32(gather_rows(features, nodes)),
            FeatureGather::Quantized { features, store } => {
                BatchInput::Packed(store.gather_quantized(features, nodes))
            }
            FeatureGather::Shared { features, store } => BatchInput::Packed(
                store
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .gather_quantized(features, nodes),
            ),
        }
    }
}

/// Stage one of the pipeline: sample the blocks for a batch of seeds (nodes
/// for NC, canonical positive-edge ids for LP) and gather their input
/// features. One definition, two consumers — `MiniBatchTrainer` and the
/// multi-GPU workers build their `SampleStage` from the same fields.
pub struct SampleStage<'a> {
    /// Layered fanout sampler (immutable — every draw is stream-keyed).
    pub sampler: &'a NeighborSampler,
    /// Parent in-edge CSR.
    pub csr_in: &'a Csr,
    /// Parent in-degrees (drives the blocks' GCN edge norms).
    pub degrees: &'a [u32],
    /// Parent-graph node labels (indexed by NC batches; unused for LP).
    pub labels: &'a [u32],
    /// LP only: the canonical positive edges + negatives drawn per positive.
    pub lp: Option<(&'a EdgeBatcher, usize)>,
    /// The feature gather (plain, quantized-owned or quantized-shared).
    pub gather: FeatureGather<'a>,
    /// Hand the quantized gather's rows to the model still bit-packed
    /// (`packed_compute`) instead of dequantizing them to FP32.
    pub packed: bool,
    /// Run-local sample/gather time accounting this stage charges into.
    pub times: &'a StageTimes,
}

impl SampleStage<'_> {
    /// Run stage one for one batch: sample (node- or edge-seeded with the
    /// leakage guard), gather features for the input frontier — borrowing
    /// `blocks[0].src_nodes` in place, no per-batch copy — and assemble the
    /// loss-side payload. Sampling and gather times are charged to `times`
    /// (and, when tracing is on, recorded as `stage1/sample` /
    /// `stage1/gather` spans on the calling thread).
    pub fn prepare(&mut self, batch: &[u32], stream: u64) -> PreparedBatch {
        let _stage_span = crate::obs::span(crate::obs::keys::SPAN_STAGE1);
        crate::obs::counter_add(crate::obs::keys::CTR_PIPELINE_BATCHES_PREPARED, 1);
        match self.lp {
            None => {
                let t0 = Instant::now();
                let blocks = {
                    let _s = crate::obs::span(crate::obs::keys::SPAN_SAMPLE);
                    self.sampler.sample_blocks(self.csr_in, self.degrees, batch, stream)
                };
                self.times.add_sample(t0.elapsed().as_secs_f64());
                let t1 = Instant::now();
                let x0 = {
                    let _s = crate::obs::span(crate::obs::keys::SPAN_GATHER);
                    self.gather.gather_input(&blocks[0].src_nodes, self.packed)
                };
                self.times.add_gather(t1.elapsed().as_secs_f64());
                let labels: Vec<u32> =
                    batch.iter().map(|&v| self.labels[v as usize]).collect();
                PreparedBatch { blocks, x0, target: BatchTarget::Nc { labels } }
            }
            Some((batcher, neg_per_pos)) => {
                let t0 = Instant::now();
                let (blocks, pairs) = {
                    let _s = crate::obs::span(crate::obs::keys::SPAN_SAMPLE);
                    sample_lp_step(
                        batcher,
                        self.sampler,
                        self.csr_in,
                        self.degrees,
                        batch,
                        stream,
                        neg_per_pos,
                    )
                };
                self.times.add_sample(t0.elapsed().as_secs_f64());
                let t1 = Instant::now();
                let x0 = {
                    let _s = crate::obs::span(crate::obs::keys::SPAN_GATHER);
                    self.gather.gather_input(&blocks[0].src_nodes, self.packed)
                };
                self.times.add_gather(t1.elapsed().as_secs_f64());
                PreparedBatch { blocks, x0, target: BatchTarget::Lp { pairs } }
            }
        }
    }
}

/// Wall-clock accounting of a prefetched loop.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefetchStats {
    /// Stage-one time **not** hidden behind consumer compute: with
    /// `depth == 0` this is the whole inline sample+gather time; with
    /// `depth > 0` it is only the time the consumer blocked on the channel.
    pub wait_s: f64,
    /// Batches consumed.
    pub batches: usize,
}

/// Handle to a scoped producer thread feeding a bounded channel.
///
/// Dropping the handle first closes the channel (so a blocked producer
/// `send` fails and the thread exits) and then joins it, swallowing any
/// panic — error paths can simply drop their sources. To *observe* a
/// producer panic, use [`ProducerHandle::recv`], which joins on disconnect
/// and surfaces the panic as an error.
pub struct ProducerHandle<'scope, T> {
    rx: Option<Receiver<T>>,
    join: Option<ScopedJoinHandle<'scope, ()>>,
}

impl<T> ProducerHandle<'_, T> {
    /// Blocking receive of the next prepared item. `Ok(None)` means the
    /// producer finished cleanly; a producer panic becomes `Err` (never a
    /// hang — the channel disconnects when the producer dies).
    pub fn recv(&mut self) -> crate::Result<Option<T>> {
        let Some(rx) = &self.rx else { return Ok(None) };
        match rx.recv() {
            Ok(item) => Ok(Some(item)),
            Err(_) => match self.join.take() {
                Some(handle) => match handle.join() {
                    Ok(()) => Ok(None),
                    Err(payload) => Err(anyhow::anyhow!(
                        "prefetch producer thread panicked: {}",
                        panic_message(&payload)
                    )),
                },
                None => Ok(None),
            },
        }
    }
}

impl<T> Drop for ProducerHandle<'_, T> {
    fn drop(&mut self) {
        // Close the channel before joining: a producer blocked in `send`
        // unblocks with an error the moment the receiver is gone.
        drop(self.rx.take());
        if let Some(handle) = self.join.take() {
            let _ = handle.join(); // panic already surfaced via recv, or moot
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Spawn a producer thread inside `scope` that runs `produce(i)` for
/// `i in 0..num_batches` **in order**, feeding a bounded channel of
/// `depth` slots (the double buffer: the producer runs at most `depth`
/// batches ahead of the consumer).
pub fn spawn_producer<'scope, T, P>(
    scope: &'scope Scope<'scope, '_>,
    depth: usize,
    num_batches: usize,
    mut produce: P,
) -> ProducerHandle<'scope, T>
where
    T: Send + 'scope,
    P: FnMut(usize) -> T + Send + 'scope,
{
    let (tx, rx) = sync_channel::<T>(depth.max(1));
    // The producer belongs to the spawner's simulated worker: inherit its
    // trace pid so timeline events group under the right process lane.
    let pid = crate::obs::trace_current_pid();
    let join = scope.spawn(move || {
        let _pid = crate::obs::trace_pid_scope(pid);
        for i in 0..num_batches {
            let item = produce(i);
            if tx.send(item).is_err() {
                break; // consumer gone (early exit / error path)
            }
        }
    });
    ProducerHandle { rx: Some(rx), join: Some(join) }
}

/// Run `consume(i, produce(i))` for `i in 0..num_batches` with stage one
/// (`produce`) prefetched `depth` batches ahead on a background thread.
///
/// - `depth == 0` (or a single batch): strictly sequential, no thread — the
///   baseline the equivalence tests compare against.
/// - `depth > 0`: `produce` moves to a producer thread; `consume` stays on
///   the caller's thread. Items arrive in index order, so the observable
///   sequence of `(i, item)` pairs is identical to the sequential loop.
///
/// Returns measured [`PrefetchStats`]; a producer panic is returned as an
/// error after the batches produced before the panic have been consumed.
pub fn run_prefetched<T, P, C>(
    num_batches: usize,
    depth: usize,
    mut produce: P,
    mut consume: C,
) -> crate::Result<PrefetchStats>
where
    T: Send,
    P: FnMut(usize) -> T + Send,
    C: FnMut(usize, T),
{
    let mut stats = PrefetchStats::default();
    if depth == 0 || num_batches <= 1 {
        for i in 0..num_batches {
            let t0 = Instant::now();
            let item = produce(i);
            stats.wait_s += t0.elapsed().as_secs_f64();
            consume(i, item);
            stats.batches += 1;
        }
        return Ok(stats);
    }
    std::thread::scope(|scope| {
        let mut producer = spawn_producer(scope, depth, num_batches, &mut produce);
        for i in 0..num_batches {
            let t0 = Instant::now();
            let item = producer.recv()?.ok_or_else(|| {
                anyhow::anyhow!("prefetch producer ended early at batch {i}/{num_batches}")
            })?;
            stats.wait_s += t0.elapsed().as_secs_f64();
            consume(i, item);
            stats.batches += 1;
        }
        Ok(stats)
    })
}

/// [`spawn_producer`] generalized to a sub-range, with the produce closure
/// living behind a shared `Mutex` so a **replacement** producer thread can
/// pick up where a panicked one died (the panic poisons the mutex; the
/// respawn recovers it via `into_inner` — the repo-wide poison idiom).
pub fn spawn_producer_range<'scope, T, P>(
    scope: &'scope Scope<'scope, '_>,
    depth: usize,
    range: std::ops::Range<usize>,
    produce: &'scope Mutex<P>,
) -> ProducerHandle<'scope, T>
where
    T: Send + 'scope,
    P: FnMut(usize) -> T + Send,
{
    let (tx, rx) = sync_channel::<T>(depth.max(1));
    let pid = crate::obs::trace_current_pid();
    let join = scope.spawn(move || {
        let _pid = crate::obs::trace_pid_scope(pid);
        let mut produce = produce.lock().unwrap_or_else(|e| e.into_inner());
        for i in range {
            let item = produce(i);
            if tx.send(item).is_err() {
                break; // consumer gone (early exit / error path)
            }
        }
    });
    ProducerHandle { rx: Some(rx), join: Some(join) }
}

/// [`run_prefetched`] with a producer-restart seam: when stage one panics
/// (injected fault or real bug), `on_panic(next, err)` decides the run's
/// fate — return `Ok(())` to respawn the producer from batch `next` (the
/// first batch not yet consumed; everything produced before the panic is
/// drained first, so no batch is lost or repeated), or `Err` to abort the
/// epoch with that error.
///
/// Semantics are otherwise identical to [`run_prefetched`] — same ordering
/// guarantee, same stats — and a panic-free run consumes exactly the same
/// `(i, item)` sequence, so the bit-identity contract of
/// `tests/pipeline_equivalence.rs` extends to recovered runs.
pub fn run_prefetched_restartable<T, P, C, F>(
    num_batches: usize,
    depth: usize,
    produce: P,
    mut consume: C,
    mut on_panic: F,
) -> crate::Result<PrefetchStats>
where
    T: Send,
    P: FnMut(usize) -> T + Send,
    C: FnMut(usize, T),
    F: FnMut(usize, anyhow::Error) -> crate::Result<()>,
{
    let mut stats = PrefetchStats::default();
    let produce = Mutex::new(produce);
    let mut next = 0usize;
    if depth == 0 || num_batches <= 1 {
        // Inline path: the "producer" is the caller's own thread, so the
        // panic is caught (and the mutex poison recovered) right here.
        while next < num_batches {
            let t0 = Instant::now();
            let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                (produce.lock().unwrap_or_else(|e| e.into_inner()))(next)
            }));
            stats.wait_s += t0.elapsed().as_secs_f64();
            match attempt {
                Ok(item) => {
                    consume(next, item);
                    stats.batches += 1;
                    next += 1;
                }
                Err(payload) => on_panic(
                    next,
                    anyhow::anyhow!(
                        "prefetch producer panicked: {}",
                        panic_message(payload.as_ref())
                    ),
                )?,
            }
        }
        return Ok(stats);
    }
    std::thread::scope(|scope| {
        while next < num_batches {
            let mut producer =
                spawn_producer_range(scope, depth, next..num_batches, &produce);
            loop {
                let t0 = Instant::now();
                let received = producer.recv();
                stats.wait_s += t0.elapsed().as_secs_f64();
                match received {
                    Ok(Some(item)) => {
                        consume(next, item);
                        stats.batches += 1;
                        next += 1;
                    }
                    Ok(None) if next < num_batches => {
                        return Err(anyhow::anyhow!(
                            "prefetch producer ended early at batch {next}/{num_batches}"
                        ));
                    }
                    Ok(None) => break,
                    Err(e) => {
                        on_panic(next, e)?;
                        break; // respawn a producer from batch `next`
                    }
                }
            }
        }
        Ok(stats)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetched_order_matches_sequential() {
        for depth in [0usize, 1, 2, 7] {
            let mut seen = Vec::new();
            let stats = run_prefetched(
                25,
                depth,
                |i| i * i,
                |i, item| {
                    assert_eq!(item, i * i);
                    seen.push(i);
                },
            )
            .unwrap();
            assert_eq!(seen, (0..25).collect::<Vec<_>>(), "depth {depth}");
            assert_eq!(stats.batches, 25);
        }
    }

    #[test]
    fn zero_batches_is_a_noop() {
        for depth in [0usize, 3] {
            let stats =
                run_prefetched(0, depth, |_| panic!("no batches"), |_, _: ()| {}).unwrap();
            assert_eq!(stats.batches, 0);
            assert_eq!(stats.wait_s, 0.0);
        }
    }

    #[test]
    fn fewer_batches_than_depth() {
        // The channel is deeper than the whole epoch: everything buffers,
        // order still holds.
        let mut got = Vec::new();
        run_prefetched(3, 16, |i| i + 100, |_, v| got.push(v)).unwrap();
        assert_eq!(got, vec![100, 101, 102]);
    }

    #[test]
    fn producer_panic_propagates_as_error_without_hang() {
        let mut consumed = 0usize;
        let err = run_prefetched(
            10,
            2,
            |i| {
                if i == 3 {
                    panic!("stage one exploded at batch {i}");
                }
                i
            },
            |_, _| consumed += 1,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("stage one exploded"), "{msg}");
        // Batches produced before the panic were consumed in order.
        assert_eq!(consumed, 3);
    }

    #[test]
    fn consumer_early_drop_shuts_producer_down() {
        // Dropping the handle mid-stream must not hang even while the
        // producer is blocked on a full channel.
        std::thread::scope(|scope| {
            let mut h = spawn_producer(scope, 1, 1000, |i| i);
            assert_eq!(h.recv().unwrap(), Some(0));
            drop(h); // closes the channel, joins the producer
        });
    }

    #[test]
    fn restartable_matches_sequential_when_no_panic() {
        for depth in [0usize, 2] {
            let mut seen = Vec::new();
            let stats = run_prefetched_restartable(
                12,
                depth,
                |i| i * 3,
                |i, item| {
                    assert_eq!(item, i * 3);
                    seen.push(i);
                },
                |_, e| panic!("no panic expected: {e}"),
            )
            .unwrap();
            assert_eq!(seen, (0..12).collect::<Vec<_>>(), "depth {depth}");
            assert_eq!(stats.batches, 12);
        }
    }

    #[test]
    fn restartable_resumes_from_last_consumed_batch() {
        use std::sync::atomic::AtomicBool;
        // The producer dies once at batch 5; after the restart the consumer
        // must see every index exactly once, in order.
        for depth in [0usize, 2] {
            let exploded = AtomicBool::new(false);
            let mut seen = Vec::new();
            let mut restarts = 0usize;
            run_prefetched_restartable(
                10,
                depth,
                |i| {
                    if i == 5 && !exploded.swap(true, Ordering::SeqCst) {
                        panic!("injected fault: producer dies at {i}");
                    }
                    i + 50
                },
                |i, item| {
                    assert_eq!(item, i + 50);
                    seen.push(i);
                },
                |next, e| {
                    assert_eq!(next, 5, "panic surfaces at the first unconsumed batch");
                    assert!(format!("{e:#}").contains("injected fault"), "{e:#}");
                    restarts += 1;
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(seen, (0..10).collect::<Vec<_>>(), "depth {depth}");
            assert_eq!(restarts, 1, "depth {depth}");
        }
    }

    #[test]
    fn restartable_on_panic_err_aborts_with_that_error() {
        let err = run_prefetched_restartable(
            6,
            2,
            |i: usize| -> usize {
                if i >= 2 {
                    panic!("injected fault: unrecoverable");
                }
                i
            },
            |_, _| {},
            |_, e| Err(anyhow::anyhow!("retry budget exhausted: {e}")),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("retry budget exhausted"), "{err:#}");
    }

    #[test]
    fn stats_measure_inline_time_when_sequential() {
        let stats = run_prefetched(
            4,
            0,
            |i| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                i
            },
            |_, _| {},
        )
        .unwrap();
        assert!(stats.wait_s >= 0.004, "inline produce time must be charged");
    }
}
