//! MFG/block-style subgraph extraction (the DGL `to_block` shape).
//!
//! A [`Block`] is one layer's bipartite message-flow graph: edges run from a
//! *source* frontier (layer input) to a compact *destination* set (layer
//! output). Node ids are compacted so tensors index densely, and the
//! destination nodes are stored as a **prefix** of the source nodes — the
//! invariant every block consumer (SPMM output rows, SDDMM `dst` lookups,
//! residual feature reuse) relies on.

use crate::graph::{Coo, Csr};

/// One layer's sampled bipartite block, with compacted local node ids.
#[derive(Debug, Clone)]
pub struct Block {
    /// Global (parent-graph) ids of the source nodes. The first
    /// [`Block::num_dst`] entries are the destination nodes — destinations
    /// are always a prefix of the sources.
    pub src_nodes: Vec<u32>,
    /// Number of destination (output) nodes.
    pub num_dst: usize,
    /// Local-id edge list: `src[e] ∈ 0..num_src`, `dst[e] ∈ 0..num_dst`.
    /// `coo.num_nodes == num_src` so source-indexed kernels stay in range.
    pub coo: Coo,
    /// Destination-grouped CSR (`num_nodes == num_dst`) — the forward
    /// aggregation layout; its `srcs` index the full source frontier.
    pub csr: Csr,
    /// Source-grouped CSR (`num_nodes == num_src`) — the backward
    /// (reversed-graph) aggregation layout; its `srcs` are destination ids.
    pub csr_rev: Csr,
    /// Per-edge GCN symmetric norm `1/sqrt(deg(u)·deg(v))` computed from the
    /// *parent graph's* in-degrees, indexed by local edge id.
    pub norm: Vec<f32>,
}

impl Block {
    /// Assemble a block from compacted edge arrays.
    ///
    /// `src_nodes` are global ids (destinations first), `src_local` /
    /// `dst_local` are parallel local-id edge arrays, and `degrees` the
    /// parent graph's in-degrees (for the GCN edge norms).
    pub fn new(
        src_nodes: Vec<u32>,
        num_dst: usize,
        src_local: Vec<u32>,
        dst_local: Vec<u32>,
        degrees: &[u32],
    ) -> Self {
        assert!(num_dst <= src_nodes.len(), "dst nodes must be a prefix of src nodes");
        assert_eq!(src_local.len(), dst_local.len(), "edge array mismatch");
        let num_src = src_nodes.len();
        let deg = |local: u32| -> f32 {
            let global = src_nodes[local as usize] as usize;
            degrees.get(global).copied().unwrap_or(1).max(1) as f32
        };
        let norm: Vec<f32> = src_local
            .iter()
            .zip(dst_local.iter())
            .map(|(&u, &v)| 1.0 / (deg(u) * deg(v)).sqrt())
            .collect();
        let csr = Csr::from_grouped_edges(num_dst, &dst_local, &src_local);
        let csr_rev = Csr::from_grouped_edges(num_src, &src_local, &dst_local);
        let coo = Coo::new(num_src, src_local, dst_local);
        Block { src_nodes, num_dst, coo, csr, csr_rev, norm }
    }

    /// The whole parent graph as one *identity* block: every node is both a
    /// source and a destination (`num_dst == num_src == |V|`) and the edges
    /// keep their original COO order, so `csr`/`csr_rev`/`norm` are exactly
    /// the parent's [`Csr::from_coo`]/[`Csr::from_coo_reversed`]/GCN-norm
    /// layouts. This is what collapses the full-graph training path into
    /// the block path: a full-graph epoch is a block step whose blocks are
    /// `layers` copies of the identity block, bit-for-bit.
    pub fn identity(graph: &Coo, degrees: &[u32]) -> Block {
        let src_nodes: Vec<u32> = (0..graph.num_nodes as u32).collect();
        Block::new(src_nodes, graph.num_nodes, graph.src.clone(), graph.dst.clone(), degrees)
    }

    /// Number of source (input) nodes.
    #[inline]
    pub fn num_src(&self) -> usize {
        self.src_nodes.len()
    }

    /// Number of edges in the block.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.coo.num_edges()
    }

    /// Global ids of the destination (output) nodes — the prefix of
    /// [`Block::src_nodes`].
    #[inline]
    pub fn dst_nodes(&self) -> &[u32] {
        &self.src_nodes[..self.num_dst]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_block() -> Block {
        // dst = {10, 11}; frontier adds {12, 13}. Edges (local):
        // 2->0, 3->0, 1->1, 0->1.
        Block::new(
            vec![10, 11, 12, 13],
            2,
            vec![2, 3, 1, 0],
            vec![0, 0, 1, 1],
            &[4, 4, 1, 9, 0, 0, 0, 0, 0, 0, 1, 1, 4, 4],
        )
    }

    #[test]
    fn shapes_and_prefix() {
        let b = toy_block();
        assert_eq!(b.num_src(), 4);
        assert_eq!(b.num_dst, 2);
        assert_eq!(b.num_edges(), 4);
        assert_eq!(b.dst_nodes(), &[10, 11]);
        assert_eq!(b.csr.num_nodes, 2);
        assert_eq!(b.csr_rev.num_nodes, 4);
        assert_eq!(b.coo.num_nodes, 4);
    }

    #[test]
    fn csr_groups_by_destination() {
        let b = toy_block();
        let (srcs, eids) = b.csr.row(0);
        assert_eq!(srcs, &[2, 3]);
        assert_eq!(eids, &[0, 1]);
        let (srcs, _) = b.csr.row(1);
        assert_eq!(srcs, &[1, 0]);
    }

    #[test]
    fn reversed_csr_groups_by_source() {
        let b = toy_block();
        // Local source 2 (global 12) feeds only dst 0.
        let (dsts, eids) = b.csr_rev.row(2);
        assert_eq!(dsts, &[0]);
        assert_eq!(eids, &[0]);
        // Local source 0 (global 10, also a dst) feeds dst 1 via edge 3.
        assert_eq!(b.csr_rev.row(0).0, &[1]);
    }

    #[test]
    fn identity_block_reproduces_parent_layouts() {
        let g = crate::graph::generators::erdos_renyi(12, 30, 3).with_self_loops();
        let deg = g.in_degrees();
        let b = Block::identity(&g, &deg);
        assert_eq!(b.num_src(), g.num_nodes);
        assert_eq!(b.num_dst, g.num_nodes);
        assert_eq!(b.num_edges(), g.num_edges());
        assert_eq!(b.coo, g, "edge order must be the parent COO order");
        assert_eq!(b.csr, Csr::from_coo(&g));
        assert_eq!(b.csr_rev, Csr::from_coo_reversed(&g));
        // Norms match the full-graph GCN formula edge for edge.
        for e in 0..g.num_edges() {
            let du = deg[g.src[e] as usize].max(1) as f32;
            let dv = deg[g.dst[e] as usize].max(1) as f32;
            assert_eq!(b.norm[e], 1.0 / (du * dv).sqrt());
        }
    }

    #[test]
    fn norms_use_parent_degrees() {
        let b = toy_block();
        // Edge 0: global 12 -> 10, degrees 4 and 1: 1/sqrt(4*1) = 0.5.
        assert!((b.norm[0] - 0.5).abs() < 1e-6);
        // Edge 2: global 11 -> 11, degree 1: 1/sqrt(1*1) = 1.0.
        assert!((b.norm[2] - 1.0).abs() < 1e-6);
    }
}
