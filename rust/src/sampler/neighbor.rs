//! Layered neighbor sampling (DGL `MultiLayerNeighborSampler` shape):
//! per-layer fanouts over the in-edge CSR, producing one [`Block`] per
//! model layer with compacted node ids.
//!
//! Sampling walks outward from the seed nodes: the last layer's block has
//! the seeds as destinations; each earlier layer's destinations are the
//! previous block's source frontier. Every draw comes from the same seeded
//! xoshiro256++ stream the quantizer uses, so a `(sampler seed, stream,
//! seeds)` triple always reproduces the same blocks.
//!
//! Fanout selection is either **uniform** (every admissible in-neighbor
//! equally likely — the default, byte-identical to the pre-policy sampler)
//! or **degree-biased** ([`SamplerBias::Degree`], `--sampler degree`):
//! each draw picks among the remaining candidates with probability
//! proportional to their *global* in-degree, the Degree-Quant-style
//! importance rule that keeps the accuracy-critical hub nodes in the
//! sampled computation graph. Both modes are stream-seeded and
//! deterministic.

use super::Block;
use crate::graph::Csr;
use crate::quant::rng::Xoshiro256pp;
use std::collections::{HashMap, HashSet};

/// How fanout draws weight the candidate in-neighbors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplerBias {
    /// Uniform without replacement (DGL default).
    #[default]
    Uniform,
    /// Without replacement, each draw proportional to the candidate's
    /// global in-degree (hubs preferentially kept in the frontier).
    Degree,
}

impl SamplerBias {
    /// The bias a [`SamplerConfig`](crate::config::SamplerConfig) asks
    /// for — the ONE conversion `MiniBatchTrainer` and the multi-GPU
    /// workers share, so the two engines (and their 1-worker replay
    /// equivalence) cannot diverge when sampling modes grow.
    pub fn from_config(sampler: &crate::config::SamplerConfig) -> Self {
        if sampler.degree_biased {
            SamplerBias::Degree
        } else {
            SamplerBias::Uniform
        }
    }
}

/// Layered neighbor sampler with per-layer fanouts.
#[derive(Debug, Clone)]
pub struct NeighborSampler {
    /// Per-layer fanouts, input-side layer first (`fanouts[l]` bounds the
    /// in-edges sampled per destination in `blocks[l]`).
    pub fanouts: Vec<usize>,
    /// Base seed for the sampling streams.
    pub seed: u64,
    /// Fanout selection weighting (uniform by default).
    pub bias: SamplerBias,
}

impl NeighborSampler {
    /// New uniform sampler; `fanouts` must name at least one layer.
    pub fn new(fanouts: Vec<usize>, seed: u64) -> Self {
        Self::with_bias(fanouts, seed, SamplerBias::Uniform)
    }

    /// New sampler with an explicit fanout-selection bias.
    pub fn with_bias(fanouts: Vec<usize>, seed: u64, bias: SamplerBias) -> Self {
        assert!(!fanouts.is_empty(), "need at least one fanout");
        assert!(fanouts.iter().all(|&f| f >= 1), "fanouts must be >= 1");
        NeighborSampler { fanouts, seed, bias }
    }

    /// Sample the per-layer blocks for one mini-batch.
    ///
    /// `csr_in` is the parent graph's in-edge CSR, `degrees` its in-degrees
    /// (drives the blocks' GCN edge norms), `seeds` the batch's **distinct**
    /// seed nodes, and `stream` a per-batch stream id (epoch × batch index).
    /// Returns `fanouts.len()` blocks, input-side first; the final block's
    /// destinations are exactly `seeds`, and `blocks[l].dst_nodes() ==
    /// blocks[l+1].src_nodes` (the chaining the layered forward consumes).
    pub fn sample_blocks(
        &self,
        csr_in: &Csr,
        degrees: &[u32],
        seeds: &[u32],
        stream: u64,
    ) -> Vec<Block> {
        self.sample_blocks_excluding(csr_in, degrees, seeds, stream, &HashSet::new())
    }

    /// Like [`Self::sample_blocks`], but never samples an in-edge `u -> v`
    /// whose **global** `(u, v)` pair is in `exclude`.
    ///
    /// This is the link-prediction leakage guard: the positive edges a
    /// batch trains on are excluded (in both directions — the datasets add
    /// reverse edges) from every layer's message edges, so the model cannot
    /// read an edge's existence off the very message it is asked to
    /// predict. With an empty `exclude` set the rng draw sequence is
    /// identical to [`Self::sample_blocks`] — the two entry points cannot
    /// drift.
    pub fn sample_blocks_excluding(
        &self,
        csr_in: &Csr,
        degrees: &[u32],
        seeds: &[u32],
        stream: u64,
        exclude: &HashSet<(u32, u32)>,
    ) -> Vec<Block> {
        let _t = crate::obs::timed(crate::obs::keys::TIMED_SAMPLER_SAMPLE_BLOCKS);
        let mut rng = Xoshiro256pp::new(self.seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        // Destinations that actually have an excluded in-edge — every other
        // frontier node takes the allocation-free fast path below.
        let excluded_dst: HashSet<u32> = exclude.iter().map(|&(_, v)| v).collect();
        let layers = self.fanouts.len();
        let mut blocks: Vec<Block> = Vec::with_capacity(layers);
        let mut frontier: Vec<u32> = seeds.to_vec();
        // Walk output-side layer (dst = seeds) back to the input side.
        for l in (0..layers).rev() {
            let fanout = self.fanouts[l];
            let num_dst = frontier.len();
            let mut src_nodes = frontier.clone();
            let mut local_of: HashMap<u32, u32> =
                frontier.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
            debug_assert_eq!(local_of.len(), num_dst, "seed/frontier nodes must be distinct");
            let mut src_local: Vec<u32> = Vec::new();
            let mut dst_local: Vec<u32> = Vec::new();
            for (dv, &v) in frontier.iter().enumerate() {
                let (all_nbrs, _eids) = csr_in.row(v as usize);
                // Drop excluded seed edges *before* drawing, so the fanout
                // budget is spent on admissible neighbours only. Nodes with
                // no excluded in-edge keep the unfiltered slice — no
                // allocation, and the rng stream is unchanged (draws depend
                // only on the admissible count, which filtering to the same
                // list preserves).
                let filtered: Vec<u32>;
                let nbrs: &[u32] = if !excluded_dst.contains(&v) {
                    all_nbrs
                } else {
                    filtered = all_nbrs
                        .iter()
                        .copied()
                        .filter(|&u| !exclude.contains(&(u, v)))
                        .collect();
                    &filtered
                };
                let take = fanout.min(nbrs.len());
                if take == 0 {
                    continue;
                }
                let mut idx: Vec<usize> = (0..nbrs.len()).collect();
                match self.bias {
                    SamplerBias::Uniform => {
                        // Uniform without replacement: partial Fisher–Yates
                        // over an index window (degree <= fanout takes every
                        // in-edge). This arm's rng draw sequence is the
                        // pre-policy sampler's, byte for byte.
                        for i in 0..take {
                            let j = i + (rng.next_u64() % (idx.len() - i) as u64) as usize;
                            idx.swap(i, j);
                        }
                    }
                    SamplerBias::Degree => {
                        // Weighted without replacement: each draw picks
                        // among the not-yet-taken candidates proportionally
                        // to their global in-degree (integer weights — the
                        // draw is exact and deterministic per stream). The
                        // remaining-weight total is maintained incrementally
                        // (subtract the taken weight) instead of re-summed
                        // per draw. The pick itself is a linear scan —
                        // O(fanout · degree) per destination — which is fine
                        // at this repo's graph scale (hub in-degrees in the
                        // hundreds); swap in a Fenwick tree over the weights
                        // if hub degrees ever reach ~10^5.
                        let mut weights: Vec<u64> = idx
                            .iter()
                            .map(|&k| u64::from(degrees[nbrs[k] as usize]).max(1))
                            .collect();
                        let mut total: u64 = weights.iter().sum();
                        for i in 0..take {
                            let mut r = rng.next_u64() % total;
                            let mut j = i;
                            for (off, &w) in weights[i..].iter().enumerate() {
                                if r < w {
                                    j = i + off;
                                    break;
                                }
                                r -= w;
                            }
                            idx.swap(i, j);
                            weights.swap(i, j);
                            total -= weights[i];
                        }
                    }
                }
                for &k in idx.iter().take(take) {
                    let u = nbrs[k];
                    let lu = *local_of.entry(u).or_insert_with(|| {
                        src_nodes.push(u);
                        (src_nodes.len() - 1) as u32
                    });
                    src_local.push(lu);
                    dst_local.push(dv as u32);
                }
            }
            let block = Block::new(src_nodes, num_dst, src_local, dst_local, degrees);
            frontier = block.src_nodes.clone();
            blocks.push(block);
        }
        blocks.reverse();
        blocks
    }
}

/// Adjust a per-layer fanout list to exactly `layers` entries: an empty
/// list falls back to 10 per layer, a short list repeats its last entry,
/// and a long one truncates. This is the one rule every sampled-training
/// consumer ([`MiniBatchTrainer`](crate::sampler::MiniBatchTrainer) and the
/// multi-GPU workers) applies to `SamplerConfig::fanouts`.
pub fn adjust_fanouts(fanouts: &[usize], layers: usize) -> Vec<usize> {
    let mut out = fanouts.to_vec();
    if out.is_empty() {
        out.push(10);
    }
    let layers = layers.max(1);
    if let Some(&last) = out.last() {
        while out.len() < layers {
            out.push(last);
        }
    }
    out.truncate(layers);
    out
}

/// Shuffle `nodes` with a seeded Fisher–Yates and split into mini-batches of
/// `batch_size` seeds (the last batch may be smaller).
pub fn shuffled_batches(nodes: &[u32], batch_size: usize, seed: u64) -> Vec<Vec<u32>> {
    assert!(batch_size >= 1, "batch_size must be >= 1");
    let mut order = nodes.to_vec();
    let mut rng = Xoshiro256pp::new(seed);
    for i in (1..order.len()).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        order.swap(i, j);
    }
    order.chunks(batch_size).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Coo;

    fn parent() -> (Coo, Csr, Vec<u32>) {
        let coo = crate::graph::generators::erdos_renyi(60, 400, 3).with_self_loops();
        let csr = Csr::from_coo(&coo);
        let deg = coo.in_degrees();
        (coo, csr, deg)
    }

    #[test]
    fn blocks_chain_and_end_at_seeds() {
        let (_, csr, deg) = parent();
        let s = NeighborSampler::new(vec![3, 2], 7);
        let seeds: Vec<u32> = vec![4, 9, 17, 33];
        let blocks = s.sample_blocks(&csr, &deg, &seeds, 1);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[1].dst_nodes(), &seeds[..]);
        assert_eq!(blocks[0].dst_nodes(), &blocks[1].src_nodes[..]);
        assert_eq!(blocks[0].num_dst, blocks[1].num_src());
    }

    #[test]
    fn fanout_bounds_per_destination_edges() {
        let (_, csr, deg) = parent();
        let s = NeighborSampler::new(vec![2], 11);
        let seeds: Vec<u32> = (0..20).collect();
        let blocks = s.sample_blocks(&csr, &deg, &seeds, 0);
        let b = &blocks[0];
        let mut per_dst = vec![0usize; b.num_dst];
        for e in 0..b.num_edges() {
            per_dst[b.coo.dst[e] as usize] += 1;
        }
        assert!(per_dst.iter().all(|&c| c <= 2), "{per_dst:?}");
        // Self-loops guarantee every seed kept at least one in-edge.
        assert!(per_dst.iter().all(|&c| c >= 1));
    }

    #[test]
    fn deterministic_under_fixed_seed_and_stream() {
        let (_, csr, deg) = parent();
        let s = NeighborSampler::new(vec![3, 3], 21);
        let seeds: Vec<u32> = vec![1, 2, 3, 5, 8];
        let a = s.sample_blocks(&csr, &deg, &seeds, 9);
        let b = s.sample_blocks(&csr, &deg, &seeds, 9);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.src_nodes, y.src_nodes);
            assert_eq!(x.coo, y.coo);
            assert_eq!(x.norm, y.norm);
        }
        // A different stream samples a different frontier (overwhelmingly).
        let c = s.sample_blocks(&csr, &deg, &seeds, 10);
        assert!(a[0].coo != c[0].coo || a[0].src_nodes != c[0].src_nodes);
    }

    #[test]
    fn full_fanout_takes_every_in_edge() {
        let (coo, csr, deg) = parent();
        let s = NeighborSampler::new(vec![1 << 30], 5);
        let seeds: Vec<u32> = (0..coo.num_nodes as u32).collect();
        let blocks = s.sample_blocks(&csr, &deg, &seeds, 2);
        assert_eq!(blocks[0].num_edges(), coo.num_edges());
        assert_eq!(blocks[0].num_src(), coo.num_nodes);
    }

    #[test]
    fn exclusion_removes_edges_and_empty_set_is_identity() {
        let (_, csr, deg) = parent();
        let s = NeighborSampler::new(vec![1 << 30, 1 << 30], 3);
        let seeds: Vec<u32> = vec![2, 7, 11];
        // Empty set: bit-identical to the plain entry point.
        let a = s.sample_blocks(&csr, &deg, &seeds, 5);
        let b = s.sample_blocks_excluding(&csr, &deg, &seeds, 5, &HashSet::new());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.src_nodes, y.src_nodes);
            assert_eq!(x.coo, y.coo);
        }
        // Exclude every in-edge of seed 2 except its self-loop; no block may
        // contain an excluded pair.
        let mut exclude = HashSet::new();
        let (nbrs, _) = csr.row(2);
        for &u in nbrs {
            if u != 2 {
                exclude.insert((u, 2u32));
            }
        }
        let blocks = s.sample_blocks_excluding(&csr, &deg, &seeds, 5, &exclude);
        for blk in &blocks {
            for e in 0..blk.num_edges() {
                let gu = blk.src_nodes[blk.coo.src[e] as usize];
                let gv = blk.src_nodes[blk.coo.dst[e] as usize];
                assert!(!exclude.contains(&(gu, gv)), "excluded edge ({gu},{gv}) sampled");
            }
        }
        // The self-loop keeps seed 2 reachable.
        let last = blocks.last().unwrap();
        let d2 = last.dst_nodes().iter().position(|&v| v == 2).unwrap();
        assert!(last.csr.row(d2).0.iter().any(|&u| last.src_nodes[u as usize] == 2));
    }

    #[test]
    fn degree_bias_is_deterministic_and_respects_fanout() {
        let (_, csr, deg) = parent();
        let s = NeighborSampler::with_bias(vec![3, 2], 13, SamplerBias::Degree);
        let seeds: Vec<u32> = vec![2, 6, 10];
        let a = s.sample_blocks(&csr, &deg, &seeds, 4);
        let b = s.sample_blocks(&csr, &deg, &seeds, 4);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.src_nodes, y.src_nodes);
            assert_eq!(x.coo, y.coo);
        }
        assert_eq!(a[1].dst_nodes(), &seeds[..]);
        let mut per_dst = vec![0usize; a[1].num_dst];
        for e in 0..a[1].num_edges() {
            per_dst[a[1].coo.dst[e] as usize] += 1;
        }
        assert!(per_dst.iter().all(|&c| (1..=2).contains(&c)), "{per_dst:?}");
    }

    #[test]
    fn degree_bias_with_full_fanout_takes_every_in_edge() {
        // Weights only matter when the fanout binds; a full-fanout layer
        // keeps the whole in-neighborhood either way.
        let (coo, csr, deg) = parent();
        let s = NeighborSampler::with_bias(vec![1 << 30], 5, SamplerBias::Degree);
        let seeds: Vec<u32> = (0..coo.num_nodes as u32).collect();
        let blocks = s.sample_blocks(&csr, &deg, &seeds, 2);
        assert_eq!(blocks[0].num_edges(), coo.num_edges());
        assert_eq!(blocks[0].num_src(), coo.num_nodes);
    }

    #[test]
    fn fanout_adjustment_repeats_truncates_and_defaults() {
        assert_eq!(adjust_fanouts(&[7], 3), vec![7, 7, 7]);
        assert_eq!(adjust_fanouts(&[9, 5, 3], 2), vec![9, 5]);
        assert_eq!(adjust_fanouts(&[], 2), vec![10, 10]);
        assert_eq!(adjust_fanouts(&[4], 0), vec![4]);
    }

    #[test]
    fn batching_covers_all_nodes_once() {
        let nodes: Vec<u32> = (0..103).collect();
        let batches = shuffled_batches(&nodes, 16, 4);
        assert_eq!(batches.len(), 7);
        assert!(batches[..6].iter().all(|b| b.len() == 16));
        assert_eq!(batches[6].len(), 7);
        let mut all: Vec<u32> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, nodes);
        // Seeded: same seed reproduces, different seed reshuffles.
        assert_eq!(shuffled_batches(&nodes, 16, 4), batches);
        assert_ne!(shuffled_batches(&nodes, 16, 5), batches);
    }
}
