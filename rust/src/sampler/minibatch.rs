//! The mini-batch training engine: seeded shuffled batches → layered
//! neighbor sampling → (quantized) feature gather → block forward/backward,
//! with stage one (sampling + gather) prefetched on a producer thread
//! (`SamplerConfig::prefetch` batches ahead — the paper's §4.2 overlap;
//! see [`super::run_prefetched`]).
//!
//! This is the sampled counterpart of [`crate::coordinator::Trainer`] and
//! produces the same [`TrainReport`] so the CLI, benches and repro drivers
//! treat both execution modes uniformly. `Trainer::run` delegates here when
//! `TrainConfig::sampler.enabled` is set.
//!
//! Both task heads are served:
//!
//! - **node classification** — batches are shuffled train-node sweeps, the
//!   sampler is seeded from the batch nodes, loss is softmax-CE over the
//!   seed rows;
//! - **link prediction** — batches are shuffled sweeps over the graph's
//!   canonical positive edges ([`EdgeBatcher`]); each batch adds seeded
//!   uniform negatives, seeds the sampler from the candidate endpoints and
//!   **excludes the positive edges from the sampled message edges** (the
//!   leakage guard), then scores pairs with the dot-product
//!   [`TaskHead`] decoder under BCE-with-logits.

use super::{
    adjust_fanouts, run_prefetched, run_prefetched_restartable, shuffled_batches, BatchTarget,
    EdgeBatcher, FeatureGather, NeighborSampler, PreparedBatch, QuantFeatureStore, SampleStage,
    SamplerBias, StageTimes,
};
use crate::ckpt::{fingerprint_of, Checkpoint, Cursor, Fingerprint};
use crate::config::{TaskKind, TrainConfig};
use crate::fault::{injected_panic, FaultClass, FaultInjector};
use crate::coordinator::qcache::CacheStats;
use crate::coordinator::{EpochStages, TrainReport};
use crate::graph::datasets::{self, Dataset, Task};
use crate::graph::Csr;
use crate::model::{
    softmax_cross_entropy, AnyModel, GnnModel, ModelSpec, Sgd, TaskHead, TrainMode,
};
use crate::policy::PolicyGatherReport;
use crate::quant::rng::mix_seeds;
use crate::quant::{derive_bits, DEFAULT_ERROR_TARGET};
use std::sync::Mutex;

/// Per-epoch checkpointing context threaded into the consume closure: the
/// run identity plus everything already completed (immutable this epoch).
struct CkptCtx {
    every: usize,
    path: String,
    fingerprint: Fingerprint,
    policy_scales: Option<Vec<f32>>,
    losses: Vec<f64>,
    evals: Vec<f64>,
}

/// Mini-batch neighbor-sampling trainer (node classification *and* link
/// prediction — see the module docs).
pub struct MiniBatchTrainer {
    cfg: TrainConfig,
    data: Dataset,
    /// Effective task (config override or the dataset's declared task).
    task: Task,
    head: TaskHead,
    model: AnyModel,
    opt: Sgd,
    sampler: NeighborSampler,
    csr_in: Csr,
    degrees: Vec<u32>,
    /// Canonical positive edges (LP runs only).
    edges: Option<EdgeBatcher>,
    /// Quantized feature store (None when the mode is full-precision).
    store: Option<QuantFeatureStore>,
}

impl MiniBatchTrainer {
    /// Build everything from a config (loads the dataset, derives bits if
    /// requested, initialises the model and sampler).
    pub fn from_config(cfg: &TrainConfig) -> crate::Result<Self> {
        let data = datasets::load_by_name_checked(&cfg.dataset, cfg.seed)
            .map_err(|e| anyhow::anyhow!(e))?;
        Self::with_dataset(cfg.clone(), data)
    }

    /// Build with an externally supplied dataset.
    pub fn with_dataset(mut cfg: TrainConfig, data: Dataset) -> crate::Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        let task = TaskKind::resolve(cfg.task, data.task);
        let head = TaskHead::for_task(task);
        let out_dim = head.out_dim(&data, cfg.hidden);
        // Same Fig. 2 rule as the full-graph trainer: probe the first
        // layer's output of the initial model on the full graph.
        if cfg.auto_bits && cfg.mode.quantize {
            let probe = Self::build_model(&cfg, &data, out_dim);
            cfg.mode.bits =
                derive_bits(&probe.first_layer_output(&data.features), DEFAULT_ERROR_TARGET).bits;
        }
        let model = Self::build_model(&cfg, &data, out_dim);
        let fanouts = adjust_fanouts(&cfg.sampler.fanouts, cfg.layers);
        let bias = SamplerBias::from_config(&cfg.sampler);
        // Seed formula shared with the multi-GPU workers (worker id 0), so a
        // 1-worker data-parallel run replays this trainer step for step.
        let sampler =
            NeighborSampler::with_bias(fanouts, mix_seeds(&[cfg.sampler.seed, cfg.seed, 0]), bias);
        let csr_in = Csr::from_coo(&data.graph);
        let degrees = data.graph.in_degrees();
        let edges = match task {
            Task::LinkPrediction => Some(EdgeBatcher::new(&data.graph)),
            Task::NodeClassification => None,
        };
        // The degree-aware mixed-precision policy decides each node's
        // `(scale, bits)`; the default uniform policy reproduces the single
        // global scale exactly, so default runs stay bit-identical.
        let store = if cfg.mode.quantize {
            let policy = cfg
                .policy
                .materialize(cfg.mode.bits, &degrees, &data.features)
                .map_err(|e| anyhow::anyhow!(e))?;
            Some(QuantFeatureStore::with_policy(policy, cfg.sampler.cache_nodes))
        } else {
            None
        };
        let opt = Sgd::new(cfg.lr);
        Ok(MiniBatchTrainer {
            cfg,
            data,
            task,
            head,
            model,
            opt,
            sampler,
            csr_in,
            degrees,
            edges,
            store,
        })
    }

    fn build_model(cfg: &TrainConfig, data: &Dataset, out_dim: usize) -> AnyModel {
        AnyModel::new_from_config(
            &ModelSpec::from_train(cfg, data.features.cols(), out_dim),
            &data.graph,
            cfg.seed,
        )
    }

    /// The dataset being trained on.
    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// The effective task of this run.
    pub fn task(&self) -> Task {
        self.task
    }

    /// The effective mode (bits may have been auto-derived).
    pub fn mode(&self) -> TrainMode {
        self.cfg.mode
    }

    /// The per-layer fanouts actually used (after layer-count adjustment).
    pub fn fanouts(&self) -> &[usize] {
        &self.sampler.fanouts
    }

    /// Flatten the trained parameters (same layout as the models'
    /// `params_flat`) — lets `coordinator::Trainer` adopt the weights after
    /// a delegated sampled run.
    pub fn params_flat(&self) -> Vec<f32> {
        self.model.params_flat()
    }

    /// Quantized feature-gather cache statistics (None in FP32 mode).
    pub fn gather_stats(&self) -> Option<CacheStats> {
        self.store.as_ref().map(|s| s.stats())
    }

    /// Per-bucket gather accounting of the mixed-precision policy (None in
    /// FP32 mode).
    pub fn policy_report(&self) -> Option<PolicyGatherReport> {
        self.store.as_ref().map(|s| s.policy_report())
    }

    /// Bytes held by the quantized feature cache.
    pub fn gather_cached_bytes(&self) -> usize {
        self.store.as_ref().map(|s| s.cached_bytes()).unwrap_or(0)
    }

    /// Run the configured number of epochs; every epoch sweeps all training
    /// seeds (nodes for NC, canonical positive edges for LP) once in
    /// shuffled mini-batches. With `SamplerConfig::prefetch > 0` every
    /// epoch runs stage one (sampling + gather) on a producer thread,
    /// `prefetch` batches ahead of the training thread — bit-identical to
    /// the sequential sweep (`tests/pipeline_equivalence.rs`).
    pub fn run(&mut self) -> crate::Result<TrainReport> {
        let fingerprint = fingerprint_of(&self.cfg, 1, true);
        let policy_scales: Option<Vec<f32>> = self.store.as_ref().map(|s| {
            let p = s.policy();
            (0..p.num_buckets()).map(|b| p.scale(b)).collect()
        });
        let mut losses = Vec::with_capacity(self.cfg.epochs);
        let mut evals = Vec::with_capacity(self.cfg.epochs);
        let mut stages = Vec::with_capacity(self.cfg.epochs);
        let mut wall = 0.0f64;
        let mut wait = 0.0f64;
        let mut start_epoch = 0usize;
        // Mid-epoch resume position: batches already consumed plus the
        // partial loss accumulator, applied to `start_epoch` only.
        let mut resume_skip: Option<(usize, f32, usize)> = None;
        if let Some(path) = self.cfg.ckpt.resume.clone() {
            let ck = Checkpoint::load(&path)?;
            ck.validate_resume("train", &fingerprint)?;
            if let (Some(stored), Some(current)) = (&ck.policy_scales, &policy_scales) {
                if stored != current {
                    anyhow::bail!(
                        "--resume checkpoint {path}: stored policy scales differ from this \
                         run's materialized policy — the dataset features or the \
                         degree-buckets/bucket-bits config changed since the checkpoint"
                    );
                }
            }
            self.model.set_params_flat(&ck.params);
            self.model.set_step_count(ck.step_count);
            self.opt.import_velocity(ck.velocity.clone());
            losses = ck.losses.iter().map(|&l| l as f32).collect();
            evals = ck.evals.iter().map(|&e| e as f32).collect();
            // Completed epochs carry no timings in a resumed report.
            stages.resize(ck.cursor.epoch, EpochStages::default());
            start_epoch = ck.cursor.epoch;
            if ck.cursor.step > 0 || ck.cursor.loss_steps > 0 {
                // `loss_sum` was widened f32→f64 exactly at save time, so
                // narrowing it back is bit-exact.
                resume_skip =
                    Some((ck.cursor.step, ck.cursor.loss_sum as f32, ck.cursor.loss_steps));
            }
            crate::obs::counter_add(crate::obs::keys::CTR_CKPT_RESUMES, 1);
        }
        let injector = FaultInjector::new(&self.cfg.fault).map(Mutex::new);
        for epoch in start_epoch..self.cfg.epochs {
            let _epoch_span = crate::obs::span(crate::obs::keys::SPAN_EPOCH);
            let t_epoch = std::time::Instant::now();
            let (start, loss_acc) = match resume_skip.take() {
                Some((step, sum, n)) => (step, (sum, n)),
                None => (0, (0.0f32, 0usize)),
            };
            let ckpt_ctx = (self.cfg.ckpt.every > 0).then(|| CkptCtx {
                every: self.cfg.ckpt.every,
                path: self.cfg.ckpt.path.clone(),
                fingerprint: fingerprint.clone(),
                policy_scales: policy_scales.clone(),
                losses: losses.iter().map(|&l| l as f64).collect(),
                evals: evals.iter().map(|&e| e as f64).collect(),
            });
            let (res, secs) = crate::metrics::time_once(|| {
                self.train_epoch(epoch as u64, start, loss_acc, injector.as_ref(), ckpt_ctx.as_ref())
            });
            let (loss, mut stage) = res?;
            let (eval, eval_s) = crate::metrics::time_once(|| {
                let _s = crate::obs::span(crate::obs::keys::SPAN_EVAL);
                self.evaluate()
            });
            stage.eval_s = eval_s;
            stage.wall_s = t_epoch.elapsed().as_secs_f64();
            wall += stage.wall_s;
            wait += stage.wait_s;
            if self.cfg.log_every > 0 && epoch % self.cfg.log_every == 0 {
                println!(
                    "epoch {epoch:>4}  loss {loss:>8.4}  eval {eval:>6.4}  ({:.1} ms)",
                    secs * 1e3
                );
            }
            losses.push(loss);
            evals.push(eval);
            stages.push(stage);
        }
        // Run-complete checkpoint: the crash-resume CI job byte-compares it
        // against the control's.
        if self.cfg.ckpt.every > 0 {
            let ck = Checkpoint {
                command: "train".to_string(),
                fingerprint,
                cursor: Cursor {
                    epoch: self.cfg.epochs,
                    step: 0,
                    loss_sum: 0.0,
                    loss_steps: 0,
                },
                step_count: self.model.step_count(),
                params: self.model.params_flat(),
                velocity: self.opt.export_velocity(),
                policy_scales,
                losses: losses.iter().map(|&l| l as f64).collect(),
                evals: evals.iter().map(|&e| e as f64).collect(),
            };
            ck.save(&self.cfg.ckpt.path)?;
        }
        let final_eval = *evals.last().unwrap_or(&0.0);
        let final_loss = *losses.last().unwrap_or(&f32::INFINITY);
        let epochs_to_converge = losses
            .iter()
            .position(|&l| l <= final_loss * 1.02)
            .unwrap_or(losses.len());
        Ok(TrainReport {
            losses,
            evals,
            final_eval,
            wall_secs: wall,
            bits: self.cfg.mode.bits,
            epochs_to_converge,
            cache: self.gather_stats(),
            cache_bytes: self.gather_cached_bytes(),
            policy: self.policy_report(),
            prefetch_wait_s: wait,
            stages,
            fault: injector
                .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()).report),
        })
    }

    /// One epoch through the prefetch pipeline: stage one (sampling +
    /// gather — the [`SampleStage`] definition shared with the multi-GPU
    /// workers) produces batches `prefetch` ahead on a producer thread
    /// while this thread steps the model; `prefetch = 0` runs the same
    /// loop strictly sequentially. Returns the mean batch loss and the
    /// epoch's stage accounting (eval/wall filled in by the caller).
    ///
    /// A resumed epoch starts at batch `start` with `loss_acc` already
    /// folded in — batch RNG streams are keyed by absolute position, so
    /// the continuation is bit-identical to the uninterrupted sweep. With
    /// an `injector`, scheduled producer panics fire (and recover) here.
    fn train_epoch(
        &mut self,
        epoch: u64,
        start: usize,
        loss_acc: (f32, usize),
        injector: Option<&Mutex<FaultInjector>>,
        ckpt: Option<&CkptCtx>,
    ) -> crate::Result<(f32, EpochStages)> {
        let shuffle_seed = mix_seeds(&[self.cfg.seed, epoch]);
        let batches = match self.task {
            Task::NodeClassification => shuffled_batches(
                &self.data.train_nodes,
                self.cfg.sampler.batch_size,
                shuffle_seed,
            ),
            Task::LinkPrediction => shuffled_batches(
                &self.edges.as_ref().expect("LP task has an EdgeBatcher").edge_ids(),
                self.cfg.sampler.batch_size,
                shuffle_seed,
            ),
        };
        let num_batches = batches.len();
        let start = start.min(num_batches);
        let neg_per_pos = self.head.neg_per_pos();
        // Run-local stage-one accounting: must outlive `stage` below, which
        // the producer thread borrows.
        let times = StageTimes::default();
        // Field-level borrow split: stage one owns the sampler + store side
        // of `self` (moved to the producer thread), the consumer keeps the
        // model + optimizer side.
        let Self { model, opt, store, sampler, csr_in, degrees, data, edges, cfg, .. } = self;
        let mut stage = SampleStage {
            sampler,
            csr_in,
            degrees: degrees.as_slice(),
            labels: &data.labels,
            lp: edges.as_ref().map(|b| (b, neg_per_pos)),
            gather: FeatureGather::new(&data.features, store.as_mut()),
            packed: cfg.packed_compute,
            times: &times,
        };
        let (mut total, mut steps) = loss_acc;
        let mut compute_s = 0.0f64;
        // Checkpoint I/O failures inside the consume closure (which returns
        // `()`) surface here after the sweep.
        let mut ckpt_err: Option<anyhow::Error> = None;
        // Producer faults key on the batch's *global* step — the position
        // its training step holds in the whole run — so schedules fire
        // identically across control, faulted and resumed runs regardless
        // of how far ahead the producer is.
        let produce = |bi: usize| {
            let abs = start + bi;
            if let Some(inj) = injector {
                let fire = inj
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .fire(FaultClass::Producer, epoch * num_batches as u64 + abs as u64);
                if fire {
                    injected_panic(&format!("producer died preparing batch {abs} of epoch {epoch}"));
                }
            }
            stage.prepare(&batches[abs], mix_seeds(&[epoch, abs as u64]))
        };
        let consume = |i: usize, pb: PreparedBatch| {
            let t0 = std::time::Instant::now();
            let _step_span = crate::obs::span(crate::obs::keys::SPAN_COMPUTE);
            let loss = match &pb.target {
                BatchTarget::Nc { labels } => {
                    let nodes: Vec<u32> = (0..labels.len() as u32).collect();
                    model
                        .train_step_input(&pb.blocks, &pb.x0, opt, &mut |lg| {
                            softmax_cross_entropy(lg, labels, &nodes)
                        })
                        .0
                }
                BatchTarget::Lp { pairs } => {
                    model
                        .train_step_input(&pb.blocks, &pb.x0, opt, &mut |emb| {
                            TaskHead::lp_loss_grad(emb, pairs)
                        })
                        .0
                }
            };
            total += loss;
            steps += 1;
            compute_s += t0.elapsed().as_secs_f64();
            if let Some(ctx) = ckpt {
                if ctx.every > 0 && model.step_count() % ctx.every as u64 == 0 && ckpt_err.is_none()
                {
                    let ck = Checkpoint {
                        command: "train".to_string(),
                        fingerprint: ctx.fingerprint.clone(),
                        cursor: Cursor {
                            epoch: epoch as usize,
                            step: start + i + 1,
                            loss_sum: total as f64,
                            loss_steps: steps,
                        },
                        step_count: model.step_count(),
                        params: model.params_flat(),
                        velocity: opt.export_velocity(),
                        policy_scales: ctx.policy_scales.clone(),
                        losses: ctx.losses.clone(),
                        evals: ctx.evals.clone(),
                    };
                    if let Err(e) = ck.save(&ctx.path) {
                        ckpt_err = Some(e);
                    }
                }
            }
        };
        let stats = match injector {
            Some(inj) => {
                // Restart budget is per batch position: a fresh panic at a
                // later batch resets the count, repeated occurrences at one
                // step exhaust it.
                let mut retries_at: (usize, usize) = (usize::MAX, 0);
                run_prefetched_restartable(
                    num_batches - start,
                    cfg.sampler.prefetch,
                    produce,
                    consume,
                    |next, e| {
                        let msg = format!("{e:#}");
                        if !msg.contains("injected fault") {
                            // A real producer bug must never be masked by
                            // the injection harness's retry loop.
                            return Err(e);
                        }
                        let mut g = inj.lock().unwrap_or_else(|p| p.into_inner());
                        let attempt = if retries_at.0 == next { retries_at.1 + 1 } else { 1 };
                        retries_at = (next, attempt);
                        if attempt > g.max_retries {
                            return Err(anyhow::anyhow!(
                                "prefetch producer died at batch {} of epoch {epoch} and the \
                                 retry budget ({}) is exhausted: {msg}",
                                start + next,
                                g.max_retries
                            ));
                        }
                        g.charge_backoff(attempt);
                        g.report.producer_restarts += 1;
                        crate::obs::counter_add(crate::obs::keys::CTR_FAULT_PRODUCER_RESTARTS, 1);
                        crate::obs::instant(crate::obs::keys::EVT_RECOVERY_PRODUCER_RESTART);
                        if crate::obs::flight_dump(crate::obs::keys::EVT_RECOVERY_PRODUCER_RESTART)
                        {
                            g.report.flight_dumps += 1;
                            crate::obs::counter_add(crate::obs::keys::CTR_FAULT_FLIGHT_DUMPS, 1);
                        }
                        Ok(())
                    },
                )?
            }
            None => run_prefetched(num_batches - start, cfg.sampler.prefetch, produce, consume)?,
        };
        if let Some(e) = ckpt_err {
            return Err(e);
        }
        let loss = if steps == 0 { 0.0 } else { total / steps as f32 };
        let stage = EpochStages {
            sample_s: times.sample_s(),
            gather_s: times.gather_s(),
            wait_s: stats.wait_s,
            compute_s,
            ..EpochStages::default()
        };
        Ok((loss, stage))
    }

    /// Full-graph evaluation on the held-out split (the model is bound to
    /// the whole graph; only *training* runs on sampled blocks).
    pub fn evaluate(&self) -> f32 {
        let out = self.model.forward(&self.data.features);
        self.head.evaluate(&out, &self.data, self.cfg.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{parse_mode, ModelKind, SamplerConfig};

    fn mb_cfg(model: ModelKind, mode: &str, epochs: usize) -> TrainConfig {
        TrainConfig {
            model,
            dataset: "tiny".into(),
            epochs,
            lr: 0.1,
            hidden: 16,
            heads: 4,
            layers: 2,
            mode: parse_mode(mode, 8).unwrap(),
            auto_bits: false,
            seed: 3,
            log_every: 0,
            sampler: SamplerConfig {
                enabled: true,
                fanouts: vec![10, 10],
                batch_size: 64,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn gcn_minibatch_learns_tiny() {
        let mut t = MiniBatchTrainer::from_config(&mb_cfg(ModelKind::Gcn, "tango", 30)).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 30);
        assert!(r.losses[29] < r.losses[0], "{:?}", r.losses);
        assert!(r.final_eval > 0.3, "eval {}", r.final_eval);
        // Quantized gather must have seen real cache traffic — and the
        // report must surface it.
        let stats = t.gather_stats().expect("quantized mode has a store");
        assert!(stats.hits > 0, "hot nodes should hit the feature cache");
        assert!(t.gather_cached_bytes() > 0);
        assert_eq!(r.cache, Some(stats));
        assert_eq!(r.cache_bytes, t.gather_cached_bytes());
    }

    #[test]
    fn packed_compute_minibatch_learns_tiny() {
        // End-to-end packed pipeline: gather stays bit-packed into the
        // model (GCN consumes it in layer 0; GAT dequantizes lazily).
        for model in [ModelKind::Gcn, ModelKind::Gat] {
            let mut cfg = mb_cfg(model, "tango", 15);
            cfg.packed_compute = true;
            let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
            let r = t.run().unwrap();
            assert!(r.losses.iter().all(|l| l.is_finite()), "{:?}", r.losses);
            assert!(r.losses.last().unwrap() < &r.losses[0], "{model:?}: {:?}", r.losses);
            assert!(r.final_eval > 0.3, "{model:?} eval {}", r.final_eval);
        }
    }

    #[test]
    fn gat_minibatch_learns_tiny() {
        let mut t = MiniBatchTrainer::from_config(&mb_cfg(ModelKind::Gat, "tango", 25)).unwrap();
        let r = t.run().unwrap();
        assert!(r.losses.last().unwrap() < &r.losses[0], "{:?}", r.losses);
        assert!(r.final_eval > 0.3, "eval {}", r.final_eval);
    }

    #[test]
    fn bounded_feature_cache_evicts_and_stays_bounded() {
        let mut cfg = mb_cfg(ModelKind::Gcn, "tango", 6);
        cfg.sampler.cache_nodes = 32;
        let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        let stats = t.gather_stats().expect("quantized mode has a store");
        assert!(stats.evictions > 0, "tiny's 160 train nodes must overflow 32 slots");
        // tiny's feat_dim is 16 → at most 32 rows of 16 bytes live at once.
        assert!(t.gather_cached_bytes() <= 32 * 16, "{}", t.gather_cached_bytes());
        assert!(r.cache.unwrap().evictions > 0, "report surfaces evictions");
    }

    #[test]
    fn fp32_mode_has_no_store_and_still_learns() {
        let mut t = MiniBatchTrainer::from_config(&mb_cfg(ModelKind::Gcn, "fp32", 20)).unwrap();
        assert!(t.gather_stats().is_none());
        let r = t.run().unwrap();
        assert!(r.losses.last().unwrap() < &r.losses[0]);
        assert!(r.cache.is_none());
    }

    #[test]
    fn fanouts_adjust_to_layer_count() {
        let mut cfg = mb_cfg(ModelKind::Gcn, "fp32", 1);
        cfg.sampler.fanouts = vec![7];
        cfg.layers = 3;
        let t = MiniBatchTrainer::from_config(&cfg).unwrap();
        assert_eq!(t.fanouts(), &[7, 7, 7]);
        let mut cfg = mb_cfg(ModelKind::Gcn, "fp32", 1);
        cfg.sampler.fanouts = vec![9, 5, 3];
        cfg.layers = 2;
        let t = MiniBatchTrainer::from_config(&cfg).unwrap();
        assert_eq!(t.fanouts(), &[9, 5]);
    }

    #[test]
    fn linkpred_dataset_trains_on_edge_seeded_blocks() {
        // The LP dataset's declared task routes through the edge-seeded
        // path: finite losses, AUC in range, and a real downward trend on
        // the topology-only objective.
        let mut cfg = mb_cfg(ModelKind::Gcn, "tango", 6);
        cfg.dataset = "DBLP".into();
        cfg.hidden = 8;
        cfg.sampler.batch_size = 512;
        cfg.sampler.fanouts = vec![5, 5];
        let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
        assert_eq!(t.task(), Task::LinkPrediction);
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 6);
        assert!(r.losses.iter().all(|l| l.is_finite()), "{:?}", r.losses);
        assert!(r.losses.last().unwrap() < &r.losses[0], "{:?}", r.losses);
        assert!(r.final_eval > 0.0 && r.final_eval <= 1.0, "AUC {}", r.final_eval);
    }

    #[test]
    fn task_override_runs_linkpred_on_nc_graph() {
        let mut cfg = mb_cfg(ModelKind::Gcn, "fp32", 5);
        cfg.task = Some(TaskKind::LinkPrediction);
        let mut t = MiniBatchTrainer::from_config(&cfg).unwrap();
        assert_eq!(t.task(), Task::LinkPrediction);
        let r = t.run().unwrap();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(r.final_eval > 0.0 && r.final_eval <= 1.0);
    }

    #[test]
    fn runs_are_deterministic_under_fixed_seed() {
        let run = || {
            let mut t =
                MiniBatchTrainer::from_config(&mb_cfg(ModelKind::Gcn, "fp32", 5)).unwrap();
            t.run().unwrap().losses
        };
        assert_eq!(run(), run());
        // LP path too (negative draws and exclusion are seeded).
        let run_lp = || {
            let mut cfg = mb_cfg(ModelKind::Gcn, "fp32", 3);
            cfg.task = Some(TaskKind::LinkPrediction);
            MiniBatchTrainer::from_config(&cfg).unwrap().run().unwrap().losses
        };
        assert_eq!(run_lp(), run_lp());
    }
}
