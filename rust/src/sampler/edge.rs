//! Edge-seeded mini-batches for sampled link-prediction training.
//!
//! Node-classification batches seed the layered sampler with *nodes*; link
//! prediction seeds it with *edges*: a batch of positive edges is drawn,
//! one uniform negative pair is sampled per positive (seeded, so every
//! worker replays the same candidates), and the fanout sampler is seeded
//! from the union of all candidate endpoints. The batch's positive edges
//! are excluded from the sampled message edges in **both** directions
//! (the datasets add reverse edges) via
//! [`NeighborSampler::sample_blocks_excluding`] — otherwise the model
//! could read each training edge's existence straight off its own message,
//! the classic LP leakage bug.
//!
//! The final block's destination rows are exactly [`EdgeBatch::seeds`], and
//! [`EdgeBatch::pairs`] index into those rows — the layout
//! [`TaskHead::lp_loss_grad`](crate::model::TaskHead::lp_loss_grad)
//! consumes.

use super::{Block, NeighborSampler};
use crate::graph::{Coo, Csr};
use crate::quant::rng::{mix_seeds, Xoshiro256pp};
use std::collections::{HashMap, HashSet};

/// The canonical positive-edge set of a graph, batched for LP training.
///
/// Canonicalisation keeps one `(u, v)` with `u < v` per undirected pair —
/// reverse duplicates collapse and self-loops (degenerate positives) drop —
/// preserving first-occurrence order so edge ids are stable and shardable.
#[derive(Debug, Clone)]
pub struct EdgeBatcher {
    /// Canonical positive edges, indexed by edge id.
    edges: Vec<(u32, u32)>,
    /// Parent-graph node count (bounds negative sampling).
    num_nodes: usize,
}

/// One assembled LP mini-batch.
#[derive(Debug, Clone)]
pub struct EdgeBatch {
    /// Distinct candidate endpoints in first-seen order — the seed list for
    /// the layered sampler; the final block's destinations equal this.
    pub seeds: Vec<u32>,
    /// Candidate pairs `(u, v, target)` with `u`/`v` **local** indices into
    /// [`EdgeBatch::seeds`]: positives (target 1.0) first, then the seeded
    /// uniform negatives (target 0.0).
    pub pairs: Vec<(u32, u32, f32)>,
    /// Global `(src, dst)` pairs of the batch's positive edges, both
    /// directions — pass to
    /// [`sample_blocks_excluding`](super::NeighborSampler::sample_blocks_excluding).
    pub exclude: HashSet<(u32, u32)>,
}

impl EdgeBatcher {
    /// Collect the canonical positive edges of a graph.
    pub fn new(graph: &Coo) -> Self {
        let mut seen = HashSet::with_capacity(graph.num_edges());
        let mut edges = Vec::new();
        for e in 0..graph.num_edges() {
            let (u, v) = (graph.src[e], graph.dst[e]);
            if u == v {
                continue; // self-loops are structural, not positives
            }
            let key = if u < v { (u, v) } else { (v, u) };
            if seen.insert(key) {
                edges.push(key);
            }
        }
        EdgeBatcher { edges, num_nodes: graph.num_nodes }
    }

    /// Number of canonical positive edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// All edge ids in canonical order — feed to
    /// [`shuffled_batches`](super::shuffled_batches) for the epoch sweep,
    /// or to a partitioner for multi-worker shards.
    pub fn edge_ids(&self) -> Vec<u32> {
        (0..self.edges.len() as u32).collect()
    }

    /// The canonical edge behind an id.
    pub fn edge(&self, id: u32) -> (u32, u32) {
        self.edges[id as usize]
    }

    /// Assemble one mini-batch from positive-edge ids: compacts endpoints
    /// into a seed list, draws `neg_per_pos` uniform negative pairs per
    /// positive from a `seed`ed stream, and builds the leakage-exclusion
    /// set (both directions of every positive).
    pub fn batch(&self, ids: &[u32], neg_per_pos: usize, seed: u64) -> EdgeBatch {
        let mut rng = Xoshiro256pp::new(seed);
        let mut seeds: Vec<u32> = Vec::with_capacity(2 * ids.len());
        let mut local_of: HashMap<u32, u32> = HashMap::with_capacity(2 * ids.len());
        let mut intern = |v: u32, seeds: &mut Vec<u32>, local_of: &mut HashMap<u32, u32>| -> u32 {
            *local_of.entry(v).or_insert_with(|| {
                seeds.push(v);
                (seeds.len() - 1) as u32
            })
        };
        let mut pairs: Vec<(u32, u32, f32)> = Vec::with_capacity(ids.len() * (1 + neg_per_pos));
        let mut exclude: HashSet<(u32, u32)> = HashSet::with_capacity(2 * ids.len());
        for &id in ids {
            let (u, v) = self.edges[id as usize];
            let lu = intern(u, &mut seeds, &mut local_of);
            let lv = intern(v, &mut seeds, &mut local_of);
            pairs.push((lu, lv, 1.0));
            exclude.insert((u, v));
            exclude.insert((v, u));
        }
        let n = self.num_nodes as u64;
        for _ in 0..ids.len() {
            for _ in 0..neg_per_pos {
                let a = (rng.next_u64() % n) as u32;
                let b = (rng.next_u64() % n) as u32;
                let la = intern(a, &mut seeds, &mut local_of);
                let lb = intern(b, &mut seeds, &mut local_of);
                pairs.push((la, lb, 0.0));
            }
        }
        EdgeBatch { seeds, pairs, exclude }
    }
}

/// Assemble one sampled link-prediction step: batch the positive-edge ids
/// (seeded uniform negatives included), then sample the edge-seeded blocks
/// with the leakage-exclusion set applied. Returns the blocks plus the
/// local-id candidate pairs for
/// [`TaskHead::lp_loss_grad`](crate::model::TaskHead::lp_loss_grad).
///
/// This is **the** LP step assembly: `MiniBatchTrainer` and the multi-GPU
/// workers both call it, so the negative-draw seeding
/// (`mix_seeds([sampler.seed, stream])`) and the exclusion behaviour cannot
/// drift between the engines — the 1-worker step-for-step replay guarantee
/// (`tests/multigpu_equivalence.rs`) rides on this single definition.
pub fn sample_lp_step(
    batcher: &EdgeBatcher,
    sampler: &NeighborSampler,
    csr_in: &Csr,
    degrees: &[u32],
    batch: &[u32],
    stream: u64,
    neg_per_pos: usize,
) -> (Vec<Block>, Vec<(u32, u32, f32)>) {
    let eb = batcher.batch(batch, neg_per_pos, mix_seeds(&[sampler.seed, stream]));
    let blocks = sampler.sample_blocks_excluding(csr_in, degrees, &eb.seeds, stream, &eb.exclude);
    (blocks, eb.pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    fn batcher() -> (datasets::Dataset, EdgeBatcher) {
        let d = datasets::tiny(5);
        let b = EdgeBatcher::new(&d.graph);
        (d, b)
    }

    #[test]
    fn canonical_edges_are_unique_ordered_and_loop_free() {
        let (d, b) = batcher();
        assert!(b.num_edges() > 0);
        let mut seen = HashSet::new();
        for id in b.edge_ids() {
            let (u, v) = b.edge(id);
            assert!(u < v, "({u},{v}) must be canonical");
            assert!(seen.insert((u, v)), "duplicate canonical edge");
        }
        // Every canonical edge is a real parent edge (in some direction).
        let parent: HashSet<(u32, u32)> =
            (0..d.graph.num_edges()).map(|e| (d.graph.src[e], d.graph.dst[e])).collect();
        for &(u, v) in &b.edges {
            assert!(parent.contains(&(u, v)) || parent.contains(&(v, u)));
        }
    }

    #[test]
    fn batch_compacts_endpoints_and_builds_exclusions() {
        let (_, b) = batcher();
        let ids: Vec<u32> = b.edge_ids().into_iter().take(8).collect();
        let eb = b.batch(&ids, 1, 99);
        // Positives first, then one negative per positive.
        assert_eq!(eb.pairs.len(), 16);
        assert!(eb.pairs[..8].iter().all(|p| p.2 == 1.0));
        assert!(eb.pairs[8..].iter().all(|p| p.2 == 0.0));
        // Seeds distinct; pair ids in range and mapping back to the edges.
        let distinct: HashSet<u32> = eb.seeds.iter().copied().collect();
        assert_eq!(distinct.len(), eb.seeds.len());
        for (k, &id) in ids.iter().enumerate() {
            let (u, v) = b.edge(id);
            let (lu, lv, _) = eb.pairs[k];
            assert_eq!(eb.seeds[lu as usize], u);
            assert_eq!(eb.seeds[lv as usize], v);
            assert!(eb.exclude.contains(&(u, v)) && eb.exclude.contains(&(v, u)));
        }
        assert_eq!(eb.exclude.len(), 2 * ids.len());
        for &(lu, lv, _) in &eb.pairs {
            assert!((lu as usize) < eb.seeds.len() && (lv as usize) < eb.seeds.len());
        }
    }

    #[test]
    fn batches_are_seeded_deterministic() {
        let (_, b) = batcher();
        let ids: Vec<u32> = b.edge_ids().into_iter().take(5).collect();
        let x = b.batch(&ids, 2, 7);
        let y = b.batch(&ids, 2, 7);
        assert_eq!(x.seeds, y.seeds);
        assert_eq!(x.pairs, y.pairs);
        // A different seed redraws the negatives.
        let z = b.batch(&ids, 2, 8);
        assert_ne!(x.pairs, z.pairs);
    }
}
