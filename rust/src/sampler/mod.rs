//! Mini-batch neighbor-sampling training subsystem.
//!
//! Tango's host framework (DGL) trains large graphs almost exclusively in
//! *sampled mini-batch* mode; this module adds that execution mode to the
//! reproduction, with the quantization lessons of the related work folded
//! in (BiFeat: the quantized feature gather dominates sampled step time;
//! see PAPERS.md):
//!
//! - [`NeighborSampler`] — layered uniform neighbor sampling with per-layer
//!   fanouts over the in-edge CSR (DGL `MultiLayerNeighborSampler` shape),
//!   plus [`shuffled_batches`] for the seeded epoch sweep;
//! - [`Block`] — MFG-style bipartite blocks with compacted node ids,
//!   destination-prefix invariant, per-layer COO/CSR/reversed-CSR layouts
//!   and parent-degree GCN edge norms (built on
//!   [`Csr::from_grouped_edges`](crate::graph::Csr::from_grouped_edges));
//! - [`QuantFeatureStore`] / [`gather_rows`] — the per-batch feature
//!   gather; the quantized path slices INT8 rows under one shared scale and
//!   caches hot (frequently re-sampled) nodes in a
//!   [`QuantCache`](crate::coordinator::QuantCache);
//! - [`MiniBatchTrainer`] — the epoch engine gluing it all to the
//!   block-aware GCN/GAT forward/backward
//!   ([`GcnModel::train_step_blocks`](crate::model::GcnModel::train_step_blocks),
//!   [`GatModel::train_step_blocks`](crate::model::GatModel::train_step_blocks));
//!   `coordinator::Trainer` delegates here when
//!   `TrainConfig::sampler.enabled` is set, so
//!   `tango train --sampler neighbor --fanouts 10,10 --batch-size 512`
//!   runs end to end.

mod block;
mod gather;
mod minibatch;
mod neighbor;

pub use block::Block;
pub use gather::{gather_rows, QuantFeatureStore};
pub use minibatch::MiniBatchTrainer;
pub use neighbor::{adjust_fanouts, shuffled_batches, NeighborSampler};
