//! Mini-batch neighbor-sampling training subsystem.
//!
//! Tango's host framework (DGL) trains large graphs almost exclusively in
//! *sampled mini-batch* mode; this module adds that execution mode to the
//! reproduction, with the quantization lessons of the related work folded
//! in (BiFeat: the quantized feature gather dominates sampled step time;
//! see PAPERS.md):
//!
//! - [`NeighborSampler`] — layered neighbor sampling with per-layer
//!   fanouts over the in-edge CSR (DGL `MultiLayerNeighborSampler` shape),
//!   uniform or degree-biased ([`SamplerBias`], `--sampler degree` — draws
//!   weighted by global in-degree, the Degree-Quant importance rule), plus
//!   [`shuffled_batches`] for the seeded epoch sweep and
//!   [`NeighborSampler::sample_blocks_excluding`] for edge-exclusion
//!   (the LP leakage guard);
//! - [`Block`] — MFG-style bipartite blocks with compacted node ids,
//!   destination-prefix invariant, per-layer COO/CSR/reversed-CSR layouts
//!   and parent-degree GCN edge norms (built on
//!   [`Csr::from_grouped_edges`](crate::graph::Csr::from_grouped_edges));
//!   [`Block::identity`] wraps the whole graph as one block — the
//!   full-graph training path is the block path run over identity blocks;
//! - [`EdgeBatcher`] — edge-seeded batches for sampled link prediction:
//!   canonical positive edges, seeded uniform negatives, endpoint seed
//!   lists and the per-batch exclusion set;
//! - [`QuantFeatureStore`] / [`gather_rows`] — the per-batch feature
//!   gather (data-parallel row copies and miss quantization); the quantized
//!   path slices rows at each node's degree-bucket `(scale, bits)` (see
//!   [`crate::policy`] — the uniform policy is the original single shared
//!   scale) into a [`QuantRows`] batch and caches hot (frequently
//!   re-sampled) nodes in a [`QuantCache`](crate::coordinator::QuantCache);
//! - [`run_prefetched`] / [`SampleStage`] — the pipelined batch-prefetch
//!   engine (the paper's §4.2 overlap made real): a producer thread runs
//!   stage one (sampling + quantized gather) for batches `t+1..t+depth`
//!   over a bounded channel while the training thread consumes batch `t`;
//!   per-batch RNG streams make prefetched runs bit-identical to
//!   sequential ones (`prefetch = 0`);
//! - [`MiniBatchTrainer`] — the epoch engine gluing it all to the unified
//!   [`GnnModel`](crate::model::GnnModel) block path for **both** tasks
//!   (node classification and link prediction, see
//!   [`TaskHead`](crate::model::TaskHead)); `coordinator::Trainer`
//!   delegates here when `TrainConfig::sampler.enabled` is set, so
//!   `tango train --sampler neighbor --fanouts 10,10 --batch-size 512`
//!   and `tango train --sampler neighbor --task linkpred` run end to end.

mod block;
mod edge;
mod gather;
mod minibatch;
mod neighbor;
mod pipeline;

pub use block::Block;
pub use edge::{sample_lp_step, EdgeBatch, EdgeBatcher};
pub use gather::{gather_rows, QuantFeatureStore, QuantRows};
pub use minibatch::MiniBatchTrainer;
pub use neighbor::{adjust_fanouts, shuffled_batches, NeighborSampler, SamplerBias};
pub use pipeline::{
    run_prefetched, run_prefetched_restartable, spawn_producer, spawn_producer_range, BatchInput,
    BatchTarget, FeatureGather, PrefetchStats, PreparedBatch, ProducerHandle, SampleStage,
    StageTimes,
};
