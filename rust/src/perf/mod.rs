//! `tango perf` — artifact-vs-artifact performance regression diffing.
//!
//! `tango perf diff A.json B.json` flattens two run artifacts into
//! comparable `key → value` maps, compares them key-by-key in
//! deterministic (BTreeMap) order, prints a delta table and exits non-zero
//! when a *gated* key moved more than the threshold — the blocking CI
//! `perf-gate` that turns `BENCH_*.json` / `--metrics-out` emissions into
//! a regression trajectory instead of a snapshot.
//!
//! Two artifact families are understood:
//!
//! - **`tango-metrics/*`** (`--metrics-out`): every span path becomes
//!   `spans.<path>.calls` (gated) and `spans.<path>.total_s` (timing),
//!   every counter becomes `counters.<name>` (gated).
//! - **`tango-bench/*`** (`benches/*.rs` emitters): top-level numeric
//!   scalars (`epochs_per_run`, `nodes`, `iters`, …) are gated — they
//!   changing means the bench *configuration* drifted — and each
//!   `results[]` row is keyed by its string-valued fields
//!   (`results[dataset=Pubmed,model=gcn].tango_speedup`).
//!
//! **Gating is count-shaped, not time-shaped.** Keys whose last segment
//! looks like a duration or a speed ratio (`*_s`, `*_s_per_*`,
//! `*speedup*`, `*secs*`, `*wall*`) are reported in the table but never
//! fail the gate: CI machines jitter, while batch counts, gather rows,
//! wire bytes and span call counts are deterministic for a fixed
//! config/seed — those regress loudly. A gated key *missing* from the new
//! artifact is always a regression (structural: an instrumented path
//! disappeared); a key only the new artifact has is informational.
//!
//! Same inputs produce a byte-identical report (`--json`): ordering is
//! BTreeMap-sorted, formatting is fixed, and nothing reads a clock.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Schema tag of the `--json` report this module writes.
pub const SCHEMA: &str = "tango-perf/v1";

/// One compared key in a [`DiffReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    /// Flattened key (`spans.epoch.calls`, `counters.gather.rows`, …).
    pub key: String,
    /// Baseline value (`None` = key absent from the baseline).
    pub base: Option<f64>,
    /// New value (`None` = key absent from the new artifact).
    pub new: Option<f64>,
    /// Percent change vs baseline; `None` when undefined (a side missing,
    /// or baseline zero with a nonzero new value).
    pub delta_pct: Option<f64>,
    /// Whether this key can fail the gate (false = timing, advisory only).
    pub gated: bool,
    /// Whether this key failed the gate.
    pub regressed: bool,
}

/// The full deterministic comparison of two artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffReport {
    /// `schema` field of the baseline artifact.
    pub base_schema: String,
    /// `schema` field of the new artifact.
    pub new_schema: String,
    /// Gate threshold, percent.
    pub threshold_pct: f64,
    /// Every compared key, sorted.
    pub rows: Vec<DiffRow>,
    /// Count of rows with `regressed == true`.
    pub regressions: usize,
}

/// Timing-shaped keys are reported but never gate (wall-clock jitter);
/// classification looks at the last `.`-segment of the flattened key.
fn is_timing(key: &str) -> bool {
    let last = key.rsplit('.').next().unwrap_or(key);
    last.ends_with("_s")
        || last.contains("_s_per_")
        || last.contains("speedup")
        || last.contains("secs")
        || last.contains("wall")
}

/// Flatten one artifact into comparable `key → value` pairs.
///
/// Errors on documents without a recognized `schema` tag — diffing two
/// arbitrary JSON files would produce a silently empty (always-green)
/// comparison.
pub fn comparable_metrics(doc: &Json) -> crate::Result<BTreeMap<String, f64>> {
    let schema = doc
        .get("schema")
        .and_then(|s| s.as_str())
        .ok_or_else(|| anyhow::anyhow!("artifact has no \"schema\" field"))?;
    let mut out = BTreeMap::new();
    if schema.starts_with("tango-metrics/") {
        if let Some(Json::Obj(spans)) = doc.get("spans") {
            for (path, st) in spans {
                if let Some(calls) = st.get("calls").and_then(|v| v.as_f64()) {
                    out.insert(format!("spans.{path}.calls"), calls);
                }
                if let Some(total) = st.get("total_s").and_then(|v| v.as_f64()) {
                    out.insert(format!("spans.{path}.total_s"), total);
                }
            }
        }
        if let Some(Json::Obj(counters)) = doc.get("counters") {
            for (name, v) in counters {
                if let Some(v) = v.as_f64() {
                    out.insert(format!("counters.{name}"), v);
                }
            }
        }
    } else if schema.starts_with("tango-bench/") {
        if let Json::Obj(top) = doc {
            for (k, v) in top {
                if let Some(v) = v.as_f64() {
                    out.insert(k.clone(), v);
                }
            }
        }
        let rows = doc.get("results").and_then(|r| r.as_arr()).unwrap_or(&[]);
        for (i, row) in rows.iter().enumerate() {
            let Json::Obj(fields) = row else { continue };
            let mut label: Vec<String> = fields
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| format!("{k}={s}")))
                .collect();
            if label.is_empty() {
                label.push(format!("row{i}"));
            }
            let label = label.join(",");
            for (k, v) in fields {
                if let Some(v) = v.as_f64() {
                    out.insert(format!("results[{label}].{k}"), v);
                }
            }
        }
    } else {
        anyhow::bail!(
            "unsupported artifact schema {schema:?} (want tango-metrics/* or tango-bench/*)"
        );
    }
    Ok(out)
}

/// Compare two parsed artifacts at `threshold_pct`.
pub fn diff(base: &Json, new: &Json, threshold_pct: f64) -> crate::Result<DiffReport> {
    let base_schema =
        base.get("schema").and_then(|s| s.as_str()).unwrap_or_default().to_string();
    let new_schema = new.get("schema").and_then(|s| s.as_str()).unwrap_or_default().to_string();
    if base_schema != new_schema {
        anyhow::bail!("schema mismatch: baseline {base_schema:?} vs new {new_schema:?}");
    }
    let a = comparable_metrics(base)?;
    let b = comparable_metrics(new)?;
    let mut keys: Vec<&String> = a.keys().chain(b.keys()).collect();
    keys.sort();
    keys.dedup();
    let mut rows = Vec::with_capacity(keys.len());
    for key in keys {
        let gated = !is_timing(key);
        let (av, bv) = (a.get(key).copied(), b.get(key).copied());
        let (delta_pct, regressed) = match (av, bv) {
            (Some(av), Some(bv)) => {
                if av == 0.0 {
                    // No baseline to take a percentage of: identical zeros
                    // pass, anything appearing from zero trips the gate.
                    if bv == 0.0 {
                        (Some(0.0), false)
                    } else {
                        (None, gated)
                    }
                } else {
                    let pct = (bv - av) / av * 100.0;
                    (Some(pct), gated && pct.abs() > threshold_pct)
                }
            }
            // A gated key vanishing is structural, threshold-independent.
            (Some(_), None) => (None, true),
            // New keys are informational (instrumentation grew).
            (None, Some(_)) => (None, false),
            (None, None) => (None, false),
        };
        rows.push(DiffRow { key: key.clone(), base: av, new: bv, delta_pct, gated, regressed });
    }
    let regressions = rows.iter().filter(|r| r.regressed).count();
    Ok(DiffReport { base_schema, new_schema, threshold_pct, rows, regressions })
}

/// Read, parse and [`diff`] two artifact files.
pub fn diff_files(
    base_path: &str,
    new_path: &str,
    threshold_pct: f64,
) -> crate::Result<DiffReport> {
    let read = |path: &str| -> crate::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading artifact {path}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing artifact {path}: {e}"))
    };
    diff(&read(base_path)?, &read(new_path)?, threshold_pct)
}

/// Fixed-format number: integers print as integers, everything else with
/// six significant decimals — deterministic for byte-identical reports.
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

impl DiffReport {
    /// True when the gate passes.
    pub fn ok(&self) -> bool {
        self.regressions == 0
    }

    /// The printed delta table, one string per line, deterministic.
    pub fn table_lines(&self) -> Vec<String> {
        let mut lines = Vec::with_capacity(self.rows.len() + 2);
        lines.push(format!(
            "perf diff ({}) — threshold {:.1}%, {} keys, {} regression(s)",
            self.base_schema,
            self.threshold_pct,
            self.rows.len(),
            self.regressions
        ));
        let width = self.rows.iter().map(|r| r.key.len()).max().unwrap_or(3).max(3);
        lines.push(format!(
            "{:<width$}  {:>14}  {:>14}  {:>9}  note",
            "key", "base", "new", "delta%"
        ));
        for r in &self.rows {
            let note = if r.regressed {
                "REGRESSED"
            } else if r.base.is_none() {
                "new key"
            } else if !r.gated {
                "timing (not gated)"
            } else {
                ""
            };
            lines.push(format!(
                "{:<width$}  {:>14}  {:>14}  {:>9}  {}",
                r.key,
                r.base.map(fmt_num).unwrap_or_else(|| "-".to_string()),
                r.new.map(fmt_num).unwrap_or_else(|| "-".to_string()),
                r.delta_pct.map(|p| format!("{p:+.2}")).unwrap_or_else(|| "-".to_string()),
                note
            ));
        }
        lines
    }

    /// The machine-readable `tango-perf/v1` report document.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("key".to_string(), Json::Str(r.key.clone()));
                m.insert("base".to_string(), r.base.map(Json::Num).unwrap_or(Json::Null));
                m.insert("new".to_string(), r.new.map(Json::Num).unwrap_or(Json::Null));
                m.insert(
                    "delta_pct".to_string(),
                    r.delta_pct.map(Json::Num).unwrap_or(Json::Null),
                );
                m.insert("gated".to_string(), Json::Bool(r.gated));
                m.insert("regressed".to_string(), Json::Bool(r.regressed));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
        m.insert("base_schema".to_string(), Json::Str(self.base_schema.clone()));
        m.insert("new_schema".to_string(), Json::Str(self.new_schema.clone()));
        m.insert("threshold_pct".to_string(), Json::Num(self.threshold_pct));
        m.insert("regressions".to_string(), Json::Num(self.regressions as f64));
        m.insert("ok".to_string(), Json::Bool(self.ok()));
        m.insert("rows".to_string(), Json::Arr(rows));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics_doc(calls: f64, total_s: f64, rows: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":"tango-metrics/v1",
                 "spans":{{"epoch":{{"calls":{calls},"total_s":{total_s}}}}},
                 "counters":{{"gather.rows":{rows}}}}}"#
        ))
        .expect("test doc")
    }

    #[test]
    fn identical_artifacts_pass() {
        let d = metrics_doc(3.0, 1.5, 100.0);
        let rep = diff(&d, &d, 10.0).expect("diff");
        assert!(rep.ok());
        assert_eq!(rep.rows.len(), 3);
        assert!(rep.rows.iter().all(|r| r.delta_pct == Some(0.0)));
    }

    #[test]
    fn gated_regression_trips_and_timing_does_not() {
        let a = metrics_doc(3.0, 1.5, 100.0);
        // Counter +50% (gated, trips); total_s +400% (timing, advisory).
        let b = metrics_doc(3.0, 7.5, 150.0);
        let rep = diff(&a, &b, 25.0).expect("diff");
        assert_eq!(rep.regressions, 1);
        let bad: Vec<&str> =
            rep.rows.iter().filter(|r| r.regressed).map(|r| r.key.as_str()).collect();
        assert_eq!(bad, vec!["counters.gather.rows"]);
        // Below threshold the same counter drift passes.
        assert!(diff(&a, &b, 60.0).expect("diff").ok());
    }

    #[test]
    fn missing_gated_key_is_always_a_regression() {
        let a = metrics_doc(3.0, 1.5, 100.0);
        let b = Json::parse(r#"{"schema":"tango-metrics/v1","spans":{},"counters":{}}"#)
            .expect("test doc");
        let rep = diff(&a, &b, 1e9).expect("diff");
        assert!(!rep.ok());
        // All three baseline keys vanished — timing ones included
        // (vanishing is structural, not jitter).
        assert_eq!(rep.regressions, 3);
    }

    #[test]
    fn new_keys_are_informational() {
        let a = Json::parse(r#"{"schema":"tango-metrics/v1","spans":{},"counters":{}}"#)
            .expect("test doc");
        let b = metrics_doc(3.0, 1.5, 100.0);
        assert!(diff(&a, &b, 10.0).expect("diff").ok());
    }

    #[test]
    fn bench_rows_are_keyed_by_string_fields() {
        let doc = Json::parse(
            r#"{"schema":"tango-bench/train_speed/v1","epochs_per_run":3,
                "results":[{"dataset":"Pubmed","model":"gcn","tango_speedup":1.4,
                            "fp32_s_per_epoch":0.5}]}"#,
        )
        .expect("test doc");
        let flat = comparable_metrics(&doc).expect("flatten");
        assert_eq!(flat.get("epochs_per_run"), Some(&3.0));
        assert_eq!(flat.get("results[dataset=Pubmed,model=gcn].tango_speedup"), Some(&1.4));
        // Bench config drift (gated scalar) trips the gate.
        let drifted = Json::parse(
            r#"{"schema":"tango-bench/train_speed/v1","epochs_per_run":30,
                "results":[{"dataset":"Pubmed","model":"gcn","tango_speedup":1.4,
                            "fp32_s_per_epoch":0.5}]}"#,
        )
        .expect("test doc");
        assert!(!diff(&doc, &drifted, 25.0).expect("diff").ok());
    }

    #[test]
    fn report_is_byte_identical_across_runs() {
        let a = metrics_doc(3.0, 1.5, 100.0);
        let b = metrics_doc(3.0, 1.6, 130.0);
        let r1 = diff(&a, &b, 10.0).expect("diff");
        let r2 = diff(&a, &b, 10.0).expect("diff");
        assert_eq!(r1.to_json().to_string(), r2.to_json().to_string());
        assert_eq!(r1.table_lines(), r2.table_lines());
    }

    #[test]
    fn mismatched_schemas_are_rejected() {
        let a = metrics_doc(1.0, 1.0, 1.0);
        let b = Json::parse(r#"{"schema":"tango-bench/packed/v1","results":[]}"#).expect("doc");
        assert!(diff(&a, &b, 10.0).is_err());
        assert!(comparable_metrics(&Json::parse(r#"{"x":1}"#).expect("doc")).is_err());
    }
}
