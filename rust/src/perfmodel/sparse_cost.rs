//! Traffic model for the memory-bound sparse primitives (SPMM / SDDMM).
//!
//! The paper's argument (§3.1/§3.3): sparse primitives are bound by the
//! *random* accesses into the node/edge feature matrices. Quantization
//! shrinks those matrices 4× (INT8), improving cache hit rates and cutting
//! DRAM traffic; a dedicated sequential quantization pass is cheap by
//! comparison. The model charges:
//!
//! - structure reads (indptr + indices), sequential;
//! - feature reads, random — de-rated by a locality factor that improves
//!   when the working set shrinks (the quantization benefit, Fig. 13/15/16a);
//! - output writes, sequential;
//! - for the quantized path, the dedicated quantize pass (sequential read
//!   of FP32 + write of INT8).

use super::gpu::GpuSpec;

/// Element type of the randomly-accessed feature matrices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseDtype {
    /// FP32 features (baseline).
    F32,
    /// INT8 features (Tango).
    I8,
    /// INT4 features (Fig. 16a; packed, but charged a byte per random
    /// touch — sub-byte accesses cannot be coalesced individually).
    I4,
}

impl SparseDtype {
    fn bytes(self) -> f64 {
        match self {
            SparseDtype::F32 => 4.0,
            SparseDtype::I8 => 1.0,
            SparseDtype::I4 => 0.5,
        }
    }
}

/// Random-access de-rating: a random touch of `b` bytes moves a whole cache
/// line unless the working set fits in cache. `working_set` in bytes.
fn random_access_efficiency(working_set: f64, cache_bytes: f64) -> f64 {
    // Fraction of touches served by cache grows as the working set shrinks.
    (cache_bytes / working_set).min(1.0).max(0.05)
}

/// L2 size used for the locality model (V100/A100 ballpark).
const CACHE_BYTES: f64 = 6.0 * 1024.0 * 1024.0;
/// DRAM burst granularity for random touches.
const LINE_BYTES: f64 = 32.0;

/// Modelled SPMM time: `out[v] = Σ_e w_e · X[src(e)]` over `edges` entries,
/// features of width `feat` per node, `nodes` nodes.
pub fn spmm_time(g: &GpuSpec, nodes: usize, edges: usize, feat: usize, dtype: SparseDtype) -> f64 {
    let (nf, ef, ff) = (nodes as f64, edges as f64, feat as f64);
    // Sequential: structure (8 B/edge) + edge values + output write (FP32).
    let mut traffic = ef * 8.0 + ef * dtype.bytes() + nf * ff * 4.0;
    // Random: one feature-row gather per edge.
    let row_bytes = ff * dtype.bytes();
    let ws = nf * row_bytes;
    let hit = random_access_efficiency(ws, CACHE_BYTES);
    let miss_bytes = row_bytes.max(LINE_BYTES); // short rows still pull a line
    traffic += ef * (1.0 - hit) * miss_bytes;
    if dtype != SparseDtype::F32 {
        // Dedicated quantization pass: sequential FP32 read + quantized write.
        traffic += nf * ff * (4.0 + dtype.bytes());
    }
    g.launch_overhead + traffic / g.mem_bw
}

/// Modelled SDDMM time: per-edge op over `feat`-wide rows of two node
/// matrices (`dot`) or scalar rows (`add`): `work_per_edge` row touches.
pub fn sddmm_time(g: &GpuSpec, nodes: usize, edges: usize, feat: usize, dtype: SparseDtype) -> f64 {
    let (nf, ef, ff) = (nodes as f64, edges as f64, feat as f64);
    // Sequential: structure + edge output (FP32).
    let mut traffic = ef * 8.0 + ef * 4.0;
    // Random: two endpoint-row gathers per edge.
    let row_bytes = ff * dtype.bytes();
    let ws = 2.0 * nf * row_bytes;
    let hit = random_access_efficiency(ws, CACHE_BYTES);
    traffic += 2.0 * ef * (1.0 - hit) * row_bytes.max(LINE_BYTES);
    if dtype != SparseDtype::F32 {
        traffic += 2.0 * nf * ff * (4.0 + dtype.bytes());
    }
    g.launch_overhead + traffic / g.mem_bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::gpu::V100;

    // ogbn-arxiv-ish scale.
    const N: usize = 169_343;
    const E: usize = 1_166_243;

    #[test]
    fn quantized_spmm_faster_on_large_graphs() {
        let f32t = spmm_time(&V100, N, E, 64, SparseDtype::F32);
        let i8t = spmm_time(&V100, N, E, 64, SparseDtype::I8);
        assert!(i8t < f32t, "{i8t} vs {f32t}");
    }

    #[test]
    fn int4_beats_int8_on_dense_graphs() {
        // Fig. 16a: dense graphs benefit more (cache reuse of node rows).
        let i8t = sddmm_time(&V100, N, E, 64, SparseDtype::I8);
        let i4t = sddmm_time(&V100, N, E, 64, SparseDtype::I4);
        assert!(i4t <= i8t);
    }

    #[test]
    fn tiny_graph_quantization_not_worth_it() {
        // When the working set fits in cache, the dedicated quantize pass
        // costs more than the (zero) random-traffic saving.
        let f32t = spmm_time(&V100, 1000, 5000, 16, SparseDtype::F32);
        let i8t = spmm_time(&V100, 1000, 5000, 16, SparseDtype::I8);
        assert!(i8t >= f32t, "{i8t} vs {f32t}");
    }

    #[test]
    fn sddmm_quantized_wins_at_scale() {
        let f32t = sddmm_time(&V100, N, E, 256, SparseDtype::F32);
        let i8t = sddmm_time(&V100, N, E, 256, SparseDtype::I8);
        let s = f32t / i8t;
        assert!(s > 1.2 && s < 5.0, "speedup {s}");
    }

    #[test]
    fn times_scale_with_edges() {
        let small = spmm_time(&V100, N, E / 10, 64, SparseDtype::F32);
        let large = spmm_time(&V100, N, E, 64, SparseDtype::F32);
        assert!(large > small);
    }
}
