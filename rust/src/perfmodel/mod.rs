//! Analytical GPU cost model (DESIGN.md §Substitutions).
//!
//! The paper's speedup claims are CUDA-hardware claims (DP4A, tensor cores,
//! cuBLAS); no GPU is present here, so this module reproduces the *shape*
//! of those results from first principles: device datasheet rates (V100 /
//! A100), a roofline GEMM model with the paper's quantization overhead
//! accounting (§3.3: `4K(M+N)` quantize + `2MN` dequantize flops), and a
//! traffic model for the memory-bound sparse primitives.
//!
//! Regenerates: Fig. 8 (end-to-end shape), Fig. 11 (GEMM speedups),
//! Fig. 12 (profiling ratios), Fig. 16b (INT8/INT4 tensor-core GEMM).

mod gemm_cost;
mod gpu;
mod sparse_cost;

pub use gemm_cost::{gemm_time, profile_ratios, GemmKind, GemmProfile};
pub use gpu::{GpuSpec, A100, V100};
pub use sparse_cost::{sddmm_time, spmm_time, SparseDtype};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_reexports_work() {
        assert_eq!(V100.name, "V100");
        assert_eq!(A100.name, "A100");
        let t = gemm_time(&V100, 1024, 1024, 1024, GemmKind::Fp32Cuda, false);
        assert!(t > 0.0);
    }
}
