//! GPU device parameters (datasheet values for the paper's testbeds).

/// Datasheet-level description of a GPU.
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    /// Marketing name.
    pub name: &'static str,
    /// FP32 CUDA-core throughput (FLOP/s).
    pub fp32_flops: f64,
    /// INT8 DP4A throughput on CUDA cores (OP/s) — 4 MACs per instruction,
    /// the paper's V100 quantized GEMM path.
    pub int8_dp4a_ops: f64,
    /// FP16 tensor-core throughput (FLOP/s).
    pub fp16_tc_flops: f64,
    /// INT8 tensor-core throughput (OP/s) — paper §1: "2× of FP16".
    pub int8_tc_ops: f64,
    /// INT4 tensor-core throughput (OP/s).
    pub int4_tc_ops: f64,
    /// HBM bandwidth (byte/s).
    pub mem_bw: f64,
    /// Kernel launch overhead (s).
    pub launch_overhead: f64,
}

/// V100S (the paper's main testbed: six V100S GPUs).
pub const V100: GpuSpec = GpuSpec {
    name: "V100",
    fp32_flops: 15.7e12,
    // 4× FP32 ALU rate via DP4A.
    int8_dp4a_ops: 62.8e12,
    fp16_tc_flops: 125.0e12,
    // V100 tensor cores have no INT8 mode; DP4A is the integer path.
    int8_tc_ops: 0.0,
    int4_tc_ops: 0.0,
    mem_bw: 1134.0e9, // V100S HBM2
    launch_overhead: 5e-6,
};

/// A100 (the paper's tensor-core comparison, §4.1/Fig. 11b/16b).
pub const A100: GpuSpec = GpuSpec {
    name: "A100",
    fp32_flops: 19.5e12,
    int8_dp4a_ops: 78.0e12,
    fp16_tc_flops: 312.0e12,
    int8_tc_ops: 624.0e12,
    int4_tc_ops: 1248.0e12,
    mem_bw: 1555.0e9,
    launch_overhead: 5e-6,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratio_int8_is_2x_fp16_on_a100() {
        // Paper §1: "computing with 8-bit integers on tensor core offers 2×
        // the throughput of 16-bit floating-point and 32× that of 32-bit".
        assert!((A100.int8_tc_ops / A100.fp16_tc_flops - 2.0).abs() < 1e-9);
        assert!((A100.int8_tc_ops / A100.fp32_flops - 32.0).abs() < 0.1);
    }

    #[test]
    fn dp4a_is_4x_fp32() {
        assert!((V100.int8_dp4a_ops / V100.fp32_flops - 4.0).abs() < 1e-9);
    }
}
