//! Compute-roofline GEMM cost model with the paper's quantization-overhead
//! accounting (§3.3 "Quantization overhead vs. benefit analysis").
//!
//! GEMM at the paper's shapes is compute-bound ("since GEMM is
//! computation-intensive, our increased computation throughput dominates
//! the performance impacts", Fig. 12 discussion), so the model is
//! `launch + MACs / (datasheet rate × achievable efficiency) + overhead`.
//! Efficiencies are calibrated once against the paper's *measured* ratios
//! (Fig. 11a ≈ 2.2×, Fig. 11b ≈ 1.8–1.9×, Fig. 16b ≈ 5–10×) and then used
//! to regenerate every GEMM figure — so the model reproduces the shape
//! (who wins, how factors move with D), not one hand-picked point.

use super::gpu::GpuSpec;

/// Which GEMM implementation is being modelled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKind {
    /// cuBLAS FP32 on CUDA cores (the Fig. 11a baseline).
    Fp32Cuda,
    /// Tango INT8 via DP4A on CUDA cores (Fig. 11a).
    Int8Dp4a,
    /// cuBLAS FP16 on tensor cores (the Fig. 11b baseline).
    Fp16Tensor,
    /// Tango INT8 on tensor cores (Fig. 11b / 16b).
    Int8Tensor,
    /// Tango INT4 on tensor cores (Fig. 16b).
    Int4Tensor,
}

impl GemmKind {
    /// Effective throughput: datasheet rate × achievable efficiency.
    ///
    /// Efficiencies: cuBLAS FP32 runs near peak on big GEMMs (0.90); DP4A
    /// kernels issue on limited ports (0.50 — calibrates Fig. 11a's 2.2×);
    /// tensor-core kernels at GNN shapes (tall-skinny, K = hidden size)
    /// reach ~20% of peak (calibrates Fig. 11b's ~1.85× and Fig. 16b's
    /// 5–8×); INT4 additionally under-utilises shared-memory bandwidth
    /// with sub-byte accesses (§4.4), halving its effective gain.
    fn effective_rate(self, g: &GpuSpec) -> f64 {
        match self {
            GemmKind::Fp32Cuda => g.fp32_flops * 0.90,
            GemmKind::Int8Dp4a => g.int8_dp4a_ops * 0.50,
            GemmKind::Fp16Tensor => g.fp16_tc_flops * 0.20,
            GemmKind::Int8Tensor => g.int8_tc_ops * 0.19,
            GemmKind::Int4Tensor => g.int4_tc_ops * 0.11,
        }
    }

    /// Whether this kind pays the Tango quantization overhead.
    pub fn quantized(self) -> bool {
        !matches!(self, GemmKind::Fp32Cuda | GemmKind::Fp16Tensor)
    }
}

/// Modelled runtime of an `M×K · K×N` GEMM.
///
/// Quantized kinds add the paper's overhead terms — `4K(M+N)` flops to
/// quantize the inputs (abs-max reduction + scale/cast) and `2MN` to
/// dequantize the result — unless `cached_inputs` marks the Fig. 10 reuse
/// path where quantized copies come from the inter-primitive cache.
pub fn gemm_time(g: &GpuSpec, m: usize, n: usize, k: usize, kind: GemmKind, cached_inputs: bool) -> f64 {
    let (mf, nf, kf) = (m as f64, n as f64, k as f64);
    let macs = 2.0 * mf * nf * kf;
    let compute = macs / kind.effective_rate(g);
    let mut overhead = 0.0;
    if kind.quantized() && !cached_inputs {
        // §3.3: 4K(M+N) quantization + 2MN dequantization flops, on the
        // FP32 units.
        overhead = (4.0 * kf * (mf + nf) + 2.0 * mf * nf) / (g.fp32_flops * 0.90);
    }
    g.launch_overhead + compute + overhead
}

/// The Fig. 12 profiling quantities for quantized-vs-FP32 GEMM.
#[derive(Debug, Clone, Copy)]
pub struct GemmProfile {
    /// Achieved-compute-throughput ratio (ops/s vs baseline).
    pub compute_throughput_ratio: f64,
    /// Achieved-memory-throughput ratio (GB/s vs baseline).
    pub memory_throughput_ratio: f64,
    /// Instructions ratio (quantized / baseline).
    pub instruction_ratio: f64,
    /// IPC ratio (quantized / baseline).
    pub ipc_ratio: f64,
}

/// Model the Fig. 12 ratios for an `M×K·K×N` GEMM on `g`.
///
/// DP4A packs 4 MACs per instruction, so the kernel retires ~1/4 the MAC
/// instructions plus quantization/pack bookkeeping (the paper measures
/// ~31% of baseline instructions). IPC drops (~70%) because DP4A issues on
/// fewer ports; throughput still roughly doubles. Memory throughput rises
/// because the kernel additionally writes the quantized tiles back (the
/// paper: "memory throughput is higher because our quantized GEMM writes
/// the quantized matrix out").
pub fn profile_ratios(g: &GpuSpec, m: usize, n: usize, k: usize) -> GemmProfile {
    let t_fp32 = gemm_time(g, m, n, k, GemmKind::Fp32Cuda, false);
    let t_int8 = gemm_time(g, m, n, k, GemmKind::Int8Dp4a, false);
    let speedup = t_fp32 / t_int8;
    let (mf, nf, kf) = (m as f64, n as f64, k as f64);
    let macs = mf * nf * kf;
    // Instruction accounting: baseline ≈ 1 FMA per MAC; quantized ≈ 1 DP4A
    // per 4 MACs + quantize/dequantize/scale instructions.
    let instr_base = macs;
    let instr_quant = macs / 4.0 + 4.0 * kf * (mf + nf) + 2.0 * mf * nf;
    let instruction_ratio = instr_quant / instr_base;
    // IPC = instructions / time, normalised to the baseline.
    let ipc_ratio = instruction_ratio * speedup;
    // Bytes moved: baseline reads A,B and writes C (FP32); quantized reads
    // A,B (FP32, fused quantize-at-load), writes C (FP32) AND the quantized
    // INT8 copies of A,B.
    let bytes_base = (mf * kf + kf * nf + mf * nf) * 4.0;
    let bytes_quant = bytes_base + (mf * kf + kf * nf) * 1.0;
    GemmProfile {
        compute_throughput_ratio: speedup,
        memory_throughput_ratio: bytes_quant / bytes_base * speedup,
        instruction_ratio,
        ipc_ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::gpu::{A100, V100};

    /// The paper's GEMM shapes: M = graph nodes, N = K = hidden size.
    const M: usize = 169_343;

    #[test]
    fn fig11a_int8_dp4a_speedup_band() {
        // Fig. 11a: 2.2× (D=256) and 2.5× (D=512) on average.
        for &d in &[256usize, 512] {
            let t32 = gemm_time(&V100, M, d, d, GemmKind::Fp32Cuda, false);
            let t8 = gemm_time(&V100, M, d, d, GemmKind::Int8Dp4a, false);
            let s = t32 / t8;
            assert!(s > 1.8 && s < 3.2, "D={d}: speedup {s}");
        }
    }

    #[test]
    fn speedup_grows_with_hidden_size() {
        // Paper Fig. 11a: "quantization offers more speedup on the GEMM
        // operator when the hidden size increases".
        let s = |d: usize| {
            gemm_time(&V100, M, d, d, GemmKind::Fp32Cuda, false)
                / gemm_time(&V100, M, d, d, GemmKind::Int8Dp4a, false)
        };
        assert!(s(512) > s(256), "{} vs {}", s(512), s(256));
    }

    #[test]
    fn fig11b_int8_tc_vs_fp16_tc_band() {
        // Fig. 11b: 1.9× (D=256), 1.8× (D=512) — below the 2× hardware
        // ratio because of quantization overhead.
        for &d in &[256usize, 512] {
            let t16 = gemm_time(&A100, M, d, d, GemmKind::Fp16Tensor, false);
            let t8 = gemm_time(&A100, M, d, d, GemmKind::Int8Tensor, false);
            let s = t16 / t8;
            assert!(s > 1.5 && s < 2.0, "D={d}: speedup {s}");
        }
    }

    #[test]
    fn fig16b_int8_int4_vs_fp32_bands() {
        // Fig. 16b (A100): INT8 5.4×/8.1×, INT4 6.2×/10.1× vs cuBLAS at
        // D=256/512. Assert the ordering and rough magnitudes.
        for &(d, lo8, hi8) in &[(256usize, 3.5, 8.0), (512, 4.5, 10.0)] {
            let t32 = gemm_time(&A100, M, d, d, GemmKind::Fp32Cuda, false);
            let t8 = gemm_time(&A100, M, d, d, GemmKind::Int8Tensor, false);
            let t4 = gemm_time(&A100, M, d, d, GemmKind::Int4Tensor, false);
            let s8 = t32 / t8;
            let s4 = t32 / t4;
            assert!(s8 > lo8 && s8 < hi8, "D={d}: int8 {s8}");
            assert!(s4 > s8, "int4 must beat int8 (D={d}): {s4} vs {s8}");
            assert!(s4 / s8 < 1.6, "int4 gain must be marginal (§4.4): {}", s4 / s8);
        }
    }

    #[test]
    fn cached_inputs_remove_overhead() {
        let fresh = gemm_time(&V100, 4096, 128, 128, GemmKind::Int8Dp4a, false);
        let cached = gemm_time(&V100, 4096, 128, 128, GemmKind::Int8Dp4a, true);
        assert!(cached < fresh, "{cached} vs {fresh}");
    }

    #[test]
    fn fig12_ratios_match_paper_shape() {
        // Paper Fig. 12: ~2.1× compute throughput, ~2.2× memory throughput,
        // IPC ≈ 70%, instructions ≈ 31%.
        let p = profile_ratios(&V100, M, 256, 256);
        assert!(p.compute_throughput_ratio > 1.8 && p.compute_throughput_ratio < 3.0,
            "compute ratio {}", p.compute_throughput_ratio);
        assert!(p.instruction_ratio > 0.2 && p.instruction_ratio < 0.45, "{}", p.instruction_ratio);
        assert!(p.ipc_ratio > 0.5 && p.ipc_ratio < 1.0, "{}", p.ipc_ratio);
        assert!(p.memory_throughput_ratio > p.compute_throughput_ratio,
            "memory ratio must exceed compute ratio (quantized copies written out)");
    }
}
