//! Configuration system: a TOML-subset parser (offline `toml` stand-in) and
//! the typed [`TrainConfig`] the launcher consumes.
//!
//! Supported TOML subset — everything the configs in `configs/` use:
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! `#` comments.

mod toml_lite;

pub use toml_lite::TomlDoc;

pub use crate::policy::PolicyConfig;

use crate::graph::datasets::Task;
use crate::model::TrainMode;

/// Which model architecture to train.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Graph Convolutional Network (GEMM + SPMM).
    Gcn,
    /// Graph Attention Network (GEMM + SPMM + SDDMM).
    Gat,
}

impl std::str::FromStr for ModelKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Ok(ModelKind::Gcn),
            "gat" => Ok(ModelKind::Gat),
            other => Err(format!("unknown model '{other}' (gcn|gat)")),
        }
    }
}

/// Which learning task to train (`--task` / the `task` TOML key). Absent,
/// the run follows the dataset's declared task; set, it overrides it — e.g.
/// link prediction on any generated graph's topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Softmax-CE node classification.
    NodeClassification,
    /// Dot-product link prediction (reports AUC).
    LinkPrediction,
}

impl TaskKind {
    /// Map onto the dataset-level task enum.
    pub fn to_task(self) -> Task {
        match self {
            TaskKind::NodeClassification => Task::NodeClassification,
            TaskKind::LinkPrediction => Task::LinkPrediction,
        }
    }

    /// The effective task of a run: the config override when set, the
    /// dataset's declared task otherwise.
    pub fn resolve(overridden: Option<TaskKind>, dataset_task: Task) -> Task {
        overridden.map(TaskKind::to_task).unwrap_or(dataset_task)
    }
}

impl std::str::FromStr for TaskKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "nc" | "node" | "node-classification" | "nodeclass" => {
                Ok(TaskKind::NodeClassification)
            }
            "linkpred" | "lp" | "link-prediction" | "linkprediction" => {
                Ok(TaskKind::LinkPrediction)
            }
            other => Err(format!("unknown task '{other}' (nc|linkpred)")),
        }
    }
}

/// Parse a task name (`"nc"` / `"linkpred"`).
pub fn parse_task(name: &str) -> Result<TaskKind, String> {
    name.parse()
}

/// Canonical name of a task kind.
pub fn task_name(task: Task) -> &'static str {
    match task {
        Task::NodeClassification => "nc",
        Task::LinkPrediction => "linkpred",
    }
}

/// Canonical name of a model kind (checkpoint fingerprints, artifacts).
pub fn model_name(kind: ModelKind) -> &'static str {
    match kind {
        ModelKind::Gcn => "gcn",
        ModelKind::Gat => "gat",
    }
}

/// Display name of a task's evaluation metric.
pub fn metric_name(task: Task) -> &'static str {
    match task {
        Task::NodeClassification => "accuracy",
        Task::LinkPrediction => "AUC",
    }
}

/// Parse a mode name into a [`TrainMode`].
pub fn parse_mode(name: &str, bits: u8) -> Result<TrainMode, String> {
    match name.to_ascii_lowercase().as_str() {
        "fp32" | "dgl" => Ok(TrainMode::fp32()),
        "tango" => Ok(TrainMode::tango(bits)),
        "tango-test1" | "test1" => Ok(TrainMode::tango_test1(bits)),
        "tango-test2" | "test2" => Ok(TrainMode::tango_test2(bits)),
        "exact" => Ok(TrainMode::exact(bits)),
        other => Err(format!("unknown mode '{other}' (fp32|tango|test1|test2|exact)")),
    }
}

/// Mode back to its canonical name.
pub fn mode_name(mode: &TrainMode) -> &'static str {
    if mode.exact_style {
        "exact"
    } else if !mode.quantize {
        "fp32"
    } else if !mode.fp32_pre_softmax {
        "tango-test1"
    } else if !mode.stochastic {
        "tango-test2"
    } else {
        "tango"
    }
}

/// Mini-batch neighbor-sampling knobs (the DGL-style sampled training mode
/// run by `sampler::MiniBatchTrainer`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SamplerConfig {
    /// Train on sampled mini-batches instead of full-graph epochs.
    pub enabled: bool,
    /// Weight fanout draws by global in-degree (`--sampler degree` — the
    /// Degree-Quant importance rule: hub nodes preferentially stay in the
    /// sampled frontier). Off = uniform draws, byte-identical to the
    /// pre-policy sampler.
    pub degree_biased: bool,
    /// Per-layer fanouts, input-side layer first. Repeated (last entry) or
    /// truncated to the model's layer count at trainer construction.
    pub fanouts: Vec<usize>,
    /// Seed nodes per mini-batch.
    pub batch_size: usize,
    /// Extra seed for the sampling streams (mixed with the run seed so the
    /// sampling randomness can vary independently of model init).
    pub seed: u64,
    /// Max distinct nodes held by the quantized feature-gather cache
    /// (0 = unbounded). An epoch sweep touches every training node, so the
    /// bound is what keeps the hot-node cache from growing to the whole
    /// feature table; evicted rows simply requantize on their next gather.
    pub cache_nodes: usize,
    /// Batch-prefetch depth (the paper's §4.2 overlap): a producer thread
    /// runs sampling + (quantized) feature gathering up to `prefetch`
    /// batches ahead of the training step. 0 = strictly sequential.
    /// Prefetched runs are bit-identical to sequential ones — per-batch RNG
    /// streams are keyed by `(epoch, batch index)` alone.
    pub prefetch: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            enabled: false,
            degree_biased: false,
            fanouts: vec![10, 10],
            batch_size: 512,
            seed: 0x5A17,
            cache_nodes: 0,
            prefetch: 2,
        }
    }
}

/// Observability knobs (the `[metrics]` TOML section and the
/// `--trace` / `--metrics-out` / `--trace-out` / `--flight-recorder` CLI
/// flags; see [`crate::obs`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsConfig {
    /// Force tracing on/off for this run. `None` leaves the process-wide
    /// default alone (on, unless the `TANGO_TRACE=0` env var disabled it).
    pub trace: Option<bool>,
    /// Write the structured JSON run artifact (`tango-metrics/v1`) to this
    /// path after the run completes.
    pub out: Option<String>,
    /// Write the Chrome trace-event timeline (`tango-trace/v1`, loadable
    /// in Perfetto) to this path after the run. Setting it turns event
    /// collection on for the run.
    pub trace_out: Option<String>,
    /// Arm the fault flight recorder: on every fault recovery (and on a
    /// trainer error) dump the last N timeline events per thread beside
    /// the metrics artifact. 0 = off.
    pub flight_recorder: usize,
}

/// Checkpoint/resume knobs (the `[ckpt]` TOML section and the
/// `--ckpt-every` / `--ckpt-path` / `--resume` CLI flags; see
/// [`crate::ckpt`]). Checkpoints are written atomically and resume is
/// bit-identical to the uninterrupted run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CkptConfig {
    /// Save a checkpoint every `every` global training steps (mini-batch
    /// steps for `tango train --sampler ...`, epochs for full-graph runs,
    /// all-reduce rounds for `tango multigpu`). 0 = checkpointing off.
    pub every: usize,
    /// Where the `tango-ckpt/v1` artifact lands (each save atomically
    /// replaces the previous one; a final checkpoint is written at run end
    /// whenever checkpointing is on).
    pub path: String,
    /// Restore from this checkpoint before training (`--resume PATH`).
    pub resume: Option<String>,
}

impl Default for CkptConfig {
    fn default() -> Self {
        CkptConfig { every: 0, path: "tango_ckpt.json".into(), resume: None }
    }
}

/// Seeded fault-injection knobs (the `[fault]` TOML section and the
/// `--inject-faults` family of CLI flags; see [`crate::fault`]). Faults are
/// scheduled by *global step*, never wall-clock, so injected runs stay
/// deterministic (audit rule D1) and recovery is testable bit-for-bit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultConfig {
    /// Master switch; off = no fault machinery touches the run.
    pub inject: bool,
    /// Seed for victim selection (which worker/link a scheduled fault hits).
    pub seed: u64,
    /// Global steps at which the prefetch producer thread panics
    /// (`tango train` sampled runs). Listing a step twice schedules two
    /// consecutive panics — how retry-budget exhaustion is exercised.
    pub producer_steps: Vec<u64>,
    /// All-reduce rounds at which a worker fails (`tango multigpu`).
    pub worker_steps: Vec<u64>,
    /// All-reduce rounds at which a ring link drops (`tango multigpu`).
    pub link_steps: Vec<u64>,
    /// All-reduce rounds at which the shared feature-store lock is
    /// poisoned (`tango multigpu`, quantized modes).
    pub lock_steps: Vec<u64>,
    /// Recovery retry budget per fault event before the run degrades
    /// (link drops) or dies (producer/worker faults).
    pub max_retries: usize,
    /// Base of the simulated exponential backoff charged per retry
    /// (`backoff_ms * 2^(attempt-1)`, accumulated in the report — never
    /// slept, never read from a clock).
    pub backoff_ms: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            inject: false,
            seed: 0xFA17,
            producer_steps: Vec::new(),
            worker_steps: Vec::new(),
            link_steps: Vec::new(),
            lock_steps: Vec::new(),
            max_retries: 2,
            backoff_ms: 100,
        }
    }
}

/// Parse a comma-separated fault-step list: `"3,5"`, `""` (no faults of
/// that class). Unlike the fanout/bucket lists, empty is meaningful here.
pub fn parse_fault_steps(s: &str) -> Result<Vec<u64>, String> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    let mut out = parse_csv::<u64>(s, "fault step", "--fault-producer-steps 3,5")?;
    out.sort_unstable();
    Ok(out)
}

/// Parse a TOML/CLI boolean (`"true"`/`"false"` only — the same strictness
/// as the rest of the config surface).
pub fn parse_bool(s: &str, what: &str) -> Result<bool, String> {
    match s {
        "true" => Ok(true),
        "false" => Ok(false),
        other => Err(format!("{what} must be true|false, got '{other}'")),
    }
}

/// Parse one comma-separated knob list (the shared scaffold of
/// [`parse_fanouts`], [`parse_degree_buckets`] and [`parse_bucket_bits`]):
/// split on commas, trim, skip empty parts, parse every entry as `T`,
/// reject a list with no entries.
fn parse_csv<T: std::str::FromStr>(s: &str, what: &str, example: &str) -> Result<Vec<T>, String>
where
    T::Err: std::fmt::Display,
{
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(part.parse::<T>().map_err(|e| format!("{what} '{part}': {e}"))?);
    }
    if out.is_empty() {
        return Err(format!("no {what} entries in '{s}' (e.g. {example})"));
    }
    Ok(out)
}

/// Parse a comma-separated fanout list: `"10,10"`, `"15, 10, 5"`.
pub fn parse_fanouts(s: &str) -> Result<Vec<usize>, String> {
    let out = parse_csv::<usize>(s, "fanout", "--fanouts 10,10")?;
    if out.contains(&0) {
        return Err("fanouts must be >= 1".to_string());
    }
    Ok(out)
}

/// The `--sampler` choice: full-graph epochs, uniform mini-batch sampling,
/// or degree-biased mini-batch sampling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerChoice {
    /// Full-graph epochs (sampling off).
    Full,
    /// Uniform neighbor sampling.
    Neighbor,
    /// Degree-biased neighbor sampling (fanout draws ∝ global in-degree).
    Degree,
}

impl SamplerChoice {
    /// Write the choice into a [`SamplerConfig`]'s `enabled`/`degree_biased`
    /// pair — the one rule CLI and TOML share.
    pub fn apply(self, sampler: &mut SamplerConfig) {
        match self {
            SamplerChoice::Full => {
                sampler.enabled = false;
                sampler.degree_biased = false;
            }
            SamplerChoice::Neighbor => {
                sampler.enabled = true;
                sampler.degree_biased = false;
            }
            SamplerChoice::Degree => {
                sampler.enabled = true;
                sampler.degree_biased = true;
            }
        }
    }
}

/// Parse a sampler kind name: `"neighbor"` enables uniform mini-batch
/// sampling, `"degree"` enables degree-biased mini-batch sampling,
/// `"full"`/`"none"` keeps full-graph epochs.
pub fn parse_sampler(name: &str) -> Result<SamplerChoice, String> {
    match name.to_ascii_lowercase().as_str() {
        "neighbor" | "neighbour" => Ok(SamplerChoice::Neighbor),
        "degree" | "degree-biased" | "importance" => Ok(SamplerChoice::Degree),
        "full" | "none" | "off" => Ok(SamplerChoice::Full),
        other => Err(format!("unknown sampler '{other}' (neighbor|degree|full)")),
    }
}

/// Parse a comma-separated ascending in-degree boundary list:
/// `"8,64"` → buckets `deg >= 64` / `8 <= deg < 64` / `deg < 8`
/// (monotonicity is enforced by `TrainConfig::validate`).
pub fn parse_degree_buckets(s: &str) -> Result<Vec<u32>, String> {
    parse_csv::<u32>(s, "degree-buckets", "--degree-buckets 8,64")
}

/// Parse a comma-separated per-bucket bit-width list, hottest bucket
/// first: `"8,6,4"` (range checks live in `TrainConfig::validate`).
pub fn parse_bucket_bits(s: &str) -> Result<Vec<u8>, String> {
    parse_csv::<u8>(s, "bucket-bits", "--bucket-bits 8,6,4")
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model architecture.
    pub model: ModelKind,
    /// Dataset name (see `graph::datasets::SPECS`) or "tiny".
    pub dataset: String,
    /// Training epochs (full-graph steps).
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// Hidden width.
    pub hidden: usize,
    /// Attention heads (GAT only).
    pub heads: usize,
    /// Layer count.
    pub layers: usize,
    /// Execution mode.
    pub mode: TrainMode,
    /// Auto-derive the bit width with the Fig. 2 rule before training.
    pub auto_bits: bool,
    /// RNG seed (graph, init, rounding streams).
    pub seed: u64,
    /// Log every `log_every` epochs (0 = silent).
    pub log_every: usize,
    /// Mini-batch neighbor-sampling mode (disabled = full-graph epochs).
    pub sampler: SamplerConfig,
    /// Degree-aware mixed-precision policy for the sampled feature gather
    /// (`--degree-buckets` / `--bucket-bits`, TOML `[policy]`). The default
    /// is the uniform policy — one bucket at the mode's bit width,
    /// bit-identical to a policy-less run.
    pub policy: PolicyConfig,
    /// Run quantized primitives directly on bit-packed payloads
    /// (`--packed-compute` / `packed_compute` — the
    /// [`PrimitiveBackend`](crate::primitives::PrimitiveBackend) seam).
    /// Off = dequantize-to-f32 kernels, bit-identical numerics either way.
    pub packed_compute: bool,
    /// Task override (`--task nc|linkpred`); `None` follows the dataset's
    /// declared task.
    pub task: Option<TaskKind>,
    /// Observability knobs (`[metrics]` / `--trace` / `--metrics-out`).
    pub metrics: MetricsConfig,
    /// Checkpoint/resume knobs (`[ckpt]` / `--ckpt-every` / `--resume`).
    pub ckpt: CkptConfig,
    /// Seeded fault-injection knobs (`[fault]` / `--inject-faults`).
    pub fault: FaultConfig,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // The paper's §4.1 model config.
        TrainConfig {
            model: ModelKind::Gcn,
            dataset: "Pubmed".into(),
            epochs: 30,
            lr: 0.05,
            hidden: 128,
            heads: 4,
            layers: 2,
            mode: TrainMode::tango(8),
            auto_bits: false,
            seed: 42,
            log_every: 0,
            sampler: SamplerConfig::default(),
            policy: PolicyConfig::default(),
            packed_compute: false,
            task: None,
            metrics: MetricsConfig::default(),
            ckpt: CkptConfig::default(),
            fault: FaultConfig::default(),
        }
    }
}

impl TrainConfig {
    /// Small config for doc examples and smoke tests.
    pub fn quickstart() -> Self {
        TrainConfig {
            dataset: "tiny".into(),
            hidden: 16,
            epochs: 20,
            ..Default::default()
        }
    }

    /// Load from a TOML file's `[train]` section (all keys optional).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = TrainConfig::default();
        let get = |k: &str| doc.get("train", k);
        if let Some(v) = get("model") {
            cfg.model = v.parse()?;
        }
        if let Some(v) = get("dataset") {
            cfg.dataset = v.to_string();
        }
        if let Some(v) = get("epochs") {
            cfg.epochs = v.parse().map_err(|e| format!("epochs: {e}"))?;
        }
        if let Some(v) = get("lr") {
            cfg.lr = v.parse().map_err(|e| format!("lr: {e}"))?;
        }
        if let Some(v) = get("hidden") {
            cfg.hidden = v.parse().map_err(|e| format!("hidden: {e}"))?;
        }
        if let Some(v) = get("heads") {
            cfg.heads = v.parse().map_err(|e| format!("heads: {e}"))?;
        }
        if let Some(v) = get("layers") {
            cfg.layers = v.parse().map_err(|e| format!("layers: {e}"))?;
        }
        if let Some(v) = get("seed") {
            cfg.seed = v.parse().map_err(|e| format!("seed: {e}"))?;
        }
        if let Some(v) = get("log_every") {
            cfg.log_every = v.parse().map_err(|e| format!("log_every: {e}"))?;
        }
        let bits: u8 = match get("bits") {
            Some(v) => v.parse().map_err(|e| format!("bits: {e}"))?,
            None => 8,
        };
        if let Some(v) = get("mode") {
            cfg.mode = parse_mode(v, bits)?;
        } else {
            cfg.mode = TrainMode::tango(bits);
        }
        if let Some(v) = get("auto_bits") {
            cfg.auto_bits = v == "true";
        }
        if let Some(v) = get("sampler") {
            parse_sampler(v)?.apply(&mut cfg.sampler);
        }
        if let Some(v) = get("fanouts") {
            cfg.sampler.fanouts = parse_fanouts(v)?;
        }
        if let Some(v) = get("batch_size") {
            cfg.sampler.batch_size = v.parse().map_err(|e| format!("batch_size: {e}"))?;
            if cfg.sampler.batch_size == 0 {
                return Err("batch_size must be >= 1".to_string());
            }
        }
        if let Some(v) = get("sample_seed") {
            cfg.sampler.seed = v.parse().map_err(|e| format!("sample_seed: {e}"))?;
        }
        if let Some(v) = get("cache_nodes") {
            cfg.sampler.cache_nodes = v.parse().map_err(|e| format!("cache_nodes: {e}"))?;
            if cfg.sampler.cache_nodes == 0 {
                return Err(
                    "cache_nodes must be >= 1 (omit the key for an unbounded cache)".to_string()
                );
            }
        }
        if let Some(v) = get("prefetch") {
            cfg.sampler.prefetch = v.parse().map_err(|e| format!("prefetch: {e}"))?;
        }
        if let Some(v) = get("packed_compute") {
            cfg.packed_compute = parse_bool(v, "packed_compute")?;
        }
        if let Some(v) = get("task") {
            cfg.task = Some(parse_task(v)?);
        }
        // Degree-aware mixed-precision knobs live in their own `[policy]`
        // section (shared by `tango train` and `tango multigpu` configs).
        if let Some(v) = doc.get("policy", "degree_buckets") {
            cfg.policy.degree_buckets = parse_degree_buckets(v)?;
        }
        if let Some(v) = doc.get("policy", "bucket_bits") {
            cfg.policy.bucket_bits = parse_bucket_bits(v)?;
        }
        // Observability knobs live in their own `[metrics]` section (shared
        // by `tango train` and `tango multigpu` configs).
        if let Some(v) = doc.get("metrics", "trace") {
            cfg.metrics.trace = Some(parse_bool(v, "metrics.trace")?);
        }
        if let Some(v) = doc.get("metrics", "out") {
            cfg.metrics.out = Some(v.to_string());
        }
        if let Some(v) = doc.get("metrics", "trace_out") {
            cfg.metrics.trace_out = Some(v.to_string());
        }
        if let Some(v) = doc.get("metrics", "flight_recorder") {
            cfg.metrics.flight_recorder = v.parse().map_err(|e| format!("flight_recorder: {e}"))?;
        }
        // Checkpoint/resume knobs live in their own `[ckpt]` section (shared
        // by `tango train` and `tango multigpu` configs).
        if let Some(v) = doc.get("ckpt", "ckpt_every") {
            cfg.ckpt.every = v.parse().map_err(|e| format!("ckpt_every: {e}"))?;
        }
        if let Some(v) = doc.get("ckpt", "ckpt_path") {
            cfg.ckpt.path = v.to_string();
        }
        if let Some(v) = doc.get("ckpt", "resume") {
            cfg.ckpt.resume = Some(v.to_string());
        }
        // Fault-injection knobs live in their own `[fault]` section; every
        // key is fully prefixed so the CLI flags match one-to-one.
        if let Some(v) = doc.get("fault", "inject_faults") {
            cfg.fault.inject = parse_bool(v, "inject_faults")?;
        }
        if let Some(v) = doc.get("fault", "fault_seed") {
            cfg.fault.seed = v.parse().map_err(|e| format!("fault_seed: {e}"))?;
        }
        if let Some(v) = doc.get("fault", "fault_producer_steps") {
            cfg.fault.producer_steps = parse_fault_steps(v)?;
        }
        if let Some(v) = doc.get("fault", "fault_worker_steps") {
            cfg.fault.worker_steps = parse_fault_steps(v)?;
        }
        if let Some(v) = doc.get("fault", "fault_link_steps") {
            cfg.fault.link_steps = parse_fault_steps(v)?;
        }
        if let Some(v) = doc.get("fault", "fault_lock_steps") {
            cfg.fault.lock_steps = parse_fault_steps(v)?;
        }
        if let Some(v) = doc.get("fault", "fault_max_retries") {
            cfg.fault.max_retries = v.parse().map_err(|e| format!("fault_max_retries: {e}"))?;
        }
        if let Some(v) = doc.get("fault", "fault_backoff_ms") {
            cfg.fault.backoff_ms = v.parse().map_err(|e| format!("fault_backoff_ms: {e}"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field sanity checks shared by every entry point (CLI, TOML,
    /// programmatic construction through the trainers). Returns an
    /// actionable message instead of panicking mid-run or silently training
    /// on nothing.
    pub fn validate(&self) -> Result<(), String> {
        if self.sampler.batch_size == 0 {
            return Err(
                "batch_size must be >= 1 — every mini-batch needs at least one seed".to_string()
            );
        }
        if self.sampler.fanouts.is_empty() {
            return Err(
                "fanouts must name at least one layer (e.g. --fanouts 10,10)".to_string()
            );
        }
        if self.sampler.fanouts.contains(&0) {
            return Err("fanouts must be >= 1 (a 0-fanout layer samples no messages)".to_string());
        }
        if self.layers == 0 {
            return Err("layers must be >= 1".to_string());
        }
        if self.hidden == 0 {
            return Err("hidden must be >= 1".to_string());
        }
        if self.mode.quantize && !(1..=8).contains(&self.mode.bits) {
            return Err(format!(
                "bits must be within 1..=8 for quantized modes, got {}",
                self.mode.bits
            ));
        }
        // Degree-aware policy: boundary monotonicity, width range and the
        // bucket-count/width-count match (actionable messages come from
        // the policy module itself).
        self.policy.validate()?;
        // The policy drives the *quantized* feature gather — without a
        // quantized mode there is no store to apply it to, and silently
        // training FP32 under a "mixed-precision" banner would mislead.
        if !self.policy.is_uniform() && !self.mode.quantize {
            return Err(
                "--degree-buckets/--bucket-bits need a quantized mode (e.g. --mode tango); \
                 FP32 runs gather full-precision rows and never apply a policy"
                    .to_string(),
            );
        }
        // Checkpointing needs somewhere to land; an empty path would only
        // surface as an I/O error mid-run.
        if self.ckpt.every > 0 && self.ckpt.path.is_empty() {
            return Err("ckpt_path must be non-empty when ckpt_every > 0".to_string());
        }
        if self.ckpt.resume.as_deref() == Some("") {
            return Err("--resume needs a checkpoint path".to_string());
        }
        // Packed compute reroutes the *quantized* kernels — an FP32 run has
        // no packed operands to hand them, so the flag would silently do
        // nothing. Reject it instead.
        if self.packed_compute && !self.mode.quantize {
            return Err(
                "--packed-compute needs a quantized mode (e.g. --mode tango); \
                 FP32 runs never materialize packed operands"
                    .to_string(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"
# paper §4.1 GAT config
[train]
model = "gat"
dataset = "ogbn-arxiv"
epochs = 500
lr = 0.01
hidden = 128
heads = 4
layers = 2
mode = "tango"
bits = 8
seed = 7
auto_bits = true
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, ModelKind::Gat);
        assert_eq!(cfg.dataset, "ogbn-arxiv");
        assert_eq!(cfg.epochs, 500);
        assert_eq!(cfg.heads, 4);
        assert!(cfg.auto_bits);
        assert_eq!(mode_name(&cfg.mode), "tango");
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert_eq!(cfg.model, ModelKind::Gcn);
        assert_eq!(cfg.epochs, 30);
        assert_eq!(mode_name(&cfg.mode), "tango");
    }

    #[test]
    fn rejects_unknown_model_and_mode() {
        assert!(TrainConfig::from_toml("[train]\nmodel = \"transformer\"\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nmode = \"int2\"\n").is_err());
    }

    #[test]
    fn sampler_keys_parse() {
        let text = r#"
[train]
model = "gcn"
sampler = "neighbor"
fanouts = "15,10"
batch_size = 256
sample_seed = 99
cache_nodes = 4096
prefetch = 4
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert!(cfg.sampler.enabled);
        assert_eq!(cfg.sampler.fanouts, vec![15, 10]);
        assert_eq!(cfg.sampler.batch_size, 256);
        assert_eq!(cfg.sampler.seed, 99);
        assert_eq!(cfg.sampler.cache_nodes, 4096);
        assert_eq!(cfg.sampler.prefetch, 4);
        // Default stays full-graph, with the overlap pipeline on.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert!(!plain.sampler.enabled);
        assert_eq!(plain.sampler.prefetch, 2);
        // prefetch = 0 is the explicit sequential mode, not an error.
        let seq = TrainConfig::from_toml("[train]\nprefetch = 0\n").unwrap();
        assert_eq!(seq.sampler.prefetch, 0);
        assert!(TrainConfig::from_toml("[train]\nprefetch = \"deep\"\n").is_err());
    }

    #[test]
    fn fanouts_parser_accepts_lists_and_rejects_junk() {
        assert_eq!(parse_fanouts("10,10").unwrap(), vec![10, 10]);
        assert_eq!(parse_fanouts(" 15, 10 ,5 ").unwrap(), vec![15, 10, 5]);
        assert!(parse_fanouts("").is_err());
        assert!(parse_fanouts("a,b").is_err());
        assert!(parse_fanouts("10,0").is_err());
        assert!(TrainConfig::from_toml("[train]\nbatch_size = 0\n").is_err());
        assert_eq!(parse_sampler("neighbor").unwrap(), SamplerChoice::Neighbor);
        assert_eq!(parse_sampler("degree").unwrap(), SamplerChoice::Degree);
        assert_eq!(parse_sampler("full").unwrap(), SamplerChoice::Full);
        assert!(parse_sampler("metis").is_err());
    }

    #[test]
    fn sampler_choice_applies_to_config() {
        let mut s = SamplerConfig::default();
        SamplerChoice::Degree.apply(&mut s);
        assert!(s.enabled && s.degree_biased);
        SamplerChoice::Neighbor.apply(&mut s);
        assert!(s.enabled && !s.degree_biased);
        SamplerChoice::Full.apply(&mut s);
        assert!(!s.enabled && !s.degree_biased);
        // TOML path: the degree sampler rides the existing `sampler` key.
        let cfg = TrainConfig::from_toml("[train]\nsampler = \"degree\"\n").unwrap();
        assert!(cfg.sampler.enabled && cfg.sampler.degree_biased);
    }

    #[test]
    fn policy_section_parses_and_validates() {
        let text = r#"
[train]
model = "gcn"
sampler = "neighbor"

[policy]
degree_buckets = "8,64"
bucket_bits = "8,6,4"
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.policy.degree_buckets, vec![8, 64]);
        assert_eq!(cfg.policy.bucket_bits, vec![8, 6, 4]);
        assert!(!cfg.policy.is_uniform());
        // No [policy] section = the uniform default.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert!(plain.policy.is_uniform());
        // Parser-level junk.
        assert!(parse_degree_buckets("8,64").is_ok());
        assert!(parse_degree_buckets("a,b").is_err());
        assert!(parse_degree_buckets("").is_err());
        assert!(parse_bucket_bits("8,6,4").is_ok());
        assert!(parse_bucket_bits("eight").is_err());
    }

    #[test]
    fn policy_validation_rejects_bad_knobs_with_actionable_messages() {
        let err = |t: &str| TrainConfig::from_toml(t).unwrap_err();
        // Widths outside 1..=8.
        let e = err("[policy]\nbucket_bits = \"9\"\n");
        assert!(e.contains("1..=8"), "{e}");
        let e = err("[policy]\nbucket_bits = \"0\"\n");
        assert!(e.contains("1..=8"), "{e}");
        // Non-monotone boundaries.
        let e = err("[policy]\ndegree_buckets = \"64,8\"\n");
        assert!(e.contains("strictly increasing"), "{e}");
        let e = err("[policy]\ndegree_buckets = \"8,8\"\n");
        assert!(e.contains("strictly increasing"), "{e}");
        // Bucket-count / width-count mismatch.
        let e = err("[policy]\ndegree_buckets = \"8,64\"\nbucket_bits = \"8,4\"\n");
        assert!(e.contains("3 buckets"), "{e}");
        // A policy without a quantized mode is silently dead — reject it.
        let e = err("[train]\nmode = \"fp32\"\n\n[policy]\ndegree_buckets = \"8\"\n");
        assert!(e.contains("quantized mode"), "{e}");
        // Same checks on a programmatic config.
        let mut cfg = TrainConfig::default();
        cfg.policy.degree_buckets = vec![8];
        cfg.policy.bucket_bits = vec![8, 6, 4];
        assert!(cfg.validate().is_err());
        cfg.policy.bucket_bits = vec![8, 4];
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn task_key_parses_and_rejects_junk() {
        let cfg = TrainConfig::from_toml("[train]\ntask = \"linkpred\"\n").unwrap();
        assert_eq!(cfg.task, Some(TaskKind::LinkPrediction));
        let cfg = TrainConfig::from_toml("[train]\ntask = \"nc\"\n").unwrap();
        assert_eq!(cfg.task, Some(TaskKind::NodeClassification));
        assert_eq!(TrainConfig::from_toml("[train]\n").unwrap().task, None);
        assert!(TrainConfig::from_toml("[train]\ntask = \"regression\"\n").is_err());
        assert_eq!(parse_task("lp").unwrap(), TaskKind::LinkPrediction);
        assert_eq!(parse_task("NODE").unwrap(), TaskKind::NodeClassification);
        assert!(parse_task("both").is_err());
    }

    #[test]
    fn task_resolution_prefers_override() {
        assert_eq!(
            TaskKind::resolve(Some(TaskKind::LinkPrediction), Task::NodeClassification),
            Task::LinkPrediction
        );
        assert_eq!(TaskKind::resolve(None, Task::LinkPrediction), Task::LinkPrediction);
        assert_eq!(task_name(Task::LinkPrediction), "linkpred");
        assert_eq!(task_name(Task::NodeClassification), "nc");
    }

    #[test]
    fn validation_rejects_degenerate_knobs() {
        let err = |t: &str| TrainConfig::from_toml(t).unwrap_err();
        assert!(err("[train]\ncache_nodes = 0\n").contains("cache_nodes"), "actionable message");
        assert!(err("[train]\nbatch_size = 0\n").contains("batch_size"));
        assert!(err("[train]\nfanouts = \"10,0\"\n").contains("fanout"));
        assert!(err("[train]\nlayers = 0\n").contains("layers"));
        assert!(err("[train]\nhidden = 0\n").contains("hidden"));
        let mut cfg = TrainConfig::default();
        cfg.sampler.batch_size = 0;
        assert!(cfg.validate().is_err());
        cfg.sampler.batch_size = 1;
        cfg.sampler.fanouts = vec![];
        assert!(cfg.validate().unwrap_err().contains("fanouts"));
        assert!(TrainConfig::default().validate().is_ok());
    }

    #[test]
    fn packed_compute_key_parses_and_requires_quantized_mode() {
        let cfg = TrainConfig::from_toml("[train]\npacked_compute = true\n").unwrap();
        assert!(cfg.packed_compute);
        // Absent key = off; tolerated alongside any quantized mode.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert!(!plain.packed_compute);
        let e2 = TrainConfig::from_toml("[train]\nmode = \"test2\"\npacked_compute = true\n");
        assert!(e2.is_ok());
        // Strict boolean, like the rest of the surface.
        assert!(TrainConfig::from_toml("[train]\npacked_compute = \"yes\"\n").is_err());
        // Packed kernels only exist for quantized operands.
        let e = TrainConfig::from_toml("[train]\nmode = \"fp32\"\npacked_compute = true\n")
            .unwrap_err();
        assert!(e.contains("quantized mode"), "{e}");
        let mut cfg = TrainConfig::default();
        cfg.packed_compute = true;
        assert!(cfg.validate().is_ok());
        cfg.mode = TrainMode::fp32();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn metrics_section_parses() {
        let text = "[train]\nmodel = \"gcn\"\n\n[metrics]\ntrace = false\nout = \"m.json\"\n\
                    trace_out = \"t.json\"\nflight_recorder = 64\n";
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.metrics.trace, Some(false));
        assert_eq!(cfg.metrics.out.as_deref(), Some("m.json"));
        assert_eq!(cfg.metrics.trace_out.as_deref(), Some("t.json"));
        assert_eq!(cfg.metrics.flight_recorder, 64);
        // Absent section = all knobs unset.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert_eq!(plain.metrics, MetricsConfig::default());
        assert!(TrainConfig::from_toml("[metrics]\ntrace = \"loud\"\n").is_err());
        assert!(TrainConfig::from_toml("[metrics]\nflight_recorder = \"lots\"\n").is_err());
    }

    #[test]
    fn ckpt_section_parses_and_validates() {
        let text = "[train]\nmodel = \"gcn\"\n\n[ckpt]\nckpt_every = 50\n\
                    ckpt_path = \"c.json\"\nresume = \"c.json\"\n";
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.ckpt.every, 50);
        assert_eq!(cfg.ckpt.path, "c.json");
        assert_eq!(cfg.ckpt.resume.as_deref(), Some("c.json"));
        // Absent section = checkpointing off, default path, no resume.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert_eq!(plain.ckpt, CkptConfig::default());
        assert_eq!(plain.ckpt.every, 0);
        // Degenerate knobs are rejected with actionable messages.
        let e = TrainConfig::from_toml("[ckpt]\nckpt_every = 5\nckpt_path = \"\"\n").unwrap_err();
        assert!(e.contains("ckpt_path"), "{e}");
        assert!(TrainConfig::from_toml("[ckpt]\nckpt_every = \"often\"\n").is_err());
    }

    #[test]
    fn fault_section_parses_with_empty_and_repeated_schedules() {
        let text = "[fault]\ninject_faults = true\nfault_seed = 99\n\
                    fault_producer_steps = \"5,3,5\"\nfault_worker_steps = \"\"\n\
                    fault_link_steps = \"2\"\nfault_lock_steps = \"1\"\n\
                    fault_max_retries = 1\nfault_backoff_ms = 50\n";
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert!(cfg.fault.inject);
        assert_eq!(cfg.fault.seed, 99);
        // Schedules sort; repeats survive (they exhaust retry budgets).
        assert_eq!(cfg.fault.producer_steps, vec![3, 5, 5]);
        assert_eq!(cfg.fault.worker_steps, Vec::<u64>::new());
        assert_eq!(cfg.fault.link_steps, vec![2]);
        assert_eq!(cfg.fault.lock_steps, vec![1]);
        assert_eq!(cfg.fault.max_retries, 1);
        assert_eq!(cfg.fault.backoff_ms, 50);
        // Absent section = injection fully off.
        let plain = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert_eq!(plain.fault, FaultConfig::default());
        assert!(!plain.fault.inject);
        // Strict boolean + numeric parsing like the rest of the surface.
        assert!(TrainConfig::from_toml("[fault]\ninject_faults = \"yes\"\n").is_err());
        assert!(TrainConfig::from_toml("[fault]\nfault_producer_steps = \"a,b\"\n").is_err());
        assert_eq!(parse_fault_steps("").unwrap(), Vec::<u64>::new());
        assert_eq!(parse_fault_steps(" 7 ,2").unwrap(), vec![2, 7]);
    }

    #[test]
    fn mode_names_roundtrip() {
        for name in ["fp32", "tango", "tango-test1", "tango-test2", "exact"] {
            let m = parse_mode(name, 8).unwrap();
            assert_eq!(mode_name(&m), name);
        }
    }
}
