//! Configuration system: a TOML-subset parser (offline `toml` stand-in) and
//! the typed [`TrainConfig`] the launcher consumes.
//!
//! Supported TOML subset — everything the configs in `configs/` use:
//! `[section]` headers, `key = value` with string/int/float/bool values,
//! `#` comments.

mod toml_lite;

pub use toml_lite::TomlDoc;

use crate::model::TrainMode;

/// Which model architecture to train.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Graph Convolutional Network (GEMM + SPMM).
    Gcn,
    /// Graph Attention Network (GEMM + SPMM + SDDMM).
    Gat,
}

impl std::str::FromStr for ModelKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "gcn" => Ok(ModelKind::Gcn),
            "gat" => Ok(ModelKind::Gat),
            other => Err(format!("unknown model '{other}' (gcn|gat)")),
        }
    }
}

/// Parse a mode name into a [`TrainMode`].
pub fn parse_mode(name: &str, bits: u8) -> Result<TrainMode, String> {
    match name.to_ascii_lowercase().as_str() {
        "fp32" | "dgl" => Ok(TrainMode::fp32()),
        "tango" => Ok(TrainMode::tango(bits)),
        "tango-test1" | "test1" => Ok(TrainMode::tango_test1(bits)),
        "tango-test2" | "test2" => Ok(TrainMode::tango_test2(bits)),
        "exact" => Ok(TrainMode::exact(bits)),
        other => Err(format!("unknown mode '{other}' (fp32|tango|test1|test2|exact)")),
    }
}

/// Mode back to its canonical name.
pub fn mode_name(mode: &TrainMode) -> &'static str {
    if mode.exact_style {
        "exact"
    } else if !mode.quantize {
        "fp32"
    } else if !mode.fp32_pre_softmax {
        "tango-test1"
    } else if !mode.stochastic {
        "tango-test2"
    } else {
        "tango"
    }
}

/// Full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model architecture.
    pub model: ModelKind,
    /// Dataset name (see `graph::datasets::SPECS`) or "tiny".
    pub dataset: String,
    /// Training epochs (full-graph steps).
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// Hidden width.
    pub hidden: usize,
    /// Attention heads (GAT only).
    pub heads: usize,
    /// Layer count.
    pub layers: usize,
    /// Execution mode.
    pub mode: TrainMode,
    /// Auto-derive the bit width with the Fig. 2 rule before training.
    pub auto_bits: bool,
    /// RNG seed (graph, init, rounding streams).
    pub seed: u64,
    /// Log every `log_every` epochs (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        // The paper's §4.1 model config.
        TrainConfig {
            model: ModelKind::Gcn,
            dataset: "Pubmed".into(),
            epochs: 30,
            lr: 0.05,
            hidden: 128,
            heads: 4,
            layers: 2,
            mode: TrainMode::tango(8),
            auto_bits: false,
            seed: 42,
            log_every: 0,
        }
    }
}

impl TrainConfig {
    /// Small config for doc examples and smoke tests.
    pub fn quickstart() -> Self {
        TrainConfig {
            dataset: "tiny".into(),
            hidden: 16,
            epochs: 20,
            ..Default::default()
        }
    }

    /// Load from a TOML file's `[train]` section (all keys optional).
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = TrainConfig::default();
        let get = |k: &str| doc.get("train", k);
        if let Some(v) = get("model") {
            cfg.model = v.parse()?;
        }
        if let Some(v) = get("dataset") {
            cfg.dataset = v.to_string();
        }
        if let Some(v) = get("epochs") {
            cfg.epochs = v.parse().map_err(|e| format!("epochs: {e}"))?;
        }
        if let Some(v) = get("lr") {
            cfg.lr = v.parse().map_err(|e| format!("lr: {e}"))?;
        }
        if let Some(v) = get("hidden") {
            cfg.hidden = v.parse().map_err(|e| format!("hidden: {e}"))?;
        }
        if let Some(v) = get("heads") {
            cfg.heads = v.parse().map_err(|e| format!("heads: {e}"))?;
        }
        if let Some(v) = get("layers") {
            cfg.layers = v.parse().map_err(|e| format!("layers: {e}"))?;
        }
        if let Some(v) = get("seed") {
            cfg.seed = v.parse().map_err(|e| format!("seed: {e}"))?;
        }
        if let Some(v) = get("log_every") {
            cfg.log_every = v.parse().map_err(|e| format!("log_every: {e}"))?;
        }
        let bits: u8 = match get("bits") {
            Some(v) => v.parse().map_err(|e| format!("bits: {e}"))?,
            None => 8,
        };
        if let Some(v) = get("mode") {
            cfg.mode = parse_mode(v, bits)?;
        } else {
            cfg.mode = TrainMode::tango(bits);
        }
        if let Some(v) = get("auto_bits") {
            cfg.auto_bits = v == "true";
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let text = r#"
# paper §4.1 GAT config
[train]
model = "gat"
dataset = "ogbn-arxiv"
epochs = 500
lr = 0.01
hidden = 128
heads = 4
layers = 2
mode = "tango"
bits = 8
seed = 7
auto_bits = true
"#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.model, ModelKind::Gat);
        assert_eq!(cfg.dataset, "ogbn-arxiv");
        assert_eq!(cfg.epochs, 500);
        assert_eq!(cfg.heads, 4);
        assert!(cfg.auto_bits);
        assert_eq!(mode_name(&cfg.mode), "tango");
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let cfg = TrainConfig::from_toml("[train]\nmodel = \"gcn\"\n").unwrap();
        assert_eq!(cfg.model, ModelKind::Gcn);
        assert_eq!(cfg.epochs, 30);
        assert_eq!(mode_name(&cfg.mode), "tango");
    }

    #[test]
    fn rejects_unknown_model_and_mode() {
        assert!(TrainConfig::from_toml("[train]\nmodel = \"transformer\"\n").is_err());
        assert!(TrainConfig::from_toml("[train]\nmode = \"int2\"\n").is_err());
    }

    #[test]
    fn mode_names_roundtrip() {
        for name in ["fp32", "tango", "tango-test1", "tango-test2", "exact"] {
            let m = parse_mode(name, 8).unwrap();
            assert_eq!(mode_name(&m), name);
        }
    }
}
