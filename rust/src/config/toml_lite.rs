//! Minimal TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values keep their raw text (quotes stripped for strings); typed access
//! happens at the config layer via `parse()`.

use std::collections::BTreeMap;

/// A parsed TOML-subset document.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl TomlDoc {
    /// Parse a document. Errors carry the line number.
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected 'key = value'", lineno + 1))?;
            let key = k.trim().to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = unquote(v.trim()).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    /// Look up `section.key` (empty string = top-level keys).
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// All keys of a section.
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections.get(section).map(|m| m.keys().map(|k| k.as_str()).collect()).unwrap_or_default()
    }

    /// Section names.
    pub fn sections(&self) -> Vec<&str> {
        self.sections.keys().map(|k| k.as_str()).collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> Result<String, String> {
    if let Some(inner) = v.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        Ok(inner.to_string())
    } else if v.is_empty() {
        Err("empty value".into())
    } else {
        Ok(v.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse("top = 1\n[a]\nx = \"hi\" # comment\ny = 2.5\n[b]\nz = true\n").unwrap();
        assert_eq!(doc.get("", "top"), Some("1"));
        assert_eq!(doc.get("a", "x"), Some("hi"));
        assert_eq!(doc.get("a", "y"), Some("2.5"));
        assert_eq!(doc.get("b", "z"), Some("true"));
        assert_eq!(doc.get("a", "missing"), None);
        assert_eq!(doc.sections(), vec!["", "a", "b"]);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse("[s]\nname = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("s", "name"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("[s]\noops\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = TomlDoc::parse("[s]\nx = \"unterminated\n").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_and_comment_lines_skipped() {
        let doc = TomlDoc::parse("\n# full comment\n[s]\n\nk = v\n").unwrap();
        assert_eq!(doc.get("s", "k"), Some("v"));
        assert_eq!(doc.keys("s"), vec!["k"]);
    }
}
