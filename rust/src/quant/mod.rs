//! Quantization substrate (paper §2.3 and §3.2).
//!
//! Tango uses **symmetric, tensor-level-granularity, dynamic** quantization:
//!
//! - *symmetric*: the clipping range is `[-absmax, +absmax]`, so the zero
//!   point `Z` is 0 and (de)quantization is a single multiply;
//! - *tensor-level*: one scaling factor `s` per tensor (one reduction, and
//!   the scale algebra `s0·s1` composes across quantized multiplies);
//! - *dynamic*: `s` is recomputed every iteration from the live values.
//!
//! The module carries the paper's accuracy machinery:
//!
//! - [`rng::Xoshiro256pp`] — the xoshiro256++ PRNG the paper uses for its
//!   GPU stochastic rounding (state in registers; the "cuRAND-like"
//!   memory-state variant [`rng::MemoryStateRng`] exists for the §3.2
//!   comparison bench);
//! - [`Rounding`] — nearest vs stochastic rounding (Eq. 3);
//! - [`quantize`] / [`QTensor`] — symmetric quantize/dequantize (Eq. 1/2);
//! - [`error_x`] — the relative quantization-error metric (Eq. 4);
//! - [`derive_bits`] — the lightweight bit-derivation rule (Fig. 2);
//! - [`pack`] — LSB-first sub-byte bit-packing, the physical layout behind
//!   `QuantRows` and the packed kernels in [`crate::primitives`].

mod bits;
mod error;
pub mod pack;
pub mod rng;
mod scheme;

pub use bits::{derive_bits, BitDerivation, DEFAULT_ERROR_TARGET};
pub use error::{error_x, error_x_quantized, error_x_slice, EPSILON};
pub use pack::{pack_row, pack_row_into, packed_len, unpack_row, unpack_row_into};
pub use scheme::{
    dequantize, packed_bits_per_elem, qmax_for_bits, quantize, quantize_slice_nearest,
    quantize_with_scale, scale_for_bits, QTensor, Rounding,
};
