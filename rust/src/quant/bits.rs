//! Lightweight bit-derivation rule (paper §3.2, Fig. 2).
//!
//! Instead of training to convergence per candidate bit width, Tango
//! quantizes the *first layer's output tensor in the first epoch* and picks
//! the smallest bit count whose [`crate::quant::error_x`] stays under a
//! dataset-independent threshold (0.3 in the paper, Fig. 2a). The rule is a
//! lower bound: training can often recover from slightly lower bit counts.

use crate::quant::error::error_x_quantized;
use crate::quant::scheme::{quantize, Rounding};
use crate::tensor::Dense;

/// The paper's universal `Error_X` threshold (Fig. 2a).
pub const DEFAULT_ERROR_TARGET: f32 = 0.3;

/// Result of the bit-derivation sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct BitDerivation {
    /// Smallest bit width meeting the target (8 if none smaller qualifies).
    pub bits: u8,
    /// `(bits, Error_X)` for every candidate evaluated — Fig. 2b's series.
    pub sweep: Vec<(u8, f32)>,
    /// The threshold used.
    pub target: f32,
}

/// Derive the number of quantization bits for a representative activation
/// tensor (the first layer's output in the first epoch).
///
/// Sweeps `B ∈ {2..=8}` with nearest rounding (the error metric measures the
/// grid, not the rounding noise) and returns the smallest `B` with
/// `Error_X ≤ target`, defaulting to 8 bits when even 8 misses the target —
/// 8 is the widest width the INT8 compute path supports, and the paper
/// observes training absorbs residual error.
pub fn derive_bits(first_layer_out: &Dense<f32>, target: f32) -> BitDerivation {
    let mut sweep = Vec::new();
    let mut chosen: Option<u8> = None;
    for bits in 2u8..=8 {
        let q = quantize(first_layer_out, bits, Rounding::Nearest);
        let e = error_x_quantized(first_layer_out, &q);
        sweep.push((bits, e));
        if chosen.is_none() && e <= target {
            chosen = Some(bits);
        }
    }
    BitDerivation { bits: chosen.unwrap_or(8), sweep, target }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smooth_tensor(n: usize) -> Dense<f32> {
        // A well-spread activation-like tensor: low relative error at 8 bits.
        Dense::from_vec(&[n], (0..n).map(|i| (i as f32 * 0.7).sin() + 1.5).collect())
    }

    #[test]
    fn smooth_tensor_needs_few_bits() {
        let d = derive_bits(&smooth_tensor(4096), DEFAULT_ERROR_TARGET);
        assert!(d.bits <= 8);
        assert_eq!(d.sweep.len(), 7);
        // The sweep must cover 2..=8 in order.
        assert_eq!(d.sweep.first().unwrap().0, 2);
        assert_eq!(d.sweep.last().unwrap().0, 8);
    }

    #[test]
    fn tighter_target_needs_at_least_as_many_bits() {
        let x = smooth_tensor(4096);
        let loose = derive_bits(&x, 0.5);
        let tight = derive_bits(&x, 0.05);
        assert!(tight.bits >= loose.bits, "{} vs {}", tight.bits, loose.bits);
    }

    #[test]
    fn chosen_bits_meet_target() {
        let x = smooth_tensor(4096);
        let d = derive_bits(&x, DEFAULT_ERROR_TARGET);
        let e = d.sweep.iter().find(|(b, _)| *b == d.bits).unwrap().1;
        // Either the target is met, or we clamped to the 8-bit maximum.
        assert!(e <= d.target || d.bits == 8);
    }

    #[test]
    fn sweep_errors_decrease_with_bits() {
        let x = smooth_tensor(4096);
        let d = derive_bits(&x, DEFAULT_ERROR_TARGET);
        for w in d.sweep.windows(2) {
            assert!(w[0].1 >= w[1].1 - 1e-4, "sweep not monotone: {:?}", d.sweep);
        }
    }

    #[test]
    fn pathological_tensor_clamps_to_8() {
        // Huge dynamic range: relative error stays high at every width.
        let mut v = vec![1e-6f32; 1024];
        v[0] = 1e6;
        let d = derive_bits(&Dense::from_vec(&[1024], v), 0.001);
        assert_eq!(d.bits, 8);
    }
}
