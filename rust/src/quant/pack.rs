//! Bit-packing for sub-byte quantized rows (paper §3.3 / QGTC direction).
//!
//! A row of `B`-bit quantized values packs at
//! [`packed_bits_per_elem`]`(B)` physical bits per element into an
//! LSB-first bitstream: element `i` occupies bits `[i*w, (i+1)*w)` of the
//! row's byte buffer, where `w = packed_bits_per_elem(B)`. Fields are
//! two's-complement at width `w`, so unpacking is a shift + sign-extend.
//! Each row is padded to a whole byte, which makes the packed length equal
//! the nominal accounting every byte-counting site already charges
//! ([`packed_len`] == the old "nominal" `packed_row_bytes`).
//!
//! Width specifics:
//!
//! - **8-bit** rows are a raw `i8 → u8` byte copy (the fast case);
//! - **4-bit** rows pack two values per byte (nibble pairs) and unpack
//!   through a 256-entry byte → two-lane LUT;
//! - **1/2-bit** rows pack four values per byte (crumbs; the 1-bit ternary
//!   grid `{-1, 0, +1}` needs two physical bits — see
//!   [`qmax_for_bits`](super::qmax_for_bits)) and unpack through a
//!   byte → four-lane LUT;
//! - **3/5/6/7-bit** rows use the generic bit-cursor path.
//!
//! Round-trip bit-identity at every width 1..=8 is pinned by the unit
//! tests here and the property tests in `tests/packed_kernels.rs`.

use super::packed_bits_per_elem;

/// Bytes `n` elements occupy packed at nominal width `bits` (row padded to
/// a whole byte). This is the same arithmetic the gather/all-reduce byte
/// accounting has always charged — packing makes it the real allocation.
#[inline]
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * packed_bits_per_elem(bits)).div_ceil(8)
}

/// Sign-extend the low `w` bits of `raw` (a two's-complement field).
#[inline(always)]
fn sign_extend(raw: u8, w: u32) -> i8 {
    ((raw << (8 - w)) as i8) >> (8 - w)
}

/// Byte → four 2-bit lanes (crumbs), sign-extended. Serves both the 2-bit
/// grid and the 1-bit ternary grid (which stores `{-1, 0, +1}` as crumbs).
pub(crate) const CRUMB_LUT: [[i8; 4]; 256] = build_crumb_lut();

/// Byte → two 4-bit lanes (nibbles), sign-extended.
pub(crate) const NIBBLE_LUT: [[i8; 2]; 256] = build_nibble_lut();

const fn build_crumb_lut() -> [[i8; 4]; 256] {
    let mut t = [[0i8; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut lane = 0usize;
        while lane < 4 {
            let raw = ((b >> (2 * lane)) & 0b11) as u8;
            t[b][lane] = ((raw << 6) as i8) >> 6;
            lane += 1;
        }
        b += 1;
    }
    t
}

const fn build_nibble_lut() -> [[i8; 2]; 256] {
    let mut t = [[0i8; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut lane = 0usize;
        while lane < 2 {
            let raw = ((b >> (4 * lane)) & 0b1111) as u8;
            t[b][lane] = ((raw << 4) as i8) >> 4;
            lane += 1;
        }
        b += 1;
    }
    t
}

/// Pack a row of quantized values into `out` (must hold exactly
/// [`packed_len`]`(values.len(), bits)` bytes, pre-zeroed). Values must lie
/// on the `bits`-bit grid (`|v| <= qmax_for_bits(bits)`), which every
/// quantizer in the crate guarantees.
pub fn pack_row_into(values: &[i8], bits: u8, out: &mut [u8]) {
    let w = packed_bits_per_elem(bits) as u32;
    debug_assert_eq!(out.len(), packed_len(values.len(), bits));
    if w == 8 {
        for (o, &v) in out.iter_mut().zip(values) {
            *o = v as u8;
        }
        return;
    }
    let mask = (1u16 << w) - 1;
    let mut cursor = 0usize; // bit offset into `out`
    for &v in values {
        let field = (v as u8 as u16) & mask;
        let byte = cursor / 8;
        let shift = (cursor % 8) as u16;
        out[byte] |= (field << shift) as u8;
        let spill = shift + w as u16;
        if spill > 8 {
            out[byte + 1] |= (field >> (8 - shift)) as u8;
        }
        cursor += w as usize;
    }
}

/// Pack a row of quantized values at nominal width `bits` into a fresh
/// buffer of [`packed_len`]`(values.len(), bits)` bytes.
pub fn pack_row(values: &[i8], bits: u8) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(values.len(), bits)];
    pack_row_into(values, bits, &mut out);
    out
}

/// Unpack a packed row back to one i8 per element. `out.len()` is the
/// element count; `packed` must hold [`packed_len`]`(out.len(), bits)`
/// bytes. Exact inverse of [`pack_row_into`] for on-grid values.
pub fn unpack_row_into(packed: &[u8], bits: u8, out: &mut [i8]) {
    let w = packed_bits_per_elem(bits) as u32;
    debug_assert_eq!(packed.len(), packed_len(out.len(), bits));
    match w {
        8 => {
            for (o, &b) in out.iter_mut().zip(packed) {
                *o = b as i8;
            }
        }
        4 => {
            let mut chunks = out.chunks_exact_mut(2);
            for (pair, &b) in (&mut chunks).zip(packed) {
                pair.copy_from_slice(&NIBBLE_LUT[b as usize]);
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                rem[0] = NIBBLE_LUT[packed[packed.len() - 1] as usize][0];
            }
        }
        2 => {
            let mut chunks = out.chunks_exact_mut(4);
            for (quad, &b) in (&mut chunks).zip(packed) {
                quad.copy_from_slice(&CRUMB_LUT[b as usize]);
            }
            let rem = chunks.into_remainder();
            if !rem.is_empty() {
                let lanes = &CRUMB_LUT[packed[packed.len() - 1] as usize];
                rem.copy_from_slice(&lanes[..rem.len()]);
            }
        }
        _ => {
            let mask = (1u16 << w) - 1;
            let mut cursor = 0usize;
            for o in out.iter_mut() {
                let byte = cursor / 8;
                let shift = (cursor % 8) as u16;
                let mut field = (packed[byte] as u16) >> shift;
                if shift + w as u16 > 8 {
                    field |= (packed[byte + 1] as u16) << (8 - shift);
                }
                *o = sign_extend((field & mask) as u8, w);
                cursor += w as usize;
            }
        }
    }
}

/// Unpack a packed row of `n` elements into a fresh i8 vector.
pub fn unpack_row(packed: &[u8], bits: u8, n: usize) -> Vec<i8> {
    let mut out = vec![0i8; n];
    unpack_row_into(packed, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qmax_for_bits;

    /// Every on-grid value at every width round-trips bit-identically.
    #[test]
    fn roundtrip_exhaustive_per_width() {
        for bits in 1..=8u8 {
            let qmax = qmax_for_bits(bits) as i8;
            // All grid values, plus repeats to exercise odd row lengths.
            let mut values: Vec<i8> = (-qmax..=qmax).collect();
            values.extend_from_slice(&[0, qmax, -qmax, 1, -1]);
            for take in [1usize, 2, 3, 4, 5, 7, 8, values.len()] {
                let row = &values[..take.min(values.len())];
                let packed = pack_row(row, bits);
                assert_eq!(packed.len(), packed_len(row.len(), bits), "bits {bits}");
                let back = unpack_row(&packed, bits, row.len());
                assert_eq!(back.as_slice(), row, "bits {bits} len {}", row.len());
            }
        }
    }

    /// The packed length is the nominal accounting every byte-counting
    /// site charges: `ceil(n * packed_bits_per_elem / 8)`.
    #[test]
    fn packed_len_matches_nominal_accounting() {
        assert_eq!(packed_len(16, 8), 16);
        assert_eq!(packed_len(16, 4), 8);
        assert_eq!(packed_len(16, 2), 4);
        assert_eq!(packed_len(16, 1), 4); // ternary charges 2 bits/elem
        assert_eq!(packed_len(12, 1), 3); // no per-plane padding
        assert_eq!(packed_len(5, 3), 2);
        assert_eq!(packed_len(5, 6), 4);
        assert_eq!(packed_len(0, 4), 0);
    }

    #[test]
    fn luts_sign_extend() {
        // 0b11 crumb = -1, 0b01 = +1, 0b00 = 0.
        assert_eq!(CRUMB_LUT[0b11_00_01_11], [-1, 1, 0, -1]);
        // 0b1111 nibble = -1, 0b0111 = 7.
        assert_eq!(NIBBLE_LUT[0b0111_1111], [-1, 7]);
        assert_eq!(NIBBLE_LUT[0b1001_0110], [6, -7]);
    }

    #[test]
    fn eight_bit_rows_are_raw_bytes() {
        let row: Vec<i8> = vec![-128, -1, 0, 1, 127];
        let packed = pack_row(&row, 8);
        assert_eq!(packed, row.iter().map(|&v| v as u8).collect::<Vec<_>>());
    }
}
