//! Pseudo-random number generation for stochastic rounding.
//!
//! The paper implements a GPU stochastic-rounding PRNG on top of
//! **xoshiro256++** [Blackman & Vigna 2021] and reports ~20× over cuRAND,
//! attributing the win to keeping generator state in *registers* instead of
//! global memory (cuRAND round-trips its state through global memory on
//! every draw).
//!
//! We reproduce both designs on the CPU substrate:
//!
//! - [`Xoshiro256pp`]: state lives in the struct; with the generator kept in
//!   a local, the optimizer keeps the four u64 words in registers across the
//!   quantization loop — the paper's "register-resident state".
//! - [`MemoryStateRng`]: the same xoshiro core, but the state is forced
//!   through a heap slab with `read_volatile`/`write_volatile` on every
//!   draw — the cuRAND-shaped baseline for `benches/quantize.rs`.

/// splitmix64, the recommended seeder for xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fold seed components (run seed, epoch, worker id, batch index, …) into
/// one well-mixed stream seed by chaining [`splitmix64`].
///
/// This is the one mixer every seeded subsystem shares. Ad-hoc xor/shift
/// mixing such as `seed ^ (epoch << 8) ^ worker` collides as soon as a
/// component outgrows its shift window (`(epoch, worker)` and
/// `(epoch - 1, worker + 256)` name the same stream) and leaves most output
/// bits correlated across epochs; chaining each component through the
/// splitmix64 finalizer avalanches every input bit into every output bit.
pub fn mix_seeds(parts: &[u64]) -> u64 {
    let mut acc = 0xA076_1D64_78BD_642F; // arbitrary odd salt
    for &p in parts {
        let mut s = acc ^ p;
        acc = splitmix64(&mut s);
    }
    acc
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// xoshiro256++ with struct-resident ("register") state.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via splitmix64 so that any u64 seed (including 0) yields a
    /// well-mixed non-zero state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Xoshiro256pp {
            s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)],
        }
    }

    /// Next 64 random bits.
    #[inline(always)]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f32 in `[0, 1)` from the top 24 bits.
    #[inline(always)]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// The `jump()` function: advances the stream by 2^128 draws, giving
    /// independent sub-streams for parallel workers.
    pub fn jump(&mut self) -> Xoshiro256pp {
        const JUMP: [u64; 4] =
            [0x180EC6D33CFD0ABA, 0xD5A61266F0C9392C, 0xA9582618E03FC9AA, 0x39ABDC4529B1661C];
        let stream = self.clone();
        let mut s = [0u64; 4];
        for &j in JUMP.iter() {
            for b in 0..64 {
                if (j >> b) & 1 == 1 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
        stream
    }
}

/// The cuRAND-shaped baseline: identical xoshiro256++ core, but generator
/// state is loaded from and stored back to a heap slab around *every* draw,
/// exactly the extra memory traffic cuRAND pays for keeping `curandState`
/// in global memory.
pub struct MemoryStateRng {
    slab: Box<[u64; 4]>,
}

impl MemoryStateRng {
    /// Seed identically to [`Xoshiro256pp`] so the two produce the same
    /// stream (verified in tests) and differ only in state residency.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        MemoryStateRng {
            slab: Box::new([
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ]),
        }
    }

    /// Next 64 random bits, with the state round-tripped through memory.
    #[inline(never)]
    pub fn next_u64(&mut self) -> u64 {
        // Volatile load: the "global memory read" of curandState.
        let ptr = self.slab.as_mut_ptr();
        let mut s = unsafe { std::ptr::read_volatile(ptr as *const [u64; 4]) };
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        // Volatile store: the write-back.
        unsafe { std::ptr::write_volatile(ptr as *mut [u64; 4], s) };
        result
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn memory_state_matches_register_state_stream() {
        let mut fast = Xoshiro256pp::new(7);
        let mut slow = MemoryStateRng::new(7);
        for _ in 0..1000 {
            assert_eq!(fast.next_u64(), slow.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Xoshiro256pp::new(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn f32_mean_near_half() {
        let mut r = Xoshiro256pp::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn jump_streams_do_not_collide_immediately() {
        let mut base = Xoshiro256pp::new(11);
        let mut s1 = base.jump();
        let mut s2 = base.jump();
        let a: Vec<u64> = (0..16).map(|_| s1.next_u64()).collect();
        let b: Vec<u64> = (0..16).map(|_| s2.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn splitmix_nonzero_state_for_zero_seed() {
        let r = Xoshiro256pp::new(0);
        assert!(r.s.iter().any(|&w| w != 0));
    }

    #[test]
    fn mix_seeds_is_deterministic_and_order_sensitive() {
        assert_eq!(mix_seeds(&[1, 2, 3]), mix_seeds(&[1, 2, 3]));
        assert_ne!(mix_seeds(&[1, 2, 3]), mix_seeds(&[3, 2, 1]));
        assert_ne!(mix_seeds(&[0]), mix_seeds(&[0, 0]));
    }

    #[test]
    fn mix_seeds_avoids_shift_window_collisions() {
        // The bug class this replaces: `seed ^ (epoch << 8) ^ worker`
        // collides for worker ids >= 256.
        let old = |seed: u64, epoch: u64, w: u64| seed ^ (epoch << 8) ^ w;
        assert_eq!(old(42, 1, 0), old(42, 0, 256));
        assert_ne!(mix_seeds(&[42, 1, 0]), mix_seeds(&[42, 0, 256]));
        let mut seen = std::collections::HashSet::new();
        for epoch in 0..8u64 {
            for w in 0..512u64 {
                assert!(seen.insert(mix_seeds(&[42, epoch, w])), "collision at ({epoch},{w})");
            }
        }
    }
}
