//! Symmetric tensor-level dynamic quantization (paper Eq. 1/2) with nearest
//! or stochastic rounding (Eq. 3).

use crate::quant::rng::Xoshiro256pp;
use crate::tensor::Dense;

/// Rounding mode for [`quantize`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// Round-to-nearest — the paper's "Test2" ablation; biased, and shown in
    /// Fig. 7 to destabilise training on several datasets.
    Nearest,
    /// Stochastic rounding (Eq. 3): `floor(x)+1` with probability
    /// `x - floor(x)`, else `floor(x)`. Unbiased: `E[q(x)] = x`.
    /// Seeded per-call so training is reproducible.
    Stochastic { seed: u64 },
}

/// A symmetric tensor-level quantized tensor.
///
/// Values live in `[-qmax, qmax]` with `qmax = 2^(bits-1) - 1` and
/// dequantize as `x ≈ scale * q` (zero point is 0 by symmetry, paper §2.3).
/// Sub-byte widths (INT4) are value-range-restricted but stored one per i8
/// slot; the perf model charges the packed size.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    /// Quantized payload.
    pub data: Dense<i8>,
    /// Scaling factor `s = absmax / qmax`.
    pub scale: f32,
    /// Bit width `B` (1..=8 on the CPU substrate; 1 = sign grid).
    pub bits: u8,
}

impl QTensor {
    /// Largest representable quantized magnitude for this bit width.
    pub fn qmax(&self) -> i32 {
        qmax_for_bits(self.bits)
    }

    /// Shape of the payload.
    pub fn shape(&self) -> &[usize] {
        self.data.shape()
    }

    /// Payload bytes as stored on the CPU substrate (1 byte/element).
    pub fn stored_bytes(&self) -> usize {
        self.data.len()
    }

    /// Payload bytes if packed at the nominal bit width (what a GPU/TPU
    /// kernel would actually move; used by `perfmodel`). 1-bit tensors
    /// charge two bits per element — their grid has three states.
    pub fn packed_bytes(&self) -> usize {
        (self.data.len() * packed_bits_per_elem(self.bits)).div_ceil(8)
    }

    /// 2-D transpose of the quantized payload (scale is layout-invariant).
    /// Lets cached quantized tensors feed the transposed backward GEMMs
    /// (`∂W = Hᵀ·∂H'`) without requantization.
    pub fn transpose2d(&self) -> QTensor {
        QTensor { data: self.data.transpose2d(), scale: self.scale, bits: self.bits }
    }
}

/// `2^(B-1) - 1`, the symmetric clip for `B`-bit signed quantization.
///
/// `B = 1` is the degenerate ternary grid: its nominal `2^0 - 1 = 0` clip
/// would collapse every value, so it clips at 1 (`{-1, 0, +1}` — the
/// policy subsystem's hardest cold-tail compression). Because that grid
/// has three states, packed accounting charges it two physical bits per
/// element ([`packed_bits_per_elem`]) — byte counts never claim
/// compression no kernel could realize.
#[inline]
pub fn qmax_for_bits(bits: u8) -> i32 {
    assert!((1..=8).contains(&bits), "bit width {bits} unsupported (1..=8)");
    ((1i32 << (bits - 1)) - 1).max(1)
}

/// Physical bits one element occupies when packed at nominal width
/// `bits`: the width itself, except the 1-bit ternary grid (`{-1, 0, +1}`,
/// see [`qmax_for_bits`]) which needs two bits. Every packed-byte
/// accounting site (gather traffic, all-reduce payloads, [`QTensor`])
/// shares this rule.
#[inline]
pub fn packed_bits_per_elem(bits: u8) -> usize {
    (bits as usize).max(2)
}

/// Dynamic symmetric scale for a tensor: `s = absmax / qmax`.
///
/// Returns a scale that maps the tensor's live range onto the `B`-bit grid;
/// an all-zero tensor gets scale 1.0 so dequantization stays exact.
pub fn scale_for_bits(x: &Dense<f32>, bits: u8) -> f32 {
    let absmax = x.abs_max();
    if absmax == 0.0 {
        1.0
    } else {
        absmax / qmax_for_bits(bits) as f32
    }
}

#[inline(always)]
fn round_stochastic(x: f32, rng: &mut Xoshiro256pp) -> f32 {
    let f = x.floor();
    if rng.next_f32() < x - f {
        f + 1.0
    } else {
        f
    }
}

/// Quantize a flat slice under a fixed scale with nearest rounding — the
/// slice-level core of [`quantize_with_scale`]'s `Nearest` arm. The
/// sampler's feature store quantizes cached rows through this same
/// function, so cached rows can never drift from direct quantization.
pub fn quantize_slice_nearest(values: &[f32], scale: f32, bits: u8) -> Vec<i8> {
    let qmax = qmax_for_bits(bits) as f32;
    let inv = 1.0 / scale;
    values.iter().map(|&v| (v * inv).round().clamp(-qmax, qmax) as i8).collect()
}

/// Quantize with a caller-provided scale (the on-the-fly path, where the
/// scale came fused out of a previous primitive).
pub fn quantize_with_scale(x: &Dense<f32>, scale: f32, bits: u8, rounding: Rounding) -> QTensor {
    let qmax = qmax_for_bits(bits) as f32;
    let inv = 1.0 / scale;
    let data = match rounding {
        Rounding::Nearest => {
            Dense::from_vec(x.shape(), quantize_slice_nearest(x.data(), scale, bits))
        }
        Rounding::Stochastic { seed } => {
            let mut rng = Xoshiro256pp::new(seed);
            let mut out = Vec::with_capacity(x.len());
            for &v in x.data() {
                let q = round_stochastic(v * inv, &mut rng).clamp(-qmax, qmax);
                out.push(q as i8);
            }
            Dense::from_vec(x.shape(), out)
        }
    };
    QTensor { data, scale, bits }
}

/// Dynamic symmetric quantization (Eq. 1 with `Z = 0`): one abs-max
/// reduction to derive `s`, then one elementwise pass to round.
pub fn quantize(x: &Dense<f32>, bits: u8, rounding: Rounding) -> QTensor {
    let scale = scale_for_bits(x, bits);
    quantize_with_scale(x, scale, bits, rounding)
}

/// Dequantize (Eq. 2 with `Z = 0`): `x ≈ s * q`.
pub fn dequantize(q: &QTensor) -> Dense<f32> {
    let s = q.scale;
    q.data.map(|v| v as f32 * s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linspace(n: usize, lo: f32, hi: f32) -> Dense<f32> {
        let step = (hi - lo) / (n as f32 - 1.0);
        Dense::from_vec(&[n], (0..n).map(|i| lo + i as f32 * step).collect())
    }

    #[test]
    fn qmax_values() {
        assert_eq!(qmax_for_bits(8), 127);
        assert_eq!(qmax_for_bits(4), 7);
        assert_eq!(qmax_for_bits(2), 1);
        // The ternary grid: 1-bit clips at 1, never 0 (scale division) —
        // and packs at two physical bits (three states don't fit in one).
        assert_eq!(qmax_for_bits(1), 1);
        assert_eq!(packed_bits_per_elem(1), 2);
        assert_eq!(packed_bits_per_elem(2), 2);
        assert_eq!(packed_bits_per_elem(8), 8);
        let x = Dense::from_vec(&[8], vec![1.0f32; 8]);
        assert_eq!(quantize(&x, 1, Rounding::Nearest).packed_bytes(), 2);
    }

    #[test]
    #[should_panic]
    fn bits_over_8_unsupported() {
        let _ = qmax_for_bits(9);
    }

    #[test]
    fn roundtrip_error_bounded_nearest() {
        // |x - deq(q(x))| <= s/2 for nearest rounding.
        let x = linspace(1001, -3.0, 5.0);
        let q = quantize(&x, 8, Rounding::Nearest);
        let y = dequantize(&q);
        let bound = q.scale / 2.0 + 1e-6;
        for (a, b) in x.data().iter().zip(y.data().iter()) {
            assert!((a - b).abs() <= bound, "{a} vs {b}, bound {bound}");
        }
    }

    #[test]
    fn roundtrip_error_bounded_stochastic() {
        // |x - deq(q(x))| <= s (one full grid step) for stochastic rounding.
        let x = linspace(1001, -3.0, 5.0);
        let q = quantize(&x, 8, Rounding::Stochastic { seed: 5 });
        let y = dequantize(&q);
        let bound = q.scale + 1e-6;
        for (a, b) in x.data().iter().zip(y.data().iter()) {
            assert!((a - b).abs() <= bound, "{a} vs {b}, bound {bound}");
        }
    }

    #[test]
    fn stochastic_rounding_is_unbiased() {
        // E[q(x)] = x: quantize the same value many times with different
        // seeds; the mean dequantized value must approach the true value.
        let v = 0.3712f32;
        let x = Dense::from_vec(&[1], vec![v]);
        let scale = 0.01f32;
        let n = 20_000;
        let mut acc = 0.0f64;
        for seed in 0..n {
            let q = quantize_with_scale(&x, scale, 8, Rounding::Stochastic { seed });
            acc += dequantize(&q).data()[0] as f64;
        }
        let mean = acc / n as f64;
        assert!(
            (mean - v as f64).abs() < 3e-4,
            "stochastic rounding biased: mean={mean} true={v}"
        );
    }

    #[test]
    fn nearest_rounding_is_biased_on_fractions() {
        // The motivating failure: round-to-nearest of 0.3*s always lands on
        // 0, losing the value entirely — stochastic keeps it in expectation.
        let x = Dense::from_vec(&[1], vec![0.003f32]);
        let q = quantize_with_scale(&x, 0.01, 8, Rounding::Nearest);
        assert_eq!(q.data.data()[0], 0);
    }

    #[test]
    fn symmetric_zero_point_preserves_zero() {
        let x = Dense::from_vec(&[3], vec![-1.0f32, 0.0, 1.0]);
        for rounding in [Rounding::Nearest, Rounding::Stochastic { seed: 1 }] {
            let q = quantize(&x, 8, rounding);
            assert_eq!(q.data.data()[1], 0, "zero must quantize to 0 (Z=0)");
        }
    }

    #[test]
    fn scale_uses_full_range() {
        let x = Dense::from_vec(&[2], vec![-2.0f32, 1.0]);
        let q = quantize(&x, 8, Rounding::Nearest);
        // absmax = 2 -> scale = 2/127; -2 should hit -127 exactly.
        assert_eq!(q.data.data()[0], -127);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-7);
    }

    #[test]
    fn all_zero_tensor_scale_is_one() {
        let x: Dense<f32> = Dense::zeros(&[16]);
        let q = quantize(&x, 8, Rounding::Nearest);
        assert_eq!(q.scale, 1.0);
        assert!(dequantize(&q).data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int4_range_respected() {
        let x = linspace(100, -1.0, 1.0);
        let q = quantize(&x, 4, Rounding::Nearest);
        assert!(q.data.data().iter().all(|&v| (-7..=7).contains(&(v as i32))));
        assert_eq!(q.packed_bytes(), 50);
        assert_eq!(q.stored_bytes(), 100);
    }

    #[test]
    fn deterministic_given_seed() {
        let x = linspace(64, -1.0, 1.0);
        let a = quantize(&x, 8, Rounding::Stochastic { seed: 77 });
        let b = quantize(&x, 8, Rounding::Stochastic { seed: 77 });
        assert_eq!(a, b);
    }
}
