//! The relative quantization-error metric `Error_X` (paper Eq. 4).
//!
//! ```text
//! Error_X = (1/N) * Σ | (X_i - X_i,Quant) / (X_i + X_i,Quant + ε) |
//! ```
//!
//! where `X_i,Quant` is the *dequantized* grid value `X_i` rounds to. The
//! metric is relative, hence comparable across tensors; its range is [0, 1]
//! per element. Tango evaluates it once — on the output tensor of the first
//! GNN layer in the first epoch — and picks the smallest bit count with
//! `Error_X ≤ 0.3` (see [`crate::quant::derive_bits`]).

use crate::quant::scheme::{dequantize, QTensor};
use crate::tensor::Dense;

/// The paper's ε (chosen as 0.0005) guarding the `X_i = X_i,Quant = 0` case.
pub const EPSILON: f32 = 0.0005;

/// `Error_X` between a full-precision tensor and its dequantized counterpart.
///
/// Panics if shapes differ.
pub fn error_x(x: &Dense<f32>, x_deq: &Dense<f32>) -> f32 {
    assert_eq!(x.shape(), x_deq.shape(), "Error_X needs same-shaped tensors");
    if x.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (&a, &b) in x.data().iter().zip(x_deq.data().iter()) {
        acc += ((a - b) / (a + b + EPSILON)).abs() as f64;
    }
    (acc / x.len() as f64) as f32
}

/// Convenience: `Error_X` of a tensor against an already-quantized version.
pub fn error_x_quantized(x: &Dense<f32>, q: &QTensor) -> f32 {
    error_x(x, &dequantize(q))
}

/// `Error_X` of one feature slice against its quantized row at `scale`
/// (dequantizing as `q_i * scale` on the fly — no staging copy). This is
/// the per-row form the quantized feature gather measures per degree bucket
/// while tracing (see [`crate::obs`]).
///
/// Panics if lengths differ.
pub fn error_x_slice(x: &[f32], q: &[i8], scale: f32) -> f32 {
    assert_eq!(x.len(), q.len(), "Error_X needs same-length slices");
    if x.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0f64;
    for (&a, &qv) in x.iter().zip(q.iter()) {
        let b = qv as f32 * scale;
        acc += ((a - b) / (a + b + EPSILON)).abs() as f64;
    }
    (acc / x.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scheme::{quantize, Rounding};

    #[test]
    fn near_zero_error_for_well_represented_tensor() {
        // Values on (or within half a step of) the 8-bit grid: Error_X must
        // be tiny. ±2 hits ±127 exactly; ±1 lands within half a grid step.
        let x = Dense::from_vec(&[4], vec![-2.0f32, -1.0, 1.0, 2.0]);
        let q = quantize(&x, 8, Rounding::Nearest);
        let e = error_x_quantized(&x, &q);
        assert!(e < 5e-3, "e={e}");
        // And a tensor built exactly on the grid has error 0.
        let s = 2.0 / 127.0;
        let grid = Dense::from_vec(&[3], vec![-127.0 * s, 64.0 * s, 127.0 * s]);
        let qg = quantize(&grid, 8, Rounding::Nearest);
        assert!(error_x_quantized(&grid, &qg) < 1e-6);
    }

    #[test]
    fn error_grows_as_bits_shrink() {
        // Monotone (up to noise): fewer bits, coarser grid, larger Error_X.
        let x = Dense::from_vec(&[512], (0..512).map(|i| ((i * 37) % 101) as f32 / 101.0 - 0.5).collect());
        let errs: Vec<f32> = [8u8, 6, 4, 2]
            .iter()
            .map(|&b| error_x_quantized(&x, &quantize(&x, b, Rounding::Nearest)))
            .collect();
        assert!(errs[0] < errs[1] && errs[1] < errs[2] && errs[2] < errs[3], "{errs:?}");
    }

    #[test]
    fn identical_tensors_have_zero_error() {
        let x = Dense::from_vec(&[3], vec![0.5f32, -0.25, 0.0]);
        assert_eq!(error_x(&x, &x.clone()), 0.0);
    }

    #[test]
    fn zero_zero_case_guarded_by_epsilon() {
        // X_i = X_i,Quant = 0 must contribute 0, not NaN.
        let x: Dense<f32> = Dense::zeros(&[8]);
        let e = error_x(&x, &Dense::zeros(&[8]));
        assert_eq!(e, 0.0);
        assert!(e.is_finite());
    }

    #[test]
    fn empty_tensor_is_zero_error() {
        let x: Dense<f32> = Dense::zeros(&[0]);
        assert_eq!(error_x(&x, &x.clone()), 0.0);
    }

    #[test]
    fn slice_form_matches_tensor_form() {
        let x = Dense::from_vec(&[6], vec![0.4f32, -0.9, 0.05, 1.3, -1.3, 0.0]);
        let q = quantize(&x, 6, Rounding::Nearest);
        let via_tensor = error_x_quantized(&x, &q);
        let via_slice = error_x_slice(x.data(), q.data.data(), q.scale);
        assert!((via_tensor - via_slice).abs() < 1e-7, "{via_tensor} vs {via_slice}");
        assert_eq!(error_x_slice(&[], &[], 1.0), 0.0);
    }

    #[test]
    fn metric_is_inductive_across_magnitudes() {
        // The point of the relative form: the same *relative* perturbation
        // yields (approximately) the same Error_X regardless of magnitude.
        let small = Dense::from_vec(&[2], vec![0.1f32, 0.2]);
        let small_p = Dense::from_vec(&[2], vec![0.101f32, 0.202]);
        let large = Dense::from_vec(&[2], vec![100.0f32, 200.0]);
        let large_p = Dense::from_vec(&[2], vec![101.0f32, 202.0]);
        let es = error_x(&small, &small_p);
        let el = error_x(&large, &large_p);
        assert!((es - el).abs() < 2e-3, "es={es} el={el}");
    }
}
