//! Markdown table rendering for the `repro` reports and EXPERIMENTS.md.

/// A simple column-aligned markdown table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display values.
    pub fn row_disp<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as github-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Fig. X", &["dataset", "speedup"]);
        t.row(&["Pubmed".into(), "1.5x".into()]);
        t.row(&["ogbn-products".into(), "2.0x".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Fig. X"));
        assert!(md.contains("| dataset       | speedup |"));
        assert!(md.contains("| ogbn-products | 2.0x    |"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn row_disp_formats() {
        let mut t = Table::new("t", &["v"]);
        t.row_disp(&[1.25f64]);
        assert!(t.to_markdown().contains("1.25"));
    }
}
