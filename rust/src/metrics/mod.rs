//! Measurement infrastructure: wall-clock benchmarking (the offline
//! criterion stand-in), counters, and table rendering for the `repro`
//! figure/table reports.

mod bench;
mod table;

pub use bench::{bench, bench_with_config, fmt_time, BenchConfig, BenchResult};
pub use table::Table;

use std::time::Instant;

/// Time a closure once, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Simple byte-traffic accounting used to report achieved memory throughput
/// the way the paper's Table 2 does (bytes moved / kernel time).
#[derive(Debug, Default, Clone, Copy)]
pub struct Traffic {
    /// Bytes read by the kernel (modelled, not hardware-counted).
    pub read_bytes: u64,
    /// Bytes written by the kernel.
    pub write_bytes: u64,
}

impl Traffic {
    /// Total bytes moved.
    pub fn total(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    /// Achieved throughput in GB/s given a runtime in seconds.
    pub fn gbps(&self, seconds: f64) -> f64 {
        if seconds <= 0.0 {
            return 0.0;
        }
        self.total() as f64 / seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn traffic_throughput() {
        let t = Traffic { read_bytes: 3_000_000_000, write_bytes: 1_000_000_000 };
        assert_eq!(t.total(), 4_000_000_000);
        assert!((t.gbps(2.0) - 2.0).abs() < 1e-9);
        assert_eq!(t.gbps(0.0), 0.0);
    }
}
