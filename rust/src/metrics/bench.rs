//! Wall-clock micro-benchmark harness (the offline criterion stand-in).
//!
//! Warmup + batched timed iterations with mean/stddev/min reporting. Used by
//! every `rust/benches/*.rs` target and the `repro` figure generators.

use std::time::Instant;

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup time before measurement.
    pub warmup_secs: f64,
    /// Minimum measurement time.
    pub measure_secs: f64,
    /// Minimum number of timed samples.
    pub min_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Short but stable defaults; the benches sweep many configurations.
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.4, min_samples: 5 }
    }
}

/// One benchmark's statistics (times in seconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Label.
    pub name: String,
    /// Mean per-iteration time.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Fastest sample.
    pub min: f64,
    /// Number of timed samples.
    pub samples: usize,
}

impl BenchResult {
    /// Throughput helper: items per second at the mean time.
    pub fn per_second(&self, items: u64) -> f64 {
        items as f64 / self.mean
    }

    /// Human-readable line.
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  (min {:>12}, n={})",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.stddev),
            fmt_time(self.min),
            self.samples
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f` with the default config.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    bench_with_config(name, BenchConfig::default(), &mut f)
}

/// Benchmark `f` with an explicit config. The closure's return value is
/// passed through `std::hint::black_box` so work is not optimized away.
pub fn bench_with_config<T>(
    name: &str,
    cfg: BenchConfig,
    f: &mut impl FnMut() -> T,
) -> BenchResult {
    // Warmup, also calibrating per-iteration cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed().as_secs_f64() < cfg.warmup_secs || warm_iters == 0 {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    let approx_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    // Batch so each sample is at least ~2ms (timer noise floor).
    let batch = ((2e-3 / approx_iter.max(1e-9)).ceil() as u64).max(1);
    let mut samples = Vec::new();
    let measure_start = Instant::now();
    while measure_start.elapsed().as_secs_f64() < cfg.measure_secs
        || samples.len() < cfg.min_samples
    {
        let t0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        samples.push(t0.elapsed().as_secs_f64() / batch as f64);
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / (n - 1.0).max(1.0);
    BenchResult {
        name: name.to_string(),
        mean,
        stddev: var.sqrt(),
        min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        samples: samples.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, min_samples: 3 };
        let mut x = 0u64;
        let r = bench_with_config("noop-ish", cfg, &mut || {
            x = x.wrapping_add(1);
            x
        });
        assert!(r.mean > 0.0);
        assert!(r.min <= r.mean);
        assert!(r.samples >= 3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn per_second() {
        let r = BenchResult { name: "x".into(), mean: 0.5, stddev: 0.0, min: 0.5, samples: 1 };
        assert_eq!(r.per_second(10), 20.0);
    }
}
