//! The vetted-exception list (`audit.allow.toml`).
//!
//! Format — one `[allow.<slug>]` section per exception, parsed with the
//! repo's own TOML-subset reader:
//!
//! ```toml
//! [allow.par-slab-invariant]
//! rule = "P1"                      # D1 | O1 | C1 | P1 | W1
//! path = "rust/src/util/par.rs"    # suffix match on the finding's path
//! contains = "batch claimed twice" # optional: substring of the flagged
//!                                  # line or message
//! reason = "slab slots are filled exactly once by construction"
//! ```
//!
//! An entry suppresses every finding it matches; an entry that matches
//! nothing is reported as a warning (stale exceptions hide regressions),
//! which `--deny-warnings` promotes to failure.

use super::{Finding, Rule};
use crate::config::TomlDoc;

/// One parsed `[allow.<name>]` entry.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    /// The `<slug>` after `allow.`.
    pub name: String,
    /// Rule this entry suppresses.
    pub rule: Rule,
    /// Path suffix the finding must end with.
    pub path: String,
    /// Optional substring of the finding's snippet or message.
    pub contains: Option<String>,
    /// One-line justification (required — an excuse-free allowlist rots).
    pub reason: String,
}

/// The whole allowlist.
#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    /// Entries in file order.
    pub entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// An empty list (no `audit.allow.toml` present).
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    /// Parse the allowlist text; errors name the offending section.
    pub fn parse(text: &str) -> Result<Allowlist, String> {
        let doc = TomlDoc::parse(text)?;
        let mut entries = Vec::new();
        for sec in doc.sections() {
            if sec.is_empty() {
                continue;
            }
            let name = sec
                .strip_prefix("allow.")
                .ok_or_else(|| format!("section [{sec}]: expected [allow.<name>]"))?
                .to_string();
            let field = |k: &str| {
                doc.get(sec, k)
                    .map(str::to_string)
                    .ok_or_else(|| format!("[{sec}]: missing required key `{k}`"))
            };
            let rule_s = field("rule")?;
            let rule = Rule::parse(&rule_s)
                .ok_or_else(|| format!("[{sec}]: unknown rule {rule_s:?} (D1|O1|C1|P1|W1)"))?;
            let reason = field("reason")?;
            if reason.trim().is_empty() {
                return Err(format!("[{sec}]: empty reason"));
            }
            entries.push(AllowEntry {
                name,
                rule,
                path: field("path")?,
                contains: doc.get(sec, "contains").map(str::to_string),
                reason,
            });
        }
        Ok(Allowlist { entries })
    }

    /// Index of the first entry matching `f`, if any.
    pub fn match_finding(&self, f: &Finding) -> Option<usize> {
        self.entries.iter().position(|e| {
            e.rule == f.rule
                && f.path.ends_with(&e.path)
                && e.contains
                    .as_deref()
                    .map(|c| f.snippet.contains(c) || f.message.contains(c))
                    .unwrap_or(true)
        })
    }

    /// Split raw findings into `(kept, suppressed-with-entry-name,
    /// unused-entry-names)`.
    pub fn apply(
        &self,
        findings: Vec<Finding>,
    ) -> (Vec<Finding>, Vec<(String, Finding)>, Vec<String>) {
        let mut used = vec![false; self.entries.len()];
        let mut kept = Vec::new();
        let mut suppressed = Vec::new();
        for f in findings {
            match self.match_finding(&f) {
                Some(i) => {
                    used[i] = true;
                    suppressed.push((self.entries[i].name.clone(), f));
                }
                None => kept.push(f),
            }
        }
        let unused = self
            .entries
            .iter()
            .zip(&used)
            .filter(|(_, u)| !**u)
            .map(|(e, _)| e.name.clone())
            .collect();
        (kept, suppressed, unused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding() -> Finding {
        Finding {
            rule: Rule::P1,
            path: "rust/src/util/par.rs".into(),
            line: 63,
            message: "`.expect(\"` in library code".into(),
            snippet: ".expect(\"batch claimed twice\");".into(),
        }
    }

    #[test]
    fn parse_match_and_usage_tracking() {
        let a = Allowlist::parse(
            "[allow.par-slab]\nrule = \"P1\"\npath = \"util/par.rs\"\n\
             contains = \"batch claimed twice\"\nreason = \"slab invariant\"\n\
             [allow.stale]\nrule = \"D1\"\npath = \"nope.rs\"\nreason = \"x\"\n",
        )
        .unwrap();
        let (kept, suppressed, unused) = a.apply(vec![finding()]);
        assert!(kept.is_empty());
        assert_eq!(suppressed.len(), 1);
        assert_eq!(suppressed[0].0, "par-slab");
        assert_eq!(unused, vec!["stale".to_string()]);
    }

    #[test]
    fn wrong_rule_or_substring_does_not_match() {
        let a = Allowlist::parse(
            "[allow.x]\nrule = \"O1\"\npath = \"util/par.rs\"\nreason = \"r\"\n",
        )
        .unwrap();
        assert_eq!(a.match_finding(&finding()), None);
    }

    #[test]
    fn malformed_entries_error_with_section_name() {
        assert!(Allowlist::parse("[allow.x]\npath = \"p\"\nreason = \"r\"\n")
            .unwrap_err()
            .contains("allow.x"));
        assert!(Allowlist::parse("[notallow.x]\nrule = \"P1\"\n").is_err());
    }
}
