//! The per-file line/token scanner: rules D1 (determinism), O1 (obs keys),
//! P1 (no panics) and W1 (atomic writes).
//!
//! Deliberately a token scanner, not a parser: the rules are phrased so
//! that substring + word-boundary checks over non-comment, non-test lines
//! are exact enough, and the allowlist absorbs the few vetted exceptions.
//! Scanning stops at the first `#[cfg(test)]` line — test modules sit at
//! the end of every file in this repo — and `//`-prefixed lines are
//! skipped so doc comments can talk about `unwrap()` freely.

use super::{Finding, Rule};
use std::collections::BTreeSet;

/// Wall-clock tokens banned outside the observability/metrics layers (D1).
const CLOCK_TOKENS: [&str; 2] = ["SystemTime", "Instant::now"];

/// Panic-path tokens banned in library code (P1). `.expect(` is matched
/// with its opening quote so `Parser::expect(b'"')`-style byte helpers
/// don't false-positive.
const PANIC_TOKENS: [&str; 3] = [".unwrap()", ".expect(\"", "panic!("];

/// Hash-ordered iteration methods banned on `HashMap`/`HashSet` values (D1).
const ITER_METHODS: [&str; 7] =
    [".iter()", ".keys()", ".values()", ".values_mut()", ".into_iter()", ".drain(", ".retain("];

/// Obs entry points whose first argument must be a `obs::keys` constant (O1).
/// `instant` is the trace-timeline marker added with the flight recorder —
/// its names flow into Chrome trace events and must resolve in `obs::keys`
/// just like span and counter names.
const OBS_FNS: [&str; 5] = ["span", "timed", "counter_add", "gauge_set", "instant"];

/// Direct file-write tokens banned in library code (W1): artifact and
/// checkpoint writers must go through `util::fsio::write_atomic` so an
/// interrupted run never leaves a truncated file. The helper's own
/// `fs::write` is the allowlisted implementation.
const WRITE_TOKENS: [&str; 2] = ["fs::write(", "File::create("];

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Find `tok` in `line[from..]` at a position not preceded by an
/// identifier byte (so `span(` does not match `print_span(`).
fn find_bounded(line: &str, tok: &str, from: usize) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut start = from;
    while let Some(rel) = line.get(start..).and_then(|s| s.find(tok)) {
        let at = start + rel;
        if at == 0 || !is_ident_byte(bytes[at - 1]) {
            return Some(at);
        }
        start = at + 1;
    }
    None
}

/// Read an identifier starting at byte `at`.
fn ident_at(line: &str, at: usize) -> Option<&str> {
    let bytes = line.as_bytes();
    if at >= bytes.len() || !is_ident_byte(bytes[at]) || bytes[at].is_ascii_digit() {
        return None;
    }
    let mut end = at;
    while end < bytes.len() && is_ident_byte(bytes[end]) {
        end += 1;
    }
    line.get(at..end)
}

/// Read the identifier that *ends* at byte `end` (exclusive).
fn ident_ending_at(line: &str, end: usize) -> Option<&str> {
    let bytes = line.as_bytes();
    let mut start = end;
    while start > 0 && is_ident_byte(bytes[start - 1]) {
        start -= 1;
    }
    if start == end || bytes[start].is_ascii_digit() {
        return None;
    }
    line.get(start..end)
}

/// Is this file inside the layers allowed to read wall clocks (D1)?
/// `obs` *is* the timing layer; `metrics` is the bench/report layer whose
/// whole job is wall-clock measurement.
fn clock_allowed(path: &str) -> bool {
    path.starts_with("rust/src/obs/") || path.starts_with("rust/src/metrics/")
}

/// Track identifiers bound to `HashMap`/`HashSet` values in this file so
/// far, honouring `let` shadowing (re-binding a name to a non-hash value
/// — e.g. draining a set into a `Vec` to sort it — untracks the name).
fn update_tracked(line: &str, tracked: &mut BTreeSet<String>) {
    let hashy = line.contains("HashMap") || line.contains("HashSet");
    let mut from = 0;
    while let Some(at) = find_bounded(line, "let ", from) {
        let mut p = at + 4;
        let bytes = line.as_bytes();
        while p < bytes.len() && bytes[p] == b' ' {
            p += 1;
        }
        if line.get(p..).is_some_and(|s| s.starts_with("mut ")) {
            p += 4;
            while p < bytes.len() && bytes[p] == b' ' {
                p += 1;
            }
        }
        if let Some(name) = ident_at(line, p) {
            if hashy {
                tracked.insert(name.to_string());
            } else {
                tracked.remove(name);
            }
        }
        from = at + 4;
    }
    // Type-position declarations — struct fields and fn params:
    // `name: HashMap<..>`, `name: &HashSet<..>`, `name: std::collections::…`.
    for ty in ["HashMap<", "HashSet<"] {
        let mut from = 0;
        while let Some(at) = find_bounded(line, ty, from) {
            let mut before = &line[..at];
            before = before.strip_suffix("std::collections::").unwrap_or(before);
            before = before.trim_end();
            before = before.strip_suffix('&').unwrap_or(before).trim_end();
            if let Some(rest) = before.strip_suffix(':') {
                let rest = rest.trim_end();
                if let Some(name) = ident_ending_at(rest, rest.len()) {
                    tracked.insert(name.to_string());
                }
            }
            from = at + 1;
        }
    }
}

/// D1 (iteration half): does `line` iterate any tracked hash container?
fn hash_iteration(line: &str, tracked: &BTreeSet<String>) -> Option<String> {
    for name in tracked {
        for meth in ITER_METHODS {
            let pat = format!("{name}{meth}");
            if find_bounded(line, &pat, 0).is_some() {
                return Some(pat);
            }
        }
        // `for x in &name` / `for x in name` loop headers.
        for prefix in ["in &", "in "] {
            let pat = format!("{prefix}{name}");
            let mut from = 0;
            while let Some(at) = find_bounded(line, &pat, from) {
                let end = at + pat.len();
                if !line.as_bytes().get(end).copied().is_some_and(is_ident_byte) {
                    return Some(format!("for … {pat}"));
                }
                from = at + 1;
            }
        }
    }
    None
}

/// O1: does `line` pass an inline string (or `format!`) as an obs key?
fn inline_obs_key(line: &str) -> Option<&'static str> {
    for f in OBS_FNS {
        let mut from = 0;
        while let Some(at) = find_bounded(line, f, from) {
            let rest = line[at + f.len()..].trim_start();
            if let Some(args) = rest.strip_prefix('(') {
                let args = args.trim_start();
                if args.starts_with('"')
                    || args.starts_with("format!")
                    || args.starts_with("&format!")
                {
                    return Some(f);
                }
            }
            from = at + f.len();
        }
    }
    None
}

/// Scan one file's source for the line rules (D1, O1, P1). `path` is the
/// repo-relative path the findings are reported under; the rules it
/// selects (e.g. the obs-layer clock allowance) key off it.
pub fn scan_source(path: &str, text: &str) -> Vec<Finding> {
    let in_obs = path.starts_with("rust/src/obs/");
    let mut findings = Vec::new();
    let mut tracked: BTreeSet<String> = BTreeSet::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line_no = lineno + 1;
        let trimmed = raw.trim();
        if trimmed.starts_with("#[cfg(test)") {
            break;
        }
        if trimmed.starts_with("//") {
            continue;
        }
        let snip = trimmed.to_string();

        if !clock_allowed(path) {
            for tok in CLOCK_TOKENS {
                if raw.contains(tok) {
                    findings.push(Finding {
                        rule: Rule::D1,
                        path: path.to_string(),
                        line: line_no,
                        message: format!(
                            "wall-clock read `{tok}` in a seeded path — move timing into the \
                             obs layer or allowlist it"
                        ),
                        snippet: snip.clone(),
                    });
                }
            }
        }

        update_tracked(raw, &mut tracked);
        if let Some(pat) = hash_iteration(raw, &tracked) {
            findings.push(Finding {
                rule: Rule::D1,
                path: path.to_string(),
                line: line_no,
                message: format!(
                    "iteration over a HashMap/HashSet (`{pat}`) — order is per-process \
                     random; collect + sort, or use a BTreeMap"
                ),
                snippet: snip.clone(),
            });
        }

        if !in_obs {
            if let Some(f) = inline_obs_key(raw) {
                findings.push(Finding {
                    rule: Rule::O1,
                    path: path.to_string(),
                    line: line_no,
                    message: format!(
                        "inline string key at `{f}(…)` — name the key in obs::keys and use \
                         the constant"
                    ),
                    snippet: snip.clone(),
                });
            }
        }

        for tok in WRITE_TOKENS {
            if raw.contains(tok) {
                findings.push(Finding {
                    rule: Rule::W1,
                    path: path.to_string(),
                    line: line_no,
                    message: format!(
                        "direct file write `{tok}…)` — route it through \
                         util::fsio::write_atomic so a crash cannot truncate the file"
                    ),
                    snippet: snip.clone(),
                });
            }
        }

        for tok in PANIC_TOKENS {
            // Method tokens start with `.` and follow an expression, so a
            // plain substring match is the right check; `panic!(` needs the
            // word boundary so `some_panic!(` variants don't slip in.
            let hit = if tok.starts_with('.') {
                raw.contains(tok)
            } else {
                find_bounded(raw, tok, 0).is_some()
            };
            if hit {
                findings.push(Finding {
                    rule: Rule::P1,
                    path: path.to_string(),
                    line: line_no,
                    message: format!(
                        "`{tok}` in library code — propagate a Result (or allowlist with a \
                         justification)",
                    ),
                    snippet: snip.clone(),
                });
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_find_respects_word_starts() {
        assert_eq!(find_bounded("print_span(x)", "span", 0), None);
        assert_eq!(find_bounded("obs::span(x)", "span", 0), Some(5));
    }

    #[test]
    fn tracking_honours_shadowing() {
        let mut t = BTreeSet::new();
        update_tracked("let mut chosen = std::collections::HashSet::new();", &mut t);
        assert!(t.contains("chosen"));
        update_tracked("let mut chosen: Vec<u32> = chosen.into_iter().collect();", &mut t);
        assert!(!t.contains("chosen"));
    }

    #[test]
    fn field_declarations_are_tracked() {
        let mut t = BTreeSet::new();
        update_tracked("    entries: HashMap<u64, QTensor>,", &mut t);
        assert!(t.contains("entries"));
        assert!(hash_iteration("self.entries.values().sum()", &t).is_some());
        assert!(hash_iteration("self.entries.get(&k)", &t).is_none());
    }
}
