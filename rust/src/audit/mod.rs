//! `tango-audit` — repo-specific static analysis (the compile-time
//! correctness tooling).
//!
//! Tango's invariants live outside the type system: bit-identical replay
//! across prefetch depths and worker counts, a pinned `tango-metrics/v1`
//! key schema, and a three-way CLI/TOML/docs config surface. This module
//! is a zero-dependency line/token scanner over `rust/src/**` that turns
//! those reviewer-discipline rules into machine-checked ones:
//!
//! - **D1 (determinism)** — no `SystemTime`/`Instant::now` outside the
//!   observability and metrics layers, and no iteration over `HashMap`/
//!   `HashSet` (per-process random order — the bit-identity bug class);
//!   require sorted or `BTreeMap` iteration instead.
//! - **O1 (obs keys)** — every `span`/`timed`/`counter_add`/`gauge_set`
//!   key must be a constant from [`crate::obs::keys`], never an inline
//!   string literal, so the metrics artifact schema cannot drift silently.
//! - **C1 (config surface)** — every `--flag` parsed in `main.rs` must
//!   have a matching TOML key in `config/` and a mention in
//!   `configs/*.toml`, and vice versa.
//! - **P1 (no panics)** — no `unwrap()`/`expect()`/`panic!` in library
//!   code outside tests and benches.
//! - **W1 (atomic writes)** — no direct `fs::write`/`File::create` in
//!   library code; artifact and checkpoint files must go through
//!   [`crate::util::fsio::write_atomic`] (tmp + rename) so a crash
//!   mid-write never leaves a truncated file behind.
//!
//! Vetted exceptions live in `audit.allow.toml` at the repo root, each
//! with a one-line justification; unused entries are warnings (failures
//! under `--deny-warnings`). The scanner skips `#[cfg(test)]` modules
//! (always file-tail in this repo), comment lines, and its own sources
//! (which contain the banned tokens as pattern strings — the rules are
//! instead exercised on inline fixtures in `tests/audit_self.rs`).
//!
//! Run locally: `cargo run --bin tango_audit -- --deny-warnings`.
//! See `rust/src/audit/README.md` for the full rule/allowlist reference.

mod allow;
mod report;
mod scanner;
mod surface;

pub use allow::{AllowEntry, Allowlist};
pub use report::{Report, SCHEMA};
pub use scanner::scan_source;
pub use surface::{check_surface, extract_cli_flags, extract_mentions, extract_toml_keys, Extracted};

use std::collections::BTreeSet;
use std::path::Path;

/// One audit rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Determinism: no wall-clock reads or hash-order iteration in seeded paths.
    D1,
    /// Obs keys: no inline string keys at `span`/`timed`/counter/gauge sites.
    O1,
    /// Config surface: CLI flags, TOML keys and config-file mentions agree.
    C1,
    /// No `unwrap()`/`expect()`/`panic!` in library code.
    P1,
    /// Atomic writes: no direct `fs::write`/`File::create` in library code.
    W1,
}

impl Rule {
    /// Short rule id, as printed in diagnostics and the allowlist.
    pub fn name(self) -> &'static str {
        match self {
            Rule::D1 => "D1",
            Rule::O1 => "O1",
            Rule::C1 => "C1",
            Rule::P1 => "P1",
            Rule::W1 => "W1",
        }
    }

    /// Parse a rule id.
    pub fn parse(s: &str) -> Option<Rule> {
        match s {
            "D1" => Some(Rule::D1),
            "O1" => Some(Rule::O1),
            "C1" => Some(Rule::C1),
            "P1" => Some(Rule::P1),
            "W1" => Some(Rule::W1),
            _ => None,
        }
    }
}

/// One diagnostic: rule, repo-relative `path:line`, message and the
/// flagged source line (what allowlist `contains` patterns match on).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// The rule that fired.
    pub rule: Rule,
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description.
    pub message: String,
    /// The trimmed source line (or symbol) that triggered the finding.
    pub snippet: String,
}

impl Finding {
    /// `path:line: rule message` — the diagnostic line format.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule.name(), self.message)
    }
}

/// Files under `rust/src` the scanner must not read: the audit sources
/// themselves contain every banned token as a pattern string.
fn is_excluded(rel: &str) -> bool {
    rel.starts_with("rust/src/audit/") || rel == "rust/src/bin/tango_audit.rs"
}

/// Recursively list `.rs` files under `dir` as repo-relative paths
/// (sorted, so findings and reports are deterministic).
fn walk_rs(dir: &Path, rel: &str, out: &mut Vec<String>) -> crate::Result<()> {
    let mut entries: Vec<(String, bool)> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let is_dir = entry.file_type()?.is_dir();
        entries.push((name, is_dir));
    }
    entries.sort();
    for (name, is_dir) in entries {
        let child_rel = format!("{rel}/{name}");
        if is_dir {
            walk_rs(&dir.join(&name), &child_rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(child_rel);
        }
    }
    Ok(())
}

/// Run the full audit from a repo root, applying `allow` to the raw
/// findings. Returns the report; it is the caller's job to pick an exit
/// code from [`Report::ok`].
pub fn run(root: &Path, allow: &Allowlist) -> crate::Result<Report> {
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        anyhow::bail!("{} is not a repo root (no rust/src)", root.display());
    }
    let mut files = Vec::new();
    walk_rs(&src, "rust/src", &mut files)?;

    let mut findings = Vec::new();
    let mut files_scanned = 0usize;
    for rel in &files {
        if is_excluded(rel) {
            continue;
        }
        let text = std::fs::read_to_string(root.join(rel))?;
        findings.extend(scan_source(rel, &text));
        files_scanned += 1;
    }

    // C1: cross-reference the CLI flag surface, the TOML key surface and
    // the example-config mentions.
    let main_rel = "rust/src/main.rs";
    let main_text = std::fs::read_to_string(root.join(main_rel))?;
    let flags = extract_cli_flags(main_rel, &main_text);
    let mut keys = Vec::new();
    for rel in ["rust/src/config/mod.rs", "rust/src/multigpu/worker.rs"] {
        let text = std::fs::read_to_string(root.join(rel))?;
        keys.extend(extract_toml_keys(rel, &text));
    }
    let mut mentions = BTreeSet::new();
    let configs = root.join("configs");
    if configs.is_dir() {
        let mut toml_files: Vec<String> = Vec::new();
        for entry in std::fs::read_dir(&configs)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if name.ends_with(".toml") {
                toml_files.push(name);
            }
        }
        toml_files.sort();
        for name in toml_files {
            let text = std::fs::read_to_string(configs.join(name))?;
            mentions.extend(extract_mentions(&text));
        }
    }
    findings.extend(check_surface(&flags, &keys, &mentions));

    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    let (kept, suppressed, unused) = allow.apply(findings);
    let warnings: Vec<String> = unused
        .into_iter()
        .map(|n| format!("unused allowlist entry [allow.{n}] — fix shipped? delete the entry"))
        .collect();
    Ok(Report { files_scanned, findings: kept, suppressed, warnings })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_names_round_trip() {
        for r in [Rule::D1, Rule::O1, Rule::C1, Rule::P1, Rule::W1] {
            assert_eq!(Rule::parse(r.name()), Some(r));
        }
        assert_eq!(Rule::parse("Z9"), None);
    }

    #[test]
    fn exclusions_cover_the_scanner_itself() {
        assert!(is_excluded("rust/src/audit/scanner.rs"));
        assert!(is_excluded("rust/src/bin/tango_audit.rs"));
        assert!(!is_excluded("rust/src/main.rs"));
    }
}
