//! Rule C1: three-way config-surface symmetry.
//!
//! The same knob is spelled three ways — a `--flag` parsed in `main.rs`, a
//! TOML key read in `config/mod.rs` (or `multigpu/worker.rs`), and a
//! `key = value` mention (live or commented) in `configs/*.toml` that
//! documents it. Any knob present in one spelling and missing in another
//! is exactly how config drift ships: a flag nobody can set from a file,
//! or a file key silently ignored. C1 extracts all three surfaces
//! syntactically and cross-references them.
//!
//! Flag names normalise `-` to `_`; the one deliberate rename
//! (`--metrics-out` ↔ `[metrics] out`) is a built-in alias. Knobs that
//! are CLI-only by design (`--config` itself, `repro` effort knobs) live
//! in `audit.allow.toml`.

use super::{Finding, Rule};
use std::collections::BTreeSet;

/// Deliberate flag↔key renames: `(normalised flag, TOML key)`.
const ALIASES: [(&str, &str); 1] = [("metrics_out", "out")];

/// One extracted config symbol with where it was first seen.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Extracted {
    /// Symbol as written (flag names keep their dashes).
    pub name: String,
    /// Repo-relative file it was extracted from.
    pub file: String,
    /// 1-based line of the first occurrence.
    pub line: usize,
}

/// Non-test, non-comment lines of a Rust source file.
fn code_lines(text: &str) -> impl Iterator<Item = (usize, &str)> {
    text.lines()
        .enumerate()
        .take_while(|(_, l)| !l.trim().starts_with("#[cfg(test)"))
        .filter(|(_, l)| !l.trim().starts_with("//"))
        .map(|(i, l)| (i + 1, l))
}

/// Read a leading `"quoted"` string (after optional whitespace).
fn quoted_prefix(s: &str) -> Option<&str> {
    let rest = s.trim_start().strip_prefix('"')?;
    rest.find('"').map(|end| &rest[..end])
}

/// Extract the `--flag` names `main.rs` consults, by its accessor idioms:
/// `args.flags.get/contains_key`, `args.get/get_bool/get_as/try_get_as`,
/// and the local `flag(args, "…")` helper.
pub fn extract_cli_flags(file: &str, text: &str) -> Vec<Extracted> {
    const PATTERNS: [&str; 7] = [
        "args.flags.get(",
        "args.flags.contains_key(",
        "args.get_bool(",
        "args.get(",
        "args.get_as(",
        "args.try_get_as(",
        "flag(args,",
    ];
    let mut out: Vec<Extracted> = Vec::new();
    for (line_no, line) in code_lines(text) {
        for pat in PATTERNS {
            let mut from = 0;
            while let Some(rel) = line.get(from..).and_then(|s| s.find(pat)) {
                let after = from + rel + pat.len();
                if let Some(name) = quoted_prefix(&line[after..]) {
                    if !out.iter().any(|e| e.name == name) {
                        out.push(Extracted {
                            name: name.to_string(),
                            file: file.to_string(),
                            line: line_no,
                        });
                    }
                }
                from = after;
            }
        }
    }
    out
}

/// Extract the TOML keys a config reader consults: `doc.get("sec", "key")`
/// (two quoted args — take the key) and the curried `get("key")` closure
/// idiom (one quoted arg, closed immediately).
pub fn extract_toml_keys(file: &str, text: &str) -> Vec<Extracted> {
    let mut out: Vec<Extracted> = Vec::new();
    let mut push = |name: &str, file: &str, line: usize, out: &mut Vec<Extracted>| {
        if !out.iter().any(|e| e.name == name) {
            out.push(Extracted { name: name.to_string(), file: file.to_string(), line });
        }
    };
    for (line_no, line) in code_lines(text) {
        let mut from = 0;
        while let Some(rel) = line.get(from..).and_then(|s| s.find("get(")) {
            let at = from + rel;
            from = at + 4;
            // Word boundary: `get(` but not `target(` etc.
            let prev = if at > 0 { Some(line.as_bytes()[at - 1]) } else { None };
            if prev.is_some_and(|b| b == b'_' || b.is_ascii_alphanumeric()) {
                continue;
            }
            let args = &line[at + 4..];
            let Some(first) = quoted_prefix(args) else { continue };
            let after_first = args.trim_start();
            // Skip the opening quote, the content and the closing quote.
            let rest = &after_first[first.len() + 2..];
            let rest = rest.trim_start();
            if let Some(two) = rest.strip_prefix(',') {
                if let Some(second) = quoted_prefix(two) {
                    push(second, file, line_no, &mut out);
                }
                // `doc.get("train", k)` — dynamic key, the closure idiom
                // below captures its call sites instead.
            } else if rest.starts_with(')') {
                push(first, file, line_no, &mut out);
            }
        }
    }
    out
}

/// Extract the key names mentioned in a `configs/*.toml` text — live
/// `key = value` lines and commented `# key = value` documentation lines.
pub fn extract_mentions(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for raw in text.lines() {
        let mut s = raw.trim_start();
        s = s.strip_prefix('#').unwrap_or(s).trim_start();
        let Some(eq) = s.find('=') else { continue };
        let name = s[..eq].trim_end();
        if !name.is_empty()
            && name.bytes().all(|b| b == b'_' || b.is_ascii_alphanumeric())
            && !name.as_bytes()[0].is_ascii_digit()
        {
            out.insert(name.to_string());
        }
    }
    out
}

/// Flag name → the canonical TOML spelling it must appear as.
fn canonical(flag: &str) -> String {
    let norm = flag.replace('-', "_");
    for (f, k) in ALIASES {
        if norm == f {
            return k.to_string();
        }
    }
    norm
}

/// Cross-reference the three surfaces and emit a C1 finding per asymmetry.
pub fn check_surface(
    flags: &[Extracted],
    keys: &[Extracted],
    mentions: &BTreeSet<String>,
) -> Vec<Finding> {
    let key_names: BTreeSet<&str> = keys.iter().map(|e| e.name.as_str()).collect();
    let flag_canon: BTreeSet<String> = flags.iter().map(|e| canonical(&e.name)).collect();
    let mut findings = Vec::new();
    for e in flags {
        let canon = canonical(&e.name);
        if !key_names.contains(canon.as_str()) {
            findings.push(Finding {
                rule: Rule::C1,
                path: e.file.clone(),
                line: e.line,
                message: format!(
                    "flag --{} has no matching TOML key `{canon}` in the config readers",
                    e.name
                ),
                snippet: format!("--{}", e.name),
            });
        }
        if !mentions.contains(canon.as_str()) {
            findings.push(Finding {
                rule: Rule::C1,
                path: e.file.clone(),
                line: e.line,
                message: format!(
                    "flag --{} is not mentioned (even commented) as `{canon} =` in configs/*.toml",
                    e.name
                ),
                snippet: format!("--{}", e.name),
            });
        }
    }
    for e in keys {
        if !flag_canon.contains(&e.name) {
            findings.push(Finding {
                rule: Rule::C1,
                path: e.file.clone(),
                line: e.line,
                message: format!("TOML key `{}` has no matching --flag in main.rs", e.name),
                snippet: e.name.clone(),
            });
        }
        if !mentions.contains(&e.name) {
            findings.push(Finding {
                rule: Rule::C1,
                path: e.file.clone(),
                line: e.line,
                message: format!(
                    "TOML key `{}` is not mentioned (even commented) in configs/*.toml",
                    e.name
                ),
                snippet: e.name.clone(),
            });
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_and_keys_extract_from_idioms() {
        let flags = extract_cli_flags(
            "m.rs",
            "cfg.epochs = flag(args, \"epochs\", cfg.epochs)?;\nif args.get_bool(\"quick\") {}",
        );
        let names: Vec<&str> = flags.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["epochs", "quick"]);

        let keys = extract_toml_keys(
            "c.rs",
            concat!(
                "let get = |k: &str| doc.get(\"train\", k);\n",
                "get(\"lr\")\n",
                "doc.get(\"policy\", \"bucket_bits\")"
            ),
        );
        let names: Vec<&str> = keys.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, vec!["lr", "bucket_bits"]);
    }

    #[test]
    fn mentions_include_commented_keys() {
        let m = extract_mentions("[train]\nlr = 0.1\n# heads = 4\n# not a key line\n");
        assert!(m.contains("lr") && m.contains("heads"));
        assert!(!m.contains("not"));
    }

    #[test]
    fn asymmetries_fire_per_direction() {
        let flags = vec![Extracted { name: "only-flag".into(), file: "m.rs".into(), line: 3 }];
        let keys = vec![Extracted { name: "only_key".into(), file: "c.rs".into(), line: 9 }];
        let mentions = BTreeSet::new();
        let f = check_surface(&flags, &keys, &mentions);
        assert_eq!(f.len(), 4); // each side: missing counterpart + missing mention
        assert!(f.iter().all(|x| x.rule == Rule::C1));
        assert_eq!(f[0].line, 3);
        assert_eq!(f[2].line, 9);
    }

    #[test]
    fn metrics_out_alias_is_symmetric() {
        let flags = vec![Extracted { name: "metrics-out".into(), file: "m.rs".into(), line: 1 }];
        let keys = vec![Extracted { name: "out".into(), file: "c.rs".into(), line: 1 }];
        let mentions: BTreeSet<String> = ["out".to_string()].into_iter().collect();
        assert!(check_surface(&flags, &keys, &mentions).is_empty());
    }
}
