//! The audit result: human-readable rendering and the machine-readable
//! `tango-audit/v1` JSON artifact (same shape discipline as the
//! `tango-metrics/v1` run artifact: deterministic key order, schema tag).

use super::Finding;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Schema tag of the JSON report.
pub const SCHEMA: &str = "tango-audit/v1";

/// Everything one audit run produced.
#[derive(Debug, Clone)]
pub struct Report {
    /// Files scanned by the line rules (exclusions already applied).
    pub files_scanned: usize,
    /// Findings that survived the allowlist — each one fails the audit.
    pub findings: Vec<Finding>,
    /// Findings suppressed by an allowlist entry, with the entry name.
    pub suppressed: Vec<(String, Finding)>,
    /// Non-fatal issues (unused allowlist entries); fatal under
    /// `--deny-warnings`.
    pub warnings: Vec<String>,
}

impl Report {
    /// Does this run pass?
    pub fn ok(&self, deny_warnings: bool) -> bool {
        self.findings.is_empty() && (!deny_warnings || self.warnings.is_empty())
    }

    /// Multi-line human-readable summary (diagnostics first).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.render());
            out.push('\n');
            out.push_str(&format!("    | {}\n", f.snippet));
        }
        for w in &self.warnings {
            out.push_str(&format!("warning: {w}\n"));
        }
        out.push_str(&format!(
            "tango-audit: {} files scanned, {} finding(s), {} allowed, {} warning(s)\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressed.len(),
            self.warnings.len()
        ));
        out
    }

    /// The `tango-audit/v1` JSON document.
    pub fn to_json(&self) -> Json {
        let finding_json = |f: &Finding| {
            let mut m = BTreeMap::new();
            m.insert("rule".to_string(), Json::Str(f.rule.name().to_string()));
            m.insert("path".to_string(), Json::Str(f.path.clone()));
            m.insert("line".to_string(), Json::Num(f.line as f64));
            m.insert("message".to_string(), Json::Str(f.message.clone()));
            m.insert("snippet".to_string(), Json::Str(f.snippet.clone()));
            Json::Obj(m)
        };
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str(SCHEMA.to_string()));
        doc.insert("files_scanned".to_string(), Json::Num(self.files_scanned as f64));
        doc.insert(
            "findings".to_string(),
            Json::Arr(self.findings.iter().map(&finding_json).collect()),
        );
        doc.insert(
            "allowed".to_string(),
            Json::Arr(
                self.suppressed
                    .iter()
                    .map(|(name, f)| {
                        let mut m = BTreeMap::new();
                        m.insert("entry".to_string(), Json::Str(name.clone()));
                        m.insert("finding".to_string(), finding_json(f));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        doc.insert(
            "warnings".to_string(),
            Json::Arr(self.warnings.iter().map(|w| Json::Str(w.clone())).collect()),
        );
        Json::Obj(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::Rule;

    fn report() -> Report {
        Report {
            files_scanned: 3,
            findings: vec![Finding {
                rule: Rule::D1,
                path: "rust/src/x.rs".into(),
                line: 7,
                message: "m".into(),
                snippet: "s".into(),
            }],
            suppressed: vec![],
            warnings: vec!["unused allowlist entry [allow.z]".into()],
        }
    }

    #[test]
    fn ok_gates_on_findings_and_warnings() {
        let mut r = report();
        assert!(!r.ok(false));
        r.findings.clear();
        assert!(r.ok(false));
        assert!(!r.ok(true)); // warning still present
        r.warnings.clear();
        assert!(r.ok(true));
    }

    #[test]
    fn json_carries_schema_and_findings() {
        let j = report().to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SCHEMA));
        let f = &j.get("findings").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(f.get("rule").and_then(Json::as_str), Some("D1"));
        assert_eq!(f.get("line").and_then(Json::as_usize), Some(7));
        // Round-trips through the repo's own parser.
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
