//! GNN models (GCN and GAT) with explicit forward/backward passes composed
//! from the three primitives, exactly following the paper's §2.1
//! decomposition (Fig. 1a/1b).
//!
//! Every model implements the [`GnnModel`] trait and executes **one** code
//! path: the sampled-block forward/backward. The full-graph mode is the
//! block path run over per-layer copies of the *identity block*
//! ([`crate::sampler::Block::identity`]) — the whole graph as a single MFG
//! whose destinations equal its sources — so full-graph and mini-batch
//! training cannot drift apart numerically. Training engines
//! ([`crate::coordinator::Trainer`], [`crate::sampler::MiniBatchTrainer`],
//! [`crate::multigpu`]) construct models through [`AnyModel`], the one
//! model dispatcher in the crate, and attach a [`TaskHead`] (softmax-CE
//! node classification or dot-product link prediction) for the loss side.
//!
//! The models run in one of several [`TrainMode`]s that map onto the
//! paper's evaluation arms:
//!
//! | mode | paper name |
//! |---|---|
//! | [`TrainMode::fp32`] | DGL (full-precision baseline) |
//! | [`TrainMode::tango`] | Tango |
//! | [`TrainMode::tango_test1`] | Test1 — quantized layer before Softmax |
//! | [`TrainMode::tango_test2`] | Test2 — nearest instead of stochastic rounding |
//! | [`TrainMode::exact`] | EXACT — quantize for memory, dequantize to compute |
//!
//! The accuracy rules of §3.2 are enforced structurally: weight updates are
//! always FP32 ([`optim`]), the layer feeding the final softmax stays FP32
//! unless `fp32_pre_softmax` is disabled (Test1), and stochastic rounding
//! seeds derive from the step counter so training is reproducible.

pub mod eval;
pub mod gat;
pub mod gcn;
pub mod head;
pub mod loss;
pub mod optim;

pub use eval::{accuracy, auc};
pub use gat::{GatConfig, GatModel};
pub use gcn::{GcnConfig, GcnModel};
pub use head::TaskHead;
pub use loss::{bce_with_logits, softmax_cross_entropy};
pub use optim::Sgd;

use crate::config::ModelKind;
use crate::graph::Coo;
use crate::primitives::PrimitiveBackend;
use crate::quant::Rounding;
use crate::sampler::{BatchInput, Block, QuantRows};
use crate::tensor::Dense;

/// How a training step executes its primitives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainMode {
    /// Use Tango's quantized primitives (GEMM/SPMM/SDDMM).
    pub quantize: bool,
    /// Stochastic rounding (true) vs nearest (false — the Test2 ablation).
    pub stochastic: bool,
    /// Keep the layer feeding the final softmax in FP32 (§3.2 rule;
    /// false — the Test1 ablation).
    pub fp32_pre_softmax: bool,
    /// EXACT-style execution: tensors are quantized for storage and
    /// dequantized back to FP32 before every compute — memory savings with
    /// *added* work, the baseline Fig. 8 shows losing to both DGL and Tango.
    pub exact_style: bool,
    /// Quantization bit width.
    pub bits: u8,
    /// Which kernel family quantized primitives dispatch to — the
    /// [`PrimitiveBackend`] seam, set from `TrainConfig::packed_compute`.
    /// Irrelevant (and left at the default) when `quantize` is off.
    pub backend: PrimitiveBackend,
}

impl TrainMode {
    /// Full-precision baseline (the paper's "DGL").
    pub fn fp32() -> Self {
        TrainMode {
            quantize: false,
            stochastic: false,
            fp32_pre_softmax: true,
            exact_style: false,
            bits: 8,
            backend: PrimitiveBackend::Dequantize,
        }
    }

    /// Tango with all accuracy rules on.
    pub fn tango(bits: u8) -> Self {
        TrainMode {
            quantize: true,
            stochastic: true,
            fp32_pre_softmax: true,
            exact_style: false,
            bits,
            backend: PrimitiveBackend::Dequantize,
        }
    }

    /// Fig. 7 "Test1": Tango but the pre-softmax layer is quantized too.
    pub fn tango_test1(bits: u8) -> Self {
        TrainMode { fp32_pre_softmax: false, ..Self::tango(bits) }
    }

    /// Fig. 7 "Test2": Tango with nearest instead of stochastic rounding.
    pub fn tango_test2(bits: u8) -> Self {
        TrainMode { stochastic: false, ..Self::tango(bits) }
    }

    /// The EXACT-style baseline of Fig. 8.
    pub fn exact(bits: u8) -> Self {
        TrainMode {
            quantize: false,
            stochastic: false,
            fp32_pre_softmax: true,
            exact_style: true,
            bits,
            backend: PrimitiveBackend::Dequantize,
        }
    }

    /// Rounding mode for a given training step (seeds derive from the step
    /// counter and a stream id, so runs are reproducible).
    pub fn rounding(&self, step: u64, stream: u64) -> Rounding {
        if self.stochastic {
            Rounding::Stochastic { seed: step.wrapping_mul(0x9E3779B97F4A7C15) ^ stream }
        } else {
            Rounding::Nearest
        }
    }
}

/// Architecture-agnostic model hyperparameters — everything
/// [`GnnModel::new_from_config`] needs to build any supported model
/// (GCN ignores `heads`).
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    /// Which architecture [`AnyModel::new_from_config`] dispatches to.
    pub kind: ModelKind,
    /// Input feature dimension.
    pub in_dim: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Output dimension (classes for NC, embedding width for LP — see
    /// [`TaskHead::out_dim`]).
    pub out_dim: usize,
    /// Attention heads (GAT only).
    pub heads: usize,
    /// Layer count (≥1).
    pub layers: usize,
    /// Execution mode.
    pub mode: TrainMode,
}

impl ModelSpec {
    /// Derive a spec from a training config plus the dataset-dependent
    /// dimensions (the one construction rule all training engines share).
    pub fn from_train(cfg: &crate::config::TrainConfig, in_dim: usize, out_dim: usize) -> Self {
        let mut mode = cfg.mode;
        mode.backend = PrimitiveBackend::from_flag(cfg.packed_compute);
        ModelSpec {
            kind: cfg.model,
            in_dim,
            hidden: cfg.hidden,
            out_dim,
            heads: cfg.heads,
            layers: cfg.layers,
            mode,
        }
    }
}

/// The loss-side callback a training step consumes: logits (or embeddings)
/// for the step's output rows in, `(loss, ∂logits)` out.
pub type LossGrad<'a> = &'a mut dyn FnMut(&Dense<f32>) -> (f32, Dense<f32>);

/// The uniform interface every GNN architecture exposes to the training
/// engines. There is exactly one execution path — the sampled-block one;
/// [`GnnModel::forward`]/[`GnnModel::train_step`] run it over identity
/// blocks of the model's bound graph.
pub trait GnnModel: Send {
    /// Build a model for a graph from an architecture-agnostic spec
    /// (expects self-loops already added).
    fn new_from_config(spec: &ModelSpec, graph: &Coo, seed: u64) -> Self
    where
        Self: Sized;

    /// Number of layers (== blocks per training step).
    fn num_layers(&self) -> usize;

    /// The execution mode the model was built with.
    fn mode(&self) -> TrainMode;

    /// Full-graph inference forward (identity-block execution).
    fn forward(&self, features: &Dense<f32>) -> Dense<f32>;

    /// Inference forward over per-layer sampled [`Block`]s; `x0` holds the
    /// input features of `blocks[0]`'s source nodes.
    fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32>;

    /// One full-graph training step (identity-block execution): forward,
    /// caller-supplied loss grad, backward, FP32 parameter update. Returns
    /// `(loss, logits)`.
    fn train_step(&mut self, features: &Dense<f32>, opt: &mut Sgd, loss_grad: LossGrad)
        -> (f32, Dense<f32>);

    /// One mini-batch training step over sampled blocks; `loss_grad` sees
    /// logits for the final block's destination (seed) rows.
    fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>);

    /// One mini-batch training step whose input features arrive bit-packed
    /// ([`QuantRows`], straight from the quantized gather). The default
    /// dequantizes to FP32 and runs [`GnnModel::train_step_blocks`]; models
    /// whose first layer can consume packed rows directly (GCN's layer-0
    /// GEMM) override this to skip the round-trip.
    fn train_step_packed(
        &mut self,
        blocks: &[Block],
        x0: &QuantRows,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        self.train_step_blocks(blocks, &x0.dequantize(), opt, loss_grad)
    }

    /// One mini-batch training step over whatever input form the pipeline
    /// produced ([`BatchInput`]): FP32 rows go to
    /// [`GnnModel::train_step_blocks`], packed rows to
    /// [`GnnModel::train_step_packed`].
    fn train_step_input(
        &mut self,
        blocks: &[Block],
        x0: &BatchInput,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        match x0 {
            BatchInput::F32(x) => self.train_step_blocks(blocks, x, opt, loss_grad),
            BatchInput::Packed(q) => self.train_step_packed(blocks, q, opt, loss_grad),
        }
    }

    /// The output of the *first layer* in the current state, evaluated in
    /// FP32 — the tensor the bit-derivation rule (Fig. 2) probes.
    fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32>;

    /// Total parameter count.
    fn num_params(&self) -> usize;

    /// Flatten all parameters — the multi-worker all-reduce layout.
    fn params_flat(&self) -> Vec<f32>;

    /// Load parameters from a flat buffer (inverse of
    /// [`GnnModel::params_flat`]).
    fn set_params_flat(&mut self, flat: &[f32]);
}

/// The one model dispatcher in the crate. Training engines hold an
/// `AnyModel` and talk to it through [`GnnModel`]; adding an architecture
/// means one new variant here plus a [`GnnModel`] impl — no engine changes.
pub enum AnyModel {
    /// Graph Convolutional Network (GEMM + SPMM).
    Gcn(GcnModel),
    /// Graph Attention Network (GEMM + SPMM + SDDMM).
    Gat(GatModel),
}

impl GnnModel for AnyModel {
    fn new_from_config(spec: &ModelSpec, graph: &Coo, seed: u64) -> Self {
        match spec.kind {
            ModelKind::Gcn => AnyModel::Gcn(GcnModel::new_from_config(spec, graph, seed)),
            ModelKind::Gat => AnyModel::Gat(GatModel::new_from_config(spec, graph, seed)),
        }
    }

    fn num_layers(&self) -> usize {
        match self {
            AnyModel::Gcn(m) => m.num_layers(),
            AnyModel::Gat(m) => m.num_layers(),
        }
    }

    fn mode(&self) -> TrainMode {
        match self {
            AnyModel::Gcn(m) => GnnModel::mode(m),
            AnyModel::Gat(m) => GnnModel::mode(m),
        }
    }

    fn forward(&self, features: &Dense<f32>) -> Dense<f32> {
        match self {
            AnyModel::Gcn(m) => m.forward(features),
            AnyModel::Gat(m) => m.forward(features),
        }
    }

    fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32> {
        match self {
            AnyModel::Gcn(m) => m.forward_blocks(blocks, x0),
            AnyModel::Gat(m) => m.forward_blocks(blocks, x0),
        }
    }

    fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        match self {
            AnyModel::Gcn(m) => m.train_step(features, opt, |lg| loss_grad(lg)),
            AnyModel::Gat(m) => m.train_step(features, opt, |lg| loss_grad(lg)),
        }
    }

    fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        match self {
            AnyModel::Gcn(m) => m.train_step_blocks(blocks, x0, opt, |lg| loss_grad(lg)),
            AnyModel::Gat(m) => m.train_step_blocks(blocks, x0, opt, |lg| loss_grad(lg)),
        }
    }

    fn train_step_packed(
        &mut self,
        blocks: &[Block],
        x0: &QuantRows,
        opt: &mut Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        match self {
            AnyModel::Gcn(m) => m.train_step_packed(blocks, x0, opt, loss_grad),
            AnyModel::Gat(m) => m.train_step_packed(blocks, x0, opt, loss_grad),
        }
    }

    fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32> {
        match self {
            AnyModel::Gcn(m) => m.first_layer_output(features),
            AnyModel::Gat(m) => m.first_layer_output(features),
        }
    }

    fn num_params(&self) -> usize {
        match self {
            AnyModel::Gcn(m) => m.num_params(),
            AnyModel::Gat(m) => m.num_params(),
        }
    }

    fn params_flat(&self) -> Vec<f32> {
        match self {
            AnyModel::Gcn(m) => m.params_flat(),
            AnyModel::Gat(m) => m.params_flat(),
        }
    }

    fn set_params_flat(&mut self, flat: &[f32]) {
        match self {
            AnyModel::Gcn(m) => m.set_params_flat(flat),
            AnyModel::Gat(m) => m.set_params_flat(flat),
        }
    }
}

impl AnyModel {
    /// Training steps taken so far — the counter that seeds each step's
    /// stochastic-rounding streams. Checkpoints must carry it: restoring
    /// parameters without it would replay different rounding noise.
    pub fn step_count(&self) -> u64 {
        match self {
            AnyModel::Gcn(m) => m.step_count,
            AnyModel::Gat(m) => m.step_count,
        }
    }

    /// Restore the step counter (resume-from-checkpoint).
    pub fn set_step_count(&mut self, steps: u64) {
        match self {
            AnyModel::Gcn(m) => m.step_count = steps,
            AnyModel::Gat(m) => m.step_count = steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_constructors_match_paper_arms() {
        let t = TrainMode::tango(8);
        assert!(t.quantize && t.stochastic && t.fp32_pre_softmax && !t.exact_style);
        let t1 = TrainMode::tango_test1(8);
        assert!(!t1.fp32_pre_softmax && t1.quantize);
        let t2 = TrainMode::tango_test2(8);
        assert!(!t2.stochastic && t2.quantize);
        let e = TrainMode::exact(8);
        assert!(e.exact_style && !e.quantize);
        let f = TrainMode::fp32();
        assert!(!f.quantize && !f.exact_style);
        // Every paper arm starts on the dense-i8 reference backend; packed
        // compute is opted into via TrainConfig::packed_compute.
        for m in [t, t1, t2, e, f] {
            assert_eq!(m.backend, PrimitiveBackend::Dequantize);
        }
    }

    #[test]
    fn rounding_is_deterministic_per_step() {
        let m = TrainMode::tango(8);
        assert_eq!(m.rounding(3, 1), m.rounding(3, 1));
        assert_ne!(m.rounding(3, 1), m.rounding(4, 1));
        assert_ne!(m.rounding(3, 1), m.rounding(3, 2));
        assert_eq!(TrainMode::tango_test2(8).rounding(5, 0), Rounding::Nearest);
    }

    #[test]
    fn any_model_dispatches_both_architectures() {
        let d = crate::graph::datasets::tiny(7);
        for kind in [ModelKind::Gcn, ModelKind::Gat] {
            let spec = ModelSpec {
                kind,
                in_dim: d.features.cols(),
                hidden: 16,
                out_dim: d.num_classes,
                heads: 4,
                layers: 2,
                mode: TrainMode::fp32(),
            };
            let mut m = AnyModel::new_from_config(&spec, &d.graph, 42);
            assert_eq!(m.num_layers(), 2);
            assert!(m.num_params() > 0);
            let out = m.forward(&d.features);
            assert_eq!(out.shape(), &[d.graph.num_nodes, d.num_classes]);
            let p = m.params_flat();
            assert_eq!(p.len(), m.num_params());
            let mut opt = Sgd::new(0.05);
            let (labels, nodes) = (d.labels.clone(), d.train_nodes.clone());
            let (loss, _) = m.train_step(&d.features, &mut opt, &mut |lg| {
                softmax_cross_entropy(lg, &labels, &nodes)
            });
            assert!(loss.is_finite());
            // Round-trip the flat parameters through the trait.
            let p2 = m.params_flat();
            assert_ne!(p, p2, "the step must move parameters");
            m.set_params_flat(&p);
            assert_eq!(m.params_flat(), p);
        }
    }
}
