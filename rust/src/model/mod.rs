//! GNN models (GCN and GAT) with explicit forward/backward passes composed
//! from the three primitives, exactly following the paper's §2.1
//! decomposition (Fig. 1a/1b).
//!
//! The models run in one of several [`TrainMode`]s that map onto the
//! paper's evaluation arms:
//!
//! | mode | paper name |
//! |---|---|
//! | [`TrainMode::fp32`] | DGL (full-precision baseline) |
//! | [`TrainMode::tango`] | Tango |
//! | [`TrainMode::tango_test1`] | Test1 — quantized layer before Softmax |
//! | [`TrainMode::tango_test2`] | Test2 — nearest instead of stochastic rounding |
//! | [`TrainMode::exact`] | EXACT — quantize for memory, dequantize to compute |
//!
//! The accuracy rules of §3.2 are enforced structurally: weight updates are
//! always FP32 ([`optim`]), the layer feeding the final softmax stays FP32
//! unless `fp32_pre_softmax` is disabled (Test1), and stochastic rounding
//! seeds derive from the step counter so training is reproducible.

pub mod eval;
pub mod gat;
pub mod gcn;
pub mod loss;
pub mod optim;

pub use eval::{accuracy, auc};
pub use gat::{GatConfig, GatModel};
pub use gcn::{GcnConfig, GcnModel};
pub use loss::{bce_with_logits, softmax_cross_entropy};
pub use optim::Sgd;

use crate::quant::Rounding;

/// How a training step executes its primitives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainMode {
    /// Use Tango's quantized primitives (GEMM/SPMM/SDDMM).
    pub quantize: bool,
    /// Stochastic rounding (true) vs nearest (false — the Test2 ablation).
    pub stochastic: bool,
    /// Keep the layer feeding the final softmax in FP32 (§3.2 rule;
    /// false — the Test1 ablation).
    pub fp32_pre_softmax: bool,
    /// EXACT-style execution: tensors are quantized for storage and
    /// dequantized back to FP32 before every compute — memory savings with
    /// *added* work, the baseline Fig. 8 shows losing to both DGL and Tango.
    pub exact_style: bool,
    /// Quantization bit width.
    pub bits: u8,
}

impl TrainMode {
    /// Full-precision baseline (the paper's "DGL").
    pub fn fp32() -> Self {
        TrainMode { quantize: false, stochastic: false, fp32_pre_softmax: true, exact_style: false, bits: 8 }
    }

    /// Tango with all accuracy rules on.
    pub fn tango(bits: u8) -> Self {
        TrainMode { quantize: true, stochastic: true, fp32_pre_softmax: true, exact_style: false, bits }
    }

    /// Fig. 7 "Test1": Tango but the pre-softmax layer is quantized too.
    pub fn tango_test1(bits: u8) -> Self {
        TrainMode { fp32_pre_softmax: false, ..Self::tango(bits) }
    }

    /// Fig. 7 "Test2": Tango with nearest instead of stochastic rounding.
    pub fn tango_test2(bits: u8) -> Self {
        TrainMode { stochastic: false, ..Self::tango(bits) }
    }

    /// The EXACT-style baseline of Fig. 8.
    pub fn exact(bits: u8) -> Self {
        TrainMode { quantize: false, stochastic: false, fp32_pre_softmax: true, exact_style: true, bits }
    }

    /// Rounding mode for a given training step (seeds derive from the step
    /// counter and a stream id, so runs are reproducible).
    pub fn rounding(&self, step: u64, stream: u64) -> Rounding {
        if self.stochastic {
            Rounding::Stochastic { seed: step.wrapping_mul(0x9E3779B97F4A7C15) ^ stream }
        } else {
            Rounding::Nearest
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_constructors_match_paper_arms() {
        let t = TrainMode::tango(8);
        assert!(t.quantize && t.stochastic && t.fp32_pre_softmax && !t.exact_style);
        let t1 = TrainMode::tango_test1(8);
        assert!(!t1.fp32_pre_softmax && t1.quantize);
        let t2 = TrainMode::tango_test2(8);
        assert!(!t2.stochastic && t2.quantize);
        let e = TrainMode::exact(8);
        assert!(e.exact_style && !e.quantize);
        let f = TrainMode::fp32();
        assert!(!f.quantize && !f.exact_style);
    }

    #[test]
    fn rounding_is_deterministic_per_step() {
        let m = TrainMode::tango(8);
        assert_eq!(m.rounding(3, 1), m.rounding(3, 1));
        assert_ne!(m.rounding(3, 1), m.rounding(4, 1));
        assert_ne!(m.rounding(3, 1), m.rounding(3, 2));
        assert_eq!(TrainMode::tango_test2(8).rounding(5, 0), Rounding::Nearest);
    }
}
