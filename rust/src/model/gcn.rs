//! GCN (Kipf & Welling) with explicit backward, in FP32 / Tango-quantized /
//! EXACT-style execution.
//!
//! Per layer: `Z = Â · (X · W)`, `Â` the symmetrically normalised adjacency
//! (encoded as one weight per edge), ReLU between layers. Per the paper
//! (§2.2) GCN exercises the GEMM and SPMM primitives.
//!
//! There is a **single** forward/backward implementation — the
//! sampled-block one. Full-graph training runs the same code over per-layer
//! copies of the graph's identity block ([`Block::identity`]), whose
//! CSR/COO/norm layouts are bit-for-bit the full graph's, so both modes
//! share every numeric property below:
//!
//! - GEMM runs as [`qgemm`] with fused output scale; the quantized inputs
//!   (`X_q`, `W_q`) are cached for the backward GEMMs (Fig. 10 reuse);
//! - SPMM runs on INT8 payloads through the
//!   [`crate::primitives::PrimitiveBackend`] seam (dense-i8 or bit-packed
//!   kernels — bit-identical arms); sampled blocks quantize their edge
//!   norms per step (they change every batch), while the static
//!   identity-block norms are quantized once at build — with deterministic
//!   nearest rounding the two are bit-identical;
//! - the backward gradient `∂(XW)` is quantized **once** and reused by both
//!   backward GEMMs — the inter-primitive caching rule (§3.3);
//! - the final layer stays FP32 while `fp32_pre_softmax` is set (§3.2).

use super::{GnnModel, LossGrad, ModelSpec, TrainMode};
use crate::graph::Coo;
use crate::primitives::{gemm_f32, packed_qgemm, qgemm, qgemm_prequantized, spmm_csr_values};
use crate::quant::rng::Xoshiro256pp;
use crate::quant::{dequantize, quantize, QTensor, Rounding};
use crate::sampler::{Block, QuantRows};
use crate::tensor::Dense;
use std::sync::Arc;

/// GCN hyperparameters (paper §4.1: hidden 128, two layers).
#[derive(Debug, Clone, Copy)]
pub struct GcnConfig {
    /// Input feature dimension.
    pub in_dim: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Output dimension (classes for NC, embedding width for LP).
    pub out_dim: usize,
    /// Number of layers (≥1).
    pub layers: usize,
    /// Execution mode.
    pub mode: TrainMode,
}

struct GcnLayer {
    w: Dense<f32>,
    grad_w: Dense<f32>,
}

/// Per-layer forward cache for the backward pass.
struct LayerCache {
    x: Dense<f32>,
    z: Dense<f32>,
    /// Quantized `X` kept from the forward GEMM (Fig. 10 reuse).
    qx: Option<QTensor>,
    /// Quantized `W` kept from the forward GEMM.
    qw: Option<QTensor>,
    /// Quantized block edge norms — quantized once per step in the forward
    /// and reused by the backward SPMM (§3.3).
    qnorm: Option<QTensor>,
}

/// A GCN model bound to one graph.
pub struct GcnModel {
    /// Config used to build the model.
    pub cfg: GcnConfig,
    layers: Vec<GcnLayer>,
    /// The bound graph as an identity block — the full-graph execution mode
    /// is [`Self::train_step_blocks`] over `layers` copies of this.
    full_block: Arc<Block>,
    /// The identity block's edge norms, quantized once at build (they are
    /// static; sampled blocks re-quantize per step because they change).
    full_qnorm: QTensor,
    /// Step counter (drives stochastic-rounding seeds).
    pub step_count: u64,
}

impl GcnModel {
    /// Build the model for a graph (expects self-loops already added).
    pub fn new(cfg: GcnConfig, graph: &Coo, seed: u64) -> Self {
        assert!(cfg.layers >= 1);
        let full_block = Arc::new(Block::identity(graph, &graph.in_degrees()));
        let full_qnorm = Self::quantize_block_norm(&full_block, cfg.mode.bits);
        let mut rng = Xoshiro256pp::new(seed);
        let mut layers = Vec::new();
        for l in 0..cfg.layers {
            let (fan_in, fan_out) = (Self::dim_at(&cfg, l), Self::dim_at(&cfg, l + 1));
            // Glorot-uniform init.
            let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
            let data = (0..fan_in * fan_out).map(|_| (rng.next_f32() * 2.0 - 1.0) * limit).collect();
            layers.push(GcnLayer {
                w: Dense::from_vec(&[fan_in, fan_out], data),
                grad_w: Dense::zeros(&[fan_in, fan_out]),
            });
        }
        GcnModel { cfg, layers, full_block, full_qnorm, step_count: 0 }
    }

    fn dim_at(cfg: &GcnConfig, boundary: usize) -> usize {
        if boundary == 0 {
            cfg.in_dim
        } else if boundary == cfg.layers {
            cfg.out_dim
        } else {
            cfg.hidden
        }
    }

    /// Whether layer `l` runs quantized under the current mode (§3.2: the
    /// layer feeding the softmax stays FP32 unless Test1).
    fn layer_quantized(&self, l: usize) -> bool {
        self.cfg.mode.quantize && (l + 1 < self.cfg.layers || !self.cfg.mode.fp32_pre_softmax)
    }

    /// EXACT-style "compress then decompress" pass (pure overhead at
    /// compute time — models the Fig. 8 EXACT baseline).
    fn exact_roundtrip(&self, x: &Dense<f32>) -> Dense<f32> {
        dequantize(&quantize(x, self.cfg.mode.bits, Rounding::Nearest))
    }

    /// Per-layer references to the identity block — the full-graph training
    /// "blocks" (cheap: one `&Block` per layer, no graph copies).
    fn full_refs(full_block: &Arc<Block>, layers: usize) -> Vec<&Block> {
        (0..layers).map(|_| full_block.as_ref()).collect()
    }

    /// Forward over per-layer blocks, returning logits for the final
    /// block's destination nodes plus the caches backward needs.
    ///
    /// `x0` holds the input features of `blocks[0]`'s source nodes; layer
    /// `l` aggregates over `blocks[l]`, shrinking the row set from
    /// `blocks[l].num_src()` to `blocks[l].num_dst`.
    fn forward_blocks_cached(
        &self,
        blocks: &[&Block],
        x0: &Dense<f32>,
    ) -> (Dense<f32>, Vec<LayerCache>) {
        assert_eq!(blocks.len(), self.layers.len(), "one block per layer");
        let mut caches = Vec::with_capacity(self.layers.len());
        let out = self.forward_layers_from(blocks, x0.clone(), 0, &mut caches);
        (out, caches)
    }

    /// The shared per-layer forward loop from layer `start` on; `x` holds
    /// input rows for `blocks[start]`'s source nodes. Packed-input steps
    /// ([`Self::forward_blocks_packed`]) run layer 0 on the packed rows and
    /// re-enter here at `start = 1`.
    fn forward_layers_from(
        &self,
        blocks: &[&Block],
        mut x: Dense<f32>,
        start: usize,
        caches: &mut Vec<LayerCache>,
    ) -> Dense<f32> {
        let mode = self.cfg.mode;
        for (l, layer) in self.layers.iter().enumerate().skip(start) {
            let blk = blocks[l];
            assert_eq!(x.rows(), blk.num_src(), "layer {l}: input rows != block src nodes");
            let (xw, qx, qw) = if self.layer_quantized(l) {
                let r = qgemm(&x, &layer.w, mode.bits, mode.rounding(self.step_count, l as u64));
                (r.out, Some(r.qa), Some(r.qb))
            } else if mode.exact_style {
                let x2 = self.exact_roundtrip(&x);
                let w2 = self.exact_roundtrip(&layer.w);
                (gemm_f32(&x2, &w2), None, None)
            } else {
                (gemm_f32(&x, &layer.w), None, None)
            };
            let (z, qnorm) = if self.layer_quantized(l) {
                let qxw = quantize(&xw, mode.bits, mode.rounding(self.step_count, 100 + l as u64));
                // Identity block (full-graph mode): its norms are static, so
                // reuse the build-time quantization (nearest rounding makes
                // it bit-identical to re-quantizing — see the tests).
                let qnorm = if std::ptr::eq(blk, self.full_block.as_ref()) {
                    self.full_qnorm.clone()
                } else {
                    Self::quantize_block_norm(blk, mode.bits)
                };
                (mode.backend.qspmm(&blk.csr, &qnorm, &qxw, 1), Some(qnorm))
            } else if mode.exact_style {
                (spmm_csr_values(&blk.csr, &blk.norm, &self.exact_roundtrip(&xw)), None)
            } else {
                (spmm_csr_values(&blk.csr, &blk.norm, &xw), None)
            };
            let out = if l + 1 < self.layers.len() { relu(&z) } else { z.clone() };
            caches.push(LayerCache { x: x.clone(), z, qx, qw, qnorm });
            x = out;
        }
        x
    }

    /// Packed-input forward: layer 0's GEMM consumes the bit-packed gather
    /// output directly ([`packed_qgemm`]) — the rows are never expanded to
    /// one-slot-per-element i8, let alone FP32. Later layers re-enter the
    /// shared loop. Callers must have checked [`Self::layer_quantized`]`(0)`.
    fn forward_blocks_packed(
        &self,
        blocks: &[&Block],
        x0: &QuantRows,
    ) -> (Dense<f32>, Vec<LayerCache>) {
        assert_eq!(blocks.len(), self.layers.len(), "one block per layer");
        let mode = self.cfg.mode;
        let blk = blocks[0];
        assert_eq!(x0.rows(), blk.num_src(), "layer 0: input rows != block src nodes");
        let layer = &self.layers[0];
        let qw = quantize(&layer.w, mode.bits, mode.rounding(self.step_count, 0));
        let (xw, _) = packed_qgemm(x0, &qw, mode.bits);
        // Backward's ∂W GEMM wants `X_q` as a dense single-scale tensor:
        // reuse the packed rows when their policy is uniform, else
        // re-quantize the dequantized rows at one batch-level scale.
        let qx = x0.to_qtensor().unwrap_or_else(|| {
            quantize(&x0.dequantize(), mode.bits, mode.rounding(self.step_count, 0))
        });
        let qxw = quantize(&xw, mode.bits, mode.rounding(self.step_count, 100));
        let qnorm = if std::ptr::eq(blk, self.full_block.as_ref()) {
            self.full_qnorm.clone()
        } else {
            Self::quantize_block_norm(blk, mode.bits)
        };
        let z = mode.backend.qspmm(&blk.csr, &qnorm, &qxw, 1);
        let out = if self.layers.len() > 1 { relu(&z) } else { z.clone() };
        let mut caches = Vec::with_capacity(self.layers.len());
        // The FP32 input is never materialized on this path; the quantized
        // backward arm reads only `qx`/`qw`/`qnorm`, so cache an empty `x`.
        caches.push(LayerCache {
            x: Dense::zeros(&[0, 0]),
            z,
            qx: Some(qx),
            qw: Some(qw),
            qnorm: Some(qnorm),
        });
        let logits = self.forward_layers_from(blocks, out, 1, &mut caches);
        (logits, caches)
    }

    /// Per-block edge norms as a quantized `[E, 1]` tensor. Deterministic
    /// nearest rounding: quantizing the same (static) norms every step
    /// yields bit-identical values, so nothing is lost versus quantizing
    /// once at build.
    fn quantize_block_norm(blk: &Block, bits: u8) -> QTensor {
        quantize(
            &Dense::from_vec(&[blk.norm.len(), 1], blk.norm.clone()),
            bits,
            Rounding::Nearest,
        )
    }

    /// Inference-only forward over the full graph (identity blocks).
    pub fn forward(&self, features: &Dense<f32>) -> Dense<f32> {
        let refs = Self::full_refs(&self.full_block, self.layers.len());
        self.forward_blocks_cached(&refs, features).0
    }

    /// Inference-only forward over sampled blocks.
    pub fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32> {
        let refs: Vec<&Block> = blocks.iter().collect();
        self.forward_blocks_cached(&refs, x0).0
    }

    /// One full-graph training step — the identity-block run of
    /// [`Self::train_step_blocks`]. `loss_grad(logits) -> (loss, ∂logits)`.
    pub fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let full = Arc::clone(&self.full_block);
        let refs = Self::full_refs(&full, self.layers.len());
        self.train_step_refs(&refs, features, opt, loss_grad)
    }

    /// One mini-batch training step over sampled blocks; `loss_grad` sees
    /// logits for the final block's destination nodes, in
    /// `blocks.last().dst_nodes()` order.
    pub fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let refs: Vec<&Block> = blocks.iter().collect();
        self.train_step_refs(&refs, x0, opt, loss_grad)
    }

    /// One mini-batch training step whose input arrives bit-packed. When
    /// layer 0 runs quantized its GEMM consumes the packed rows in place
    /// ([`packed_qgemm`]); otherwise (FP32 / EXACT first layer) this falls
    /// back to dequantizing into the dense-input step.
    pub fn train_step_packed_rows(
        &mut self,
        blocks: &[Block],
        x0: &QuantRows,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        if !self.layer_quantized(0) {
            return self.train_step_blocks(blocks, &x0.dequantize(), opt, loss_grad);
        }
        let refs: Vec<&Block> = blocks.iter().collect();
        let (logits, caches) = self.forward_blocks_packed(&refs, x0);
        let (loss, dlogits) = loss_grad(&logits);
        self.backward_blocks(&refs, &caches, dlogits);
        for (i, layer) in self.layers.iter_mut().enumerate() {
            opt.step(i, &mut layer.w, &layer.grad_w);
        }
        self.step_count += 1;
        (loss, logits)
    }

    fn train_step_refs(
        &mut self,
        blocks: &[&Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let (logits, caches) = self.forward_blocks_cached(blocks, x0);
        let (loss, dlogits) = loss_grad(&logits);
        self.backward_blocks(blocks, &caches, dlogits);
        for (i, layer) in self.layers.iter_mut().enumerate() {
            opt.step(i, &mut layer.w, &layer.grad_w);
        }
        self.step_count += 1;
        (loss, logits)
    }

    /// Backward over blocks: the reversed aggregation runs on each block's
    /// source-grouped CSR, expanding gradients from `num_dst` back to
    /// `num_src` rows before the weight GEMMs. `∂(XW)` is quantized ONCE
    /// and shared by both GEMMs; `X_q`/`W_q` come from the forward cache
    /// (inter-primitive reuse, §3.3).
    fn backward_blocks(&mut self, blocks: &[&Block], caches: &[LayerCache], mut grad: Dense<f32>) {
        let mode = self.cfg.mode;
        for l in (0..self.layers.len()).rev() {
            let blk = blocks[l];
            let cache = &caches[l];
            if l + 1 < self.layers.len() {
                grad = relu_backward(&cache.z, &grad);
            }
            // ∂(XW) = Âᵀ · ∂Z (SPMM on the reversed graph, Fig. 1b step 4).
            let dxw = if self.layer_quantized(l) {
                let qg = quantize(&grad, mode.bits, mode.rounding(self.step_count, 200 + l as u64));
                // Reuse the forward's quantized block norms (§3.3 rule).
                let qnorm = cache.qnorm.as_ref().expect("forward cached block qnorm");
                mode.backend.qspmm(&blk.csr_rev, qnorm, &qg, 1)
            } else if mode.exact_style {
                spmm_csr_values(&blk.csr_rev, &blk.norm, &self.exact_roundtrip(&grad))
            } else {
                spmm_csr_values(&blk.csr_rev, &blk.norm, &grad)
            };
            // ∂W = Xᵀ·∂(XW) and ∂X = ∂(XW)·Wᵀ.
            if self.layer_quantized(l) {
                let qdxw = quantize(&dxw, mode.bits, mode.rounding(self.step_count, 300 + l as u64));
                let qx = cache.qx.as_ref().expect("forward cached qx");
                let qw = cache.qw.as_ref().expect("forward cached qw");
                let (gw, _) = qgemm_prequantized(&qx.transpose2d(), &qdxw, mode.bits);
                self.layers[l].grad_w = gw;
                if l > 0 {
                    let (gx, _) = qgemm_prequantized(&qdxw, &qw.transpose2d(), mode.bits);
                    grad = gx;
                }
            } else if mode.exact_style {
                let x2 = self.exact_roundtrip(&cache.x);
                let d2 = self.exact_roundtrip(&dxw);
                self.layers[l].grad_w = gemm_f32(&x2.transpose(), &d2);
                if l > 0 {
                    grad = gemm_f32(&d2, &self.exact_roundtrip(&self.layers[l].w).transpose());
                }
            } else {
                self.layers[l].grad_w = gemm_f32(&cache.x.transpose(), &dxw);
                if l > 0 {
                    grad = gemm_f32(&dxw, &self.layers[l].w.transpose());
                }
            }
        }
    }

    /// The output of the *first layer* in the current state — the tensor the
    /// bit-derivation rule (Fig. 2) evaluates (always FP32).
    pub fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32> {
        let xw = gemm_f32(features, &self.layers[0].w);
        spmm_csr_values(&self.full_block.csr, &self.full_block.norm, &xw)
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len()).sum()
    }

    /// Flatten all parameters (layer order) — used by the multi-worker
    /// all-reduce.
    pub fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            out.extend_from_slice(l.w.data());
        }
        out
    }

    /// Load parameters from a flat buffer (inverse of [`Self::params_flat`]).
    pub fn set_params_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.num_params());
        let mut off = 0;
        for l in &mut self.layers {
            let n = l.w.len();
            l.w.data_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }
}

impl GnnModel for GcnModel {
    fn new_from_config(spec: &ModelSpec, graph: &Coo, seed: u64) -> Self {
        GcnModel::new(
            GcnConfig {
                in_dim: spec.in_dim,
                hidden: spec.hidden,
                out_dim: spec.out_dim,
                layers: spec.layers,
                mode: spec.mode,
            },
            graph,
            seed,
        )
    }

    fn num_layers(&self) -> usize {
        self.layers.len()
    }

    fn mode(&self) -> TrainMode {
        self.cfg.mode
    }

    fn forward(&self, features: &Dense<f32>) -> Dense<f32> {
        GcnModel::forward(self, features)
    }

    fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32> {
        GcnModel::forward_blocks(self, blocks, x0)
    }

    fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        GcnModel::train_step(self, features, opt, |lg| loss_grad(lg))
    }

    fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        GcnModel::train_step_blocks(self, blocks, x0, opt, |lg| loss_grad(lg))
    }

    fn train_step_packed(
        &mut self,
        blocks: &[Block],
        x0: &QuantRows,
        opt: &mut super::Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        GcnModel::train_step_packed_rows(self, blocks, x0, opt, |lg| loss_grad(lg))
    }

    fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32> {
        GcnModel::first_layer_output(self, features)
    }

    fn num_params(&self) -> usize {
        GcnModel::num_params(self)
    }

    fn params_flat(&self) -> Vec<f32> {
        GcnModel::params_flat(self)
    }

    fn set_params_flat(&mut self, flat: &[f32]) {
        GcnModel::set_params_flat(self, flat)
    }
}

fn relu(x: &Dense<f32>) -> Dense<f32> {
    x.map(|v| v.max(0.0))
}

fn relu_backward(pre: &Dense<f32>, grad: &Dense<f32>) -> Dense<f32> {
    assert_eq!(pre.shape(), grad.shape());
    let mut out = grad.clone();
    for (g, &z) in out.data_mut().iter_mut().zip(pre.data().iter()) {
        if z <= 0.0 {
            *g = 0.0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::model::{softmax_cross_entropy, Sgd};

    fn tiny_model(mode: TrainMode) -> (GcnModel, datasets::Dataset) {
        let d = datasets::tiny(7);
        let cfg = GcnConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            layers: 2,
            mode,
        };
        (GcnModel::new(cfg, &d.graph, 42), d)
    }

    fn train_losses(mode: TrainMode, steps: usize) -> Vec<f32> {
        let (mut m, d) = tiny_model(mode);
        let mut opt = Sgd::new(0.05);
        let mut losses = Vec::new();
        for _ in 0..steps {
            let (loss, _) = m.train_step(&d.features, &mut opt, |logits| {
                softmax_cross_entropy(logits, &d.labels, &d.train_nodes)
            });
            losses.push(loss);
        }
        losses
    }

    #[test]
    fn fp32_training_reduces_loss() {
        let losses = train_losses(TrainMode::fp32(), 30);
        assert!(losses[29] < losses[0] * 0.8, "{:?}", &losses[..3]);
    }

    #[test]
    fn quantized_training_reduces_loss() {
        let losses = train_losses(TrainMode::tango(8), 30);
        assert!(losses[29] < losses[0] * 0.85, "{losses:?}");
    }

    #[test]
    fn exact_style_matches_fp32_closely() {
        // EXACT computes in FP32 after a quantize/dequantize round-trip, so
        // its loss curve should track FP32 within quantization noise.
        let a = train_losses(TrainMode::fp32(), 10);
        let b = train_losses(TrainMode::exact(8), 10);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 0.3, "{x} vs {y}");
        }
    }

    #[test]
    fn quantized_final_accuracy_close_to_fp32() {
        // The paper's headline accuracy claim (>99% of FP32) at test scale.
        let run = |mode| {
            let (mut m, d) = tiny_model(mode);
            let mut opt = Sgd::new(0.05);
            for _ in 0..60 {
                m.train_step(&d.features, &mut opt, |logits| {
                    softmax_cross_entropy(logits, &d.labels, &d.train_nodes)
                });
            }
            let logits = m.forward(&d.features);
            crate::model::accuracy(&logits, &d.labels, &d.eval_nodes)
        };
        let fp = run(TrainMode::fp32());
        let tg = run(TrainMode::tango(8));
        assert!(tg >= fp - 0.1, "tango {tg} vs fp32 {fp}");
    }

    #[test]
    fn gradient_check_fp32_tiny() {
        // Finite-difference check of ∂W on a 6-node graph.
        let g = crate::graph::generators::erdos_renyi(6, 12, 3).with_self_loops();
        let cfg = GcnConfig { in_dim: 3, hidden: 4, out_dim: 2, layers: 2, mode: TrainMode::fp32() };
        let mut m = GcnModel::new(cfg, &g, 1);
        let feats = crate::graph::generators::random_features(6, 3, 2);
        let labels = vec![0u32, 1, 0, 1, 0, 1];
        let nodes: Vec<u32> = (0..6).collect();

        let loss_of = |m: &GcnModel| -> f32 {
            let logits = m.forward(&feats);
            softmax_cross_entropy(&logits, &labels, &nodes).0
        };
        // Compute analytic grads without updating params (lr = 0).
        let mut opt = Sgd::new(0.0);
        m.train_step(&feats, &mut opt, |logits| softmax_cross_entropy(logits, &labels, &nodes));
        let eps = 1e-2f32;
        for l in 0..2 {
            for &idx in &[0usize, 3, 7] {
                let orig = m.layers[l].w.data()[idx];
                m.layers[l].w.data_mut()[idx] = orig + eps;
                let fp = loss_of(&m);
                m.layers[l].w.data_mut()[idx] = orig - eps;
                let fm = loss_of(&m);
                m.layers[l].w.data_mut()[idx] = orig;
                let fd = (fp - fm) / (2.0 * eps);
                let an = m.layers[l].grad_w.data()[idx];
                assert!((fd - an).abs() < 3e-2, "layer {l} idx {idx}: fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn block_path_matches_full_graph_fp32() {
        // Blocks with full fanout over every node are the whole graph in
        // MFG clothing — forward and one training step must agree with the
        // full-graph (identity-block) path up to float summation order.
        use crate::graph::Csr;
        use crate::sampler::{gather_rows, NeighborSampler};
        let d = datasets::tiny(7);
        let cfg = GcnConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            layers: 2,
            mode: TrainMode::fp32(),
        };
        let mut full = GcnModel::new(cfg, &d.graph, 42);
        let mut blocked = GcnModel::new(cfg, &d.graph, 42);
        let csr = Csr::from_coo(&d.graph);
        let degrees = d.graph.in_degrees();
        let seeds: Vec<u32> = (0..d.graph.num_nodes as u32).collect();
        let sampler = NeighborSampler::new(vec![1 << 30, 1 << 30], 1);
        let blocks = sampler.sample_blocks(&csr, &degrees, &seeds, 0);
        let x0 = gather_rows(&d.features, &blocks[0].src_nodes);
        assert_eq!(x0, d.features, "full-fanout all-node frontier is the identity");

        let a = full.forward(&d.features);
        let b = blocked.forward_blocks(&blocks, &x0);
        assert_eq!(a.shape(), b.shape());
        assert!(a.max_abs_diff(&b) < 1e-4, "forward diff {}", a.max_abs_diff(&b));

        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        let (la, _) = full.train_step(&d.features, &mut opt_a, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        let (lb, _) = blocked.train_step_blocks(&blocks, &x0, &mut opt_b, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        assert!((la - lb).abs() < 1e-4, "loss {la} vs {lb}");
        let pa = full.params_flat();
        let pb = blocked.params_flat();
        let max_diff = pa
            .iter()
            .zip(pb.iter())
            .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()));
        assert!(max_diff < 1e-4, "post-step param diff {max_diff}");
    }

    #[test]
    fn identity_blocks_replay_full_graph_exactly() {
        // The collapse invariant itself: explicitly passing `layers` copies
        // of the identity block to the block API is bit-identical to the
        // full-graph wrappers, in FP32 *and* quantized modes.
        for mode in [TrainMode::fp32(), TrainMode::tango(8)] {
            let (mut a, d) = tiny_model(mode);
            let (mut b, _) = tiny_model(mode);
            let ident = Block::identity(&d.graph, &d.graph.in_degrees());
            let blocks = vec![ident.clone(), ident];
            assert_eq!(a.forward(&d.features), b.forward_blocks(&blocks, &d.features));
            let mut opt_a = Sgd::new(0.05);
            let mut opt_b = Sgd::new(0.05);
            for _ in 0..3 {
                let (la, _) = a.train_step(&d.features, &mut opt_a, |lg| {
                    softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
                });
                let (lb, _) = b.train_step_blocks(&blocks, &d.features, &mut opt_b, |lg| {
                    softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
                });
                assert_eq!(la, lb, "losses must be bitwise equal");
            }
            assert_eq!(a.params_flat(), b.params_flat());
        }
    }

    #[test]
    fn packed_input_step_tracks_dense_step() {
        // Feeding the step bit-packed rows (layer-0 GEMM on packed bits)
        // must track the dense-input step that consumes the dequantized
        // copy of the same rows. With nearest rounding the quantized codes
        // survive the round-trip, so the two paths agree to float noise.
        use crate::sampler::QuantRows;
        let mode = TrainMode::tango_test2(8);
        let (mut dense_m, d) = tiny_model(mode);
        let (mut packed_m, _) = tiny_model(mode);
        let ident = Block::identity(&d.graph, &d.graph.in_degrees());
        let blocks = vec![ident.clone(), ident];
        let q = QuantRows::from_qtensor(&quantize(&d.features, 8, Rounding::Nearest));
        let x0 = q.dequantize();
        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        for _ in 0..3 {
            let (la, _) = dense_m.train_step_blocks(&blocks, &x0, &mut opt_a, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            let (lb, _) = packed_m.train_step_packed_rows(&blocks, &q, &mut opt_b, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            assert!(lb.is_finite());
            assert!((la - lb).abs() < 1e-3, "packed loss {lb} vs dense {la}");
        }
        let pa = dense_m.params_flat();
        let pb = packed_m.params_flat();
        let max_diff =
            pa.iter().zip(pb.iter()).fold(0.0f32, |m, (x, y)| m.max((x - y).abs()));
        assert!(max_diff < 1e-3, "post-step param diff {max_diff}");
    }

    #[test]
    fn packed_input_falls_back_when_layer0_is_fp32() {
        // FP32 mode can't consume packed rows in layer 0 — the packed step
        // must be *exactly* the dense step on the dequantized rows.
        use crate::sampler::QuantRows;
        let (mut a, d) = tiny_model(TrainMode::fp32());
        let (mut b, _) = tiny_model(TrainMode::fp32());
        let ident = Block::identity(&d.graph, &d.graph.in_degrees());
        let blocks = vec![ident.clone(), ident];
        let q = QuantRows::from_qtensor(&quantize(&d.features, 8, Rounding::Nearest));
        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        let (la, _) = a.train_step_blocks(&blocks, &q.dequantize(), &mut opt_a, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        let (lb, _) = b.train_step_packed_rows(&blocks, &q, &mut opt_b, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        assert_eq!(la, lb, "fallback must be bitwise the dense step");
        assert_eq!(a.params_flat(), b.params_flat());
    }

    #[test]
    fn packed_backend_replays_dequantize_backend_exactly() {
        // Flipping PrimitiveBackend::Packed on changes only *how* the SPMM
        // consumes its quantized operand — training must be bit-identical.
        use crate::primitives::PrimitiveBackend;
        let mut packed_mode = TrainMode::tango(8);
        packed_mode.backend = PrimitiveBackend::Packed;
        let (mut a, d) = tiny_model(TrainMode::tango(8));
        let (mut b, _) = tiny_model(packed_mode);
        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        for _ in 0..3 {
            let (la, _) = a.train_step(&d.features, &mut opt_a, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            let (lb, _) = b.train_step(&d.features, &mut opt_b, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            assert_eq!(la, lb, "losses must be bitwise equal across backends");
        }
        assert_eq!(a.params_flat(), b.params_flat());
    }

    #[test]
    fn sampled_minibatch_steps_reduce_loss() {
        use crate::graph::Csr;
        use crate::sampler::{gather_rows, shuffled_batches, NeighborSampler};
        let d = datasets::tiny(5);
        let cfg = GcnConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            layers: 2,
            mode: TrainMode::tango(8),
        };
        let mut m = GcnModel::new(cfg, &d.graph, 3);
        let csr = Csr::from_coo(&d.graph);
        let degrees = d.graph.in_degrees();
        let sampler = NeighborSampler::new(vec![8, 8], 13);
        let mut opt = Sgd::new(0.05);
        let mut epoch_means = Vec::new();
        for epoch in 0..15u64 {
            let mut total = 0.0f32;
            let mut steps = 0usize;
            for (bi, batch) in
                shuffled_batches(&d.train_nodes, 64, epoch).iter().enumerate()
            {
                let blocks = sampler.sample_blocks(&csr, &degrees, batch, (epoch << 8) ^ bi as u64);
                let x0 = gather_rows(&d.features, &blocks[0].src_nodes);
                let labels: Vec<u32> = batch.iter().map(|&v| d.labels[v as usize]).collect();
                let nodes: Vec<u32> = (0..batch.len() as u32).collect();
                let (loss, logits) = m.train_step_blocks(&blocks, &x0, &mut opt, |lg| {
                    softmax_cross_entropy(lg, &labels, &nodes)
                });
                assert_eq!(logits.rows(), batch.len());
                assert!(loss.is_finite());
                total += loss;
                steps += 1;
            }
            epoch_means.push(total / steps as f32);
        }
        let (first, last) = (epoch_means[0], *epoch_means.last().unwrap());
        assert!(last < first, "mean batch loss {first} -> {last}: {epoch_means:?}");
    }

    #[test]
    fn first_layer_output_shape() {
        let (m, d) = tiny_model(TrainMode::fp32());
        let out = m.first_layer_output(&d.features);
        assert_eq!(out.shape(), &[d.graph.num_nodes, 16]);
    }

    #[test]
    fn param_count() {
        let (m, d) = tiny_model(TrainMode::fp32());
        assert_eq!(m.num_params(), d.features.cols() * 16 + 16 * d.num_classes);
    }

    #[test]
    fn single_layer_model_works() {
        let g = crate::graph::generators::erdos_renyi(10, 30, 5).with_self_loops();
        let cfg = GcnConfig { in_dim: 4, hidden: 8, out_dim: 3, layers: 1, mode: TrainMode::tango(8) };
        let mut m = GcnModel::new(cfg, &g, 2);
        let feats = crate::graph::generators::random_features(10, 4, 6);
        let labels = vec![0u32; 10];
        let mut opt = Sgd::new(0.1);
        let nodes: Vec<u32> = (0..10).collect();
        let (l1, _) = m.train_step(&feats, &mut opt, |lg| softmax_cross_entropy(lg, &labels, &nodes));
        let (l2, _) = m.train_step(&feats, &mut opt, |lg| softmax_cross_entropy(lg, &labels, &nodes));
        assert!(l2 <= l1 + 0.1);
    }
}
