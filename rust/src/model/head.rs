//! Task heads: the loss/metric side of training, decoupled from the GNN
//! encoder so every architecture × every execution engine serves every
//! workload through one interface.
//!
//! - [`TaskHead::NodeClassification`] — softmax cross-entropy over labelled
//!   rows, accuracy on the held-out nodes;
//! - [`TaskHead::LinkPrediction`] — a dot-product edge decoder over node
//!   embeddings with seeded uniform negative sampling, BCE-with-logits loss
//!   and rank AUC.
//!
//! The head works on *rows of the encoder output*: in full-graph mode rows
//! are global node ids, in sampled mode they are the batch's compacted seed
//! ids — which is what lets the same head drive `Trainer`,
//! `MiniBatchTrainer` and the multi-GPU workers unchanged.

use super::{accuracy, auc, bce_with_logits};
use crate::graph::datasets::{Dataset, Task};
use crate::graph::Coo;
use crate::quant::rng::Xoshiro256pp;
use crate::tensor::Dense;

/// The learning task attached to a training run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskHead {
    /// Softmax-CE node classification (accuracy metric).
    NodeClassification,
    /// Dot-product link prediction (BCE-with-logits loss, AUC metric).
    LinkPrediction {
        /// Uniform negative pairs sampled per positive edge.
        neg_per_pos: usize,
    },
}

impl TaskHead {
    /// The head for a dataset's declared task.
    pub fn for_task(task: Task) -> TaskHead {
        match task {
            Task::NodeClassification => TaskHead::NodeClassification,
            Task::LinkPrediction => TaskHead::LinkPrediction { neg_per_pos: 1 },
        }
    }

    /// The dataset task this head trains.
    pub fn task(&self) -> Task {
        match self {
            TaskHead::NodeClassification => Task::NodeClassification,
            TaskHead::LinkPrediction { .. } => Task::LinkPrediction,
        }
    }

    /// Uniform negative pairs drawn per positive edge (0 for the NC head,
    /// which has no negative sampling).
    pub fn neg_per_pos(&self) -> usize {
        match self {
            TaskHead::NodeClassification => 0,
            TaskHead::LinkPrediction { neg_per_pos } => *neg_per_pos,
        }
    }

    /// Encoder output width for this head: classes for NC, a bounded
    /// embedding width for the LP decoder.
    pub fn out_dim(&self, data: &Dataset, hidden: usize) -> usize {
        match self {
            TaskHead::NodeClassification => data.num_classes,
            TaskHead::LinkPrediction { .. } => hidden.min(64),
        }
    }

    /// Dot-product decoder loss: scores every `(u, v, target)` candidate
    /// pair as `emb[u] · emb[v]`, applies BCE-with-logits and scatters the
    /// score gradients back onto the embedding rows. `u`/`v` are row
    /// indices into `emb` (global node ids in full-graph mode, compacted
    /// seed ids in sampled mode).
    pub fn lp_loss_grad(emb: &Dense<f32>, pairs: &[(u32, u32, f32)]) -> (f32, Dense<f32>) {
        let dim = emb.cols();
        let scores: Vec<f32> = pairs
            .iter()
            .map(|&(u, v, _)| {
                emb.row(u as usize).iter().zip(emb.row(v as usize)).map(|(a, b)| a * b).sum()
            })
            .collect();
        let targets: Vec<f32> = pairs.iter().map(|p| p.2).collect();
        let (loss, dscores) = bce_with_logits(&scores, &targets);
        let mut grad = Dense::zeros(&[emb.rows(), dim]);
        for (k, &(u, v, _)) in pairs.iter().enumerate() {
            let g = dscores[k];
            // ∂/∂emb[u] = g·emb[v]; ∂/∂emb[v] = g·emb[u].
            for j in 0..dim {
                grad.row_mut(u as usize)[j] += g * emb.at(v as usize, j);
            }
            for j in 0..dim {
                grad.row_mut(v as usize)[j] += g * emb.at(u as usize, j);
            }
        }
        (loss, grad)
    }

    /// Sample a full-graph LP training batch: up to `max_pos` positive
    /// edges, each followed by one uniform negative pair (global node ids).
    /// This is the full-graph epoch's candidate set; the sampled path
    /// builds its batches through
    /// [`EdgeBatcher`](crate::sampler::EdgeBatcher) instead.
    pub fn sample_global_pairs(
        graph: &Coo,
        max_pos: usize,
        rng: &mut Xoshiro256pp,
    ) -> Vec<(u32, u32, f32)> {
        let n = graph.num_nodes;
        let m = graph.num_edges().min(max_pos);
        let mut pairs: Vec<(u32, u32, f32)> = Vec::with_capacity(2 * m);
        for _ in 0..m {
            let e = (rng.next_u64() % graph.num_edges() as u64) as usize;
            pairs.push((graph.src[e], graph.dst[e], 1.0));
            pairs.push((
                (rng.next_u64() % n as u64) as u32,
                (rng.next_u64() % n as u64) as u32,
                0.0,
            ));
        }
        pairs
    }

    /// Evaluate the full-graph encoder output on the held-out split:
    /// accuracy over `eval_nodes` for NC, sampled-edge AUC for LP.
    pub fn evaluate(&self, out: &Dense<f32>, data: &Dataset, seed: u64) -> f32 {
        match self {
            TaskHead::NodeClassification => accuracy(out, &data.labels, &data.eval_nodes),
            TaskHead::LinkPrediction { .. } => {
                // AUC over held-out positive edges vs random pairs.
                let g = &data.graph;
                let mut rng = Xoshiro256pp::new(seed ^ 0xEA1);
                let k = g.num_edges().min(2000);
                let mut pos = Vec::with_capacity(k);
                let mut neg = Vec::with_capacity(k);
                for _ in 0..k {
                    let e = (rng.next_u64() % g.num_edges() as u64) as usize;
                    let (u, v) = (g.src[e] as usize, g.dst[e] as usize);
                    pos.push(out.row(u).iter().zip(out.row(v)).map(|(a, b)| a * b).sum());
                    let (ru, rv) = (
                        (rng.next_u64() % g.num_nodes as u64) as usize,
                        (rng.next_u64() % g.num_nodes as u64) as usize,
                    );
                    neg.push(out.row(ru).iter().zip(out.row(rv)).map(|(a, b)| a * b).sum());
                }
                auc(&pos, &neg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn head_follows_dataset_task() {
        assert_eq!(TaskHead::for_task(Task::NodeClassification), TaskHead::NodeClassification);
        assert_eq!(
            TaskHead::for_task(Task::LinkPrediction),
            TaskHead::LinkPrediction { neg_per_pos: 1 }
        );
        assert_eq!(TaskHead::for_task(Task::LinkPrediction).task(), Task::LinkPrediction);
    }

    #[test]
    fn out_dim_is_classes_or_bounded_embedding() {
        let d = datasets::tiny(3);
        assert_eq!(TaskHead::NodeClassification.out_dim(&d, 128), d.num_classes);
        assert_eq!(TaskHead::LinkPrediction { neg_per_pos: 1 }.out_dim(&d, 128), 64);
        assert_eq!(TaskHead::LinkPrediction { neg_per_pos: 1 }.out_dim(&d, 16), 16);
    }

    #[test]
    fn lp_loss_grad_matches_finite_difference() {
        let emb = Dense::from_vec(&[3, 2], vec![0.4, -0.2, 0.1, 0.9, -0.5, 0.3]);
        let pairs = vec![(0u32, 1u32, 1.0f32), (1, 2, 0.0), (0, 2, 1.0)];
        let (_, grad) = TaskHead::lp_loss_grad(&emb, &pairs);
        let eps = 1e-3f32;
        for r in 0..3 {
            for c in 0..2 {
                let mut ep = emb.clone();
                ep.set(r, c, emb.at(r, c) + eps);
                let mut em = emb.clone();
                em.set(r, c, emb.at(r, c) - eps);
                let (fp, _) = TaskHead::lp_loss_grad(&ep, &pairs);
                let (fm, _) = TaskHead::lp_loss_grad(&em, &pairs);
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - grad.at(r, c)).abs() < 1e-3,
                    "({r},{c}): fd={fd} an={}",
                    grad.at(r, c)
                );
            }
        }
    }

    #[test]
    fn global_pairs_alternate_pos_neg() {
        let d = datasets::tiny(5);
        let mut rng = Xoshiro256pp::new(7);
        let pairs = TaskHead::sample_global_pairs(&d.graph, 64, &mut rng);
        assert_eq!(pairs.len(), 128);
        let parent: std::collections::HashSet<(u32, u32)> = (0..d.graph.num_edges())
            .map(|e| (d.graph.src[e], d.graph.dst[e]))
            .collect();
        for (i, &(u, v, t)) in pairs.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(t, 1.0);
                assert!(parent.contains(&(u, v)), "positive must be a real edge");
            } else {
                assert_eq!(t, 0.0);
            }
        }
    }

    #[test]
    fn evaluate_dispatches_per_task() {
        let d = datasets::tiny(4);
        // A perfectly separable LP embedding is hard to fabricate; just
        // check ranges and determinism.
        let out = crate::graph::generators::random_features(d.graph.num_nodes, 8, 2);
        let lp = TaskHead::LinkPrediction { neg_per_pos: 1 };
        let a = lp.evaluate(&out, &d, 42);
        let b = lp.evaluate(&out, &d, 42);
        assert_eq!(a, b, "LP eval must be seeded-deterministic");
        assert!((0.0..=1.0).contains(&a));
        let logits = crate::graph::generators::random_features(d.graph.num_nodes, d.num_classes, 3);
        let acc = TaskHead::NodeClassification.evaluate(&logits, &d, 42);
        assert!((0.0..=1.0).contains(&acc));
    }
}
