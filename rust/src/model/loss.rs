//! Losses. Always computed in FP32 — the softmax cross-entropy sits behind
//! the paper's "full precision for the layer before Softmax" rule (§3.2).

use crate::tensor::Dense;

/// Softmax cross-entropy over selected rows (the training nodes).
///
/// `logits: [N, C]`, `labels[v] ∈ 0..C`. Returns `(mean loss, ∂logits)`
/// where the gradient is zero outside `nodes` and already divided by
/// `|nodes|`.
pub fn softmax_cross_entropy(
    logits: &Dense<f32>,
    labels: &[u32],
    nodes: &[u32],
) -> (f32, Dense<f32>) {
    let c = logits.cols();
    let mut grad = Dense::zeros(&[logits.rows(), c]);
    if nodes.is_empty() {
        return (0.0, grad);
    }
    let inv_n = 1.0 / nodes.len() as f32;
    let mut loss = 0.0f64;
    for &v in nodes {
        let row = logits.row(v as usize);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &x in row {
            denom += (x - maxv).exp();
        }
        let label = labels[v as usize] as usize;
        let log_p = row[label] - maxv - denom.ln();
        loss -= log_p as f64;
        let grow = grad.row_mut(v as usize);
        for j in 0..c {
            let p = (row[j] - maxv).exp() / denom;
            grow[j] = (p - if j == label { 1.0 } else { 0.0 }) * inv_n;
        }
    }
    ((loss * inv_n as f64) as f32, grad)
}

/// Binary cross-entropy with logits over edge scores (link prediction).
///
/// `scores[i]` is the dot-product score of candidate edge `i`,
/// `targets[i] ∈ {0.0, 1.0}`. Returns `(mean loss, ∂scores)`.
pub fn bce_with_logits(scores: &[f32], targets: &[f32]) -> (f32, Vec<f32>) {
    assert_eq!(scores.len(), targets.len());
    if scores.is_empty() {
        return (0.0, Vec::new());
    }
    let inv_n = 1.0 / scores.len() as f32;
    let mut loss = 0.0f64;
    let mut grad = Vec::with_capacity(scores.len());
    for (&x, &t) in scores.iter().zip(targets.iter()) {
        // Numerically stable: log(1+e^-|x|) + max(x,0) - t*x
        let l = x.max(0.0) - t * x + (-(x.abs())).exp().ln_1p();
        loss += l as f64;
        let sig = 1.0 / (1.0 + (-x).exp());
        grad.push((sig - t) * inv_n);
    }
    ((loss * inv_n as f64) as f32, grad)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce_loss_decreases_toward_correct_logits() {
        let labels = vec![0u32, 1];
        let nodes = vec![0u32, 1];
        let bad = Dense::from_vec(&[2, 2], vec![0.0, 0.0, 0.0, 0.0]);
        let good = Dense::from_vec(&[2, 2], vec![5.0, -5.0, -5.0, 5.0]);
        let (lb, _) = softmax_cross_entropy(&bad, &labels, &nodes);
        let (lg, _) = softmax_cross_entropy(&good, &labels, &nodes);
        assert!(lg < lb);
        assert!((lb - (2.0f32).ln()).abs() < 1e-5, "uniform logits -> ln(2)");
    }

    #[test]
    fn ce_gradient_matches_finite_difference() {
        let labels = vec![2u32];
        let nodes = vec![0u32];
        let logits = Dense::from_vec(&[1, 3], vec![0.3, -0.7, 1.1]);
        let (_, grad) = softmax_cross_entropy(&logits, &labels, &nodes);
        let eps = 1e-3;
        for j in 0..3 {
            let mut lp = logits.clone();
            lp.set(0, j, logits.at(0, j) + eps);
            let mut lm = logits.clone();
            lm.set(0, j, logits.at(0, j) - eps);
            let (fp, _) = softmax_cross_entropy(&lp, &labels, &nodes);
            let (fm, _) = softmax_cross_entropy(&lm, &labels, &nodes);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - grad.at(0, j)).abs() < 1e-3, "j={j}: {fd} vs {}", grad.at(0, j));
        }
    }

    #[test]
    fn ce_gradient_zero_outside_train_nodes() {
        let labels = vec![0u32, 1];
        let logits = Dense::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let (_, grad) = softmax_cross_entropy(&logits, &labels, &[0]);
        assert!(grad.row(1).iter().all(|&g| g == 0.0));
        assert!(grad.row(0).iter().any(|&g| g != 0.0));
    }

    #[test]
    fn bce_loss_and_gradient() {
        let (l, g) = bce_with_logits(&[0.0, 0.0], &[1.0, 0.0]);
        assert!((l - (2.0f32).ln()).abs() < 1e-5);
        assert!((g[0] + 0.25).abs() < 1e-6); // (0.5 - 1) / 2
        assert!((g[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn bce_gradient_matches_finite_difference() {
        let scores = vec![0.7f32, -1.2, 2.0];
        let targets = vec![1.0f32, 0.0, 1.0];
        let (_, grad) = bce_with_logits(&scores, &targets);
        let eps = 1e-3;
        for j in 0..3 {
            let mut sp = scores.clone();
            sp[j] += eps;
            let mut sm = scores.clone();
            sm[j] -= eps;
            let (fp, _) = bce_with_logits(&sp, &targets);
            let (fm, _) = bce_with_logits(&sm, &targets);
            let fd = (fp - fm) / (2.0 * eps);
            assert!((fd - grad[j]).abs() < 1e-3);
        }
    }

    #[test]
    fn empty_inputs() {
        let (l, g) = bce_with_logits(&[], &[]);
        assert_eq!(l, 0.0);
        assert!(g.is_empty());
        let logits = Dense::from_vec(&[1, 2], vec![0.0, 0.0]);
        let (l2, _) = softmax_cross_entropy(&logits, &[0], &[]);
        assert_eq!(l2, 0.0);
    }
}
