//! Optimizers. **Full-precision weight update** is one of the paper's
//! accuracy rules (§3.2, Eq. 5/6): updating quantized weights with quantized
//! gradients loses `Q(W_roundoff + ΔW_roundoff)`; updating FP32 master
//! weights (and re-quantizing next step) keeps it.

use crate::tensor::Dense;

/// SGD with optional momentum, operating on FP32 master weights.
#[derive(Debug, Clone)]
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient (0.0 = plain SGD).
    pub momentum: f32,
    /// L2 weight decay.
    pub weight_decay: f32,
    velocity: Vec<Option<Dense<f32>>>,
}

impl Sgd {
    /// Plain SGD.
    pub fn new(lr: f32) -> Self {
        Sgd { lr, momentum: 0.0, weight_decay: 0.0, velocity: Vec::new() }
    }

    /// SGD with momentum.
    pub fn with_momentum(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, weight_decay: 0.0, velocity: Vec::new() }
    }

    /// Update parameter `idx` in place. Parameters are identified by a
    /// stable index so momentum buffers persist across steps.
    pub fn step(&mut self, idx: usize, param: &mut Dense<f32>, grad: &Dense<f32>) {
        assert_eq!(param.shape(), grad.shape(), "param/grad shape mismatch");
        if self.velocity.len() <= idx {
            self.velocity.resize(idx + 1, None);
        }
        let effective: Dense<f32> = if self.weight_decay != 0.0 {
            let mut g = grad.clone();
            g.axpy_neg(-self.weight_decay, param); // g += wd * param
            g
        } else {
            grad.clone()
        };
        if self.momentum != 0.0 {
            let v = self.velocity[idx].get_or_insert_with(|| Dense::zeros(param.shape()));
            // v = momentum * v + g
            v.scale(self.momentum);
            v.add_assign(&effective);
            param.axpy_neg(self.lr, v);
        } else {
            param.axpy_neg(self.lr, &effective);
        }
    }

    /// Snapshot the per-parameter momentum buffers for checkpointing:
    /// `(shape, data)` per populated slot, `None` for never-touched slots.
    pub fn export_velocity(&self) -> Vec<Option<(Vec<usize>, Vec<f32>)>> {
        self.velocity
            .iter()
            .map(|v| v.as_ref().map(|d| (d.shape().to_vec(), d.data().to_vec())))
            .collect()
    }

    /// Restore momentum buffers snapshotted by [`export_velocity`] —
    /// resume-from-checkpoint is bit-identical even mid-momentum.
    pub fn import_velocity(&mut self, state: Vec<Option<(Vec<usize>, Vec<f32>)>>) {
        self.velocity = state
            .into_iter()
            .map(|v| v.map(|(shape, data)| Dense::from_vec(&shape, data)))
            .collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_descends_quadratic() {
        // minimize f(w) = 0.5 * w^2, grad = w.
        let mut w = Dense::from_vec(&[1], vec![10.0f32]);
        let mut opt = Sgd::new(0.1);
        for _ in 0..100 {
            let g = w.clone();
            opt.step(0, &mut w, &g);
        }
        assert!(w.data()[0].abs() < 1e-3);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mom: f32| {
            let mut w = Dense::from_vec(&[1], vec![10.0f32]);
            let mut opt = Sgd::with_momentum(0.01, mom);
            for _ in 0..50 {
                let g = w.clone();
                opt.step(0, &mut w, &g);
            }
            w.data()[0].abs()
        };
        assert!(run(0.9) < run(0.0), "momentum should converge faster here");
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut w = Dense::from_vec(&[1], vec![1.0f32]);
        let mut opt = Sgd::new(0.1);
        opt.weight_decay = 0.5;
        let zero_grad = Dense::zeros(&[1]);
        opt.step(0, &mut w, &zero_grad);
        assert!((w.data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn distinct_params_have_distinct_momentum() {
        let mut opt = Sgd::with_momentum(0.1, 0.9);
        let mut w0 = Dense::from_vec(&[1], vec![1.0f32]);
        let mut w1 = Dense::from_vec(&[1], vec![1.0f32]);
        let g = Dense::from_vec(&[1], vec![1.0f32]);
        opt.step(0, &mut w0, &g);
        opt.step(1, &mut w1, &g);
        opt.step(0, &mut w0, &g);
        // w0 took two momentum-compounded steps, w1 one.
        assert!(w0.data()[0] < w1.data()[0]);
    }

    #[test]
    fn full_precision_update_beats_quantized_update() {
        // The Eq. 5/6 argument, numerically: accumulate 100 small gradients.
        // FP32 master weights absorb them; updating a quantized weight with
        // quantized gradients loses every sub-grid update.
        use crate::quant::{dequantize, quantize, Rounding};
        let mut master = Dense::from_vec(&[1], vec![1.0f32]);
        let mut quantized_only = 1.0f32;
        let grad = Dense::from_vec(&[1], vec![0.001f32]);
        let mut opt = Sgd::new(1.0);
        for _ in 0..100 {
            opt.step(0, &mut master, &grad);
            // "Quantized update": quantize weight and gradient to a coarse
            // grid (scale 0.05), add, keep quantized.
            let qw = quantize(&Dense::from_vec(&[1], vec![quantized_only]), 8, Rounding::Nearest);
            let qg = (0.001f32 / 0.05).round() * 0.05; // grid-rounds to 0
            quantized_only = dequantize(&qw).data()[0] - qg;
        }
        let target = 1.0 - 100.0 * 0.001;
        assert!((master.data()[0] - target).abs() < 1e-4);
        assert!((quantized_only - target).abs() > 0.05, "quantized update should have lost the updates");
    }
}
