//! Evaluation metrics: node-classification accuracy and link-prediction AUC.

use crate::tensor::Dense;

/// Classification accuracy of argmax(logits) over `nodes`.
pub fn accuracy(logits: &Dense<f32>, labels: &[u32], nodes: &[u32]) -> f32 {
    if nodes.is_empty() {
        return 0.0;
    }
    let c = logits.cols();
    let mut hits = 0usize;
    for &v in nodes {
        let row = logits.row(v as usize);
        let mut best = 0usize;
        for j in 1..c {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[v as usize] as usize {
            hits += 1;
        }
    }
    hits as f32 / nodes.len() as f32
}

/// Area under the ROC curve for positive/negative score samples
/// (rank-based; ties get half credit).
pub fn auc(pos: &[f32], neg: &[f32]) -> f32 {
    if pos.is_empty() || neg.is_empty() {
        return 0.5;
    }
    let mut wins = 0.0f64;
    for &p in pos {
        for &n in neg {
            if p > n {
                wins += 1.0;
            } else if p == n {
                wins += 0.5;
            }
        }
    }
    (wins / (pos.len() as f64 * neg.len() as f64)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax_hits() {
        let logits = Dense::from_vec(&[3, 2], vec![2.0, 1.0, 0.0, 1.0, 5.0, -1.0]);
        let labels = vec![0u32, 1, 1];
        assert_eq!(accuracy(&logits, &labels, &[0, 1, 2]), 2.0 / 3.0);
        assert_eq!(accuracy(&logits, &labels, &[0, 1]), 1.0);
        assert_eq!(accuracy(&logits, &labels, &[]), 0.0);
    }

    #[test]
    fn auc_perfect_and_random() {
        assert_eq!(auc(&[2.0, 3.0], &[0.0, 1.0]), 1.0);
        assert_eq!(auc(&[0.0], &[1.0]), 0.0);
        assert_eq!(auc(&[1.0], &[1.0]), 0.5);
        assert_eq!(auc(&[], &[1.0]), 0.5);
    }

    #[test]
    fn auc_mixed() {
        // pos {1, 3}, neg {0, 2}: pairs (1>0, 1<2, 3>0, 3>2) = 3/4 wins.
        assert_eq!(auc(&[1.0, 3.0], &[0.0, 2.0]), 0.75);
    }
}
