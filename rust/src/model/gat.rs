//! GAT (Veličković et al.) with explicit backward following the paper's
//! Fig. 1 walkthrough step by step — the model that exercises **all three**
//! primitives (GEMM + SPMM + SDDMM).
//!
//! Forward (Fig. 1a):
//! 1. `H' = H·W`                       — GEMM (quantized);
//! 2. `S = (H'·a_src)ᵀ, D = (H'·a_dst)ᵀ` — per-head consolidation;
//! 3. `E = G ⊙ (S ⊕ Dᵀ)` + LeakyReLU  — SDDMM-add (quantized inputs,
//!    on-the-fly dequantization) — logits stay FP32 for the softmax;
//! 4. `α = edge_softmax(E)`            — FP32 (§3.2 rule);
//! 5. `H^(l) = (G ⊙ α)·H'`            — SPMM (quantized).
//!
//! Backward (Fig. 1b):
//! 4'. `∂H' = (Gᵀ ⊙ α)·∂H^(l)`        — SPMM on the reversed graph;
//! 5'. `∂α = G ⊙ (∂H^(l)·H'ᵀ)`        — SDDMM-dot, computed *directly on
//!     quantized values* with the fused `s0·s1` scale;
//! 3'. softmax + LeakyReLU backward    — FP32;
//! 4''. `∂S = (Gᵀ ⊙ ∂E)·1, ∂D = (G ⊙ ∂E)·1` — **incidence-matrix SPMM**;
//! 1'. `∂W = Hᵀ·∂H', ∂H = ∂H'·Wᵀ`     — GEMMs from cached quantized tensors.
//!
//! Like GCN, the model has one forward/backward implementation — the block
//! one, run over each layer's bipartite [`Block`]. Full-graph mode runs the
//! same code over per-layer copies of the identity block
//! ([`Block::identity`]), whose COO/CSR layouts are bit-for-bit the parent
//! graph's.
//!
//! The inter-primitive cache rule is applied where the paper points it out:
//! `∂H^(l)` is quantized **once** and consumed by both the backward SPMM
//! (4') and the SDDMM-dot (5'); `H'_q` from the forward pass is reused by
//! the SDDMM-dot; `H_q`/`W_q` from the forward GEMM feed the backward GEMMs.

use super::{GnnModel, LossGrad, ModelSpec, TrainMode};
use crate::graph::{Coo, Incidence};
use crate::primitives::{
    edge_softmax, edge_softmax_backward, gemm_f32, incidence_spmm, leaky_relu,
    leaky_relu_backward, qgemm, qgemm_prequantized, qsddmm_add, qsddmm_dot, sddmm_add,
    sddmm_dot, spmm_edge_weighted,
};
use crate::quant::rng::Xoshiro256pp;
use crate::quant::{dequantize, quantize, QTensor, Rounding};
use crate::sampler::Block;
use crate::tensor::Dense;
use std::sync::Arc;

/// LeakyReLU slope used on attention logits (DGL default).
const SLOPE: f32 = 0.2;

/// EXACT-style "compress then decompress" pass (pure overhead at compute
/// time — models the Fig. 8 EXACT baseline).
fn exact_roundtrip(bits: u8, x: &Dense<f32>) -> Dense<f32> {
    dequantize(&quantize(x, bits, Rounding::Nearest))
}

/// GAT hyperparameters (paper §4.1: hidden 128, 2 layers, 4 heads).
#[derive(Debug, Clone, Copy)]
pub struct GatConfig {
    /// Input feature dimension.
    pub in_dim: usize,
    /// Hidden width (total across heads).
    pub hidden: usize,
    /// Output dimension (classes / embedding width). Final layer is 1-head.
    pub out_dim: usize,
    /// Attention heads in the hidden layers.
    pub heads: usize,
    /// Number of layers (≥1).
    pub layers: usize,
    /// Execution mode.
    pub mode: TrainMode,
}

struct GatLayer {
    /// `[in, heads*d]` projection.
    w: Dense<f32>,
    /// `[heads, d]` source attention vector.
    a_src: Dense<f32>,
    /// `[heads, d]` destination attention vector.
    a_dst: Dense<f32>,
    grad_w: Dense<f32>,
    grad_a_src: Dense<f32>,
    grad_a_dst: Dense<f32>,
    heads: usize,
}

struct LayerCache {
    x: Dense<f32>,
    h_prime: Dense<f32>,
    logits_pre: Dense<f32>,
    alpha: Dense<f32>,
    agg: Dense<f32>,
    qx: Option<QTensor>,
    qw: Option<QTensor>,
    /// Quantized `H'` from the forward pass, reused by backward SDDMM-dot
    /// and by the ∂a projections.
    qh_prime: Option<QTensor>,
}

/// A GAT model bound to one graph.
pub struct GatModel {
    /// Config used to build the model.
    pub cfg: GatConfig,
    layers: Vec<GatLayer>,
    /// The bound graph as an identity block — the full-graph execution mode
    /// is the block path over `layers` copies of this.
    full_block: Arc<Block>,
    /// Incidence structures of the identity block, built once (sampled
    /// blocks rebuild theirs per step — they change every batch).
    full_inc_in: Incidence,
    full_inc_out: Incidence,
    /// Step counter (drives stochastic-rounding seeds).
    pub step_count: u64,
}

impl GatModel {
    /// Build the model for a graph (expects self-loops already added).
    pub fn new(cfg: GatConfig, graph: &Coo, seed: u64) -> Self {
        assert!(cfg.layers >= 1);
        assert_eq!(cfg.hidden % cfg.heads, 0, "hidden must divide by heads");
        let mut rng = Xoshiro256pp::new(seed);
        let mut layers = Vec::new();
        for l in 0..cfg.layers {
            let last = l + 1 == cfg.layers;
            let fan_in = if l == 0 { cfg.in_dim } else { cfg.hidden };
            let (heads, d) = if last { (1, cfg.out_dim) } else { (cfg.heads, cfg.hidden / cfg.heads) };
            let fan_out = heads * d;
            let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
            let rand_mat = |rng: &mut Xoshiro256pp, r: usize, c: usize, lim: f32| {
                Dense::from_vec(&[r, c], (0..r * c).map(|_| (rng.next_f32() * 2.0 - 1.0) * lim).collect())
            };
            layers.push(GatLayer {
                w: rand_mat(&mut rng, fan_in, fan_out, limit),
                a_src: rand_mat(&mut rng, heads, d, 0.3),
                a_dst: rand_mat(&mut rng, heads, d, 0.3),
                grad_w: Dense::zeros(&[fan_in, fan_out]),
                grad_a_src: Dense::zeros(&[heads, d]),
                grad_a_dst: Dense::zeros(&[heads, d]),
                heads,
            });
        }
        let full_block = Arc::new(Block::identity(graph, &graph.in_degrees()));
        let full_inc_in = Incidence::in_edges(&full_block.coo);
        let full_inc_out = Incidence::out_edges(&full_block.coo);
        GatModel { cfg, layers, full_block, full_inc_in, full_inc_out, step_count: 0 }
    }

    /// Whether layer `l` runs quantized under `mode` (§3.2: the layer
    /// feeding the softmax stays FP32 unless Test1).
    fn layer_quantized_in(&self, mode: TrainMode, l: usize) -> bool {
        mode.quantize && (l + 1 < self.cfg.layers || !mode.fp32_pre_softmax)
    }

    /// Per-layer references to the identity block (full-graph mode).
    fn full_refs(full_block: &Arc<Block>, layers: usize) -> Vec<&Block> {
        (0..layers).map(|_| full_block.as_ref()).collect()
    }

    /// Forward over per-layer blocks (parameterised over the execution mode
    /// so the FP32 bit-derivation probe shares this code).
    ///
    /// Each layer runs the full Fig. 1a pipeline on its block's bipartite
    /// graph: `H'` is computed for the whole source frontier, attention
    /// logits/softmax/aggregation group over the block's destination rows,
    /// and the row set shrinks from `num_src` to `num_dst` per layer.
    fn forward_blocks_cached(
        &self,
        mode: TrainMode,
        blocks: &[&Block],
        x0: &Dense<f32>,
    ) -> (Dense<f32>, Vec<LayerCache>) {
        assert_eq!(blocks.len(), self.layers.len(), "one block per layer");
        let mut caches = Vec::with_capacity(self.layers.len());
        let mut x = x0.clone();
        for (l, layer) in self.layers.iter().enumerate() {
            let blk = blocks[l];
            assert_eq!(x.rows(), blk.num_src(), "layer {l}: input rows != block src nodes");
            let heads = layer.heads;
            let quant = self.layer_quantized_in(mode, l);
            // Step 1: H' = H·W over the whole source frontier.
            let (h_prime, qx, qw) = if quant {
                let r = qgemm(&x, &layer.w, mode.bits, mode.rounding(self.step_count, l as u64));
                (r.out, Some(r.qa), Some(r.qb))
            } else if mode.exact_style {
                (
                    gemm_f32(
                        &exact_roundtrip(mode.bits, &x),
                        &exact_roundtrip(mode.bits, &layer.w),
                    ),
                    None,
                    None,
                )
            } else {
                (gemm_f32(&x, &layer.w), None, None)
            };
            // Step 2: per-head consolidation S, D (small GEMMs; FP32 — their
            // output feeds the softmax path, §3.2). Destination rows are a
            // prefix of the source rows, so one projection serves both.
            let s = head_project(&h_prime, &layer.a_src, heads);
            let d = head_project(&h_prime, &layer.a_dst, heads);
            // Step 3: SDDMM-add + LeakyReLU on the block's edge list.
            // Quantized mode exercises the on-the-fly dequantization kernel
            // (scales of S and D differ).
            let logits_pre = if quant {
                let qs = quantize(&s, mode.bits, mode.rounding(self.step_count, 400 + l as u64));
                let qd = quantize(&d, mode.bits, mode.rounding(self.step_count, 500 + l as u64));
                qsddmm_add(&blk.coo, &qs, &qd)
            } else if mode.exact_style {
                sddmm_add(
                    &blk.coo,
                    &exact_roundtrip(mode.bits, &s),
                    &exact_roundtrip(mode.bits, &d),
                )
            } else {
                sddmm_add(&blk.coo, &s, &d)
            };
            let logits = leaky_relu(&logits_pre, SLOPE);
            // Step 4: edge softmax per destination row — always FP32 (§3.2).
            let alpha = edge_softmax(&blk.csr, &logits);
            // Step 5: SPMM aggregation onto the destination rows.
            let (agg, qh_prime) = if quant {
                let qa = quantize(&alpha, mode.bits, mode.rounding(self.step_count, 600 + l as u64));
                let qh = quantize(&h_prime, mode.bits, mode.rounding(self.step_count, 700 + l as u64));
                (mode.backend.qspmm(&blk.csr, &qa, &qh, heads), Some(qh))
            } else if mode.exact_style {
                (
                    spmm_edge_weighted(
                        &blk.csr,
                        &exact_roundtrip(mode.bits, &alpha),
                        &exact_roundtrip(mode.bits, &h_prime),
                        heads,
                    ),
                    None,
                )
            } else {
                (spmm_edge_weighted(&blk.csr, &alpha, &h_prime, heads), None)
            };
            let out = if l + 1 < self.layers.len() { elu(&agg) } else { agg.clone() };
            caches.push(LayerCache { x: x.clone(), h_prime, logits_pre, alpha, agg, qx, qw, qh_prime });
            x = out;
        }
        (x, caches)
    }

    /// Inference-only forward over the full graph (identity blocks).
    pub fn forward(&self, features: &Dense<f32>) -> Dense<f32> {
        let refs = Self::full_refs(&self.full_block, self.layers.len());
        self.forward_blocks_cached(self.cfg.mode, &refs, features).0
    }

    /// Inference-only forward over sampled blocks.
    pub fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32> {
        let refs: Vec<&Block> = blocks.iter().collect();
        self.forward_blocks_cached(self.cfg.mode, &refs, x0).0
    }

    /// One full-graph training step — the identity-block run of
    /// [`Self::train_step_blocks`].
    pub fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let full = Arc::clone(&self.full_block);
        let refs = Self::full_refs(&full, self.layers.len());
        self.train_step_refs(&refs, features, opt, loss_grad)
    }

    /// One mini-batch training step over sampled blocks.
    pub fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let refs: Vec<&Block> = blocks.iter().collect();
        self.train_step_refs(&refs, x0, opt, loss_grad)
    }

    fn train_step_refs(
        &mut self,
        blocks: &[&Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: impl FnOnce(&Dense<f32>) -> (f32, Dense<f32>),
    ) -> (f32, Dense<f32>) {
        let (logits, caches) = self.forward_blocks_cached(self.cfg.mode, blocks, x0);
        let (loss, dlogits) = loss_grad(&logits);
        self.backward_blocks(blocks, &caches, dlogits);
        let mut p = 0;
        for layer in self.layers.iter_mut() {
            opt.step(p, &mut layer.w, &layer.grad_w);
            opt.step(p + 1, &mut layer.a_src, &layer.grad_a_src);
            opt.step(p + 2, &mut layer.a_dst, &layer.grad_a_dst);
            p += 3;
        }
        self.step_count += 1;
        (loss, logits)
    }

    /// Backward over blocks — the Fig. 1b walk on each block's bipartite
    /// graph (incidences are rebuilt per block; they are tiny compared to
    /// the aggregation work).
    fn backward_blocks(&mut self, blocks: &[&Block], caches: &[LayerCache], mut grad: Dense<f32>) {
        let mode = self.cfg.mode;
        for l in (0..self.layers.len()).rev() {
            let blk = blocks[l];
            let cache = &caches[l];
            let heads = self.layers[l].heads;
            let quant = self.layer_quantized_in(mode, l);
            if l + 1 < self.layers.len() {
                grad = elu_backward(&cache.agg, &grad);
            }
            // Quantize ∂H^(l) ONCE for both consumers (backward SPMM +
            // SDDMM-dot) — the inter-primitive cache (§3.3).
            let q_grad = if quant {
                Some(quantize(&grad, mode.bits, mode.rounding(self.step_count, 800 + l as u64)))
            } else {
                None
            };
            // Step 4': ∂H' over the source frontier (reversed-block SPMM).
            let mut dh_prime = if let Some(qg) = &q_grad {
                let qa = quantize(&cache.alpha, mode.bits, mode.rounding(self.step_count, 900 + l as u64));
                mode.backend.qspmm(&blk.csr_rev, &qa, qg, heads)
            } else if mode.exact_style {
                spmm_edge_weighted(
                    &blk.csr_rev,
                    &exact_roundtrip(mode.bits, &cache.alpha),
                    &exact_roundtrip(mode.bits, &grad),
                    heads,
                )
            } else {
                spmm_edge_weighted(&blk.csr_rev, &cache.alpha, &grad, heads)
            };
            // Step 5': ∂α (SDDMM-dot: dst-indexed ∂H^(l) × src-indexed H')
            // — directly on quantized values (mul commutes with the scales).
            let dalpha = if let Some(qg) = &q_grad {
                let qh = cache.qh_prime.as_ref().expect("forward cached qh_prime");
                qsddmm_dot(&blk.coo, qg, qh, heads)
            } else if mode.exact_style {
                sddmm_dot(
                    &blk.coo,
                    &exact_roundtrip(mode.bits, &grad),
                    &exact_roundtrip(mode.bits, &cache.h_prime),
                    heads,
                )
            } else {
                sddmm_dot(&blk.coo, &grad, &cache.h_prime, heads)
            };
            // Step 3': softmax + LeakyReLU backward (FP32, §3.2).
            let dlogits = edge_softmax_backward(&blk.csr, &cache.alpha, &dalpha);
            let de = leaky_relu_backward(&cache.logits_pre, &dlogits, SLOPE);
            // Step 4'': ∂S = (Gᵀ ⊙ ∂E)·1 and ∂D = (G ⊙ ∂E)·1 — the
            // incidence-matrix SPMM (Fig. 5) over the block's edge list.
            // Identity block (full-graph mode): reuse the incidences built
            // at construction instead of two O(E) rebuilds per step.
            let built;
            let (inc_in, inc_out) = if std::ptr::eq(blk, self.full_block.as_ref()) {
                (&self.full_inc_in, &self.full_inc_out)
            } else {
                built = (Incidence::in_edges(&blk.coo), Incidence::out_edges(&blk.coo));
                (&built.0, &built.1)
            };
            let ds = incidence_spmm(inc_out, &de);
            let dd = incidence_spmm(inc_in, &de);
            // ∂H' contributions from S and D; ∂a_src/∂a_dst projections.
            let layer = &mut self.layers[l];
            add_outer(&mut dh_prime, &ds, &layer.a_src, heads);
            add_outer(&mut dh_prime, &dd, &layer.a_dst, heads);
            layer.grad_a_src = project_grad(&cache.h_prime, &ds, heads);
            layer.grad_a_dst = project_grad(&cache.h_prime, &dd, heads);
            // Step 1': weight gradients from cached quantized tensors.
            if quant {
                let q_dh = quantize(&dh_prime, mode.bits, mode.rounding(self.step_count, 1000 + l as u64));
                let qx = cache.qx.as_ref().expect("forward cached qx");
                let qw = cache.qw.as_ref().expect("forward cached qw");
                let (gw, _) = qgemm_prequantized(&qx.transpose2d(), &q_dh, mode.bits);
                layer.grad_w = gw;
                if l > 0 {
                    let (gx, _) = qgemm_prequantized(&q_dh, &qw.transpose2d(), mode.bits);
                    grad = gx;
                }
            } else if mode.exact_style {
                let x2 = exact_roundtrip(mode.bits, &cache.x);
                let d2 = exact_roundtrip(mode.bits, &dh_prime);
                layer.grad_w = gemm_f32(&x2.transpose(), &d2);
                if l > 0 {
                    let w2 = exact_roundtrip(mode.bits, &layer.w);
                    grad = gemm_f32(&d2, &w2.transpose());
                }
            } else {
                layer.grad_w = gemm_f32(&cache.x.transpose(), &dh_prime);
                if l > 0 {
                    grad = gemm_f32(&dh_prime, &layer.w.transpose());
                }
            }
        }
    }

    /// First-layer output for the bit-derivation rule (Fig. 2), evaluated
    /// in FP32 regardless of mode (the rule measures the tensor, not the
    /// kernels) — one identity-block forward with a mode override.
    pub fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32> {
        let refs = Self::full_refs(&self.full_block, self.layers.len());
        let (_, caches) = self.forward_blocks_cached(TrainMode::fp32(), &refs, features);
        caches[0].agg.clone()
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.a_src.len() + l.a_dst.len()).sum()
    }

    /// Flatten all parameters (layer order: W, a_src, a_dst) — used by the
    /// multi-worker all-reduce.
    pub fn params_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            out.extend_from_slice(l.w.data());
            out.extend_from_slice(l.a_src.data());
            out.extend_from_slice(l.a_dst.data());
        }
        out
    }

    /// Load parameters from a flat buffer (inverse of [`Self::params_flat`]).
    pub fn set_params_flat(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.num_params());
        let mut off = 0;
        for l in &mut self.layers {
            for t in [&mut l.w, &mut l.a_src, &mut l.a_dst] {
                let n = t.len();
                t.data_mut().copy_from_slice(&flat[off..off + n]);
                off += n;
            }
        }
    }
}

impl GnnModel for GatModel {
    fn new_from_config(spec: &ModelSpec, graph: &Coo, seed: u64) -> Self {
        GatModel::new(
            GatConfig {
                in_dim: spec.in_dim,
                hidden: spec.hidden,
                out_dim: spec.out_dim,
                heads: spec.heads,
                layers: spec.layers,
                mode: spec.mode,
            },
            graph,
            seed,
        )
    }

    fn num_layers(&self) -> usize {
        self.layers.len()
    }

    fn mode(&self) -> TrainMode {
        self.cfg.mode
    }

    fn forward(&self, features: &Dense<f32>) -> Dense<f32> {
        GatModel::forward(self, features)
    }

    fn forward_blocks(&self, blocks: &[Block], x0: &Dense<f32>) -> Dense<f32> {
        GatModel::forward_blocks(self, blocks, x0)
    }

    fn train_step(
        &mut self,
        features: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        GatModel::train_step(self, features, opt, |lg| loss_grad(lg))
    }

    fn train_step_blocks(
        &mut self,
        blocks: &[Block],
        x0: &Dense<f32>,
        opt: &mut super::Sgd,
        loss_grad: LossGrad,
    ) -> (f32, Dense<f32>) {
        GatModel::train_step_blocks(self, blocks, x0, opt, |lg| loss_grad(lg))
    }

    fn first_layer_output(&self, features: &Dense<f32>) -> Dense<f32> {
        GatModel::first_layer_output(self, features)
    }

    fn num_params(&self) -> usize {
        GatModel::num_params(self)
    }

    fn params_flat(&self) -> Vec<f32> {
        GatModel::params_flat(self)
    }

    fn set_params_flat(&mut self, flat: &[f32]) {
        GatModel::set_params_flat(self, flat)
    }
}

/// `S[v,h] = Σ_d H'[v,(h,d)] · a[h,d]` (Fig. 1a step 2).
fn head_project(h: &Dense<f32>, a: &Dense<f32>, heads: usize) -> Dense<f32> {
    let n = h.rows();
    let d = h.cols() / heads;
    let mut out = Dense::zeros(&[n, heads]);
    for v in 0..n {
        let hrow = h.row(v);
        let orow = out.row_mut(v);
        for hh in 0..heads {
            let arow = a.row(hh);
            let mut acc = 0.0f32;
            for dd in 0..d {
                acc += hrow[hh * d + dd] * arow[dd];
            }
            orow[hh] = acc;
        }
    }
    out
}

/// `∂a[h,d] = Σ_v ∂S[v,h] · H'[v,(h,d)]`.
fn project_grad(h: &Dense<f32>, ds: &Dense<f32>, heads: usize) -> Dense<f32> {
    let n = h.rows();
    let d = h.cols() / heads;
    let mut out = Dense::zeros(&[heads, d]);
    for v in 0..n {
        let hrow = h.row(v);
        let srow = ds.row(v);
        for hh in 0..heads {
            let g = srow[hh];
            if g == 0.0 {
                continue;
            }
            let orow = out.row_mut(hh);
            for dd in 0..d {
                orow[dd] += g * hrow[hh * d + dd];
            }
        }
    }
    out
}

/// `∂H'[v,(h,d)] += ∂S[v,h] · a[h,d]`.
fn add_outer(dh: &mut Dense<f32>, ds: &Dense<f32>, a: &Dense<f32>, heads: usize) {
    let n = dh.rows();
    let d = dh.cols() / heads;
    for v in 0..n {
        let srow = ds.row(v);
        let dhrow = dh.row_mut(v);
        for hh in 0..heads {
            let g = srow[hh];
            if g == 0.0 {
                continue;
            }
            let arow = a.row(hh);
            for dd in 0..d {
                dhrow[hh * d + dd] += g * arow[dd];
            }
        }
    }
}

fn elu(x: &Dense<f32>) -> Dense<f32> {
    x.map(|v| if v >= 0.0 { v } else { v.exp() - 1.0 })
}

fn elu_backward(pre: &Dense<f32>, grad: &Dense<f32>) -> Dense<f32> {
    let mut out = grad.clone();
    for (g, &z) in out.data_mut().iter_mut().zip(pre.data().iter()) {
        if z < 0.0 {
            *g *= z.exp();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::model::{softmax_cross_entropy, Sgd};

    fn tiny_model(mode: TrainMode) -> (GatModel, datasets::Dataset) {
        let d = datasets::tiny(9);
        let cfg = GatConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            heads: 4,
            layers: 2,
            mode,
        };
        (GatModel::new(cfg, &d.graph, 11), d)
    }

    fn train_losses(mode: TrainMode, steps: usize) -> Vec<f32> {
        let (mut m, d) = tiny_model(mode);
        let mut opt = Sgd::new(0.05);
        let mut losses = Vec::new();
        for _ in 0..steps {
            let (loss, _) = m.train_step(&d.features, &mut opt, |logits| {
                softmax_cross_entropy(logits, &d.labels, &d.train_nodes)
            });
            losses.push(loss);
        }
        losses
    }

    #[test]
    fn fp32_training_reduces_loss() {
        let losses = train_losses(TrainMode::fp32(), 30);
        assert!(losses[29] < losses[0] * 0.8, "{:?}", &losses[..5]);
    }

    #[test]
    fn quantized_training_reduces_loss() {
        let losses = train_losses(TrainMode::tango(8), 30);
        assert!(losses[29] < losses[0] * 0.85, "{losses:?}");
    }

    #[test]
    fn gradient_check_fp32_tiny() {
        let g = crate::graph::generators::erdos_renyi(6, 14, 4).with_self_loops();
        let cfg = GatConfig { in_dim: 3, hidden: 4, out_dim: 2, heads: 2, layers: 2, mode: TrainMode::fp32() };
        let mut m = GatModel::new(cfg, &g, 1);
        let feats = crate::graph::generators::random_features(6, 3, 2);
        let labels = vec![0u32, 1, 0, 1, 0, 1];
        let nodes: Vec<u32> = (0..6).collect();
        let loss_of = |m: &GatModel| -> f32 {
            softmax_cross_entropy(&m.forward(&feats), &labels, &nodes).0
        };
        let mut opt = Sgd::new(0.0);
        m.train_step(&feats, &mut opt, |lg| softmax_cross_entropy(lg, &labels, &nodes));
        let eps = 1e-2f32;
        // W of layer 0 and 1
        for l in 0..2 {
            for &idx in &[0usize, 5] {
                let orig = m.layers[l].w.data()[idx];
                m.layers[l].w.data_mut()[idx] = orig + eps;
                let fp = loss_of(&m);
                m.layers[l].w.data_mut()[idx] = orig - eps;
                let fm = loss_of(&m);
                m.layers[l].w.data_mut()[idx] = orig;
                let fd = (fp - fm) / (2.0 * eps);
                let an = m.layers[l].grad_w.data()[idx];
                assert!((fd - an).abs() < 3e-2, "W layer {l} idx {idx}: fd={fd} an={an}");
            }
        }
        // attention vectors of layer 0
        for &idx in &[0usize, 3] {
            let orig = m.layers[0].a_src.data()[idx];
            m.layers[0].a_src.data_mut()[idx] = orig + eps;
            let fp = loss_of(&m);
            m.layers[0].a_src.data_mut()[idx] = orig - eps;
            let fm = loss_of(&m);
            m.layers[0].a_src.data_mut()[idx] = orig;
            let fd = (fp - fm) / (2.0 * eps);
            let an = m.layers[0].grad_a_src.data()[idx];
            assert!((fd - an).abs() < 3e-2, "a_src idx {idx}: fd={fd} an={an}");
        }
    }

    #[test]
    fn quantized_final_accuracy_close_to_fp32() {
        let run = |mode| {
            let (mut m, d) = tiny_model(mode);
            let mut opt = Sgd::new(0.05);
            for _ in 0..60 {
                m.train_step(&d.features, &mut opt, |logits| {
                    softmax_cross_entropy(logits, &d.labels, &d.train_nodes)
                });
            }
            crate::model::accuracy(&m.forward(&d.features), &d.labels, &d.eval_nodes)
        };
        let fp = run(TrainMode::fp32());
        let tg = run(TrainMode::tango(8));
        assert!(tg >= fp - 0.12, "tango {tg} vs fp32 {fp}");
    }

    #[test]
    fn block_path_matches_full_graph_fp32() {
        // Full-fanout blocks over every node reproduce the full-graph GAT
        // pass (up to float summation order — edge order inside a block's
        // softmax segments differs from the parent edge-id order).
        use crate::graph::Csr;
        use crate::sampler::{gather_rows, NeighborSampler};
        let d = datasets::tiny(9);
        let cfg = GatConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            heads: 4,
            layers: 2,
            mode: TrainMode::fp32(),
        };
        let mut full = GatModel::new(cfg, &d.graph, 11);
        let mut blocked = GatModel::new(cfg, &d.graph, 11);
        let csr = Csr::from_coo(&d.graph);
        let degrees = d.graph.in_degrees();
        let seeds: Vec<u32> = (0..d.graph.num_nodes as u32).collect();
        let sampler = NeighborSampler::new(vec![1 << 30, 1 << 30], 1);
        let blocks = sampler.sample_blocks(&csr, &degrees, &seeds, 0);
        let x0 = gather_rows(&d.features, &blocks[0].src_nodes);

        let a = full.forward(&d.features);
        let b = blocked.forward_blocks(&blocks, &x0);
        assert_eq!(a.shape(), b.shape());
        assert!(a.max_abs_diff(&b) < 1e-3, "forward diff {}", a.max_abs_diff(&b));

        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        let (la, _) = full.train_step(&d.features, &mut opt_a, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        let (lb, _) = blocked.train_step_blocks(&blocks, &x0, &mut opt_b, |lg| {
            softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
        });
        assert!((la - lb).abs() < 1e-3, "loss {la} vs {lb}");
        let pa = full.params_flat();
        let pb = blocked.params_flat();
        let max_diff = pa
            .iter()
            .zip(pb.iter())
            .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()));
        assert!(max_diff < 1e-3, "post-step param diff {max_diff}");
    }

    #[test]
    fn identity_blocks_replay_full_graph_exactly() {
        // The collapse invariant: the block API over identity blocks is
        // bit-identical to the full-graph wrappers, FP32 and quantized.
        for mode in [TrainMode::fp32(), TrainMode::tango(8)] {
            let (mut a, d) = tiny_model(mode);
            let (mut b, _) = tiny_model(mode);
            let ident = Block::identity(&d.graph, &d.graph.in_degrees());
            let blocks = vec![ident.clone(), ident];
            assert_eq!(a.forward(&d.features), b.forward_blocks(&blocks, &d.features));
            let mut opt_a = Sgd::new(0.05);
            let mut opt_b = Sgd::new(0.05);
            for _ in 0..2 {
                let (la, _) = a.train_step(&d.features, &mut opt_a, |lg| {
                    softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
                });
                let (lb, _) = b.train_step_blocks(&blocks, &d.features, &mut opt_b, |lg| {
                    softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
                });
                assert_eq!(la, lb, "losses must be bitwise equal");
            }
            assert_eq!(a.params_flat(), b.params_flat());
        }
    }

    #[test]
    fn packed_backend_replays_dequantize_backend_exactly() {
        // Multi-head GAT through PrimitiveBackend::Packed must be bitwise
        // the dense-i8 run — the seam only changes the SPMM's data layout.
        use crate::primitives::PrimitiveBackend;
        let mut packed_mode = TrainMode::tango(8);
        packed_mode.backend = PrimitiveBackend::Packed;
        let (mut a, d) = tiny_model(TrainMode::tango(8));
        let (mut b, _) = tiny_model(packed_mode);
        let mut opt_a = Sgd::new(0.05);
        let mut opt_b = Sgd::new(0.05);
        for _ in 0..2 {
            let (la, _) = a.train_step(&d.features, &mut opt_a, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            let (lb, _) = b.train_step(&d.features, &mut opt_b, |lg| {
                softmax_cross_entropy(lg, &d.labels, &d.train_nodes)
            });
            assert_eq!(la, lb, "losses must be bitwise equal across backends");
        }
        assert_eq!(a.params_flat(), b.params_flat());
    }

    #[test]
    fn sampled_minibatch_steps_reduce_loss() {
        use crate::graph::Csr;
        use crate::sampler::{gather_rows, shuffled_batches, NeighborSampler};
        let d = datasets::tiny(9);
        let cfg = GatConfig {
            in_dim: d.features.cols(),
            hidden: 16,
            out_dim: d.num_classes,
            heads: 4,
            layers: 2,
            mode: TrainMode::tango(8),
        };
        let mut m = GatModel::new(cfg, &d.graph, 11);
        let csr = Csr::from_coo(&d.graph);
        let degrees = d.graph.in_degrees();
        let sampler = NeighborSampler::new(vec![8, 8], 17);
        let mut opt = Sgd::new(0.05);
        let mut epoch_means = Vec::new();
        for epoch in 0..12u64 {
            let mut total = 0.0f32;
            let mut steps = 0usize;
            for (bi, batch) in
                shuffled_batches(&d.train_nodes, 64, epoch).iter().enumerate()
            {
                let blocks = sampler.sample_blocks(&csr, &degrees, batch, (epoch << 8) ^ bi as u64);
                let x0 = gather_rows(&d.features, &blocks[0].src_nodes);
                let labels: Vec<u32> = batch.iter().map(|&v| d.labels[v as usize]).collect();
                let nodes: Vec<u32> = (0..batch.len() as u32).collect();
                let (loss, logits) = m.train_step_blocks(&blocks, &x0, &mut opt, |lg| {
                    softmax_cross_entropy(lg, &labels, &nodes)
                });
                assert_eq!(logits.rows(), batch.len());
                assert!(loss.is_finite());
                total += loss;
                steps += 1;
            }
            epoch_means.push(total / steps as f32);
        }
        let (first, last) = (epoch_means[0], *epoch_means.last().unwrap());
        assert!(last < first, "mean batch loss {first} -> {last}: {epoch_means:?}");
    }

    #[test]
    fn head_project_matches_manual() {
        // 1 node, 2 heads, d=2: S[0,h] = dot(h'[h], a[h]).
        let h = Dense::from_vec(&[1, 4], vec![0.59, 0.73, 0.51, -0.65]);
        let a = Dense::from_vec(&[2, 2], vec![0.91, 0.90, 0.42, 0.62]);
        let s = head_project(&h, &a, 2);
        // Paper step 2: [0.59,0.73]·[0.91,0.90] = 1.19..1.20
        assert!((s.at(0, 0) - 1.194).abs() < 1e-3);
        assert!((s.at(0, 1) - (0.51 * 0.42 + -0.65 * 0.62)).abs() < 1e-5);
    }

    #[test]
    fn elu_roundtrip() {
        let x = Dense::from_vec(&[3], vec![-1.0f32, 0.0, 2.0]);
        let y = elu(&x);
        assert!((y.data()[0] - ((-1.0f32).exp() - 1.0)).abs() < 1e-6);
        assert_eq!(y.data()[2], 2.0);
    }

    #[test]
    fn num_params_counts_attention_vectors() {
        let (m, d) = tiny_model(TrainMode::fp32());
        let in_dim = d.features.cols();
        let expected = in_dim * 16 + 2 * 16            // layer 0: W + a vecs (4 heads × 4)
            + 16 * d.num_classes + 2 * d.num_classes; // layer 1 (1 head)
        assert_eq!(m.num_params(), expected);
    }
}
