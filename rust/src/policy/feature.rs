//! A policy materialized against a concrete graph + feature table.
//!
//! [`FeaturePolicy`] is what the gather path consumes: per-node bucket ids
//! (from in-degrees) and per-bucket symmetric scales (from the feature
//! table — the table is static across training, so per-bucket scales are
//! static too, exactly like the single global scale they generalize).

use super::bits::BitPolicy;
use super::buckets::DegreeBuckets;
use crate::quant::qmax_for_bits;
use crate::tensor::Dense;

/// Per-node bucket assignment + per-bucket `(scale, bits)`.
///
/// The **uniform** instance (one bucket at width `B`) reproduces the
/// pre-policy store exactly: its single scale is the whole table's
/// `absmax / qmax(B)` — the same fold `quant::scale_for_bits` computes —
/// so uniform-policy gathers are bit-identical to policy-less ones.
#[derive(Debug, Clone)]
pub struct FeaturePolicy {
    buckets: DegreeBuckets,
    bits: BitPolicy,
    /// Bucket id per node (`assignment[v]`), hottest bucket 0.
    assignment: Vec<u8>,
    /// Per-bucket symmetric scale (`absmax over the bucket's rows / qmax`);
    /// an empty bucket keeps scale 1.0 so dequantization stays exact.
    scales: Vec<f32>,
    /// Nodes per bucket (assignment census, for reports).
    node_counts: Vec<u64>,
}

impl FeaturePolicy {
    /// Materialize: assign each node by in-degree and derive each bucket's
    /// scale from its feature rows. `degrees` and `features` must describe
    /// the same node set.
    pub fn materialize(
        buckets: DegreeBuckets,
        bits: BitPolicy,
        degrees: &[u32],
        features: &Dense<f32>,
    ) -> Result<Self, String> {
        if bits.num_buckets() != buckets.num_buckets() {
            return Err(format!(
                "bit policy covers {} buckets but the degree partition has {}",
                bits.num_buckets(),
                buckets.num_buckets()
            ));
        }
        if degrees.len() != features.rows() {
            return Err(format!(
                "degree list covers {} nodes but the feature table has {} rows",
                degrees.len(),
                features.rows()
            ));
        }
        let assignment = buckets.assign(degrees);
        let nb = buckets.num_buckets();
        let mut absmax = vec![0.0f32; nb];
        let mut node_counts = vec![0u64; nb];
        for (v, &b) in assignment.iter().enumerate() {
            let m = &mut absmax[b as usize];
            for &x in features.row(v) {
                *m = m.max(x.abs());
            }
            node_counts[b as usize] += 1;
        }
        let scales = (0..nb)
            .map(|b| {
                if absmax[b] == 0.0 {
                    1.0
                } else {
                    absmax[b] / qmax_for_bits(bits.bits_of(b)) as f32
                }
            })
            .collect();
        Ok(FeaturePolicy { buckets, bits, assignment, scales, node_counts })
    }

    /// The uniform single-bucket policy at width `bits` — scale identical
    /// to `quant::scale_for_bits(features, bits)`.
    pub fn uniform(bits: u8, features: &Dense<f32>) -> Result<Self, String> {
        let degrees = vec![0u32; features.rows()];
        Self::materialize(DegreeBuckets::uniform(), BitPolicy::uniform(bits)?, &degrees, features)
    }

    /// Bucket count.
    pub fn num_buckets(&self) -> usize {
        self.scales.len()
    }

    /// True when more than one `(scale, bits)` pair is live — i.e. the
    /// gather path is genuinely mixed-precision.
    pub fn is_mixed(&self) -> bool {
        self.num_buckets() > 1
    }

    /// Bucket of a node.
    pub fn bucket_of_node(&self, node: usize) -> usize {
        self.assignment[node] as usize
    }

    /// Symmetric scale of a bucket.
    pub fn scale(&self, bucket: usize) -> f32 {
        self.scales[bucket]
    }

    /// Bit width of a bucket.
    pub fn bits_of(&self, bucket: usize) -> u8 {
        self.bits.bits_of(bucket)
    }

    /// The per-bucket width list (hottest first).
    pub fn bits(&self) -> &[u8] {
        self.bits.bits()
    }

    /// The degree partition.
    pub fn buckets(&self) -> &DegreeBuckets {
        &self.buckets
    }

    /// Nodes assigned to each bucket.
    pub fn node_counts(&self) -> &[u64] {
        &self.node_counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;
    use crate::quant::scale_for_bits;

    #[test]
    fn uniform_scale_matches_global_scale_exactly() {
        let f = random_features(40, 8, 3);
        for bits in [8u8, 4] {
            let p = FeaturePolicy::uniform(bits, &f).unwrap();
            assert_eq!(p.num_buckets(), 1);
            assert!(!p.is_mixed());
            assert_eq!(p.scale(0), scale_for_bits(&f, bits), "bits {bits}");
        }
    }

    #[test]
    fn bucket_scales_cover_each_buckets_rows() {
        // Nodes 0..3 cold (deg 0), 4..7 hot (deg 10) under boundary [5].
        let f = random_features(8, 4, 9);
        let degrees = vec![0, 0, 0, 0, 10, 10, 10, 10];
        let p = FeaturePolicy::materialize(
            DegreeBuckets::new(vec![5]).unwrap(),
            BitPolicy::new(vec![8, 4]).unwrap(),
            &degrees,
            &f,
        )
        .unwrap();
        assert_eq!(p.num_buckets(), 2);
        assert!(p.is_mixed());
        assert_eq!(p.node_counts(), &[4, 4]);
        for v in 0..4 {
            assert_eq!(p.bucket_of_node(v), 1, "low degree is the cold bucket");
        }
        for v in 4..8 {
            assert_eq!(p.bucket_of_node(v), 0, "high degree is the hot bucket");
        }
        // Each bucket's scale is its own rows' absmax over its qmax.
        let hot_absmax =
            (4..8).flat_map(|v| f.row(v)).fold(0.0f32, |m, &x| m.max(x.abs()));
        let cold_absmax =
            (0..4).flat_map(|v| f.row(v)).fold(0.0f32, |m, &x| m.max(x.abs()));
        assert_eq!(p.scale(0), hot_absmax / 127.0);
        assert_eq!(p.scale(1), cold_absmax / 7.0);
        assert_eq!(p.bits_of(0), 8);
        assert_eq!(p.bits_of(1), 4);
    }

    #[test]
    fn empty_bucket_gets_unit_scale() {
        let f = random_features(4, 4, 1);
        // Every node cold: the hot bucket is empty.
        let p = FeaturePolicy::materialize(
            DegreeBuckets::new(vec![100]).unwrap(),
            BitPolicy::new(vec![8, 8]).unwrap(),
            &vec![1u32; 4],
            &f,
        )
        .unwrap();
        assert_eq!(p.scale(0), 1.0);
        assert_eq!(p.node_counts(), &[0, 4]);
    }

    #[test]
    fn mismatched_shapes_are_rejected() {
        let f = random_features(4, 4, 2);
        assert!(FeaturePolicy::materialize(
            DegreeBuckets::new(vec![5]).unwrap(),
            BitPolicy::uniform(8).unwrap(),
            &vec![1u32; 4],
            &f,
        )
        .unwrap_err()
        .contains("buckets"));
        assert!(FeaturePolicy::materialize(
            DegreeBuckets::uniform(),
            BitPolicy::uniform(8).unwrap(),
            &vec![1u32; 3],
            &f,
        )
        .unwrap_err()
        .contains("nodes"));
    }
}
