//! Per-bucket bit widths and the user-facing policy configuration.
//!
//! A [`BitPolicy`] maps degree buckets (hottest first — see
//! [`DegreeBuckets`](super::DegreeBuckets)) to quantization bit widths.
//! [`PolicyConfig`] is the raw knob pair the config layer carries
//! (`--degree-buckets` / `--bucket-bits`, or the `[policy]` TOML section);
//! it validates early with actionable messages and materializes into a
//! [`FeaturePolicy`](super::FeaturePolicy) once a concrete graph and
//! feature table are in hand.

use super::buckets::DegreeBuckets;
use super::feature::FeaturePolicy;
use crate::tensor::Dense;

/// Per-bucket quantization bit widths, hottest bucket first.
///
/// `--bucket-bits 8,6,4` with `--degree-buckets 8,64` keeps nodes of
/// in-degree `>= 64` at INT8, mid-degree nodes at 6 bits, and compresses
/// the `deg < 8` cold tail to 4 bits. Widths are `1..=8`; the 1-bit grid
/// is ternary (`{-1, 0, +1}`) and packed accounting charges it two
/// physical bits (see `quant::packed_bits_per_elem`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPolicy {
    bits: Vec<u8>,
}

impl BitPolicy {
    /// Policy from a per-bucket width list. Rejects empty lists and widths
    /// outside `1..=8`.
    pub fn new(bits: Vec<u8>) -> Result<Self, String> {
        if bits.is_empty() {
            return Err(
                "bucket-bits must name at least one width; e.g. --bucket-bits 8,6,4".to_string()
            );
        }
        for &b in &bits {
            if !(1..=8).contains(&b) {
                return Err(format!(
                    "bucket-bits entries must be within 1..=8, got {b}; \
                     e.g. --bucket-bits 8,6,4"
                ));
            }
        }
        Ok(BitPolicy { bits })
    }

    /// One bucket at a single width.
    pub fn uniform(bits: u8) -> Result<Self, String> {
        Self::new(vec![bits])
    }

    /// The per-bucket width list (hottest bucket first).
    pub fn bits(&self) -> &[u8] {
        &self.bits
    }

    /// Width of one bucket.
    pub fn bits_of(&self, bucket: usize) -> u8 {
        self.bits[bucket]
    }

    /// Buckets this policy covers.
    pub fn num_buckets(&self) -> usize {
        self.bits.len()
    }
}

/// The raw degree-aware policy knobs, as the config layer carries them
/// (`TrainConfig::policy`). Both lists empty = the uniform policy: one
/// bucket at the execution mode's bit width — configured that way the
/// gather path is bit-identical to a policy-less run.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PolicyConfig {
    /// Ascending in-degree boundaries (`--degree-buckets 8,64`, TOML
    /// `[policy] degree_buckets = "8,64"`); empty = one bucket.
    pub degree_buckets: Vec<u32>,
    /// Per-bucket bit widths, hottest bucket first (`--bucket-bits 8,6,4`,
    /// TOML `[policy] bucket_bits = "8,6,4"`); empty = every bucket at the
    /// mode's bit width.
    pub bucket_bits: Vec<u8>,
}

impl PolicyConfig {
    /// The default single-bucket policy.
    pub fn uniform() -> Self {
        PolicyConfig::default()
    }

    /// True when this is the single-bucket, mode-width policy (no knobs
    /// set) — the configuration pinned bit-identical to pre-policy runs.
    pub fn is_uniform(&self) -> bool {
        self.degree_buckets.is_empty() && self.bucket_bits.is_empty()
    }

    /// Structural validation (no graph needed): boundary monotonicity,
    /// width range, and the bucket-count/width-count match. Called by
    /// `TrainConfig::validate` so every entry point (CLI, TOML,
    /// programmatic) rejects broken policies before training starts.
    pub fn validate(&self) -> Result<(), String> {
        let buckets = DegreeBuckets::new(self.degree_buckets.clone())?;
        if !self.bucket_bits.is_empty() {
            BitPolicy::new(self.bucket_bits.clone())?;
            if self.bucket_bits.len() != buckets.num_buckets() {
                return Err(format!(
                    "{} degree-bucket boundaries make {} buckets, but bucket-bits names {} \
                     widths — pass exactly {} (hottest bucket first, e.g. --degree-buckets \
                     8,64 --bucket-bits 8,6,4)",
                    self.degree_buckets.len(),
                    buckets.num_buckets(),
                    self.bucket_bits.len(),
                    buckets.num_buckets()
                ));
            }
        }
        Ok(())
    }

    /// The effective per-bucket widths once the mode's default width is
    /// known: an empty `bucket_bits` fills every bucket with
    /// `default_bits`.
    pub fn effective_bits(&self, default_bits: u8) -> Vec<u8> {
        if self.bucket_bits.is_empty() {
            vec![default_bits; self.degree_buckets.len() + 1]
        } else {
            self.bucket_bits.clone()
        }
    }

    /// Materialize against a concrete graph: validate, assign every node
    /// its bucket by in-degree, and derive per-bucket symmetric scales
    /// from the feature table. `default_bits` (the execution mode's width)
    /// fills the widths when `bucket_bits` is unset.
    pub fn materialize(
        &self,
        default_bits: u8,
        degrees: &[u32],
        features: &Dense<f32>,
    ) -> Result<FeaturePolicy, String> {
        self.validate()?;
        let buckets = DegreeBuckets::new(self.degree_buckets.clone())?;
        let bits = BitPolicy::new(self.effective_bits(default_bits))?;
        FeaturePolicy::materialize(buckets, bits, degrees, features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_validate_range_and_nonempty() {
        assert!(BitPolicy::new(vec![8, 6, 4]).is_ok());
        assert!(BitPolicy::new(vec![1]).is_ok());
        assert!(BitPolicy::new(vec![]).unwrap_err().contains("at least one"));
        assert!(BitPolicy::new(vec![0]).unwrap_err().contains("1..=8"));
        assert!(BitPolicy::new(vec![9]).unwrap_err().contains("1..=8"));
        assert_eq!(BitPolicy::uniform(8).unwrap().bits(), &[8]);
    }

    #[test]
    fn config_validates_count_match() {
        let ok = PolicyConfig { degree_buckets: vec![8, 64], bucket_bits: vec![8, 6, 4] };
        assert!(ok.validate().is_ok());
        let mismatch = PolicyConfig { degree_buckets: vec![8, 64], bucket_bits: vec![8, 4] };
        let err = mismatch.validate().unwrap_err();
        assert!(err.contains("3 buckets"), "{err}");
        assert!(err.contains("2 widths"), "{err}");
        // Boundaries alone are fine (widths default to the mode's bits)…
        let buckets_only = PolicyConfig { degree_buckets: vec![8, 64], bucket_bits: vec![] };
        assert!(buckets_only.validate().is_ok());
        assert_eq!(buckets_only.effective_bits(6), vec![6, 6, 6]);
        // …and a single width alone is a one-bucket override.
        let bits_only = PolicyConfig { degree_buckets: vec![], bucket_bits: vec![4] };
        assert!(bits_only.validate().is_ok());
        assert!(!bits_only.is_uniform());
        assert!(PolicyConfig::uniform().is_uniform());
    }

    #[test]
    fn config_rejects_bad_parts() {
        let bad_bits = PolicyConfig { degree_buckets: vec![8], bucket_bits: vec![8, 0] };
        assert!(bad_bits.validate().unwrap_err().contains("1..=8"));
        let bad_bounds = PolicyConfig { degree_buckets: vec![64, 8], bucket_bits: vec![] };
        assert!(bad_bounds.validate().unwrap_err().contains("strictly increasing"));
    }
}
