//! Per-bucket gather accounting: where the mixed-precision bytes went.
//!
//! The policy's speed claim is measurable — fewer bytes gathered and
//! transferred for cold-bucket rows — so the gather path counts row
//! traffic per bucket and reports it next to what the same rows would have
//! cost at uniform INT8. `TrainReport::policy` / `MultiGpuReport::policy`
//! carry a [`PolicyGatherReport`] and the CLI prints its summary lines.

use super::buckets::bucket_range_label;

/// Cumulative gather traffic of one degree bucket.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct BucketGatherStats {
    /// Feature rows gathered from this bucket (hits + misses).
    pub rows: u64,
    /// Rows served from the quantized row cache.
    pub hits: u64,
    /// Rows quantized fresh on this gather.
    pub misses: u64,
    /// Bytes those rows occupy at the bucket's policy width (packed).
    pub packed_bytes: u64,
    /// Bytes the same rows would occupy at uniform INT8.
    pub int8_bytes: u64,
    /// Sum of per-row `Error_X` (paper Eq. 4) over freshly quantized rows —
    /// only measured while tracing is on (see [`crate::obs`]), 0 otherwise.
    pub err_sum: f64,
    /// Rows whose `Error_X` was measured into `err_sum`.
    pub err_rows: u64,
}

impl BucketGatherStats {
    /// Fold another bucket's traffic into this one (totals row).
    pub fn merge(&mut self, other: &BucketGatherStats) {
        self.rows += other.rows;
        self.hits += other.hits;
        self.misses += other.misses;
        self.packed_bytes += other.packed_bytes;
        self.int8_bytes += other.int8_bytes;
        self.err_sum += other.err_sum;
        self.err_rows += other.err_rows;
    }

    /// Mean measured quantization `Error_X` of this bucket's fresh rows
    /// (`None` when nothing was measured — tracing off or no misses).
    pub fn mean_error(&self) -> Option<f64> {
        if self.err_rows == 0 {
            None
        } else {
            Some(self.err_sum / self.err_rows as f64)
        }
    }
}

/// A whole run's per-bucket gather accounting, with the policy shape
/// (boundaries, widths, node census) riding along so reports are
/// self-describing.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyGatherReport {
    /// Ascending in-degree boundaries (empty = one bucket).
    pub boundaries: Vec<u32>,
    /// Per-bucket widths, hottest bucket first.
    pub bits: Vec<u8>,
    /// Nodes assigned to each bucket.
    pub node_counts: Vec<u64>,
    /// Per-bucket gather traffic, aligned with `bits`.
    pub buckets: Vec<BucketGatherStats>,
}

impl PolicyGatherReport {
    /// True when more than one precision tier is live.
    pub fn is_mixed(&self) -> bool {
        self.bits.len() > 1
    }

    /// Total gathered bytes at the policy widths.
    pub fn packed_bytes(&self) -> u64 {
        self.buckets.iter().map(|b| b.packed_bytes).sum()
    }

    /// Total gathered bytes had every row moved at uniform INT8.
    pub fn int8_bytes(&self) -> u64 {
        self.buckets.iter().map(|b| b.int8_bytes).sum()
    }

    /// Human summary, one line per bucket plus a totals line — what
    /// `tango train` / `tango multigpu` print for mixed runs.
    pub fn summary_lines(&self) -> Vec<String> {
        let mut out = Vec::with_capacity(self.buckets.len() + 1);
        for (i, st) in self.buckets.iter().enumerate() {
            let total = st.hits + st.misses;
            let err = match st.mean_error() {
                Some(e) => format!(", Error_X {e:.4}"),
                None => String::new(),
            };
            out.push(format!(
                "bucket {i} ({}, {} bits): {} nodes, {} rows gathered \
                 ({:.1}% hits), {:.1} KiB packed vs {:.1} KiB INT8{err}",
                bucket_range_label(&self.boundaries, i),
                self.bits[i],
                self.node_counts.get(i).copied().unwrap_or(0),
                st.rows,
                st.hits as f64 / total.max(1) as f64 * 100.0,
                st.packed_bytes as f64 / 1024.0,
                st.int8_bytes as f64 / 1024.0,
            ));
        }
        let (packed, int8) = (self.packed_bytes(), self.int8_bytes());
        out.push(format!(
            "policy total: {:.1} KiB gathered vs {:.1} KiB at uniform INT8 ({:.2}x)",
            packed as f64 / 1024.0,
            int8 as f64 / 1024.0,
            int8 as f64 / (packed as f64).max(1.0),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> PolicyGatherReport {
        PolicyGatherReport {
            boundaries: vec![8],
            bits: vec![8, 4],
            node_counts: vec![10, 90],
            buckets: vec![
                BucketGatherStats {
                    rows: 100,
                    hits: 60,
                    misses: 40,
                    packed_bytes: 1600,
                    int8_bytes: 1600,
                    ..Default::default()
                },
                BucketGatherStats {
                    rows: 300,
                    hits: 100,
                    misses: 200,
                    packed_bytes: 2400,
                    int8_bytes: 4800,
                    err_sum: 6.0,
                    err_rows: 200,
                },
            ],
        }
    }

    #[test]
    fn totals_sum_buckets() {
        let r = report();
        assert!(r.is_mixed());
        assert_eq!(r.packed_bytes(), 4000);
        assert_eq!(r.int8_bytes(), 6400);
        let mut total = BucketGatherStats::default();
        for b in &r.buckets {
            total.merge(b);
        }
        assert_eq!(total.rows, 400);
        assert_eq!(total.hits, 160);
        assert_eq!(total.packed_bytes, 4000);
    }

    #[test]
    fn summary_names_every_bucket_and_the_total() {
        let lines = report().summary_lines();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("deg >= 8") && lines[0].contains("8 bits"), "{}", lines[0]);
        assert!(lines[1].contains("deg < 8") && lines[1].contains("4 bits"), "{}", lines[1]);
        assert!(lines[2].contains("uniform INT8"), "{}", lines[2]);
        // Error_X appears only where it was measured (bucket 1's 200 rows).
        assert!(!lines[0].contains("Error_X"), "{}", lines[0]);
        assert!(lines[1].contains("Error_X 0.0300"), "{}", lines[1]);
    }

    #[test]
    fn mean_error_needs_measured_rows() {
        let r = report();
        assert_eq!(r.buckets[0].mean_error(), None);
        assert_eq!(r.buckets[1].mean_error(), Some(0.03));
        let mut total = BucketGatherStats::default();
        for b in &r.buckets {
            total.merge(b);
        }
        assert_eq!(total.err_rows, 200);
        assert_eq!(total.mean_error(), Some(0.03));
    }
}
