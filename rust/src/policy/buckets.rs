//! Degree-bucket partition: which nodes count as "hot".
//!
//! Degree-Quant's observation (see PAPERS.md) is that quantization error
//! concentrates its accuracy damage on **high-in-degree** nodes — they
//! aggregate many messages, so per-message rounding error compounds there —
//! while the long cold tail of low-degree nodes tolerates aggressive
//! compression. [`DegreeBuckets`] turns that observation into a partition:
//! a short ascending boundary list splits the in-degree axis into
//! contiguous ranges, and **bucket 0 is the hottest** (highest-degree)
//! range so that policies reading "hot first" (`--bucket-bits 8,6,4`) keep
//! the accuracy-critical nodes at high precision and compress the tail.

/// A partition of nodes by in-degree into contiguous buckets.
///
/// Boundaries are ascending in-degree thresholds; `b` boundaries make
/// `b + 1` buckets, **numbered hottest first**. With boundaries `[8, 64]`:
///
/// | bucket | in-degree range |
/// |--------|-----------------|
/// | 0      | `deg >= 64`     |
/// | 1      | `8 <= deg < 64` |
/// | 2      | `deg < 8`       |
///
/// The partition is complete and disjoint by construction — every degree
/// falls in exactly one range (`tests/sampler_invariants.rs` pins this as a
/// property). No boundaries means one bucket holding every node (the
/// uniform policy).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DegreeBuckets {
    /// Ascending in-degree thresholds (each `>= 1`, strictly increasing).
    boundaries: Vec<u32>,
}

/// Sanity cap on the bucket count: policies are a handful of precision
/// tiers, and per-node bucket ids are stored as `u8`.
pub const MAX_BUCKETS: usize = 32;

impl DegreeBuckets {
    /// Partition from ascending boundaries. Rejects non-monotone or zero
    /// boundaries with an actionable message (a boundary of 0 would make
    /// the coldest bucket empty for every graph — in-degrees are
    /// non-negative — which is always a config typo).
    pub fn new(boundaries: Vec<u32>) -> Result<Self, String> {
        if boundaries.len() + 1 > MAX_BUCKETS {
            return Err(format!(
                "{} degree-bucket boundaries make {} buckets — at most {MAX_BUCKETS} \
                 precision tiers are supported",
                boundaries.len(),
                boundaries.len() + 1
            ));
        }
        for (i, &b) in boundaries.iter().enumerate() {
            if b == 0 {
                return Err(
                    "degree-buckets boundaries must be >= 1 (an in-degree threshold of 0 \
                     leaves the coldest bucket empty); e.g. --degree-buckets 8,64"
                        .to_string(),
                );
            }
            if i > 0 && boundaries[i - 1] >= b {
                return Err(format!(
                    "degree-buckets boundaries must be strictly increasing, got {} then {b}; \
                     e.g. --degree-buckets 8,64",
                    boundaries[i - 1]
                ));
            }
        }
        Ok(DegreeBuckets { boundaries })
    }

    /// The single-bucket partition (every node in bucket 0).
    pub fn uniform() -> Self {
        DegreeBuckets { boundaries: Vec::new() }
    }

    /// Number of buckets (`boundaries + 1`).
    pub fn num_buckets(&self) -> usize {
        self.boundaries.len() + 1
    }

    /// The ascending boundary list.
    pub fn boundaries(&self) -> &[u32] {
        &self.boundaries
    }

    /// Bucket of an in-degree: the number of boundaries strictly above it,
    /// so bucket 0 is the hottest range and the last bucket the coldest.
    pub fn bucket_of(&self, degree: u32) -> usize {
        self.boundaries.iter().filter(|&&b| degree < b).count()
    }

    /// Per-node bucket assignment (`u8` ids — see [`MAX_BUCKETS`]).
    pub fn assign(&self, degrees: &[u32]) -> Vec<u8> {
        degrees.iter().map(|&d| self.bucket_of(d) as u8).collect()
    }

    /// Human-readable in-degree range of a bucket (for report summaries):
    /// `"deg >= 64"`, `"8 <= deg < 64"`, `"deg < 8"`, or `"all degrees"`
    /// for the uniform partition. Shared with
    /// [`PolicyGatherReport`](crate::policy::PolicyGatherReport) via
    /// [`bucket_range_label`].
    pub fn range_label(&self, bucket: usize) -> String {
        bucket_range_label(&self.boundaries, bucket)
    }
}

/// Range label of `bucket` under ascending `boundaries` (see
/// [`DegreeBuckets::range_label`]).
pub fn bucket_range_label(boundaries: &[u32], bucket: usize) -> String {
    let m = boundaries.len();
    assert!(bucket <= m, "bucket {bucket} out of range for {m} boundaries");
    if m == 0 {
        return "all degrees".to_string();
    }
    if bucket == 0 {
        format!("deg >= {}", boundaries[m - 1])
    } else if bucket == m {
        format!("deg < {}", boundaries[0])
    } else {
        format!("{} <= deg < {}", boundaries[m - 1 - bucket], boundaries[m - bucket])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundaries_partition_the_degree_axis() {
        let b = DegreeBuckets::new(vec![8, 64]).unwrap();
        assert_eq!(b.num_buckets(), 3);
        assert_eq!(b.bucket_of(1000), 0);
        assert_eq!(b.bucket_of(64), 0);
        assert_eq!(b.bucket_of(63), 1);
        assert_eq!(b.bucket_of(8), 1);
        assert_eq!(b.bucket_of(7), 2);
        assert_eq!(b.bucket_of(0), 2);
    }

    #[test]
    fn uniform_has_one_bucket() {
        let b = DegreeBuckets::uniform();
        assert_eq!(b.num_buckets(), 1);
        for d in [0u32, 1, 7, 1 << 20] {
            assert_eq!(b.bucket_of(d), 0);
        }
        assert_eq!(b.range_label(0), "all degrees");
    }

    #[test]
    fn rejects_non_monotone_and_zero_boundaries() {
        assert!(DegreeBuckets::new(vec![8, 8]).unwrap_err().contains("strictly increasing"));
        assert!(DegreeBuckets::new(vec![64, 8]).unwrap_err().contains("strictly increasing"));
        assert!(DegreeBuckets::new(vec![0, 8]).unwrap_err().contains(">= 1"));
        assert!(DegreeBuckets::new((1..64).collect()).unwrap_err().contains("at most"));
        assert!(DegreeBuckets::new(vec![]).is_ok());
        assert!(DegreeBuckets::new(vec![1]).is_ok());
    }

    #[test]
    fn assignment_matches_bucket_of() {
        let b = DegreeBuckets::new(vec![2, 5]).unwrap();
        let degrees = vec![0u32, 1, 2, 4, 5, 9];
        assert_eq!(b.assign(&degrees), vec![2, 2, 1, 1, 0, 0]);
    }

    #[test]
    fn range_labels_cover_every_bucket() {
        let b = DegreeBuckets::new(vec![8, 64]).unwrap();
        assert_eq!(b.range_label(0), "deg >= 64");
        assert_eq!(b.range_label(1), "8 <= deg < 64");
        assert_eq!(b.range_label(2), "deg < 8");
    }
}
