//! Degree-aware mixed-precision policy subsystem.
//!
//! Tango's first contribution is a set of *rules* that decide where low
//! precision is safe instead of paying a uniform accuracy tax. This module
//! supplies the degree-aware rule the related work points at (Degree-Quant:
//! high-in-degree nodes are the accuracy-critical ones under quantization;
//! BiFeat: the feature gather is where the sampled-training byte traffic
//! lives — see PAPERS.md): partition nodes by in-degree, keep the hot
//! buckets at high precision, compress the cold tail hard, and optionally
//! bias fanout sampling toward the same high-degree nodes.
//!
//! The pieces, hot path first:
//!
//! - [`DegreeBuckets`] — the partition: ascending in-degree boundaries,
//!   bucket 0 hottest; complete and disjoint by construction;
//! - [`BitPolicy`] — per-bucket quantization widths (`1..=8`), hottest
//!   bucket first, so `--degree-buckets 8,64 --bucket-bits 8,6,4` reads
//!   "INT8 above degree 64, 6 bits in the middle, 4-bit cold tail";
//! - [`PolicyConfig`] — the raw knob pair carried by `TrainConfig::policy`
//!   (CLI `--degree-buckets`/`--bucket-bits`, TOML `[policy]`), validated
//!   early with actionable messages;
//! - [`FeaturePolicy`] — the policy materialized against a concrete graph:
//!   per-node bucket ids and per-bucket static symmetric scales (the
//!   feature table is static, so per-bucket scales are too). Its uniform
//!   instance reproduces the single global `(scale, bits)` exactly, which
//!   is what keeps default runs bit-identical to pre-policy builds;
//! - [`BucketGatherStats`] / [`PolicyGatherReport`] — per-bucket gather
//!   traffic (rows, hits/misses, packed bytes vs uniform INT8) surfaced
//!   through `TrainReport::policy` / `MultiGpuReport::policy` and the CLI.
//!
//! The consumer is the sampled gather path: `sampler::QuantFeatureStore`
//! holds a `FeaturePolicy` and quantizes each node's row at its bucket's
//! `(scale, bits)`; the degree-biased sampler mode
//! (`sampler::SamplerBias::Degree`, `--sampler degree`) weights fanout
//! draws by the same in-degrees the partition reads.

mod bits;
mod buckets;
mod feature;
mod report;

pub use bits::{BitPolicy, PolicyConfig};
pub use buckets::{bucket_range_label, DegreeBuckets, MAX_BUCKETS};
pub use feature::FeaturePolicy;
pub use report::{BucketGatherStats, PolicyGatherReport};
