//! Training orchestrator: dataset + model + mode + epochs → loss curve and
//! final metric. This is what `tango train` and the Fig. 7/8 repro drive.
//!
//! The trainer holds an [`AnyModel`] behind the [`GnnModel`] trait (the one
//! model dispatcher in the crate, see `model/mod.rs`) and a [`TaskHead`]
//! for the loss side, so model architectures and learning tasks compose
//! freely. Full-graph epochs run the unified block path over identity
//! blocks inside the model; when `TrainConfig::sampler.enabled` is set the
//! run delegates to [`crate::sampler::MiniBatchTrainer`] (which serves both
//! tasks too — node classification on node-seeded blocks, link prediction
//! on edge-seeded blocks).

use crate::config::{TaskKind, TrainConfig};
use crate::coordinator::qcache::CacheStats;
use crate::graph::datasets::{self, Dataset, Task};
use crate::model::{
    softmax_cross_entropy, AnyModel, GnnModel, ModelSpec, Sgd, TaskHead, TrainMode,
};
use crate::quant::rng::Xoshiro256pp;
use crate::quant::{derive_bits, DEFAULT_ERROR_TARGET};

/// Where one epoch's wall time went.
///
/// `sample_s`/`gather_s` are stage-one *producer-side* work: when
/// `prefetch > 0` they overlap with compute and do **not** sum into the
/// wall. The consumer-side budget `wait_s + compute_s + eval_s`
/// ([`accounted`](Self::accounted)) is what closes against the measured
/// `wall_s` — within a small bookkeeping slack (shuffling, channel
/// plumbing), asserted in `tests/training_integration.rs`.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EpochStages {
    /// Stage-one sampling seconds (producer side; 0 for full-graph runs).
    pub sample_s: f64,
    /// Stage-one feature-gather seconds (producer side; 0 for full-graph).
    pub gather_s: f64,
    /// Stage-one seconds *not* hidden by the prefetch pipeline (the whole
    /// inline stage-one time when `prefetch = 0`).
    pub wait_s: f64,
    /// Forward + backward + update seconds on the training thread.
    pub compute_s: f64,
    /// Evaluation seconds.
    pub eval_s: f64,
    /// Measured epoch wall seconds (training sweep + evaluation).
    pub wall_s: f64,
}

impl EpochStages {
    /// Consumer-side accounted seconds: `wait + compute + eval`.
    pub fn accounted(&self) -> f64 {
        self.wait_s + self.compute_s + self.eval_s
    }

    /// Fold another epoch's stages in (run totals).
    pub fn add(&mut self, other: &EpochStages) {
        self.sample_s += other.sample_s;
        self.gather_s += other.gather_s;
        self.wait_s += other.wait_s;
        self.compute_s += other.compute_s;
        self.eval_s += other.eval_s;
        self.wall_s += other.wall_s;
    }
}

/// One training run's results.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Loss after every epoch.
    pub losses: Vec<f32>,
    /// Evaluation metric after every epoch (accuracy for NC, AUC for LP).
    pub evals: Vec<f32>,
    /// Final evaluation metric.
    pub final_eval: f32,
    /// Total measured wall seconds across epochs — the *full* budget
    /// (training sweep + per-epoch evaluation) that [`stages`](Self::stages)
    /// breaks down, not just forward+backward+update.
    pub wall_secs: f64,
    /// Bit width used (after auto-derivation if enabled).
    pub bits: u8,
    /// Epochs until the loss first dropped below 1.02× its final value
    /// (a convergence-speed proxy for the Fig. 7 comparison).
    pub epochs_to_converge: usize,
    /// Quantized feature-gather cache statistics (sampled quantized runs
    /// only — `None` for full-graph or FP32 runs).
    pub cache: Option<CacheStats>,
    /// Bytes of INT8 rows held by the feature cache at run end.
    pub cache_bytes: usize,
    /// Per-bucket gather accounting of the degree-aware mixed-precision
    /// policy (sampled quantized runs only; the uniform policy reports one
    /// bucket).
    pub policy: Option<crate::policy::PolicyGatherReport>,
    /// Sampled runs: measured stage-one (sampling + gather) seconds *not*
    /// hidden by the prefetch pipeline — the whole inline stage-one time
    /// when `prefetch = 0`, only the consumer's channel-wait otherwise.
    /// 0 for full-graph runs.
    pub prefetch_wait_s: f64,
    /// Per-epoch stage breakdown; each entry's `wait + compute + eval`
    /// closes against its measured `wall_s`.
    pub stages: Vec<EpochStages>,
    /// Fault-injection ledger (`--inject-faults` runs only; `None` when the
    /// harness is off). Lands in the artifact's `fault` section.
    pub fault: Option<crate::fault::FaultReport>,
}

impl TrainReport {
    /// Sum of the per-epoch stage breakdown (whole-run budget).
    pub fn stage_totals(&self) -> EpochStages {
        let mut t = EpochStages::default();
        for s in &self.stages {
            t.add(s);
        }
        t
    }
}

/// The training coordinator.
pub struct Trainer {
    cfg: TrainConfig,
    data: Dataset,
    /// Effective task (config override or the dataset's declared task).
    task: Task,
    head: TaskHead,
    model: AnyModel,
    opt: Sgd,
}

impl Trainer {
    /// Build everything from a config (loads the dataset, derives bits if
    /// requested, initialises the model).
    pub fn from_config(cfg: &TrainConfig) -> crate::Result<Self> {
        let data = datasets::load_by_name_checked(&cfg.dataset, cfg.seed)
            .map_err(|e| anyhow::anyhow!(e))?;
        Self::with_dataset(cfg.clone(), data)
    }

    /// Build with an externally supplied dataset (multi-worker path).
    pub fn with_dataset(mut cfg: TrainConfig, data: Dataset) -> crate::Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        // The degree-aware policy lives in the sampled gather path; a
        // full-graph run would silently ignore it while claiming mixed
        // precision. (Checked here, not in `TrainConfig::validate`: the
        // multi-GPU engine always samples and never consults `enabled`.)
        if !cfg.policy.is_uniform() && !cfg.sampler.enabled {
            anyhow::bail!(
                "degree-buckets/bucket-bits apply to the sampled feature gather — \
                 enable sampling (--sampler neighbor or --sampler degree) to use them"
            );
        }
        let task = TaskKind::resolve(cfg.task, data.task);
        let head = TaskHead::for_task(task);
        let out_dim = head.out_dim(&data, cfg.hidden);
        // The Fig. 2 rule: quantize the first layer's output of the initial
        // model and pick the bit width meeting Error_X <= 0.3.
        if cfg.auto_bits && cfg.mode.quantize {
            let probe = Self::build_model(&cfg, &data, out_dim);
            let first = probe.first_layer_output(&data.features);
            cfg.mode.bits = derive_bits(&first, DEFAULT_ERROR_TARGET).bits;
        }
        let model = Self::build_model(&cfg, &data, out_dim);
        let opt = Sgd::new(cfg.lr);
        Ok(Trainer { cfg, data, task, head, model, opt })
    }

    fn build_model(cfg: &TrainConfig, data: &Dataset, out_dim: usize) -> AnyModel {
        AnyModel::new_from_config(
            &ModelSpec::from_train(cfg, data.features.cols(), out_dim),
            &data.graph,
            cfg.seed,
        )
    }

    /// The dataset being trained on.
    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// The effective task of this run.
    pub fn task(&self) -> Task {
        self.task
    }

    /// The effective mode (bits may have been auto-derived).
    pub fn mode(&self) -> TrainMode {
        self.cfg.mode
    }

    /// Run the configured number of epochs. When
    /// `TrainConfig::sampler.enabled` is set, training runs as sampled
    /// mini-batches via [`crate::sampler::MiniBatchTrainer`] instead of
    /// full-graph steps (evaluation stays full-graph in both modes).
    pub fn run(&mut self) -> crate::Result<TrainReport> {
        if self.cfg.sampler.enabled {
            // Bits were already derived in `with_dataset` when auto_bits is
            // set — don't re-run the probe inside the delegate.
            let mut cfg = self.cfg.clone();
            cfg.auto_bits = false;
            let mut mb =
                crate::sampler::MiniBatchTrainer::with_dataset(cfg, self.data.clone())?;
            let report = mb.run()?;
            // Adopt the trained weights so `evaluate()` (and a later
            // full-graph `run()`) continue from the sampled training state.
            let trained = mb.params_flat();
            self.model.set_params_flat(&trained);
            return Ok(report);
        }
        // Full-graph checkpoints sit at epoch boundaries: one train_step per
        // epoch means the model's step_count *is* the epoch count, so the
        // `--ckpt-every` cadence counts epochs here.
        let fingerprint = crate::ckpt::fingerprint_of(&self.cfg, 1, false);
        let mut losses = Vec::with_capacity(self.cfg.epochs);
        let mut evals = Vec::with_capacity(self.cfg.epochs);
        let mut stages = Vec::with_capacity(self.cfg.epochs);
        let mut wall = 0.0f64;
        let mut start_epoch = 0usize;
        if let Some(path) = self.cfg.ckpt.resume.clone() {
            let ck = crate::ckpt::Checkpoint::load(&path)?;
            ck.validate_resume("train", &fingerprint)?;
            if ck.cursor.step != 0 {
                anyhow::bail!(
                    "checkpoint {path} has a mid-epoch cursor (step {}), but full-graph \
                     training checkpoints at epoch boundaries — was it written by a \
                     --sampler run?",
                    ck.cursor.step
                );
            }
            self.model.set_params_flat(&ck.params);
            self.model.set_step_count(ck.step_count);
            self.opt.import_velocity(ck.velocity.clone());
            losses = ck.losses.iter().map(|&l| l as f32).collect();
            evals = ck.evals.iter().map(|&e| e as f32).collect();
            // Completed epochs carry no timings in a resumed report.
            stages.resize(ck.cursor.epoch, EpochStages::default());
            start_epoch = ck.cursor.epoch;
            crate::obs::counter_add(crate::obs::keys::CTR_CKPT_RESUMES, 1);
        }
        for epoch in start_epoch..self.cfg.epochs {
            let _epoch_span = crate::obs::span(crate::obs::keys::SPAN_EPOCH);
            let t_epoch = std::time::Instant::now();
            let (loss, secs) = crate::metrics::time_once(|| self.train_epoch(epoch as u64));
            let (eval, eval_s) = crate::metrics::time_once(|| {
                let _s = crate::obs::span(crate::obs::keys::SPAN_EVAL);
                self.evaluate()
            });
            let wall_s = t_epoch.elapsed().as_secs_f64();
            wall += wall_s;
            stages.push(EpochStages {
                compute_s: secs,
                eval_s,
                wall_s,
                ..EpochStages::default()
            });
            if self.cfg.log_every > 0 && epoch % self.cfg.log_every == 0 {
                println!(
                    "epoch {epoch:>4}  loss {loss:>8.4}  eval {eval:>6.4}  ({:.1} ms)",
                    secs * 1e3
                );
            }
            losses.push(loss);
            evals.push(eval);
            if self.cfg.ckpt.every > 0
                && epoch + 1 < self.cfg.epochs
                && self.model.step_count() % self.cfg.ckpt.every as u64 == 0
            {
                self.save_checkpoint(&fingerprint, epoch + 1, &losses, &evals)?;
            }
        }
        // Run-complete checkpoint: the crash-resume CI job byte-compares it
        // against the control's.
        if self.cfg.ckpt.every > 0 {
            self.save_checkpoint(&fingerprint, self.cfg.epochs, &losses, &evals)?;
        }
        let final_eval = *evals.last().unwrap_or(&0.0);
        let final_loss = *losses.last().unwrap_or(&f32::INFINITY);
        let epochs_to_converge = losses
            .iter()
            .position(|&l| l <= final_loss * 1.02)
            .unwrap_or(losses.len());
        Ok(TrainReport {
            losses,
            evals,
            final_eval,
            wall_secs: wall,
            bits: self.cfg.mode.bits,
            epochs_to_converge,
            cache: None,
            cache_bytes: 0,
            policy: None,
            prefetch_wait_s: 0.0,
            stages,
            // Full-graph runs have no producer/worker/link surface; an
            // injection-enabled run still reports an (all-zero) ledger so
            // the artifact's `fault` section reflects the knob.
            fault: crate::fault::FaultInjector::new(&self.cfg.fault).map(|i| i.report),
        })
    }

    /// Write an epoch-boundary checkpoint (`cursor.step == 0`).
    fn save_checkpoint(
        &self,
        fingerprint: &crate::ckpt::Fingerprint,
        next_epoch: usize,
        losses: &[f32],
        evals: &[f32],
    ) -> crate::Result<()> {
        let ck = crate::ckpt::Checkpoint {
            command: "train".to_string(),
            fingerprint: fingerprint.clone(),
            cursor: crate::ckpt::Cursor {
                epoch: next_epoch,
                step: 0,
                loss_sum: 0.0,
                loss_steps: 0,
            },
            step_count: self.model.step_count(),
            params: self.model.params_flat(),
            velocity: self.opt.export_velocity(),
            policy_scales: None,
            losses: losses.iter().map(|&l| l as f64).collect(),
            evals: evals.iter().map(|&e| e as f64).collect(),
        };
        ck.save(&self.cfg.ckpt.path)
    }

    /// Flattened model parameters (bit-identity assertions in tests).
    pub fn model_params(&self) -> Vec<f32> {
        self.model.params_flat()
    }

    /// One full-graph training step (identity-block execution inside the
    /// model — see `model/mod.rs`). Destructuring `self` gives the model,
    /// optimizer and dataset disjoint borrows, so nothing is cloned.
    fn train_epoch(&mut self, epoch: u64) -> f32 {
        let _compute_span = crate::obs::span(crate::obs::keys::SPAN_COMPUTE);
        let Trainer { task, model, opt, data, cfg, .. } = self;
        match task {
            Task::NodeClassification => {
                model
                    .train_step(&data.features, opt, &mut |lg| {
                        softmax_cross_entropy(lg, &data.labels, &data.train_nodes)
                    })
                    .0
            }
            Task::LinkPrediction => {
                // Positive edges + seeded uniform negatives, dot-product
                // scores, BCE — the TaskHead decoder over global node rows.
                let mut rng = Xoshiro256pp::new(cfg.seed ^ epoch.wrapping_mul(0x1234_5678_9ABC));
                let pairs = TaskHead::sample_global_pairs(&data.graph, 4096, &mut rng);
                model
                    .train_step(&data.features, opt, &mut |emb| {
                        TaskHead::lp_loss_grad(emb, &pairs)
                    })
                    .0
            }
        }
    }

    /// Evaluation metric on the held-out split (accuracy for NC, AUC for
    /// LP — the head dispatches).
    pub fn evaluate(&self) -> f32 {
        let out = self.model.forward(&self.data.features);
        self.head.evaluate(&out, &self.data, self.cfg.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{parse_mode, ModelKind};

    fn quick_cfg(model: ModelKind, mode: &str) -> TrainConfig {
        TrainConfig {
            model,
            dataset: "tiny".into(),
            epochs: 40,
            lr: 0.1,
            hidden: 16,
            heads: 4,
            layers: 2,
            mode: parse_mode(mode, 8).unwrap(),
            auto_bits: false,
            seed: 3,
            log_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn full_graph_run_rejects_a_dead_policy() {
        // A non-uniform policy with sampling off would silently train
        // single-scale while the config claims mixed precision.
        let mut cfg = quick_cfg(ModelKind::Gcn, "tango");
        cfg.policy.degree_buckets = vec![8];
        let err = Trainer::from_config(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("sampled feature gather"), "{err:#}");
        // With sampling on, the same policy is accepted.
        cfg.sampler.enabled = true;
        assert!(Trainer::from_config(&cfg).is_ok());
    }

    #[test]
    fn gcn_trainer_learns_tiny_nc() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gcn, "tango")).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 40);
        assert!(r.losses[39] < r.losses[0], "{:?}", r.losses);
        assert!(r.final_eval > 0.3, "eval {}", r.final_eval);
        assert!(r.cache.is_none(), "full-graph runs have no gather cache");
    }

    #[test]
    fn gat_trainer_learns_tiny_nc() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gat, "tango")).unwrap();
        let r = t.run().unwrap();
        assert!(r.losses[39] < r.losses[0]);
    }

    #[test]
    fn auto_bits_derives_a_width() {
        let mut cfg = quick_cfg(ModelKind::Gcn, "tango");
        cfg.auto_bits = true;
        let t = Trainer::from_config(&cfg).unwrap();
        let bits = t.mode().bits;
        assert!((2..=8).contains(&bits), "derived bits {bits}");
    }

    #[test]
    fn lp_task_trains_and_reports_auc() {
        let mut cfg = quick_cfg(ModelKind::Gcn, "fp32");
        cfg.dataset = "DBLP".into();
        cfg.epochs = 3;
        // shrink for test speed
        cfg.hidden = 8;
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.task(), Task::LinkPrediction);
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 3);
        assert!(r.final_eval > 0.0 && r.final_eval <= 1.0);
    }

    #[test]
    fn task_override_runs_linkpred_on_nc_dataset() {
        // `--task linkpred` on a node-classification graph: the head trains
        // on topology alone and reports AUC.
        let mut cfg = quick_cfg(ModelKind::Gcn, "fp32");
        cfg.epochs = 8;
        cfg.task = Some(TaskKind::LinkPrediction);
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.task(), Task::LinkPrediction);
        let r = t.run().unwrap();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(r.final_eval > 0.0 && r.final_eval <= 1.0, "AUC {}", r.final_eval);
        // And the reverse: force NC on an LP dataset (labels are random
        // community ids — it must *run*, not necessarily learn).
        let mut cfg = quick_cfg(ModelKind::Gcn, "fp32");
        cfg.dataset = "DBLP".into();
        cfg.hidden = 8;
        cfg.epochs = 2;
        cfg.task = Some(TaskKind::NodeClassification);
        let mut t = Trainer::from_config(&cfg).unwrap();
        assert_eq!(t.task(), Task::NodeClassification);
        let r = t.run().unwrap();
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn sampler_flag_delegates_to_minibatch_path() {
        // `tango train --sampler neighbor` goes through the same Trainer
        // front door; with generous fanouts on tiny the sampled run must
        // land within 5% of the full-graph run (the DGL-parity criterion).
        let mut full_cfg = quick_cfg(ModelKind::Gcn, "tango");
        full_cfg.epochs = 60;
        let full = Trainer::from_config(&full_cfg).unwrap().run().unwrap();

        let mut mb_cfg = full_cfg.clone();
        mb_cfg.sampler.enabled = true;
        mb_cfg.sampler.fanouts = vec![16, 16];
        mb_cfg.sampler.batch_size = 64;
        let mb = Trainer::from_config(&mb_cfg).unwrap().run().unwrap();

        assert_eq!(mb.losses.len(), 60);
        assert!(mb.losses[59] < mb.losses[0], "{:?}", mb.losses);
        assert!(
            mb.final_eval >= full.final_eval - 0.05,
            "sampled eval {} vs full-graph {}",
            mb.final_eval,
            full.final_eval
        );
        // The sampled quantized run surfaces its gather-cache stats.
        let stats = mb.cache.expect("sampled tango run has cache stats");
        assert!(stats.hits + stats.misses > 0);
        assert!(mb.cache_bytes > 0);
        // The Trainer adopts the trained weights from the sampled run, so
        // its own evaluate() reflects the training (stochastic-rounding
        // streams differ by step count, hence the tolerance).
        let mut t = Trainer::from_config(&mb_cfg).unwrap();
        let report = t.run().unwrap();
        let after = t.evaluate();
        assert!(
            (after - report.final_eval).abs() < 0.05,
            "adopted-weights eval {after} vs reported {}",
            report.final_eval
        );
    }

    #[test]
    fn convergence_epoch_is_sane() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gcn, "fp32")).unwrap();
        let r = t.run().unwrap();
        assert!(r.epochs_to_converge <= r.losses.len());
    }
}
