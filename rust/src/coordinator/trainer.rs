//! Training orchestrator: dataset + model + mode + epochs → loss curve and
//! final metric. This is what `tango train` and the Fig. 7/8 repro drive.

use crate::config::{ModelKind, TrainConfig};
use crate::graph::datasets::{self, Dataset, Task};
use crate::model::{
    accuracy, auc, bce_with_logits, softmax_cross_entropy, GatConfig, GatModel, GcnConfig,
    GcnModel, Sgd, TrainMode,
};
use crate::quant::rng::Xoshiro256pp;
use crate::quant::{derive_bits, DEFAULT_ERROR_TARGET};
use crate::tensor::Dense;

/// The model under training.
enum AnyModel {
    Gcn(GcnModel),
    Gat(GatModel),
}

/// One training run's results.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Loss after every epoch.
    pub losses: Vec<f32>,
    /// Evaluation metric after every epoch (accuracy for NC, AUC for LP).
    pub evals: Vec<f32>,
    /// Final evaluation metric.
    pub final_eval: f32,
    /// Total wall-clock training seconds (forward+backward+update only).
    pub wall_secs: f64,
    /// Bit width used (after auto-derivation if enabled).
    pub bits: u8,
    /// Epochs until the loss first dropped below 1.02× its final value
    /// (a convergence-speed proxy for the Fig. 7 comparison).
    pub epochs_to_converge: usize,
}

/// The training coordinator.
pub struct Trainer {
    cfg: TrainConfig,
    data: Dataset,
    model: AnyModel,
    opt: Sgd,
}

impl Trainer {
    /// Build everything from a config (loads the dataset, derives bits if
    /// requested, initialises the model).
    pub fn from_config(cfg: &TrainConfig) -> crate::Result<Self> {
        let data = if cfg.dataset == "tiny" {
            datasets::tiny(cfg.seed)
        } else {
            datasets::load_by_name(&cfg.dataset, cfg.seed)
        };
        Self::with_dataset(cfg.clone(), data)
    }

    /// Build with an externally supplied dataset (multi-worker path).
    pub fn with_dataset(mut cfg: TrainConfig, data: Dataset) -> crate::Result<Self> {
        let out_dim = match data.task {
            Task::NodeClassification => data.num_classes,
            // LP trains an embedding; score = dot of endpoint embeddings.
            Task::LinkPrediction => cfg.hidden.min(64),
        };
        // The Fig. 2 rule: quantize the first layer's output of the initial
        // model and pick the bit width meeting Error_X <= 0.3.
        if cfg.auto_bits && cfg.mode.quantize {
            let probe = Self::build_model(&cfg, &data, out_dim);
            let first = match &probe {
                AnyModel::Gcn(m) => m.first_layer_output(&data.features),
                AnyModel::Gat(m) => m.first_layer_output(&data.features),
            };
            let derived = derive_bits(&first, DEFAULT_ERROR_TARGET);
            cfg.mode.bits = derived.bits;
        }
        let model = Self::build_model(&cfg, &data, out_dim);
        let opt = Sgd::new(cfg.lr);
        Ok(Trainer { cfg, data, model, opt })
    }

    fn build_model(cfg: &TrainConfig, data: &Dataset, out_dim: usize) -> AnyModel {
        match cfg.model {
            ModelKind::Gcn => AnyModel::Gcn(GcnModel::new(
                GcnConfig {
                    in_dim: data.features.cols(),
                    hidden: cfg.hidden,
                    out_dim,
                    layers: cfg.layers,
                    mode: cfg.mode,
                },
                &data.graph,
                cfg.seed,
            )),
            ModelKind::Gat => AnyModel::Gat(GatModel::new(
                GatConfig {
                    in_dim: data.features.cols(),
                    hidden: cfg.hidden,
                    out_dim,
                    heads: cfg.heads,
                    layers: cfg.layers,
                    mode: cfg.mode,
                },
                &data.graph,
                cfg.seed,
            )),
        }
    }

    /// The dataset being trained on.
    pub fn dataset(&self) -> &Dataset {
        &self.data
    }

    /// The effective mode (bits may have been auto-derived).
    pub fn mode(&self) -> TrainMode {
        self.cfg.mode
    }

    /// Run the configured number of epochs. When
    /// `TrainConfig::sampler.enabled` is set, training runs as sampled
    /// mini-batches via [`crate::sampler::MiniBatchTrainer`] instead of
    /// full-graph steps (evaluation stays full-graph in both modes).
    pub fn run(&mut self) -> crate::Result<TrainReport> {
        if self.cfg.sampler.enabled {
            // Bits were already derived in `with_dataset` when auto_bits is
            // set — don't re-run the probe inside the delegate.
            let mut cfg = self.cfg.clone();
            cfg.auto_bits = false;
            let mut mb =
                crate::sampler::MiniBatchTrainer::with_dataset(cfg, self.data.clone())?;
            let report = mb.run()?;
            // Adopt the trained weights so `evaluate()` (and a later
            // full-graph `run()`) continue from the sampled training state.
            let trained = mb.params_flat();
            match &mut self.model {
                AnyModel::Gcn(m) => m.set_params_flat(&trained),
                AnyModel::Gat(m) => m.set_params_flat(&trained),
            }
            return Ok(report);
        }
        let mut losses = Vec::with_capacity(self.cfg.epochs);
        let mut evals = Vec::with_capacity(self.cfg.epochs);
        let mut wall = 0.0f64;
        for epoch in 0..self.cfg.epochs {
            let (loss, secs) = crate::metrics::time_once(|| self.train_epoch(epoch as u64));
            wall += secs;
            let eval = self.evaluate();
            if self.cfg.log_every > 0 && epoch % self.cfg.log_every == 0 {
                println!(
                    "epoch {epoch:>4}  loss {loss:>8.4}  eval {eval:>6.4}  ({:.1} ms)",
                    secs * 1e3
                );
            }
            losses.push(loss);
            evals.push(eval);
        }
        let final_eval = *evals.last().unwrap_or(&0.0);
        let final_loss = *losses.last().unwrap_or(&f32::INFINITY);
        let epochs_to_converge = losses
            .iter()
            .position(|&l| l <= final_loss * 1.02)
            .unwrap_or(losses.len());
        Ok(TrainReport {
            losses,
            evals,
            final_eval,
            wall_secs: wall,
            bits: self.cfg.mode.bits,
            epochs_to_converge,
        })
    }

    /// One full-graph training step.
    fn train_epoch(&mut self, epoch: u64) -> f32 {
        match self.data.task {
            Task::NodeClassification => {
                let (labels, train) = (self.data.labels.clone(), self.data.train_nodes.clone());
                let features = self.data.features.clone();
                let opt = &mut self.opt;
                match &mut self.model {
                    AnyModel::Gcn(m) => {
                        m.train_step(&features, opt, |lg| softmax_cross_entropy(lg, &labels, &train)).0
                    }
                    AnyModel::Gat(m) => {
                        m.train_step(&features, opt, |lg| softmax_cross_entropy(lg, &labels, &train)).0
                    }
                }
            }
            Task::LinkPrediction => self.train_epoch_lp(epoch),
        }
    }

    /// LP step: positive edges + sampled negatives, dot-product scores, BCE.
    fn train_epoch_lp(&mut self, epoch: u64) -> f32 {
        let graph = self.data.graph.clone();
        let n = graph.num_nodes;
        let mut rng = Xoshiro256pp::new(self.cfg.seed ^ epoch.wrapping_mul(0x1234_5678_9ABC));
        // Sample up to 4096 positive edges and as many negatives.
        let m = graph.num_edges().min(4096);
        let mut pairs: Vec<(u32, u32, f32)> = Vec::with_capacity(2 * m);
        for _ in 0..m {
            let e = (rng.next_u64() % graph.num_edges() as u64) as usize;
            pairs.push((graph.src[e], graph.dst[e], 1.0));
            pairs.push((
                (rng.next_u64() % n as u64) as u32,
                (rng.next_u64() % n as u64) as u32,
                0.0,
            ));
        }
        let features = self.data.features.clone();
        let opt = &mut self.opt;
        let loss_grad = |emb: &Dense<f32>| -> (f32, Dense<f32>) {
            let dim = emb.cols();
            let scores: Vec<f32> = pairs
                .iter()
                .map(|&(u, v, _)| {
                    emb.row(u as usize).iter().zip(emb.row(v as usize)).map(|(a, b)| a * b).sum()
                })
                .collect();
            let targets: Vec<f32> = pairs.iter().map(|p| p.2).collect();
            let (loss, dscores) = bce_with_logits(&scores, &targets);
            let mut grad = Dense::zeros(&[emb.rows(), dim]);
            for (k, &(u, v, _)) in pairs.iter().enumerate() {
                let g = dscores[k];
                // ∂/∂emb[u] = g·emb[v]; ∂/∂emb[v] = g·emb[u].
                for j in 0..dim {
                    grad.row_mut(u as usize)[j] += g * emb.at(v as usize, j);
                }
                for j in 0..dim {
                    grad.row_mut(v as usize)[j] += g * emb.at(u as usize, j);
                }
            }
            (loss, grad)
        };
        match &mut self.model {
            AnyModel::Gcn(m) => m.train_step(&features, opt, loss_grad).0,
            AnyModel::Gat(m) => m.train_step(&features, opt, loss_grad).0,
        }
    }

    /// Evaluation metric on the held-out split.
    pub fn evaluate(&self) -> f32 {
        let out = match &self.model {
            AnyModel::Gcn(m) => m.forward(&self.data.features),
            AnyModel::Gat(m) => m.forward(&self.data.features),
        };
        match self.data.task {
            Task::NodeClassification => accuracy(&out, &self.data.labels, &self.data.eval_nodes),
            Task::LinkPrediction => {
                // AUC over held-out positive edges vs random pairs.
                let g = &self.data.graph;
                let mut rng = Xoshiro256pp::new(self.cfg.seed ^ 0xEA1);
                let k = g.num_edges().min(2000);
                let mut pos = Vec::with_capacity(k);
                let mut neg = Vec::with_capacity(k);
                for _ in 0..k {
                    let e = (rng.next_u64() % g.num_edges() as u64) as usize;
                    let (u, v) = (g.src[e] as usize, g.dst[e] as usize);
                    pos.push(out.row(u).iter().zip(out.row(v)).map(|(a, b)| a * b).sum());
                    let (ru, rv) = (
                        (rng.next_u64() % g.num_nodes as u64) as usize,
                        (rng.next_u64() % g.num_nodes as u64) as usize,
                    );
                    neg.push(out.row(ru).iter().zip(out.row(rv)).map(|(a, b)| a * b).sum());
                }
                auc(&pos, &neg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_mode;

    fn quick_cfg(model: ModelKind, mode: &str) -> TrainConfig {
        TrainConfig {
            model,
            dataset: "tiny".into(),
            epochs: 40,
            lr: 0.1,
            hidden: 16,
            heads: 4,
            layers: 2,
            mode: parse_mode(mode, 8).unwrap(),
            auto_bits: false,
            seed: 3,
            log_every: 0,
            ..Default::default()
        }
    }

    #[test]
    fn gcn_trainer_learns_tiny_nc() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gcn, "tango")).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 40);
        assert!(r.losses[39] < r.losses[0], "{:?}", r.losses);
        assert!(r.final_eval > 0.3, "eval {}", r.final_eval);
    }

    #[test]
    fn gat_trainer_learns_tiny_nc() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gat, "tango")).unwrap();
        let r = t.run().unwrap();
        assert!(r.losses[39] < r.losses[0]);
    }

    #[test]
    fn auto_bits_derives_a_width() {
        let mut cfg = quick_cfg(ModelKind::Gcn, "tango");
        cfg.auto_bits = true;
        let t = Trainer::from_config(&cfg).unwrap();
        let bits = t.mode().bits;
        assert!((2..=8).contains(&bits), "derived bits {bits}");
    }

    #[test]
    fn lp_task_trains_and_reports_auc() {
        let mut cfg = quick_cfg(ModelKind::Gcn, "fp32");
        cfg.dataset = "DBLP".into();
        cfg.epochs = 3;
        // shrink for test speed
        cfg.hidden = 8;
        let mut t = Trainer::from_config(&cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses.len(), 3);
        assert!(r.final_eval > 0.0 && r.final_eval <= 1.0);
    }

    #[test]
    fn sampler_flag_delegates_to_minibatch_path() {
        // `tango train --sampler neighbor` goes through the same Trainer
        // front door; with generous fanouts on tiny the sampled run must
        // land within 5% of the full-graph run (the DGL-parity criterion).
        let mut full_cfg = quick_cfg(ModelKind::Gcn, "tango");
        full_cfg.epochs = 60;
        let full = Trainer::from_config(&full_cfg).unwrap().run().unwrap();

        let mut mb_cfg = full_cfg.clone();
        mb_cfg.sampler.enabled = true;
        mb_cfg.sampler.fanouts = vec![16, 16];
        mb_cfg.sampler.batch_size = 64;
        let mb = Trainer::from_config(&mb_cfg).unwrap().run().unwrap();

        assert_eq!(mb.losses.len(), 60);
        assert!(mb.losses[59] < mb.losses[0], "{:?}", mb.losses);
        assert!(
            mb.final_eval >= full.final_eval - 0.05,
            "sampled eval {} vs full-graph {}",
            mb.final_eval,
            full.final_eval
        );
        // The Trainer adopts the trained weights from the sampled run, so
        // its own evaluate() reflects the training (stochastic-rounding
        // streams differ by step count, hence the tolerance).
        let mut t = Trainer::from_config(&mb_cfg).unwrap();
        let report = t.run().unwrap();
        let after = t.evaluate();
        assert!(
            (after - report.final_eval).abs() < 0.05,
            "adopted-weights eval {after} vs reported {}",
            report.final_eval
        );
    }

    #[test]
    fn convergence_epoch_is_sane() {
        let mut t = Trainer::from_config(&quick_cfg(ModelKind::Gcn, "fp32")).unwrap();
        let r = t.run().unwrap();
        assert!(r.epochs_to_converge <= r.losses.len());
    }
}
