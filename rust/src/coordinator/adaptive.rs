//! Kernel-count-based adaptive SPMM selection (paper §3.3, Fig. 6/14).
//!
//! A three-matrix SPMM (graph × edge-features × node-features) can run as:
//!
//! - the native DGL-style kernel (one launch, reads the sparse structure
//!   once, but a slower per-element rate — DGL's generic 3-matrix kernel);
//! - `H` per-head two-matrix "cuSPARSE" SPMMs (the faster cuSPARSE rate,
//!   `H` launches, `H` re-reads of the structure);
//! - `H·D` SpMVs (same fast rate, but launch count and structure re-reads
//!   explode — Fig. 14's rising tail).
//!
//! "Neither DGL nor transformed cuSPARSE bests the other across all
//! configurations. We hence adaptively leverage these two solutions." The
//! cost model captures the two opposing forces the paper measures: the
//! split kernels' ~2× better per-element rate (Fig. 13) versus the
//! per-kernel fixed costs (launch + one pass over the CSR structure), which
//! the native kernel amortises across all feature columns.

/// Which kernel the adaptive policy selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpmmKernel {
    /// Native three-matrix kernel (one launch).
    Native3Mat,
    /// One two-matrix SPMM per head (`heads` launches).
    PerHeadSplit,
    /// One SpMV per (head, column) (`heads·dim` launches).
    ManySpmv,
}

/// Cost-model constants, calibrated so the Fig. 13/14 shapes reproduce
/// (split rate ≈ 2× native, crossover at feature size ≈ 6–8 on an
/// ogbn-arxiv-sized graph).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveCosts {
    /// Fixed cost per kernel launch (seconds). ~5 µs on CUDA.
    pub launch_overhead: f64,
    /// Per-stored-edge cost of reading the CSR structure once (indptr +
    /// indices), paid once per kernel launch.
    pub structure_per_edge: f64,
    /// Per-element compute/traffic rate of the native three-matrix kernel.
    pub native_per_elem: f64,
    /// Per-element rate of the split cuSPARSE-style kernels (the paper's
    /// "significantly faster" single-purpose kernels).
    pub split_per_elem: f64,
}

impl Default for AdaptiveCosts {
    fn default() -> Self {
        AdaptiveCosts {
            launch_overhead: 5e-6,
            structure_per_edge: 2.0e-9,
            native_per_elem: 2.7e-9,
            split_per_elem: 1.0e-9,
        }
    }
}

impl AdaptiveCosts {
    fn fixed_per_kernel(&self, edges: usize) -> f64 {
        self.launch_overhead + edges as f64 * self.structure_per_edge
    }
}

/// Modelled cost of each option (used by `repro fig14` to print the
/// crossover curve).
pub fn modelled_costs(edges: usize, heads: usize, dim: usize, costs: &AdaptiveCosts) -> [(SpmmKernel, f64); 3] {
    let work = (edges * heads * dim) as f64;
    let fixed = costs.fixed_per_kernel(edges);
    [
        (SpmmKernel::Native3Mat, fixed + work * costs.native_per_elem),
        (SpmmKernel::PerHeadSplit, fixed * heads as f64 + work * costs.split_per_elem),
        (SpmmKernel::ManySpmv, fixed * (heads * dim) as f64 + work * costs.split_per_elem),
    ]
}

/// Pick the cheapest kernel for an SPMM over `edges` stored entries with
/// `heads` heads of width `dim` each.
pub fn choose_spmm_kernel(edges: usize, heads: usize, dim: usize, costs: &AdaptiveCosts) -> SpmmKernel {
    let all = modelled_costs(edges, heads, dim, costs);
    // First strict minimum over a fixed-size non-empty array (same tie-break
    // as `min_by`), without the unwrap the iterator API would force.
    let mut best = all[0];
    for cand in &all[1..] {
        if cand.1 < best.1 {
            best = *cand;
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn large_work_prefers_split() {
        // Big graph, few heads: fixed costs are amortised, the faster
        // per-element rate wins.
        let k = choose_spmm_kernel(1_000_000, 4, 32, &AdaptiveCosts::default());
        assert_eq!(k, SpmmKernel::PerHeadSplit);
    }

    #[test]
    fn tiny_work_prefers_native() {
        // Tiny graph with huge head count: launches dominate.
        let k = choose_spmm_kernel(100, 64, 8, &AdaptiveCosts::default());
        assert_eq!(k, SpmmKernel::Native3Mat);
    }

    #[test]
    fn fig14_crossover_on_arxiv_sized_graph() {
        // Fig. 14's shape: single-head SPMM on an ogbn-arxiv-sized graph
        // (1.17M edges); the many-SpMV transform wins at small feature size
        // and loses once kernel count (= feature size) grows.
        let costs = AdaptiveCosts::default();
        let edges = 1_166_243;
        let spmv_cost = |dim: usize| modelled_costs(edges, 1, dim, &costs)[2].1;
        let native_cost = |dim: usize| modelled_costs(edges, 1, dim, &costs)[0].1;
        assert!(spmv_cost(2) < native_cost(2), "SpMV must win at feature size 2");
        assert!(spmv_cost(12) > native_cost(12), "SpMV must lose at feature size 12");
        // There is a crossover point in between.
        let crossover = (2..=12).find(|&d| spmv_cost(d) >= native_cost(d)).unwrap();
        assert!((4..=12).contains(&crossover), "crossover at {crossover}");
    }

    #[test]
    fn chosen_kernel_has_minimal_modelled_cost() {
        prop::check("adaptive picks argmin", 128, |g| {
            let edges = g.usize_in(1, 2_000_000);
            let heads = g.usize_in(1, 64);
            let dim = g.usize_in(1, 128);
            let costs = AdaptiveCosts::default();
            let choice = choose_spmm_kernel(edges, heads, dim, &costs);
            let all = modelled_costs(edges, heads, dim, &costs);
            let min = all.iter().map(|&(_, c)| c).fold(f64::INFINITY, f64::min);
            let chosen_cost = all.iter().find(|&&(k, _)| k == choice).unwrap().1;
            assert!(chosen_cost <= min + 1e-15, "{choice:?} not minimal");
        });
    }

    #[test]
    fn many_spmv_never_beats_per_head_for_dim_over_1() {
        // Same per-element rate, strictly more fixed cost when dim > 1.
        prop::check("spmv vs per-head dominance", 64, |g| {
            let edges = g.usize_in(1, 500_000);
            let heads = g.usize_in(1, 16);
            let dim = g.usize_in(2, 64);
            let c = modelled_costs(edges, heads, dim, &AdaptiveCosts::default());
            assert!(c[2].1 >= c[1].1);
        });
    }
}
