//! The Tango coordinator — the paper's system-level contribution (§3.3).
//!
//! - [`graph_ir`] — a small computation-graph IR (tensors as nodes,
//!   operators as edges) over which the caching opportunities are derived;
//! - [`reuse`] — the **detection algorithm** of §3.3: (a) tensors with more
//!   than one consumer are quantized once and cached; (b) the backward graph
//!   (reversed edges) reuses tensors already quantized in the forward graph;
//! - [`qcache`] — the quantized-tensor cache the trainer carries across a
//!   step (forward→backward) keyed by tensor id;
//! - [`adaptive`] — the kernel-count-based adaptive SPMM policy (Fig. 6 /
//!   Fig. 14): choose between the native three-matrix kernel, the per-head
//!   split, and the many-SpMV transform by modelled cost;
//! - [`trainer`] — the epoch orchestrator gluing datasets, models, the
//!   cache and metrics together (what `tango train` runs).

pub mod adaptive;
pub mod graph_ir;
pub mod qcache;
pub mod reuse;
pub mod trainer;

pub use adaptive::{choose_spmm_kernel, SpmmKernel};
pub use graph_ir::{CompGraph, OpKind, TensorId};
pub use qcache::{CacheStats, QuantCache};
pub use reuse::{detect_reuse, ReusePlan};
pub use trainer::{EpochStages, TrainReport, Trainer};
