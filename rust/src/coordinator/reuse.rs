//! The caching-opportunity **detection algorithm** (paper §3.3).
//!
//! Quoting the paper: "The computation graph consists of tensors as nodes
//! and operators as edges. For nodes with more than one out edge, we can
//! quantize once for multiple operators. [...] Then we reverse the edges in
//! the computation graph for the backward pass. In this backpropagation
//! graph, we will check if the to-be-quantized tensors are already quantized
//! in the forward graph in order to facilitate quantization sharing."
//!
//! [`detect_reuse`] runs exactly that analysis and returns a [`ReusePlan`]:
//! which tensors to cache after their first quantization, and how many
//! quantization passes the plan saves per training step.

use super::graph_ir::{CompGraph, TensorId};
use std::collections::BTreeSet;

/// The derived caching plan for one training step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReusePlan {
    /// Tensors consumed by >1 quantizable operator within one pass —
    /// quantize once, cache for the remaining consumers.
    pub multi_consumer: BTreeSet<TensorId>,
    /// Tensors quantized in the forward pass and consumed again by the
    /// backward pass — keep the forward quantized copy alive.
    pub forward_to_backward: BTreeSet<TensorId>,
    /// Total quantization passes a naive schedule would run.
    pub naive_quantizations: usize,
    /// Quantization passes after caching.
    pub cached_quantizations: usize,
}

impl ReusePlan {
    /// All tensors worth caching.
    pub fn cached_tensors(&self) -> BTreeSet<TensorId> {
        self.multi_consumer.union(&self.forward_to_backward).cloned().collect()
    }

    /// Quantization passes avoided per step.
    pub fn saved(&self) -> usize {
        self.naive_quantizations - self.cached_quantizations
    }
}

/// Run the detection algorithm over a computation graph.
pub fn detect_reuse(g: &CompGraph) -> ReusePlan {
    let mut multi_consumer = BTreeSet::new();
    let mut forward_to_backward = BTreeSet::new();
    let mut naive = 0usize;
    let mut cached = 0usize;
    for t in 0..g.num_tensors() {
        let (fwd, bwd) = g.quantizable_consumers(t);
        let total = fwd + bwd;
        naive += total;
        if total == 0 {
            continue;
        }
        // One quantization materialises the tensor; every further consumer
        // reuses it.
        cached += 1;
        // Rule (a): >1 consumer within a pass.
        if fwd > 1 || bwd > 1 {
            multi_consumer.insert(t);
        }
        // Rule (b): quantized in forward, needed again in backward.
        if fwd >= 1 && bwd >= 1 {
            forward_to_backward.insert(t);
        }
    }
    ReusePlan { multi_consumer, forward_to_backward, naive_quantizations: naive, cached_quantizations: cached }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::graph_ir::{CompGraph, OpKind};
    use crate::util::prop;

    #[test]
    fn gat_example_matches_paper_claims() {
        let (g, t) = CompGraph::gat_layer_example();
        let plan = detect_reuse(&g);
        // Paper: H^(l-1) and W are used in both forward and backward GEMMs.
        assert!(plan.forward_to_backward.contains(&t.h), "H reused fwd->bwd");
        assert!(plan.forward_to_backward.contains(&t.w), "W reused fwd->bwd");
        // Paper: H' feeds multiple forward ops and the backward SDDMM.
        assert!(plan.multi_consumer.contains(&t.h_prime));
        assert!(plan.forward_to_backward.contains(&t.h_prime));
        // Paper: ∂H^(l) feeds the backward SPMM and SDDMM — quantize once.
        assert!(plan.multi_consumer.contains(&t.d_hout));
        // Caching must save work.
        assert!(plan.saved() > 0);
        assert!(plan.cached_quantizations < plan.naive_quantizations);
    }

    #[test]
    fn lone_consumer_not_cached() {
        let mut g = CompGraph::new();
        let a = g.tensor("a");
        let b = g.tensor("b");
        let c = g.tensor("c");
        g.op(OpKind::Gemm, "g", &[a, b], &[c], false);
        let plan = detect_reuse(&g);
        assert!(plan.multi_consumer.is_empty());
        assert!(plan.forward_to_backward.is_empty());
        assert_eq!(plan.saved(), 0);
    }

    #[test]
    fn softmax_consumers_do_not_trigger_caching() {
        // alpha feeding two softmax ops is NOT a quantization-sharing case.
        let mut g = CompGraph::new();
        let a = g.tensor("a");
        let o1 = g.tensor("o1");
        let o2 = g.tensor("o2");
        g.op(OpKind::Softmax, "s1", &[a], &[o1], false);
        g.op(OpKind::Softmax, "s2", &[a], &[o2], true);
        let plan = detect_reuse(&g);
        assert!(plan.cached_tensors().is_empty());
    }

    #[test]
    fn prop_detection_never_misses_multi_consumer() {
        // Property: any tensor feeding >=2 quantizable ops in the same pass
        // is in the plan; any tensor feeding fwd+bwd is in the f2b set.
        prop::check("reuse completeness", 64, |gen| {
            let n_tensors = gen.usize_in(2, 12);
            let mut g = CompGraph::new();
            let ids: Vec<_> = (0..n_tensors).map(|i| g.tensor(&format!("t{i}"))).collect();
            let n_ops = gen.usize_in(1, 15);
            for i in 0..n_ops {
                let kind = match gen.usize_in(0, 3) {
                    0 => OpKind::Gemm,
                    1 => OpKind::Spmm,
                    2 => OpKind::Sddmm,
                    _ => OpKind::Elementwise,
                };
                let a = ids[gen.usize_in(0, n_tensors - 1)];
                let b = ids[gen.usize_in(0, n_tensors - 1)];
                let out = ids[gen.usize_in(0, n_tensors - 1)];
                g.op(kind, &format!("op{i}"), &[a, b], &[out], gen.bool(0.5));
            }
            let plan = detect_reuse(&g);
            for &t in &ids {
                let (f, b) = g.quantizable_consumers(t);
                assert_eq!(plan.multi_consumer.contains(&t), f > 1 || b > 1, "multi consumer t={t}");
                assert_eq!(plan.forward_to_backward.contains(&t), f >= 1 && b >= 1, "f2b t={t}");
            }
            // Accounting invariant: savings = total consumers - distinct
            // quantized tensors.
            let total: usize = ids.iter().map(|&t| {
                let (f, b) = g.quantizable_consumers(t);
                f + b
            }).sum();
            assert_eq!(plan.naive_quantizations, total);
        });
    }
}
