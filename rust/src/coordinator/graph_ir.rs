//! Computation-graph IR: tensors as nodes, operators as edges (paper §3.3,
//! "we derive the caching opportunity on the computation graph").
//!
//! The IR is deliberately small — just enough to express a GNN training
//! step (Fig. 1) and run the reuse-detection algorithm over it. The trainer
//! does not interpret this graph at runtime; it is the *planning* structure
//! from which the static quantization/caching schedule is derived (and the
//! hand-scheduled model code is asserted against it in tests).

/// Identifies a tensor in the computation graph.
pub type TensorId = usize;

/// Operator kinds (the primitives of §2.1 plus FP32-only glue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matmul — quantizable.
    Gemm,
    /// Sparse-dense matmul — quantizable.
    Spmm,
    /// Sampled dense-dense — quantizable.
    Sddmm,
    /// Edge/row softmax — always FP32 (§3.2).
    Softmax,
    /// Elementwise (ReLU etc.) — FP32 glue, not quantized.
    Elementwise,
    /// Parameter update — always FP32 (§3.2).
    WeightUpdate,
}

impl OpKind {
    /// Whether this operator consumes quantized inputs under Tango's rules.
    pub fn quantizable(self) -> bool {
        matches!(self, OpKind::Gemm | OpKind::Spmm | OpKind::Sddmm)
    }
}

/// One operator application.
#[derive(Debug, Clone)]
pub struct Op {
    /// Operator kind.
    pub kind: OpKind,
    /// Input tensor ids.
    pub inputs: Vec<TensorId>,
    /// Output tensor ids.
    pub outputs: Vec<TensorId>,
    /// Human-readable label (e.g. "fwd.gemm.H'").
    pub label: String,
    /// True for backward-pass operators (the reversed graph).
    pub backward: bool,
}

/// A computation graph for one training step.
#[derive(Debug, Default, Clone)]
pub struct CompGraph {
    tensors: Vec<String>,
    ops: Vec<Op>,
}

impl CompGraph {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a tensor, returning its id.
    pub fn tensor(&mut self, name: &str) -> TensorId {
        self.tensors.push(name.to_string());
        self.tensors.len() - 1
    }

    /// Register an operator.
    pub fn op(&mut self, kind: OpKind, label: &str, inputs: &[TensorId], outputs: &[TensorId], backward: bool) {
        assert!(inputs.iter().chain(outputs.iter()).all(|&t| t < self.tensors.len()));
        self.ops.push(Op {
            kind,
            inputs: inputs.to_vec(),
            outputs: outputs.to_vec(),
            label: label.to_string(),
            backward,
        });
    }

    /// All operators.
    pub fn ops(&self) -> &[Op] {
        &self.ops
    }

    /// Tensor name.
    pub fn tensor_name(&self, id: TensorId) -> &str {
        &self.tensors[id]
    }

    /// Number of tensors.
    pub fn num_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Quantizable consumers per tensor: how many quantizable ops read it,
    /// split by (forward, backward).
    pub fn quantizable_consumers(&self, id: TensorId) -> (usize, usize) {
        let mut fwd = 0;
        let mut bwd = 0;
        for op in &self.ops {
            if op.kind.quantizable() && op.inputs.contains(&id) {
                if op.backward {
                    bwd += 1;
                } else {
                    fwd += 1;
                }
            }
        }
        (fwd, bwd)
    }

    /// Build the computation graph of one **GAT layer's** training step
    /// (forward Fig. 1a + backward Fig. 1b) — the paper's running example,
    /// used by tests and by `repro` to print the derived caching plan.
    pub fn gat_layer_example() -> (CompGraph, GatTensors) {
        let mut g = CompGraph::new();
        let h = g.tensor("H");
        let w = g.tensor("W");
        let h_prime = g.tensor("H'");
        let s = g.tensor("S");
        let d = g.tensor("D");
        let e = g.tensor("E");
        let alpha = g.tensor("alpha");
        let h_out = g.tensor("H_l");
        let a_src = g.tensor("a_src");
        let a_dst = g.tensor("a_dst");
        // Forward (Fig. 1a).
        g.op(OpKind::Gemm, "fwd.gemm.H'", &[h, w], &[h_prime], false);
        g.op(OpKind::Gemm, "fwd.gemm.S", &[h_prime, a_src], &[s], false);
        g.op(OpKind::Gemm, "fwd.gemm.D", &[h_prime, a_dst], &[d], false);
        g.op(OpKind::Sddmm, "fwd.sddmm.E", &[s, d], &[e], false);
        g.op(OpKind::Softmax, "fwd.softmax.alpha", &[e], &[alpha], false);
        g.op(OpKind::Spmm, "fwd.spmm.H_l", &[alpha, h_prime], &[h_out], false);
        // Backward (Fig. 1b).
        let d_hout = g.tensor("dH_l");
        let d_hprime = g.tensor("dH'");
        let d_alpha = g.tensor("dalpha");
        let d_e = g.tensor("dE");
        let d_s = g.tensor("dS");
        let d_d = g.tensor("dD");
        let d_w = g.tensor("dW");
        let d_h = g.tensor("dH");
        g.op(OpKind::Spmm, "bwd.spmm.dH'", &[alpha, d_hout], &[d_hprime], true);
        g.op(OpKind::Sddmm, "bwd.sddmm.dalpha", &[d_hout, h_prime], &[d_alpha], true);
        g.op(OpKind::Softmax, "bwd.softmax.dE", &[d_alpha, alpha], &[d_e], true);
        g.op(OpKind::Spmm, "bwd.spmm.dS", &[d_e], &[d_s], true);
        g.op(OpKind::Spmm, "bwd.spmm.dD", &[d_e], &[d_d], true);
        g.op(OpKind::Gemm, "bwd.gemm.dW", &[h, d_hprime], &[d_w], true);
        g.op(OpKind::Gemm, "bwd.gemm.dH", &[d_hprime, w], &[d_h], true);
        g.op(OpKind::WeightUpdate, "update.W", &[w, d_w], &[], true);
        let t = GatTensors { h, w, h_prime, alpha, d_hout, d_hprime, d_e };
        (g, t)
    }
}

/// Named tensor ids of the GAT example (for tests/reports).
#[derive(Debug, Clone, Copy)]
pub struct GatTensors {
    /// Input features.
    pub h: TensorId,
    /// Weights.
    pub w: TensorId,
    /// Projected features `H'`.
    pub h_prime: TensorId,
    /// Attention scores.
    pub alpha: TensorId,
    /// Upstream gradient `∂H^(l)`.
    pub d_hout: TensorId,
    /// `∂H'`.
    pub d_hprime: TensorId,
    /// `∂E`.
    pub d_e: TensorId,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gat_example_builds() {
        let (g, t) = CompGraph::gat_layer_example();
        assert!(g.num_tensors() >= 15);
        assert_eq!(g.tensor_name(t.h_prime), "H'");
        // H' is consumed by 3 forward quantizable ops (S, D projections and
        // the aggregation SPMM) and 1 backward (SDDMM-dot).
        let (fwd, bwd) = g.quantizable_consumers(t.h_prime);
        assert_eq!(fwd, 3);
        assert_eq!(bwd, 1);
    }

    #[test]
    fn softmax_is_not_quantizable() {
        assert!(!OpKind::Softmax.quantizable());
        assert!(!OpKind::WeightUpdate.quantizable());
        assert!(OpKind::Gemm.quantizable() && OpKind::Spmm.quantizable() && OpKind::Sddmm.quantizable());
    }

    #[test]
    fn d_hout_has_two_backward_consumers() {
        // The paper's example: ∂H^(l) feeds both the backward SPMM and the
        // SDDMM-dot — the inter-primitive caching case.
        let (g, t) = CompGraph::gat_layer_example();
        let (fwd, bwd) = g.quantizable_consumers(t.d_hout);
        assert_eq!(fwd, 0);
        assert_eq!(bwd, 2);
    }
}
