//! Quantized-tensor cache (paper §3.3, Fig. 10).
//!
//! Holds the quantized copies produced during a step so later primitives
//! (same pass or backward) skip requantization. Keys are caller-chosen
//! stable ids (layer × role); entries are invalidated wholesale at the end
//! of each step because dynamic quantization re-derives scales every
//! iteration.

use crate::quant::{quantize, QTensor, Rounding};
use crate::tensor::Dense;
use std::collections::HashMap;

/// Cache statistics (drives the Fig. 10 speedup report).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Quantization passes actually executed.
    pub misses: u64,
    /// Quantization passes skipped thanks to the cache.
    pub hits: u64,
}

/// A per-step quantized tensor cache.
#[derive(Debug, Default)]
pub struct QuantCache {
    entries: HashMap<u64, QTensor>,
    stats: CacheStats,
}

impl QuantCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get the quantized form of `x` under `key`, quantizing on miss.
    ///
    /// The caller guarantees `key` uniquely identifies the tensor *value*
    /// within the current step (the trainer derives keys from layer index ×
    /// role, and clears the cache between steps).
    pub fn get_or_quantize(
        &mut self,
        key: u64,
        x: &Dense<f32>,
        bits: u8,
        rounding: Rounding,
    ) -> &QTensor {
        use std::collections::hash_map::Entry;
        match self.entries.entry(key) {
            Entry::Occupied(e) => {
                self.stats.hits += 1;
                e.into_mut()
            }
            Entry::Vacant(e) => {
                self.stats.misses += 1;
                e.insert(quantize(x, bits, rounding))
            }
        }
    }

    /// Get the cached tensor under `key`, building it with `make` on miss.
    ///
    /// Unlike [`Self::get_or_quantize`] the caller controls how the tensor
    /// is produced — the sampler's feature store quantizes per-node rows
    /// against one *shared* scale so gathered rows assemble into a single
    /// batch `QTensor`. Hit/miss accounting matches `get_or_quantize`.
    pub fn get_or_insert_with(&mut self, key: u64, make: impl FnOnce() -> QTensor) -> &QTensor {
        use std::collections::hash_map::Entry;
        match self.entries.entry(key) {
            Entry::Occupied(e) => {
                self.stats.hits += 1;
                e.into_mut()
            }
            Entry::Vacant(e) => {
                self.stats.misses += 1;
                e.insert(make())
            }
        }
    }

    /// Insert an externally produced quantized tensor (e.g. the `qa`/`qb`
    /// copies the fused GEMM stores back).
    pub fn put(&mut self, key: u64, q: QTensor) {
        self.entries.insert(key, q);
    }

    /// Look up without quantizing.
    pub fn get(&mut self, key: u64) -> Option<&QTensor> {
        let hit = self.entries.contains_key(&key);
        if hit {
            self.stats.hits += 1;
        }
        self.entries.get(&key)
    }

    /// Drop all entries (end of step — dynamic quantization re-derives
    /// scales next iteration). Stats survive.
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Cache statistics so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no live entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes held by cached quantized payloads.
    pub fn cached_bytes(&self) -> usize {
        self.entries.values().map(|q| q.stored_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;

    #[test]
    fn second_lookup_hits() {
        let mut c = QuantCache::new();
        let x = random_features(8, 8, 1);
        let q1 = c.get_or_quantize(7, &x, 8, Rounding::Nearest).clone();
        let q2 = c.get_or_quantize(7, &x, 8, Rounding::Nearest).clone();
        assert_eq!(q1, q2, "cache must return bit-identical tensors");
        assert_eq!(c.stats(), CacheStats { misses: 1, hits: 1 });
    }

    #[test]
    fn different_keys_do_not_collide() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 2);
        let y = random_features(4, 4, 3);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        c.get_or_quantize(2, &y, 8, Rounding::Nearest);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn clear_resets_entries_not_stats() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 4);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().misses, 1);
        // After clear, same key requantizes (dynamic quantization).
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn get_or_insert_with_counts_and_reuses() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 7);
        let q = crate::quant::quantize(&x, 8, Rounding::Nearest);
        let mut built = 0usize;
        for _ in 0..3 {
            let got = c.get_or_insert_with(5, || {
                built += 1;
                q.clone()
            });
            assert_eq!(got, &q);
        }
        assert_eq!(built, 1, "factory must run only on the miss");
        assert_eq!(c.stats(), CacheStats { misses: 1, hits: 2 });
    }

    #[test]
    fn put_then_get() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 5);
        let q = crate::quant::quantize(&x, 8, Rounding::Nearest);
        c.put(9, q.clone());
        assert_eq!(c.get(9), Some(&q));
        assert_eq!(c.stats().hits, 1);
        assert!(c.get(10).is_none());
    }

    #[test]
    fn cached_bytes_accounts_payloads() {
        let mut c = QuantCache::new();
        let x = random_features(8, 8, 6);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        assert_eq!(c.cached_bytes(), 64);
    }
}
