//! Quantized-tensor cache (paper §3.3, Fig. 10).
//!
//! Holds the quantized copies produced during a step so later primitives
//! (same pass or backward) skip requantization. Keys are caller-chosen
//! stable ids (layer × role); entries are invalidated wholesale at the end
//! of each step because dynamic quantization re-derives scales every
//! iteration.

use crate::quant::{quantize, QTensor, Rounding};
use crate::tensor::Dense;
use std::collections::{HashMap, VecDeque};

/// Cache statistics (drives the Fig. 10 speedup report).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Quantization passes actually executed.
    pub misses: u64,
    /// Quantization passes skipped thanks to the cache.
    pub hits: u64,
    /// Entries dropped to honour a capacity bound.
    pub evictions: u64,
}

impl CacheStats {
    /// One-line human summary (the format the CLI and examples print):
    /// hit rate, row traffic, evictions and the bytes currently cached.
    pub fn summary(&self, cached_bytes: usize) -> String {
        let total = self.hits + self.misses;
        format!(
            "{:.1}% hit rate ({} hits / {} gathered rows), {} evictions, {} KiB cached",
            self.hits as f64 / total.max(1) as f64 * 100.0,
            self.hits,
            total,
            self.evictions,
            cached_bytes / 1024
        )
    }
}

/// A quantized tensor cache, optionally bounded.
///
/// Unbounded by default (the per-step trainer cache clears every step so it
/// never grows). Long-lived caches — the sampler's hot-node feature store
/// keeps rows for a whole run — pass a capacity via [`Self::with_capacity`]
/// and oldest-first (FIFO) eviction keeps the footprint bounded; evictions
/// are counted in [`CacheStats::evictions`].
#[derive(Debug)]
pub struct QuantCache {
    entries: HashMap<u64, QTensor>,
    /// Insertion order of live keys (eviction order when bounded).
    order: VecDeque<u64>,
    /// Max live entries; `usize::MAX` = unbounded.
    capacity: usize,
    stats: CacheStats,
}

impl Default for QuantCache {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantCache {
    /// Empty, unbounded cache.
    pub fn new() -> Self {
        Self::with_capacity(usize::MAX)
    }

    /// Empty cache holding at most `capacity` entries (oldest evicted
    /// first). `capacity` must be at least 1.
    pub fn with_capacity(capacity: usize) -> Self {
        assert!(capacity >= 1, "cache capacity must be >= 1");
        QuantCache {
            entries: HashMap::new(),
            order: VecDeque::new(),
            capacity,
            stats: CacheStats::default(),
        }
    }

    /// The capacity bound (`usize::MAX` = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Insert `q` under `key`, evicting oldest entries beyond capacity.
    fn insert_bounded(&mut self, key: u64, q: QTensor) {
        if self.entries.insert(key, q).is_none() {
            self.order.push_back(key);
        }
        while self.entries.len() > self.capacity {
            match self.order.pop_front() {
                Some(old) => {
                    self.entries.remove(&old);
                    self.stats.evictions += 1;
                }
                None => break,
            }
        }
    }

    /// Get the quantized form of `x` under `key`, quantizing on miss.
    ///
    /// The caller guarantees `key` uniquely identifies the tensor *value*
    /// within the current step (the trainer derives keys from layer index ×
    /// role, and clears the cache between steps).
    pub fn get_or_quantize(
        &mut self,
        key: u64,
        x: &Dense<f32>,
        bits: u8,
        rounding: Rounding,
    ) -> &QTensor {
        if self.entries.contains_key(&key) {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
            self.insert_bounded(key, quantize(x, bits, rounding));
        }
        self.entries.get(&key).expect("key present after insert")
    }

    /// Get the cached tensor under `key`, building it with `make` on miss.
    ///
    /// Unlike [`Self::get_or_quantize`] the caller controls how the tensor
    /// is produced — the sampler's feature store quantizes per-node rows
    /// against one *shared* scale so gathered rows assemble into a single
    /// batch `QTensor`. Hit/miss accounting matches `get_or_quantize`.
    pub fn get_or_insert_with(&mut self, key: u64, make: impl FnOnce() -> QTensor) -> &QTensor {
        if self.entries.contains_key(&key) {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
            self.insert_bounded(key, make());
        }
        self.entries.get(&key).expect("key present after insert")
    }

    /// Insert an externally produced quantized tensor (e.g. the `qa`/`qb`
    /// copies the fused GEMM stores back).
    pub fn put(&mut self, key: u64, q: QTensor) {
        self.insert_bounded(key, q);
    }

    /// Look up without touching the hit/miss statistics — batch gathers
    /// classify their whole node list first and account traffic in bulk via
    /// [`Self::count_hits`]/[`Self::count_misses`].
    pub fn peek(&self, key: u64) -> Option<&QTensor> {
        self.entries.get(&key)
    }

    /// Bulk-account `n` cache hits (see [`Self::peek`]).
    pub fn count_hits(&mut self, n: u64) {
        self.stats.hits += n;
    }

    /// Bulk-account `n` cache misses (see [`Self::peek`]).
    pub fn count_misses(&mut self, n: u64) {
        self.stats.misses += n;
    }

    /// Look up without quantizing.
    pub fn get(&mut self, key: u64) -> Option<&QTensor> {
        let hit = self.entries.contains_key(&key);
        if hit {
            self.stats.hits += 1;
        }
        self.entries.get(&key)
    }

    /// Drop all entries (end of step — dynamic quantization re-derives
    /// scales next iteration). Stats survive.
    pub fn clear(&mut self) {
        self.entries.clear();
        self.order.clear();
    }

    /// Cache statistics so far.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no live entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Bytes held by cached quantized payloads.
    pub fn cached_bytes(&self) -> usize {
        self.entries.values().map(|q| q.stored_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::random_features;

    #[test]
    fn second_lookup_hits() {
        let mut c = QuantCache::new();
        let x = random_features(8, 8, 1);
        let q1 = c.get_or_quantize(7, &x, 8, Rounding::Nearest).clone();
        let q2 = c.get_or_quantize(7, &x, 8, Rounding::Nearest).clone();
        assert_eq!(q1, q2, "cache must return bit-identical tensors");
        assert_eq!(c.stats(), CacheStats { misses: 1, hits: 1, evictions: 0 });
    }

    #[test]
    fn different_keys_do_not_collide() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 2);
        let y = random_features(4, 4, 3);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        c.get_or_quantize(2, &y, 8, Rounding::Nearest);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn clear_resets_entries_not_stats() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 4);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.stats().misses, 1);
        // After clear, same key requantizes (dynamic quantization).
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn get_or_insert_with_counts_and_reuses() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 7);
        let q = crate::quant::quantize(&x, 8, Rounding::Nearest);
        let mut built = 0usize;
        for _ in 0..3 {
            let got = c.get_or_insert_with(5, || {
                built += 1;
                q.clone()
            });
            assert_eq!(got, &q);
        }
        assert_eq!(built, 1, "factory must run only on the miss");
        assert_eq!(c.stats(), CacheStats { misses: 1, hits: 2, evictions: 0 });
    }

    #[test]
    fn capacity_bound_evicts_oldest_first() {
        let mut c = QuantCache::with_capacity(2);
        let xs: Vec<_> = (0..4).map(|i| random_features(4, 4, 10 + i)).collect();
        for (i, x) in xs.iter().enumerate() {
            c.get_or_quantize(i as u64, x, 8, Rounding::Nearest);
        }
        // Keys 0 and 1 were evicted to admit 2 and 3.
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 2);
        assert!(c.get(0).is_none());
        assert!(c.get(1).is_none());
        assert!(c.get(2).is_some());
        assert!(c.get(3).is_some());
        // Re-inserting an evicted key is a fresh miss, and evicts again.
        c.get_or_quantize(0, &xs[0], 8, Rounding::Nearest);
        assert_eq!(c.stats().evictions, 3);
        assert_eq!(c.len(), 2);
        assert!(c.cached_bytes() <= 2 * 16);
    }

    #[test]
    fn overwriting_put_does_not_grow_or_evict() {
        let mut c = QuantCache::with_capacity(2);
        let x = random_features(4, 4, 20);
        let q = crate::quant::quantize(&x, 8, Rounding::Nearest);
        c.put(1, q.clone());
        c.put(2, q.clone());
        c.put(1, q.clone()); // overwrite in place
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn put_then_get() {
        let mut c = QuantCache::new();
        let x = random_features(4, 4, 5);
        let q = crate::quant::quantize(&x, 8, Rounding::Nearest);
        c.put(9, q.clone());
        assert_eq!(c.get(9), Some(&q));
        assert_eq!(c.stats().hits, 1);
        assert!(c.get(10).is_none());
    }

    #[test]
    fn cached_bytes_accounts_payloads() {
        let mut c = QuantCache::new();
        let x = random_features(8, 8, 6);
        c.get_or_quantize(1, &x, 8, Rounding::Nearest);
        assert_eq!(c.cached_bytes(), 64);
    }
}
