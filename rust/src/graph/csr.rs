//! Destination-grouped adjacency (CSR over in-edges) with edge ids.

use super::Coo;

/// Compressed sparse row adjacency, grouped by **destination** node.
///
/// Row `v` lists the in-edges of `v`: for `k` in
/// `indptr[v]..indptr[v+1]`, edge `edge_ids[k]` goes `srcs[k] -> v`.
///
/// This is the layout every aggregation in the paper's Fig. 1 walks:
/// forward SPMM (step 5) sums over in-edges, edge softmax (step 4) is a
/// segmented reduction over the same rows, and SDDMM (step 3) pairs each
/// stored edge with its endpoints. The *edge id* indirection is what lets
/// edge-feature matrices stay in original edge order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csr {
    /// Number of nodes (rows).
    pub num_nodes: usize,
    /// Number of edges (stored entries).
    pub num_edges: usize,
    /// Row offsets, length `num_nodes + 1`.
    pub indptr: Vec<usize>,
    /// Source node of each stored entry.
    pub srcs: Vec<u32>,
    /// Original edge id of each stored entry.
    pub edge_ids: Vec<u32>,
}

impl Csr {
    /// Build the in-edge CSR from an edge list (counting sort by dst).
    pub fn from_coo(coo: &Coo) -> Self {
        Self::group_by(coo.num_nodes, &coo.dst, &coo.src)
    }

    /// Build the *out-edge* CSR (the reversed graph `G^T` the backward SPMM
    /// of paper Fig. 1b step 4/5 runs on): row `v` lists edges `v -> dst`.
    pub fn from_coo_reversed(coo: &Coo) -> Self {
        Self::group_by(coo.num_nodes, &coo.src, &coo.dst)
    }

    /// Build a (possibly rectangular) grouping from parallel edge arrays:
    /// row `group_key[e]` gets the entry `(other_end[e], e)`.
    ///
    /// Unlike [`Csr::from_coo`], `other_end` values may exceed `num_rows` —
    /// the sampler's MFG blocks group edges by a compact destination set
    /// while sources index a larger frontier (`num_src >= num_dst`). The
    /// resulting `Csr` is only a row grouping; [`Csr::reverse`] assumes a
    /// square adjacency and must not be called on it.
    pub fn from_grouped_edges(num_rows: usize, group_key: &[u32], other_end: &[u32]) -> Self {
        assert_eq!(group_key.len(), other_end.len(), "group_key/other_end length mismatch");
        debug_assert!(group_key.iter().all(|&v| (v as usize) < num_rows));
        Self::group_by(num_rows, group_key, other_end)
    }

    fn group_by(num_nodes: usize, group_key: &[u32], other_end: &[u32]) -> Self {
        let m = group_key.len();
        let mut indptr = vec![0usize; num_nodes + 1];
        for &k in group_key {
            indptr[k as usize + 1] += 1;
        }
        for v in 0..num_nodes {
            indptr[v + 1] += indptr[v];
        }
        let mut cursor = indptr.clone();
        let mut srcs = vec![0u32; m];
        let mut edge_ids = vec![0u32; m];
        for e in 0..m {
            let row = group_key[e] as usize;
            let slot = cursor[row];
            srcs[slot] = other_end[e];
            edge_ids[slot] = e as u32;
            cursor[row] += 1;
        }
        Csr { num_nodes, num_edges: m, indptr, srcs, edge_ids }
    }

    /// The reversed CSR of this CSR, rebuilt through COO form.
    pub fn reverse(&self) -> Csr {
        // Reconstruct the original edge list (id -> (src, dst)) then regroup.
        let mut src = vec![0u32; self.num_edges];
        let mut dst = vec![0u32; self.num_edges];
        for v in 0..self.num_nodes {
            for k in self.indptr[v]..self.indptr[v + 1] {
                let e = self.edge_ids[k] as usize;
                src[e] = self.srcs[k];
                dst[e] = v as u32;
            }
        }
        Csr::from_coo_reversed(&Coo::new(self.num_nodes, src, dst))
    }

    /// Neighbour entries of row `v`: parallel `(srcs, edge_ids)` slices.
    #[inline]
    pub fn row(&self, v: usize) -> (&[u32], &[u32]) {
        let (a, b) = (self.indptr[v], self.indptr[v + 1]);
        (&self.srcs[a..b], &self.edge_ids[a..b])
    }

    /// Degree of row `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.indptr[v + 1] - self.indptr[v]
    }

    /// Maximum row degree (used to pad the Pallas SPMM layout).
    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes).map(|v| self.degree(v)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Coo {
        // Paper Fig. 1: e0: 1->0, e1: 3->1, e2: 1->2, e3: 0->3, e4: 2->3
        Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3])
    }

    #[test]
    fn in_edge_grouping() {
        let csr = Csr::from_coo(&toy());
        assert_eq!(csr.indptr, vec![0, 1, 2, 3, 5]);
        // v3 has in-edges e3 (from 0) and e4 (from 2)
        let (srcs, eids) = csr.row(3);
        assert_eq!(srcs, &[0, 2]);
        assert_eq!(eids, &[3, 4]);
    }

    #[test]
    fn out_edge_grouping() {
        let rev = Csr::from_coo_reversed(&toy());
        // v1 has out-edges e0 (to 0) and e2 (to 2)
        let (dsts, eids) = rev.row(1);
        assert_eq!(dsts, &[0, 2]);
        assert_eq!(eids, &[0, 2]);
    }

    #[test]
    fn reverse_of_reverse_is_identity() {
        let csr = Csr::from_coo(&toy());
        let back = csr.reverse().reverse();
        assert_eq!(csr, back);
    }

    #[test]
    fn edge_ids_cover_all_edges_once() {
        let csr = Csr::from_coo(&toy());
        let mut ids: Vec<u32> = csr.edge_ids.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn degrees_and_max() {
        let csr = Csr::from_coo(&toy());
        assert_eq!(csr.degree(0), 1);
        assert_eq!(csr.degree(3), 2);
        assert_eq!(csr.max_degree(), 2);
    }

    #[test]
    fn rectangular_grouping_for_blocks() {
        // 2 dst rows, 4 src (frontier) nodes: edges 2->0, 3->0, 1->1.
        let csr = Csr::from_grouped_edges(2, &[0, 0, 1], &[2, 3, 1]);
        assert_eq!(csr.num_nodes, 2);
        assert_eq!(csr.num_edges, 3);
        let (srcs, eids) = csr.row(0);
        assert_eq!(srcs, &[2, 3]);
        assert_eq!(eids, &[0, 1]);
        assert_eq!(csr.row(1).0, &[1]);
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::from_coo(&Coo::new(3, vec![], vec![]));
        assert_eq!(csr.num_edges, 0);
        assert_eq!(csr.max_degree(), 0);
        assert_eq!(csr.indptr, vec![0, 0, 0, 0]);
    }
}
