//! Edge-list (COO) graph form.

/// A directed graph in coordinate form. Edge `e` goes `src[e] -> dst[e]`;
/// the position in the arrays *is* the edge id, which edge-feature matrices
/// (`E`, `α`, `∂E`) are indexed by.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Coo {
    /// Number of nodes.
    pub num_nodes: usize,
    /// Source node of each edge.
    pub src: Vec<u32>,
    /// Destination node of each edge.
    pub dst: Vec<u32>,
}

impl Coo {
    /// Build from parallel edge arrays. Panics on malformed input.
    pub fn new(num_nodes: usize, src: Vec<u32>, dst: Vec<u32>) -> Self {
        assert_eq!(src.len(), dst.len(), "src/dst length mismatch");
        debug_assert!(src.iter().chain(dst.iter()).all(|&v| (v as usize) < num_nodes));
        Coo { num_nodes, src, dst }
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// Average in-degree = |E| / |V|.
    pub fn avg_degree(&self) -> f64 {
        if self.num_nodes == 0 {
            0.0
        } else {
            self.num_edges() as f64 / self.num_nodes as f64
        }
    }

    /// Add the reverse of every edge (paper §4.1: "we add the reverse edges
    /// for the directed graphs"). Self-loops are not duplicated.
    pub fn with_reverse_edges(mut self) -> Self {
        let m = self.num_edges();
        for e in 0..m {
            let (s, d) = (self.src[e], self.dst[e]);
            if s != d {
                self.src.push(d);
                self.dst.push(s);
            }
        }
        self
    }

    /// Add a self-loop to every node (paper §4.1: "self-connect edges to
    /// ensure the SPMM operation works for every node"). Nodes that already
    /// have a self-loop are skipped.
    pub fn with_self_loops(mut self) -> Self {
        let mut has_loop = vec![false; self.num_nodes];
        for e in 0..self.num_edges() {
            if self.src[e] == self.dst[e] {
                has_loop[self.src[e] as usize] = true;
            }
        }
        for v in 0..self.num_nodes {
            if !has_loop[v] {
                self.src.push(v as u32);
                self.dst.push(v as u32);
            }
        }
        self
    }

    /// Deduplicate edges (keeps first occurrence, preserves relative order).
    pub fn dedup(mut self) -> Self {
        let mut seen = std::collections::HashSet::with_capacity(self.num_edges());
        let mut src = Vec::with_capacity(self.num_edges());
        let mut dst = Vec::with_capacity(self.num_edges());
        for e in 0..self.num_edges() {
            if seen.insert((self.src[e], self.dst[e])) {
                src.push(self.src[e]);
                dst.push(self.dst[e]);
            }
        }
        self.src = src;
        self.dst = dst;
        self
    }

    /// In-degree of every node.
    pub fn in_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_nodes];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }

    /// Out-degree of every node.
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_nodes];
        for &s in &self.src {
            deg[s as usize] += 1;
        }
        deg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Coo {
        // The paper's Fig. 1 toy graph: 4 nodes, 5 edges.
        // e0: 1->0, e1: 3->1, e2: 1->2, e3: 0->3, e4: 2->3
        Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3])
    }

    #[test]
    fn basic_counts() {
        let g = toy();
        assert_eq!(g.num_nodes, 4);
        assert_eq!(g.num_edges(), 5);
        assert!((g.avg_degree() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn reverse_edges_double_non_loops() {
        let g = toy().with_reverse_edges();
        assert_eq!(g.num_edges(), 10);
        // reverse of e0 (1->0) is 0->1
        assert_eq!(g.src[5], 0);
        assert_eq!(g.dst[5], 1);
    }

    #[test]
    fn self_loops_added_once() {
        let g = toy().with_self_loops();
        assert_eq!(g.num_edges(), 9); // 5 + 4 loops
        let again = g.clone().with_self_loops();
        assert_eq!(again.num_edges(), 9);
    }

    #[test]
    fn degrees() {
        let g = toy();
        assert_eq!(g.in_degrees(), vec![1, 1, 1, 2]);
        assert_eq!(g.out_degrees(), vec![1, 2, 1, 1]);
    }

    #[test]
    fn dedup_removes_duplicates() {
        let g = Coo::new(3, vec![0, 0, 1], vec![1, 1, 2]).dedup();
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn reverse_then_loops_composition() {
        let g = toy().with_reverse_edges().with_self_loops();
        assert_eq!(g.num_edges(), 14);
        let deg = g.in_degrees();
        assert!(deg.iter().all(|&d| d >= 1), "every node reachable for SPMM");
    }
}
