//! Incidence-matrix structure (paper §3.3, Fig. 5).
//!
//! The paper reformulates the edge-gradient aggregation
//! `∂D = (G ⊙ ∂E) · 1` — a three-matrix SPMM DGL has to emulate with an
//! all-ones node-feature matrix — as a plain two-matrix product
//! `incidence × edge_features`, where the incidence matrix is `V × E` with
//! a 1 wherever edge `e` is incident to node `v`. Because a node's incident
//! edge ids are stored *contiguously*, the random access pattern is far more
//! regular than walking the adjacency matrix (paper Table 2).

use super::{Coo, Csr};

/// Node→incident-edge-id lists in CSR layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Incidence {
    /// Number of nodes (rows).
    pub num_nodes: usize,
    /// Number of edges (columns of the conceptual V×E matrix).
    pub num_edges: usize,
    /// Row offsets, length `num_nodes + 1`.
    pub indptr: Vec<usize>,
    /// Incident edge ids, grouped per node.
    pub edge_ids: Vec<u32>,
}

impl Incidence {
    /// Incidence over **in-edges**: row `v` lists edges with `dst == v`
    /// (computes `∂D = (G ⊙ ∂E) · 1`).
    pub fn in_edges(coo: &Coo) -> Self {
        Self::build(coo.num_nodes, &coo.dst)
    }

    /// Incidence over **out-edges**: row `v` lists edges with `src == v`
    /// (computes `∂S = (G^T ⊙ ∂E) · 1`).
    pub fn out_edges(coo: &Coo) -> Self {
        Self::build(coo.num_nodes, &coo.src)
    }

    /// Derive directly from an in-edge [`Csr`] (shares the grouping).
    pub fn from_csr(csr: &Csr) -> Self {
        Incidence {
            num_nodes: csr.num_nodes,
            num_edges: csr.num_edges,
            indptr: csr.indptr.clone(),
            edge_ids: csr.edge_ids.clone(),
        }
    }

    fn build(num_nodes: usize, endpoint: &[u32]) -> Self {
        let m = endpoint.len();
        let mut indptr = vec![0usize; num_nodes + 1];
        for &v in endpoint {
            indptr[v as usize + 1] += 1;
        }
        for v in 0..num_nodes {
            indptr[v + 1] += indptr[v];
        }
        let mut cursor = indptr.clone();
        let mut edge_ids = vec![0u32; m];
        for (e, &v) in endpoint.iter().enumerate() {
            edge_ids[cursor[v as usize]] = e as u32;
            cursor[v as usize] += 1;
        }
        Incidence { num_nodes, num_edges: m, indptr, edge_ids }
    }

    /// Incident edge ids of node `v`.
    #[inline]
    pub fn row(&self, v: usize) -> &[u32] {
        &self.edge_ids[self.indptr[v]..self.indptr[v + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Coo {
        Coo::new(4, vec![1, 3, 1, 0, 2], vec![0, 1, 2, 3, 3])
    }

    #[test]
    fn in_edge_incidence_matches_paper_example() {
        // Paper Fig. 5: v3's in-edges are e3 and e4.
        let inc = Incidence::in_edges(&toy());
        assert_eq!(inc.row(3), &[3, 4]);
        assert_eq!(inc.row(0), &[0]);
    }

    #[test]
    fn out_edge_incidence() {
        let inc = Incidence::out_edges(&toy());
        // v1 sources e0 and e2.
        assert_eq!(inc.row(1), &[0, 2]);
        // v3 sources e1.
        assert_eq!(inc.row(3), &[1]);
    }

    #[test]
    fn from_csr_equals_in_edges() {
        let g = toy();
        let a = Incidence::in_edges(&g);
        let b = Incidence::from_csr(&Csr::from_coo(&g));
        assert_eq!(a, b);
    }

    #[test]
    fn every_edge_appears_exactly_once() {
        let inc = Incidence::in_edges(&toy());
        let mut ids = inc.edge_ids.clone();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn edge_ids_contiguous_per_node() {
        // The locality claim behind Table 2: a node's incident edges are
        // adjacent in memory.
        let inc = Incidence::in_edges(&toy());
        let total: usize = (0..4).map(|v| inc.row(v).len()).sum();
        assert_eq!(total, inc.num_edges);
    }
}
